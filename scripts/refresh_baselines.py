"""Regenerate the committed benchmark baselines in ``benchmarks/baselines/``.

Runs the baseline-gated suites through ``benchmarks.run --tiny --json`` (the
same path CI measures) and writes one ``BENCH_<suite>.json`` per suite, each
row stamped with this host's device/backend/jax metadata so the gate
(``benchmarks.baseline``) knows when a comparison crosses machines.

Usage (from the repo root):
    PYTHONPATH=src python scripts/refresh_baselines.py            # tiny (CI)
    PYTHONPATH=src python scripts/refresh_baselines.py --full     # full size
    PYTHONPATH=src python scripts/refresh_baselines.py --suites serve_qps
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

SUITES = ("serve_qps", "cache_sim", "cache_drift")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(REPO, "benchmarks", "baselines")


def refresh(suite: str, *, tiny: bool) -> str:
    out = os.path.join(OUT_DIR, f"BENCH_{suite}.json")
    cmd = [sys.executable, "-m", "benchmarks.run", "--only", suite,
           "--json", out]
    if tiny:
        cmd.append("--tiny")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH")) if p
    )
    print(f"$ {' '.join(cmd)}")
    subprocess.run(cmd, check=True, cwd=REPO, env=env)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suites", default=",".join(SUITES),
                    help=f"comma-separated (default {','.join(SUITES)})")
    ap.add_argument("--full", action="store_true",
                    help="full-size configs instead of --tiny (slow)")
    args = ap.parse_args(argv)

    os.makedirs(OUT_DIR, exist_ok=True)
    for suite in args.suites.split(","):
        path = refresh(suite.strip(), tiny=not args.full)
        print(f"# refreshed {path}")
    print("# review the diff, then commit benchmarks/baselines/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
