"""Regenerate the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/**.json.  Hand-written narrative (§Perf hypotheses, claims
validation) lives in EXPERIMENTS.header.md / EXPERIMENTS.perf.md and is
stitched in verbatim.

Run:  PYTHONPATH=src python scripts/make_experiments.py
"""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import terms  # noqa: E402

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")


def load(tagged=False):
    recs = []
    for path in sorted(glob.glob(os.path.join(ROOT, "experiments/dryrun/*/*.json"))):
        with open(path) as f:
            r = json.load(f)
        r["_file"] = os.path.basename(path)
        is_variant = "__config" not in path
        if tagged == is_variant:
            recs.append(r)
    return recs


def fmt_bytes(n):
    return f"{n / 2**30:.2f} GiB"


def dryrun_section(recs):
    out = ["## §Dry-run — 40 cells x {16x16, 2x16x16} lower+compile", ""]
    ok = [r for r in recs if r.get("status") == "run"]
    skip = [r for r in recs if str(r.get("status", "")).startswith("skip")]
    out.append(
        f"**{len(ok)} cells compiled clean** (32 runnable cells x 2 meshes), "
        f"{len(skip)} recorded skips (8 shape-rule skips x 2 meshes). "
        "Every record holds `memory_analysis()`, `cost_analysis()`, the "
        "loop-aware HLO analysis and the collective schedule "
        "(`experiments/dryrun/<mesh>/<arch>__<shape>__config.json`)."
    )
    out.append("")
    out.append("| arch | shape | mesh | mb | params | arg B/dev | temp B/dev | "
               "peak est | compile s | collectives (loop-adjusted counts) |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        m = r["memory"]
        cc = r["hlo"]["coll_counts"]
        cstr = " ".join(f"{k.split('-')[-1]}:{v}" for k, v in cc.items() if v)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['microbatches']} | "
            f"{r['params_total']/1e9:.2f}B | {fmt_bytes(m['argument_bytes'])} | "
            f"{fmt_bytes(m['temp_bytes'])} | {fmt_bytes(m['peak_est_bytes'])} | "
            f"{r['compile_s']:.0f} | {cstr} |"
        )
    out.append("")
    out.append("Skipped cells (per the assignment's shape rules):")
    seen = set()
    for r in skip:
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        out.append(f"- {r['arch']} x {r['shape']}: {r['status']}")
    out.append("")
    return "\n".join(out)


def roofline_section(recs):
    out = ["## §Roofline — three terms per (arch x shape), single-pod", ""]
    out.append(
        "Terms from the loop-aware HLO analyzer over the compiled per-device "
        "module (v5e constants: 197 TF/s bf16, 819 GB/s HBM, 2x50 GB/s ICI "
        "ring): compute = FLOPs/peak, memory = bytes/HBM_bw, collective = "
        "ring-effective wire bytes/ICI_bw. step est = max(terms); "
        "MFU_model = MODEL_FLOPS/chips/peak/step."
    )
    out.append("")
    out.append("| arch | shape | mesh | compute s | memory s | collective s | "
               "dominant | MODEL_FLOPS | useful/HLO | MFU_model | roofline frac |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        if r.get("status") != "run":
            continue
        t = terms(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | {t['dominant']} | "
            f"{r['model_flops']:.2e} | {t['useful_flops_ratio']:.2f} | "
            f"{t['mfu_model']:.3f} | {t['roofline_fraction']:.3f} |"
        )
    out.append("")
    return "\n".join(out)


def variants_section(recs):
    if not recs:
        return ""
    out = ["### §Perf variant cells (hillclimb artifacts)", ""]
    out.append("| file | arch | shape | variant | compute s | memory s | "
               "collective s | dominant | step est s |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["_file"])):
        if r.get("status") != "run":
            continue
        t = terms(r)
        v = {k: x for k, x in (r.get("variant") or {}).items() if x}
        v["emb"] = r.get("embedding")
        out.append(
            f"| {r['_file']} | {r['arch']} | {r['shape']} | {v} | "
            f"{t['compute_s']:.3f} | {t['memory_s']:.3f} | "
            f"{t['collective_s']:.3f} | {t['dominant']} | {t['step_s']:.3f} |"
        )
    out.append("")
    return "\n".join(out)


def main():
    base = load(tagged=False)
    tagged = load(tagged=True)
    parts = []
    hdr = os.path.join(ROOT, "EXPERIMENTS.header.md")
    if os.path.exists(hdr):
        parts.append(open(hdr).read())
    parts.append(dryrun_section(base))
    parts.append(roofline_section(base))
    perf = os.path.join(ROOT, "EXPERIMENTS.perf.md")
    if os.path.exists(perf):
        parts.append(open(perf).read())
    parts.append(variants_section(tagged))
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(parts))
    print("EXPERIMENTS.md written")


if __name__ == "__main__":
    main()
