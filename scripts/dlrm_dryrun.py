import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""Production-mesh dry-run for the paper's own model: DLRM with QR tables.

Lowers one training step of the full-size DLRM (26 tables x 2M rows x 128
dims; QR c=64 -> 26 x (31.25K + 64) physical rows) on the 16x16 mesh with the
two-level sharded GnR, and the dense-table baseline next to it. Writes
records next to the LM grid (experiments/dryrun/pod1/dlrm__*.json).

Run:  PYTHONPATH=src python scripts/dlrm_dryrun.py
"""

import dataclasses
import gzip
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import sharded_embedding as SE
from repro.distributed import sharding as SH
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import dlrm
from repro.train import optimizer as opt_mod
from repro.train.train_step import make_dlrm_loss, make_train_step


def lower(cfg, tag: str, batch: int = 65536) -> dict:
    mesh = make_production_mesh()
    rules = dict(SH.DEFAULT_RULES)

    params_sds = jax.eval_shape(
        lambda k: dlrm.init_dlrm(k, cfg)[0], jax.random.PRNGKey(0)
    )
    # table shardings: Q/dense rows over `model` (padded), R replicated (LUT)
    def table_shard(t):
        out = {}
        for k, v in t.items():
            if k in ("q", "table", "g2"):
                rows = -(-v.shape[0] // SE.ROW_PAD) * SE.ROW_PAD
                spec = P("model", None) if rows % mesh.shape["model"] == 0 else P()
                out[k] = NamedSharding(mesh, spec)
            else:
                out[k] = NamedSharding(mesh, P())
        return out

    pshard = {
        "bottom": jax.tree.map(lambda _: NamedSharding(mesh, P()), params_sds["bottom"]),
        "top": jax.tree.map(lambda _: NamedSharding(mesh, P()), params_sds["top"]),
        "tables": [table_shard(t) for t in params_sds["tables"]],
    }
    # pad tables abstractly so the model axis divides rows
    params_sds = jax.eval_shape(
        lambda p: dlrm.pad_tables_for_mesh(p, cfg, mesh.shape["model"]), params_sds
    )
    opt_sds = jax.eval_shape(opt_mod.init, params_sds)
    opt_shard = {"mu": pshard, "nu": pshard, "step": NamedSharding(mesh, P())}

    batch_sds = {
        "dense": jax.ShapeDtypeStruct((batch, cfg.num_dense), jnp.float32),
        "idx": jax.ShapeDtypeStruct((batch, cfg.num_tables, cfg.pooling), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }
    bshard = {
        k: NamedSharding(mesh, P("data", *([None] * (len(v.shape) - 1))))
        for k, v in batch_sds.items()
    }

    loss0 = make_dlrm_loss(cfg)

    def loss_fn(p, b):
        with SH.use_rules(mesh, rules):
            return loss0(p, b)

    step = make_train_step(loss_fn, opt_mod.OptConfig(), microbatches=8)
    fn = jax.jit(step, in_shardings=(pshard, opt_shard, bshard),
                 out_shardings=(pshard, opt_shard, None))
    t0 = time.time()
    lowered = fn.lower(params_sds, opt_sds, batch_sds)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    rec = {
        "arch": f"dlrm-{tag}", "shape": f"train_b{batch}", "mesh": "pod1",
        "kind": "train", "embedding": cfg.embedding_kind, "status": "run",
        "params_total": sum(
            int(jnp.prod(jnp.array(x.shape))) for x in jax.tree.leaves(params_sds)
        ),
        "logical_embedding_params": cfg.num_tables * cfg.vocab_per_table * cfg.dim,
        "model_flops": 0,
        "microbatches": 8,
        "compile_s": round(time.time() - t0, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_est_bytes": int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes),
        },
        "hlo": hlo_analysis.analyze(hlo),
        "chips": mesh.size,
    }
    path = f"experiments/dryrun/pod1/dlrm__{tag}.json"
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with gzip.open(path.replace(".json", ".hlo.gz"), "wt") as g:
        g.write(hlo)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    h = rec["hlo"]
    print(f"dlrm-{tag}: compiled in {rec['compile_s']}s | params "
          f"{rec['params_total']/1e6:.1f}M phys (embedding logical "
          f"{rec['logical_embedding_params']/1e9:.1f}B) | bytes/dev "
          f"{h['bytes']:.2e} | coll wire {h['coll_wire_total']:.2e} | "
          f"peak {rec['memory']['peak_est_bytes']/2**30:.2f} GiB")
    return rec


def main():
    from repro.configs import registry

    qr = lower(registry.get_dlrm("dlrm-qr"), "qr")
    tt = lower(registry.get_dlrm("dlrm-tt"), "tt")
    dense = lower(registry.get_dlrm("dlrm-dense"), "dense")
    m_qr = qr["hlo"]["bytes"] / 819e9
    m_tt = tt["hlo"]["bytes"] / 819e9
    m_d = dense["hlo"]["bytes"] / 819e9
    print(f"memory term: dense {m_d*1000:.1f} ms vs qr {m_qr*1000:.1f} ms vs "
          f"tt {m_tt*1000:.1f} ms per step "
          f"(capacity {dense['params_total']/qr['params_total']:.0f}x larger dense)")


if __name__ == "__main__":
    main()
