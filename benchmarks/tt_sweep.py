"""TT-Rec rank/factorization sweep (paper Fig. 5/6 + Table 3 analog for the
tensor-train path).

Sweeps the TT rank and the vocab factorization shape and reports, per point:

* compression vs the dense table (capacity story);
* SRAM footprint of the pinned outer cores (must stay bg-PIM/VMEM sized);
* analytic DRAM bytes per bag: dense vs naive TT (3 cores from DRAM) vs fused
  (outer cores pinned) — the traffic-amplification trade-off that motivates
  the SRAM cache;
* measured wall-time of the fused Pallas bag kernel vs the jnp reference on
  this host (ratios are the tracking target, not absolutes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro.core import qr_embedding as QE, tt_embedding as TT
from repro.core.embedding_bag import BagConfig, traffic_model
from repro.core.qr_embedding import EmbeddingConfig
from repro.kernels import ops, ref


def _cfg(vocab, dim, rank, vf=None):
    return EmbeddingConfig(
        vocab=vocab, dim=dim, kind="tt", tt_rank=rank, tt_vocab_factors=vf,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )


def rank_sweep(vocab=2_000_000, dim=128, pooling=32) -> None:
    for rank in (4, 8, 16, 32):
        cfg = _cfg(vocab, dim, rank)
        spec = cfg.tt_spec
        t = traffic_model(BagConfig(emb=cfg, pooling=pooling), bytes_per_elem=4)
        emit(
            f"tt_sweep/rank{rank}_dim{dim}", 0.0,
            f"factors={spec.vocab_factors}x{spec.dim_factors} "
            f"compression={spec.compression:.0f}x sram={spec.sram_bytes()}B "
            f"dense={t['dense']}B naive_tt={t['naive']}B fused={t['fused']}B "
            f"amplification={t['naive'] / t['dense']:.2f}x "
            f"fused_vs_dense={t['fused'] / t['dense']:.2f}x",
        )


def factorization_sweep(vocab=2_000_000, dim=128, rank=16) -> None:
    """Outer-factor size trades SRAM footprint against middle-core rows
    (hot-tier granularity) at ~constant compression."""
    for outer in (16, 38, 128, 512):
        mid = -(-vocab // (outer * outer))
        cfg = _cfg(vocab, dim, rank, vf=(outer, mid, outer))
        spec = cfg.tt_spec
        emit(
            f"tt_sweep/factor_outer{outer}", 0.0,
            f"factors={spec.vocab_factors} compression={spec.compression:.0f}x "
            f"sram={spec.sram_bytes()}B mid_rows={spec.v2} "
            f"(outer^ => sram^ but finer mid tiering)",
        )


def measured_kernel(vocab=65536, dim=128, rank=8, batch=256, pooling=16) -> None:
    cfg = _cfg(vocab, dim, rank)
    spec = cfg.tt_spec
    params = QE.init(jax.random.PRNGKey(0), cfg)
    idx = jax.random.randint(jax.random.PRNGKey(1), (batch, pooling), 0, vocab)
    i1, i2, i3 = TT.tt_decompose(idx, spec)
    dims = (spec.d1, spec.d2, spec.d3, spec.rank)

    f_ref = jax.jit(
        lambda p, a, b, c: ref.tt_bag_ref(p["g1"], p["g2"], p["g3"], a, b, c, dims=dims)
    )
    t_ref = time_jit(f_ref, params, i1, i2, i3)
    f_kernel = lambda p, a, b, c: ops.tt_pooled(
        p["g1"], p["g2"], p["g3"], a, b, c, dims=dims
    )
    t_kernel = time_jit(f_kernel, params, i1, i2, i3)
    # engine front-door bag (what the model path runs): one-table GnR via
    # the packed megakernel dispatch (jnp oracle on CPU)
    from repro import engine as engine_mod

    bag = BagConfig(emb=cfg, pooling=pooling)
    eng = engine_mod.engine_for(engine_mod.EngineSpec.from_bags((bag,)))
    f_mod = jax.jit(lambda p, i: eng.lookup([p], i[:, None, :])[:, 0])
    t_mod = time_jit(f_mod, params, idx)

    emit("tt_sweep/measured_ref_bag", t_ref, f"batch={batch} pooling={pooling} rank={rank}")
    emit("tt_sweep/measured_engine_bag", t_mod, f"vs_ref={t_ref / t_mod:.2f}x")
    emit(
        "tt_sweep/measured_pallas_bag", t_kernel,
        "interpret-mode on CPU: parity target, not a speed target",
    )


def run(tiny: bool = False) -> None:
    if tiny:
        # CI smoke: same code paths at toy sizes
        rank_sweep(vocab=4096, dim=32, pooling=4)
        factorization_sweep(vocab=4096, dim=32, rank=4)
        measured_kernel(vocab=4096, dim=32, rank=4, batch=8, pooling=4)
        return
    rank_sweep()
    factorization_sweep()
    measured_kernel()
