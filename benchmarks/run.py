"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure family; each prints CSV rows
``name,us_per_call,derived``. ``--only`` selects a subset.
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import collision_sweep, design_opt, locality, roofline, traffic

SUITES = {
    "traffic": traffic.run,            # paper: weight-sharing traffic table
    "locality": locality.run,          # paper: Q/R temporal locality figures
    "design_opt": design_opt.run,      # paper: design-optimization ladder
    "collision_sweep": collision_sweep.run,  # paper: shortcoming analyses
    "roofline": roofline.run,          # deliverable (g)
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    for n in names:
        t0 = time.time()
        try:
            SUITES[n]()
            print(f"# suite {n} done in {time.time() - t0:.1f}s")
        except Exception as e:  # keep the harness going; failures are visible
            import traceback

            traceback.print_exc()
            print(f"{n}/SUITE_FAILED,0.00,{type(e).__name__}: {e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
