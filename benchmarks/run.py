"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure family; each prints CSV rows
``name,us_per_call,derived``. ``--only`` selects a subset.
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import common
from benchmarks import (
    autotune, cache_sim, collision_sweep, design_opt, locality, roofline,
    serve_qps, serve_storm, traffic, tt_sweep,
)

SUITES = {
    "traffic": traffic.run,            # paper: weight-sharing traffic table (QR + TT)
    "locality": locality.run,          # paper: Q/R + TT-core temporal locality
    "design_opt": design_opt.run,      # paper: design-optimization ladders
    "collision_sweep": collision_sweep.run,  # paper: shortcoming analyses
    "tt_sweep": tt_sweep.run,          # paper: TT rank/factorization trade-off
    "cache_sim": cache_sim.run,        # paper: SRAM cache + duplication sweep
    "cache_drift": cache_sim.run_drift,  # online adaptation: hot-set rotation
    "serve_qps": serve_qps.run,        # measured QPS: packed megakernel pipeline
    "serve_storm": serve_storm.run,    # resilient front end: flash crowds + chaos
    "roofline": roofline.run,          # deliverable (g)
    "autotune": autotune.run,          # cost-model fidelity + tuned-vs-heuristic
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all emitted rows as JSON (perf trajectory)")
    ap.add_argument("--tiny", action="store_true",
                    help="shrunk configs for suites that support them (CI smoke)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for suites that take one (stamped into their "
                         "JSON rows so any row reproduces its run)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for n in names:
        t0 = time.time()
        try:
            import inspect

            fn = SUITES[n]
            sig = inspect.signature(fn).parameters
            kw = {}
            if args.tiny and "tiny" in sig:
                kw["tiny"] = True
            if "seed" in sig:
                kw["seed"] = args.seed
            fn(**kw)
            wall = time.time() - t0
            # wall-clock rides the emitted rows so --json tracks a MEASURED
            # perf trajectory across PRs, not just modeled traffic
            common.emit(f"run/{n}_wall", wall * 1e6, f"suite wall-clock {wall:.1f}s")
        except Exception as e:  # keep the harness going; failures are visible
            import traceback

            traceback.print_exc()
            print(f"{n}/SUITE_FAILED,0.00,{type(e).__name__}: {e}")
            failed.append(n)
    if args.json:
        common.write_json(args.json)
    if failed:  # every suite still ran, but CI must see the breakage
        print(f"# FAILED suites: {','.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
