"""Autotuner benchmark: cost-model fidelity + tuned-vs-heuristic serving QPS.

Maps to the paper's design-space exploration figures (cache size / duplication
budget ladders): instead of sweeping blindly, the fitted cost model
(``repro.tune``) predicts the ladder and this suite reports how well those
predictions track reality:

* ``autotune/rank_agreement``    — fraction of candidate pairs whose
  predicted latency order matches the measured order (the acceptance bar is
  >= 0.8 over pairs separated by more than noise);
* ``autotune/cand_*``            — per-candidate measured vs predicted us;
* ``autotune/tuned_vs_heuristic``— steady-state ``serve_qps`` of the tuned
  plan against the heuristic plan through the same pipeline;
* ``autotune/drift``             — the ``repro.obs.drift`` monitor's verdict
  over the fit's own samples (rank-agreement floor; a re-fit recommendation
  here means the freshly fitted model is already wrong on this host).

CLI (the CI smoke step): ``python -m benchmarks.autotune --tiny --artifacts
DIR`` additionally writes ``cost_model.json`` (the fitted models + samples)
and ``plan_summary.json`` (the tuned plan) to DIR.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks.common import emit
from repro.obs.drift import DriftMonitor, rank_agreement


def run(tiny: bool = False, artifacts_dir: str | None = None) -> None:
    import jax
    import numpy as np

    from repro import tune
    from repro.configs import registry
    from repro.data import synthetic
    from repro.engine import EngineSpec
    from repro.launch import serve_rec
    from repro.models import dlrm

    cfg = registry.get_dlrm("dlrm-qr-smoke")
    batch, batches, repeats = (8, 5, 3) if tiny else (16, 8, 3)
    max_samples = 6 if tiny else 12

    spec = EngineSpec.from_dlrm(cfg, serving=True)
    traces = [
        synthetic.zipf_trace(cfg.vocab_per_table, 50_000, alpha=1.05,
                             seed=7 + t)
        for t in range(cfg.num_tables)
    ]

    # fit on timed micro-runs of the real execution paths on THIS host, so
    # predictions and the serving measurement share a machine.
    t0 = time.time()
    tuner = tune.fit(
        spec, traces, mode="measure", batch=batch, num_shards=4,
        max_samples=max_samples, repeats=repeats,
    )
    fit_wall = time.time() - t0
    emit(
        "autotune/fit_wall", fit_wall * 1e6,
        f"mode={tuner.source} samples={len(tuner.samples)} "
        f"device={tuner.metadata['device_kind']}",
    )

    # predicted-vs-measured over the fit's observations (both backends, both
    # probe batch sizes — cross-backend and cross-size orderings are exactly
    # what the backend knob and the per-byte term must get right)
    scored = []
    for i, s in enumerate(tuner.samples):
        pred = tuner.models[s.knobs.backend].predict(s.features)
        scored.append((pred, s.measured_s))
        emit(
            f"autotune/cand_{i}", s.measured_s * 1e6,
            f"pred={pred * 1e6:.1f}us {s.knobs.describe()}",
        )
    agreement, pairs = rank_agreement(scored)
    emit(
        "autotune/rank_agreement", 0.0,
        f"agreement={agreement:.2f} over {pairs} rankable pairs "
        f"(of {len(scored) * (len(scored) - 1) // 2})",
    )

    # tuned vs heuristic plans through the same serving pipeline
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
    state_h = serve_rec.build_serve_state(cfg, shards=4, alpha=1.05, seed=0)
    state_t = serve_rec.build_serve_state(cfg, shards=4, alpha=1.05, seed=0,
                                          tuner=tuner)
    same_plan = state_t.eplan == state_h.eplan
    qps = {}
    for name, state in (("heuristic", state_h), ("tuned", state_t)):
        if name == "tuned" and same_plan:
            qps["tuned"] = qps["heuristic"]    # identical plan: don't re-time
            continue
        best = None
        for _ in range(repeats):
            res = serve_rec.run_pipeline(
                cfg, batch=batch, batches=batches, mode="overlap",
                state=state, params=params,
            )
            if best is None or res["wall_s"] < best["wall_s"]:
                best = res
        qps[name] = best["qps"]
        us = best["wall_s"] * 1e6 / max(1, batches - 1)
        emit(f"autotune/serve_{name}", us,
             f"qps={best['qps']:.1f} hit={best['hit_rate']:.3f}")
    ratio = qps["tuned"] / max(qps["heuristic"], 1e-9)
    emit(
        "autotune/tuned_vs_heuristic", 0.0,
        f"tuned/heuristic={ratio:.2f}x "
        + ("(tuned plan == heuristic plan)" if same_plan
           else f"knobs={state_t.eplan.knobs.describe()}"),
    )

    # drift verdict over the fit's own samples: a refit recommendation right
    # after fitting means the model is broken on this host.  (Serving-time
    # drift uses the tuned state's own monitor — run_pipeline feeds it —
    # which stays separate because micro-run and pipeline latencies differ
    # by a constant the residual monitor would misread as drift.)
    monitor = DriftMonitor(min_points=min(4, len(scored)))
    for pred, meas in scored:
        monitor.observe(pred, meas)
    d = monitor.summary()
    emit(
        "autotune/drift", 0.0,
        f"refit_recommended={d['refit_recommended']} "
        f"drift={d['drift']:.2f} (tol {d['rel_tol']}) "
        f"rank={d['rank_agreement']:.2f}/{d['rankable_pairs']}p "
        f"n={d['observations']}",
    )

    if artifacts_dir:
        os.makedirs(artifacts_dir, exist_ok=True)
        with open(os.path.join(artifacts_dir, "cost_model.json"), "w") as f:
            json.dump(tuner.describe(), f, indent=1)
        with open(os.path.join(artifacts_dir, "plan_summary.json"), "w") as f:
            json.dump(state_t.engine.summary(), f, indent=1)
        print(f"# wrote cost_model.json + plan_summary.json to {artifacts_dir}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="write cost_model.json + plan_summary.json here")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(tiny=args.tiny, artifacts_dir=args.artifacts)
    return 0


if __name__ == "__main__":
    sys.exit(main())
