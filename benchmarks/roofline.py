"""Roofline derivation from the dry-run's compiled artifacts (deliverable g).

For every (arch x shape x mesh) JSON produced by ``repro.launch.dryrun``:

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = ring-effective wire bytes per chip / interconnect bw
                    (ICI for data/model axes; DCN for the pod axis, classified
                    by replica-group size == num_pods on multi-pod records)

All three use the loop-aware HLO analyzer (see launch/hlo_analysis.py), so a
94-layer scan counts 94 body executions.  The dominant term is the bottleneck;
step-time estimate = max(terms) (perfect-overlap roofline);

  MFU_model  = MODEL_FLOPS / chips / peak / step_time   (useful-work MFU)
  roofline fraction = compute_term / step_time          (1.0 = compute-bound)

The compute/memory/collective -> seconds conversion routes through
``repro.obs.attribution.model_terms`` — the SAME pricing the serving
attribution table uses — and every cell's terms are also written in the
``stage-attribution/v1`` row schema (``experiments/roofline_rows.json``), so
dry-run rooflines and serving reports join on one vocabulary.

Methodology caveats recorded in EXPERIMENTS.md: the HLO comes from the CPU
backend (fp32-promoted dots, different fusion choices than TPU), so absolute
terms are conservative; comparisons across variants of the same cell are
apples-to-apples.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.launch.mesh import (
    DCN_BW, HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16,
)
from repro.obs import attribution as obs_attribution

ICI_BW = 2 * ICI_BW_PER_LINK     # bidirectional ring on one torus dimension


def load_records(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*", "*.json"))):
        with open(path) as f:
            r = json.load(f)
        r["_path"] = path
        recs.append(r)
    return recs


def terms(rec: dict) -> dict | None:
    if rec.get("status") != "run" or "hlo" not in rec:
        return None
    h = rec["hlo"]
    chips = rec["chips"]
    wire = h["coll_wire_total"]
    wire_bw = ICI_BW
    if rec["mesh"] == "pod2":
        # group-size==2 collectives ride DCN (the pod axis); approximate the
        # split by attributing all-reduce wire with g==2 proportionally.
        dcn_share = 0.0
        if dcn_share > 0:
            wire_bw = 1.0 / ((1 - dcn_share) / ICI_BW + dcn_share / DCN_BW)
    # bytes/flops -> seconds via the shared serving-attribution pricing
    t = obs_attribution.model_terms(
        flops=h["flops"], hbm_bytes=h["bytes"], wire_bytes=wire,
        peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, wire_bw=wire_bw,
    )
    step = t["step_s"]
    mfu = rec["model_flops"] / chips / PEAK_FLOPS_BF16 / step
    return {
        **t,
        "mfu_model": mfu,
        "roofline_fraction": t["compute_s"] / step,
        "useful_flops_ratio": rec["model_flops"] / chips / max(h["flops"], 1.0),
        "_hbm_bytes": h["bytes"],
        "_wire_bytes": wire,
    }


_LEVERS = {
    "compute": "cut redundant FLOPs (remat policy, QR-factorized logits head)",
    "memory": "shrink activation traffic (bf16 residuals, fused attention "
              "blocks, bigger microbatches)",
    "collective": "reshard to cut all-gathers (FSDP prefetch, 2D sharded "
                  "embedding combine, overlap with compute)",
}


def table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | emb | compute s | memory s | collective s | "
        "dominant | MODEL_TF | useful ratio | MFU_model | lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        t = terms(r)
        if t is None:
            status = r.get("status", "?")
            if status != "run":
                lines.append(
                    f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} | "
                    f"{r.get('embedding','-')} | — | — | — | {status} | | | | |"
                )
            continue
        lines.append(
            "| {arch} | {shape} | {mesh} | {emb} | {c:.3f} | {m:.3f} | {x:.3f} | "
            "**{dom}** | {mf:.0f} | {ur:.2f} | {mfu:.3f} | {lever} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                emb=r.get("embedding", "-"),
                c=t["compute_s"], m=t["memory_s"], x=t["collective_s"],
                dom=t["dominant"], mf=r["model_flops"] / 1e12,
                ur=t["useful_flops_ratio"], mfu=t["mfu_model"],
                lever=_LEVERS[t["dominant"]],
            )
        )
    return "\n".join(lines)


def run() -> None:
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "run"]
    emit("roofline/cells_compiled", 0.0, f"{len(ok)} run records loaded")
    doms = {}
    cells = []
    for r in ok:
        t = terms(r)
        if t:
            doms[t["dominant"]] = doms.get(t["dominant"], 0) + 1
            emit(
                f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}/{r.get('embedding')}",
                t["step_s"] * 1e6,
                f"dom={t['dominant']} c={t['compute_s']:.3f}s m={t['memory_s']:.3f}s "
                f"x={t['collective_s']:.3f}s mfu={t['mfu_model']:.3f} "
                f"useful={t['useful_flops_ratio']:.2f}",
            )
            # the same terms in the serving-attribution row schema
            cells.append({
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "embedding": r.get("embedding"),
                "schema": obs_attribution.SCHEMA,
                "dominant": t["dominant"],
                "step_s": t["step_s"],
                "rows": obs_attribution.term_rows(
                    t, hbm_bytes=t["_hbm_bytes"], wire_bytes=t["_wire_bytes"],
                ),
            })
    emit("roofline/dominant_histogram", 0.0, str(doms))
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.md", "w") as f:
        f.write("# Roofline table (single-pod + multi-pod dry-run)\n\n")
        f.write(table(recs))
        f.write("\n")
    emit("roofline/table_written", 0.0, "experiments/roofline.md")
    with open("experiments/roofline_rows.json", "w") as f:
        json.dump(cells, f, indent=1)
    emit("roofline/rows_written", 0.0,
         f"experiments/roofline_rows.json ({len(cells)} cells, "
         f"{obs_attribution.SCHEMA})")
