"""Measured serving throughput: the packed megakernel pipeline end-to-end.

Runs the ``serve_rec`` driver (offline plan -> packed tables -> per-batch
megakernel gather + prefetch staging -> interaction/MLP head) on the dense,
QR, and TT DLRM configs, in both pipeline modes:

* ``sequential`` — gather, head, host sync, every batch (the baseline);
* ``overlap``    — batch ``t+1``'s prefetch + packed gather dispatched while
  batch ``t``'s head runs; one host sync at the tail of the stream.

Emitted rows carry **measured wall-clock** (us per steady-state batch) and
steady-state QPS — the cross-PR perf trajectory the BENCH JSON artifacts
track (earlier PRs only recorded modeled traffic).  The overlap/sequential
ratio is the double-buffering win; parity of the two modes' logits is
asserted by the tier-1 suite (`tests/test_packed_tables.py`).

Observatory columns (informational, never a gate):

* per-mode ``burn=`` — the slow-window burn rate against a derived SLO of
  2x the *sequential* p50 (so the overlap pipeline's distribution is judged
  against the baseline's median, on any host);
* a ``_bottleneck`` row per arch from one extra **fenced** overlap run put
  through the per-stage attribution join (fenced runs serialize the
  pipeline, so only the stage verdict is reported — never its QPS);
* the raw per-batch latency samples ride into the JSON rows (``samples_s``)
  so ``benchmarks/baseline.py`` can bootstrap a CI instead of comparing two
  points.
"""

from __future__ import annotations

from benchmarks.common import emit


def run(tiny: bool = False) -> None:
    import jax

    from repro import obs
    from repro.configs import registry
    from repro.launch import serve_rec
    from repro.models import dlrm
    from repro.obs import attribution as obs_attribution

    # smoke-sized tables on CPU hosts; batch/batches set the measured load.
    # Wall-clock on shared CI hosts is noisy at this scale, so each mode is
    # measured best-of-`repeats` (the time_jit idiom applied to the pipeline).
    batch, batches, repeats = (8, 6, 3) if tiny else (32, 10, 3)
    for arch in ("dlrm-dense", "dlrm-qr", "dlrm-tt"):
        cfg = registry.get_dlrm(f"{arch}-smoke")
        params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
        state = serve_rec.build_serve_state(cfg, shards=4, alpha=1.05, seed=0)
        # interleave the modes' repeats so host-load drift hits both equally
        best: dict = {}
        for _ in range(repeats):
            for mode in ("sequential", "overlap"):
                res = serve_rec.run_pipeline(
                    cfg, batch=batch, batches=batches, mode=mode,
                    state=state, params=params,
                )
                if mode not in best or res["wall_s"] < best[mode]["wall_s"]:
                    best[mode] = res
        # derived SLO: 2x the sequential-mode median — a host-relative target,
        # so the burn column means the same thing on a laptop and in CI
        slo_target = 2.0 * best["sequential"]["lat_p50_s"]
        qps = {}
        for mode in ("sequential", "overlap"):
            res = best[mode]
            qps[mode] = res["qps"]
            us_per_batch = res["wall_s"] * 1e6 / max(1, batches - 1)
            tr = res["traffic"]
            n = len(res["latencies_s"])
            eng = obs.SLOEngine(obs.SLOSpec(
                name=f"{arch}-{mode}", p99_latency_s=slo_target,
                fast_window=max(1, n // 2), slow_window=max(1, n),
            ))
            for lat in res["latencies_s"]:
                eng.observe(lat)
            emit(
                f"serve_qps/{arch}_{mode}", us_per_batch,
                f"qps={res['qps']:.1f} "
                f"p50={res['lat_p50_s'] * 1e3:.2f}ms "
                f"p95={res['lat_p95_s'] * 1e3:.2f}ms "
                f"p99={res['lat_p99_s'] * 1e3:.2f}ms "
                f"burn={eng.burn_rate(eng.spec.slow_window):.2f}x"
                f"@{slo_target * 1e3:.2f}ms "
                f"compile={res['compile_s']:.2f}s "
                f"hit={res['hit_rate']:.3f} "
                f"staged/batch={res['staged_per_batch']:.1f} "
                f"dram={tr['hbm_cached_bytes']}B/"
                f"{tr['hbm_baseline_bytes']}B "
                f"batch={batch} batches={batches} best_of={repeats}",
                samples=res["latencies_s"],
            )
        ratio = qps["overlap"] / max(qps["sequential"], 1e-9)
        emit(
            f"serve_qps/{arch}_overlap_speedup", 0.0,
            f"overlap/sequential={ratio:.2f}x "
            f"({qps['overlap']:.1f} vs {qps['sequential']:.1f} QPS)",
        )
        # bottleneck verdict from ONE fenced run (device-honest spans; the
        # fencing serializes the pipeline, so its QPS is never emitted)
        obs.enable()
        fres = serve_rec.run_pipeline(
            cfg, batch=batch, batches=batches, mode="overlap",
            state=state, params=params, fence=True,
        )
        att = obs_attribution.attribute(
            obs.tracer().events, fres["traffic_report"], state.eplan,
            batch=batch, fenced=True,
        )
        obs.disable()
        bn = next((r for r in att.rows if r.stage == att.bottleneck), None)
        detail = f"stage={att.bottleneck}"
        if bn is not None:
            if bn.share is not None:
                detail += f" share={bn.share * 100:.1f}%"
            if bn.achieved_gbps is not None:
                detail += f" achieved={bn.achieved_gbps:.2f}GB/s"
            if bn.modeled_gbps is not None:
                detail += f" modeled={bn.modeled_gbps:.2f}GB/s"
        detail += " fenced=1"
        emit(f"serve_qps/{arch}_bottleneck", 0.0, detail)
