"""Measured serving throughput: the packed megakernel pipeline end-to-end.

Runs the ``serve_rec`` driver (offline plan -> packed tables -> per-batch
megakernel gather + prefetch staging -> interaction/MLP head) on the dense,
QR, and TT DLRM configs, in both pipeline modes:

* ``sequential`` — gather, head, host sync, every batch (the baseline);
* ``overlap``    — batch ``t+1``'s prefetch + packed gather dispatched while
  batch ``t``'s head runs; one host sync at the tail of the stream.

Emitted rows carry **measured wall-clock** (us per steady-state batch) and
steady-state QPS — the cross-PR perf trajectory the BENCH JSON artifacts
track (earlier PRs only recorded modeled traffic).  The overlap/sequential
ratio is the double-buffering win; parity of the two modes' logits is
asserted by the tier-1 suite (`tests/test_packed_tables.py`).
"""

from __future__ import annotations

from benchmarks.common import emit


def run(tiny: bool = False) -> None:
    import jax

    from repro.configs import registry
    from repro.launch import serve_rec
    from repro.models import dlrm

    # smoke-sized tables on CPU hosts; batch/batches set the measured load.
    # Wall-clock on shared CI hosts is noisy at this scale, so each mode is
    # measured best-of-`repeats` (the time_jit idiom applied to the pipeline).
    batch, batches, repeats = (8, 6, 3) if tiny else (32, 10, 3)
    for arch in ("dlrm-dense", "dlrm-qr", "dlrm-tt"):
        cfg = registry.get_dlrm(f"{arch}-smoke")
        params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
        state = serve_rec.build_serve_state(cfg, shards=4, alpha=1.05, seed=0)
        # interleave the modes' repeats so host-load drift hits both equally
        best: dict = {}
        for _ in range(repeats):
            for mode in ("sequential", "overlap"):
                res = serve_rec.run_pipeline(
                    cfg, batch=batch, batches=batches, mode=mode,
                    state=state, params=params,
                )
                if mode not in best or res["wall_s"] < best[mode]["wall_s"]:
                    best[mode] = res
        qps = {}
        for mode in ("sequential", "overlap"):
            res = best[mode]
            qps[mode] = res["qps"]
            us_per_batch = res["wall_s"] * 1e6 / max(1, batches - 1)
            tr = res["traffic"]
            emit(
                f"serve_qps/{arch}_{mode}", us_per_batch,
                f"qps={res['qps']:.1f} "
                f"p50={res['lat_p50_s'] * 1e3:.2f}ms "
                f"p95={res['lat_p95_s'] * 1e3:.2f}ms "
                f"p99={res['lat_p99_s'] * 1e3:.2f}ms "
                f"compile={res['compile_s']:.2f}s "
                f"hit={res['hit_rate']:.3f} "
                f"staged/batch={res['staged_per_batch']:.1f} "
                f"dram={tr['hbm_cached_bytes']}B/"
                f"{tr['hbm_baseline_bytes']}B "
                f"batch={batch} batches={batches} best_of={repeats}",
            )
        ratio = qps["overlap"] / max(qps["sequential"], 1e-9)
        emit(
            f"serve_qps/{arch}_overlap_speedup", 0.0,
            f"overlap/sequential={ratio:.2f}x "
            f"({qps['overlap']:.1f} vs {qps['sequential']:.1f} QPS)",
        )
