"""Paper figure: the design-optimization ladder (its Fig. 9 analog).

The PIM paper stacks: baseline -> +bank-group PIM -> +batching -> +LUT. Our
TPU mapping stacks the corresponding mechanisms on the sharded GnR:

  baseline    : GSPMD auto-sharded gathers (XLA inserts row all-gathers)
  +two-level  : shard_map local partial-GnR + one pooled psum ("bg-PIM")
  +batching   : 4 bags fused into one dispatch (amortized index traffic)
  +LUT        : R table replicated & served locally (never crosses ICI/HBM
                twice) — in the Pallas kernel it is VMEM-resident

Scored two ways: (a) analytic per-chip service model from the roofline
constants, (b) measured wall-time of each real implementation on an 8-device
host mesh (subprocess), ratios being the reproduction target.
"""

from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import emit
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_WORKER = r"""
import time
import jax, jax.numpy as jnp, numpy as np
from repro import engine as E
from repro.core import sharded_embedding as SE, qr_embedding as QE
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
cfg = EmbeddingConfig(vocab=1_048_576, dim=128, kind="qr", collision=64,
                      compute_dtype=jnp.float32)
bags4 = [BagConfig(emb=cfg, pooling=32) for _ in range(4)]
key = jax.random.PRNGKey(0)
params = QE.init(key, cfg)
sp = SE.shard_qr_params(params, cfg, mesh)
idx4 = jax.random.randint(key, (512, 4, 32), 0, cfg.vocab)

# all four ladder rungs compile from the same engine front door
eng4 = E.compile(E.plan(E.EngineSpec.from_bags(bags4), mesh=mesh))
eng1 = E.compile(E.plan(E.EngineSpec.from_bags(bags4[:1]), mesh=mesh))

def timeit(f, *a, it=4):
    jax.block_until_ready(f(*a))
    ts = []
    for _ in range(it):
        t0 = time.perf_counter(); jax.block_until_ready(f(*a))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts)//2] * 1e6

# baseline: GSPMD auto-sharding of the naive double-gather
base = eng4.baseline(mesh)
t_base = timeit(base, [sp]*4, idx4)

# + two-level (per-bag dispatch, R spread) — single-bag calls, no batching
one = eng1.gnr(mesh)
def per_bag(tabs, idx):
    outs = [one([tabs[t]], idx[:, t:t+1]) for t in range(4)]
    return jnp.concatenate(outs, axis=1)
t_two = timeit(per_bag, [sp]*4, idx4)

# + batching: all 4 bags in one fused dispatch
four = eng4.gnr(mesh)
t_batch = timeit(four, [sp]*4, idx4)

# + LUT: R replicated (already) AND Q hot tier replicated: serve hottest rows
# locally, modeled by hot tier covering 80% of requests
from repro.core import placement, hashing
from repro.data.synthetic import zipf_trace
trace = zipf_trace(cfg.vocab, 50000, seed=1)
q_idx, _ = hashing.qr_decompose(jnp.asarray(trace), cfg.collision)
counts = placement.profile_counts(np.asarray(q_idx), cfg.qr_spec.q_rows)
plan = placement.plan_tiers(counts, request_share=0.8)
padded = SE.pad_q_table(params["q"], cfg)
slot = np.pad(plan.hot_slot, (0, padded.shape[0] - plan.hot_slot.size),
              constant_values=-1)
hot, cold = placement.split_table(padded, placement.TierPlan(
    plan.hot_rows, slot, plan.hot_fraction, plan.expected_hot_hit))
spc = SE.shard_qr_params({"q": cold, "r": params["r"]}, cfg, mesh)
tier = {"hot_table": hot, "hot_slot": jnp.asarray(slot)}
four_hot = eng4.gnr(mesh, hot=True)
t_lut = timeit(four_hot, [spc]*4, idx4, [tier]*4)

print(f"RESULT {t_base:.1f} {t_two:.1f} {t_batch:.1f} {t_lut:.1f}")
"""


def analytic_ladder(dim_bytes: int = 512, pooling: int = 32, chips: int = 16):
    """Per-chip service time (ns) per bag under the four designs."""
    row = dim_bytes
    hbm = HBM_BW
    ici = ICI_BW_PER_LINK * 2
    # baseline: every Q and R row crosses the network to the requester
    base = pooling * 2 * row / ici + pooling * 2 * row / hbm
    # two-level: rows served from owner HBM; one pooled vector crosses ICI
    two = pooling * 2 * row / chips / hbm * chips + row / ici  # per-bag
    two = pooling * 2 * row / hbm + row / ici
    # batching of 4 amortizes the combine latency
    batch = pooling * 2 * row / hbm + row / ici / 4
    # LUT: R rows never touch HBM (VMEM-resident): half the gather bytes
    lut = pooling * 1 * row / hbm + row / ici / 4
    return base, two, batch, lut


def tt_analytic_ladder(
    *, dim: int = 128, rank: int = 16, pooling: int = 32, bytes_per_elem: int = 4,
):
    """Per-bag service time (ns) ladder for the TT path (paper's 2.15x case).

    baseline     : all three core rows cross the network per lookup
    +two-level   : rows served from owner HBM, one pooled vector crosses ICI
    +SRAM pin    : outer cores VMEM-resident — only the G2 row from HBM
    +hot tier    : hottest G2 rows replicated (80% of requests, paper's
                   hot-vector share) — hot contractions are all-local, so only
                   the cold 20% still pays the pooled ICI combine
    """
    from repro.core.tt_embedding import dim_factors3

    d1, d2, d3 = dim_factors3(dim)        # same factorization the tables use
    w1 = d1 * rank * bytes_per_elem
    w2 = rank * d2 * rank * bytes_per_elem
    w3 = rank * d3 * bytes_per_elem
    hbm, ici = HBM_BW, ICI_BW_PER_LINK * 2
    row_out = dim * bytes_per_elem
    base = pooling * (w1 + w2 + w3) / ici + pooling * (w1 + w2 + w3) / hbm
    two = pooling * (w1 + w2 + w3) / hbm + row_out / ici
    sram = pooling * w2 / hbm + row_out / ici
    hot = pooling * w2 / hbm + 0.2 * row_out / ici
    return base, two, sram, hot


def run() -> None:
    b, t, bt, l = analytic_ladder()
    emit("design_opt/analytic_baseline_ns", 0.0, f"{b * 1e9:.1f}ns/bag")
    emit("design_opt/analytic_two_level", 0.0,
         f"{t * 1e9:.1f}ns/bag speedup={b / t:.2f}x")
    emit("design_opt/analytic_batching", 0.0,
         f"{bt * 1e9:.1f}ns/bag speedup={b / bt:.2f}x")
    emit("design_opt/analytic_lut", 0.0,
         f"{l * 1e9:.1f}ns/bag speedup={b / l:.2f}x (paper ladder: 1.34x/1.9x/2.2x)")

    tb, tt_, ts, th = tt_analytic_ladder()
    emit("design_opt/tt_analytic_baseline_ns", 0.0, f"{tb * 1e9:.1f}ns/bag")
    emit("design_opt/tt_analytic_two_level", 0.0,
         f"{tt_ * 1e9:.1f}ns/bag speedup={tb / tt_:.2f}x")
    emit("design_opt/tt_analytic_sram_pin", 0.0,
         f"{ts * 1e9:.1f}ns/bag speedup={tb / ts:.2f}x")
    emit("design_opt/tt_analytic_hot_tier", 0.0,
         f"{th * 1e9:.1f}ns/bag speedup={tb / th:.2f}x (paper TT-Rec: 2.15x)")

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", _WORKER], capture_output=True, text=True,
        env=env, timeout=560,
    )
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")]
    if not line:
        emit("design_opt/measured", 0.0, f"FAILED: {out.stderr[-200:]}")
        return
    t_base, t_two, t_batch, t_lut = map(float, line[0].split()[1:])
    emit("design_opt/measured_gspmd_baseline", t_base, "8-dev host mesh, 4 bags")
    emit("design_opt/measured_two_level", t_two, f"speedup={t_base / t_two:.2f}x")
    emit("design_opt/measured_batching", t_batch, f"speedup={t_base / t_batch:.2f}x")
    emit("design_opt/measured_lut_hot_tier", t_lut, f"speedup={t_base / t_lut:.2f}x")
