"""Tolerance-gated diff of a measured benchmark JSON against a committed
baseline (the ROADMAP "perf trajectory" gate).

Baselines live in ``benchmarks/baselines/BENCH_<suite>.json`` — written by
``scripts/refresh_baselines.py`` via ``benchmarks.run --tiny --json`` — and
carry per-row host metadata (``benchmarks.common.run_metadata``).  The gate:

* every baseline row name must appear in the measured run (a vanished row
  means a suite silently stopped covering something) — always fatal;
* timed rows (``us_per_call > 0``) must not regress beyond ``--rel-tol``.
  Wall-clock across CI hosts is noisy, so the default tolerance is generous
  (3.0 = 4x slower fails): the gate catches order-of-magnitude regressions
  and structural breakage, not scheduler jitter.  When the measured run's
  ``device_kind``/``backend`` differ from the baseline's, timing rows are
  reported but not gated (cross-machine comparison is meaningless).

CLI: ``python -m benchmarks.baseline --measured out.json --baseline
benchmarks/baselines/BENCH_serve_qps.json [--rel-tol 3.0]`` — exit 1 on
missing rows or gated regressions.
"""

from __future__ import annotations

import argparse
import json
import sys


def _rows(path: str) -> list[dict]:
    with open(path) as f:
        records = json.load(f)
    if not isinstance(records, list):
        raise ValueError(f"{path}: expected a list of row records")
    return records


def _host(records: list[dict]) -> tuple[str, str]:
    for r in records:
        if "device_kind" in r:
            return str(r.get("device_kind")), str(r.get("backend"))
    return "unknown", "unknown"


def compare(measured: list[dict], baseline: list[dict], *, rel_tol: float,
            gate_timing: bool = True) -> dict:
    """Diff measured rows against baseline rows (keyed by name).

    Returns {"missing": [...], "regressions": [(name, base_us, meas_us,
    ratio)], "improvements": [...], "checked": n}.
    """
    got = {r["name"]: r for r in measured}
    missing, regressions, improvements = [], [], []
    checked = 0
    for b in baseline:
        name = b["name"]
        m = got.get(name)
        if m is None:
            missing.append(name)
            continue
        base_us, meas_us = float(b["us_per_call"]), float(m["us_per_call"])
        if base_us <= 0 or meas_us <= 0:
            continue                        # modeled/ratio rows: presence only
        checked += 1
        ratio = meas_us / base_us
        if ratio > 1.0 + rel_tol:
            regressions.append((name, base_us, meas_us, ratio))
        elif ratio < 1.0 / (1.0 + rel_tol):
            improvements.append((name, base_us, meas_us, ratio))
    if not gate_timing:
        regressions = []
    return {
        "missing": missing,
        "regressions": regressions,
        "improvements": improvements,
        "checked": checked,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--measured", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--rel-tol", type=float, default=3.0,
                    help="gate: measured > baseline*(1+tol) fails (default 3.0)")
    ap.add_argument("--force-timing", action="store_true",
                    help="gate timings even across differing host metadata")
    args = ap.parse_args(argv)

    measured = _rows(args.measured)
    baseline = _rows(args.baseline)
    m_host, b_host = _host(measured), _host(baseline)
    same_host_class = m_host == b_host
    gate_timing = same_host_class or args.force_timing
    if not same_host_class:
        print(f"# host mismatch: baseline {b_host} vs measured {m_host} -> "
              + ("timing gated anyway (--force-timing)" if gate_timing
                 else "timing informational only"))

    res = compare(measured, baseline, rel_tol=args.rel_tol,
                  gate_timing=gate_timing)
    for name in res["missing"]:
        print(f"MISSING  {name}")
    for name, base, meas, ratio in res["regressions"]:
        print(f"REGRESS  {name}: {base:.1f}us -> {meas:.1f}us ({ratio:.2f}x)")
    for name, base, meas, ratio in res["improvements"]:
        print(f"IMPROVE  {name}: {base:.1f}us -> {meas:.1f}us ({ratio:.2f}x)")
    print(f"# {res['checked']} timed rows checked against "
          f"{len(baseline)} baseline rows "
          f"(tol {args.rel_tol}, gate_timing={gate_timing})")
    if res["missing"] or res["regressions"]:
        return 1
    print("# baseline gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
