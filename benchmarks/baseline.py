"""Tolerance-gated diff of a measured benchmark JSON against a committed
baseline (the ROADMAP "perf trajectory" gate).

Baselines live in ``benchmarks/baselines/BENCH_<suite>.json`` — written by
``scripts/refresh_baselines.py`` via ``benchmarks.run --tiny --json`` — and
carry per-row host metadata (``benchmarks.common.run_metadata``).  The gate:

* every baseline row name must appear in the measured run (a vanished row
  means a suite silently stopped covering something) — always fatal;
* timed rows where BOTH sides carry raw per-batch latency samples
  (``samples_s``, written by ``benchmarks.common.emit(..., samples=)``) get
  the **noise-aware gate**: a bootstrap confidence interval on the ratio of
  median latencies.  A regression needs the whole 95% CI above
  ``1 + --boot-tol`` — one jittery batch cannot fail the gate, but a
  consistent shift well inside the old 3x backstop can;
* timed rows without samples fall back to the point-ratio gate at
  ``--rel-tol``; the point-ratio **3x hard backstop always applies** even to
  sampled rows (a 4x median shift fails regardless of CI politics).
  When the measured run's ``device_kind``/``backend`` differ from the
  baseline's, timing rows are reported but not gated (cross-machine
  comparison is meaningless) — unchanged.

CLI: ``python -m benchmarks.baseline --measured out.json --baseline
benchmarks/baselines/BENCH_serve_qps.json [--rel-tol 3.0] [--boot-tol 0.5]``
— exit 1 on missing rows or gated regressions.
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

# resampling depth for the CI; deterministic seed so the gate is reproducible
N_BOOT = 2000
BOOT_SEED = 0
MIN_SAMPLES = 4                      # below this a CI is meaningless


def _rows(path: str) -> list[dict]:
    with open(path) as f:
        records = json.load(f)
    if not isinstance(records, list):
        raise ValueError(f"{path}: expected a list of row records")
    return records


def _host(records: list[dict]) -> tuple[str, str]:
    for r in records:
        if "device_kind" in r:
            return str(r.get("device_kind")), str(r.get("backend"))
    return "unknown", "unknown"


def bootstrap_ratio_ci(base_samples, meas_samples, *, n_boot: int = N_BOOT,
                       alpha: float = 0.05, seed: int = BOOT_SEED
                       ) -> tuple[float, float]:
    """Percentile-bootstrap CI for median(measured)/median(baseline).

    Resamples each side independently with replacement; deterministic
    (seeded) so the gate verdict is reproducible run-to-run.
    """
    rng = np.random.default_rng(seed)
    b = np.asarray(base_samples, dtype=np.float64)
    m = np.asarray(meas_samples, dtype=np.float64)
    bi = rng.integers(0, b.size, size=(n_boot, b.size))
    mi = rng.integers(0, m.size, size=(n_boot, m.size))
    ratios = np.median(m[mi], axis=1) / np.maximum(
        np.median(b[bi], axis=1), 1e-12
    )
    lo, hi = np.quantile(ratios, [alpha / 2.0, 1.0 - alpha / 2.0])
    return float(lo), float(hi)


def _samples(row: dict) -> np.ndarray | None:
    s = row.get("samples_s")
    if not s or len(s) < MIN_SAMPLES:
        return None
    return np.asarray(s, dtype=np.float64)


def compare(measured: list[dict], baseline: list[dict], *, rel_tol: float,
            gate_timing: bool = True, boot_tol: float = 0.5) -> dict:
    """Diff measured rows against baseline rows (keyed by name).

    Returns {"missing": [...], "regressions": [(name, base_us, meas_us,
    ratio)], "improvements": [...], "checked": n, "detail": {name: {...}}}.
    ``detail`` records per-row gate method ("point" or "bootstrap") and the
    CI for sampled rows.
    """
    got = {r["name"]: r for r in measured}
    missing, regressions, improvements = [], [], []
    detail: dict = {}
    checked = 0
    for b in baseline:
        name = b["name"]
        m = got.get(name)
        if m is None:
            missing.append(name)
            continue
        base_us, meas_us = float(b["us_per_call"]), float(m["us_per_call"])
        if base_us <= 0 or meas_us <= 0:
            continue                        # modeled/ratio rows: presence only
        checked += 1
        ratio = meas_us / base_us
        bs, ms = _samples(b), _samples(m)
        if bs is not None and ms is not None:
            lo, hi = bootstrap_ratio_ci(bs, ms)
            detail[name] = {"method": "bootstrap", "ci": (lo, hi),
                            "ratio": ratio}
            # significant-and-large shift, OR the hard point backstop
            regress = lo > 1.0 + boot_tol or ratio > 1.0 + rel_tol
            improve = hi < 1.0 / (1.0 + boot_tol)
        else:
            detail[name] = {"method": "point", "ratio": ratio}
            regress = ratio > 1.0 + rel_tol
            improve = ratio < 1.0 / (1.0 + rel_tol)
        if regress:
            regressions.append((name, base_us, meas_us, ratio))
        elif improve:
            improvements.append((name, base_us, meas_us, ratio))
    if not gate_timing:
        regressions = []
    return {
        "missing": missing,
        "regressions": regressions,
        "improvements": improvements,
        "checked": checked,
        "detail": detail,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--measured", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--rel-tol", type=float, default=3.0,
                    help="point gate + hard backstop: measured > "
                         "baseline*(1+tol) fails (default 3.0)")
    ap.add_argument("--boot-tol", type=float, default=0.5,
                    help="bootstrap gate (sampled rows): fail when the whole "
                         "95%% CI of the median ratio sits above 1+tol "
                         "(default 0.5)")
    ap.add_argument("--force-timing", action="store_true",
                    help="gate timings even across differing host metadata")
    args = ap.parse_args(argv)

    measured = _rows(args.measured)
    baseline = _rows(args.baseline)
    m_host, b_host = _host(measured), _host(baseline)
    same_host_class = m_host == b_host
    gate_timing = same_host_class or args.force_timing
    if not same_host_class:
        print(f"# host mismatch: baseline {b_host} vs measured {m_host} -> "
              + ("timing gated anyway (--force-timing)" if gate_timing
                 else "timing informational only"))

    res = compare(measured, baseline, rel_tol=args.rel_tol,
                  gate_timing=gate_timing, boot_tol=args.boot_tol)

    def _ci(name: str) -> str:
        d = res["detail"].get(name, {})
        if d.get("method") == "bootstrap":
            lo, hi = d["ci"]
            return f" [median-ratio CI {lo:.2f}..{hi:.2f}]"
        return ""

    for name in res["missing"]:
        print(f"MISSING  {name}")
    for name, base, meas, ratio in res["regressions"]:
        print(f"REGRESS  {name}: {base:.1f}us -> {meas:.1f}us "
              f"({ratio:.2f}x){_ci(name)}")
    for name, base, meas, ratio in res["improvements"]:
        print(f"IMPROVE  {name}: {base:.1f}us -> {meas:.1f}us "
              f"({ratio:.2f}x){_ci(name)}")
    n_boot_rows = sum(
        1 for d in res["detail"].values() if d["method"] == "bootstrap"
    )
    print(f"# {res['checked']} timed rows checked against "
          f"{len(baseline)} baseline rows "
          f"({n_boot_rows} bootstrap-gated, boot_tol {args.boot_tol}, "
          f"point tol {args.rel_tol}, gate_timing={gate_timing})")
    if res["missing"] or res["regressions"]:
        return 1
    print("# baseline gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
