"""Paper figures: compositional-embedding shortcoming analyses.

(a) hot-vector count vs hash-collision value (its Fig. 12(a)): quotient
    folding shrinks the hot set sub-linearly because hot rows are scattered.
(b) model quality vs collision (its Fig. 12(b) flavor): tiny DLRM trained on
    synthetic CTR data with planted embedding structure; AUC drop vs the
    dense baseline as collision grows.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import dlrm_qr
from repro.core import placement
from repro.data.synthetic import zipf_trace
from repro.models import dlrm
from repro.train import optimizer as opt_mod
from repro.train.train_step import make_dlrm_loss, make_train_step


def hot_vs_collision() -> None:
    counts = placement.profile_counts(zipf_trace(262_144, 80_000, seed=3), 262_144)
    curve = placement.hot_vector_reduction_curve(counts, [1, 2, 4, 8, 16, 32, 64])
    base = curve[1]
    for c, n in curve.items():
        emit(
            f"collision_sweep/hot_vectors_c{c}", 0.0,
            f"hot_rows={n} reduction={base / max(n, 1):.2f}x "
            f"(ideal={c}x; sub-linear = scattered hot rows)",
        )


def quality_vs_collision(steps: int = 60) -> None:
    from repro.data.synthetic import dlrm_planted_batch, dlrm_truth

    base_cfg = dataclasses.replace(
        dlrm_qr.SMOKE, vocab_per_table=2048, num_tables=4, dim=16, pooling=4,
        bottom_mlp=(64, 16), top_mlp=(64, 1),
    )
    truth = dlrm_truth(base_cfg)

    aucs = {}
    for kind, coll in (("dense", 1), ("qr", 4), ("qr", 16), ("qr", 64)):
        cfg = dataclasses.replace(base_cfg, embedding_kind=kind, qr_collision=coll)
        params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
        step = jax.jit(make_train_step(
            make_dlrm_loss(cfg), opt_mod.OptConfig(lr=3e-3, warmup_steps=5,
                                                   total_steps=steps)))
        opt = opt_mod.init(params)
        for i in range(steps):
            batch = dlrm_planted_batch(cfg, truth, 256, seed=1, step=i)
            params, opt, m = step(params, opt, batch)
        test = dlrm_planted_batch(cfg, truth, 2048, seed=2, step=10_000)
        logits = dlrm.forward_dlrm(params, test["dense"], test["idx"], cfg)
        aucs[(kind, coll)] = float(dlrm.auc(logits, test["labels"]))

    base = aucs[("dense", 1)]
    emit("collision_sweep/auc_dense", 0.0, f"auc={base:.4f}")
    for (kind, coll), a in aucs.items():
        if kind == "dense":
            continue
        emit(
            f"collision_sweep/auc_qr_c{coll}", 0.0,
            f"auc={a:.4f} drop={base - a:+.4f} "
            f"compression~{coll}x (paper: drop grows with collision)",
        )


def run() -> None:
    hot_vs_collision()
    quality_vs_collision()
