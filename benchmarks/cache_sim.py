"""ProactivePIM cache-subsystem sweep: cache size vs hit rate vs traffic.

Simulates the double-buffered next-batch prefetch scheduler
(``repro.cache.sram_cache``) over Zipf(1.05) synthetic request batches — the
paper's long-tail access model — and reports, per cache size:

* steady-state hit rate of the staged cache (paper's SRAM-cache efficacy);
* staged rows per batch (the prefetch DMA the double buffer must hide);
* modeled DRAM bytes: uncached baseline vs misses+staging (the traffic win);

plus the intra-GnR locality of the shared subtables (why the prefetch works
at all) and the duplication planner's communication kill at two budgets.

Default point: QR, 2^18 vocab, c=64, 1024 slots — a 512 KB cache at the
paper's 128-dim fp32 rows, the bg-PIM SRAM size class.  Hit rate there is
the tracked acceptance number (>= 0.8).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.cache import intra_gnr
from repro.cache.sram_cache import simulate
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.data.synthetic import zipf_trace
from repro.obs.traffic import cache_traffic, format_cache_traffic

ALPHA = 1.05


def _batches(vocab: int, batches: int, batch: int, pooling: int, seed: int = 3):
    n = batches * batch * pooling
    return zipf_trace(vocab, n, alpha=ALPHA, seed=seed).reshape(
        batches, batch * pooling
    )


def qr_cache_sweep(
    *, vocab=262_144, collision=64, pooling=32, batch=256, n_batches=24,
    dim=128, slot_sweep=(256, 512, 1024, 2048), default_slots=1024,
) -> float:
    """Hit rate / staged rows / traffic vs cache size on the Q-row stream.

    Returns the default-size hit rate (the acceptance number).
    """
    cfg = EmbeddingConfig(vocab=vocab, dim=dim, kind="qr", collision=collision)
    trace = _batches(vocab, n_batches, batch, pooling)
    q, q_rows, row_bytes = intra_gnr.subtable_traces(trace, cfg)["q"]
    default_hit = 0.0
    for slots in slot_sweep:
        stats = simulate([q[t] for t in range(n_batches)], q_rows, slots)
        tag = " (default)" if slots == default_slots else ""
        emit(
            f"cache_sim/qr_slots{slots}", 0.0,
            format_cache_traffic(cache_traffic(stats, row_bytes)) + tag,
        )
        if slots == default_slots:
            default_hit = stats.hit_rate
    return default_hit


def tt_cache_sweep(
    *, vocab=262_144, dim=128, rank=16, pooling=32, batch=256, n_batches=24,
    slot_sweep=(64, 128, 256, 512),
) -> None:
    """Same sweep on the TT middle-core (i2) stream."""
    cfg = EmbeddingConfig(vocab=vocab, dim=dim, kind="tt", tt_rank=rank)
    spec = cfg.tt_spec
    trace = _batches(vocab, n_batches, batch, pooling)
    i2, _v2, row_bytes = intra_gnr.subtable_traces(trace, cfg)["g2"]
    for slots in slot_sweep:
        stats = simulate([i2[t] for t in range(n_batches)], spec.v2, slots)
        emit(
            f"cache_sim/tt_slots{slots}", 0.0,
            format_cache_traffic(cache_traffic(stats, row_bytes))
            + f" v2={spec.v2}",
        )


def locality_report(*, vocab=262_144, collision=64, pooling=32, n=40_000) -> None:
    """Intra-GnR reuse of every subtable — the prefetch-value ranking input."""
    trace = zipf_trace(vocab, n - n % pooling, alpha=ALPHA, seed=5).reshape(
        -1, pooling
    )
    for kind, kw in (
        ("qr", {"collision": collision}),
        ("tt", {"tt_rank": 16}),
    ):
        cfg = EmbeddingConfig(vocab=vocab, dim=128, kind=kind, **kw)
        locs = intra_gnr.analyze_table(trace, cfg)
        parts = " ".join(
            f"{name}={loc.mean_intra_reuse:.2f}(touched={loc.touched_rows})"
            for name, loc in locs.items()
        )
        emit(f"cache_sim/intra_gnr_{kind}", 0.0, f"reuse/bag: {parts}")


def duplication_report(
    *, vocab=262_144, collision=64, pooling=32, num_tables=8, batch=1024,
    shards=8, n=60_000,
) -> None:
    """Planner outcome at a generous and a starved budget (via engine.plan)."""
    from repro import engine as engine_mod

    trace = zipf_trace(vocab, n, alpha=ALPHA, seed=9)
    for kind, kw in (("qr", {"collision": collision}), ("tt", {"tt_rank": 16})):
        emb = EmbeddingConfig(vocab=vocab, dim=128, kind=kind, **kw)
        bags = [BagConfig(emb=emb, pooling=pooling) for _ in range(num_tables)]
        for budget in (64 * 2**20, 256 * 2**10):
            spec = engine_mod.EngineSpec.from_bags(
                bags, duplication=True, dup_budget_bytes=budget,
            )
            plan = engine_mod.plan(
                spec, num_shards=shards, trace=[trace] * num_tables,
            ).dup
            ici = plan.ici_bytes_per_batch(batch, emb.dim)
            emit(
                f"cache_sim/dup_{kind}_budget{budget // 1024}K", 0.0,
                f"replicated={plan.replicated_bytes}B comm_free={plan.comm_free} "
                f"local_share={plan.tables[0].local_share:.2f} "
                f"ici_saved/batch={ici['saved']:.0f}B of {ici['baseline']:.0f}B",
            )


def _drift_arms(
    *, vocab, collision, pooling, batch, n_batches, period, fraction,
    num_tables, cache_slots, seed, sketch_kw, policy,
) -> dict:
    """Serve one drifting index stream through three residency arms.

    * ``frozen`` — the offline plan's pin, never touched (no online info);
    * ``adaptive`` — same initial pin + :class:`AdaptController` incremental
      re-pins (sketch -> trigger -> ``PinnedCache.pin``);
    * ``oracle`` — a *fresh offline plan per epoch*: exact access counts of
      each rotation epoch pin the true optimum at the epoch boundary.  This
      is the re-planned static optimum the adaptive arm chases.

    Host-side simulation (slot maps only, no device dispatch) over the same
    ``big_rows`` fold the serving loop uses.  Returns per-batch hit series
    per arm plus the controller's event log.
    """
    from repro import engine as engine_mod
    from repro.adapt.policy import AdaptController
    from repro.adapt.replan import (
        PinnedCache, big_id_map, fold_to_big, pinned_from_plan, top_rows,
    )
    from repro.adapt.schedule import DriftSchedule, drifting_zipf_batches
    from repro.engine.plan import big_rows, big_subtable

    emb = EmbeddingConfig(vocab=vocab, dim=64, kind="qr", collision=collision)
    bags = [BagConfig(emb=emb, pooling=pooling) for _ in range(num_tables)]
    spec = engine_mod.EngineSpec.from_bags(bags, cache_slots=cache_slots)
    schedule = DriftSchedule(period=float(period), fraction=fraction, seed=seed)

    # offline profile on pre-rotation traffic (offset_at(0) == 0, so a plain
    # Zipf draw with the serving seeds IS epoch-0 traffic)
    profile = [
        zipf_trace(vocab, 4 * batch * pooling * max(1, int(period) or n_batches),
                   alpha=ALPHA, seed=seed + 7 + t)
        for t in range(num_tables)
    ]
    eplan = engine_mod.plan(spec, trace=profile)

    per_table = [
        drifting_zipf_batches(
            vocab, n_batches, batch * pooling,
            schedule=schedule, alpha=ALPHA, seed=seed + 7 + t,
        )
        for t in range(num_tables)
    ]
    # logical (B, K) per table per batch -> big-subtable row streams
    rows_bt = [
        [big_rows(per_table[t][b].reshape(batch, pooling), emb)
         for t in range(num_tables)]
        for b in range(n_batches)
    ]
    num_rows = big_subtable(emb)[1]
    ids = big_id_map(emb)

    frozen = pinned_from_plan(eplan)
    adaptive = pinned_from_plan(eplan)
    ctl = AdaptController(eplan, policy=policy, sketch_kw=sketch_kw, seed=seed)

    # oracle re-pin points: the first batch of every rotation epoch
    rotations = [
        b for b in range(1, n_batches)
        if schedule.offset_at(b, vocab) != schedule.offset_at(b - 1, vocab)
    ]
    epoch_starts = [0] + rotations
    oracle = [PinnedCache(num_rows, eplan.slot_budgets[t])
              for t in range(num_tables)]

    def epoch_pin(start: int) -> None:
        end = min(
            [r for r in epoch_starts if r > start] + [n_batches]
        )
        for t in range(num_tables):
            flat = per_table[t][start:end].reshape(-1)
            exact = np.bincount(flat, minlength=vocab).astype(np.float64)
            est = fold_to_big(exact, ids, num_rows)
            oracle[t].pin(top_rows(est, eplan.slot_budgets[t]))

    epoch_pin(0)
    series = {"frozen": [], "adaptive": [], "oracle": []}
    for b in range(n_batches):
        if b in rotations:
            epoch_pin(b)
        for arm, caches in (("frozen", frozen), ("adaptive", adaptive),
                            ("oracle", oracle)):
            hits = acc = 0
            for t in range(num_tables):
                slots = caches[t].slots_for(rows_bt[b][t])
                hits += int((slots >= 0).sum())
                acc += slots.size
            series[arm].append(hits / max(1, acc))
        # adaptation happens after the batch is served, like the live loop
        idx = np.stack([per_table[t][b].reshape(batch, pooling)
                        for t in range(num_tables)], axis=1)
        ctl.observe(idx)
        ctl.step(adaptive)
    return {
        "series": series,
        "rotations": rotations,
        "events": list(ctl.events),
        "schedule": schedule.describe(),
        "slot_budgets": list(eplan.slot_budgets),
    }


def _recovery_batches(series, rotations, *, tol: float) -> list[int | None]:
    """Batches from each rotation until adaptive is within ``tol`` of the
    oracle's per-batch hit rate (None = never caught up)."""
    out = []
    for r in rotations:
        rec = None
        for b in range(r, len(series["adaptive"])):
            if series["adaptive"][b] >= series["oracle"][b] - tol:
                rec = b - r
                break
        out.append(rec)
    return out


def run_drift(tiny: bool = False, seed: int = 0) -> dict:
    """Hot-set rotation: frozen vs adaptive vs per-epoch fresh plan.

    Emits the drift rows (recovery time is the tracked acceptance number)
    and returns the gate summary the CLI / CI smoke checks:

    * adaptive recovers to within ``tol`` of the re-planned static optimum
      within ``max_recovery`` batches of every gateable rotation;
    * the frozen pin does NOT recover (tail gap above ``tol``);
    * a stationary run of the same controller fires zero re-plan events.
    """
    from repro.adapt.policy import AdaptPolicy

    tol = 0.05
    if tiny:
        kw = dict(vocab=4096, collision=16, pooling=8, batch=64,
                  num_tables=2, cache_slots=128, seed=seed)
        n_batches, period, fraction, max_recovery = 48, 16, 0.3, 10
        width = 2048
    else:
        kw = dict(vocab=65_536, collision=32, pooling=16, batch=128,
                  num_tables=4, cache_slots=512, seed=seed)
        n_batches, period, fraction, max_recovery = 72, 24, 0.3, 12
        width = 32_768
    # tracking-tuned sketch/policy: short windows + fast decay follow a
    # rotation within a few batches; the CMS width stays within 2x of the
    # logical vocab (collision inflation corrupts mid-rank ordering
    # otherwise) and the gain floor sits ~1.5x above the measured
    # stationary sampling-noise plateau at this sample size
    sketch_kw = dict(window_batches=4, windows=4, decay=0.3, width=width)
    policy = AdaptPolicy(check_every=4, min_batches=8, min_gain=0.08,
                         cooldown_batches=4)

    drift = _drift_arms(n_batches=n_batches, period=period, fraction=fraction,
                        sketch_kw=sketch_kw, policy=policy, **kw)
    flat = _drift_arms(n_batches=n_batches, period=0, fraction=fraction,
                       sketch_kw=sketch_kw, policy=policy, **kw)

    series, rotations = drift["series"], drift["rotations"]
    # only rotations with room for a trigger check afterwards are gateable
    gateable = [r for r in rotations
                if n_batches - r > policy.check_every + 2]
    recov = _recovery_batches(series, gateable, tol=tol)
    tail = range(rotations[-1], n_batches) if rotations else range(n_batches)
    tail_hit = {
        arm: float(np.mean([series[arm][b] for b in tail]))
        for arm in ("frozen", "adaptive", "oracle")
    }
    replans = sum(1 for e in drift["events"] if e["kind"] == "replan")
    flat_replans = len(flat["events"])

    gates = {
        "recovered": all(r is not None and r <= max_recovery for r in recov),
        "frozen_stuck": tail_hit["oracle"] - tail_hit["frozen"] > tol,
        "stationary_quiet": flat_replans == 0,
    }
    extra = {
        "seed": seed, "tol": tol, "max_recovery": max_recovery,
        "schedule": drift["schedule"], "rotations": rotations,
        "recovery_batches": recov, "events": drift["events"],
        "hit_series": {a: [round(h, 4) for h in s]
                       for a, s in series.items()},
        "gates": gates,
    }
    emit(
        "cache_sim/drift_adaptive", 0.0,
        f"tail_hit={tail_hit['adaptive']:.3f} replans={replans} "
        f"recovery={recov} (tol={tol} of oracle)",
        extra=extra,
    )
    emit("cache_sim/drift_frozen", 0.0,
         f"tail_hit={tail_hit['frozen']:.3f} "
         f"gap_vs_oracle={tail_hit['oracle'] - tail_hit['frozen']:.3f}")
    emit("cache_sim/drift_oracle", 0.0,
         f"tail_hit={tail_hit['oracle']:.3f} "
         f"(fresh offline plan per epoch x{len(rotations) + 1})")
    emit("cache_sim/drift_stationary", 0.0,
         f"replans={flat_replans} (target 0) "
         f"hit={float(np.mean(flat['series']['adaptive'])):.3f}")
    emit("cache_sim/drift_gates", 0.0,
         " ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in gates.items()))
    return {"gates": gates, "tail_hit": tail_hit, "recovery": recov,
            "stationary_replans": flat_replans, "extra": extra}


def run(tiny: bool = False) -> None:
    if tiny:
        # CI smoke: same code paths, seconds not minutes
        hit = qr_cache_sweep(
            vocab=16_384, collision=16, pooling=8, batch=64, n_batches=6,
            slot_sweep=(64, 128), default_slots=128,
        )
        tt_cache_sweep(
            vocab=16_384, pooling=8, batch=64, n_batches=6, slot_sweep=(32, 64)
        )
        locality_report(vocab=16_384, collision=16, pooling=8, n=4_000)
        duplication_report(vocab=16_384, collision=16, num_tables=2, n=6_000)
    else:
        hit = qr_cache_sweep()
        tt_cache_sweep()
        locality_report()
        duplication_report()
    emit("cache_sim/default_hit_rate", 0.0, f"hit={hit:.3f} target>=0.8")


def main(argv=None) -> int:
    """``python -m benchmarks.cache_sim --drift`` — the adapt smoke gate.

    Runs the drift suite and FAILS (exit 1) unless the adaptive arm
    recovers, the frozen arm stays stuck, and the stationary run fires zero
    re-plans — the CI acceptance checks for the online-adaptation subsystem.
    """
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--drift", action="store_true",
                    help="run the hot-set-rotation suite with gating")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)

    if args.drift:
        out = run_drift(tiny=args.tiny, seed=args.seed)
    else:
        run(tiny=args.tiny)
        out = None
    if args.json:
        common.write_json(args.json)
    if out is not None:
        failed = [k for k, ok in out["gates"].items() if not ok]
        if failed:
            print(f"# DRIFT GATES FAILED: {','.join(failed)}")
            return 1
        print("# drift gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
