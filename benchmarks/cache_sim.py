"""ProactivePIM cache-subsystem sweep: cache size vs hit rate vs traffic.

Simulates the double-buffered next-batch prefetch scheduler
(``repro.cache.sram_cache``) over Zipf(1.05) synthetic request batches — the
paper's long-tail access model — and reports, per cache size:

* steady-state hit rate of the staged cache (paper's SRAM-cache efficacy);
* staged rows per batch (the prefetch DMA the double buffer must hide);
* modeled DRAM bytes: uncached baseline vs misses+staging (the traffic win);

plus the intra-GnR locality of the shared subtables (why the prefetch works
at all) and the duplication planner's communication kill at two budgets.

Default point: QR, 2^18 vocab, c=64, 1024 slots — a 512 KB cache at the
paper's 128-dim fp32 rows, the bg-PIM SRAM size class.  Hit rate there is
the tracked acceptance number (>= 0.8).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.cache import intra_gnr
from repro.cache.sram_cache import simulate
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.data.synthetic import zipf_trace
from repro.obs.traffic import cache_traffic, format_cache_traffic

ALPHA = 1.05


def _batches(vocab: int, batches: int, batch: int, pooling: int, seed: int = 3):
    n = batches * batch * pooling
    return zipf_trace(vocab, n, alpha=ALPHA, seed=seed).reshape(
        batches, batch * pooling
    )


def qr_cache_sweep(
    *, vocab=262_144, collision=64, pooling=32, batch=256, n_batches=24,
    dim=128, slot_sweep=(256, 512, 1024, 2048), default_slots=1024,
) -> float:
    """Hit rate / staged rows / traffic vs cache size on the Q-row stream.

    Returns the default-size hit rate (the acceptance number).
    """
    cfg = EmbeddingConfig(vocab=vocab, dim=dim, kind="qr", collision=collision)
    trace = _batches(vocab, n_batches, batch, pooling)
    q, q_rows, row_bytes = intra_gnr.subtable_traces(trace, cfg)["q"]
    default_hit = 0.0
    for slots in slot_sweep:
        stats = simulate([q[t] for t in range(n_batches)], q_rows, slots)
        tag = " (default)" if slots == default_slots else ""
        emit(
            f"cache_sim/qr_slots{slots}", 0.0,
            format_cache_traffic(cache_traffic(stats, row_bytes)) + tag,
        )
        if slots == default_slots:
            default_hit = stats.hit_rate
    return default_hit


def tt_cache_sweep(
    *, vocab=262_144, dim=128, rank=16, pooling=32, batch=256, n_batches=24,
    slot_sweep=(64, 128, 256, 512),
) -> None:
    """Same sweep on the TT middle-core (i2) stream."""
    cfg = EmbeddingConfig(vocab=vocab, dim=dim, kind="tt", tt_rank=rank)
    spec = cfg.tt_spec
    trace = _batches(vocab, n_batches, batch, pooling)
    i2, _v2, row_bytes = intra_gnr.subtable_traces(trace, cfg)["g2"]
    for slots in slot_sweep:
        stats = simulate([i2[t] for t in range(n_batches)], spec.v2, slots)
        emit(
            f"cache_sim/tt_slots{slots}", 0.0,
            format_cache_traffic(cache_traffic(stats, row_bytes))
            + f" v2={spec.v2}",
        )


def locality_report(*, vocab=262_144, collision=64, pooling=32, n=40_000) -> None:
    """Intra-GnR reuse of every subtable — the prefetch-value ranking input."""
    trace = zipf_trace(vocab, n - n % pooling, alpha=ALPHA, seed=5).reshape(
        -1, pooling
    )
    for kind, kw in (
        ("qr", {"collision": collision}),
        ("tt", {"tt_rank": 16}),
    ):
        cfg = EmbeddingConfig(vocab=vocab, dim=128, kind=kind, **kw)
        locs = intra_gnr.analyze_table(trace, cfg)
        parts = " ".join(
            f"{name}={loc.mean_intra_reuse:.2f}(touched={loc.touched_rows})"
            for name, loc in locs.items()
        )
        emit(f"cache_sim/intra_gnr_{kind}", 0.0, f"reuse/bag: {parts}")


def duplication_report(
    *, vocab=262_144, collision=64, pooling=32, num_tables=8, batch=1024,
    shards=8, n=60_000,
) -> None:
    """Planner outcome at a generous and a starved budget (via engine.plan)."""
    from repro import engine as engine_mod

    trace = zipf_trace(vocab, n, alpha=ALPHA, seed=9)
    for kind, kw in (("qr", {"collision": collision}), ("tt", {"tt_rank": 16})):
        emb = EmbeddingConfig(vocab=vocab, dim=128, kind=kind, **kw)
        bags = [BagConfig(emb=emb, pooling=pooling) for _ in range(num_tables)]
        for budget in (64 * 2**20, 256 * 2**10):
            spec = engine_mod.EngineSpec.from_bags(
                bags, duplication=True, dup_budget_bytes=budget,
            )
            plan = engine_mod.plan(
                spec, num_shards=shards, trace=[trace] * num_tables,
            ).dup
            ici = plan.ici_bytes_per_batch(batch, emb.dim)
            emit(
                f"cache_sim/dup_{kind}_budget{budget // 1024}K", 0.0,
                f"replicated={plan.replicated_bytes}B comm_free={plan.comm_free} "
                f"local_share={plan.tables[0].local_share:.2f} "
                f"ici_saved/batch={ici['saved']:.0f}B of {ici['baseline']:.0f}B",
            )


def run(tiny: bool = False) -> None:
    if tiny:
        # CI smoke: same code paths, seconds not minutes
        hit = qr_cache_sweep(
            vocab=16_384, collision=16, pooling=8, batch=64, n_batches=6,
            slot_sweep=(64, 128), default_slots=128,
        )
        tt_cache_sweep(
            vocab=16_384, pooling=8, batch=64, n_batches=6, slot_sweep=(32, 64)
        )
        locality_report(vocab=16_384, collision=16, pooling=8, n=4_000)
        duplication_report(vocab=16_384, collision=16, num_tables=2, n=6_000)
    else:
        hit = qr_cache_sweep()
        tt_cache_sweep()
        locality_report()
        duplication_report()
    emit("cache_sim/default_hit_rate", 0.0, f"hit={hit:.3f} target>=0.8")
