"""Paper figures: temporal/spatial locality of the Q and R tables.

Reproduces the cache-behaviour analysis (paper Fig. 4(b), Fig. 5, Fig. 6): on
long-tail traces, Q-table hits stay high (it inherits the original table's
Zipf skew), the R table is ~100% hot, and R-table accesses are uniformly
distributed — the facts that justify pinning R in per-PIM SRAM (VMEM here)
and tiering Q.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import hashing
from repro.data.synthetic import zipf_trace


def lru_hit_rate(trace: np.ndarray, cache_rows: int) -> float:
    """Row-granular LRU cache simulation (hit rate)."""
    from collections import OrderedDict

    cache: OrderedDict[int, None] = OrderedDict()
    hits = 0
    for r in trace:
        r = int(r)
        if r in cache:
            hits += 1
            cache.move_to_end(r)
        else:
            cache[r] = None
            if len(cache) > cache_rows:
                cache.popitem(last=False)
    return hits / len(trace)


def run() -> None:
    vocab, n, collision = 262_144, 60_000, 8
    trace = zipf_trace(vocab, n, alpha=1.05, seed=7)
    q_idx, r_idx = np.asarray(trace) // collision, np.asarray(trace) % collision
    rand = np.random.default_rng(0).integers(0, vocab // collision, n)

    # temporal locality: hit rate vs cache size (1x .. 8x of "1MB"/64B rows)
    for rows in (4096, 8192, 16384, 32768):
        hq = lru_hit_rate(q_idx, rows)
        hr = lru_hit_rate(r_idx, rows)
        hrand = lru_hit_rate(rand, rows)
        emit(
            f"locality/hit_rate_cache{rows}", 0.0,
            f"q_table={hq:.3f} r_table={hr:.3f} random={hrand:.3f} "
            f"(paper: q>>random, r~1.0)",
        )
        assert hr > 0.99 and hq > hrand

    # R-table access uniformity (paper Fig. 6): coefficient of variation
    counts = np.bincount(r_idx, minlength=collision)
    cv = counts.std() / counts.mean()
    emit("locality/r_table_uniformity_cv", 0.0,
         f"cv={cv:.3f} (uniform => all R rows hot; LUT load-balances freely)")

    # Q-table long tail survives quotient folding (paper Fig. 5)
    qcounts = np.bincount(q_idx, minlength=vocab // collision)
    qsorted = np.sort(qcounts)[::-1]
    top1pct = qsorted[: len(qsorted) // 100].sum() / max(qsorted.sum(), 1)
    emit("locality/q_table_top1pct_share", 0.0,
         f"top1%_rows_serve={top1pct:.2%} of requests (long tail preserved)")

    run_tt(trace)


def run_tt(trace: np.ndarray) -> None:
    """TT-Rec intra-GnR locality (the paper's bg-PIM SRAM cache premise).

    The outer-core index streams (i1, i3) range over ~vocab**0.25 rows, so a
    tiny cache serves them at ~100% — that is the *structural* intra-GnR
    locality the paper prefetches into SRAM.  The middle-core stream (i2)
    inherits the Zipf skew, which is what legalizes hot-tiering it.
    """
    import jax.numpy as jnp

    from repro.core import placement
    from repro.core.qr_embedding import EmbeddingConfig

    vocab = 262_144
    cfg = EmbeddingConfig(vocab=vocab, dim=128, kind="tt", tt_rank=16)
    spec = cfg.tt_spec
    from repro.core.tt_embedding import tt_decompose

    i1, i2, i3 = (np.asarray(x) for x in tt_decompose(jnp.asarray(trace), spec))
    rand_mid = np.random.default_rng(0).integers(0, spec.v2, trace.size)

    cache_rows = 64                       # a few KB of SRAM at TT core widths
    h1 = lru_hit_rate(i1, cache_rows)
    h3 = lru_hit_rate(i3, cache_rows)
    h2 = lru_hit_rate(i2, cache_rows)
    h2r = lru_hit_rate(rand_mid, cache_rows)
    emit(
        f"locality/tt_hit_rate_cache{cache_rows}", 0.0,
        f"g1={h1:.3f} g3={h3:.3f} g2={h2:.3f} random_mid={h2r:.3f} "
        f"(paper: outer cores ~1.0 -> SRAM-pin; g2 skew > random -> hot tier)",
    )
    assert h1 > 0.99 and h3 > 0.99 and h2 > h2r

    # middle-core skew survives index folding (hot-tier granularity check)
    counts = placement.profile_counts(trace, vocab)
    folded = placement.fold_counts_tt(counts, spec)
    plan = placement.plan_tiers(folded, request_share=0.8)
    emit(
        "locality/tt_mid_hot_rows", 0.0,
        f"hot={plan.num_hot}/{spec.v2} rows serve 80% of requests "
        f"(fraction={plan.hot_fraction:.3f}; sub-linear like quotient folding)",
    )
    assert plan.hot_fraction < 0.9
