"""serve_storm — the resilient front end under calm, flash-crowd, and chaos.

Three scenarios over the same offline plan (one ``build_serve_state`` per
config, reused across scenarios so only the traffic and faults differ):

* ``calm``  — base-rate Poisson traffic, no faults: the front end's floor
  (expect ~zero shed, ~zero misses, ladder never moves);
* ``flash`` — a flash-crowd episode multiplies the arrival rate mid-run:
  admission control must shed, the ladder may step, everything recovers;
* ``chaos`` — flash crowd **plus** a dispatch stall, a prefetch drop, a
  replica loss, and transient gather errors: the full degradation ladder
  with bounded-retry dispatch.

Rows (one per scenario): ``us_per_call`` is the virtual p99 request latency;
``derived`` summarizes deadline-miss rate, shed rate, ladder transitions,
and time-to-recover; ``samples_s`` carries the per-batch virtual latencies;
and the ``extra`` payload stamps the **full arrival + fault specs (seeds
included)** so any JSON row reproduces its run exactly.

Virtual-clock semantics (see ``repro.serve.frontend``): latencies are
virtual seconds, so rows are comparable across hosts; the suite runs
``service_mode="measured"`` by default so real kernel time still moves the
needle, and ``tiny=True`` (CI) switches to ``"fixed"`` for determinism.
"""

from __future__ import annotations

import jax

from benchmarks import common
from repro import obs, serve
from repro.configs import registry
from repro.launch.serve_rec import build_serve_state
from repro.models import dlrm


def _scenarios(horizon_s: float, seed: int) -> list[tuple[str, str, str]]:
    """(name, arrival spec, fault spec) per scenario — all times virtual."""
    h = horizon_s
    flash = f"flash={0.3 * h:.2f}+{0.25 * h:.2f}x6"
    return [
        ("calm",
         f"rate=300,horizon={h},deadline_ms=250,seed={seed}",
         ""),
        ("flash",
         f"rate=300,horizon={h},deadline_ms=250,{flash},drift_s={0.4 * h:.2f},"
         f"seed={seed}",
         ""),
        ("chaos",
         f"rate=300,horizon={h},deadline_ms=250,{flash},seed={seed}",
         f"stall@{0.35 * h:.2f}:0.5,drop@{0.4 * h:.2f},"
         f"replica@{0.5 * h:.2f}:{0.2 * h:.2f},gather@{0.7 * h:.2f}:1,"
         f"retries=3"),
    ]


def _drift_scenario(cfg, params, state, *, horizon_s: float, seed: int,
                    tiny: bool) -> None:
    """Hot-set drift through the front end: frozen pin vs online adaptation.

    Both arms serve **pinned** residency (the steady-state configuration —
    the oracle prefetcher would self-heal and hide the drift); the adaptive
    arm adds the sketch->trigger->re-pin controller.  The emitted gap is the
    hit rate the adaptation subsystem buys back under rotation.
    """
    from repro.adapt import AdaptController, AdaptPolicy

    h = horizon_s
    arrival = (
        f"rate=300,horizon={h},deadline_ms=250,"
        f"drift_s={0.3 * h:.2f},drift_frac=0.3,seed={seed}"
    )
    aspec = serve.ArrivalSpec.parse(arrival)
    reports = {}
    for arm in ("frozen", "adaptive"):
        adapt = None
        if arm == "adaptive":
            adapt = AdaptController(
                state.eplan,
                policy=AdaptPolicy(check_every=4, min_batches=8,
                                   min_gain=0.08, cooldown_batches=4),
                sketch_kw=dict(window_batches=4, windows=4, decay=0.3),
                seed=seed,
            )
        fcfg = serve.FrontendConfig(
            batch_size=8, queue_cap=48, residency="pinned",
            service_mode="fixed" if tiny else "measured",
        )
        frontend = serve.Frontend(cfg, fcfg, state, params, adapt=adapt)
        reports[arm] = frontend.run(serve.generate(aspec, cfg))

    gap = reports["adaptive"]["hit_rate"] - reports["frozen"]["hit_rate"]
    events = reports["adaptive"].get("adapt", {}).get("event_log", [])
    for arm, report in reports.items():
        common.emit(
            f"serve_storm/drift/{arm}",
            report["req_lat_p99_s"] * 1e6,
            f"hit_rate={report['hit_rate']:.3f} "
            f"served={report['requests']['served']} "
            + (f"replans={len(events)} adaptive_gap={gap:+.3f}"
               if arm == "adaptive" else "(pinned, no adaptation)"),
            extra={
                "scenario": "drift", "arm": arm, "seed": seed,
                "arrival": aspec.describe(),
                "hit_rate": report["hit_rate"],
                "adaptive_gap": gap,
                **({"adapt_events": events} if arm == "adaptive" else {}),
            },
        )


def run(tiny: bool = False, seed: int = 0) -> None:
    cfg = registry.get_dlrm("dlrm-qr-smoke")
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(seed), cfg)
    state = build_serve_state(cfg, shards=4, alpha=1.05, seed=seed)
    horizon = 1.5 if tiny else 3.0
    fcfg = serve.FrontendConfig(
        batch_size=8, queue_cap=48,
        service_mode="fixed" if tiny else "measured",
    )

    for name, arrival, faults in _scenarios(horizon, seed):
        aspec = serve.ArrivalSpec.parse(arrival)
        fspec = serve.FaultSpec.parse(faults) if faults else serve.FaultSpec()
        slo = obs.SLOEngine(obs.SLOSpec.parse(
            "p99_ms=60,objective=0.99,fast_window=4,slow_window=8,"
            f"name=storm_{name}"
        ))
        frontend = serve.Frontend(
            cfg, fcfg, state, params,
            slo=slo, faults=serve.FaultInjector(fspec),
        )
        report = frontend.run(serve.generate(aspec, cfg))

        req = report["requests"]
        deg = report["degrade"]
        ttr = report["time_to_recover_s"]
        common.emit(
            f"serve_storm/{name}/p99_virtual",
            report["req_lat_p99_s"] * 1e6,
            f"served={req['served']}/{req['generated']} "
            f"miss={report['deadline_miss_rate']:.3f} "
            f"shed={report['shed_rate']:.3f} "
            f"steps={len(deg['transitions'])} "
            f"ttr={'%.2fs' % ttr if ttr is not None else 'n/a'} "
            f"unaccounted={req['unaccounted']}",
            samples=None,
            extra={
                "scenario": name,
                "seed": seed,
                "arrival": aspec.describe(),
                "faults": fspec.describe(),
                "requests": req,
                "deadline_miss_rate": report["deadline_miss_rate"],
                "shed_rate": report["shed_rate"],
                "time_to_recover_s": ttr,
                "transitions": deg["transitions"],
                "service_mode": fcfg.service_mode,
            },
        )
        common.emit(
            f"serve_storm/{name}/p50_virtual",
            report["req_lat_p50_s"] * 1e6,
            f"virtual_qps={report['virtual_qps']:.0f} "
            f"hit_rate={report['hit_rate']:.3f}",
            extra={"scenario": name, "seed": seed},
        )
        if req["unaccounted"] != 0:
            raise AssertionError(
                f"serve_storm/{name}: {req['unaccounted']} unaccounted "
                f"requests — the front end's conservation law is broken"
            )

    _drift_scenario(cfg, params, state, horizon_s=horizon, seed=seed,
                    tiny=tiny)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(tiny=True)
