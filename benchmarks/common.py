"""Shared benchmark helpers: timing, CSV + JSON emission."""

from __future__ import annotations

import functools
import json
import time

import jax

ROWS: list[tuple[str, float, str, list | None, dict | None]] = []


@functools.lru_cache(maxsize=1)
def run_metadata() -> dict:
    """Host identity stamped on every JSON row, so baselines and tuner-cache
    entries from different machines are never compared blindly (same fields
    as the tuner cache: ``repro.tune.run_metadata``)."""
    from repro.tune import run_metadata as _meta

    return dict(_meta())


def emit(name: str, us_per_call: float, derived: str = "",
         samples: list | None = None, extra: dict | None = None) -> None:
    """Record one benchmark row.  ``samples`` (per-batch latency seconds)
    rides along into the JSON artifact as ``samples_s`` so the baseline gate
    can bootstrap a confidence interval instead of comparing two points.
    ``extra`` is merged verbatim into the JSON record — suites use it to
    stamp the seeds/specs that reproduce the row (e.g. ``serve_storm``'s
    arrival + fault schedules)."""
    ROWS.append((name, us_per_call, derived,
                 [float(s) for s in samples] if samples else None,
                 dict(extra) if extra else None))
    print(f"{name},{us_per_call:.2f},{derived}")


def write_json(path: str) -> None:
    """Dump every emitted row as machine-readable JSON (perf-trajectory
    tracking across PRs: stable keys, one record per ``emit``, each stamped
    with the host/backend metadata and, for serving rows, the raw latency
    samples the noise-aware gate resamples)."""
    meta = run_metadata()
    records = [
        {"name": n, "us_per_call": u, "derived": d, **meta,
         **({"samples_s": s} if s else {}),
         **(x or {})}
        for n, u, d, s, x in ROWS
    ]
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    print(f"# wrote {len(records)} records to {path}")


def time_jit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of a jitted callable on this host."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
