"""Paper table: traffic amplification of weight-sharing embedding.

The paper's premise (its Fig. 4(a) analog): compositional/QR embedding doubles
main-memory access vs the dense table — ~25% (HBM) / ~40% (DIMM) slower end to
end — and the shared-table LUT restores parity.  We validate with (a) the
analytic bytes model and (b) measured wall-time of the jitted GnR variants on
this host (one memory system; the *ratio* is the reproduction target).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro.core import embedding_bag as EB, qr_embedding as QE
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig


def _bag(kind, dim, vocab=2_000_000, collision=64, tt_rank=16):
    emb = EmbeddingConfig(
        vocab=vocab, dim=dim, kind=kind, collision=collision, tt_rank=tt_rank,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    return BagConfig(emb=emb, pooling=32)


def run() -> None:
    # (a) analytic bytes per bag, the paper's core arithmetic
    for dim in (32, 64, 128):          # 128B / 256B / 512B rows
        bag = _bag("qr", dim)
        t = EB.traffic_model(bag, bytes_per_elem=4)
        emit(
            f"traffic/qr_dim{dim}", 0.0,
            f"dense={t['dense']}B naive_qr={t['naive']}B fused_lut={t['fused']}B "
            f"amplification={t['naive'] / t['dense']:.2f}x",
        )

    # TT-Rec: amplification is rank-driven (core rows are r*d2*r wide — wider
    # than the dense row at high rank), and the SRAM pin removes two of the
    # three core fetches: the paper's Fig. 4(a) arithmetic for the TT path.
    for rank in (8, 16, 32):
        bag = _bag("tt", 128, tt_rank=rank)
        t = EB.traffic_model(bag, bytes_per_elem=4)
        emit(
            f"traffic/tt_dim128_rank{rank}", 0.0,
            f"dense={t['dense']}B naive_tt={t['naive']}B fused_sram={t['fused']}B "
            f"amplification={t['naive'] / t['dense']:.2f}x "
            f"fused_vs_dense={t['fused'] / t['dense']:.2f}x",
        )

    # (b) measured: dense vs naive-QR vs fused GnR on this host, in the
    # DRAM-bound regime the paper assumes (tables >> last-level cache; a
    # cache-resident compressed table would behave like the paper's SRAM LUT
    # and invert the comparison — that effect itself is the LUT insight).
    batch, pooling, dim, vocab, coll = 2048, 8, 64, 8_000_000, 8
    key = jax.random.PRNGKey(0)
    idx = jax.random.randint(key, (batch, pooling), 0, vocab)

    dense_bag = _bag("dense", dim, vocab, coll)
    dense_params = QE.init(key, dense_bag.emb)       # 2 GB table
    f_dense = jax.jit(lambda p, i: EB.bag_lookup(p, i, dense_bag))
    t_dense = time_jit(f_dense, dense_params, idx)

    qr_bag = _bag("qr", dim, vocab, coll)            # 256 MB Q table
    qr_params = QE.init(key, qr_bag.emb)
    # naive: two full-table-path gathers, reduce after reconstruction
    f_naive = jax.jit(
        lambda p, i: QE.lookup(p, i, qr_bag.emb).sum(axis=-2)
    )
    t_naive = time_jit(f_naive, qr_params, idx)
    # fused: associativity-split partial sums (R reduced against the tiny
    # table = the LUT effect at XLA level)
    f_fused = jax.jit(lambda p, i: EB.bag_lookup(p, i, qr_bag))
    t_fused = time_jit(f_fused, qr_params, idx)

    emit("traffic/measured_dense_gnr", t_dense, f"batch={batch} pooling={pooling}")
    emit(
        "traffic/measured_naive_qr_gnr", t_naive,
        f"vs_dense={t_naive / t_dense:.2f}x (paper band 1.25-1.40x; <1 means "
        f"the compressed table went cache-resident = the LUT effect)",
    )
    emit(
        "traffic/measured_fused_qr_gnr", t_fused,
        f"vs_naive={t_naive / t_fused:.2f}x (fused partial-sum GnR)",
    )
