"""Cached pooled gather-and-reduce — the bg-PIM SRAM cache in a Pallas kernel.

The ProactivePIM cache serves high-intra-GnR-locality rows from bank-group
SRAM while the remaining rows stream from DRAM.  TPU realization:

* the **cache block** — a ``(slots, dim)`` slice holding the rows the prefetch
  scheduler staged for this batch — is mapped into VMEM once (constant
  BlockSpec index map, resident across all grid steps);
* the **slot map** rides in SMEM via scalar prefetch alongside the indices:
  for each bag element the kernel reads ``slot[b, k]`` and routes the access
  — ``slot >= 0`` selects the VMEM cache row, ``slot < 0`` selects the row
  DMA'd from HBM by the streamed operand;
* the **streamed operand**'s index map sends misses to ``idx[b, k]`` and pins
  hits to block 0: Pallas elides the DMA when consecutive grid steps name the
  same block, so runs of cache hits issue *no* HBM traffic — the kernel-level
  analogue of the cache absorbing DRAM accesses;
* accumulation is fp32 in a VMEM output block revisited across the K steps
  (bank-group MAC + register file), exactly like ``gnr_bag``.

Two variants: ``cached_bag`` (dense / big-table-only) and ``cached_qr_bag``
(fused with the VMEM-resident R LUT, so one bag element costs at most one
HBM row — and zero on a cache hit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_DIM_BLOCK = 512


def _cached_kernel(idx_ref, slot_ref, row_ref, cache_ref, out_ref):
    b, k = pl.program_id(0), pl.program_id(1)
    s = slot_ref[b, k]
    hit = s >= 0
    cached = cache_ref[jnp.maximum(s, 0), :][None, :].astype(jnp.float32)
    streamed = row_ref[...].astype(jnp.float32)
    row = jnp.where(hit, cached, streamed)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = row

    @pl.when(k > 0)
    def _acc():
        out_ref[...] = out_ref[...] + row


def _cached_qr_kernel(q_idx_ref, slot_ref, r_idx_ref, row_ref, cache_ref,
                      r_lut_ref, out_ref):
    b, k = pl.program_id(0), pl.program_id(1)
    s = slot_ref[b, k]
    hit = s >= 0
    cached = cache_ref[jnp.maximum(s, 0), :][None, :].astype(jnp.float32)
    streamed = row_ref[...].astype(jnp.float32)
    row = jnp.where(hit, cached, streamed)
    row = row + r_lut_ref[r_idx_ref[b, k], :][None, :].astype(jnp.float32)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = row

    @pl.when(k > 0)
    def _acc():
        out_ref[...] = out_ref[...] + row


def _stream_spec(bd: int):
    # Misses DMA row idx[b,k]; hits pin the stream to block 0 so consecutive
    # hits revisit the same block and Pallas skips the fetch.
    return pl.BlockSpec(
        (1, bd), lambda b, k, j, idx, slot, *_: (jnp.where(slot[b, k] >= 0, 0, idx[b, k]), j)
    )


@functools.partial(jax.jit, static_argnames=("dim_block", "interpret"))
def cached_bag(
    table: jax.Array,
    cache: jax.Array,
    idx: jax.Array,
    slot: jax.Array,
    *,
    dim_block: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Cached pooled bag: out[b] = Σ_k (slot[b,k] >= 0 ? C[slot] : T[idx]).

    table: (rows, dim) in HBM; cache: (slots, dim) VMEM-resident (the staged
    block — same dtype as table); idx/slot: (B, K) int32.  Returns (B, dim)
    in the table dtype (fp32 accumulation inside).
    """
    bsz, k_steps = idx.shape
    dim = table.shape[1]
    bd = dim_block or min(dim, DEFAULT_DIM_BLOCK)
    assert dim % bd == 0, f"dim {dim} not divisible by dim_block {bd}"
    assert cache.shape[1] == dim, (cache.shape, table.shape)

    grid = (bsz, k_steps, dim // bd)
    kernel = pl.pallas_call(
        _cached_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                _stream_spec(bd),
                pl.BlockSpec((cache.shape[0], bd), lambda b, k, j, idx, slot: (0, j)),
            ],
            out_specs=pl.BlockSpec((1, bd), lambda b, k, j, idx, slot: (b, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, dim), jnp.float32),
        interpret=interpret,
    )
    out = kernel(idx.astype(jnp.int32), slot.astype(jnp.int32), table, cache)
    return out.astype(table.dtype)


@functools.partial(jax.jit, static_argnames=("dim_block", "interpret"))
def cached_qr_bag(
    q_table: jax.Array,
    cache: jax.Array,
    r_lut: jax.Array,
    q_idx: jax.Array,
    slot: jax.Array,
    r_idx: jax.Array,
    *,
    dim_block: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Cached pooled QR bag:
    out[b] = Σ_k ( (slot >= 0 ? C[slot] : Q[q_idx]) + R[r_idx] ).

    The R LUT and the cache block are both VMEM-resident; only cache misses
    touch HBM.  q_idx/slot/r_idx: (B, K) int32 -> (B, dim).
    """
    bsz, k_steps = q_idx.shape
    dim = q_table.shape[1]
    bd = dim_block or min(dim, DEFAULT_DIM_BLOCK)
    assert dim % bd == 0, f"dim {dim} not divisible by dim_block {bd}"
    assert cache.shape[1] == dim and r_lut.shape[1] == dim

    grid = (bsz, k_steps, dim // bd)
    kernel = pl.pallas_call(
        _cached_qr_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                _stream_spec(bd),
                pl.BlockSpec(
                    (cache.shape[0], bd), lambda b, k, j, qi, sl, ri: (0, j)
                ),
                pl.BlockSpec(
                    (r_lut.shape[0], bd), lambda b, k, j, qi, sl, ri: (0, j)
                ),
            ],
            out_specs=pl.BlockSpec((1, bd), lambda b, k, j, qi, sl, ri: (b, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, dim), jnp.float32),
        interpret=interpret,
    )
    out = kernel(
        q_idx.astype(jnp.int32), slot.astype(jnp.int32), r_idx.astype(jnp.int32),
        q_table, cache, r_lut,
    )
    return out.astype(q_table.dtype)
