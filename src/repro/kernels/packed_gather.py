"""Multi-table megakernel: packed-table fused gather-and-reduce.

The serving and mesh paths used to launch one Pallas kernel per embedding
table — a 26-table DLRM paid 26 dispatches plus 26 short HBM-streaming loops
per batch.  ProactivePIM's bg-PIM wins by batching many small gathers into one
wide memory-side pass (the RecNMP / TensorDIMM observation); the TPU analogue
is a single kernel over a **packed** layout:

* all same-width big subtables (dense tables / QR Q tables / TT middle cores)
  are concatenated row-major into ONE buffer; per-table row offsets turn the
  logical (table_id, row) pair into a flat packed row id **before** the kernel
  — the index streams arriving here are already global;
* bags from every table ride one flattened stream: grid step ``g`` is bag
  ``(sample b, table t) = divmod(g, T)``; the kernel never sees table
  boundaries, so HBM row DMAs pipeline *across* tables instead of draining
  per-table loops back-to-back;
* the small shared subtables of every table (QR R LUTs, TT outer cores) are
  packed the same way and mapped into VMEM once — one resident block serves
  all tables;
* cache-slot routing (PR 3's prefetch scheduler) is folded in: ``slot >= 0``
  reads the packed VMEM cache block (per-table slot ranges concatenated),
  ``slot < 0`` streams the HBM row.  Hits pin the streamed operand to block 0
  so Pallas elides their DMAs — runs of hits issue no HBM traffic;
* accumulation is fp32 in a VMEM output block revisited across the K steps.

The mesh path calls the same kernels with a 1-row dummy cache and an all-miss
slot map: masking (non-owned rows, off-shard R positions, ragged bag tails)
is expressed by routing those accesses to an appended all-zero row, so one
kernel body covers cached serving, sharded partials, and ragged bags.

Layout construction and index-stream packing live in
``repro.core.packed_tables``; pure-jnp oracles in ``ref.py``
(``packed_bag_ref`` / ``packed_qr_bag_ref`` / ``packed_tt_bag_ref``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import cached_gather as _cg

# Budget for the VMEM-RESIDENT operands of one dispatch (packed cache block +
# packed R LUT / TT outer cores; constant index maps keep them live across the
# whole grid).  Layout builders must size slot budgets under this — see
# DLRMConfig.cache_vmem_mb — so the guard failing means a mis-sized layout,
# caught at trace time instead of as a Mosaic VMEM OOM.
VMEM_RESIDENT_BUDGET = 12 * 2**20


def _check_resident(**blocks) -> None:
    total = sum(a.size * a.dtype.itemsize for a in blocks.values())
    assert total <= VMEM_RESIDENT_BUDGET, (
        f"VMEM-resident operands {total / 2**20:.1f} MiB exceed the "
        f"{VMEM_RESIDENT_BUDGET / 2**20:.0f} MiB budget: "
        + ", ".join(f"{k}={tuple(v.shape)}" for k, v in blocks.items())
        + " — shrink the cache slot budget (cache_vmem_mb) or the packed LUTs"
    )


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------

def _packed_tt_kernel(
    i1_ref, i2_ref, i3_ref, slot_ref,   # scalar-prefetched (G, K) streams
    g2_row_ref,                          # (1, r*d2*r) streamed middle-core row
    cache_ref,                           # (slots, r*d2*r) staged G2 rows (VMEM)
    g1_ref,                              # (T*v1, d1*r) packed outer cores (VMEM)
    g3_ref,                              # (T*v3, r*d3) packed outer cores (VMEM)
    out_ref,                             # (1, d1*d2*d3) fp32 accumulator
    *,
    d1: int, d2: int, d3: int, rank: int,
):
    g, k = pl.program_id(0), pl.program_id(1)
    s = slot_ref[g, k]
    hit = s >= 0
    cached = cache_ref[jnp.maximum(s, 0), :].astype(jnp.float32)
    streamed = g2_row_ref[0, :].astype(jnp.float32)
    m = jnp.where(hit, cached, streamed).reshape(rank, d2 * rank)
    a = g1_ref[i1_ref[g, k], :].astype(jnp.float32).reshape(d1, rank)
    t = jnp.dot(a, m, preferred_element_type=jnp.float32).reshape(d1 * d2, rank)
    c = g3_ref[i3_ref[g, k], :].astype(jnp.float32).reshape(rank, d3)
    row = jnp.dot(t, c, preferred_element_type=jnp.float32).reshape(1, d1 * d2 * d3)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = row

    @pl.when(k > 0)
    def _acc():
        out_ref[...] = out_ref[...] + row


# ---------------------------------------------------------------------------
# megakernel dispatchers (one pallas_call for ALL tables)
# ---------------------------------------------------------------------------

def packed_bag(
    table: jax.Array,
    cache: jax.Array,
    idx: jax.Array,
    slot: jax.Array,
    *,
    dim_block: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Packed dense megabag: out[g] = Σ_k (slot[g,k] >= 0 ? C[slot] : T[idx]).

    table: (total_rows, dim) — ALL tables concatenated (+ trailing zero row);
    cache: (total_slots, dim) packed staged block; idx/slot: (G, K) int32
    with G = batch * num_tables and idx already globally offset.

    The kernel body IS ``cached_gather.cached_bag``: the multi-table fusion
    lives entirely in the pre-offset index stream and the packed buffers, so
    the slot-routing/hit-pinning logic stays single-sourced.  This wrapper
    adds the packed-layout VMEM-residency guard (the cache block here holds
    EVERY table's slots).  Returns (G, dim) in the table dtype.
    """
    _check_resident(cache=cache)
    return _cg.cached_bag(
        table, cache, idx, slot, dim_block=dim_block, interpret=interpret
    )


def packed_qr_bag(
    q_table: jax.Array,
    cache: jax.Array,
    r_lut: jax.Array,
    q_idx: jax.Array,
    slot: jax.Array,
    r_idx: jax.Array,
    *,
    dim_block: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Packed QR megabag:
    out[g] = Σ_k ( (slot >= 0 ? C[slot] : Q[q_idx]) + R[r_idx] ).

    q_table: (total_q_rows, dim) all Q tables packed (+ zero row); r_lut:
    (total_r_rows, dim) all R LUTs packed (+ zero row), VMEM-resident as one
    block; q_idx/slot/r_idx: (G, K) globally-offset streams -> (G, dim).
    Kernel body = ``cached_gather.cached_qr_bag`` over the packed buffers
    (see ``packed_bag``), plus the packed-layout residency guard.
    """
    _check_resident(cache=cache, r_lut=r_lut)
    return _cg.cached_qr_bag(
        q_table, cache, r_lut, q_idx, slot, r_idx,
        dim_block=dim_block, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("dims", "interpret"))
def packed_tt_bag(
    g1: jax.Array,
    g2: jax.Array,
    g3: jax.Array,
    cache: jax.Array,
    i1: jax.Array,
    i2: jax.Array,
    i3: jax.Array,
    slot: jax.Array,
    *,
    dims: tuple[int, int, int, int],
    interpret: bool = False,
) -> jax.Array:
    """Packed TT megabag with slot-routed middle core:
    out[g] = Σ_k G1[i1] · (slot >= 0 ? C[slot] : G2[i2]) · G3[i3].

    g1: (T*v1, d1*r) / g3: (T*v3, r*d3) — every table's outer cores packed and
    VMEM-resident (the bg-PIM SRAM pin, now shared by the whole model);
    g2: (total_v2_rows, r*d2*r) packed middle cores (+ zero row); cache:
    (total_slots, r*d2*r) staged G2 rows.  i1/i2/i3/slot: (G, K) globally
    offset.  ``dims`` = (d1, d2, d3, rank), static.  Returns (G, d1*d2*d3).
    """
    d1, d2, d3, rank = dims
    gsz, k_steps = i1.shape
    dim = d1 * d2 * d3
    assert g1.shape[1] == d1 * rank, (g1.shape, dims)
    assert g2.shape[1] == rank * d2 * rank, (g2.shape, dims)
    assert g3.shape[1] == rank * d3, (g3.shape, dims)
    assert cache.shape[1] == g2.shape[1], (cache.shape, g2.shape)
    _check_resident(cache=cache, g1=g1, g3=g3)

    grid = (gsz, k_steps)
    kernel = pl.pallas_call(
        functools.partial(_packed_tt_kernel, d1=d1, d2=d2, d3=d3, rank=rank),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                # Streamed G2 row: misses DMA i2's packed row, hits pin block 0.
                pl.BlockSpec(
                    (1, g2.shape[1]),
                    lambda g, k, i1, i2, i3, sl: (
                        jnp.where(sl[g, k] >= 0, 0, i2[g, k]), 0
                    ),
                ),
                pl.BlockSpec(
                    (cache.shape[0], cache.shape[1]),
                    lambda g, k, i1, i2, i3, sl: (0, 0),
                ),
                pl.BlockSpec(g1.shape, lambda g, k, i1, i2, i3, sl: (0, 0)),
                pl.BlockSpec(g3.shape, lambda g, k, i1, i2, i3, sl: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, dim), lambda g, k, i1, i2, i3, sl: (g, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((gsz, dim), jnp.float32),
        interpret=interpret,
    )
    out = kernel(
        i1.astype(jnp.int32), i2.astype(jnp.int32), i3.astype(jnp.int32),
        slot.astype(jnp.int32), g2, cache, g1, g3,
    )
    return out.astype(g2.dtype)
