"""Pallas TPU kernels for the paper's compute hot-spots (+ a fused attention
kernel motivated by the roofline analysis). Validated with interpret=True on
CPU against the pure-jnp oracles in ``ref.py``; ``ops.py`` is the public
jit'd surface with shape dispatch and CPU fallbacks.

* ``qr_gather``        — fused QR lookup: HBM Q-row DMA + VMEM-resident R LUT
                         (the paper's shared-table-in-SRAM mechanism)
* ``gnr_bag``          — pooled gather-and-reduce bag with fp32 VMEM
                         accumulator (the bank-group partial-GnR unit)
* ``tt_gather``        — fused TT-Rec gather-contract bag: outer cores pinned
                         in VMEM (bg-PIM SRAM cache), middle core streamed by
                         scalar-prefetched index, fp32 chained contraction
* ``cached_gather``    — slot-map-routed cached bag (hits read the VMEM cache
                         block staged by the prefetch scheduler)
* ``packed_gather``    — multi-table megakernel: every table's pooled bag in
                         ONE grid over packed buffers (dense/QR/TT variants,
                         cache-slot routing folded in) — replaces the
                         per-table kernel loop on the serving + sharded paths
* ``flash_attention``  — VMEM-resident online-softmax attention (kills the
                         dominant memory-roofline term; see EXPERIMENTS §Perf)
"""

from repro.kernels import ops, ref  # noqa: F401
