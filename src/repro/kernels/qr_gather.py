"""Fused quotient–remainder gather kernel (the paper's LUT mechanism on TPU).

One logical lookup = one HBM row DMA (the Q row) + one VMEM LUT read (the R
row).  The naive QR implementation costs two HBM gathers per lookup; pinning
the small shared table in VMEM removes the second one — this kernel *is* the
"shared-table-in-PIM-SRAM" idea expressed in the TPU memory hierarchy:

* ``r_lut``   — whole R table mapped into VMEM once (BlockSpec index_map is
  constant), persisting across all grid steps: the SRAM LUT;
* ``q_table`` — stays in HBM; each grid step DMAs exactly the row named by the
  scalar-prefetched index (``PrefetchScalarGridSpec``), so the *indices run
  ahead of the data* and Pallas double-buffers row ``i+1``'s DMA behind row
  ``i``'s add: the proactive-prefetch analogue;
* the reconstruction add runs on the VPU between DMAs — GnR "in memory".

Grid: one step per lookup row, a second grid dim tiles wide embedding dims so
the VMEM working set stays bounded and lanes stay 128-aligned on hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Lane-dim tile for wide embeddings; must divide dim or equal dim.
DEFAULT_DIM_BLOCK = 512


def _kernel(q_idx_ref, r_idx_ref, q_row_ref, r_lut_ref, out_ref):
    n = pl.program_id(0)
    r = r_idx_ref[n]
    out_ref[...] = q_row_ref[...] + r_lut_ref[r, :][None, :]


@functools.partial(
    jax.jit, static_argnames=("dim_block", "interpret")
)
def qr_gather(
    q_table: jax.Array,
    r_lut: jax.Array,
    q_idx: jax.Array,
    r_idx: jax.Array,
    *,
    dim_block: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """out[n, :] = q_table[q_idx[n], :] + r_lut[r_idx[n], :].

    q_table: (Q, D) float; r_lut: (C, D) same dtype; q_idx/r_idx: (N,) int32.
    """
    n = q_idx.shape[0]
    dim = q_table.shape[1]
    bd = dim_block or min(dim, DEFAULT_DIM_BLOCK)
    assert dim % bd == 0, f"dim {dim} not divisible by dim_block {bd}"

    grid = (n, dim // bd)
    kernel = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # q_idx, r_idx ride in SMEM ahead of DMAs
            grid=grid,
            in_specs=[
                # One Q row per step, DMA'd from HBM by prefetched index.
                pl.BlockSpec((1, bd), lambda i, j, qi, ri: (qi[i], j)),
                # The LUT: same block every step -> stays resident in VMEM.
                pl.BlockSpec((r_lut.shape[0], bd), lambda i, j, qi, ri: (0, j)),
            ],
            out_specs=pl.BlockSpec((1, bd), lambda i, j, qi, ri: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, dim), q_table.dtype),
        interpret=interpret,
    )
    return kernel(q_idx.astype(jnp.int32), r_idx.astype(jnp.int32), q_table, r_lut)
