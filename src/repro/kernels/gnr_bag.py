"""Pooled gather-and-reduce kernel (embedding bag) with fused QR LUT.

Computes ``out[b] = Σ_k ( Q[q_idx[b,k]] + R[r_idx[b,k]] )`` — the DLRM bag
operator with the weight-sharing reconstruction folded into the reduction.

TPU realization of the PIM partial-GnR unit:

* grid ``(B, K, dim_tiles)`` — the output block for bag ``b`` is *revisited*
  across the K steps (TPU grids execute sequentially, so in-place accumulation
  into the output block is the idiomatic reduction pattern);
* the accumulator lives in VMEM in fp32 (MAC-unit accuracy), initialized at
  k==0 and written through on every step — bank-group MAC + register file;
* Q rows stream from HBM via scalar-prefetched index maps (double-buffered by
  the Pallas pipeline = proactive prefetch), R rows come from the resident
  VMEM LUT; one bag element costs one HBM row, not two.

A dense (non-weight-sharing) variant is included for baseline benches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_DIM_BLOCK = 512


def _qr_kernel(q_idx_ref, r_idx_ref, q_row_ref, r_lut_ref, out_ref, *, k_steps):
    # out_ref is the fp32 VMEM accumulator (bank-group MAC register file);
    # it is revisited across the K grid steps of the same bag.
    b, k = pl.program_id(0), pl.program_id(1)
    row = q_row_ref[...].astype(jnp.float32)
    r = r_idx_ref[b, k]
    row = row + r_lut_ref[r, :][None, :].astype(jnp.float32)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = row

    @pl.when(k > 0)
    def _acc():
        out_ref[...] = out_ref[...] + row


def _dense_kernel(idx_ref, row_ref, out_ref, *, k_steps):
    k = pl.program_id(1)
    row = row_ref[...].astype(jnp.float32)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = row

    @pl.when(k > 0)
    def _acc():
        out_ref[...] = out_ref[...] + row


@functools.partial(jax.jit, static_argnames=("dim_block", "interpret"))
def gnr_bag(
    q_table: jax.Array,
    r_lut: jax.Array,
    q_idx: jax.Array,
    r_idx: jax.Array,
    *,
    dim_block: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Pooled QR bag. q_idx/r_idx: (B, K) int32 -> out (B, D)."""
    bsz, k_steps = q_idx.shape
    dim = q_table.shape[1]
    bd = dim_block or min(dim, DEFAULT_DIM_BLOCK)
    assert dim % bd == 0, f"dim {dim} not divisible by dim_block {bd}"

    grid = (bsz, k_steps, dim // bd)
    kernel = pl.pallas_call(
        functools.partial(_qr_kernel, k_steps=k_steps),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bd), lambda b, k, j, qi, ri: (qi[b, k], j)),
                pl.BlockSpec(
                    (r_lut.shape[0], bd), lambda b, k, j, qi, ri: (0, j)
                ),
            ],
            out_specs=pl.BlockSpec((1, bd), lambda b, k, j, qi, ri: (b, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, dim), jnp.float32),
        interpret=interpret,
    )
    out = kernel(q_idx.astype(jnp.int32), r_idx.astype(jnp.int32), q_table, r_lut)
    return out.astype(q_table.dtype)


@functools.partial(jax.jit, static_argnames=("dim_block", "interpret"))
def gnr_bag_dense(
    table: jax.Array,
    idx: jax.Array,
    *,
    dim_block: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Pooled dense bag (baseline: no weight sharing). idx: (B, K) -> (B, D)."""
    bsz, k_steps = idx.shape
    dim = table.shape[1]
    bd = dim_block or min(dim, DEFAULT_DIM_BLOCK)
    assert dim % bd == 0

    grid = (bsz, k_steps, dim // bd)
    kernel = pl.pallas_call(
        functools.partial(_dense_kernel, k_steps=k_steps),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((1, bd), lambda b, k, j, i: (i[b, k], j))],
            out_specs=pl.BlockSpec((1, bd), lambda b, k, j, i: (b, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, dim), jnp.float32),
        interpret=interpret,
    )
    return kernel(idx.astype(jnp.int32), table).astype(table.dtype)
