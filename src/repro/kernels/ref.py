"""Pure-jnp oracles for every Pallas kernel in this package.

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle; these are the
ground truth, kept deliberately naive.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def qr_lookup_ref(
    q_table: jax.Array, r_lut: jax.Array, q_idx: jax.Array, r_idx: jax.Array
) -> jax.Array:
    """Fused QR reconstruction: out[n] = Q[q_idx[n]] + R[r_idx[n]]."""
    return q_table[q_idx] + r_lut[r_idx]


def gnr_bag_ref(
    q_table: jax.Array, r_lut: jax.Array, q_idx: jax.Array, r_idx: jax.Array
) -> jax.Array:
    """Pooled QR bag: out[b] = Σ_k ( Q[q_idx[b,k]] + R[r_idx[b,k]] ).

    Accumulation in fp32 regardless of table dtype (kernel matches this).
    """
    rows = (q_table[q_idx].astype(jnp.float32) + r_lut[r_idx].astype(jnp.float32))
    return rows.sum(axis=-2).astype(q_table.dtype)


def dense_bag_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Pooled dense bag: out[b] = Σ_k T[idx[b,k]] (fp32 accumulation)."""
    return table[idx].astype(jnp.float32).sum(axis=-2).astype(table.dtype)


def tt_row_ref(
    g1: jax.Array, g2: jax.Array, g3: jax.Array,
    i1: jax.Array, i2: jax.Array, i3: jax.Array,
    *, dims: tuple[int, int, int, int],
) -> jax.Array:
    """Unpooled TT reconstruction (fp32 contraction):
    out[n] = G1[i1[n]] · G2[i2[n]] · G3[i3[n]] reshaped to d1*d2*d3."""
    d1, d2, d3, rank = dims
    a = g1[i1].astype(jnp.float32).reshape(*i1.shape, d1, rank)
    b = g2[i2].astype(jnp.float32).reshape(*i2.shape, rank, d2, rank)
    c = g3[i3].astype(jnp.float32).reshape(*i3.shape, rank, d3)
    rows = jnp.einsum("...ap,...pbq,...qc->...abc", a, b, c)
    return rows.reshape(*i1.shape, d1 * d2 * d3).astype(g2.dtype)


def tt_bag_ref(
    g1: jax.Array, g2: jax.Array, g3: jax.Array,
    i1: jax.Array, i2: jax.Array, i3: jax.Array,
    *, dims: tuple[int, int, int, int],
) -> jax.Array:
    """Pooled TT bag: out[b] = Σ_k G1[i1[b,k]]·G2[i2[b,k]]·G3[i3[b,k]].

    Contraction and accumulation in fp32 regardless of core dtype (kernel
    matches this; no intermediate round-trip through the core dtype)."""
    d1, d2, d3, rank = dims
    a = g1[i1].astype(jnp.float32).reshape(*i1.shape, d1, rank)
    b = g2[i2].astype(jnp.float32).reshape(*i2.shape, rank, d2, rank)
    c = g3[i3].astype(jnp.float32).reshape(*i3.shape, rank, d3)
    rows = jnp.einsum("...ap,...pbq,...qc->...abc", a, b, c)
    rows = rows.reshape(*i1.shape, d1 * d2 * d3)
    return rows.sum(axis=-2).astype(g2.dtype)


def cached_bag_ref(
    table: jax.Array, cache: jax.Array, idx: jax.Array, slot: jax.Array
) -> jax.Array:
    """Cached pooled bag: out[b] = Σ_k (slot[b,k] >= 0 ? C[slot] : T[idx]).

    ``slot`` routes each access: >= 0 selects the staged cache row, -1 the
    backing table (fp32 accumulation; kernel matches this).
    """
    hit = (slot >= 0)[..., None]
    rows = jnp.where(
        hit,
        cache[jnp.maximum(slot, 0)].astype(jnp.float32),
        table[idx].astype(jnp.float32),
    )
    return rows.sum(axis=-2).astype(table.dtype)


def cached_qr_bag_ref(
    q_table: jax.Array, cache: jax.Array, r_lut: jax.Array,
    q_idx: jax.Array, slot: jax.Array, r_idx: jax.Array,
) -> jax.Array:
    """Cached pooled QR bag:
    out[b] = Σ_k ( (slot >= 0 ? C[slot] : Q[q_idx]) + R[r_idx] )."""
    hit = (slot >= 0)[..., None]
    q_rows = jnp.where(
        hit,
        cache[jnp.maximum(slot, 0)].astype(jnp.float32),
        q_table[q_idx].astype(jnp.float32),
    )
    rows = q_rows + r_lut[r_idx].astype(jnp.float32)
    return rows.sum(axis=-2).astype(q_table.dtype)


def packed_bag_ref(
    table: jax.Array, cache: jax.Array, idx: jax.Array, slot: jax.Array
) -> jax.Array:
    """Packed dense megabag oracle — same math as ``cached_bag_ref``; the
    multi-table packing lives entirely in the (already offset) index stream."""
    return cached_bag_ref(table, cache, idx, slot)


def packed_qr_bag_ref(
    q_table: jax.Array, cache: jax.Array, r_lut: jax.Array,
    q_idx: jax.Array, slot: jax.Array, r_idx: jax.Array,
) -> jax.Array:
    """Packed QR megabag oracle — ``cached_qr_bag_ref`` over packed buffers."""
    return cached_qr_bag_ref(q_table, cache, r_lut, q_idx, slot, r_idx)


def packed_tt_bag_ref(
    g1: jax.Array, g2: jax.Array, g3: jax.Array, cache: jax.Array,
    i1: jax.Array, i2: jax.Array, i3: jax.Array, slot: jax.Array,
    *, dims: tuple[int, int, int, int],
) -> jax.Array:
    """Packed TT megabag oracle with slot-routed middle core:
    out[g] = Σ_k G1[i1] · (slot >= 0 ? C[slot] : G2[i2]) · G3[i3].

    Outer-core indices are global packed rows (t*v1 + i1); contraction and
    accumulation in fp32 (kernel matches this).
    """
    d1, d2, d3, rank = dims
    hit = (slot >= 0)[..., None]
    g2_rows = jnp.where(
        hit,
        cache[jnp.maximum(slot, 0)].astype(jnp.float32),
        g2[i2].astype(jnp.float32),
    )
    a = g1[i1].astype(jnp.float32).reshape(*i1.shape, d1, rank)
    b = g2_rows.reshape(*i2.shape, rank, d2, rank)
    c = g3[i3].astype(jnp.float32).reshape(*i3.shape, rank, d3)
    rows = jnp.einsum("...ap,...pbq,...qc->...abc", a, b, c)
    rows = rows.reshape(*i1.shape, d1 * d2 * d3)
    return rows.sum(axis=-2).astype(g2.dtype)


def flash_attention_ref(q, k, v, *, causal=True):
    """Naive full-matrix attention oracle with GQA (fp32 softmax)."""
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    g = h // kh
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q * d ** -0.5, kk).astype(jnp.float32)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), vv)
