"""Fused TT-Rec gather-contract bag kernel (the paper's TT path on TPU).

One pooled TT lookup = one HBM row DMA (the middle core G2) + two VMEM reads
(the outer cores) + two tiny matmuls.  The naive TT implementation gathers
three cores from main memory per lookup and ships partial contractions over
the CPU-PIM link; this kernel is the paper's TT execution expressed in the
TPU memory hierarchy:

* ``g1`` / ``g3`` — whole outer cores mapped into VMEM once (constant
  BlockSpec index maps, resident across all grid steps): the bg-PIM SRAM
  cache holding the high-intra-GnR-locality subtables;
* ``g2``          — stays in HBM; each grid step DMAs exactly the row named by
  the scalar-prefetched ``i2`` (``PrefetchScalarGridSpec``), so indices run
  ahead of data and Pallas double-buffers step ``k+1``'s DMA behind step
  ``k``'s contraction — the proactive-prefetch analogue;
* the chained contraction ``(d1,r)@(r,d2*r)`` then ``(d1*d2,r)@(r,d3)`` runs
  between DMAs, and the per-bag sum accumulates in an fp32 VMEM block that is
  revisited across the K grid steps (bank-group MAC + register file) — the
  subtable-duplication move that removes the CPU-side combine.

Grid ``(B, K)``: one step per bag element.  The embedding dim is NOT tiled —
the contraction needs the whole G2 row, and TT dims are small by construction
(``dim <= 1024`` for every recommendation config here), so one output block
per bag stays far under the VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    i1_ref, i2_ref, i3_ref,      # scalar-prefetched (B, K) index maps
    g2_row_ref,                  # (1, r*d2*r) — the streamed middle-core row
    g1_ref,                      # (v1, d1*r)  — VMEM-resident outer core
    g3_ref,                      # (v3, r*d3)  — VMEM-resident outer core
    out_ref,                     # (1, d1*d2*d3) fp32 accumulator
    *,
    d1: int, d2: int, d3: int, rank: int,
):
    b, k = pl.program_id(0), pl.program_id(1)
    a = g1_ref[i1_ref[b, k], :].astype(jnp.float32).reshape(d1, rank)
    m = g2_row_ref[0, :].astype(jnp.float32).reshape(rank, d2 * rank)
    # T[d1_i, d2_i*r + r2] = sum_r1 A[d1_i, r1] * G2[r1, d2_i*r + r2]
    t = jnp.dot(a, m, preferred_element_type=jnp.float32).reshape(d1 * d2, rank)
    c = g3_ref[i3_ref[b, k], :].astype(jnp.float32).reshape(rank, d3)
    row = jnp.dot(t, c, preferred_element_type=jnp.float32).reshape(1, d1 * d2 * d3)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = row

    @pl.when(k > 0)
    def _acc():
        out_ref[...] = out_ref[...] + row


@functools.partial(jax.jit, static_argnames=("dims", "interpret"))
def tt_bag(
    g1: jax.Array,
    g2: jax.Array,
    g3: jax.Array,
    i1: jax.Array,
    i2: jax.Array,
    i3: jax.Array,
    *,
    dims: tuple[int, int, int, int],
    interpret: bool = False,
) -> jax.Array:
    """Pooled TT bag: out[b] = Σ_k G1[i1[b,k]] · G2[i2[b,k]] · G3[i3[b,k]].

    g1: (v1, d1*r); g2: (v2, r*d2*r); g3: (v3, r*d3) — same dtype;
    i1/i2/i3: (B, K) int32.  ``dims`` = (d1, d2, d3, rank), static.
    Returns (B, d1*d2*d3) in the table dtype (fp32 accumulation inside).
    """
    d1, d2, d3, rank = dims
    bsz, k_steps = i1.shape
    dim = d1 * d2 * d3
    assert g1.shape[1] == d1 * rank, (g1.shape, dims)
    assert g2.shape[1] == rank * d2 * rank, (g2.shape, dims)
    assert g3.shape[1] == rank * d3, (g3.shape, dims)

    grid = (bsz, k_steps)
    kernel = pl.pallas_call(
        functools.partial(_kernel, d1=d1, d2=d2, d3=d3, rank=rank),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,  # i1, i2, i3 ride in SMEM ahead of the DMAs
            grid=grid,
            in_specs=[
                # One middle-core row per step, DMA'd from HBM by prefetched i2.
                pl.BlockSpec((1, g2.shape[1]), lambda b, k, i1, i2, i3: (i2[b, k], 0)),
                # Outer cores: same block every step -> stay resident in VMEM.
                pl.BlockSpec(g1.shape, lambda b, k, i1, i2, i3: (0, 0)),
                pl.BlockSpec(g3.shape, lambda b, k, i1, i2, i3: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, dim), lambda b, k, i1, i2, i3: (b, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, dim), jnp.float32),
        interpret=interpret,
    )
    out = kernel(
        i1.astype(jnp.int32), i2.astype(jnp.int32), i3.astype(jnp.int32), g2, g1, g3
    )
    return out.astype(g2.dtype)
