"""Pallas TPU flash attention — the answer to the dominant roofline term.

The §Perf analysis (EXPERIMENTS.md) shows ~80% of the memory term of every
train/prefill cell is the (Bq, Bk) probability/score tiles that a lax-level
blockwise attention materializes in HBM.  On TPU those tiles belong in VMEM:
this kernel keeps the online-softmax state (m, l, acc) in VMEM scratch across
the KV-block grid dimension and writes only the (Sq, D) output to HBM — HBM
traffic becomes q+k+v+o, cutting the attention share of the memory term by
~50x (tile bytes / qkvo bytes = Bk x heads / ~4D).

Layout: grid (B*KH, nq, nk); KV streams innermost so the q tile + state stay
resident; GQA handled by folding the group dim into the q-tile rows (g*Bq
rows share one KV head).  MXU-aligned: D and blocks multiples of 128 where
the arch allows; `_fit_block` picks divisors otherwise.

Backward: `flash_mha` carries a custom_vjp whose backward recomputes with the
lax reference (flash-style, O(S) memory) — exact same math, so gradients are
identical to the reference path; a fused backward kernel is the listed
follow-up in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fit_block(n: int, target: int) -> int:
    b = min(target, n)
    while n % b:
        b -= 1
    return b


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, scale: float, q_block: int, kv_block: int, nk: int):
    """One (q-tile, kv-tile) step. Scratch m/l/acc persist across the kv grid."""
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _update():
        q = q_ref[0].astype(jnp.float32) * scale          # (gq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                       # (gq, bk) in VMEM
        if causal:
            # rows are g groups x q_block query positions
            rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % q_block
            qpos = q_i * q_block + rows
            kpos = kv_i * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_cur)                            # stays in VMEM
        corr = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_prev * corr + p.sum(-1, keepdims=True)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * corr + p @ v

    if causal:
        # skip fully-masked kv tiles (block-sparse causal schedule)
        @pl.when(kv_i * kv_block <= q_i * q_block + q_block - 1)
        def _():
            _update()
    else:
        _update()

    @pl.when(kv_i == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "q_block", "kv_block", "interpret")
)
def flash_fwd(
    q: jax.Array,          # (B, H, Sq, D)
    k: jax.Array,          # (B, KH, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    g = h // kh
    scale = d ** -0.5
    qb = _fit_block(sq, q_block)
    kb = _fit_block(skv, kv_block)
    nq, nk = sq // qb, skv // kb

    # fold (B, KH) into one grid dim; q-tile qi holds g * qb rows (every GQA
    # group's slice of that query block shares this tile's KV stream)
    qf = _tile_groups(q.reshape(b, kh, g, sq, d).reshape(b * kh, g * sq, d), g, sq, qb)
    kf = k.reshape(b * kh, skv, d)
    vf = v.reshape(b * kh, skv, d)

    grid = (b * kh, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _kernel, causal=causal, scale=scale, q_block=qb, kv_block=kb, nk=nk
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g * qb, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kb, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, kb, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, g * qb, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kh, g * sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * qb, 1), jnp.float32),   # m
            pltpu.VMEM((g * qb, 1), jnp.float32),   # l
            pltpu.VMEM((g * qb, d), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = _untile_groups(out, g, sq, qb)
    return out.reshape(b, kh, g, sq, d).reshape(b, h, sq, d)


def _tile_groups(qf: jax.Array, g: int, sq: int, qb: int) -> jax.Array:
    """(BKH, g*sq, d) group-major -> q-tile-major rows (g rows per tile)."""
    bkh, _, d = qf.shape
    x = qf.reshape(bkh, g, sq // qb, qb, d)
    x = x.transpose(0, 2, 1, 3, 4)                 # (bkh, nq, g, qb, d)
    return x.reshape(bkh, (sq // qb) * g * qb, d)


def _untile_groups(of: jax.Array, g: int, sq: int, qb: int) -> jax.Array:
    bkh, _, d = of.shape
    x = of.reshape(bkh, sq // qb, g, qb, d)
    x = x.transpose(0, 2, 1, 3, 4)
    return x.reshape(bkh, g * sq, d)


# ---------------------------------------------------------------------------
# differentiable wrapper: fused forward, reference (recompute) backward
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_mha(q, k, v, causal: bool = True, interpret: bool | None = None):
    """Fused-forward attention with reference-recompute backward."""
    it = jax.default_backend() != "tpu" if interpret is None else interpret
    return flash_fwd(q, k, v, causal=causal, interpret=it)


def _fwd(q, k, v, causal, interpret):
    return flash_mha(q, k, v, causal, interpret), (q, k, v)


def _bwd(causal, interpret, res, do):
    from repro.models.layers import flash_attention

    q, k, v = res
    _, vjp = jax.vjp(lambda a, b, c: flash_attention(a, b, c, causal=causal), q, k, v)
    return vjp(do)


flash_mha.defvjp(_fwd, _bwd)
