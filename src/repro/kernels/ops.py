"""Public jit'd wrappers around the Pallas kernels.

Dispatch rules:

* on CPU (this container) kernels run with ``interpret=True`` — the kernel body
  executes in Python, validating the exact TPU program;
* arbitrary leading index shapes are flattened to the kernel's (N,)/(B,K)
  layouts and restored;
* the lane tile (``dim_block``) is an explicit knob: callers may pass a tuned
  block (``repro.tune`` / ``EmbeddingPlan.dim_block``); ``None`` takes the
  heuristic ladder default.  Dims with no 8-aligned tile fall back to the
  jnp reference (the assigned archs all have 128-aligned dims; tests exercise
  the fallback too).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import gnr_bag as _gnr
from repro.kernels import qr_gather as _qr
from repro.kernels import ref
from repro.tune import knobs as _knobs


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pick_dim_block(dim: int) -> int | None:
    """Heuristic lane-tile default — now sourced from the tuner's knob space
    (``repro.tune.knobs``), same ladder: largest of 512/256/128 dividing dim,
    else the whole dim as one padded tile when 8-aligned, else ``None`` (the
    caller takes the pure-jnp reference path).  Kept as the zero-knob
    fallback; tuned plans pass ``dim_block=`` explicitly instead."""
    return _knobs.default_dim_block(dim)


def _resolve_dim_block(dim: int, dim_block: int | None) -> int | None:
    """An explicit ``dim_block`` must be legal for ``dim``; ``None`` defers
    to the heuristic ladder."""
    if dim_block is None:
        return _knobs.default_dim_block(dim)
    valid = _knobs.valid_dim_blocks(dim)
    if dim_block not in valid:
        raise ValueError(
            f"dim_block={dim_block} is not valid for dim {dim}; "
            f"valid blocks: {list(valid) or '(none: jnp reference only)'}"
        )
    return dim_block


def qr_lookup(
    q_table: jax.Array,
    r_lut: jax.Array,
    q_idx: jax.Array,
    r_idx: jax.Array,
    *,
    interpret: bool | None = None,
    dim_block: int | None = None,
) -> jax.Array:
    """Fused QR reconstruction for any index shape: (...,) -> (..., D)."""
    interpret = _interpret_default() if interpret is None else interpret
    dim = q_table.shape[1]
    bd = _resolve_dim_block(dim, dim_block)
    if bd is None:
        return ref.qr_lookup_ref(q_table, r_lut, q_idx, r_idx)
    shape = q_idx.shape
    out = _qr.qr_gather(
        q_table, r_lut, q_idx.reshape(-1), r_idx.reshape(-1),
        dim_block=bd, interpret=interpret,
    )
    return out.reshape(*shape, dim)


def gnr_pooled(
    q_table: jax.Array,
    r_lut: jax.Array,
    q_idx: jax.Array,
    r_idx: jax.Array,
    *,
    interpret: bool | None = None,
    dim_block: int | None = None,
) -> jax.Array:
    """Pooled QR bag for index shape (..., K) -> (..., D)."""
    interpret = _interpret_default() if interpret is None else interpret
    dim = q_table.shape[1]
    bd = _resolve_dim_block(dim, dim_block)
    if bd is None:
        return ref.gnr_bag_ref(q_table, r_lut, q_idx, r_idx)
    *lead, k = q_idx.shape
    out = _gnr.gnr_bag(
        q_table, r_lut, q_idx.reshape(-1, k), r_idx.reshape(-1, k),
        dim_block=bd, interpret=interpret,
    )
    return out.reshape(*lead, dim)


def tt_pooled(
    g1: jax.Array,
    g2: jax.Array,
    g3: jax.Array,
    i1: jax.Array,
    i2: jax.Array,
    i3: jax.Array,
    *,
    dims: tuple[int, int, int, int],
    interpret: bool | None = None,
) -> jax.Array:
    """Pooled TT-Rec bag for index shape (..., K) -> (..., D).

    ``dims`` = (d1, d2, d3, rank).  Dims with no 8-aligned output tile fall
    back to the jnp reference (assigned configs all have 128-aligned dims).
    """
    from repro.kernels import tt_gather as _tt

    interpret = _interpret_default() if interpret is None else interpret
    d1, d2, d3, _ = dims
    dim = d1 * d2 * d3
    if dim % 8:
        return ref.tt_bag_ref(g1, g2, g3, i1, i2, i3, dims=dims)
    *lead, k = i1.shape
    out = _tt.tt_bag(
        g1, g2, g3,
        i1.reshape(-1, k), i2.reshape(-1, k), i3.reshape(-1, k),
        dims=dims, interpret=interpret,
    )
    return out.reshape(*lead, dim)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _tt_pooled_diff(g1, g2, g3, i1, i2, i3, dims, interpret):
    """Kernel forward with a reference-recompute vjp (flash_attention idiom):
    pallas_call has no autodiff rule, so the backward pass re-derives the
    core cotangents through the jnp oracle — identical math, fp32 throughout.
    Keeps ``tt_exec="pallas"`` legal inside value_and_grad (training)."""
    return tt_pooled(g1, g2, g3, i1, i2, i3, dims=dims, interpret=interpret)


def _tt_pooled_diff_fwd(g1, g2, g3, i1, i2, i3, dims, interpret):
    out = _tt_pooled_diff(g1, g2, g3, i1, i2, i3, dims, interpret)
    return out, (g1, g2, g3, i1, i2, i3)


def _tt_pooled_diff_bwd(dims, interpret, res, ct):
    g1, g2, g3, i1, i2, i3 = res
    _, vjp = jax.vjp(
        lambda a, b, c: ref.tt_bag_ref(a, b, c, i1, i2, i3, dims=dims), g1, g2, g3
    )
    dg1, dg2, dg3 = vjp(ct)
    zero = lambda i: np.zeros(i.shape, jax.dtypes.float0)
    return dg1, dg2, dg3, zero(i1), zero(i2), zero(i3)


_tt_pooled_diff.defvjp(_tt_pooled_diff_fwd, _tt_pooled_diff_bwd)


def tt_pooled_auto(
    g1: jax.Array,
    g2: jax.Array,
    g3: jax.Array,
    i1: jax.Array,
    i2: jax.Array,
    i3: jax.Array,
    *,
    dims: tuple[int, int, int, int],
    exec_mode: str = "jnp",
    interpret: bool | None = None,
) -> jax.Array:
    """Pooled TT bag with config-driven kernel dispatch (serving/jit path).

    ``exec_mode="pallas"`` routes to the fused gather-contract kernel on TPU
    (or in interpret mode when ``interpret=True`` is forced — tests); on CPU
    the pure-jnp oracle is the fallback, so the same config runs everywhere.
    ``exec_mode="jnp"`` always uses the oracle.  The kernel path is
    differentiable via a reference-recompute vjp, so the flag is safe in
    training configs too.
    """
    if exec_mode == "pallas" and (interpret or jax.default_backend() == "tpu"):
        return _tt_pooled_diff(g1, g2, g3, i1, i2, i3, dims, bool(interpret))
    return ref.tt_bag_ref(g1, g2, g3, i1, i2, i3, dims=dims)


def tt_lookup(
    g1: jax.Array,
    g2: jax.Array,
    g3: jax.Array,
    i1: jax.Array,
    i2: jax.Array,
    i3: jax.Array,
    *,
    dims: tuple[int, int, int, int],
    interpret: bool | None = None,
) -> jax.Array:
    """Fused unpooled TT reconstruction for any index shape: (...,) -> (..., D)."""
    shape = i1.shape
    out = tt_pooled(
        g1, g2, g3,
        i1.reshape(-1, 1), i2.reshape(-1, 1), i3.reshape(-1, 1),
        dims=dims, interpret=interpret,
    )
    d1, d2, d3, _ = dims
    return out.reshape(*shape, d1 * d2 * d3)


def cached_pooled(
    table: jax.Array,
    cache: jax.Array,
    idx: jax.Array,
    slot: jax.Array,
    *,
    interpret: bool | None = None,
    dim_block: int | None = None,
) -> jax.Array:
    """Cached pooled bag for index shape (..., K) -> (..., D).

    ``cache`` is the prefetch scheduler's staged block; ``slot`` its per-access
    routing (-1 = miss -> streamed HBM row).
    """
    from repro.kernels import cached_gather as _cg

    interpret = _interpret_default() if interpret is None else interpret
    dim = table.shape[1]
    bd = _resolve_dim_block(dim, dim_block)
    if bd is None:
        return ref.cached_bag_ref(table, cache, idx, slot)
    *lead, k = idx.shape
    out = _cg.cached_bag(
        table, cache, idx.reshape(-1, k), slot.reshape(-1, k),
        dim_block=bd, interpret=interpret,
    )
    return out.reshape(*lead, dim)


def cached_qr_pooled(
    q_table: jax.Array,
    cache: jax.Array,
    r_lut: jax.Array,
    q_idx: jax.Array,
    slot: jax.Array,
    r_idx: jax.Array,
    *,
    interpret: bool | None = None,
    dim_block: int | None = None,
) -> jax.Array:
    """Cached pooled QR bag for index shape (..., K) -> (..., D)."""
    from repro.kernels import cached_gather as _cg

    interpret = _interpret_default() if interpret is None else interpret
    dim = q_table.shape[1]
    bd = _resolve_dim_block(dim, dim_block)
    if bd is None:
        return ref.cached_qr_bag_ref(q_table, cache, r_lut, q_idx, slot, r_idx)
    *lead, k = q_idx.shape
    out = _cg.cached_qr_bag(
        q_table, cache, r_lut,
        q_idx.reshape(-1, k), slot.reshape(-1, k), r_idx.reshape(-1, k),
        dim_block=bd, interpret=interpret,
    )
    return out.reshape(*lead, dim)


# ---------------------------------------------------------------------------
# packed-table megakernel wrappers (multi-table fused gather; see
# repro.kernels.packed_gather / repro.core.packed_tables)
# ---------------------------------------------------------------------------

def packed_dense_pooled(
    table: jax.Array,
    cache: jax.Array,
    idx: jax.Array,
    slot: jax.Array,
    *,
    interpret: bool | None = None,
    dim_block: int | None = None,
) -> jax.Array:
    """Packed dense megabag for index shape (..., K) -> (..., D).

    ``idx`` rows are global packed-buffer rows (per-table offsets applied by
    ``repro.core.packed_tables``); ``slot`` routes into the packed cache block
    (-1 = miss -> streamed HBM row)."""
    from repro.kernels import packed_gather as _pg

    interpret = _interpret_default() if interpret is None else interpret
    dim = table.shape[1]
    bd = _resolve_dim_block(dim, dim_block)
    if bd is None:
        return ref.packed_bag_ref(table, cache, idx, slot)
    *lead, k = idx.shape
    out = _pg.packed_bag(
        table, cache, idx.reshape(-1, k), slot.reshape(-1, k),
        dim_block=bd, interpret=interpret,
    )
    return out.reshape(*lead, dim)


def packed_qr_pooled(
    q_table: jax.Array,
    cache: jax.Array,
    r_lut: jax.Array,
    q_idx: jax.Array,
    slot: jax.Array,
    r_idx: jax.Array,
    *,
    interpret: bool | None = None,
    dim_block: int | None = None,
) -> jax.Array:
    """Packed QR megabag for index shape (..., K) -> (..., D)."""
    from repro.kernels import packed_gather as _pg

    interpret = _interpret_default() if interpret is None else interpret
    dim = q_table.shape[1]
    bd = _resolve_dim_block(dim, dim_block)
    if bd is None:
        return ref.packed_qr_bag_ref(q_table, cache, r_lut, q_idx, slot, r_idx)
    *lead, k = q_idx.shape
    out = _pg.packed_qr_bag(
        q_table, cache, r_lut,
        q_idx.reshape(-1, k), slot.reshape(-1, k), r_idx.reshape(-1, k),
        dim_block=bd, interpret=interpret,
    )
    return out.reshape(*lead, dim)


def packed_tt_pooled(
    g1: jax.Array,
    g2: jax.Array,
    g3: jax.Array,
    cache: jax.Array,
    i1: jax.Array,
    i2: jax.Array,
    i3: jax.Array,
    slot: jax.Array,
    *,
    dims: tuple[int, int, int, int],
    interpret: bool | None = None,
) -> jax.Array:
    """Packed TT megabag for index shape (..., K) -> (..., D)."""
    from repro.kernels import packed_gather as _pg

    interpret = _interpret_default() if interpret is None else interpret
    d1, d2, d3, _ = dims
    if (d1 * d2 * d3) % 8:
        return ref.packed_tt_bag_ref(g1, g2, g3, cache, i1, i2, i3, slot, dims=dims)
    *lead, k = i1.shape
    out = _pg.packed_tt_bag(
        g1, g2, g3, cache,
        i1.reshape(-1, k), i2.reshape(-1, k), i3.reshape(-1, k),
        slot.reshape(-1, k),
        dims=dims, interpret=interpret,
    )
    return out.reshape(*lead, d1 * d2 * d3)


# Differentiable megakernel entry points (reference-recompute vjp, the
# tt_pooled_auto idiom): pallas_call has no autodiff rule, so the backward
# pass re-derives table/cache cotangents through the packed jnp oracle —
# identical math, fp32 throughout.  Index streams get float0 cotangents.

def _zero_idx(*idxs):
    return tuple(np.zeros(i.shape, jax.dtypes.float0) for i in idxs)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _packed_dense_diff(table, cache, idx, slot, interpret, dim_block=None):
    return packed_dense_pooled(
        table, cache, idx, slot, interpret=interpret, dim_block=dim_block
    )


def _packed_dense_diff_fwd(table, cache, idx, slot, interpret, dim_block=None):
    out = _packed_dense_diff(table, cache, idx, slot, interpret, dim_block)
    return out, (table, cache, idx, slot)


def _packed_dense_diff_bwd(interpret, dim_block, res, ct):
    table, cache, idx, slot = res
    _, vjp = jax.vjp(
        lambda t, c: ref.packed_bag_ref(t, c, idx, slot), table, cache
    )
    dt, dc = vjp(ct.astype(table.dtype))
    return dt, dc, *_zero_idx(idx, slot)


_packed_dense_diff.defvjp(_packed_dense_diff_fwd, _packed_dense_diff_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _packed_qr_diff(q, cache, r, q_idx, slot, r_idx, interpret, dim_block=None):
    return packed_qr_pooled(
        q, cache, r, q_idx, slot, r_idx, interpret=interpret, dim_block=dim_block
    )


def _packed_qr_diff_fwd(q, cache, r, q_idx, slot, r_idx, interpret,
                        dim_block=None):
    out = _packed_qr_diff(q, cache, r, q_idx, slot, r_idx, interpret, dim_block)
    return out, (q, cache, r, q_idx, slot, r_idx)


def _packed_qr_diff_bwd(interpret, dim_block, res, ct):
    q, cache, r, q_idx, slot, r_idx = res
    _, vjp = jax.vjp(
        lambda a, c, b: ref.packed_qr_bag_ref(a, c, b, q_idx, slot, r_idx),
        q, cache, r,
    )
    dq, dc, dr = vjp(ct.astype(q.dtype))
    return dq, dc, dr, *_zero_idx(q_idx, slot, r_idx)


_packed_qr_diff.defvjp(_packed_qr_diff_fwd, _packed_qr_diff_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9))
def _packed_tt_diff(g1, g2, g3, cache, i1, i2, i3, slot, dims, interpret):
    return packed_tt_pooled(
        g1, g2, g3, cache, i1, i2, i3, slot, dims=dims, interpret=interpret
    )


def _packed_tt_diff_fwd(g1, g2, g3, cache, i1, i2, i3, slot, dims, interpret):
    out = _packed_tt_diff(g1, g2, g3, cache, i1, i2, i3, slot, dims, interpret)
    return out, (g1, g2, g3, cache, i1, i2, i3, slot)


def _packed_tt_diff_bwd(dims, interpret, res, ct):
    g1, g2, g3, cache, i1, i2, i3, slot = res
    _, vjp = jax.vjp(
        lambda a, b, c, cc: ref.packed_tt_bag_ref(
            a, b, c, cc, i1, i2, i3, slot, dims=dims
        ),
        g1, g2, g3, cache,
    )
    dg1, dg2, dg3, dc = vjp(ct.astype(g2.dtype))
    return dg1, dg2, dg3, dc, *_zero_idx(i1, i2, i3, slot)


_packed_tt_diff.defvjp(_packed_tt_diff_fwd, _packed_tt_diff_bwd)


def packed_multi_pooled(
    params: dict,
    streams: dict,
    *,
    kind: str,
    dims: tuple[int, int, int, int] | None = None,
    exec_mode: str = "auto",
    interpret: bool | None = None,
    dim_block: int | None = None,
) -> jax.Array:
    """One megakernel dispatch for every table's pooled bag (differentiable).

    ``params``: packed buffers — dense {"table", "cache"}, qr {"q", "cache",
    "r"}, tt {"g1", "g2", "g3", "cache"}; ``streams``: globally-offset int32
    index streams of shape (..., K) — dense {"idx", "slot"}, qr {"q_idx",
    "slot", "r_idx"}, tt {"i1", "i2", "i3", "slot"}.  Built by
    ``repro.core.packed_tables`` / ``repro.core.sharded_embedding``.

    ``exec_mode="auto"`` runs the Pallas megakernel on TPU (or when
    ``interpret=True`` is forced — tests); elsewhere the pure-jnp packed
    oracle, so the same config trains and serves on every backend.
    ``"kernel"`` always runs the kernel (interpret on CPU — the serving
    driver's validation mode); ``"jnp"`` always the oracle.  The kernel path
    carries a reference-recompute vjp, so all modes are training-safe.
    """
    use_kernel = {
        "auto": bool(interpret) or jax.default_backend() == "tpu",
        "kernel": True,
        "jnp": False,
    }[exec_mode]
    if kind == "qr":
        args = (params["q"], params["cache"], params["r"],
                streams["q_idx"], streams["slot"], streams["r_idx"])
        if use_kernel:
            return _packed_qr_diff(
                *args, bool(interpret) or _interpret_default(), dim_block
            )
        return ref.packed_qr_bag_ref(*args)
    if kind == "tt":
        args = (params["g1"], params["g2"], params["g3"], params["cache"],
                streams["i1"], streams["i2"], streams["i3"], streams["slot"])
        if use_kernel:
            return _packed_tt_diff(
                *args, dims, bool(interpret) or _interpret_default()
            )
        return ref.packed_tt_bag_ref(*args, dims=dims)
    if kind == "dense":
        args = (params["table"], params["cache"], streams["idx"], streams["slot"])
        if use_kernel:
            return _packed_dense_diff(
                *args, bool(interpret) or _interpret_default(), dim_block
            )
        return ref.packed_bag_ref(*args)
    raise ValueError(f"packed_multi_pooled: unsupported kind {kind!r}")


def gnr_pooled_dense(
    table: jax.Array, idx: jax.Array, *, interpret: bool | None = None,
    dim_block: int | None = None,
) -> jax.Array:
    """Pooled dense bag for index shape (..., K) -> (..., D)."""
    interpret = _interpret_default() if interpret is None else interpret
    dim = table.shape[1]
    bd = _resolve_dim_block(dim, dim_block)
    if bd is None:
        return ref.dense_bag_ref(table, idx)
    *lead, k = idx.shape
    out = _gnr.gnr_bag_dense(table, idx.reshape(-1, k), dim_block=bd, interpret=interpret)
    return out.reshape(*lead, dim)


def flash_attention_fused(q, k, v, *, causal=True, interpret=None):
    """Fused VMEM-resident attention (Pallas) with reference-recompute vjp.

    q: (B, H, Sq, D); k/v: (B, KH, Skv, D); GQA via KH | H.
    """
    from repro.kernels.flash_attention import flash_mha

    interpret = _interpret_default() if interpret is None else interpret
    return flash_mha(q, k, v, causal, interpret)
