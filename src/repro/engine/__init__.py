"""repro.engine — one plan/compile/execute front door for every GnR path.

The paper's pipeline is a single logical flow — analyze locality, plan
prefetch/duplication/placement, then execute gather-and-reduce — and this
package is that flow as an API (the RecNMP/TensorDIMM request->schedule->
execute framing):

1. **declare**: ``EngineSpec`` — tables + bags + policies (compression kind,
   cache/slot policy, duplication, sharding axes, packing, exec backend);
2. **plan**: ``plan(spec, mesh?, trace?)`` runs the intra-GnR analyzer, the
   cache-slot waterfill, the duplication planner, and the packed-layout
   construction once, returning a hashable ``EmbeddingPlan``;
3. **execute**: ``compile(plan)`` returns an ``EmbeddingEngine`` whose
   ``lookup`` / ``forward_partial`` / ``gnr`` / ``inline_gnr`` /
   ``cached_lookup`` + ``serve_gather`` entries dispatch internally to the
   packed megakernel, cached, per-table, or jnp-oracle backends with
   automatic fallback (CPU hosts, non-packable bag sets).

Every caller (``models/dlrm``, ``launch/serve_rec``, ``launch/train``, the
benchmarks, the examples) routes through this seam — the legacy
``sharded_embedding`` builder shims were removed in favor of it.

    spec   = EngineSpec.from_dlrm(cfg, serving=True)
    eplan  = engine.plan(spec, num_shards=4, trace=traces)
    eng    = engine.compile(eplan)
    pooled = eng.lookup(tables, idx)          # or gnr(mesh) / serve_gather

Every tunable decision in step 2 (lane tile, cache-slot budget + split
policy, duplication budget, packed-vs-pertable backend) is an explicit
``repro.tune.Knobs`` frozen into the plan: heuristic defaults with no tuner,
the cost-model argmin with ``plan(spec, traces, tuner=tune.fit(spec, traces))``.
"""

from repro.engine.engine import (           # noqa: F401
    EmbeddingEngine, compile, engine_for,
)
from repro.engine.plan import (             # noqa: F401
    EmbeddingPlan, big_rows, big_subtable, plan,
)
from repro.engine.spec import EngineSpec    # noqa: F401
