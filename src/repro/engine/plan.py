"""plan() — the offline half of the engine: analyze, budget, place, pack.

``plan(spec, mesh?, trace?)`` runs the paper's whole offline pipeline once —
the intra-GnR locality analyzer, the cache-slot waterfill, the
replicate-vs-shard duplication planner, and the packed-layout construction —
and freezes the result into an ``EmbeddingPlan``.  The plan is **hashable**
(numpy payloads are excluded from eq/hash), so it is safe as a jit static
argument: the serving dispatch is one module-level jit keyed by the plan.

Everything here is host-side and runs once per (spec, trace); execution state
(packed buffers, schedulers) is built later by ``compile``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.cache import duplication, intra_gnr
from repro.cache.sram_cache import PrefetchScheduler
from repro.core import packed_tables, placement
from repro.engine.spec import EngineSpec
from repro.tune.knobs import Knobs, default_knobs, slot_budgets as _knob_budgets


def big_subtable(emb) -> tuple[str, int]:
    """(name, rows) of the streamed/tiered big subtable the cache covers."""
    if emb.kind == "qr":
        return "q", emb.qr_spec.q_rows
    if emb.kind == "tt":
        return "g2", emb.tt_spec.v2
    rows = emb.physical_hashed_rows if emb.kind == "hashed" else emb.vocab
    return "table", rows


def big_rows(idx: np.ndarray, emb) -> np.ndarray:
    """Map a logical-index batch (bags, pooling) onto big-subtable rows (the
    cached stream), via the analyzer's single-sourced decomposition."""
    name, _rows = big_subtable(emb)
    trace, _r, _b = intra_gnr.subtable_traces(idx, emb)[name]
    return trace


def _bag_shaped(trace: np.ndarray, pooling: int) -> np.ndarray:
    """Normalize a per-table trace to (bags, pooling) logical indices."""
    trace = np.asarray(trace)
    if trace.ndim == 2:
        return trace
    n = trace.size - trace.size % pooling
    return trace[:n].reshape(-1, pooling)


@dataclasses.dataclass(frozen=True)
class EmbeddingPlan:
    """Frozen output of the offline pass — the engine's compilation unit.

    Eq/hash cover only the static execution-relevant fields (``spec``,
    ``num_shards``, ``backend``, ``layout``, ``slot_budgets``); the numpy
    planning payloads (duplication plan, prefetch values, locality stats) are
    carried ``compare=False`` so the plan stays usable as a jit static arg.
    """

    spec: EngineSpec
    num_shards: int
    backend: str                                  # packed | pertable
    layout: packed_tables.PackedLayout | None
    slot_budgets: tuple[int, ...]
    # the knob setting frozen into this plan (heuristic default or tuner
    # argmin).  Part of eq/hash: plans differing only in tuned knobs must be
    # distinct jit static arguments.
    knobs: Knobs | None = None
    # planning payloads (host numpy; excluded from eq/hash)
    dup: duplication.DuplicationPlan | None = dataclasses.field(
        default=None, compare=False, repr=False
    )
    values: tuple = dataclasses.field(default=(), compare=False, repr=False)
    locality: tuple = dataclasses.field(default=(), compare=False, repr=False)
    # per-table logical-id access profile (the trace's popularity counts) —
    # the plan's own notion of "hot"; the online re-planner pins against it
    counts: tuple = dataclasses.field(default=(), compare=False, repr=False)

    @property
    def bags(self):
        return self.spec.bags

    @property
    def kind(self) -> str:
        return self.spec.kind

    @property
    def packed(self) -> bool:
        return self.backend == "packed"

    @property
    def has_cache(self) -> bool:
        return sum(self.slot_budgets) > 0

    @property
    def dim_block(self) -> int | None:
        """The lane tile frozen into this plan (None = ladder default)."""
        return self.knobs.dim_block if self.knobs is not None else None

    @property
    def comm_free(self) -> tuple[bool, ...]:
        """Per-table: True when the duplication planner killed the combine."""
        if self.dup is None:
            return tuple(False for _ in self.bags)
        return tuple(t.comm_free for t in self.dup.tables)

    def fresh_schedulers(self) -> list[PrefetchScheduler]:
        """One prefetch scheduler per table (stateful — fresh per session)."""
        if not self.has_cache:
            raise ValueError("plan has no cache slots; set spec.cache_slots")
        scheds = []
        for t, bag in enumerate(self.bags):
            _name, rows = big_subtable(bag.emb)
            value = self.values[t] if self.values else None
            scheds.append(PrefetchScheduler(rows, self.slot_budgets[t], value))
        return scheds

    def summary(self) -> dict:
        """JSON-serializable description (the CI plan artifact)."""
        out = {
            "kind": self.kind,
            "num_tables": self.spec.num_tables,
            "backend": self.backend,
            "exec_backend": self.spec.exec_backend,
            "num_shards": self.num_shards,
            "slot_budgets": list(self.slot_budgets),
            "total_slots": int(sum(self.slot_budgets)),
            "packed_rows": self.layout.total_rows if self.layout else 0,
            "comm_free": list(self.comm_free),
            "knobs": self.knobs.describe() if self.knobs is not None else None,
        }
        if self.dup is not None:
            out["replicated_bytes_per_chip"] = int(self.dup.replicated_bytes)
            out["dup_budget_bytes"] = int(self.dup.budget_bytes)
        if self.locality:
            big = big_subtable(self.bags[0].emb)[0]
            out["mean_intra_reuse_big"] = [
                round(float(loc[big].mean_intra_reuse), 4) for loc in self.locality
            ]
        return out


def plan(
    spec: EngineSpec,
    mesh=None,
    trace: Sequence[np.ndarray] | None = None,
    *,
    num_shards: int | None = None,
    dup: duplication.DuplicationPlan | None = None,
    knobs: Knobs | None = None,
    tuner=None,
) -> EmbeddingPlan:
    """Run the offline pipeline once: analyze -> budget -> duplicate -> pack.

    ``mesh`` (or ``num_shards``) sizes the row-shard axis the duplication
    planner models; ``trace`` is one logical-index trace per table — flat
    ``(N,)`` or bag-shaped ``(bags, pooling)`` — feeding the analyzer.  The
    trace may also be passed positionally in the mesh slot
    (``plan(spec, traces, tuner=...)``); a list/tuple there is unambiguous.
    A pre-built ``dup`` plan may be adopted instead of re-planning (the
    deprecation shims use this).

    Knob resolution: an explicit ``knobs=`` wins; else a fitted ``tuner=``
    (:func:`repro.tune.fit`) picks the predicted-latency argmin over the knob
    space; else the zero-trace heuristics (``tune.default_knobs``) reproduce
    the historical plans bit-for-bit.  Without a trace, cache budgets fall
    back to the uniform policy and no duplication plan is built.
    """
    bags = spec.bags
    if isinstance(mesh, (list, tuple)):          # plan(spec, traces, ...)
        if trace is not None:
            raise ValueError("trace passed both positionally and as trace=")
        mesh, trace = None, mesh
    if num_shards is None:
        num_shards = 1
        if mesh is not None and spec.row_axis in mesh.shape:
            num_shards = mesh.shape[spec.row_axis]

    locs: list[dict] = []
    values: list[np.ndarray] | None = None
    counts: list[np.ndarray] | None = None
    if trace is not None:
        if len(trace) != len(bags):
            raise ValueError(f"need one trace per table: {len(trace)} vs {len(bags)}")
        values, counts = [], []
        big = big_subtable(bags[0].emb)[0]
        for bag, tr in zip(bags, trace):
            shaped = _bag_shaped(tr, bag.pooling)
            loc = intra_gnr.analyze_table(shaped, bag.emb)
            locs.append(loc)
            values.append(loc[big].prefetch_value().astype(np.float64))
            counts.append(
                placement.profile_counts(shaped.reshape(-1), bag.emb.vocab)
            )

    packable = packed_tables.packable(bags)
    if knobs is None and tuner is not None:
        knobs = tuner.choose(spec, packable=packable)
    if knobs is None:
        knobs = default_knobs(spec, packable=packable)
    if knobs.backend == "packed" and not packable:
        raise ValueError("knobs.backend='packed' but the bag set is not packable")

    budgets = _knob_budgets(spec, knobs, values)

    if dup is None and spec.duplication:
        if counts is None:
            raise ValueError(
                "spec.duplication=True needs an access profile: pass trace= "
                "(one per table) or adopt a pre-built plan via dup="
            )
        dup = duplication.plan_duplication(
            list(bags), counts,
            num_shards=num_shards,
            budget_bytes=int(knobs.dup_budget_bytes),
            slot_budgets=list(budgets),
        )

    packed = knobs.backend == "packed"
    layout = packed_tables.build_layout(bags, budgets) if packed else None

    return EmbeddingPlan(
        spec=spec,
        num_shards=num_shards,
        backend="packed" if packed else "pertable",
        layout=layout,
        slot_budgets=budgets,
        knobs=knobs,
        dup=dup,
        values=tuple(values) if values is not None else (),
        locality=tuple(locs),
        counts=tuple(counts) if counts is not None else (),
    )
