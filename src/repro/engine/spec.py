"""EngineSpec — the declarative request half of the plan/compile/execute API.

One frozen, hashable dataclass names everything the paper's pipeline needs to
know about an embedding layer *before* any planning runs: the tables + bags
(compression kind rides on each ``BagConfig``), the cache/slot policy, the
duplication budget, the sharding axes, the packing policy, and the kernel
backend.  ``repro.engine.plan`` consumes a spec (plus an optional mesh and
trace) and returns an ``EmbeddingPlan``; ``repro.engine.compile`` turns the
plan into an executable ``EmbeddingEngine``.

Hashability is load-bearing: specs key the module-level engine cache (so a
model forward can resolve its engine at trace time for free) and plans key
the jit cache of the serving dispatch.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.embedding_bag import BagConfig


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Declarative description of one embedding layer for the engine.

    Policies (all static):

    * ``cache_slots`` / ``cache_slot_policy`` / ``cache_vmem_mb`` — the
      prefetch-cache budget.  ``cache_slots`` is a per-table allowance whose
      ``num_tables``-fold total is either waterfilled across tables by the
      intra-GnR analyzer's prefetch value (``"adaptive"``) or split uniformly
      (``"uniform"``); the packed cache block is clamped to
      ``cache_vmem_mb`` (the bg-PIM SRAM size class).  0 slots = no cache.
    * ``duplication`` / ``dup_budget_mb`` — run the replicate-vs-shard
      planner under a per-chip byte budget (the paper's communication kill).
    * ``packing`` — ``"auto"`` packs uniform bag sets into the multi-table
      megakernel layout, ``"off"`` forces the per-table loop.
    * ``exec_backend`` — ``"auto"`` (Pallas kernels on TPU, jnp oracles
      elsewhere), ``"kernel"`` (always the kernel — interpret mode on CPU),
      ``"jnp"`` (always the oracle).
    * ``batch_axis`` / ``row_axis`` — mesh axis names of the two-level
      scheme (requests over ``batch_axis``, table rows over ``row_axis``).
    """

    bags: tuple[BagConfig, ...]
    # prefetch-cache policy
    cache_slots: int = 0
    cache_slot_policy: str = "adaptive"     # adaptive | uniform
    cache_vmem_mb: int = 8
    # duplication policy
    duplication: bool = False
    dup_budget_mb: int = 64
    dup_budget_bytes: int | None = None     # byte-granular override of the MB knob
    # execution policy
    packing: str = "auto"                   # auto | off
    exec_backend: str = "auto"              # auto | kernel | jnp
    batch_axis: str = "data"
    row_axis: str = "model"

    def __post_init__(self):
        if not self.bags:
            raise ValueError("EngineSpec needs at least one bag")
        if self.packing not in ("auto", "off"):
            raise ValueError(f"unknown packing policy {self.packing!r}")
        if self.exec_backend not in ("auto", "kernel", "jnp"):
            raise ValueError(f"unknown exec backend {self.exec_backend!r}")
        if self.cache_slot_policy not in ("adaptive", "uniform"):
            raise ValueError(f"unknown slot policy {self.cache_slot_policy!r}")

    @property
    def num_tables(self) -> int:
        return len(self.bags)

    @property
    def kind(self) -> str:
        return self.bags[0].emb.kind

    def replace(self, **kw) -> "EngineSpec":
        return dataclasses.replace(self, **kw)

    # -- constructors ---------------------------------------------------------

    @classmethod
    def from_bags(cls, bags: Sequence[BagConfig], **kw) -> "EngineSpec":
        return cls(bags=tuple(bags), **kw)

    @classmethod
    def from_dlrm(cls, cfg, *, serving: bool = False, **kw) -> "EngineSpec":
        """Spec for a ``DLRMConfig``.  ``serving=True`` turns on the config's
        cache + duplication policies (the offline pass); the training/forward
        spec leaves them off — the model forward needs no plan state."""
        from repro.models import dlrm

        bags = tuple(dlrm.make_bags(cfg))
        if serving:
            kw.setdefault("cache_slots", cfg.cache_slots)
            kw.setdefault("cache_slot_policy",
                          getattr(cfg, "cache_slot_policy", "adaptive"))
            kw.setdefault("cache_vmem_mb", cfg.cache_vmem_mb)
            kw.setdefault("duplication", True)
            kw.setdefault("dup_budget_mb", cfg.dup_budget_mb)
            # the serving megakernel always runs the kernel program (interpret
            # mode on CPU — the validation configuration)
            kw.setdefault("exec_backend", "kernel")
        return cls(bags=bags, **kw)
