"""compile() — turn an EmbeddingPlan into an executable EmbeddingEngine.

The engine is the ONE front door for every gather-and-reduce path in the
repo.  It owns the dispatch that used to be hand-wired per caller:

* ``lookup``            — single-chip multi-table GnR (packed megakernel on
                          packable sets, per-table loop otherwise; Pallas on
                          TPU, jnp oracles elsewhere).  Differentiable — the
                          kernel paths carry reference-recompute custom vjps,
                          so this is also the training entry.
* ``forward_partial``   — the sharded two-level GnR, run INSIDE ``shard_map``:
                          local partials (one megakernel dispatch when packed)
                          plus the pooled psum, with duplication-plan
                          comm-free tables skipping the combine.
* ``gnr``               — jitted global wrapper over ``forward_partial``
                          (replaces ``build_multi_bag_gnr`` /
                          ``build_dup_multi_bag_gnr``).
* ``inline_gnr``        — mesh-aware dispatch usable inside a jitted model
                          body (the DLRM forward): reads the active mesh and
                          picks single-chip vs two-level automatically.
* ``cached_lookup`` / ``pack`` / ``serve_gather`` — the batched serving path:
                          prefetch-scheduler slot maps routed through the
                          packed cache block, one jit keyed by the (hashable)
                          plan.
* ``baseline``          — the no-technique GSPMD reference (benchmarks diff
                          against it).

Engines are cheap to construct; ``engine_for(spec)`` memoizes the no-trace
plan+compile so model forwards can resolve their engine at trace time.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.core import embedding_bag, hashing, packed_tables
from repro.core import sharded_embedding as SE
from repro.distributed import jax_compat
from repro.engine.plan import EmbeddingPlan, plan as _plan
from repro.engine.spec import EngineSpec


# ---------------------------------------------------------------------------
# serving dispatch — module-level jit keyed by the STATIC (hashable) plan, so
# repeated sessions/benchmark repeats hit jax's compilation cache.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("plan",))
def _serve_gather_jit(packed, idx, slot, cache_rows, plan: EmbeddingPlan):
    from repro.kernels import ops

    # Trace-time bump: a counter inside a jitted body counts *traces*, not
    # calls, so this is the compiled-program count for the serving dispatch.
    # The online re-planner's runtime-arg swaps must leave it at 1.
    obs.inc("engine/compile/serve_gather")
    layout = plan.layout
    streams = packed_tables.pack_indices(idx, layout)
    streams["slot"] = packed_tables.global_slots(slot, layout)
    # the cache-block gather IS the staging DMA (overlapped on hardware)
    cache = packed[packed_tables.big_key(layout.kind)][cache_rows]
    pooled = ops.packed_multi_pooled(
        {**packed, "cache": cache}, streams,
        kind=layout.kind, dims=layout.tt_dims, exec_mode=plan.spec.exec_backend,
        dim_block=plan.dim_block,
    )
    scale = packed_tables.combiner_scale(plan.bags, jnp.float32)
    return pooled * scale[None, :, None].astype(pooled.dtype)


class EmbeddingEngine:
    """Executable embedding layer compiled from an ``EmbeddingPlan``."""

    def __init__(self, plan: EmbeddingPlan):
        self.plan = plan
        self.spec = plan.spec
        self.bags = list(plan.spec.bags)
        # telemetry: the active plan's summary rides along with any metrics
        # snapshot taken while this engine serves (repro.obs is a no-op when
        # disabled, so plain compiles pay nothing).
        if obs.enabled():
            obs.attach("engine_plan", plan.summary())

    # -- single-chip / training entry ----------------------------------------

    def lookup(self, tables, indices, *, lengths=None, interpret=None):
        """All-tables GnR, (B, T, K) indices -> (B, T, dim).

        Packed plans run ONE megakernel dispatch (``packed_multi_pooled`` —
        Pallas on TPU, packed jnp oracle elsewhere, custom-vjp backed so
        ``jax.grad`` through this entry is exact); per-table plans run the
        semantic loop.  This is the training entry: DLRM's forward and the
        engine parity/grad tests differentiate straight through it.
        """
        obs.inc("engine/dispatch/lookup")
        if self.plan.packed:
            return packed_tables.packed_multi_bag_lookup(
                tables, indices, self.bags, lengths=lengths,
                exec_mode=self.spec.exec_backend, interpret=interpret,
                dim_block=self.plan.dim_block,
            )
        if lengths is not None:
            raise NotImplementedError("ragged bags need a packable bag set")
        return embedding_bag.multi_bag_lookup(tables, indices, self.bags)

    # -- sharded two-level GnR (inside shard_map) ----------------------------

    def forward_partial(
        self,
        tables,
        indices,
        *,
        num_shards: int | None = None,
        hot_tiers=None,
        axis: str | None = None,
        interpret=None,
    ):
        """Two-level GnR body: local partials + the pooled psum.

        Runs INSIDE ``shard_map`` over local shards.  Packed plans compute
        every table's local partial in one megakernel dispatch
        (``SE.packed_local_partial``); otherwise the per-kind partials run in
        a loop.  Duplication-plan comm-free tables are served entirely from
        local replicas and skip the psum (the paper's communication kill).
        """
        obs.inc("engine/dispatch/forward_partial")
        axis = axis or self.spec.row_axis
        nsh = num_shards or self.plan.num_shards
        bags = self.bags
        plans = [SE.ShardPlan(b.emb, nsh) for b in bags]
        cf = list(self.plan.comm_free)
        dup = self.plan.dup
        psum_cols = [t for t, c in enumerate(cf) if not c]

        if self.plan.packed:
            parts = SE.packed_local_partial(
                tables, indices, bags, plans, axis=axis,
                hot_tiers=hot_tiers, comm_free=cf if any(cf) else None,
                interpret=interpret,
            )
            if len(psum_cols) == len(bags):
                return jax.lax.psum(parts, axis)
            if psum_cols:
                combined = jax.lax.psum(parts[:, psum_cols], axis)
                parts = parts.at[:, psum_cols].set(combined)
            return parts

        outs, needs_psum = [], []
        for t, (bag, tplan) in enumerate(zip(bags, plans)):
            idx = indices[:, t]
            params = tables[t]
            if cf[t]:
                # replicated everywhere -> full local lookup, no combine
                outs.append(embedding_bag.bag_lookup(params, idx, bag))
                needs_psum.append(False)
                continue
            tier = None if hot_tiers is None else hot_tiers[t]
            if bag.emb.kind == "qr":
                part = SE.qr_bag_partial(
                    params["q"], params["r"], idx, tplan, axis=axis,
                    hot_table=None if tier is None else tier["hot_table"],
                    hot_slot=None if tier is None else tier["hot_slot"],
                )
            elif bag.emb.kind == "tt":
                part = SE.tt_bag_partial(
                    params["g1"], params["g2"], params["g3"], idx, tplan,
                    axis=axis,
                    hot_table=None if tier is None else tier["hot_table"],
                    hot_slot=None if tier is None else tier["hot_slot"],
                )
            else:
                part = SE.dense_bag_partial(params["table"], idx, tplan, axis=axis)
            if bag.combiner == "mean":
                part = part / jnp.asarray(bag.pooling, part.dtype)
            outs.append(part)
            needs_psum.append(True)
        if all(needs_psum):
            return jax.lax.psum(jnp.stack(outs, axis=1), axis)
        if any(needs_psum):
            combined = jax.lax.psum(
                jnp.stack([o for o, n in zip(outs, needs_psum) if n], axis=1),
                axis,
            )
        res, si = [], 0
        for o, n in zip(outs, needs_psum):
            if n:
                res.append(combined[:, si])
                si += 1
            else:
                res.append(o)
        return jnp.stack(res, axis=1)

    # -- global (jitted) two-level GnR ---------------------------------------

    def _table_specs(self, bag, comm_free: bool, row_axis: str):
        if comm_free:
            keys = {"qr": ("q", "r"), "tt": ("g1", "g2", "g3")}.get(
                bag.emb.kind, ("table",)
            )
            return {k: P() for k in keys}
        if bag.emb.kind == "qr":
            return {"q": P(row_axis, None), "r": P()}
        if bag.emb.kind == "tt":
            return {"g1": P(), "g2": P(row_axis, None), "g3": P()}
        return {"table": P(row_axis, None)}

    def gnr(self, mesh: Mesh, *, hot: bool = False):
        """Jitted global GnR over all tables — the end-to-end two-level scheme.

        Returned fn: ``fn(tables, indices (B, T, K), hot_tiers=None)`` ->
        (B, T, dim).  Plans carrying a duplication plan serve comm-free
        tables from local replicas (replicated in_specs, no psum); ``hot``
        adds hot-tier specs on plain plans.
        """
        obs.inc("engine/dispatch/gnr_build")
        spec = self.spec
        row_axis, batch_axis = spec.row_axis, spec.batch_axis
        nsh = mesh.shape[row_axis]
        cf = self.plan.comm_free
        has_dup = self.plan.dup is not None
        with_tiers = hot or has_dup

        def local_fn(tables, indices, hot_tiers):
            return self.forward_partial(
                tables, indices, num_shards=nsh, hot_tiers=hot_tiers,
                axis=row_axis,
            )

        in_specs = (
            [self._table_specs(b, c, row_axis) for b, c in zip(self.bags, cf)],
            P(batch_axis, None, None),
            [{"hot_table": P(), "hot_slot": P()} for _ in self.bags]
            if with_tiers else None,
        )
        out_specs = P(batch_axis, None, None)

        @jax.jit
        def fn(tables, indices, hot_tiers=None):
            return jax_compat.shard_map(
                local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False,
            )(tables, indices, hot_tiers)

        return fn

    def inline_gnr(self, tables, indices):
        """GnR usable INSIDE a jitted model body (the DLRM forward).

        Reads the active mesh/rules from ``repro.distributed.sharding`` (set
        by the launcher's ``use_rules``): no mesh or no row axis -> the
        single-chip ``lookup``; otherwise the two-level ``forward_partial``
        under ``shard_map``.  Differentiable on both paths.
        """
        obs.inc("engine/dispatch/inline_gnr")
        from repro.distributed import sharding as SH

        mesh = SH.current_mesh()
        row_axis = self.spec.row_axis
        if mesh is None or row_axis not in mesh.shape:
            return self.lookup(tables, indices)

        nsh = mesh.shape[row_axis]
        batch_spec = SH.spec_for(("batch",))[0]

        def local_fn(tabs, idx):
            return self.forward_partial(tabs, idx, num_shards=nsh, axis=row_axis)

        return jax_compat.shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(
                [self._table_specs(b, False, row_axis) for b in self.bags],
                P(batch_spec, None, None),
            ),
            out_specs=P(batch_spec, None, None),
            check_vma=False,
        )(tables, indices)

    # -- cached / packed serving path ----------------------------------------

    def cached_lookup(
        self, params, idx, table: int = 0, *, cache_rows=None, slot=None,
        interpret=None,
    ):
        """Single-chip cached GnR for one table — the serving path unit.

        Consumes the prefetch scheduler's staged state: ``cache_rows``
        (slots,) names the big-table rows resident this batch, ``slot``
        (..., K) routes each access (-1 = miss).  QR/dense run the
        ``cached_gather`` kernel; TT runs the fused TT bag kernel (outer
        cores already VMEM-pinned); hashed sets fall back to the plain bag.
        """
        obs.inc("engine/dispatch/cached_lookup")
        from repro.kernels import ops

        bag = self.bags[table]
        emb = bag.emb
        if emb.kind == "qr":
            q_idx, r_idx = hashing.qr_decompose(idx, emb.collision)
            cache = params["q"][cache_rows]
            out = ops.cached_qr_pooled(
                params["q"], cache, params["r"], q_idx, slot, r_idx,
                interpret=interpret, dim_block=self.plan.dim_block,
            )
        elif emb.kind == "tt":
            from repro.core import tt_embedding

            spec = emb.tt_spec
            i1, i2, i3 = tt_embedding.tt_decompose(idx, spec)
            out = ops.tt_pooled_auto(
                params["g1"], params["g2"], params["g3"], i1, i2, i3,
                dims=(spec.d1, spec.d2, spec.d3, spec.rank),
                exec_mode=emb.tt_exec, interpret=interpret,
            )
        elif emb.kind == "hashed":
            # k-ary expansion doesn't fit the single-row slot map; serve uncached
            return embedding_bag.bag_lookup(params, idx, bag)
        else:
            cache = params["table"][cache_rows]
            out = ops.cached_pooled(
                params["table"], cache, idx, slot, interpret=interpret,
                dim_block=self.plan.dim_block,
            )
        if bag.combiner == "mean":
            out = out / jnp.asarray(bag.pooling, out.dtype)
        return out

    def pack(self, tables: Sequence[dict]) -> dict:
        """Concatenate per-table params into the packed megakernel buffers."""
        if not self.plan.packed:
            raise ValueError("plan is not packed; no packed buffers to build")
        obs.inc("engine/dispatch/pack")
        return packed_tables.pack_params(tables, self.plan.layout)

    def serve_gather(self, packed, idx, slot, cache_rows):
        """One megakernel dispatch for a whole batch's embedding layer.

        ``packed`` from :meth:`pack`; ``idx`` (B, T, K) logical indices;
        ``slot`` (B, T, K) per-table scheduler slots (-1 = miss);
        ``cache_rows`` the packed cache block's global rows
        (``packed_tables.packed_cache_rows`` over the schedulers).  One jit
        keyed by the hashable plan — repeat sessions recompile nothing.
        """
        if not self.plan.packed:
            raise ValueError("plan is not packed; serve_gather needs a layout")
        obs.inc("engine/dispatch/serve_gather")
        return _serve_gather_jit(packed, idx, slot, cache_rows, self.plan)

    def packed_cache_rows(self, schedulers) -> "np.ndarray":
        """Per-table scheduler state -> the packed cache block's global rows."""
        if not self.plan.packed:
            raise ValueError("plan is not packed; no packed cache block exists")
        return packed_tables.packed_cache_rows(
            [s.cache_rows() for s in schedulers], self.plan.layout
        )

    def hot_tiers(self, tables: Sequence[dict]):
        """Duplication-plan hot-tier arrays (uniform pytree, one per table)."""
        if self.plan.dup is None:
            raise ValueError("plan has no duplication plan")
        return SE.make_dup_hot_tiers(tables, self.bags, self.plan.dup)

    def fresh_schedulers(self):
        return self.plan.fresh_schedulers()

    def summary(self) -> dict:
        return self.plan.summary()

    # -- baseline (benchmarks diff against this) ------------------------------

    def baseline(self, mesh: Mesh):
        """No-technique GSPMD baseline: plain gathers under auto-sharding.

        XLA materializes all-gathers of table rows; benchmarks diff its
        collective bytes / wall-time against :meth:`gnr`.
        """
        obs.inc("engine/dispatch/baseline_build")
        spec = self.spec
        bags = self.bags

        def fn(tables, indices):
            tables = [
                {
                    k: jax.lax.with_sharding_constraint(
                        v, NamedSharding(mesh, P(spec.row_axis, None))
                    )
                    for k, v in t.items()
                }
                for t in tables
            ]
            indices = jax.lax.with_sharding_constraint(
                indices, NamedSharding(mesh, P(spec.batch_axis, None, None))
            )
            return embedding_bag.multi_bag_lookup(tables, indices, bags)

        return jax.jit(fn)


def compile(plan: EmbeddingPlan) -> EmbeddingEngine:  # noqa: A001
    """EmbeddingPlan -> executable EmbeddingEngine."""
    return EmbeddingEngine(plan)


@functools.lru_cache(maxsize=64)
def _engine_for(spec: EngineSpec, num_shards: int) -> EmbeddingEngine:
    return compile(_plan(spec, num_shards=num_shards))


def engine_for(spec: EngineSpec, *, num_shards: int = 1) -> EmbeddingEngine:
    """Memoized no-trace plan+compile — the model-forward resolution path.

    Specs are hashable, so resolving an engine inside a jitted forward costs
    one dict lookup after the first trace.
    """
    return _engine_for(spec, num_shards)
