from repro.train import optimizer, serve_step, train_step  # noqa: F401
from repro.train.optimizer import OptConfig  # noqa: F401
