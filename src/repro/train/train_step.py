"""Generic training step: loss -> grads -> AdamW, with microbatch grad-accum.

Any model plugs in through a ``loss_fn(params, batch) -> (loss, metrics)``;
the step handles microbatching (a ``lax.scan`` over batch slices accumulating
fp32 grads — this is also the activation-memory knob for the big train cells),
gradient clipping and the optimizer update.  Everything is jit-compatible and
lowers under pjit with the shardings supplied by the launcher.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_mod
from repro.train.optimizer import OptConfig


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def next_token_loss(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """Causal LM loss: logits (B, S, V) vs shifted tokens (B, S); fp32 math."""
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def make_lm_loss(forward_fn: Callable, cfg) -> Callable:
    """forward_fn(params, tokens, cfg) -> logits. batch = {"tokens": (B, S)}."""

    def loss_fn(params, batch):
        logits = forward_fn(params, batch["tokens"], cfg)
        loss = next_token_loss(logits, batch["tokens"])
        return loss, {"loss": loss}

    return loss_fn


def make_prefixed_lm_loss(forward_fn: Callable, cfg, prefix_key: str) -> Callable:
    """For whisper (prefix=frames) / pixtral (prefix=patches)."""

    def loss_fn(params, batch):
        logits = forward_fn(params, batch[prefix_key], batch["tokens"], cfg)
        loss = next_token_loss(logits, batch["tokens"])
        return loss, {"loss": loss}

    return loss_fn


def make_dlrm_loss(cfg) -> Callable:
    from repro.models import dlrm

    def loss_fn(params, batch):
        logits = dlrm.forward_dlrm(params, batch["dense"], batch["idx"], cfg)
        loss = dlrm.bce_loss(logits, batch["labels"])
        return loss, {"loss": loss}

    return loss_fn


# ---------------------------------------------------------------------------
# the step
# ---------------------------------------------------------------------------

def _split_microbatches(batch: dict, m: int) -> dict:
    return jax.tree.map(lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)


def make_train_step(
    loss_fn: Callable,
    opt_cfg: OptConfig,
    *,
    microbatches: int = 1,
) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def step(params, opt_state, batch):
        if microbatches <= 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            mb = _split_microbatches(batch, microbatches)

            def body(acc, b):
                loss_i, _, g_i = grads_of(params, b)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches, acc[0], g_i
                ), acc[1] + loss_i / microbatches
                return acc, None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = jax.lax.scan(body, (zero, jnp.float32(0)), mb)
            metrics = {"loss": loss}

        params, opt_state, opt_metrics = opt_mod.update(
            params, grads, opt_state, opt_cfg
        )
        return params, opt_state, {**metrics, **opt_metrics}

    return step


def make_eval_step(loss_fn: Callable) -> Callable:
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
