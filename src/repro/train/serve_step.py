"""Serving steps: prefill + one-token decode, unified across model families.

Every family exposes the same surface so the launcher/dry-run treats them
uniformly:

    make_cache(cfg, batch, max_len)      -> cache pytree (+ axes via cache_axes)
    prefill(params, batch, cfg, max_len) -> (last logits, cache)
    decode(params, cache, token, pos, cfg) -> (logits, cache)

``decode_*``/``long_*`` shape cells lower exactly one ``decode`` call with a
cache of the cell's full seq_len — one new token against a seq_len-deep cache,
per the assignment.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeFamily:
    make_cache: Callable          # (cfg, batch, max_len) -> cache
    cache_axes: Callable          # () -> logical-axes pytree
    prefill: Callable             # (params, batch, cfg, max_len) -> (logits, cache)
    decode: Callable              # (params, cache, token, pos, cfg) -> (logits, cache)


# ---------------------------------------------------------------------------
# decoder-only transformers (qwen2, granite, chatglm3, minitron, moe archs)
# ---------------------------------------------------------------------------

def _tf_family() -> ServeFamily:
    from repro.models import transformer as T

    return ServeFamily(
        make_cache=lambda cfg, b, m: T.init_cache(cfg, b, m),
        cache_axes=T.cache_axes,
        prefill=lambda p, batch, cfg, m: T.forward_prefill(p, batch["tokens"], cfg, m),
        decode=lambda p, c, tok, pos, cfg: T.forward_decode(p, tok, c, pos, cfg),
    )


def _zamba_family() -> ServeFamily:
    from repro.models import zamba2 as Z

    return ServeFamily(
        make_cache=lambda cfg, b, m: Z.init_zamba2_cache(cfg, b, m),
        cache_axes=Z.zamba2_cache_axes,
        prefill=lambda p, batch, cfg, m: _zamba_prefill(p, batch, cfg, m),
        decode=lambda p, c, tok, pos, cfg: Z.forward_zamba2(
            p, tok, cfg, cache=c, pos=pos, decode=True
        ),
    )


def _zamba_prefill(p, batch, cfg, max_len):
    from repro.models import zamba2 as Z

    cache = Z.init_zamba2_cache(cfg, batch["tokens"].shape[0], max_len)
    logits, cache = Z.forward_zamba2(
        p, batch["tokens"], cfg, cache=cache, pos=jnp.int32(0), decode=False
    )
    return logits[:, -1:, :], cache


def _xlstm_family() -> ServeFamily:
    from repro.models import xlstm as X

    def prefill(p, batch, cfg, m):
        states = X.init_xlstm_state(cfg, batch["tokens"].shape[0])
        logits, states = X.forward_xlstm(p, batch["tokens"], cfg, states=states)
        return logits[:, -1:, :], states

    return ServeFamily(
        make_cache=lambda cfg, b, m: X.init_xlstm_state(cfg, b),
        cache_axes=lambda: None,     # recurrent states: replicated-over-model
        prefill=prefill,
        decode=lambda p, c, tok, pos, cfg: X.forward_xlstm(
            p, tok, cfg, states=c, decode=True
        ),
    )


def _whisper_family() -> ServeFamily:
    from repro.models import whisper as W

    return ServeFamily(
        make_cache=lambda cfg, b, m: W.init_cache(cfg, b, m),
        cache_axes=W.cache_axes,
        prefill=lambda p, batch, cfg, m: W.forward_prefill(
            p, batch["frames"], batch["tokens"], cfg, m
        ),
        decode=lambda p, c, tok, pos, cfg: W.forward_decode(p, tok, c, pos, cfg),
    )


def _pixtral_family() -> ServeFamily:
    # cache length covers the patch prefix + max_len text positions
    from repro.models import pixtral as P

    return ServeFamily(
        make_cache=lambda cfg, b, m: P.init_cache(cfg, b, m + cfg.num_patches),
        cache_axes=P.cache_axes,
        prefill=lambda p, batch, cfg, m: P.forward_prefill(
            p, batch["patches"], batch["tokens"], cfg, m + cfg.num_patches
        ),
        decode=lambda p, c, tok, pos, cfg: P.forward_decode(p, tok, c, pos, cfg),
    )


_FAMILIES: dict[str, Callable[[], ServeFamily]] = {
    "transformer": _tf_family,
    "zamba2": _zamba_family,
    "xlstm": _xlstm_family,
    "whisper": _whisper_family,
    "pixtral": _pixtral_family,
}


def serve_family(kind: str) -> ServeFamily:
    return _FAMILIES[kind]()


# ---------------------------------------------------------------------------
# batched serving loop (runnable example path; jit per step)
# ---------------------------------------------------------------------------

def greedy_generate(
    fam: ServeFamily,
    params: Any,
    batch: dict,
    cfg: ModelConfig,
    *,
    max_new: int,
    max_len: int,
):
    """Prefill then greedy-decode ``max_new`` tokens. Returns (B, max_new)."""
    logits, cache = jax.jit(
        lambda p, b: fam.prefill(p, b, cfg, max_len)
    )(params, batch)
    step = jax.jit(
        lambda p, c, t, pos: fam.decode(p, c, t, pos, cfg)
    )
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    pos0 = batch["tokens"].shape[1]
    if "patches" in batch:
        pos0 += batch["patches"].shape[1]
    outs = []
    for i in range(max_new):
        outs.append(tok[:, 0])
        logits, cache = step(params, cache, tok, jnp.int32(pos0 + i))
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    return jnp.stack(outs, axis=1)
