"""AdamW with sharded fp32 state + schedules + global-norm clipping.

State is a pytree parallel to params (mu, nu) so it inherits the params'
NamedShardings leaf-for-leaf — FSDP-sharded params give FSDP-sharded optimizer
state for free (ZeRO-style).  All state math is fp32 regardless of param
dtype; bf16 params are updated through an fp32 round-trip.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"          # cosine | linear | constant


def init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_axes(param_axes: dict) -> dict:
    """Logical axes for the optimizer state tree (mirrors params)."""
    return {"mu": param_axes, "nu": param_axes, "step": ()}


def learning_rate(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        t = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        if cfg.schedule == "linear":
            decay = 1.0 - (1.0 - cfg.min_lr_ratio) * t
        else:  # cosine
            decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * 0.5 * (
                1.0 + jnp.cos(jnp.pi * t)
            )
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, clip: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(params, grads, state, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)

    step = state["step"] + 1
    lr = learning_rate(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def leaf(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        upd = (m2 / c1) / (jnp.sqrt(v2 / c2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m2, v2

    out = jax.tree.map(leaf, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
