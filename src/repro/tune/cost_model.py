"""Fitted per-kernel linear cost model: latency from bytes, rows, dispatches.

The tuner's predictor is deliberately simple — per execution backend (the
packed megakernel vs the per-table kernel loop), one nonnegative linear model

    latency_s  =  c_dispatch * dispatches
                + c_bytes    * hbm_bytes
                + c_tiles    * row_tiles
                + c_comm     * comm_bytes

whose features are computed analytically from the trace profile and a knob
setting (:func:`plan_features`), and whose coefficients are fitted from
observed samples: timed micro-runs of the real kernels on-device, or the
loop-aware HLO analyzer's byte/flop counts when no accelerator is present
(``launch/hlo_analysis`` — the same machinery ``benchmarks/roofline`` uses).

RecNMP/UpDLRM-style: the model only has to *rank* candidate knob settings
correctly; absolute accuracy is a bonus that ``benchmarks/autotune`` reports.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.tune.knobs import Knobs

FEATURES = ("dispatches", "hbm_bytes", "row_tiles", "comm_bytes")

# 128-lane vector width of the dim-tiled kernels (Mosaic pads partial tiles).
_LANES = 128


@dataclasses.dataclass(frozen=True)
class CostSample:
    """One (knob setting, features, observed latency) observation."""

    knobs: Knobs
    features: tuple[float, ...]
    measured_s: float
    source: str = "measure"           # measure | hlo

    def describe(self) -> dict:
        return {
            "knobs": self.knobs.describe(),
            "features": dict(zip(FEATURES, self.features)),
            "measured_s": self.measured_s,
            "source": self.source,
        }


@dataclasses.dataclass(frozen=True)
class KernelCostModel:
    """Nonnegative linear model over :data:`FEATURES` for one backend."""

    coef: tuple[float, ...]
    backend: str = "packed"
    source: str = "measure"
    num_samples: int = 0

    def predict(self, features: tuple[float, ...]) -> float:
        return float(sum(c * f for c, f in zip(self.coef, features)))

    def describe(self) -> dict:
        return {
            "backend": self.backend,
            "source": self.source,
            "num_samples": self.num_samples,
            "coef": dict(zip(FEATURES, self.coef)),
        }

    @classmethod
    def from_json(cls, d: dict) -> "KernelCostModel":
        return cls(
            coef=tuple(float(d["coef"][f]) for f in FEATURES),
            backend=d.get("backend", "packed"),
            source=d.get("source", "measure"),
            num_samples=int(d.get("num_samples", 0)),
        )


def fit_cost_model(
    samples: "list[CostSample]", *, backend: str, source: str = "measure"
) -> KernelCostModel:
    """Nonnegative least squares over the samples (clip-and-refit).

    A plain ``lstsq`` can go negative on collinear features (e.g. bytes and
    tiles move together when only the slot budget varies); negative
    coefficients would let the tuner "pay" for more traffic, so they are
    clipped to zero and the surviving columns refitted once.
    """
    if not samples:
        raise ValueError("need at least one sample to fit a cost model")
    x = np.asarray([s.features for s in samples], dtype=np.float64)
    y = np.asarray([s.measured_s for s in samples], dtype=np.float64)
    # column scaling keeps lstsq well-conditioned across ~12 orders of magnitude
    scale = np.maximum(np.abs(x).max(axis=0), 1e-30)
    coef, *_ = np.linalg.lstsq(x / scale, y, rcond=None)
    if (coef < 0).any():
        pos = coef > 0
        coef = np.zeros_like(coef)
        if pos.any():
            sub, *_ = np.linalg.lstsq((x / scale)[:, pos], y, rcond=None)
            coef[pos] = np.maximum(sub, 0.0)
    coef = coef / scale
    return KernelCostModel(
        coef=tuple(float(c) for c in coef), backend=backend, source=source,
        num_samples=len(samples),
    )


# ---------------------------------------------------------------------------
# analytic features of (spec, knobs) against a trace profile
# ---------------------------------------------------------------------------

def _padded_row_bytes(row_bytes: int, width_elems: int, dim_block: int | None
                      ) -> float:
    """HBM bytes one streamed row costs under a lane tile choice.

    Full-lane tiles stream exactly the row; a partial trailing tile is padded
    to the 128-lane width (the single-wide-tile fallback for dims like 96),
    so its traffic is inflated by ``ceil(bd/128)*128 / bd``.
    """
    if dim_block is None or width_elems <= 0:
        return float(row_bytes)
    bd = min(dim_block, width_elems)
    padded = -(-bd // _LANES) * _LANES
    return row_bytes * (padded / bd)


def plan_features(spec, knobs: Knobs, profile) -> tuple[float, ...]:
    """Per-batch feature vector of one knob setting.

    ``profile`` is a :class:`repro.tune.tuner.TraceProfile`; features are the
    cost model's regressors:

    * ``dispatches`` — kernel launches per batch (1 packed, T per-table);
    * ``hbm_bytes`` — streamed big-subtable bytes after the prefetch cache:
      misses + staging DMA, padded by the lane-tile choice;
    * ``row_tiles`` — gathered rows x dim tiles (per-tile issue overhead:
      a smaller ``dim_block`` means more grid steps per row);
    * ``comm_bytes`` — modeled cross-shard combine bytes left after the
      duplication budget kills comm-free tables.
    """
    from repro.tune import knobs as knobs_mod

    num_t = spec.num_tables
    dispatches = 1.0 if knobs.backend == "packed" else float(num_t)

    values = [t.values for t in profile.tables]
    budgets = knobs_mod.slot_budgets(spec, knobs, values)

    hbm = 0.0
    tiles = 0.0
    for t, (tp, slots) in enumerate(zip(profile.tables, budgets)):
        hit_rate, staged = profile.hit_stats(t, slots)
        acc = tp.accesses_per_batch
        streamed_rows = acc * (1.0 - hit_rate) + staged
        hbm += streamed_rows * _padded_row_bytes(
            tp.row_bytes, tp.width_elems, knobs.dim_block
        )
        width = max(1, tp.width_elems)
        bd = knobs.dim_block or width
        tiles += acc * max(1.0, width / min(bd, width))
    comm = profile.comm_bytes(spec, knobs.dup_budget_bytes)
    return (dispatches, hbm, tiles, comm)
