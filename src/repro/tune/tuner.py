"""Trace-driven autotuner: profile -> fit cost model -> choose plan knobs.

Closes the loop ROADMAP names "the refactor that makes every future kernel
self-tuning": a short profiled trace is distilled into a
:class:`TraceProfile`, observed latencies of candidate knob settings fit the
per-backend linear :class:`~repro.tune.cost_model.KernelCostModel`, and
``plan(spec, trace, tuner=fit(...))`` ranks the whole knob space by
predicted latency and freezes the argmin into the ``EmbeddingPlan``.

Two observation backends (the byteprofile-analysis trace->cost-model->replay
idiom):

* ``mode="measure"`` — timed micro-runs of the real execution paths (the
  packed ``serve_gather`` megakernel / the per-table loop) on this host, at
  two batch sizes so the per-byte and per-dispatch terms separate;
* ``mode="hlo"``    — no accelerator needed: lower the jnp-oracle execution
  to optimized HLO, run the loop-aware analyzer
  (``launch/hlo_analysis``, shared with ``benchmarks/roofline``), and
  convert bytes/flops to time via the chip constants in ``launch/mesh``.

``mode="auto"`` picks ``measure`` on TPU and ``hlo`` elsewhere.  Fit results
are memoized to a JSON cache keyed by (spec digest, device kind, mode) with
host metadata recorded per entry, so tuning runs once per machine class.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Sequence

import numpy as np

from repro.cache import duplication, intra_gnr, sram_cache
from repro.tune.cost_model import (
    FEATURES, CostSample, KernelCostModel, fit_cost_model, plan_features,
)
from repro.tune.knobs import Knobs, default_knobs, knob_space

# modeled per-launch host/dispatch overhead for the HLO cost oracle
DISPATCH_OVERHEAD_S = 5e-6


def spec_digest(spec) -> str:
    """Stable (cross-process) digest of a spec — the tuner-cache key half.

    ``hash(spec)`` is salted per interpreter, so the JSON cache keys on a
    sha1 of the spec's repr instead (frozen dataclasses repr

    deterministically)."""
    return hashlib.sha1(repr(spec).encode()).hexdigest()[:16]


def device_kind() -> str:
    import jax

    dev = jax.devices()[0]
    return str(getattr(dev, "device_kind", jax.default_backend()))


def run_metadata() -> dict:
    """Host/backend identity recorded on tuner-cache entries and benchmark
    rows, so entries are comparable across machines."""
    import jax

    return {
        "backend": jax.default_backend(),
        "device_kind": device_kind(),
        "jax_version": jax.__version__,
    }


def _bag_shaped(trace: np.ndarray, pooling: int) -> np.ndarray:
    trace = np.asarray(trace)
    if trace.ndim == 2:
        return trace
    n = trace.size - trace.size % pooling
    return trace[:n].reshape(-1, pooling)


_BIG_NAME = {"qr": "q", "tt": "g2"}


# ---------------------------------------------------------------------------
# trace profile: everything the cost model needs, distilled once per trace
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TableProfile:
    """Per-table distillation of the profiled trace."""

    rows: int                    # big-subtable row count
    row_bytes: int
    width_elems: int
    accesses_per_batch: float    # big-subtable fetches per serving batch
    counts: np.ndarray           # logical-row access profile (dup planning)
    values: np.ndarray           # analyzer prefetch values (slot waterfill)
    batches: list                # per-batch big-row streams (hit simulation)


class TraceProfile:
    """Workload statistics the feature computation reads.

    Hit-rate/staging curves are simulated lazily per (table, slot budget) on
    a bounded batch sample; duplication outcomes are re-planned lazily per
    candidate byte budget.  Both are memoized — the knob space revisits the
    same budgets many times.
    """

    def __init__(self, tables: list[TableProfile], *, batch: int,
                 num_shards: int, dim: int):
        self.tables = tables
        self.batch = batch
        self.num_shards = num_shards
        self.dim = dim
        self._hit_memo: dict = {}
        self._comm_memo: dict = {}
        self._bags = None

    @classmethod
    def from_trace(cls, spec, trace: Sequence[np.ndarray], *, batch: int = 32,
                   num_shards: int = 1, max_batches: int = 8) -> "TraceProfile":
        if len(trace) != spec.num_tables:
            raise ValueError(
                f"need one trace per table: {len(trace)} vs {spec.num_tables}"
            )
        tables = []
        for bag, tr in zip(spec.bags, trace):
            emb = bag.emb
            shaped = _bag_shaped(tr, bag.pooling)
            big = _BIG_NAME.get(emb.kind, "table")
            big_trace, rows, row_bytes = intra_gnr.subtable_traces(
                shaped, emb
            )[big]
            loc = intra_gnr.analyze_bags(big_trace, rows, row_bytes=row_bytes)
            from repro.core import placement

            counts = placement.profile_counts(shaped.reshape(-1), emb.vocab)
            n_batches = min(max_batches, max(1, big_trace.shape[0] // batch))
            batches = [
                big_trace[i * batch: (i + 1) * batch] for i in range(n_batches)
            ]
            tables.append(TableProfile(
                rows=rows,
                row_bytes=row_bytes,
                width_elems=row_bytes // 4,
                accesses_per_batch=float(batch * shaped.shape[1]),
                counts=counts,
                values=loc.prefetch_value().astype(np.float64),
                batches=batches,
            ))
        prof = cls(tables, batch=batch, num_shards=num_shards,
                   dim=spec.bags[0].emb.dim)
        prof._bags = list(spec.bags)
        return prof

    def hit_stats(self, t: int, slots: int) -> tuple[float, float]:
        """(hit rate, staged rows/batch) of table ``t`` at a slot budget."""
        key = (t, int(slots))
        if key not in self._hit_memo:
            tp = self.tables[t]
            if slots <= 0 or not tp.batches:
                self._hit_memo[key] = (0.0, 0.0)
            else:
                stats = sram_cache.simulate(
                    tp.batches, tp.rows, int(slots), tp.values
                )
                self._hit_memo[key] = (stats.hit_rate, stats.staged_per_batch)
        return self._hit_memo[key]

    def comm_bytes(self, spec, dup_budget_bytes: int) -> float:
        """Modeled cross-shard combine bytes per batch under a dup budget."""
        n = self.num_shards
        if n <= 1:
            return 0.0
        key = int(dup_budget_bytes)
        if key not in self._comm_memo:
            num_t = len(self.tables)
            if key <= 0:
                not_free = num_t
            else:
                dplan = duplication.plan_duplication(
                    self._bags or list(spec.bags),
                    [tp.counts for tp in self.tables],
                    num_shards=n, budget_bytes=key,
                )
                not_free = sum(1 for t in dplan.tables if not t.comm_free)
            vec = self.dim * 4
            self._comm_memo[key] = (
                self.batch * not_free * vec * (n - 1) / max(1, n)
            )
        return self._comm_memo[key]


# ---------------------------------------------------------------------------
# the tuner object plan() consumes
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Tuner:
    """Fitted cost models + the profile they were fitted against."""

    models: dict                      # backend -> KernelCostModel
    profile: TraceProfile | None
    source: str                       # measure | hlo
    metadata: dict
    samples: list = dataclasses.field(default_factory=list)
    digest: str = ""
    from_cache: bool = False

    def predict(self, spec, knobs: Knobs, *, profile: TraceProfile | None = None
                ) -> float:
        profile = profile or self.profile
        if profile is None:
            raise ValueError("tuner has no trace profile; pass profile=")
        model = self.models.get(knobs.backend)
        if model is None:
            model = next(iter(self.models.values()))
        return model.predict(plan_features(spec, knobs, profile))

    def rank(self, spec, *, packable: bool | None = None,
             backend: str | None = None,
             profile: TraceProfile | None = None) -> list:
        """Knob space ordered by predicted latency: [(knobs, seconds), ...]."""
        if packable is None:
            from repro.core import packed_tables

            packable = packed_tables.packable(spec.bags)
        space = knob_space(spec, packable=packable)
        if backend is not None:
            space = tuple(k for k in space if k.backend == backend) or space
        scored = [(k, self.predict(spec, k, profile=profile)) for k in space]
        scored.sort(key=lambda kp: kp[1])
        return scored

    def choose(self, spec, *, packable: bool | None = None,
               backend: str | None = None,
               profile: TraceProfile | None = None,
               tie_rel: float = 0.02) -> Knobs:
        """Argmin-predicted-latency knobs, with near-ties (within ``tie_rel``)
        resolved toward the heuristic default — the tuner only moves a knob
        when the model predicts a real win."""
        if packable is None:
            from repro.core import packed_tables

            packable = packed_tables.packable(spec.bags)
        ranked = self.rank(spec, packable=packable, backend=backend,
                           profile=profile)
        best_k, best_p = ranked[0]
        default = default_knobs(spec, packable=packable)
        if backend is not None and default.backend != backend:
            return best_k
        d_pred = self.predict(spec, default, profile=profile)
        if d_pred <= best_p * (1.0 + tie_rel):
            return default
        return best_k

    def describe(self) -> dict:
        """JSON form — the memo-cache entry / CI cost-model artifact."""
        return {
            "metadata": self.metadata,
            "source": self.source,
            "spec_digest": self.digest,
            "models": {b: m.describe() for b, m in self.models.items()},
            "samples": [s.describe() for s in self.samples],
        }


# ---------------------------------------------------------------------------
# observation: timed micro-runs / HLO-analyzed lowerings
# ---------------------------------------------------------------------------

def _time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of a blocking call on this host."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _batch_indices(spec, trace, batch: int, seed: int = 0):
    """(B, T, K) logical bag indices drawn from the profiled trace."""
    import jax.numpy as jnp

    cols = []
    for bag, tr in zip(spec.bags, trace):
        shaped = _bag_shaped(tr, bag.pooling)
        if shaped.shape[0] < batch:          # tile short traces
            reps = -(-batch // shaped.shape[0])
            shaped = np.tile(shaped, (reps, 1))
        cols.append(shaped[:batch])
    return jnp.asarray(np.stack(cols, axis=1).astype(np.int32))


def _serving_call(eng, tables, idx):
    """The executable + args a micro-run times: the packed ``serve_gather``
    (with a live prefetch schedule) when the plan carries a cache, the
    ``lookup`` entry otherwise."""
    import jax
    import jax.numpy as jnp

    from repro.engine import big_rows

    eplan = eng.plan
    if eplan.packed and eplan.has_cache:
        packed = eng.pack(tables)
        scheds = eng.fresh_schedulers()
        emb = eplan.bags[0].emb
        rows = np.stack(
            [np.asarray(big_rows(np.asarray(idx)[:, t], emb))
             for t in range(len(eplan.bags))], axis=1,
        )
        for t in range(len(eplan.bags)):
            scheds[t].prefetch(rows[:, t])
        slot = jnp.asarray(np.stack(
            [scheds[t].slots_for(rows[:, t], record=False)
             for t in range(len(eplan.bags))], axis=1,
        ))
        cache_rows = jnp.asarray(eng.packed_cache_rows(scheds))
        return (lambda p, i, s, c: eng.serve_gather(p, i, s, c),
                (packed, idx, slot, cache_rows))
    fn = jax.jit(lambda tabs, i: eng.lookup(tabs, i))
    return fn, (tables, idx)


def _measure_sample(spec, knobs: Knobs, trace, batch: int, *, repeats: int
                    ) -> float:
    """Per-batch seconds of one knob setting, timed on this host."""
    import jax

    from repro import engine as engine_mod
    from repro.core import embedding_bag as EB

    eplan = engine_mod.plan(spec, trace=trace, knobs=knobs, num_shards=1)
    eng = engine_mod.compile(eplan)
    tables = EB.init_tables(jax.random.PRNGKey(0), list(spec.bags))
    idx = _batch_indices(spec, trace, batch)
    fn, args = _serving_call(eng, tables, idx)
    return _time_call(fn, *args, iters=repeats)


def _hlo_sample(spec, knobs: Knobs, trace, batch: int) -> float:
    """Per-batch seconds of one knob setting, modeled from optimized HLO.

    Lowers the jnp-oracle execution (same math as the kernel path — the
    Pallas interpret lowering hides its body from HLO), analyzes bytes/flops
    with the loop-aware analyzer, and converts to time with the chip
    constants plus a per-dispatch overhead term.
    """
    import jax

    from repro import engine as engine_mod
    from repro.core import embedding_bag as EB
    from repro.launch import hlo_analysis
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

    spec_j = spec.replace(exec_backend="jnp")
    eplan = engine_mod.plan(spec_j, trace=trace, knobs=knobs, num_shards=1)
    eng = engine_mod.compile(eplan)
    tables = EB.init_tables(jax.random.PRNGKey(0), list(spec_j.bags))
    idx = _batch_indices(spec_j, trace, batch)
    fn, args = _serving_call(eng, tables, idx)
    text = jax.jit(fn).lower(*args).compile().as_text()
    h = hlo_analysis.analyze(text)
    dispatches = 1.0 if knobs.backend == "packed" else float(spec.num_tables)
    return (
        max(h["flops"] / PEAK_FLOPS_BF16, h["bytes"] / HBM_BW)
        + DISPATCH_OVERHEAD_S * dispatches
    )


# ---------------------------------------------------------------------------
# fit
# ---------------------------------------------------------------------------

def _sample_keys(space) -> list:
    """Distinct measurement settings: duplication only changes the modeled
    comm term (never a single-chip micro-run), so candidates are deduped on
    the execution-affecting knobs."""
    seen, keys = set(), []
    for k in space:
        key = (k.backend, k.dim_block, k.cache_slots, k.cache_slot_policy)
        if key not in seen:
            seen.add(key)
            keys.append(key)
    return keys


def fit(
    spec,
    trace: Sequence[np.ndarray],
    *,
    mode: str = "auto",
    batch: int = 32,
    num_shards: int = 1,
    max_samples: int = 12,
    repeats: int = 3,
    cache_path: str | None = None,
) -> Tuner:
    """Fit per-backend cost models for a spec from a profiled trace.

    ``mode="measure"`` times the real execution paths; ``"hlo"`` lowers the
    jnp oracle and prices the analyzer's bytes/flops (the no-accelerator
    path); ``"auto"`` measures on TPU, analyzes HLO elsewhere.  When
    ``cache_path`` holds a previous fit for (spec digest, device kind, mode),
    it is loaded instead of re-observing (``tuner.from_cache``).
    """
    import jax

    from repro.core import packed_tables

    if mode not in ("auto", "measure", "hlo"):
        raise ValueError(f"unknown tuner mode {mode!r}")
    source = mode
    if mode == "auto":
        source = "measure" if jax.default_backend() == "tpu" else "hlo"

    digest = spec_digest(spec)
    meta = run_metadata()
    cache_key = f"{digest}:{meta['device_kind']}:{source}"
    profile = TraceProfile.from_trace(
        spec, trace, batch=batch, num_shards=num_shards
    )

    if cache_path and os.path.exists(cache_path):
        with open(cache_path) as f:
            cache = json.load(f)
        if cache_key in cache:
            entry = cache[cache_key]
            models = {
                b: KernelCostModel.from_json(m)
                for b, m in entry["models"].items()
            }
            return Tuner(models=models, profile=profile, source=source,
                         metadata=entry.get("metadata", meta),
                         digest=digest, from_cache=True)

    packable = packed_tables.packable(spec.bags)
    space = knob_space(spec, packable=packable)
    keys = _sample_keys(space)
    if len(keys) > max_samples:
        stride = len(keys) / max_samples
        keys = [keys[int(i * stride)] for i in range(max_samples)]

    # measurement drops duplication (it only moves the modeled comm term) and
    # observes each setting at two batch sizes so per-byte and per-dispatch
    # costs separate in the fit.
    spec_m = spec.replace(duplication=False)
    small = max(4, batch // 2)
    profiles = {batch: TraceProfile.from_trace(spec_m, trace, batch=batch),
                small: TraceProfile.from_trace(spec_m, trace, batch=small)}

    samples: list[CostSample] = []
    for backend, bd, slots, policy in keys:
        k = Knobs(dim_block=bd, cache_slots=slots, cache_slot_policy=policy,
                  dup_budget_bytes=0, backend=backend)
        for b, prof in profiles.items():
            if source == "measure":
                sec = _measure_sample(spec_m, k, trace, b, repeats=repeats)
            else:
                sec = _hlo_sample(spec_m, k, trace, b)
            samples.append(CostSample(
                knobs=k, features=plan_features(spec_m, k, prof),
                measured_s=sec, source=source,
            ))

    models = {}
    for backend in sorted({s.knobs.backend for s in samples}):
        sub = [s for s in samples if s.knobs.backend == backend]
        model = fit_cost_model(sub, backend=backend, source=source)
        models[backend] = _with_comm_floor(model)

    tuner = Tuner(models=models, profile=profile, source=source,
                  metadata=meta, samples=samples, digest=digest)
    if cache_path:
        cache = {}
        if os.path.exists(cache_path):
            with open(cache_path) as f:
                cache = json.load(f)
        cache[cache_key] = tuner.describe()
        with open(cache_path, "w") as f:
            json.dump(cache, f, indent=1)
    return tuner


def _with_comm_floor(model: KernelCostModel) -> KernelCostModel:
    """Single-chip observations can never price the comm term (its feature
    column is zero there), so an unfitted comm coefficient falls back to the
    analytic ICI wire rate — ranking across duplication budgets stays
    meaningful."""
    from repro.launch.mesh import ICI_BW_PER_LINK

    idx = FEATURES.index("comm_bytes")
    if model.coef[idx] > 0:
        return model
    coef = list(model.coef)
    coef[idx] = 1.0 / (2 * ICI_BW_PER_LINK)
    return dataclasses.replace(model, coef=tuple(coef))
