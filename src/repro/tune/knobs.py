"""The tunable-decision surface of ``plan()`` as one explicit dataclass.

Every knob the offline pass used to hard-wire — the Pallas lane tile
(``_pick_dim_block``'s ladder), the VMEM cache-slot budget and its per-table
split policy, the duplication byte budget, and the packed-vs-pertable
backend — is a field of :class:`Knobs`, and :func:`knob_space` enumerates the
valid candidate settings for a spec.  ``plan(spec, ...)`` freezes one
``Knobs`` into the ``EmbeddingPlan`` (heuristic defaults without a tuner, the
cost-model argmin with one), so the choice is always visible, hashable, and
part of the plan's jit identity.

Host-side and dependency-light: this module is imported by ``kernels/ops.py``
(the dim-block default) and by ``engine/plan.py`` (budgets), so it must not
import jax or the engine.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.cache import intra_gnr


def valid_dim_blocks(dim: int) -> tuple[int, ...]:
    """Legal lane tiles for the dim-tiled kernels, preferred first.

    * multiples of the 128-lane width that divide ``dim`` (512/256/128 —
      full lane utilization, the fast path);
    * the whole dim as a single tile when ``dim % 8 == 0`` (Mosaic pads the
      trailing tile to the 128-lane width — legal, some lanes wasted);
    * empty when ``dim`` has no 8-aligned tile: the caller must take the
      pure-jnp reference path.
    """
    blocks = [bd for bd in (512, 256, 128) if bd <= dim and dim % bd == 0]
    if dim % 8 == 0 and dim not in blocks:
        blocks.append(dim)
    return tuple(blocks)


def default_dim_block(dim: int) -> int | None:
    """The zero-trace heuristic: first entry of the ladder (``None`` = no
    kernel, jnp reference).  Bit-for-bit the historical ``_pick_dim_block``
    choice, minus the warnings — the choice is now explicit plan state."""
    blocks = valid_dim_blocks(dim)
    return blocks[0] if blocks else None


@dataclasses.dataclass(frozen=True)
class Knobs:
    """One candidate setting of every tunable decision in the offline pass.

    Frozen + hashable: rides ``EmbeddingPlan`` eq/hash, so two plans that
    differ only in tuned knobs are distinct jit static arguments (no stale
    compilation-cache hits).
    """

    dim_block: int | None = None      # lane tile for dim-tiled kernels
    cache_slots: int = 0              # per-table VMEM cache-slot allowance
    cache_slot_policy: str = "adaptive"   # adaptive (waterfill) | uniform
    dup_budget_bytes: int = 0         # duplication byte budget (0 = off)
    backend: str = "pertable"         # packed | pertable

    def describe(self) -> dict:
        """JSON-serializable form (plan summaries, tuner cache entries)."""
        return {
            "dim_block": self.dim_block,
            "cache_slots": int(self.cache_slots),
            "cache_slot_policy": self.cache_slot_policy,
            "dup_budget_bytes": int(self.dup_budget_bytes),
            "backend": self.backend,
        }


def spec_dup_budget_bytes(spec) -> int:
    """The spec's duplication budget in bytes (0 when duplication is off)."""
    if not spec.duplication:
        return 0
    if spec.dup_budget_bytes is not None:
        return int(spec.dup_budget_bytes)
    return int(spec.dup_budget_mb) * 2**20


def default_knobs(spec, *, packable: bool) -> Knobs:
    """The heuristic knob setting — exactly what ``plan()`` chose before the
    tuner existed, so the zero-trace/no-tuner path reproduces historical
    plans bit-for-bit."""
    return Knobs(
        dim_block=default_dim_block(spec.bags[0].emb.dim),
        cache_slots=int(spec.cache_slots),
        cache_slot_policy=spec.cache_slot_policy,
        dup_budget_bytes=spec_dup_budget_bytes(spec),
        backend="packed" if (spec.packing == "auto" and packable) else "pertable",
    )


def knob_space(spec, *, packable: bool) -> tuple[Knobs, ...]:
    """Enumerate the candidate knob settings for a spec, default first.

    The space stays small by construction (a few dozen points): lane tiles
    from :func:`valid_dim_blocks`, a halve/keep/double ladder around the
    spec's slot and duplication budgets, both split policies when a cache
    exists, and both backends when the bag set is packable.
    """
    base = default_knobs(spec, packable=packable)

    dims: tuple = valid_dim_blocks(spec.bags[0].emb.dim) or (None,)
    if spec.cache_slots > 0:
        slot_ladder = sorted({max(1, spec.cache_slots // 2), spec.cache_slots,
                              spec.cache_slots * 2})
        policies = ("adaptive", "uniform")
    else:
        slot_ladder = [0]
        policies = (spec.cache_slot_policy,)
    dup_base = spec_dup_budget_bytes(spec)
    if dup_base > 0:
        dup_ladder = sorted({dup_base // 2, dup_base, dup_base * 2})
    else:
        dup_ladder = [0]
    if spec.packing == "auto" and packable:
        backends = ("packed", "pertable")
    else:
        backends = (base.backend,)

    space = [base]
    for backend in backends:
        for bd in dims:
            for slots in slot_ladder:
                for policy in policies:
                    for dup in dup_ladder:
                        k = Knobs(
                            dim_block=bd, cache_slots=slots,
                            cache_slot_policy=policy, dup_budget_bytes=dup,
                            backend=backend,
                        )
                        if k != base:
                            space.append(k)
    return tuple(space)


def slot_budgets(spec, knobs: Knobs, values: "list[np.ndarray] | None"
                 ) -> tuple[int, ...]:
    """Per-table cache-slot budgets under a knob setting + the VMEM ceiling.

    The historical ``plan._slot_budgets`` with the slot allowance and split
    policy read from ``knobs`` instead of the spec: the default knobs
    reproduce the old budgets exactly; tuned knobs move them.
    """
    num_t = spec.num_tables
    if knobs.cache_slots <= 0:
        return tuple(0 for _ in range(num_t))
    emb = spec.bags[0].emb
    width = emb.tt_spec.g2_width if emb.kind == "tt" else emb.dim
    row_bytes = width * np.dtype(emb.param_dtype).itemsize
    vmem_slots = (spec.cache_vmem_mb * 2**20) // max(1, row_bytes)
    total = min(knobs.cache_slots * num_t, vmem_slots)
    if total <= 0:
        # cache_slots > 0 but the VMEM clamp leaves no room for one row:
        # surface the contradiction instead of silently over-allocating the
        # per-table floor (the waterfill refuses zero budgets by contract)
        raise ValueError(
            f"cache_vmem_mb={spec.cache_vmem_mb} fits no cache row "
            f"(row_bytes={row_bytes}) but knobs.cache_slots="
            f"{knobs.cache_slots} asks for a cache; raise cache_vmem_mb or "
            f"set cache_slots=0"
        )
    if knobs.cache_slot_policy == "adaptive" and values is not None:
        budgets = intra_gnr.split_slot_budget(values, total)
    else:
        budgets = [min(knobs.cache_slots, total // num_t)] * num_t
    rows = [_big_rows_count(b.emb) for b in spec.bags]
    return tuple(max(1, min(b, r)) for b, r in zip(budgets, rows))


def _big_rows_count(emb) -> int:
    """Row count of the streamed big subtable (mirrors ``plan.big_subtable``
    without importing the engine)."""
    if emb.kind == "qr":
        return emb.qr_spec.q_rows
    if emb.kind == "tt":
        return emb.tt_spec.v2
    return emb.physical_hashed_rows if emb.kind == "hashed" else emb.vocab
