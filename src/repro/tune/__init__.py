"""Trace-driven autotuner: knob space, fitted cost model, tuner.

Layering: :mod:`~repro.tune.knobs` and :mod:`~repro.tune.cost_model` are
numpy-only and imported eagerly — ``kernels/ops.py`` and ``engine/plan.py``
depend on them, so they must not pull in jax or the engine.  The
:class:`Tuner` / :func:`fit` half (micro-run timing, HLO lowering) does need
jax and the engine, so it loads lazily on first attribute access.
"""

from repro.tune.cost_model import (           # noqa: F401
    FEATURES, CostSample, KernelCostModel, fit_cost_model, plan_features,
)
from repro.tune.knobs import (                # noqa: F401
    Knobs, default_dim_block, default_knobs, knob_space, slot_budgets,
    spec_dup_budget_bytes, valid_dim_blocks,
)

_LAZY = ("Tuner", "TraceProfile", "TableProfile", "fit", "spec_digest",
         "device_kind", "run_metadata")


def __getattr__(name: str):
    if name in _LAZY:
        from repro.tune import tuner as _tuner

        return getattr(_tuner, name)
    raise AttributeError(f"module 'repro.tune' has no attribute {name!r}")


__all__ = [
    "FEATURES", "CostSample", "KernelCostModel", "Knobs", "TableProfile",
    "TraceProfile", "Tuner", "default_dim_block", "default_knobs",
    "device_kind", "fit", "fit_cost_model", "knob_space", "plan_features",
    "run_metadata", "slot_budgets", "spec_digest", "spec_dup_budget_bytes",
    "valid_dim_blocks",
]
