"""Intra-GnR locality analysis (paper Fig. 7 / §IV-A analogue).

One gather-and-reduce pools ``pooling`` rows per bag.  Weight-sharing makes
several of those rows land in *small shared subtables*: every QR lookup in a
bag touches the R table (``idx % c`` over only ``c`` rows) and every TT lookup
touches the outer cores G1/G3 (``~vocab**0.25`` rows).  The result is heavy
reuse *within a single GnR* — the paper's intra-GnR locality — which a cache
filled *before* the GnR arrives converts into SRAM hits.

This module measures that reuse from a trace, per subtable row:

* ``touches[row]``  — total accesses to the row;
* ``bags[row]``     — number of distinct bags that touch it.

``touches / bags`` is the mean intra-GnR reuse: how many DRAM fetches one
staged copy of the row replaces inside each bag that uses it.  Rows are
ranked for prefetch by the accesses a single staging DMA saves
(``touches - bags`` for a per-bag cache, ``touches - 1`` for a per-batch
cache — the ordering is the same, by ``touches`` with ``bags`` as tiebreak).

All host-side numpy: the paper profiles traces offline, between training and
inference deployment.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import hashing


@dataclasses.dataclass(frozen=True)
class GnRLocality:
    """Per-row intra-GnR reuse statistics for one subtable."""

    rows: int                   # subtable row count
    touches: np.ndarray         # (rows,) int64: total accesses
    bags: np.ndarray            # (rows,) int64: distinct bags touching the row
    num_bags: int               # bags in the analyzed trace
    row_bytes: int = 0          # bytes per row (0 = unknown)

    @property
    def intra_reuse(self) -> np.ndarray:
        """Mean touches per touching bag, per row (1.0 = no intra-GnR reuse)."""
        return self.touches / np.maximum(self.bags, 1)

    @property
    def mean_intra_reuse(self) -> float:
        """Access-weighted intra-GnR reuse of the whole subtable."""
        total_bags = max(1, int(self.bags.sum()))
        return float(self.touches.sum() / total_bags)

    @property
    def touched_rows(self) -> int:
        return int(np.count_nonzero(self.touches))

    def prefetch_value(self) -> np.ndarray:
        """(rows,) accesses saved if the row is staged once per batch.

        One staging DMA replaces every subsequent DRAM touch, so the saving
        is ``touches - 1`` for touched rows (0 for untouched ones).
        """
        return np.maximum(self.touches - 1, 0) * (self.touches > 0)


def analyze_bags(trace: np.ndarray, rows: int, *, row_bytes: int = 0) -> GnRLocality:
    """Measure per-row intra-GnR reuse from a bag trace.

    ``trace``: (num_bags, pooling) subtable-row indices — one row per GnR.
    """
    trace = np.asarray(trace)
    if trace.ndim != 2:
        raise ValueError(f"trace must be (num_bags, pooling), got {trace.shape}")
    num_bags = trace.shape[0]
    touches = np.bincount(trace.reshape(-1), minlength=rows)
    # distinct (bag, row) pairs -> per-row bag counts
    if trace.size:
        bag_ids = np.repeat(np.arange(num_bags, dtype=np.int64), trace.shape[1])
        key = bag_ids * rows + trace.reshape(-1).astype(np.int64)
        uniq_rows = (np.unique(key) % rows).astype(np.int64)
        bags = np.bincount(uniq_rows, minlength=rows)
    else:
        bags = np.zeros(rows, dtype=np.int64)
    return GnRLocality(
        rows=rows,
        touches=touches.astype(np.int64),
        bags=bags.astype(np.int64),
        num_bags=num_bags,
        row_bytes=row_bytes,
    )


def subtable_traces(idx: np.ndarray, cfg, *, bytes_per_elem: int = 4) -> dict:
    """Decompose a logical bag trace into per-subtable traces.

    ``idx``: (num_bags, pooling) logical row ids; ``cfg``: EmbeddingConfig.
    Returns ``{name: (trace, rows, row_bytes)}`` for every subtable the kind
    touches — the access streams whose locality the cache exploits.
    """
    idx = np.asarray(idx)
    if cfg.kind == "qr":
        # single-sourced index math: the same decomposition the lookup uses
        q, r = (np.asarray(a) for a in hashing.qr_decompose(idx, cfg.collision))
        spec = cfg.qr_spec
        rb = cfg.dim * bytes_per_elem
        return {"q": (q, spec.q_rows, rb), "r": (r, spec.r_rows, rb)}
    if cfg.kind == "tt":
        from repro.core import tt_embedding

        spec = cfg.tt_spec
        i1, i2, i3 = (np.asarray(a) for a in tt_embedding.tt_decompose(idx, spec))
        return {
            "g1": (i1, spec.v1, spec.g1_width * bytes_per_elem),
            "g2": (i2, spec.v2, spec.g2_width * bytes_per_elem),
            "g3": (i3, spec.v3, spec.g3_width * bytes_per_elem),
        }
    if cfg.kind == "hashed":
        rows = cfg.physical_hashed_rows
        hs = np.asarray(hashing.k_ary_hash(idx, rows, cfg.hashed_k))
        return {"table": (hs.reshape(idx.shape[0], -1), rows, cfg.dim * bytes_per_elem)}
    return {"table": (idx, cfg.vocab, cfg.dim * bytes_per_elem)}


def analyze_table(idx: np.ndarray, cfg, *, bytes_per_elem: int = 4) -> dict:
    """Full per-subtable intra-GnR analysis of one table's bag trace."""
    out = {}
    for name, (trace, rows, rb) in subtable_traces(
        idx, cfg, bytes_per_elem=bytes_per_elem
    ).items():
        out[name] = analyze_bags(trace, rows, row_bytes=rb)
    return out


def split_slot_budget(
    values: "list[np.ndarray]", total_slots: int, *, min_slots: int = 1
) -> list[int]:
    """Waterfill a global cache-slot budget across tables by prefetch value.

    ``values[t]`` is table ``t``'s per-row prefetch value (``prefetch_value``
    of its big subtable).  Giving a slot to a table captures its
    next-highest-value row, so the exact greedy is a waterfill: pour slots
    into whichever table's next marginal row is most valuable, until the
    budget is spent.  Replaces the single per-table ``cache_slots`` knob —
    tables whose traces show more intra-GnR/inter-batch reuse get more slots.

    Every table is guaranteed ``min_slots`` (a scheduler needs at least one
    slot) — this per-table floor takes precedence over the total, so a
    starved budget (``min_slots * len(values) <= total_slots < ...``)
    over-allocates to honor it.  No table is given more slots than it has
    rows (a rowless table gets zero).  Otherwise budgets sum to
    <= ``total_slots``.

    Degenerate inputs are **errors**, not silent empty plans: an empty table
    list, a zero/negative ``total_slots``, or a non-positive ``min_slots``
    all raise ``ValueError`` — a caller that reached the waterfill with no
    budget has a configuration bug upstream (e.g. ``cache_vmem_mb`` too
    small for one row), and an empty ``[]`` plan would only surface later as
    a confusing scheduler failure.
    """
    num_t = len(values)
    if num_t == 0:
        raise ValueError(
            "split_slot_budget needs at least one table's prefetch values; "
            "an empty table list cannot be budgeted (disable the cache "
            "instead of waterfilling nothing)"
        )
    if total_slots <= 0:
        raise ValueError(
            f"split_slot_budget needs a positive slot budget, got "
            f"total_slots={total_slots}; 0-slot configurations must skip the "
            f"waterfill (spec.cache_slots=0 disables the cache)"
        )
    if min_slots <= 0:
        raise ValueError(f"min_slots must be positive, got {min_slots}")
    caps = [int(v.size) for v in values]
    alloc = [min(min_slots, cap) for cap in caps]
    remaining = total_slots - sum(alloc)
    if remaining <= 0:
        return alloc
    # marginal values beyond the guaranteed base, highest first across tables
    cand_v, cand_t = [], []
    for t, v in enumerate(values):
        sv = np.sort(np.asarray(v, dtype=np.float64))[::-1][alloc[t]: caps[t]]
        cand_v.append(sv)
        cand_t.append(np.full(sv.size, t, dtype=np.int64))
    all_v = np.concatenate(cand_v) if cand_v else np.empty(0)
    all_t = np.concatenate(cand_t) if cand_t else np.empty(0, np.int64)
    order = np.argsort(-all_v, kind="stable")[:remaining]
    extra = np.bincount(all_t[order], minlength=num_t)
    return [int(a + e) for a, e in zip(alloc, extra)]


def rank_prefetch(loc: GnRLocality, *, top: int | None = None) -> np.ndarray:
    """Row ids ordered by prefetch value (descending), ties broken stably.

    The head of this ranking is what the prefetch scheduler stages and what
    the duplication planner replicates first.
    """
    value = loc.prefetch_value()
    order = np.argsort(-value, kind="stable")
    n = int(np.count_nonzero(value)) if top is None else top
    return order[:n]
