"""Software-managed SRAM cache model with next-batch prefetch scheduling.

The paper's bg-PIM SRAM cache is *proactively* filled: the host knows batch
``t+1``'s embedding indices while batch ``t`` executes (inference requests are
queued), so the cache controller stages exactly the rows the next GnR will
touch — no reactive misses, no tag checks on the critical path.  Double
buffering hides the staging DMA behind the executing batch.

TPU realization: the "SRAM" is a VMEM-resident cache block (a ``(slots, width)``
array) plus a host-side slot map.  Per batch:

1. ``prefetch(next_idx)`` (called while batch ``t`` runs) ranks the next
   batch's rows by in-batch access count × analyzer prefetch value, keeps
   already-resident winners (their staging cost is zero — the paper's
   inter-batch locality), and stages the rest into evicted slots;
2. ``slots_for(idx)`` translates batch ``t``'s accesses through the slot map
   — hits route to the cache block, misses stream from HBM — and records
   hit-rate / staged-row statistics (the modeled traffic).

The model is exact (slot map is ground truth, no approximation), host-side
numpy, and deliberately simple: one slot per row, full associativity,
value-ranked eviction.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CacheStats:
    """Running counters over a serving session."""

    accesses: int = 0
    hits: int = 0
    staged_rows: int = 0        # rows DMA'd into the cache (prefetch traffic)
    kept_rows: int = 0          # next-batch rows already resident (free)
    batches: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.accesses)

    @property
    def staged_per_batch(self) -> float:
        return self.staged_rows / max(1, self.batches)

    def traffic_bytes(self, row_bytes: int) -> dict:
        """Modeled DRAM bytes: uncached baseline vs cached (misses + staging)."""
        baseline = self.accesses * row_bytes
        cached = (self.accesses - self.hits + self.staged_rows) * row_bytes
        return {"baseline": baseline, "cached": cached}


class PrefetchScheduler:
    """Double-buffered next-batch prefetcher over one subtable.

    ``num_rows`` — subtable rows; ``num_slots`` — cache capacity in rows;
    ``value`` — optional (num_rows,) static prefetch value from the intra-GnR
    analyzer, used to break ties between rows with equal in-batch counts
    (rows that historically show more intra-GnR reuse win a slot).
    """

    def __init__(self, num_rows: int, num_slots: int, value: np.ndarray | None = None):
        if num_slots <= 0:
            raise ValueError("num_slots must be positive")
        self.num_rows = num_rows
        self.num_slots = min(num_slots, num_rows)
        self.slot_rows = np.full(self.num_slots, -1, dtype=np.int32)
        self.slot_map = np.full(num_rows, -1, dtype=np.int32)
        if value is not None and value.shape != (num_rows,):
            raise ValueError(f"value must be ({num_rows},), got {value.shape}")
        # normalized to [0, 1): strictly a tiebreak under integer counts
        if value is None:
            self.value = np.zeros(num_rows)
        else:
            v = np.asarray(value, dtype=np.float64)
            self.value = v / (v.max() + 1.0) if v.size else v
        self.stats = CacheStats()

    def prefetch(self, next_idx: np.ndarray) -> int:
        """Stage batch ``t+1``'s most valuable rows; returns rows DMA'd.

        Runs (in hardware: overlapped) during batch ``t``.  Rows are ranked
        by in-batch access count + analyzer tiebreak; the top ``num_slots``
        win residency.  Winners already resident keep their slot — only the
        difference is staged, which is what makes steady-state Zipf traffic
        small (the hot head barely changes between batches).
        """
        flat = np.asarray(next_idx).reshape(-1)
        counts = np.bincount(flat, minlength=self.num_rows)
        want = np.argsort(-(counts + self.value), kind="stable")[: self.num_slots]
        want = want[counts[want] > 0]                  # never stage untouched rows

        resident = set(int(r) for r in self.slot_rows if r >= 0)
        keep = np.array([r for r in want if int(r) in resident], dtype=np.int32)
        stage = np.array([r for r in want if int(r) not in resident], dtype=np.int32)

        # evict non-winners, then fill free slots with the staged rows
        keep_set = set(int(r) for r in keep)
        for s, r in enumerate(self.slot_rows):
            if r >= 0 and int(r) not in keep_set:
                self.slot_map[r] = -1
                self.slot_rows[s] = -1
        free = np.flatnonzero(self.slot_rows < 0)
        for s, r in zip(free, stage):
            self.slot_rows[s] = r
            self.slot_map[r] = s

        self.stats.staged_rows += int(stage.size)
        self.stats.kept_rows += int(keep.size)
        return int(stage.size)

    def slots_for(self, idx: np.ndarray, *, record: bool = True) -> np.ndarray:
        """Slot per access (-1 = miss) for the executing batch; records stats."""
        idx = np.asarray(idx)
        slots = self.slot_map[idx]
        if record:
            self.stats.accesses += int(idx.size)
            self.stats.hits += int((slots >= 0).sum())
            self.stats.batches += 1
        return slots

    def cache_rows(self) -> np.ndarray:
        """(num_slots,) row id per slot, clamped so empty slots gather row 0.

        Feeds the device-side cache-block gather ``table[cache_rows()]`` (the
        staging DMA made visible to jax); the slot map never routes an access
        to an empty slot, so the clamp is unobservable.
        """
        return np.maximum(self.slot_rows, 0).astype(np.int32)


def simulate(
    batches: list[np.ndarray], num_rows: int, num_slots: int,
    value: np.ndarray | None = None,
) -> CacheStats:
    """Run the full double-buffered schedule over a batch sequence.

    Batch 0's staging is a cold start (nothing to overlap behind); every
    later prefetch overlaps the preceding batch — exactly the serve_rec loop.
    """
    sched = PrefetchScheduler(num_rows, num_slots, value)
    sched.prefetch(batches[0])
    for t, batch in enumerate(batches):
        sched.slots_for(batch)
        if t + 1 < len(batches):
            sched.prefetch(batches[t + 1])
    return sched.stats
