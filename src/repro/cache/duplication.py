"""Subtable duplication planner (the paper's §IV-B communication kill).

ProactivePIM duplicates the weight-sharing subtables into every bank group so
a whole reconstruction completes where the big-table row lives — the CPU–PIM
transfer of partial sums disappears.  The TPU analogue: decide, per subtable,
**replicate on every shard** vs **row-shard over the model axis**.  Requests
to replicated data are served from local HBM/VMEM with zero ICI traffic; only
tables with row-sharded remainders need the pooled-vector psum (the
"base-die combine" — our ICI analogue of the paper's CPU–PIM communication).
When every subtable a table touches fits the per-chip replication budget, the
combine is eliminated outright for that table.

Greedy knapsack, highest traffic-per-byte first:

1. the whole small shared subtables (QR's R, TT's G1/G3) — touched once per
   lookup, tiny, so their traffic density dwarfs everything else;
2. then big-table rows (Q / G2 / dense), hottest first across *all* tables,
   until the budget is spent — the same skew argument as the HBM hot tier,
   but now sized by a chip-level byte budget instead of a bandwidth balance.

Everything is host-side numpy over offline profiles, like the paper's
post-training placement pass.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import hashing, placement

# Per-chip budget for replicated embedding state.  The paper's duplication
# targets a few-hundred-KB SRAM; on TPU the replicas live in HBM (the hot
# tier) and VMEM (the pinned LUT/outer cores), so the budget is a slice of
# per-chip HBM, not of VMEM.
DEFAULT_BUDGET = 64 * 2**20


def _fold_quotient(counts: np.ndarray, collision: int, q_rows: int) -> np.ndarray:
    pad = (-counts.size) % collision
    folded = np.pad(counts, (0, pad)).reshape(-1, collision).sum(axis=1)
    if folded.size < q_rows:
        folded = np.pad(folded, (0, q_rows - folded.size))
    return folded[:q_rows]


@dataclasses.dataclass(frozen=True)
class SubtableDecision:
    """Replicate-vs-shard verdict for one subtable (or its hot slice)."""

    name: str                   # "r", "g1", "g3", "q", "g2", "table"
    rows: int                   # rows this decision covers
    bytes_per_replica: int
    replicated: bool
    request_share: float        # fraction of *observed* accesses served
    covers_all_rows: bool = True  # every row replicated (unseen indices too)


@dataclasses.dataclass(frozen=True)
class TableDupPlan:
    """Placement decision for one table's subtables."""

    kind: str                               # qr | tt | dense | hashed
    big: str                                # name of the row-sharded subtable
    decisions: tuple[SubtableDecision, ...]
    hot_plan: placement.TierPlan            # hot tier over big-table rows
    touches_per_lookup: int                 # subtable fetches one lookup makes
    cache_slots: int = 0                    # prefetch-cache slot budget (0 = unset)

    @property
    def replicated_bytes(self) -> int:
        return sum(d.bytes_per_replica for d in self.decisions if d.replicated)

    @property
    def comm_free(self) -> bool:
        """True when a lookup never leaves the chip: every subtable replicated
        whole (hot tier covering *all* big-table rows, not just observed ones —
        unseen indices must stay local too).  An all-hot *profile* is not
        enough: ``covers_all_rows`` is the row-count check."""
        return all(d.replicated and d.covers_all_rows for d in self.decisions)

    @property
    def local_share(self) -> float:
        """Expected fraction of one lookup's subtable fetches served locally."""
        served = sum(
            d.request_share for d in self.decisions if d.replicated
        )
        return served / self.touches_per_lookup


@dataclasses.dataclass(frozen=True)
class DuplicationPlan:
    """Whole-model duplication decision + modeled communication effect."""

    tables: tuple[TableDupPlan, ...]
    num_shards: int
    budget_bytes: int

    @property
    def replicated_bytes(self) -> int:
        return sum(t.replicated_bytes for t in self.tables)

    @property
    def comm_free(self) -> bool:
        return all(t.comm_free for t in self.tables)

    def ici_bytes_per_batch(
        self, batch: int, dim: int, *, bytes_per_elem: int = 4
    ) -> dict:
        """Modeled cross-shard combine bytes for one serving batch.

        Baseline two-level GnR: one pooled vector per (sample, table) rides
        the psum — ``(n-1)/n`` of it crosses ICI.  Duplication removes the
        psum for comm-free tables entirely.
        """
        n = self.num_shards
        frac = (n - 1) / max(1, n)
        vec = dim * bytes_per_elem
        base = batch * len(self.tables) * vec * frac
        dup = batch * sum(1 for t in self.tables if not t.comm_free) * vec * frac
        return {"baseline": base, "duplicated": dup, "saved": base - dup}


def _table_candidates(bag, counts: np.ndarray, bytes_per_elem: int):
    """-> (small candidates [(name, rows, bytes)], big name, folded counts,
    big row bytes, big total rows, touches per lookup)."""
    emb = bag.emb
    if emb.kind == "qr":
        spec = emb.qr_spec
        rb = emb.dim * bytes_per_elem
        smalls = [("r", spec.r_rows, spec.r_rows * rb)]
        folded = _fold_quotient(counts, emb.collision, spec.q_rows)
        return smalls, "q", folded, rb, spec.q_rows, 2
    if emb.kind == "tt":
        spec = emb.tt_spec
        smalls = [
            ("g1", spec.v1, spec.v1 * spec.g1_width * bytes_per_elem),
            ("g3", spec.v3, spec.v3 * spec.g3_width * bytes_per_elem),
        ]
        folded = placement.fold_counts_tt(counts, spec)
        return smalls, "g2", folded, spec.g2_width * bytes_per_elem, spec.v2, 3
    rb = emb.dim * bytes_per_elem
    if emb.kind == "hashed":
        # fold logical counts onto physical rows through the k-ary hash
        rows = emb.physical_hashed_rows
        hs = np.asarray(hashing.k_ary_hash(
            np.arange(counts.size), rows, emb.hashed_k
        ))                                             # (vocab, k)
        folded = np.bincount(
            hs.reshape(-1), weights=np.repeat(counts, emb.hashed_k),
            minlength=rows,
        ).astype(np.int64)
        return [], "table", folded, rb, rows, emb.hashed_k
    rows = emb.vocab
    c = np.asarray(counts, dtype=np.int64)
    if c.size < rows:
        c = np.pad(c, (0, rows - c.size))
    return [], "table", c[:rows], rb, rows, 1


def plan_duplication(
    bags: Sequence,
    counts_per_table: Sequence[np.ndarray],
    *,
    num_shards: int = 1,
    budget_bytes: int = DEFAULT_BUDGET,
    bytes_per_elem: int = 4,
    slot_budgets: Sequence[int] | None = None,
) -> DuplicationPlan:
    """Choose replicated vs row-sharded subtables under a per-chip budget.

    ``counts_per_table``: logical-row access profiles (``profile_counts`` on a
    trace), one per bag; folding onto physical subtable rows happens here.
    ``slot_budgets`` (optional, one per bag) records the analyzer-driven
    prefetch-cache slot split (``intra_gnr.split_slot_budget``) on the plan,
    so serving state can be rebuilt from the plan alone.
    """
    infos = [
        _table_candidates(bag, np.asarray(cnt, dtype=np.int64), bytes_per_elem)
        for bag, cnt in zip(bags, counts_per_table)
    ]

    budget = budget_bytes
    small_decisions: list[list[SubtableDecision]] = []
    # Phase 1: whole shared subtables, cheapest (highest traffic/byte) first.
    order = sorted(
        ((b, t, i) for t, (smalls, *_rest) in enumerate(infos)
         for i, (_n, _r, b) in enumerate(smalls)),
    )
    chosen: set[tuple[int, int]] = set()
    for b, t, i in order:
        if b <= budget:
            budget -= b
            chosen.add((t, i))
    for t, (smalls, *_rest) in enumerate(infos):
        small_decisions.append([
            SubtableDecision(
                name=n, rows=r, bytes_per_replica=b,
                replicated=(t, i) in chosen, request_share=1.0,
            )
            for i, (n, r, b) in enumerate(smalls)
        ])

    # Phase 2: big-table rows, hottest first across all tables.
    row_tables, row_counts = [], []
    for t, (_s, _big, folded, rb, rows, _tpl) in enumerate(infos):
        row_tables.append(np.full(rows, t, dtype=np.int64))
        row_counts.append(folded / rb)             # traffic density per byte
    all_t = np.concatenate(row_tables) if row_tables else np.empty(0, np.int64)
    all_v = np.concatenate(row_counts) if row_counts else np.empty(0)
    order2 = np.argsort(-all_v, kind="stable")
    num_hot = [0] * len(infos)
    for j in order2:
        t = int(all_t[j])
        rb = infos[t][3]
        if rb <= budget:
            budget -= rb
            num_hot[t] += 1
        # rows of other tables may be narrower — keep scanning, don't break

    tables = []
    for t, (smalls, big, folded, rb, rows, touches) in enumerate(infos):
        hot = _top_rows_plan(folded, num_hot[t])
        decs = list(small_decisions[t])
        decs.append(
            SubtableDecision(
                name=big, rows=hot.num_hot, bytes_per_replica=hot.num_hot * rb,
                replicated=hot.num_hot > 0,
                request_share=1.0 if hot.num_hot >= rows else hot.expected_hot_hit,
                covers_all_rows=hot.num_hot >= rows,
            )
        )
        tables.append(
            TableDupPlan(
                kind=bags[t].emb.kind, big=big, decisions=tuple(decs),
                hot_plan=hot, touches_per_lookup=touches,
                cache_slots=0 if slot_budgets is None else int(slot_budgets[t]),
            )
        )
    return DuplicationPlan(
        tables=tuple(tables), num_shards=num_shards, budget_bytes=budget_bytes
    )


def _top_rows_plan(counts: np.ndarray, num_hot: int) -> placement.TierPlan:
    """TierPlan replicating exactly the ``num_hot`` hottest rows (matching the
    global greedy's per-table selection order)."""
    counts = np.asarray(counts, dtype=np.int64)
    order = np.argsort(-counts, kind="stable")
    hot_rows = np.sort(order[:num_hot])
    hot_slot = np.full(counts.size, -1, dtype=np.int32)
    hot_slot[hot_rows] = np.arange(hot_rows.size, dtype=np.int32)
    total = max(1, int(counts.sum()))
    return placement.TierPlan(
        hot_rows=hot_rows,
        hot_slot=hot_slot,
        hot_fraction=hot_rows.size / max(1, counts.size),
        expected_hot_hit=float(counts[hot_rows].sum() / total),
    )
