"""ProactivePIM cache subsystem — the paper's two headline mechanisms.

The paper accelerates weight-sharing embedding layers with two levers:

1. **intra-GnR prefetching** — within one gather-and-reduce, the shared
   subtables (QR's R table, TT's outer cores) are touched once per bag
   element, so their reuse is ~pooling-fold; ProactivePIM prefetches them
   into a bg-PIM SRAM cache *before* the GnR arrives, double-buffered so
   batch ``t+1``'s rows stage while batch ``t`` executes;
2. **subtable duplication** — replicating the small shared subtables (and
   the hottest big-table rows) across bank groups removes the CPU–PIM
   transfer entirely: every partial sum completes where the data lives.

TPU analogue implemented here:

* ``intra_gnr``    — trace-driven locality analyzer: measures per-GnR reuse
  per subtable row and ranks rows by prefetch value;
* ``sram_cache``   — software-managed cache model (slot map + double-buffered
  next-batch prefetch scheduler) that drives the
  ``repro.kernels.cached_gather`` Pallas kernel: scalar-prefetched slot maps
  route hits to a VMEM-resident cache block, misses to streamed HBM rows;
* ``duplication``  — planner deciding which subtables are replicated per
  shard vs row-sharded; when the duplicated footprint fits the per-chip
  budget the cross-shard combine (the ICI analogue of the paper's CPU–PIM
  communication) is eliminated outright.

Flow: trace -> ``intra_gnr.analyze_table`` -> ``duplication.plan_duplication``
-> ``sram_cache.PrefetchScheduler`` -> cached kernels / serving pipeline
(``repro.launch.serve_rec``).
"""

from repro.cache.duplication import (             # noqa: F401
    DuplicationPlan, SubtableDecision, TableDupPlan, plan_duplication,
)
from repro.cache.intra_gnr import (               # noqa: F401
    GnRLocality, analyze_bags, analyze_table, rank_prefetch, subtable_traces,
)
from repro.cache.sram_cache import (              # noqa: F401
    CacheStats, PrefetchScheduler,
)
