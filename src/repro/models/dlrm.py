"""DLRM — the paper's own model family (CTR prediction).

Architecture (Naumov et al. '19): dense features → bottom-MLP; sparse features
→ per-table embedding-bag GnR; pairwise-dot feature interaction; top-MLP →
CTR logit.  The embedding layer is where the paper's technique lives: tables
are weight-shared (QR), served by the two-level sharded GnR with the
VMEM-pinned R LUT, and the memory-bound GnR branch is structured to overlap
the compute-bound bottom-MLP (the PIM-runs-beside-the-host analogue).

Distribution: tables row-sharded over `model` ("bank groups"), requests over
`data`; the only `model`-axis collective is one psum of pooled vectors.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.core import embedding_bag
from repro.core.embedding_bag import BagConfig
from repro.core.overlap import parallel_branches
from repro.core.qr_embedding import EmbeddingConfig
from repro.distributed import sharding
from repro.models.layers import _normal


def make_bags(cfg: DLRMConfig) -> list[BagConfig]:
    emb = EmbeddingConfig(
        vocab=cfg.vocab_per_table,
        dim=cfg.dim,
        kind=cfg.embedding_kind,  # type: ignore[arg-type]
        collision=cfg.qr_collision,
        param_dtype=cfg.pdtype,
        compute_dtype=cfg.cdtype,
        tt_rank=cfg.tt_rank,
        tt_vocab_factors=cfg.tt_vocab_factors,
        tt_dim_factors=cfg.tt_dim_factors,
        tt_exec=cfg.tt_exec,
    )
    return [BagConfig(emb=emb, pooling=cfg.pooling) for _ in range(cfg.num_tables)]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_mlp(key, dims: tuple[int, ...], in_dim: int, dtype):
    params, axes = [], []
    d = in_dim
    keys = jax.random.split(key, len(dims))
    for k, out in zip(keys, dims):
        params.append(
            {
                "w": _normal(k, (d, out), dtype, 1.0 / math.sqrt(d)),
                "b": jnp.zeros((out,), dtype),
            }
        )
        axes.append({"w": ("mlp", "mlp"), "b": ("mlp",)})
        d = out
    return params, axes


def _mlp_fwd(params, x, compute_dtype, *, final_linear=True):
    for i, p in enumerate(params):
        x = x.astype(compute_dtype) @ p["w"].astype(compute_dtype) + p["b"].astype(
            compute_dtype
        )
        last = i == len(params) - 1
        if not (last and final_linear):
            x = jax.nn.relu(x)
    return x


def num_interactions(cfg: DLRMConfig) -> int:
    f = cfg.num_tables + 1
    return f * (f - 1) // 2


def init_dlrm(key, cfg: DLRMConfig):
    kb, kt, ke = jax.random.split(key, 3)
    bags = make_bags(cfg)
    params, axes = {}, {}
    params["bottom"], axes["bottom"] = _init_mlp(
        kb, cfg.bottom_mlp, cfg.num_dense, cfg.pdtype
    )
    top_in = cfg.bottom_mlp[-1] + num_interactions(cfg)
    params["top"], axes["top"] = _init_mlp(kt, cfg.top_mlp, top_in, cfg.pdtype)
    params["tables"] = embedding_bag.init_tables(ke, bags)
    axes["tables"] = embedding_bag.table_axes(bags)
    return params, axes


# ---------------------------------------------------------------------------
# sharded GnR dispatch (two-level scheme when a mesh is active)
# ---------------------------------------------------------------------------

def _gnr(tables, idx, bags, cfg: DLRMConfig):
    """(B, T, pooling) indices -> (B, T, dim) pooled, two-level under a mesh.

    Routed through the engine front door (``repro.engine``): the memoized
    engine for this config's bag set dispatches to the packed-table
    megakernel on packable sets (every DLRM config) or the per-table loop,
    single-chip or two-level sharded depending on the active mesh.
    """
    from repro import engine as engine_mod

    eng = engine_mod.engine_for(engine_mod.EngineSpec.from_bags(bags))
    return eng.inline_gnr(tables, idx)


def pad_tables_for_mesh(params, cfg: DLRMConfig, num_shards: int):
    """Pad Q/dense tables so the `model` axis divides rows (dry-run helper)."""
    from repro.core import sharded_embedding as SE

    bags = make_bags(cfg)
    out = []
    for t, bag in zip(params["tables"], bags):
        if "q" in t:
            out.append({"q": SE.pad_q_table(t["q"], bag.emb), "r": t["r"]})
        elif "g2" in t:
            out.append(
                {"g1": t["g1"], "g2": SE.pad_q_table(t["g2"], bag.emb), "g3": t["g3"]}
            )
        else:
            out.append({"table": SE.pad_q_table(t["table"], bag.emb)})
    return {**params, "tables": out}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def interact(bottom: jax.Array, pooled: jax.Array) -> jax.Array:
    """Pairwise-dot interaction. bottom: (B, dim); pooled: (B, T, dim)."""
    feats = jnp.concatenate([bottom[:, None, :], pooled], axis=1)  # (B, F, dim)
    gram = jnp.einsum("bfd,bgd->bfg", feats, feats)
    f = feats.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    return gram[:, iu, ju]  # (B, F*(F-1)/2)


def forward_dlrm(params, dense: jax.Array, idx: jax.Array, cfg: DLRMConfig) -> jax.Array:
    """dense: (B, num_dense) fp; idx: (B, T, pooling) int32 -> CTR logits (B,).

    The two branches are evaluated with no artificial dependency so XLA's
    scheduler may overlap the memory/ICI-bound GnR with the MXU-bound MLP.
    """
    bags = make_bags(cfg)
    bottom, pooled = parallel_branches(
        lambda d: _mlp_fwd(params["bottom"], d, cfg.cdtype, final_linear=False),
        lambda t, i: _gnr(t, i, bags, cfg),
        (dense,),
        (params["tables"], idx),
    )
    bottom = sharding.constrain(bottom, "batch", None)
    pooled = sharding.constrain(pooled, "batch", None, None)
    z = interact(bottom.astype(cfg.cdtype), pooled.astype(cfg.cdtype))
    top_in = jnp.concatenate([bottom, z], axis=-1)
    logit = _mlp_fwd(params["top"], top_in, cfg.cdtype)[:, 0]
    return logit.astype(jnp.float32)


def forward_from_pooled(
    params, dense: jax.Array, pooled: jax.Array, cfg: DLRMConfig
) -> jax.Array:
    """CTR logits from precomputed pooled embeddings (B, T, dim) -> (B,).

    The recommendation-serving pipeline (``repro.launch.serve_rec``) computes
    ``pooled`` through the cached/fused kernels and reuses the interaction +
    MLP stack unchanged."""
    bottom = _mlp_fwd(params["bottom"], dense, cfg.cdtype, final_linear=False)
    z = interact(bottom.astype(cfg.cdtype), pooled.astype(cfg.cdtype))
    top_in = jnp.concatenate([bottom, z], axis=-1)
    return _mlp_fwd(params["top"], top_in, cfg.cdtype)[:, 0].astype(jnp.float32)


def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Binary cross-entropy with logits (labels in {0, 1})."""
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def auc(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Rank-based AUC (Mann–Whitney). Used by the model-quality benchmarks."""
    order = jnp.argsort(logits)
    ranks = jnp.empty_like(order).at[order].set(jnp.arange(1, logits.size + 1))
    pos = labels > 0.5
    n_pos = pos.sum()
    n_neg = labels.size - n_pos
    sum_pos = jnp.where(pos, ranks, 0).sum()
    return (sum_pos - n_pos * (n_pos + 1) / 2) / jnp.maximum(n_pos * n_neg, 1)
