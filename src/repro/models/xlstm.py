"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scalar
memory, strictly recurrent with block-diagonal recurrence).

mLSTM uses the stabilized exponential-gating formulation (Beck et al. 2024):
    C_t = f_t C_{t-1} + i_t v_t k_t^T,   n_t = f_t n_{t-1} + i_t k_t,
    h_t = (C_t q_t) / max(|n_t · q_t|, exp(-m_t)),
computed chunkwise: within-chunk parallel (decay matrix D), cross-chunk state
passed through a scan — O(S·chunk), sub-quadratic, so xlstm runs long_500k.

sLSTM is inherently sequential (state mixing via recurrent weights); it scans
over time. The 125M assigned config keeps this cheap.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import _normal, apply_norm, init_norm

MLSTM_CHUNK = 128
MLSTM_PF = 2          # mLSTM block projection factor
SLSTM_PF = 4 / 3      # sLSTM block FFN projection factor


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel with stabilizer
# ---------------------------------------------------------------------------

def mlstm_chunked(q, k, v, i_pre, f_pre, *, state=None, chunk=MLSTM_CHUNK):
    """q,k,v: (B,H,S,D); i_pre,f_pre: (B,H,S). Returns (h, state).

    state = (C, n, m): (B,H,D,D), (B,H,D), (B,H) — the stabilized matrix
    memory, normalizer and max-log-scale.
    """
    bsz, h, s, d = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    scale = d ** -0.5

    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))           # (B,H,S)
    logi = i_pre.astype(jnp.float32)

    qc = q.reshape(bsz, h, nc, chunk, d).transpose(2, 0, 1, 3, 4)  # (C,B,H,L,D)
    kc = k.reshape(bsz, h, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(bsz, h, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    lf = logf.reshape(bsz, h, nc, chunk).transpose(2, 0, 1, 3)     # (C,B,H,L)
    li = logi.reshape(bsz, h, nc, chunk).transpose(2, 0, 1, 3)

    if state is None:
        C0 = jnp.zeros((bsz, h, d, d), jnp.float32)
        n0 = jnp.zeros((bsz, h, d), jnp.float32)
        m0 = jnp.full((bsz, h), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(carry, inp):
        C, n, m = carry
        qt, kt, vt, lft, lit = inp
        b = jnp.cumsum(lft, axis=-1)                                # (B,H,L) inclusive
        # decay matrix: D[t,s] = b_t - b_s + logi_s  (s <= t)
        D = b[..., :, None] - b[..., None, :] + lit[..., None, :]
        D = jnp.where(tri, D, -jnp.inf)
        m_intra = D.max(-1)                                         # (B,H,L)
        m_inter = b + m[..., None]                                  # (B,H,L)
        m_t = jnp.maximum(m_intra, m_inter)
        m_t = jnp.maximum(m_t, -1e30)                               # keep finite

        W = jnp.exp(D - m_t[..., None])                             # (B,H,L,L)
        scores = jnp.einsum("bhtd,bhsd->bhts", qt, kt).astype(jnp.float32) * scale
        gated = W * scores
        num = jnp.einsum("bhts,bhsd->bhtd", gated, vt.astype(jnp.float32))
        den = gated.sum(-1)                                         # (B,H,L)

        inter_scale = jnp.exp(m_inter - m_t)                        # (B,H,L)
        qf = qt.astype(jnp.float32) * scale
        num = num + inter_scale[..., None] * jnp.einsum("bhtd,bhde->bhte", qf, C)
        den = den + inter_scale * jnp.einsum("bhtd,bhd->bht", qf, n)

        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # state to end of chunk
        bL = b[..., -1]                                             # (B,H)
        g = bL[..., None] - b + lit                                 # (B,H,L) decay to end
        m_new = jnp.maximum(bL + m, g.max(-1))
        m_new = jnp.maximum(m_new, -1e30)
        carry_scale = jnp.exp(bL + m - m_new)[..., None, None]
        gw = jnp.exp(g - m_new[..., None])                          # (B,H,L)
        C_new = C * carry_scale + jnp.einsum(
            "bhs,bhsd,bhse->bhde", gw, vt.astype(jnp.float32), kt.astype(jnp.float32)
        ).swapaxes(-1, -2)  # accumulate v k^T -> (D_v? ) keep (d, d): C[dv? ] see below
        n_new = n * carry_scale[..., 0] + jnp.einsum(
            "bhs,bhsd->bhd", gw, kt.astype(jnp.float32)
        )
        return (C_new, n_new, m_new), hout

    # NOTE on C layout: C is (B,H,Dq,Dv) with h = q·C ⇒ C_new accumulates
    # k ⊗ v. The einsum above builds (d_v, d_k); swapaxes fixes to (d_k, d_v).
    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, lf, li))
    h_out = hs.transpose(1, 2, 0, 3, 4).reshape(bsz, h, s, d)
    return h_out.astype(v.dtype), (C, n, m)


def mlstm_step(state, q, k, v, i_pre, f_pre):
    """One-token recurrence. q,k,v: (B,H,D); i_pre,f_pre: (B,H)."""
    C, n, m = state
    d = q.shape[-1]
    scale = d ** -0.5
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    logi = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, logi)
    m_new = jnp.maximum(m_new, -1e30)
    fs = jnp.exp(logf + m - m_new)[..., None]
    is_ = jnp.exp(logi - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C_new = C * fs[..., None] + is_[..., None] * kf[..., :, None] * vf[..., None, :]
    n_new = n * fs + is_ * kf
    qf = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhd,bhde->bhe", qf, C_new)
    den = jnp.einsum("bhd,bhd->bh", qf, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(v.dtype), (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM cell — recurrent scan with block-diagonal recurrence
# ---------------------------------------------------------------------------

def slstm_scan(x_gates, r_weights, *, state=None):
    """x_gates: (B,S,H,4,D) input contributions for (i,f,z,o);
    r_weights: (H,4,D,D) recurrent block-diag weights. Returns (h, state)."""
    bsz, s, h, _, d = x_gates.shape
    if state is None:
        c0 = jnp.zeros((bsz, h, d), jnp.float32)
        n0 = jnp.ones((bsz, h, d), jnp.float32)
        hh0 = jnp.zeros((bsz, h, d), jnp.float32)
        m0 = jnp.zeros((bsz, h, d), jnp.float32)
    else:
        c0, n0, hh0, m0 = state
    rw = r_weights.astype(jnp.float32)

    def step(carry, xt):
        c, n, hh, m = carry
        rec = jnp.einsum("bhd,hgde->bhge", hh, rw)                  # (B,H,4,D)
        pre = xt.astype(jnp.float32) + rec
        i_pre, f_pre, z_pre, o_pre = (pre[:, :, g] for g in range(4))
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        i_ = jnp.exp(i_pre - m_new)
        f_ = jnp.exp(logf + m - m_new)
        z = jnp.tanh(z_pre)
        o = jax.nn.sigmoid(o_pre)
        c_new = f_ * c + i_ * z
        n_new = jnp.maximum(f_ * n + i_, jnp.exp(-m_new))
        h_new = o * c_new / n_new
        return (c_new, n_new, h_new, m_new), h_new

    (c, n, hh, m), hs = jax.lax.scan(step, (c0, n0, hh0, m0), x_gates.swapaxes(0, 1))
    return hs.swapaxes(0, 1), (c, n, hh, m)  # (B,S,H,D)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _heads(cfg: ModelConfig) -> int:
    return cfg.num_heads


def init_mlstm_block(key, cfg: ModelConfig):
    d = cfg.d_model
    di = MLSTM_PF * d
    hd = di // _heads(cfg)
    ks = jax.random.split(key, 8)
    s_in = 1.0 / math.sqrt(d)
    s_i = 1.0 / math.sqrt(di)
    params = {
        "ln": init_norm("rms", d, cfg.pdtype)[0],
        "up": _normal(ks[0], (d, 2 * di), cfg.pdtype, s_in),
        "wq": _normal(ks[1], (di, di), cfg.pdtype, s_i),
        "wk": _normal(ks[2], (di, di), cfg.pdtype, s_i),
        "wv": _normal(ks[3], (di, di), cfg.pdtype, s_i),
        "wi": _normal(ks[4], (di, _heads(cfg)), cfg.pdtype, s_i),
        "wf": _normal(ks[5], (di, _heads(cfg)), cfg.pdtype, s_i),
        "f_bias": jnp.full((_heads(cfg),), 3.0, cfg.pdtype),
        "out_norm": jnp.ones((di,), cfg.pdtype),
        "down": _normal(ks[6], (di, d), cfg.pdtype,
                        1.0 / math.sqrt(di * 2 * max(cfg.num_layers, 1))),
    }
    axes = {
        "ln": {"scale": ("embed",)},
        "up": ("embed", "ffn"), "wq": ("ffn", "ffn"), "wk": ("ffn", "ffn"),
        "wv": ("ffn", "ffn"), "wi": ("ffn", None), "wf": ("ffn", None),
        "f_bias": (None,), "out_norm": ("ffn",), "down": ("ffn", "embed"),
    }
    del hd
    return params, axes


def mlstm_block_fwd(p, x, cfg: ModelConfig, *, state=None, decode=False):
    cd = cfg.cdtype
    bsz, s, d = x.shape
    di = MLSTM_PF * d
    h = _heads(cfg)
    hd = di // h
    xin = apply_norm(p["ln"], x)
    up = xin.astype(cd) @ p["up"].astype(cd)
    xm, z = up[..., :di], up[..., di:]
    q = (xm @ p["wq"].astype(cd)).reshape(bsz, s, h, hd).transpose(0, 2, 1, 3)
    k = (xm @ p["wk"].astype(cd)).reshape(bsz, s, h, hd).transpose(0, 2, 1, 3)
    v = (xm @ p["wv"].astype(cd)).reshape(bsz, s, h, hd).transpose(0, 2, 1, 3)
    i_pre = (xm @ p["wi"].astype(cd)).transpose(0, 2, 1)            # (B,H,S)
    f_pre = (xm @ p["wf"].astype(cd)).transpose(0, 2, 1) + p["f_bias"].astype(cd)[None, :, None]

    if decode:
        hout, new_state = mlstm_step(state, q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                     i_pre[:, :, 0], f_pre[:, :, 0])
        hout = hout[:, :, None, :]
    else:
        hout, new_state = mlstm_chunked(q, k, v, i_pre, f_pre, state=state)

    hout = hout.transpose(0, 2, 1, 3).reshape(bsz, s, di)
    # per-block norm then input gate
    hf = hout.astype(jnp.float32)
    var = (hf ** 2).mean(-1, keepdims=True)
    hout = (hf * jax.lax.rsqrt(var + 1e-6) * p["out_norm"].astype(jnp.float32)).astype(cd)
    hout = hout * jax.nn.silu(z)
    y = hout @ p["down"].astype(cd)
    return x + y.astype(x.dtype), new_state


def init_slstm_block(key, cfg: ModelConfig):
    d = cfg.d_model
    h = _heads(cfg)
    hd = d // h
    f = int(SLSTM_PF * d)
    ks = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(d)
    params = {
        "ln": init_norm("rms", d, cfg.pdtype)[0],
        "w_gates": _normal(ks[0], (d, h, 4, hd), cfg.pdtype, s_in),
        "r_gates": _normal(ks[1], (h, 4, hd, hd), cfg.pdtype, 1.0 / math.sqrt(hd)),
        "gate_bias": jnp.concatenate([
            jnp.zeros((h, 1, hd)), jnp.full((h, 1, hd), 3.0), jnp.zeros((h, 2, hd))
        ], axis=1).astype(cfg.pdtype),
        "ln2": init_norm("rms", d, cfg.pdtype)[0],
        "ffn_up": _normal(ks[2], (d, 2 * f), cfg.pdtype, s_in),
        "ffn_down": _normal(ks[3], (f, d), cfg.pdtype,
                            1.0 / math.sqrt(f * 2 * max(cfg.num_layers, 1))),
    }
    axes = {
        "ln": {"scale": ("embed",)},
        "w_gates": ("embed", None, None, None),
        "r_gates": (None, None, None, None),
        "gate_bias": (None, None, None),
        "ln2": {"scale": ("embed",)},
        "ffn_up": ("embed", "ffn"),
        "ffn_down": ("ffn", "embed"),
    }
    return params, axes


def slstm_block_fwd(p, x, cfg: ModelConfig, *, state=None, decode=False):
    cd = cfg.cdtype
    bsz, s, d = x.shape
    h = _heads(cfg)
    hd = d // h
    xin = apply_norm(p["ln"], x)
    gates = jnp.einsum("bsd,dhge->bshge", xin.astype(cd), p["w_gates"].astype(cd))
    gates = gates + p["gate_bias"].astype(cd)[None, None]
    hs, new_state = slstm_scan(gates, p["r_gates"], state=state)
    hs = hs.reshape(bsz, s, d).astype(cd)
    x = x + hs.astype(x.dtype)
    # gated FFN
    xin2 = apply_norm(p["ln2"], x)
    up = xin2.astype(cd) @ p["ffn_up"].astype(cd)
    f = up.shape[-1] // 2
    y = jax.nn.silu(up[..., :f]) * up[..., f:]
    y = y @ p["ffn_down"].astype(cd)
    return x + y.astype(x.dtype), new_state


def is_slstm_layer(cfg: ModelConfig, i: int) -> bool:
    return cfg.slstm_every > 0 and (i % cfg.slstm_every) == (cfg.slstm_every - 1)


# ---------------------------------------------------------------------------
# full model (unrolled layers — 12-layer config keeps HLO small)
# ---------------------------------------------------------------------------

def init_xlstm(key, cfg: ModelConfig):
    from repro.core import qr_embedding

    ke, *kl = jax.random.split(key, cfg.num_layers + 1)
    params = {"embed": qr_embedding.init(ke, cfg.emb_config)}
    axes = {"embed": qr_embedding.param_axes(cfg.emb_config)}
    blocks, baxes = [], []
    for i in range(cfg.num_layers):
        if is_slstm_layer(cfg, i):
            p, a = init_slstm_block(kl[i], cfg)
        else:
            p, a = init_mlstm_block(kl[i], cfg)
        blocks.append(p)
        baxes.append(a)
    params["blocks"] = blocks
    axes["blocks"] = baxes
    params["final_norm"], axes["final_norm"] = init_norm("rms", cfg.d_model, cfg.pdtype)
    return params, axes


def init_xlstm_state(cfg: ModelConfig, batch: int):
    states = []
    d = cfg.d_model
    h = _heads(cfg)
    for i in range(cfg.num_layers):
        if is_slstm_layer(cfg, i):
            hd = d // h
            states.append((
                jnp.zeros((batch, h, hd), jnp.float32),
                jnp.ones((batch, h, hd), jnp.float32),
                jnp.zeros((batch, h, hd), jnp.float32),
                jnp.zeros((batch, h, hd), jnp.float32),
            ))
        else:
            hd = MLSTM_PF * d // h
            states.append((
                jnp.zeros((batch, h, hd, hd), jnp.float32),
                jnp.zeros((batch, h, hd), jnp.float32),
                jnp.full((batch, h), -1e30, jnp.float32),
            ))
    return states


def forward_xlstm(params, tokens, cfg: ModelConfig, *, states=None, decode=False):
    """tokens: (B, S) -> (logits, states)."""
    from repro.core import qr_embedding
    from repro.models.transformer import lm_logits

    x = qr_embedding.lookup(params["embed"], tokens, cfg.emb_config).astype(cfg.cdtype)
    x = constrain(x, "batch", "seq", "embed")
    new_states = []
    for i, bp in enumerate(params["blocks"]):
        st = None if states is None else states[i]
        if is_slstm_layer(cfg, i):
            x, ns = slstm_block_fwd(bp, x, cfg, state=st, decode=decode)
        else:
            x, ns = mlstm_block_fwd(bp, x, cfg, state=st, decode=decode)
        new_states.append(ns)
    x = apply_norm(params["final_norm"], x)
    return lm_logits(params, x, cfg), new_states
