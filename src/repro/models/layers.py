"""Shared layers: norms, RoPE, GQA attention (flash-style), MLPs.

Conventions
-----------
* every ``init_*`` returns ``(params, axes)`` — parallel pytrees of arrays and
  logical-axis tuples (resolved by ``repro.distributed.sharding``);
* activations flow in ``cfg.cdtype`` (bf16), softmax/normalizers in fp32;
* attention never materializes (S, S): training/prefill use a blockwise
  online-softmax (flash) formulation written in lax.scan so XLA keeps the
  working set at (block_q, block_kv).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_dense(key, in_dim, out_dim, axes, *, dtype, bias=False, scale=None):
    scale = (1.0 / math.sqrt(in_dim)) if scale is None else scale
    p = {"w": _normal(key, (in_dim, out_dim), dtype, scale)}
    a = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        a["b"] = (axes[-1],)
    return p, a


def dense(p, x, compute_dtype):
    y = x.astype(compute_dtype) @ p["w"].astype(compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(kind, dim, dtype):
    if kind == "rms":
        return {"scale": jnp.ones((dim,), dtype)}, {"scale": ("embed",)}
    return (
        {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
        {"scale": ("embed",), "bias": ("embed",)},
    )


def apply_norm(p, x, *, eps=1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # LayerNorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # RMSNorm
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headwise(x, scale):
    """Per-head q/k norm (qwen3)."""
    xf = x.astype(jnp.float32)
    var = (xf ** 2).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, *, theta: float, partial_factor: float = 1.0):
    """Rotate-half RoPE on the last dim. x: (..., S, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    rot = int(d * partial_factor)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    # broadcast ang over head axis: x is (..., S, D) where leading dims may
    # include batch/heads; positions aligns with the S axis.
    while ang.ndim < x_rot.ndim:
        ang = ang[None]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# flash-style blockwise attention (pure jnp/lax; differentiable)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _fit_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (block-shape fitting)."""
    b = min(target, n)
    while n % b:
        b -= 1
    return b


def _attn_block(q, k, v, m_prev, l_prev, acc_prev, *, bias, p_dtype=None):
    """One online-softmax update. q:(...,Bq,D) k/v:(...,Bk,D).

    ``p_dtype=bf16`` stores the probability tile in bf16 (the row-sum
    normalizer upcasts back to f32) — the (Bq, Bk) tiles are the dominant HBM
    traffic of blockwise attention when XLA materializes them, and bf16
    halves it; the AV matmul consumes bf16 anyway. ~1e-3 relative error on
    the normalizer (§Perf hillclimb knob `flash_block_dtype`).
    """
    s = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32)
    if bias is not None:
        s = s + bias
    m = jnp.maximum(m_prev, s.max(-1))
    corr = jnp.exp(m_prev - m)
    if p_dtype is not None:
        p = jnp.exp(s - m[..., None]).astype(p_dtype)
        l = l_prev * corr + p.astype(jnp.float32).sum(-1)
    else:
        p = jnp.exp(s - m[..., None])
        l = l_prev * corr + p.sum(-1)
    acc = acc_prev * corr[..., None] + jnp.einsum(
        "...qk,...kd->...qd", p.astype(v.dtype), v
    ).astype(jnp.float32)
    return m, l, acc


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_block: int = 1024,
    kv_block: int = 1024,
    scale: float | None = None,
    p_dtype=None,
) -> jax.Array:
    """Blockwise attention. q: (B, H, Sq, D); k/v: (B, KH, Skv, D). GQA via KH|H.

    Never materializes (Sq, Skv); scans KV blocks inside a scan over Q blocks.
    """
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    g = h // kh
    scale = scale if scale is not None else d ** -0.5
    q = (q * scale).reshape(b, kh, g, sq, d)

    q_block = _fit_block(sq, q_block)
    kv_block = _fit_block(skv, kv_block)
    nq, nk = sq // q_block, skv // kv_block

    qb = q.reshape(b, kh, g, nq, q_block, d).transpose(3, 0, 1, 2, 4, 5)
    kb = k.reshape(b, kh, nk, kv_block, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, kh, nk, kv_block, d).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_q):
        qi, qtile = qi_q
        m0 = jnp.full((b, kh, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_block, d), jnp.float32)

        def kv_step(carry, ki_kv):
            ki, ktile, vtile = ki_kv
            m, l, acc = carry
            if causal:
                qpos = qi * q_block + jnp.arange(q_block)
                kpos = ki * kv_block + jnp.arange(kv_block)
                bias = jnp.where(qpos[:, None] >= kpos[None, :], 0.0, NEG_INF)
            else:
                bias = None
            m, l, acc = _attn_block(
                qtile, ktile[:, :, None], vtile[:, :, None], m, l, acc, bias=bias,
                p_dtype=p_dtype,
            )
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(v.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    # outs: (nq, b, kh, g, q_block, d) -> (b, h, sq, d)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, sq, d)
    return out


def decode_attention(q, k, v, pos, *, scale=None):
    """Single-token attention vs a cache. q: (B,H,1,D); k/v: (B,KH,S,D).

    Masks cache positions > ``pos`` (scalar current position).
    """
    b, h, _, d = q.shape
    kh, s = k.shape[1], k.shape[2]
    g = h // kh
    scale = scale if scale is not None else d ** -0.5
    qg = (q * scale).reshape(b, kh, g, d)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg, k).astype(jnp.float32)
    mask = jnp.arange(s) <= pos
    logits = jnp.where(mask[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p.astype(v.dtype), v)
    return out.reshape(b, h, 1, d)


# ---------------------------------------------------------------------------
# GQA attention module
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, *, cross=False):
    d, hd = cfg.d_model, cfg.head_dim_
    h, kh = cfg.num_heads, cfg.kv_heads
    ks = jax.random.split(key, 5)
    params, axes = {}, {}
    params["wq"], axes["wq"] = init_dense(
        ks[0], d, h * hd, ("embed", "heads"), dtype=cfg.pdtype, bias=cfg.qkv_bias
    )
    params["wk"], axes["wk"] = init_dense(
        ks[1], d, kh * hd, ("embed", "kv_heads"), dtype=cfg.pdtype, bias=cfg.qkv_bias
    )
    params["wv"], axes["wv"] = init_dense(
        ks[2], d, kh * hd, ("embed", "kv_heads"), dtype=cfg.pdtype, bias=cfg.qkv_bias
    )
    params["wo"], axes["wo"] = init_dense(
        ks[3], h * hd, d, ("heads", "embed"), dtype=cfg.pdtype,
        scale=1.0 / math.sqrt(h * hd * 2 * max(cfg.num_layers, 1)),
    )
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((hd,), cfg.pdtype)
        params["k_norm"] = jnp.ones((hd,), cfg.pdtype)
        axes["q_norm"] = ("head_dim",)
        axes["k_norm"] = ("head_dim",)
    return params, axes


def attention(
    p,
    x,
    cfg: ModelConfig,
    *,
    causal=True,
    use_rope=True,
    positions=None,
    kv_src=None,
    cache=None,
    pos=None,
):
    """GQA attention.

    * train/prefill: ``cache is None`` — full-sequence flash attention; returns
      (y, (k, v)) so prefill can build the cache.
    * decode: ``cache = (k_cache, v_cache)`` (B, S, KH, D) and scalar ``pos`` —
      one-token update; returns (y, updated_cache).
    * cross-attention: ``kv_src`` supplies the encoder output.
    """
    b, s, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.kv_heads, cfg.head_dim_
    cd = cfg.cdtype

    q = dense(p["wq"], x, cd).reshape(b, s, h, hd)
    src = x if kv_src is None else kv_src
    k = dense(p["wk"], src, cd).reshape(b, src.shape[1], kh, hd)
    v = dense(p["wv"], src, cd).reshape(b, src.shape[1], kh, hd)

    if cfg.qk_norm:
        q = rms_norm_headwise(q, p["q_norm"])
        k = rms_norm_headwise(k, p["k_norm"])

    if use_rope and kv_src is None:
        if positions is None:
            positions = jnp.arange(s) if pos is None else (pos + jnp.zeros((s,), jnp.int32))
        q = rope(q.swapaxes(1, 2), positions, theta=cfg.rope_theta,
                 partial_factor=cfg.partial_rotary).swapaxes(1, 2)
        k = rope(k.swapaxes(1, 2), positions, theta=cfg.rope_theta,
                 partial_factor=cfg.partial_rotary).swapaxes(1, 2)

    q = constrain(q, "batch", "seq", "heads", "head_dim")

    if cache is not None:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
        k_cache = constrain(k_cache, "batch", "kvseq", "kv_heads", "head_dim")
        v_cache = constrain(v_cache, "batch", "kvseq", "kv_heads", "head_dim")
        y = decode_attention(
            q.transpose(0, 2, 1, 3),
            k_cache.transpose(0, 2, 1, 3).astype(cd),
            v_cache.transpose(0, 2, 1, 3).astype(cd),
            pos,
        )
        y = y.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
        out = dense(p["wo"], y, cd)
        return out, (k_cache, v_cache)

    y = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal and kv_src is None,
        p_dtype=jnp.bfloat16 if cfg.flash_block_dtype == "bf16" else None,
    )
    y = y.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    out = dense(p["wo"], y, cd)
    return out, (k, v)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, *, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    params, axes = {}, {}
    gated = cfg.activation == "silu"
    params["w_up"], axes["w_up"] = init_dense(ks[0], d, f, ("embed", "ffn"), dtype=cfg.pdtype)
    if gated:
        params["w_gate"], axes["w_gate"] = init_dense(ks[1], d, f, ("embed", "ffn"), dtype=cfg.pdtype)
    params["w_down"], axes["w_down"] = init_dense(
        ks[2], f, d, ("ffn", "embed"), dtype=cfg.pdtype,
        scale=1.0 / math.sqrt(f * 2 * max(cfg.num_layers, 1)),
    )
    return params, axes


def mlp(p, x, cfg: ModelConfig):
    cd = cfg.cdtype
    up = dense(p["w_up"], x, cd)
    up = constrain(up, "batch", "seq", "ffn")
    if cfg.activation == "silu":
        gate = dense(p["w_gate"], x, cd)
        hcat = jax.nn.silu(gate) * up
    elif cfg.activation == "relu2":
        hcat = jnp.square(jax.nn.relu(up))
    else:
        hcat = jax.nn.gelu(up)
    return dense(p["w_down"], hcat, cd)
