"""Pixtral-12B backbone: Mistral-NeMo-style decoder with a vision prefix.

Per the assignment the pixtral-ViT frontend is a STUB — ``input_specs``
provides precomputed patch embeddings ``(B, NUM_PATCHES, d_model)`` (the
vision-encoder + adapter output of the real model).  The multimodal sequence
is ``[patches ; text tokens]`` with full causal attention over the whole
sequence; logits are produced for the text positions.

Decode: the patch prefix occupies cache slots ``[0, NUM_PATCHES)``; text
decoding proceeds from position ``NUM_PATCHES + prompt_len`` with the standard
one-token path, so serving reuses the transformer machinery unchanged.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import transformer as T

init_pixtral = T.init_lm          # same parameter structure as a decoder LM
init_cache = T.init_cache
cache_axes = T.cache_axes


def _remat_policy(cfg):
    """None = recompute everything (min memory); 'dots' saves matmul outputs
    (the standard MaxText-style policy: ~1/3 less recompute for ~1 activation
    copy more memory)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return None


def _with_prefix(params, patches, tokens, cfg: ModelConfig):
    x_txt = T.embed_tokens(params, tokens, cfg).astype(cfg.cdtype)
    x = jnp.concatenate([patches.astype(cfg.cdtype), x_txt], axis=1)
    return constrain(x, "batch", "seq", "embed")


def forward_train(params, patches, tokens, cfg: ModelConfig):
    """patches: (B, P, d); tokens: (B, S) -> logits (B, S, vocab) for text."""
    p_len = patches.shape[1]
    x = _with_prefix(params, patches, tokens, cfg)

    def body(carry, lp):
        y, _ = T.layer_fwd(lp, carry, cfg)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(params["final_norm"], x)
    return T.lm_logits(params, x[:, p_len:, :], cfg)


def forward_prefill(params, patches, tokens, cfg: ModelConfig, max_len: int):
    """Prefill patches + prompt; cache covers max_len total positions."""
    p_len = patches.shape[1]
    b = tokens.shape[0]
    x = _with_prefix(params, patches, tokens, cfg)

    def body(carry, lp):
        y, (k, v) = T.layer_fwd(lp, carry, cfg)
        return y, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    pad = max_len - ks.shape[2]
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    x = L.apply_norm(params["final_norm"], x)
    logits = T.lm_logits(params, x[:, -1:, :], cfg)
    del b, p_len
    return logits, {"k": ks, "v": vs}


def forward_decode(params, token, cache, pos, cfg: ModelConfig):
    """One text-token step; ``pos`` counts from the start of the prefix."""
    return T.forward_decode(params, token, cache, pos, cfg)
