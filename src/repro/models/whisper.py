"""Whisper-large-v3 backbone: encoder–decoder transformer.

Per the assignment the conv/mel frontend is a STUB — ``input_specs`` provides
precomputed frame embeddings ``(B, N_AUDIO, d_model)`` (the conv1/conv2 output
of the real model).  The backbone is faithful: pre-LayerNorm, GELU MLPs, MHA
(kv_heads == num_heads), sinusoidal positions on the encoder, learned-style
positions on the decoder (realized sinusoidally — noted in DESIGN.md), tied
decoder vocab head, cross-attention into the encoder output.

Decode path: the cross-attention K/V are computed once at prefill and carried
in the cache (they never change during decoding) — the standard enc-dec
serving optimization.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import qr_embedding
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.transformer import lm_logits

# Whisper's fixed 30 s audio context after the conv frontend (stubbed; padded
# 1500 -> 1536 for 128-lane alignment, see DESIGN.md hardware-adaptation notes).
N_AUDIO = 1536


def _remat_policy(cfg):
    """None = recompute everything (min memory); 'dots' saves matmul outputs
    (the standard MaxText-style policy: ~1/3 less recompute for ~1 activation
    copy more memory)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return None


def sinusoid_positions(n: int, dim: int, dtype=jnp.float32) -> jax.Array:
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = jnp.arange(n, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_enc_layer(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    params, axes = {}, {}
    params["attn"], axes["attn"] = L.init_attention(ka, cfg)
    params["mlp"], axes["mlp"] = L.init_mlp(km, cfg)
    params["ln1"], axes["ln1"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    params["ln2"], axes["ln2"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    return params, axes


def _init_dec_layer(key, cfg: ModelConfig):
    ka, kc, km = jax.random.split(key, 3)
    params, axes = {}, {}
    params["attn"], axes["attn"] = L.init_attention(ka, cfg)
    params["xattn"], axes["xattn"] = L.init_attention(kc, cfg, cross=True)
    params["mlp"], axes["mlp"] = L.init_mlp(km, cfg)
    params["ln1"], axes["ln1"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    params["lnx"], axes["lnx"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    params["ln2"], axes["ln2"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    return params, axes


def _stack(key, n, cfg, init_fn):
    keys = jax.random.split(key, n)
    stacked = jax.vmap(lambda k: init_fn(k, cfg)[0])(keys)
    _, axes = init_fn(keys[0], cfg)
    axes = jax.tree.map(
        lambda a: ("layers",) + a,
        axes,
        is_leaf=lambda a: isinstance(a, tuple)
        and all(isinstance(x, (str, type(None))) for x in a),
    )
    return stacked, axes


def init_whisper(key, cfg: ModelConfig):
    ke, kenc, kdec = jax.random.split(key, 3)
    params, axes = {}, {}
    params["embed"] = qr_embedding.init(ke, cfg.emb_config)
    axes["embed"] = qr_embedding.param_axes(cfg.emb_config)
    params["enc"], axes["enc"] = _stack(kenc, cfg.enc_layers, cfg, _init_enc_layer)
    params["dec"], axes["dec"] = _stack(kdec, cfg.dec_layers, cfg, _init_dec_layer)
    params["enc_norm"], axes["enc_norm"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    params["dec_norm"], axes["dec_norm"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    return params, axes


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, N_AUDIO, d_model) stub conv output -> encoder states."""
    cd = cfg.cdtype
    x = frames.astype(cd) + sinusoid_positions(frames.shape[1], cfg.d_model, cd)[None]
    x = constrain(x, "batch", "seq", "embed")

    def body(carry, lp):
        h = L.apply_norm(lp["ln1"], carry)
        attn, _ = L.attention(lp["attn"], h, cfg, causal=False, use_rope=False)
        y = carry + attn
        h = L.apply_norm(lp["ln2"], y)
        y = y + L.mlp(lp["mlp"], h, cfg)
        return constrain(y, "batch", "seq", "embed"), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.apply_norm(params["enc_norm"], x)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _dec_layer_fwd(lp, x, enc_out, cfg, *, cache=None, pos=None, cross_kv=None):
    """One decoder layer. cache: (k, v) self-attn cache or None."""
    h = L.apply_norm(lp["ln1"], x)
    attn, new_cache = L.attention(
        lp["attn"], h, cfg, causal=True, use_rope=False, cache=cache, pos=pos
    )
    x = x + attn
    h = L.apply_norm(lp["lnx"], x)
    if cross_kv is not None:
        xk, xv = cross_kv
        b, s, _ = h.shape
        kh, hd = cfg.kv_heads, cfg.head_dim_
        q = L.dense(lp["xattn"]["wq"], h, cfg.cdtype).reshape(b, s, cfg.num_heads, hd)
        y = L.decode_attention(
            q.transpose(0, 2, 1, 3),
            xk.transpose(0, 2, 1, 3).astype(cfg.cdtype),
            xv.transpose(0, 2, 1, 3).astype(cfg.cdtype),
            jnp.int32(xk.shape[1] - 1),
        )
        y = y.transpose(0, 2, 1, 3).reshape(b, s, cfg.num_heads * hd)
        xattn = L.dense(lp["xattn"]["wo"], y, cfg.cdtype)
    else:
        xattn, _ = L.attention(
            lp["xattn"], h, cfg, causal=False, use_rope=False, kv_src=enc_out
        )
    x = x + xattn
    h = L.apply_norm(lp["ln2"], x)
    x = x + L.mlp(lp["mlp"], h, cfg)
    return constrain(x, "batch", "seq", "embed"), new_cache


def _sinusoid_at(pos: jax.Array, dim: int, dtype) -> jax.Array:
    """Positional row for one (traced) position scalar. -> (1, 1, dim)."""
    half = dim // 2
    freqs = jnp.exp(
        -math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)
    )
    ang = pos.astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)]).astype(dtype)[None, None, :]


def _embed_dec(params, tokens, cfg, *, pos_offset=0, positions=None):
    cd = cfg.cdtype
    x = qr_embedding.lookup(params["embed"], tokens, cfg.emb_config).astype(cd)
    s = tokens.shape[1]
    if positions is None:
        pe = sinusoid_positions(pos_offset + s, cfg.d_model, cd)[pos_offset:]
        x = x + pe[None]
    else:  # decode: one traced position scalar
        x = x + _sinusoid_at(jnp.asarray(positions), cfg.d_model, cd)
    return constrain(x, "batch", "seq", "embed")


def forward_train(params, frames, tokens, cfg: ModelConfig):
    """frames: (B, N_AUDIO, d); tokens: (B, S) -> logits (B, S, vocab)."""
    enc_out = encode(params, frames, cfg)
    x = _embed_dec(params, tokens, cfg)

    def body(carry, lp):
        y, _ = _dec_layer_fwd(lp, carry, enc_out, cfg)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = L.apply_norm(params["dec_norm"], x)
    return lm_logits(params, x, cfg)


# ---------------------------------------------------------------------------
# serving: prefill builds self-cache + frozen cross K/V; decode is one token
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.cdtype
    kh, hd = cfg.kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((cfg.dec_layers, batch, max_len, kh, hd), dtype),
        "v": jnp.zeros((cfg.dec_layers, batch, max_len, kh, hd), dtype),
        "ck": jnp.zeros((cfg.dec_layers, batch, N_AUDIO, kh, hd), dtype),
        "cv": jnp.zeros((cfg.dec_layers, batch, N_AUDIO, kh, hd), dtype),
    }


def cache_axes() -> dict:
    return {
        "k": ("layers", "batch", "kvseq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "kvseq", "kv_heads", "head_dim"),
        "ck": ("layers", "batch", None, "kv_heads", "head_dim"),
        "cv": ("layers", "batch", None, "kv_heads", "head_dim"),
    }


def forward_prefill(params, frames, tokens, cfg: ModelConfig, max_len: int):
    """Run encoder + full prompt through the decoder; build the cache."""
    enc_out = encode(params, frames, cfg)
    b, s = tokens.shape
    x = _embed_dec(params, tokens, cfg)

    def body(carry, lp):
        # self-attn K/V for the prompt + frozen cross K/V from enc_out
        h = carry
        y, (k, v) = _dec_layer_fwd(lp, h, enc_out, cfg)
        kh, hd = cfg.kv_heads, cfg.head_dim_
        ck = L.dense(lp["xattn"]["wk"], enc_out, cfg.cdtype).reshape(b, N_AUDIO, kh, hd)
        cv = L.dense(lp["xattn"]["wv"], enc_out, cfg.cdtype).reshape(b, N_AUDIO, kh, hd)
        return y, (k, v, ck, cv)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec"])
    pad = max_len - s
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    x = L.apply_norm(params["dec_norm"], x)
    logits = lm_logits(params, x[:, -1:, :], cfg)
    return logits, {"k": ks, "v": vs, "ck": cks, "cv": cvs}


def forward_decode(params, token, cache, pos, cfg: ModelConfig):
    """One decode step. token: (B, 1); cache from prefill; pos: scalar."""
    x = _embed_dec(params, token, cfg, positions=pos)

    def body(carry, xs):
        lp, kc, vc, ck, cv = xs
        y, (kc2, vc2) = _dec_layer_fwd(
            lp, carry, None, cfg, cache=(kc, vc), pos=pos, cross_kv=(ck, cv)
        )
        return y, (kc2, vc2)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["ck"], cache["cv"])
    )
    x = L.apply_norm(params["dec_norm"], x)
    logits = lm_logits(params, x, cfg)
    return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"]}
