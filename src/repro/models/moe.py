"""Capacity-based top-k MoE with expert parallelism over the `model` axis.

Execution scheme (mirrors the paper's two-level partial-reduce philosophy):
tokens stay sharded over `data`; experts are row-sharded over `model`. Each
device dispatches *its local tokens* to *its local experts* (capacity-bounded,
one-hot-cumsum slotting — no sort), computes the expert FFNs, and contributes
a partial output; a single psum over `model` combines expert contributions.
No all-to-all is emitted — the only collective is the same output-combine the
TP layers already pay.

Dropped-token semantics (GShard/Switch style): assignments beyond an expert's
capacity contribute nothing. Router probabilities are renormalized over the
top-k (Qwen3 `norm_topk_prob` convention).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import jax_compat, sharding
from repro.models.layers import _normal


def padded_experts(cfg: ModelConfig, num_shards: int) -> int:
    return -(-cfg.num_experts // num_shards) * num_shards


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f * 2 * max(cfg.num_layers, 1))
    params = {
        "router": _normal(ks[0], (d, e), cfg.pdtype, scale_in),
        "w_up": _normal(ks[1], (e, d, f), cfg.pdtype, scale_in),
        "w_gate": _normal(ks[2], (e, d, f), cfg.pdtype, scale_in),
        "w_down": _normal(ks[3], (e, f, d), cfg.pdtype, scale_out),
    }
    axes = {
        "router": ("embed", "experts"),
        "w_up": ("experts", "embed", "expert_ffn"),
        "w_gate": ("experts", "embed", "expert_ffn"),
        "w_down": ("experts", "expert_ffn", "embed"),
    }
    return params, axes


def _capacity(tokens: int, cfg: ModelConfig, num_shards: int) -> int:
    e = padded_experts(cfg, num_shards)
    c = int(math.ceil(tokens * cfg.top_k / e * cfg.capacity_factor))
    return max(c, 4)


def _local_expert_ffn(w_up, w_gate, w_down, buf, cfg: ModelConfig):
    """buf: (E_loc, C, d) -> (E_loc, C, d)."""
    cd = cfg.cdtype
    up = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(cd))
    gate = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(cd))
    h = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(cd))


def _dispatch_compute(
    x: jax.Array,            # (T, d) local tokens
    ids: jax.Array,          # (T, k) global expert ids
    wts: jax.Array,          # (T, k) combine weights
    w_up, w_gate, w_down,    # (E_loc, ...) local expert shards
    e_start: jax.Array,      # global id of first local expert
    capacity: int,
    cfg: ModelConfig,
) -> jax.Array:
    t, k = ids.shape
    e_loc = w_up.shape[0]
    cd = cfg.cdtype

    flat_ids = ids.reshape(-1)                       # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = wts.reshape(-1).astype(cd)

    local_id = flat_ids - e_start
    mine = (local_id >= 0) & (local_id < e_loc)
    local_id_c = jnp.clip(local_id, 0, e_loc - 1)

    # Position of each assignment within its expert queue (one-hot cumsum —
    # capacity slotting without a sort).
    onehot = (
        jax.nn.one_hot(local_id_c, e_loc, dtype=jnp.int32)
        * mine[:, None].astype(jnp.int32)
    )
    pos = jnp.cumsum(onehot, axis=0) - onehot        # (T*k, E_loc)
    pos = jnp.take_along_axis(pos, local_id_c[:, None], axis=1)[:, 0]
    keep = mine & (pos < capacity)

    slot = jnp.clip(local_id_c * capacity + pos, 0, e_loc * capacity - 1)

    if cfg.moe_dispatch == "gather":
        # Beyond-paper dispatch (§Perf hillclimb): instead of materializing a
        # (T·k, d) copy of every routed token and scatter-adding it into the
        # capacity buffer (~2 full activation copies of HBM traffic), scatter
        # only int32 TOKEN IDS into the slot map and gather rows directly into
        # the (E_loc·cap, d) buffer — the buffer is ~top_k·cap/T smaller than
        # the assignment expansion, cutting dispatch bytes ~10x at E=128,k=8.
        trash = e_loc * capacity
        slot_safe = jnp.where(keep, slot, trash)
        slot_tok = (
            jnp.zeros((e_loc * capacity + 1,), jnp.int32)
            .at[slot_safe]
            .set(flat_tok + 1)            # +1 so 0 = empty slot
        )[:-1]
        valid = (slot_tok > 0).astype(cd)[:, None]
        buf = x[jnp.maximum(slot_tok - 1, 0)].astype(cd) * valid
    else:  # "scatter": the GShard-style baseline
        contrib = x[flat_tok].astype(cd) * keep[:, None].astype(cd)
        buf = jnp.zeros((e_loc * capacity, x.shape[1]), cd).at[slot].add(contrib)

    y = _local_expert_ffn(
        w_up, w_gate, w_down, buf.reshape(e_loc, capacity, -1), cfg
    ).reshape(e_loc * capacity, -1)

    back = y[slot] * (keep[:, None].astype(cd) * flat_w[:, None])
    out = jnp.zeros((t, x.shape[1]), cd).at[flat_tok].add(back)
    return out


def apply_moe(p, x, cfg: ModelConfig, *, row_axis: str = "model"):
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    cd = cfg.cdtype
    mesh = sharding.current_mesh()

    logits = (x.astype(jnp.float32).reshape(-1, d) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    wts, ids = jax.lax.top_k(probs, cfg.top_k)
    wts = wts / jnp.maximum(wts.sum(-1, keepdims=True), 1e-9)
    ids = ids.astype(jnp.int32)

    nsh = mesh.shape[row_axis] if mesh is not None else 1
    e_pad = padded_experts(cfg, nsh)

    def pad_e(w):
        if w.shape[0] == e_pad:
            return w
        return jnp.pad(w, ((0, e_pad - w.shape[0]),) + ((0, 0),) * (w.ndim - 1))

    w_up, w_gate, w_down = pad_e(p["w_up"]), pad_e(p["w_gate"]), pad_e(p["w_down"])

    if mesh is None:
        cap = _capacity(b * s, cfg, 1)
        out = _dispatch_compute(
            x.reshape(-1, d), ids, wts, w_up.astype(cd), w_gate.astype(cd),
            w_down.astype(cd), jnp.int32(0), cap, cfg,
        )
        return out.reshape(b, s, d)

    # EP shard_map: tokens replicated over `model`, experts sharded.
    batch_axes = sharding.spec_for(("batch",))[0]
    from jax.sharding import PartitionSpec as P

    e_loc = e_pad // nsh
    tokens_local = (b // _axis_size(mesh, batch_axes)) * s
    cap = _capacity(tokens_local, cfg, nsh)

    def local_fn(xl, idsl, wtsl, wu, wg, wd):
        shard = jax.lax.axis_index(row_axis)
        tl = xl.shape[0] * xl.shape[1]
        out = _dispatch_compute(
            xl.reshape(tl, d), idsl.reshape(tl, -1), wtsl.reshape(tl, -1),
            wu, wg, wd, shard * e_loc, cap, cfg,
        )
        return jax.lax.psum(out.reshape(xl.shape), row_axis)

    out = jax_compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None, None),
            P(batch_axes, None, None),
            P(batch_axes, None, None),
            P(row_axis, None, None),
            P(row_axis, None, None),
            P(row_axis, None, None),
        ),
        out_specs=P(batch_axes, None, None),
        check_vma=False,
    )(
        x, ids.reshape(b, s, -1), wts.astype(cd).reshape(b, s, -1),
        w_up, w_gate, w_down,
    )
    return out


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]
