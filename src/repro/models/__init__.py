from repro.models import layers  # noqa: F401
