"""Zamba2-style hybrid: a Mamba2 backbone with a single weight-SHARED
attention+MLP block applied every ``attn_every`` layers.

Simplifications vs. the released checkpoints (noted in DESIGN.md): the shared
block consumes the hidden state only (no concatenated original-embedding
input, no per-application LoRA deltas); one shared block, full MHA (kv=32 per
the assigned config line).

The layer stack is statically segmented: 13 scanned 6-layer mamba segments,
each followed by one shared-attention application (plus 3 trailing mamba
layers) — no data-dependent branching in the HLO. Each attention site owns
its own KV-cache slot (weights are shared, caches are not).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import qr_embedding
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.transformer import lm_logits


def _remat_policy(cfg):
    """None = recompute everything (min memory); 'dots' saves matmul outputs
    (the standard MaxText-style policy: ~1/3 less recompute for ~1 activation
    copy more memory)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return None


def num_attn_sites(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.attn_every


def init_zamba2(key, cfg: ModelConfig):
    ke, kl, ka, km, kn = jax.random.split(key, 5)
    params, axes = {}, {}
    params["embed"] = qr_embedding.init(ke, cfg.emb_config)
    axes["embed"] = qr_embedding.param_axes(cfg.emb_config)

    keys = jax.random.split(kl, cfg.num_layers)
    params["mamba"] = jax.vmap(lambda k: M.init_mamba2(k, cfg)[0])(keys)
    _, ma = M.init_mamba2(keys[0], cfg)
    axes["mamba"] = jax.tree.map(
        lambda a: ("layers",) + a, ma,
        is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a),
    )

    # the shared attention + MLP block
    params["shared_attn"], axes["shared_attn"] = L.init_attention(ka, cfg)
    params["shared_mlp"], axes["shared_mlp"] = L.init_mlp(km, cfg)
    params["shared_ln1"], axes["shared_ln1"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    params["shared_ln2"], axes["shared_ln2"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    params["final_norm"], axes["final_norm"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    return params, axes


def _shared_block(params, x, cfg: ModelConfig, *, cache=None, pos=None):
    h = L.apply_norm(params["shared_ln1"], x)
    attn_out, new_cache = L.attention(params["shared_attn"], h, cfg, cache=cache, pos=pos)
    x = x + attn_out
    h = L.apply_norm(params["shared_ln2"], x)
    x = x + L.mlp(params["shared_mlp"], h, cfg)
    return x, new_cache


def init_zamba2_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.cdtype
    sites = num_attn_sites(cfg)
    h, pdim, n = M.num_ssm_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = M.d_inner(cfg) + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((cfg.num_layers, batch, h, pdim, n), dtype),
        "conv": jnp.zeros((cfg.num_layers, batch, M.CONV_WIDTH - 1, conv_dim), dtype),
        "k": jnp.zeros((sites, batch, max_len, cfg.kv_heads, cfg.head_dim_), dtype),
        "v": jnp.zeros((sites, batch, max_len, cfg.kv_heads, cfg.head_dim_), dtype),
    }


def zamba2_cache_axes() -> dict:
    return {
        "ssm": ("layers", "batch", "heads", None, "state"),
        "conv": ("layers", "batch", None, "ffn"),
        "k": ("layers", "batch", "kvseq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "kvseq", "kv_heads", "head_dim"),
    }


def _segment_bounds(cfg: ModelConfig) -> list[tuple[int, int, bool]]:
    """(start, stop, attn_after) per segment: `every`-layer mamba runs with a
    shared-attention application after each complete segment, plus a trailing
    remainder segment.  Static segmentation (vs. a lax.cond inside the layer
    scan) keeps the HLO free of data-dependent branches — XLA schedules the
    attention sites concretely and the roofline analyzer needs no branch
    heuristics."""
    nl, every = cfg.num_layers, cfg.attn_every
    sites = num_attn_sites(cfg)
    segs = [(g * every, (g + 1) * every, True) for g in range(sites)]
    if sites * every < nl:
        segs.append((sites * every, nl, False))
    return segs


def _slice_layers(tree, start: int, stop: int):
    return jax.tree.map(lambda a: a[start:stop], tree)


def forward_zamba2(params, tokens, cfg: ModelConfig, *, cache=None, pos=None,
                   decode=False):
    """tokens: (B, S) -> (logits, cache). Train: cache=None."""
    x = qr_embedding.lookup(params["embed"], tokens, cfg.emb_config).astype(cfg.cdtype)
    x = constrain(x, "batch", "seq", "embed")
    segs = _segment_bounds(cfg)

    if cache is None:

        def body(carry, lp):
            h, _ = M.mamba2_fwd(lp, carry, cfg)
            return h, None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
        for start, stop, attn in segs:
            x, _ = jax.lax.scan(body, x, _slice_layers(params["mamba"], start, stop))
            if attn:
                x, _ = _shared_block(params, x, cfg)
        x = L.apply_norm(params["final_norm"], x)
        return lm_logits(params, x, cfg), None

    # stateful path: prefill (decode=False, S tokens) or decode (S==1)
    max_len = cache["k"].shape[2]

    def body(carry, xs):
        h = carry
        lp, ssm_l, conv_l = xs
        h, (ssm2, conv2) = M.mamba2_fwd(
            lp, h, cfg, state=ssm_l, conv_state=conv_l, decode=decode
        )
        return h, (ssm2, conv2)

    kstack, vstack = cache["k"], cache["v"]
    ssm_out, conv_out = [], []
    for g, (start, stop, attn) in enumerate(segs):
        x, (ssm2, conv2) = jax.lax.scan(
            body,
            x,
            (
                _slice_layers(params["mamba"], start, stop),
                cache["ssm"][start:stop],
                cache["conv"][start:stop],
            ),
        )
        ssm_out.append(ssm2)
        conv_out.append(conv2)
        if not attn:
            continue
        if decode:
            y, (kc2, vc2) = _shared_block(
                params, x, cfg, cache=(kstack[g], vstack[g]), pos=pos
            )
        else:  # prefill: full-seq attention, then materialize the cache slot
            y, (k, v) = _shared_block(params, x, cfg)
            pad = max_len - k.shape[1]
            kc2 = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(kstack.dtype)
            vc2 = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(vstack.dtype)
        kstack = kstack.at[g].set(kc2)
        vstack = vstack.at[g].set(vc2)
        x = y
    x = L.apply_norm(params["final_norm"], x)
    new_cache = {
        "ssm": jnp.concatenate(ssm_out, axis=0),
        "conv": jnp.concatenate(conv_out, axis=0),
        "k": kstack,
        "v": vstack,
    }
    return lm_logits(params, x, cfg), new_cache
