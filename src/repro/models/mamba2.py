"""Mamba2 (SSD) blocks — chunked parallel scan for train/prefill, O(1)-state
recurrence for decode. Sub-quadratic: cost is O(S · chunk) not O(S²), which is
what qualifies the hybrid/ssm archs for the long_500k cell.

Structure follows the SSD "minimal" algorithm (Dao & Gu 2024): within-chunk
quadratic attention-like term + cross-chunk state passing via a scan.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import _normal

CONV_WIDTH = 4
CHUNK = 256


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def num_ssm_heads(cfg: ModelConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def init_mamba2(key, cfg: ModelConfig):
    d = cfg.d_model
    di = d_inner(cfg)
    g, n, h = cfg.ssm_groups, cfg.ssm_state, num_ssm_heads(cfg)
    conv_dim = di + 2 * g * n
    proj_out = 2 * di + 2 * g * n + h
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    params = {
        "in_proj": _normal(ks[0], (d, proj_out), cfg.pdtype, scale),
        "conv_w": _normal(ks[1], (CONV_WIDTH, conv_dim), cfg.pdtype, 0.5),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(cfg.pdtype),
        "D": jnp.ones((h,), cfg.pdtype),
        "dt_bias": jnp.zeros((h,), cfg.pdtype),
        "norm_scale": jnp.ones((di,), cfg.pdtype),
        "out_proj": _normal(ks[2], (di, d), cfg.pdtype,
                            1.0 / math.sqrt(di * 2 * max(cfg.num_layers, 1))),
    }
    axes = {
        "in_proj": ("embed", "ffn"),
        "conv_w": (None, "ffn"),
        "conv_b": ("ffn",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm_scale": ("ffn",),
        "out_proj": ("ffn", "embed"),
    }
    return params, axes


def _segsum(a):
    """a: (..., l) -> (..., l, l) with out[i, j] = sum_{k=j+1..i} a[k], -inf j>i."""
    l = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, a, b, c, *, chunk=CHUNK, initial_state=None):
    """SSD scan.

    x: (B, S, H, P); a: (B, S, H) (= dt·A, negative); b, c: (B, S, G, N).
    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    hpg = h // g

    # chunked views
    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = a.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)      # (B,H,C,L)
    bc = b.reshape(bsz, nc, chunk, g, n)
    cc = c.reshape(bsz, nc, chunk, g, n)

    a_cum = jnp.cumsum(ac, axis=-1)                               # (B,H,C,L)
    L = jnp.exp(_segsum(ac))                                      # (B,H,C,L,L)

    # broadcast groups to heads: head hh uses group hh // hpg
    def expand_heads(t):  # (B,NC,L,G,N) -> (B,NC,L,H,N)
        return jnp.repeat(t, hpg, axis=3)

    bh = expand_heads(bc)
    ch = expand_heads(cc)

    # 1) within-chunk (diagonal blocks)
    y_diag = jnp.einsum(
        "bclhn,bcshn,bhcls,bcshp->bclhp", ch, bh, L.astype(ch.dtype), xc
    )

    # 2) per-chunk final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)               # (B,H,C,L)
    states = jnp.einsum(
        "bclhn,bhcl,bclhp->bchpn", bh, decay_states.astype(bh.dtype), xc
    )

    # 3) cross-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1]).transpose(0, 2, 1)      # (B,C,H)
    init = (
        jnp.zeros((bsz, h, p, n), x.dtype) if initial_state is None else initial_state
    )

    def scan_fn(prev, inp):
        st, dec = inp                                              # (B,H,P,N), (B,H)
        new = st + dec[..., None, None].astype(st.dtype) * prev
        return new, prev

    stacked = states.transpose(1, 0, 2, 3, 4)                     # (C,B,H,P,N)
    decs = chunk_decay.transpose(1, 0, 2)                         # (C,B,H)
    final, prevs = jax.lax.scan(scan_fn, init, (stacked, decs))
    prev_states = prevs.transpose(1, 0, 2, 3, 4)                  # (B,C,H,P,N)

    # 4) cross-chunk contribution
    state_decay = jnp.exp(a_cum)                                  # (B,H,C,L)
    y_off = jnp.einsum(
        "bclhn,bchpn,bhcl->bclhp", ch, prev_states, state_decay.astype(ch.dtype)
    )

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final


def ssd_step(state, x, a, b, c):
    """One-token recurrence. state: (B,H,P,N); x: (B,H,P); a: (B,H); b,c: (B,G,N)."""
    h = x.shape[1]
    hpg = h // b.shape[1]
    bh = jnp.repeat(b, hpg, axis=1)                               # (B,H,N)
    ch = jnp.repeat(c, hpg, axis=1)
    decay = jnp.exp(a)[..., None, None].astype(state.dtype)
    new_state = state * decay + jnp.einsum("bhn,bhp->bhpn", bh, x)
    y = jnp.einsum("bhn,bhpn->bhp", ch, new_state)
    return y, new_state


def _split_proj(z, cfg: ModelConfig):
    di = d_inner(cfg)
    g, n, h = cfg.ssm_groups, cfg.ssm_state, num_ssm_heads(cfg)
    zs, xs, bs, cs, dts = jnp.split(
        z, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1
    )
    return zs, xs, bs, cs, dts


def _gated_norm(y, z, scale):
    yf = (y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)).astype(jnp.float32)
    var = (yf ** 2).mean(-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(y.dtype)


def mamba2_fwd(p, u, cfg: ModelConfig, *, state=None, conv_state=None, decode=False):
    """u: (B, S, d_model). If decode, S==1 and (state, conv_state) are required.

    Returns (out, (state, conv_state)).
    """
    cd = cfg.cdtype
    bsz, s, _ = u.shape
    di = d_inner(cfg)
    g, n, h = cfg.ssm_groups, cfg.ssm_state, num_ssm_heads(cfg)
    pdim = cfg.ssm_head_dim

    z = u.astype(cd) @ p["in_proj"].astype(cd)
    zs, xs, bs, cs, dts = _split_proj(z, cfg)
    conv_in = jnp.concatenate([xs, bs, cs], axis=-1)              # (B,S,conv_dim)

    w = p["conv_w"].astype(cd)                                    # (W, conv_dim)
    if decode:
        # conv_state: (B, W-1, conv_dim) holding the last W-1 inputs
        window = jnp.concatenate([conv_state.astype(cd), conv_in], axis=1)  # (B,W,conv)
        conv_out = jnp.einsum("bwc,wc->bc", window, w)[:, None, :]
        new_conv_state = window[:, 1:, :]
    else:
        pad = jnp.pad(conv_in, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
        conv_out = sum(
            pad[:, i : i + s, :] * w[i][None, None, :] for i in range(CONV_WIDTH)
        )
        new_conv_state = pad[:, pad.shape[1] - (CONV_WIDTH - 1) :, :]
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(cd))

    xs, bs, cs = jnp.split(conv_out, [di, di + g * n], axis=-1)
    x4 = xs.reshape(bsz, s, h, pdim)
    b4 = bs.reshape(bsz, s, g, n)
    c4 = cs.reshape(bsz, s, g, n)

    dt = jax.nn.softplus(dts.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = (-jnp.exp(p["A_log"].astype(jnp.float32)))[None, None, :] * dt  # (B,S,H)

    xdt = x4 * dt.astype(cd)[..., None]
    if decode:
        y, new_state = ssd_step(
            state, xdt[:, 0], a[:, 0].astype(cd), b4[:, 0], c4[:, 0]
        )
        y = y[:, None]
    else:
        init = state if state is not None else None
        y, new_state = ssd_chunked(xdt, a.astype(cd), b4, c4, initial_state=init)

    y = y + x4 * p["D"].astype(cd)[None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = _gated_norm(y, zs, p["norm_scale"])
    out = y @ p["out_proj"].astype(cd)
    return constrain(out, "batch", "seq", "embed"), (new_state, new_conv_state)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=None):
    dtype = dtype or cfg.cdtype
    h, pdim, n = num_ssm_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state
    di = d_inner(cfg)
    g = cfg.ssm_groups
    conv_dim = di + 2 * g * n
    return (
        jnp.zeros((batch, h, pdim, n), dtype),
        jnp.zeros((batch, CONV_WIDTH - 1, conv_dim), dtype),
    )
