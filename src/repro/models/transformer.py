"""Decoder-only LM: the workhorse for 7 of the 10 assigned architectures.

Features: GQA(+MQA) attention with explicit head_dim, RoPE variants, qkv bias,
q/k norm, SwiGLU/GeLU/ReLU² MLP or capacity-based MoE, tied or untied vocab
head, and the paper's weight-sharing embedding (dense/hashed/qr) with the
QR-factorized logits head.

Layers are stacked (leading L axis) and executed with ``lax.scan`` + optional
remat so the HLO stays O(1) in depth — required for 88-/94-layer archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import qr_embedding
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import moe as moe_mod


def _remat_policy(cfg):
    """None = recompute everything (min memory); 'dots' saves matmul outputs
    (the standard MaxText-style policy: ~1/3 less recompute for ~1 activation
    copy more memory)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return None


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig):
    ka, km, kn = jax.random.split(key, 3)
    params, axes = {}, {}
    params["attn"], axes["attn"] = L.init_attention(ka, cfg)
    if cfg.num_experts > 0:
        params["moe"], axes["moe"] = moe_mod.init_moe(km, cfg)
    else:
        params["mlp"], axes["mlp"] = L.init_mlp(km, cfg)
    params["ln1"], axes["ln1"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    params["ln2"], axes["ln2"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    return params, axes


def _stack_layers(key, cfg: ModelConfig, init_fn):
    keys = jax.random.split(key, cfg.num_layers)
    stacked = jax.vmap(lambda k: init_fn(k, cfg)[0])(keys)
    _, axes = init_fn(keys[0], cfg)  # axes tree only (strings aren't traceable)
    axes = jax.tree.map(
        lambda a: ("layers",) + a,
        axes,
        is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a),
    )
    return stacked, axes


def init_lm(key, cfg: ModelConfig):
    ke, kl, kh = jax.random.split(key, 3)
    params, axes = {}, {}
    params["embed"] = qr_embedding.init(ke, cfg.emb_config)
    axes["embed"] = qr_embedding.param_axes(cfg.emb_config)
    params["layers"], axes["layers"] = _stack_layers(kl, cfg, init_layer)
    params["final_norm"], axes["final_norm"] = L.init_norm(cfg.norm, cfg.d_model, cfg.pdtype)
    if not cfg.tie_embedding:
        params["head"], axes["head"] = L.init_dense(
            kh, cfg.d_model, cfg.vocab, ("embed", "vocab"), dtype=cfg.pdtype
        )
    return params, axes


# ---------------------------------------------------------------------------
# embedding in/out (the paper's technique lives here)
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig):
    if cfg.embedding_exec == "twolevel" and cfg.embedding_kind == "qr":
        from repro.core import sharded_embedding as SE

        x = SE.token_embed_inline(params["embed"], tokens, cfg.emb_config)
    else:
        x = qr_embedding.lookup(params["embed"], tokens, cfg.emb_config)
    return constrain(x, "batch", "seq", "embed")


def lm_logits(params, x, cfg: ModelConfig):
    if cfg.tie_embedding:
        logits = qr_embedding.logits_head(params["embed"], x, cfg.emb_config)
    else:
        logits = L.dense(params["head"], x, cfg.cdtype)
    return constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# layer body (shared by train/prefill/decode)
# ---------------------------------------------------------------------------

def layer_fwd(p, x, cfg: ModelConfig, *, cache=None, pos=None, positions=None):
    h = L.apply_norm(p["ln1"], x)
    attn_out, new_cache = L.attention(
        p["attn"], h, cfg, causal=True, cache=cache, pos=pos, positions=positions
    )
    x = x + attn_out
    h = L.apply_norm(p["ln2"], x)
    if cfg.num_experts > 0:
        ff = moe_mod.apply_moe(p["moe"], h, cfg)
    else:
        ff = L.mlp(p["mlp"], h, cfg)
    x = x + ff
    x = constrain(x, "batch", "seq", "embed")
    return x, new_cache


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def forward_train(params, tokens, cfg: ModelConfig, *, positions=None):
    """tokens: (B, S) -> logits (B, S, vocab). Scan over layers (+ remat)."""
    x = embed_tokens(params, tokens, cfg).astype(cfg.cdtype)

    def body(carry, layer_params):
        y, _ = layer_fwd(layer_params, carry, cfg, positions=positions)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, _ = body(x, lp)
    x = L.apply_norm(params["final_norm"], x)
    return lm_logits(params, x, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Stacked KV cache (L, B, S, KH, D) pair."""
    dtype = dtype or cfg.cdtype
    shape = (cfg.num_layers, batch, max_len, cfg.kv_heads, cfg.head_dim_)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_axes() -> dict:
    return {
        "k": ("layers", "batch", "kvseq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "kvseq", "kv_heads", "head_dim"),
    }


def forward_prefill(params, tokens, cfg: ModelConfig, max_len: int):
    """Prefill: returns (last-token logits, filled cache (len=max_len))."""
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg).astype(cfg.cdtype)

    def body(carry, layer_params):
        y, (k, v) = layer_fwd(layer_params, carry, cfg)
        return y, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    pad = max_len - s
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    x = L.apply_norm(params["final_norm"], x)
    logits = lm_logits(params, x[:, -1:, :], cfg)
    return logits, {"k": ks, "v": vs}


def forward_decode(params, token, cache, pos, cfg: ModelConfig):
    """One decode step. token: (B, 1); cache: stacked (L, ...); pos: scalar."""
    x = embed_tokens(params, token, cfg).astype(cfg.cdtype)

    def body(carry, xs):
        layer_params, kc, vc = xs
        y, (kc2, vc2) = layer_fwd(layer_params, carry, cfg, cache=(kc, vc), pos=pos)
        return y, (kc2, vc2)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.apply_norm(params["final_norm"], x)
    logits = lm_logits(params, x, cfg)
    return logits, {"k": ks, "v": vs}
