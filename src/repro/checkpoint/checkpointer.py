"""Atomic sharded checkpointing with auto-resume.

Layout:  <dir>/step_<N>/  holding one ``.npy`` per pytree leaf plus a
``manifest.json`` (tree structure, shapes, dtypes, data-pipeline cursor).
Writes go to ``step_<N>.tmp`` and are renamed only after fsync — a crashed
write never corrupts the latest checkpoint (the fault-tolerance contract:
kill -9 at any moment leaves a loadable directory).

Restore places leaves directly onto the target mesh via ``jax.device_put``
with the caller's shardings — this is also the *elastic resharding* path: the
on-disk format is mesh-agnostic (full logical arrays), so a checkpoint written
on a (16, 16) mesh restores onto (2, 16, 16) or a single CPU device unchanged.
For multi-TB deployments each host would write only its address-able shards
(`jax.experimental.multihost_utils`); the manifest format is unchanged.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

from repro.distributed import jax_compat


def _flatten_with_names(tree):
    leaves, treedef = jax.tree.flatten(tree)
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        for path, _ in jax_compat.tree_flatten_with_path(tree)[0]
    ]
    return leaves, paths, treedef


def save(directory: str, step: int, tree, *, extra: dict | None = None) -> str:
    """Atomically persist ``tree`` (+ json-serializable ``extra``)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, paths, _ = _flatten_with_names(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (leaf, path) in enumerate(zip(leaves, paths)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, like, *, shardings=None):
    """Load a checkpoint into the structure of ``like``.

    ``shardings``: optional pytree of NamedShardings parallel to ``like`` —
    leaves are device_put straight onto the (possibly different) target mesh.
    Returns (tree, extra).
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_like, _, treedef = _flatten_with_names(like)
    if len(manifest["leaves"]) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"expected {len(leaves_like)}"
        )
    shard_leaves = (
        jax.tree.flatten(shardings)[0] if shardings is not None else [None] * len(leaves_like)
    )
    out = []
    for rec, like_leaf, shd in zip(manifest["leaves"], leaves_like, shard_leaves):
        arr = np.load(os.path.join(path, rec["file"]))
        want = tuple(getattr(like_leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {rec['path']}: shape {arr.shape} != {want}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), manifest.get("extra", {})


def prune(directory: str, keep: int = 3) -> None:
    """Keep only the newest ``keep`` checkpoints (bounded disk)."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
