from repro.checkpoint import checkpointer  # noqa: F401
from repro.checkpoint.checkpointer import latest_step, restore, save  # noqa: F401
