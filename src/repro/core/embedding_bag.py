"""Multi-table gather-and-reduce (GnR) — the DLRM embedding-bag operator.

A recommendation batch carries, per sample and per sparse feature (table), a
multi-hot bag of ``pooling`` logical indices. GnR gathers each row and reduces
(sum / mean / weighted-sum) into one pooled vector per (sample, table).

This module gives the *semantic* (pure-jnp) implementation used as oracle and
CPU path; the TPU hot path is ``repro.kernels.gnr_bag`` (fused with the QR
reconstruction so each bag touches DRAM once per Q row and never for R rows —
the paper's LUT effect).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import qr_embedding
from repro.core.qr_embedding import EmbeddingConfig


@dataclasses.dataclass(frozen=True)
class BagConfig:
    """One sparse feature's table + pooling semantics."""

    emb: EmbeddingConfig
    pooling: int = 32                 # indices per bag (multi-hot degree)
    combiner: str = "sum"             # sum | mean


def init_tables(key: jax.Array, bags: Sequence[BagConfig]) -> list[dict]:
    keys = jax.random.split(key, len(bags))
    return [qr_embedding.init(k, b.emb) for k, b in zip(keys, bags)]


def table_axes(bags: Sequence[BagConfig]) -> list[dict]:
    return [qr_embedding.param_axes(b.emb) for b in bags]


def bag_lookup(
    params: dict, idx: jax.Array, bag: BagConfig, weights: jax.Array | None = None
) -> jax.Array:
    """Pooled lookup for one table. ``idx``: (batch, pooling) -> (batch, dim).

    For QR-add tables the reduction is pushed *through* the reconstruction:
    ``Σ_k (Q[q_k] + R[r_k]) = Σ_k Q[q_k] + Σ_k R[r_k]`` — associativity is what
    lets the sharded/PIM execution reduce Q and R contributions independently.
    """
    emb = bag.emb
    if emb.kind == "qr" and emb.reconstruction == "add" and weights is None:
        from repro.core import hashing

        q_idx, r_idx = hashing.qr_decompose(idx, emb.collision)
        q = params["q"].astype(emb.compute_dtype)[q_idx].sum(axis=-2)
        r = params["r"].astype(emb.compute_dtype)[r_idx].sum(axis=-2)
        pooled = q + r
    elif emb.kind == "tt" and emb.tt_exec == "pallas" and weights is None:
        # serving/jit path on the fused Pallas gather-contract kernel
        # (tt_pooled_auto falls back to the jnp oracle off-TPU)
        from repro.core import tt_embedding
        from repro.kernels import ops

        spec = emb.tt_spec
        i1, i2, i3 = tt_embedding.tt_decompose(idx, spec)
        pooled = ops.tt_pooled_auto(
            params["g1"], params["g2"], params["g3"], i1, i2, i3,
            dims=(spec.d1, spec.d2, spec.d3, spec.rank), exec_mode="pallas",
        ).astype(emb.compute_dtype)
    else:
        vecs = qr_embedding.lookup(params, idx, emb)  # (batch, pooling, dim)
        if weights is not None:
            vecs = vecs * weights[..., None].astype(vecs.dtype)
        pooled = vecs.sum(axis=-2)
    if bag.combiner == "mean":
        pooled = pooled / jnp.asarray(bag.pooling, pooled.dtype)
    return pooled


def multi_bag_lookup(
    tables: Sequence[dict],
    indices: jax.Array,
    bags: Sequence[BagConfig],
    weights: jax.Array | None = None,
) -> jax.Array:
    """All-tables GnR. ``indices``: (batch, num_tables, pooling).

    Returns (batch, num_tables, dim). Tables may have heterogeneous vocab but
    must share ``dim`` (DLRM convention).
    """
    outs = []
    for t, (params, bag) in enumerate(zip(tables, bags)):
        w = None if weights is None else weights[:, t]
        outs.append(bag_lookup(params, indices[:, t], bag, w))
    return jnp.stack(outs, axis=1)


def traffic_model(bag: BagConfig, bytes_per_elem: int = 2) -> dict:
    """Analytic DRAM-traffic amplification of weight-sharing (paper's premise).

    Returns bytes-per-bag for: dense baseline, naive weight-sharing (every
    physical row from DRAM), and LUT-fused execution (shared table pinned in
    VMEM — the paper's scheme). Used by benchmarks to reproduce the
    traffic-amplification table without hardware.
    """
    emb, p = bag.emb, bag.pooling
    row = emb.dim * bytes_per_elem
    dense = p * row
    if emb.kind == "dense":
        return {"dense": dense, "naive": dense, "fused": dense}
    if emb.kind == "hashed":
        naive = p * emb.hashed_k * row
        return {"dense": dense, "naive": naive, "fused": naive}  # no tiny LUT to pin
    if emb.kind == "tt":
        spec = emb.tt_spec
        w1 = spec.g1_width * bytes_per_elem
        w2 = spec.g2_width * bytes_per_elem
        w3 = spec.g3_width * bytes_per_elem
        naive = p * (w1 + w2 + w3)           # all three cores from DRAM
        fused = p * w2                       # outer cores pinned in VMEM/SRAM
        return {"dense": dense, "naive": naive, "fused": fused}
    naive = 2 * p * row                      # Q row + R row per index
    fused = p * row                          # R served from VMEM LUT
    return {"dense": dense, "naive": naive, "fused": fused}
