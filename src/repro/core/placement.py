"""Tiered placement of weight-sharing tables (the paper's allocation strategy).

The PIM paper splits the big (Q) table across a fast tier (HBM, near the PIM
units) and a bulk tier (DIMM), sized so each tier's request rate matches its
bandwidth; the tiny shared (R) table is pinned whole in per-PIM SRAM.

TPU adaptation:

* fast tier  -> rows **replicated** on every chip (served from local HBM, zero
  ICI traffic);
* bulk tier  -> rows **row-sharded** over the `model` axis (served with one
  partial-sum + psum);
* SRAM LUT   -> R table replicated and VMEM-pinned in the fused kernel.

The split fraction is chosen by the same balance argument as the paper's
Eq. (1), with HBM/DIMM bandwidths replaced by the TPU roofline terms:
local-HBM service rate vs. ICI combine rate.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TierPlan:
    """Placement decision for one table."""

    hot_rows: np.ndarray        # logical Q-row ids in the replicated tier (host np)
    hot_slot: np.ndarray        # (q_rows,) int32: slot in hot table, -1 if cold
    hot_fraction: float         # fraction of rows replicated
    expected_hot_hit: float     # fraction of *requests* served by the hot tier

    @property
    def num_hot(self) -> int:
        return int(self.hot_rows.size)


def profile_counts(q_indices: np.ndarray, q_rows: int) -> np.ndarray:
    """Access-frequency profile from a trace of Q-row indices (host-side).

    The paper collects this distribution after training, before inference; it
    is a one-off pass over a trace.
    """
    return np.bincount(np.asarray(q_indices).reshape(-1), minlength=q_rows)


def bandwidth_balanced_fraction(
    *,
    counts: np.ndarray,
    hbm_gbps: float = 819.0,
    ici_gbps_per_link: float = 50.0,
    ici_links: int = 4,
    safety: float = 1.0,
) -> float:
    """Pick the replicated-tier *request* share to balance HBM vs ICI service.

    Paper analog of  Request_HBM / Request_DIMM = BW_HBM / BW_DIMM:
    requests served locally (replicated tier) cost HBM bytes only; requests to
    the sharded tier additionally cost one pooled-vector ICI combine.  We size
    the hot tier so the sharded-tier ICI time does not exceed the HBM time,
    i.e. hot request share >= 1 - (ICI/HBM) * safety, clamped to [0, 1).
    """
    ici = ici_gbps_per_link * ici_links
    target_cold_share = min(1.0, (ici / hbm_gbps) * safety)
    return float(np.clip(1.0 - target_cold_share, 0.0, 0.999))


def plan_tiers(
    counts: np.ndarray,
    *,
    request_share: float | None = None,
    hot_fraction: float | None = None,
    max_hot_rows: int | None = None,
) -> TierPlan:
    """Choose the hot (replicated) row set from an access profile.

    Exactly one of ``request_share`` (cumulative-request target, paper style:
    "hot vectors = rows covering X% of requests") or ``hot_fraction`` (row-count
    fraction) should be given.
    """
    counts = np.asarray(counts, dtype=np.int64)
    q_rows = counts.size
    order = np.argsort(-counts, kind="stable")
    total = max(1, counts.sum())
    if hot_fraction is not None:
        num_hot = int(round(hot_fraction * q_rows))
    else:
        share = 0.8 if request_share is None else request_share
        cum = np.cumsum(counts[order]) / total
        num_hot = int(np.searchsorted(cum, share) + 1) if share > 0 else 0
        num_hot = min(num_hot, q_rows)
    if max_hot_rows is not None:
        num_hot = min(num_hot, max_hot_rows)
    hot_rows = np.sort(order[:num_hot])
    hot_slot = np.full((q_rows,), -1, dtype=np.int32)
    hot_slot[hot_rows] = np.arange(num_hot, dtype=np.int32)
    hit = float(counts[hot_rows].sum() / total)
    return TierPlan(
        hot_rows=hot_rows,
        hot_slot=hot_slot,
        hot_fraction=num_hot / max(1, q_rows),
        expected_hot_hit=hit,
    )


def split_table(table: jax.Array, plan: TierPlan) -> tuple[jax.Array, jax.Array]:
    """Split a Q table into (hot_table, cold_table_with_zeroed_hot_rows).

    The cold table keeps full shape (simplifies contiguous row-sharding and
    checkpoint layout); hot rows are zeroed there so hot+cold lookups never
    double-count.  Capacity overhead = hot_fraction, by design small.
    """
    hot = table[jnp.asarray(plan.hot_rows, dtype=jnp.int32)]
    mask = jnp.asarray(plan.hot_slot < 0, dtype=table.dtype)[:, None]
    cold = table * mask
    return hot, cold


# ---------------------------------------------------------------------------
# TT-Rec tiered placement (the paper's bg-PIM SRAM cache + subtable duplication)
# ---------------------------------------------------------------------------

# Default per-core SRAM budget: the paper's bg-PIM cache is a few hundred KB;
# on TPU the analogue is a slice of the ~16 MB VMEM left over by the kernel's
# working set.  Outer cores must fit it *whole* for the pin to be legal.
DEFAULT_SRAM_BUDGET = 512 * 1024


@dataclasses.dataclass(frozen=True)
class TTTierPlan:
    """Placement decision for one TT table.

    The outer cores (G1/G3) are duplicated whole into every bank group's SRAM
    (VMEM pin + replication across chips): their intra-GnR locality is
    structural — every lookup touches them — so duplication removes both the
    DRAM traffic and the CPU-PIM combine for two of the three contraction
    operands.  The middle core is the "big table": its rows are row-sharded,
    and the hottest rows (by i2 request skew) are replicated as the hot tier,
    exactly the Q-table treatment on the QR path.
    """

    mid_plan: TierPlan          # hot tier over middle-core (i2) rows
    sram_bytes: int             # G1 + G3 pinned footprint per replica
    sram_budget: int            # budget the pin was checked against
    duplication: int            # replicas of the outer cores ("bank groups")

    @property
    def sram_fits(self) -> bool:
        return self.sram_bytes <= self.sram_budget

    @property
    def num_hot(self) -> int:
        return self.mid_plan.num_hot


def fold_counts_tt(counts_logical: np.ndarray, spec) -> np.ndarray:
    """Fold a logical-row access profile onto middle-core (i2) rows.

    ``i2 = (idx // v3) % v2`` — each middle row serves ``v1 * v3`` logical
    rows, so, like quotient folding, hot logical rows stay hot but the hot
    *set* shrinks sub-linearly (they rarely cluster into the same i2).
    """
    counts_logical = np.asarray(counts_logical, dtype=np.int64)
    idx = np.arange(counts_logical.size, dtype=np.int64)
    i2 = (idx // spec.v3) % spec.v2
    return np.bincount(i2, weights=counts_logical, minlength=spec.v2).astype(np.int64)


def plan_tt_tiers(
    counts_logical: np.ndarray,
    spec,
    *,
    request_share: float | None = None,
    hot_fraction: float | None = None,
    max_hot_rows: int | None = None,
    sram_budget: int = DEFAULT_SRAM_BUDGET,
    bytes_per_elem: int = 4,
    duplication: int = 1,
) -> TTTierPlan:
    """TT-aware tier plan from a logical access profile.

    SRAM-pins the outer cores (checked against ``sram_budget``), hot-tiers the
    middle core by folded i2 skew.  ``duplication`` is the bank-group replica
    count of the pinned cores (paper: duplication across bank groups kills the
    CPU-PIM communication; on TPU it is replication across chips).
    """
    folded = fold_counts_tt(counts_logical, spec)
    mid = plan_tiers(
        folded,
        request_share=request_share,
        hot_fraction=hot_fraction,
        max_hot_rows=max_hot_rows,
    )
    return TTTierPlan(
        mid_plan=mid,
        sram_bytes=spec.sram_bytes(bytes_per_elem),
        sram_budget=sram_budget,
        duplication=duplication,
    )


def hot_vector_reduction_curve(
    counts_logical: np.ndarray, collisions: list[int], request_share: float = 0.8
) -> dict[int, int]:
    """Paper's shortcoming analysis: #hot vectors vs. hash-collision value.

    Quotient hashing folds ``c`` consecutive logical rows into one Q row; hot
    logical rows stay hot but rarely cluster, so the hot-row count shrinks
    sub-linearly in ``c``.  Returns {collision: num_hot_rows}.
    """
    counts_logical = np.asarray(counts_logical, dtype=np.int64)
    out: dict[int, int] = {}
    for c in collisions:
        pad = (-counts_logical.size) % c
        folded = np.pad(counts_logical, (0, pad)).reshape(-1, c).sum(axis=1)
        out[c] = plan_tiers(folded, request_share=request_share).num_hot
    return out
