"""Packed-table execution layout — one buffer, one index stream, one kernel.

The DLRM embedding layer is T independent tables with identical row width.
Launching one gather kernel per table costs T dispatches and T short
HBM-streaming loops per batch; the paper's bg-PIM (like RecNMP/TensorDIMM)
wins by batching many small gathers into one wide memory-side pass.  This
module builds that pass for the TPU:

* ``PackedLayout`` — a static (hashable, jit-friendly) description of all
  same-width subtables concatenated row-major: per-table row offsets for the
  big subtables (dense table / QR Q / TT middle core G2), for the small
  shared subtables (QR R LUTs, TT outer cores G1/G3), and for the per-table
  cache-slot ranges of the prefetch scheduler;
* ``pack_params`` — the device-side concatenation (+ one trailing all-zero
  row per streamed buffer: accesses that must contribute nothing — ragged
  bag tails, non-owned rows on a shard — are *routed to the zero row*
  instead of masked, so the kernel needs no predication);
* ``pack_indices`` — logical (B, T, K) bag indices -> globally-offset int32
  streams, vectorized over all tables at once (the per-table Python loop
  becomes index arithmetic);
* slot-map helpers translating each table's local prefetch-scheduler state
  into the packed cache block's coordinates;
* ``packed_multi_bag_lookup`` — the drop-in multi-table GnR used by the
  single-chip model forward: pack, stream, one
  ``ops.packed_multi_pooled`` dispatch (megakernel on TPU, packed jnp oracle
  elsewhere; differentiable on both paths).

The sharded two-level path builds its own local streams (ownership / hot-tier
/ position routing) in ``repro.core.sharded_embedding`` but lands in the same
megakernel.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, qr_embedding
from repro.core.embedding_bag import BagConfig


def _cumsum(sizes: Sequence[int]) -> tuple[int, ...]:
    off, acc = [], 0
    for s in sizes:
        off.append(acc)
        acc += int(s)
    return tuple(off)


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """Static shape/offset description of one packed multi-table family.

    All tables must share ``kind`` and row width (the DLRM convention); the
    per-table row counts may differ.  Hashable — safe as a jit static arg.
    """

    kind: str                                   # dense | qr | tt
    num_tables: int
    dim: int                                    # pooled output width
    rows_per_table: tuple[int, ...]             # big-subtable physical rows
    small_rows_per_table: tuple[int, ...] = ()  # QR R rows (empty otherwise)
    slot_budgets: tuple[int, ...] = ()          # cache slots per table
    collision: int = 0                          # QR hash collision value
    tt_dims: tuple[int, int, int, int] | None = None    # (d1, d2, d3, rank)
    tt_vocab: tuple[int, int, int] | None = None        # (v1, v2, v3)

    # -- big (streamed) buffer ------------------------------------------------
    @property
    def row_offsets(self) -> tuple[int, ...]:
        return _cumsum(self.rows_per_table)

    @property
    def total_rows(self) -> int:
        return sum(self.rows_per_table)

    @property
    def zero_row(self) -> int:
        """Index of the appended all-zero row (ragged/masked accesses)."""
        return self.total_rows

    @property
    def big_width(self) -> int:
        """Row width of the streamed buffer (G2 is wider than dim for TT)."""
        if self.kind == "tt":
            d1, d2, d3, rank = self.tt_dims
            return rank * d2 * rank
        return self.dim

    # -- small shared buffer (QR R LUTs) -------------------------------------
    @property
    def small_offsets(self) -> tuple[int, ...]:
        return _cumsum(self.small_rows_per_table)

    @property
    def total_small(self) -> int:
        return sum(self.small_rows_per_table)

    @property
    def small_zero_row(self) -> int:
        return self.total_small

    # -- packed cache block ---------------------------------------------------
    @property
    def slot_offsets(self) -> tuple[int, ...]:
        return _cumsum(self.slot_budgets)

    @property
    def total_slots(self) -> int:
        return sum(self.slot_budgets)


# ---------------------------------------------------------------------------
# layout construction
# ---------------------------------------------------------------------------

def packable(bags: Sequence[BagConfig]) -> bool:
    """True when every bag can ride one packed megakernel dispatch: uniform
    kind (dense / additive-QR / TT), row width, vocab, and decomposition
    constants across tables (the DLRM convention).  Uniform vocab keeps the
    per-table hot-slot maps stackable on the sharded path; mixed-vocab sets
    fall back to the per-table loop."""
    if not bags:
        return False
    e0 = bags[0].emb
    if e0.kind not in ("dense", "qr", "tt"):
        return False
    if e0.kind == "qr" and e0.reconstruction != "add":
        return False
    for b in bags:
        e = b.emb
        if e.kind != e0.kind or e.dim != e0.dim or e.vocab != e0.vocab:
            return False
        if e.kind == "qr" and e.collision != e0.collision:
            return False
        if e.kind == "tt" and (
            e.tt_spec.vocab_factors != e0.tt_spec.vocab_factors
            or e.tt_spec.dim_factors != e0.tt_spec.dim_factors
            or e.tt_spec.rank != e0.tt_spec.rank
        ):
            return False
    return True


def build_layout(
    bags: Sequence[BagConfig], slot_budgets: Sequence[int] | None = None
) -> PackedLayout:
    assert packable(bags), "bags are not uniform enough to pack"
    e0 = bags[0].emb
    budgets = tuple(int(s) for s in (slot_budgets or [0] * len(bags)))
    assert len(budgets) == len(bags)
    if e0.kind == "qr":
        return PackedLayout(
            kind="qr",
            num_tables=len(bags),
            dim=e0.dim,
            rows_per_table=tuple(
                qr_embedding._pad_rows(b.emb.qr_spec.q_rows) for b in bags
            ),
            small_rows_per_table=tuple(b.emb.qr_spec.r_rows for b in bags),
            slot_budgets=budgets,
            collision=e0.collision,
        )
    if e0.kind == "tt":
        spec = e0.tt_spec
        return PackedLayout(
            kind="tt",
            num_tables=len(bags),
            dim=e0.dim,
            rows_per_table=tuple(b.emb.tt_spec.g2_rows_padded for b in bags),
            slot_budgets=budgets,
            tt_dims=(spec.d1, spec.d2, spec.d3, spec.rank),
            tt_vocab=spec.vocab_factors,
        )
    return PackedLayout(
        kind="dense",
        num_tables=len(bags),
        dim=e0.dim,
        rows_per_table=tuple(qr_embedding._pad_rows(b.emb.vocab) for b in bags),
        slot_budgets=budgets,
    )


@functools.lru_cache(maxsize=64)
def _layout_for(bags: tuple) -> PackedLayout:
    return build_layout(list(bags))


def layout_for(bags: Sequence[BagConfig]) -> PackedLayout:
    """Cached layout lookup (BagConfig is frozen/hashable)."""
    return _layout_for(tuple(bags))


# ---------------------------------------------------------------------------
# device-side packing
# ---------------------------------------------------------------------------

def big_key(kind: str) -> str:
    """Param-dict key of the streamed big subtable for an embedding kind."""
    return {"qr": "q", "tt": "g2"}.get(kind, "table")


def combiner_scale(bags: Sequence[BagConfig], dtype) -> jax.Array:
    """(T,) per-table post-pool scale implementing the bag combiners."""
    return jnp.asarray(
        [1.0 / b.pooling if b.combiner == "mean" else 1.0 for b in bags], dtype
    )


def concat_with_zero(parts: Sequence[jax.Array], dtype) -> jax.Array:
    """Row-concatenate buffers and append one all-zero row (the routing sink
    for accesses that must contribute nothing)."""
    width = parts[0].shape[1]
    zero = jnp.zeros((1, width), dtype)
    return jnp.concatenate([p.astype(dtype) for p in parts] + [zero], axis=0)


def pack_params(tables: Sequence[dict], layout: PackedLayout, *, dtype=None) -> dict:
    """Concatenate per-table params into the packed buffers (+ zero rows).

    Streamed buffers (big table, QR R LUT) get one trailing all-zero row so
    masked accesses can be routed instead of predicated.  Outer TT cores are
    packed without a zero row — a zero G2 row already nulls the contraction.
    """
    if layout.kind == "qr":
        dtype = dtype or tables[0]["q"].dtype
        q = concat_with_zero([t["q"] for t in tables], dtype)
        r = concat_with_zero([t["r"] for t in tables], dtype)
        assert q.shape[0] == layout.total_rows + 1, (q.shape, layout.rows_per_table)
        assert r.shape[0] == layout.total_small + 1
        return {"q": q, "r": r}
    if layout.kind == "tt":
        dtype = dtype or tables[0]["g2"].dtype
        g2 = concat_with_zero([t["g2"] for t in tables], dtype)
        g1 = jnp.concatenate([t["g1"].astype(dtype) for t in tables], axis=0)
        g3 = jnp.concatenate([t["g3"].astype(dtype) for t in tables], axis=0)
        assert g2.shape[0] == layout.total_rows + 1
        return {"g1": g1, "g2": g2, "g3": g3}
    dtype = dtype or tables[0]["table"].dtype
    table = concat_with_zero([t["table"] for t in tables], dtype)
    assert table.shape[0] == layout.total_rows + 1
    return {"table": table}


# ---------------------------------------------------------------------------
# index-stream packing (vectorized over all tables)
# ---------------------------------------------------------------------------

def _valid_mask(idx: jax.Array, lengths: jax.Array | None) -> jax.Array | None:
    if lengths is None:
        return None
    k = idx.shape[-1]
    return jnp.arange(k, dtype=jnp.int32)[None, None, :] < lengths[..., None]


def pack_indices(
    idx: jax.Array, layout: PackedLayout, *, lengths: jax.Array | None = None
) -> dict:
    """Logical (B, T, K) bag indices -> globally-offset packed streams.

    ``lengths`` (B, T) optionally marks ragged bags: positions ``k >=
    lengths[b, t]`` are routed to the zero rows and contribute nothing —
    empty bags (length 0) pool to exactly zero.
    """
    idx = idx.astype(jnp.int32)
    assert idx.shape[-2] == layout.num_tables, (idx.shape, layout.num_tables)
    off = jnp.asarray(layout.row_offsets, jnp.int32)[None, :, None]
    mask = _valid_mask(idx, lengths)

    if layout.kind == "qr":
        q_idx, r_idx = hashing.qr_decompose(idx, layout.collision)
        q_g = q_idx + off
        r_g = r_idx + jnp.asarray(layout.small_offsets, jnp.int32)[None, :, None]
        if mask is not None:
            q_g = jnp.where(mask, q_g, layout.zero_row)
            r_g = jnp.where(mask, r_g, layout.small_zero_row)
        return {"q_idx": q_g, "r_idx": r_g}
    if layout.kind == "tt":
        from repro.core import tt_embedding

        v1, v2, v3 = layout.tt_vocab
        i1, i2, i3 = tt_embedding.tt_decompose_factors(idx, v2, v3)
        t_ids = jnp.arange(layout.num_tables, dtype=jnp.int32)[None, :, None]
        i1_g = i1 + t_ids * v1
        i3_g = i3 + t_ids * v3
        i2_g = i2 + off
        if mask is not None:
            # zero G2 row nulls the product; i1/i3 stay valid rows
            i2_g = jnp.where(mask, i2_g, layout.zero_row)
        return {"i1": i1_g, "i2": i2_g, "i3": i3_g}
    g = idx + off
    if mask is not None:
        g = jnp.where(mask, g, layout.zero_row)
    return {"idx": g}


def global_slots(slot: jax.Array, layout: PackedLayout) -> jax.Array:
    """Per-table local cache slots (B, T, K), -1 = miss -> packed-block slots."""
    off = jnp.asarray(layout.slot_offsets, jnp.int32)[None, :, None]
    slot = slot.astype(jnp.int32)
    return jnp.where(slot >= 0, slot + off, -1)


def miss_slots(idx: jax.Array) -> jax.Array:
    """All-miss slot map (the no-cache / mesh configuration)."""
    return jnp.full(idx.shape, -1, jnp.int32)


def packed_cache_rows(
    cache_rows: Sequence[np.ndarray], layout: PackedLayout
) -> np.ndarray:
    """Per-table scheduler ``cache_rows()`` -> global packed-buffer rows.

    The packed cache block is ``big[packed_cache_rows(...)]`` — one gather is
    the whole staging DMA for every table's slots.
    """
    parts = []
    for t, rows in enumerate(cache_rows):
        assert rows.shape == (layout.slot_budgets[t],), (
            rows.shape, layout.slot_budgets[t])
        parts.append(np.asarray(rows, np.int64) + layout.row_offsets[t])
    total = (
        np.concatenate(parts) if parts else np.empty((0,), np.int64)
    )
    return total.astype(np.int32)


def dummy_cache(layout: PackedLayout, dtype) -> jax.Array:
    """1-row zero cache block for cache-less calls (slot map all -1)."""
    return jnp.zeros((1, layout.big_width), dtype)


# ---------------------------------------------------------------------------
# single-chip multi-table GnR (the model-forward entry point)
# ---------------------------------------------------------------------------

def packed_multi_bag_lookup(
    tables: Sequence[dict],
    indices: jax.Array,
    bags: Sequence[BagConfig],
    *,
    lengths: jax.Array | None = None,
    exec_mode: str = "auto",
    interpret: bool | None = None,
    dim_block: int | None = None,
) -> jax.Array:
    """All-tables GnR in one megakernel dispatch. ``indices``: (B, T, K).

    Drop-in for ``embedding_bag.multi_bag_lookup`` on packable bag sets: the
    per-table Python loop (T kernel launches / T gathers) becomes one packed
    dispatch.  Returns (B, T, dim) in the compute dtype.
    """
    from repro.kernels import ops

    layout = layout_for(bags)
    emb = bags[0].emb
    packed = pack_params(tables, layout, dtype=emb.compute_dtype)
    streams = pack_indices(indices, layout, lengths=lengths)
    streams["slot"] = miss_slots(indices)
    packed["cache"] = dummy_cache(layout, emb.compute_dtype)
    pooled = ops.packed_multi_pooled(
        packed, streams, kind=layout.kind, dims=layout.tt_dims,
        exec_mode=exec_mode, interpret=interpret, dim_block=dim_block,
    )
    if lengths is None:
        pooled = pooled * combiner_scale(bags, pooled.dtype)[None, :, None]
    else:
        # mean combiners divide by the VALID bag length, not the padded K
        mean_t = jnp.asarray([b.combiner == "mean" for b in bags])
        denom = jnp.where(
            mean_t[None, :], jnp.maximum(lengths, 1).astype(pooled.dtype), 1.0
        )
        pooled = pooled / denom[..., None]
    return pooled.astype(emb.compute_dtype)
