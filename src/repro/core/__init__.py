"""repro.core — the paper's contribution: weight-sharing embedding acceleration.

Public surface:

* ``hashing``            — QR / k-ary hash index math
* ``qr_embedding``       — weight-sharing embedding modules (dense/hashed/qr)
* ``embedding_bag``      — multi-table gather-and-reduce (DLRM semantics)
* ``placement``          — hot/cold tier planning (the allocation strategy)
* ``tt_embedding``       — TT-Rec tensor-train tables (3-core factorization)
* ``packed_tables``      — packed multi-table layout feeding the megakernel
  (one buffer / one index stream / one dispatch for every table's bag)
* ``sharded_embedding``  — two-level shard_map partials (the PIM scheme on a
  mesh): the kernel-level pieces ``repro.engine`` composes
* ``overlap``            — compute/ICI overlap helpers

The ProactivePIM cache subsystem (intra-GnR analyzer, prefetch scheduler,
duplication planner) lives in ``repro.cache``; the plan/compile/execute
front door every GnR path routes through lives in ``repro.engine``.
"""

from repro.core import (  # noqa: F401
    embedding_bag,
    hashing,
    overlap,
    placement,
    qr_embedding,
    sharded_embedding,
)
from repro.core.embedding_bag import BagConfig  # noqa: F401
from repro.core.qr_embedding import EmbeddingConfig  # noqa: F401
