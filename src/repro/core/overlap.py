"""Compute/communication overlap helpers.

The PIM design hides GnR latency behind the dense compute stream (the
embedding engine runs while the host does MLP work).  The XLA analogue is
graph-level independence plus collective chunking so the scheduler can
interleave ICI transfers with MXU work.
"""

from __future__ import annotations

from typing import Callable, TypeVar

import jax
import jax.numpy as jnp

T = TypeVar("T")
U = TypeVar("U")


def parallel_branches(f: Callable[..., T], g: Callable[..., U], fa, ga) -> tuple[T, U]:
    """Evaluate two independent branches with no artificial data dependence.

    DLRM's bottom-MLP (compute-bound) and embedding GnR (memory/ICI-bound) are
    structured through this so XLA's latency-hiding scheduler can overlap them
    — the graph-level analogue of PIM running concurrently with the host.
    """
    return f(*fa), g(*ga)


def chunked_psum(x: jax.Array, axis_name: str, *, chunks: int = 1) -> jax.Array:
    """psum split into ``chunks`` along the last dim.

    Smaller collectives can be interleaved with neighbouring compute by the
    scheduler (overlap hillclimb knob); chunks=1 is a plain psum.
    """
    if chunks <= 1:
        return jax.lax.psum(x, axis_name)
    parts = jnp.split(x, chunks, axis=-1)
    return jnp.concatenate([jax.lax.psum(p, axis_name) for p in parts], axis=-1)
