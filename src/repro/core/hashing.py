"""Weight-sharing hash constructions for embedding tables.

The paper's technique family ("weight-sharing embedding layers") covers:

* the **hashing trick** [Weinberger et al. '09]: one universal hash maps the
  logical row id into a smaller physical table — k-ary variants reconstruct a
  row from k physical rows;
* the **quotient–remainder (QR / compositional) trick** [Shi et al. '20]:
  complementary partitions ``(idx // c, idx % c)`` map each logical row to a
  unique (q, r) pair; the logical row is reconstructed as ``op(Q[q], R[r])``.

Everything here is pure index arithmetic (int32), jit-safe and shard-safe.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Large odd multipliers for universal (multiply-shift) hashing.  Fixed seeds
# keep traces reproducible across hosts/restarts (fault-tolerance requirement:
# a restarted worker must hash identically).
_MULTIPLIERS = np.array(
    [0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F, 0x165667B1, 0x9E3779B9],
    dtype=np.uint32,
)


@dataclasses.dataclass(frozen=True)
class QRSpec:
    """Static shape spec of a quotient–remainder factorization."""

    vocab: int          # logical rows
    collision: int      # hash collision value "c" (R-table rows)
    dim: int            # embedding dim of the reconstructed vector

    @property
    def q_rows(self) -> int:
        return -(-self.vocab // self.collision)  # ceil div

    @property
    def r_rows(self) -> int:
        return self.collision

    @property
    def compression(self) -> float:
        """Capacity reduction factor vs. the dense table."""
        dense = self.vocab * self.dim
        shared = (self.q_rows + self.r_rows) * self.dim
        return dense / shared

    def lut_bytes(self, bytes_per_elem: int = 4) -> int:
        """Size of the shared (R) table — the thing the paper pins in PIM SRAM.

        On TPU this is the VMEM-resident LUT; it must be small (tens of KB).
        """
        return self.r_rows * self.dim * bytes_per_elem


def qr_decompose(idx: jax.Array, collision: int) -> tuple[jax.Array, jax.Array]:
    """Map logical indices to (quotient, remainder) physical indices.

    Complementary partitions: (q, r) is unique per logical idx, so no two
    logical rows share *both* physical rows.
    """
    idx = idx.astype(jnp.int32)
    return idx // collision, idx % collision


def universal_hash(idx: jax.Array, buckets: int, seed: int = 0) -> jax.Array:
    """Multiply-shift universal hash of int indices into ``[0, buckets)``."""
    mult = jnp.uint32(_MULTIPLIERS[seed % len(_MULTIPLIERS)])
    h = (idx.astype(jnp.uint32) + jnp.uint32((seed * 0x517C_C1B7) & 0xFFFF_FFFF)) * mult
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x2C1B_3C6D)
    h = h ^ (h >> 12)
    return (h % jnp.uint32(buckets)).astype(jnp.int32)


def k_ary_hash(idx: jax.Array, buckets: int, k: int) -> jax.Array:
    """k independent hashes per index; shape ``idx.shape + (k,)``."""
    return jnp.stack([universal_hash(idx, buckets, seed=s) for s in range(k)], axis=-1)


@partial(jax.jit, static_argnames=("collision", "q_rows", "num_shards"))
def qr_shard_owner(
    idx: jax.Array, collision: int, q_rows: int, num_shards: int
) -> jax.Array:
    """Which row-shard ("bank group") owns the Q row of each logical index."""
    q, _ = qr_decompose(idx, collision)
    return row_owner(q, q_rows, num_shards)


def row_owner(row_idx: jax.Array, table_rows: int, num_shards: int) -> jax.Array:
    """Owner shard under contiguous ("blocked") row sharding."""
    rows_per_shard = -(-table_rows // num_shards)
    return (row_idx // rows_per_shard).astype(jnp.int32)


def local_row(row_idx: jax.Array, table_rows: int, num_shards: int) -> jax.Array:
    """Row offset within the owner shard under contiguous sharding."""
    rows_per_shard = -(-table_rows // num_shards)
    return (row_idx % rows_per_shard).astype(jnp.int32)


def padded_rows(table_rows: int, num_shards: int) -> int:
    """Total rows after padding so every shard holds the same count."""
    rows_per_shard = -(-table_rows // num_shards)
    return rows_per_shard * num_shards
