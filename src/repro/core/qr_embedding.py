"""Weight-sharing embedding modules (dense / hashed / quotient–remainder /
tensor-train — the TT path lives in ``repro.core.tt_embedding`` and is routed
through the same ``init`` / ``lookup`` / ``param_axes`` entry points here).

Functional style: ``init(key, cfg) -> params``, ``lookup(params, idx, cfg)``.
Params are plain dict pytrees; logical sharding axes are provided by
``param_axes(cfg)`` as a parallel tree of axis-name tuples, resolved to mesh
axes by ``repro.distributed.sharding``.

The QR path is the paper's target operator.  Reconstruction supports the three
ops of Shi et al. — ``add`` (default; associativity enables the two-level
partial-reduce that the PIM scheme exploits), ``mul`` and ``concat``.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import hashing

EmbeddingKind = Literal["dense", "hashed", "qr", "tt"]
Reconstruction = Literal["add", "mul", "concat"]

# Physical row counts are padded so mesh axes divide them (odd vocabs like
# whisper's 51,866 stay row-shardable). Lookups never touch pad rows; logits
# heads slice back to the logical vocab.
ROW_PAD = 128


def _pad_rows(rows: int) -> int:
    return -(-rows // ROW_PAD) * ROW_PAD


@dataclasses.dataclass(frozen=True)
class EmbeddingConfig:
    vocab: int
    dim: int
    kind: EmbeddingKind = "dense"
    collision: int = 64               # QR hash-collision value c
    reconstruction: Reconstruction = "add"
    hashed_rows: int = 0              # physical rows for kind="hashed" (0 -> vocab//collision)
    hashed_k: int = 2                 # k-ary reconstruction for hashing trick
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    # Fraction of Q-table rows replicated as the "hot" tier (paper's HBM tier).
    hot_fraction: float = 0.0
    # Tied-head mode: "factorized" (beyond-paper FLOP cut) or "materialize"
    # (paper-faithful: logits against the reconstructed logical table).
    head: str = "factorized"
    # TT-Rec knobs (kind="tt"): core rank and optional explicit factorizations
    # i -> (i1,i2,i3) / dim -> (d1,d2,d3); None = auto (asymmetric vocab split,
    # balanced dim split — see repro.core.tt_embedding).
    tt_rank: int = 16
    tt_vocab_factors: tuple[int, int, int] | None = None
    tt_dim_factors: tuple[int, int, int] | None = None
    # TT execution scheme: "jnp" (pure-jnp contraction) or "pallas" (fused
    # gather-contract kernel on TPU; the jnp oracle is the CPU fallback).
    tt_exec: str = "jnp"

    @property
    def qr_spec(self) -> hashing.QRSpec:
        return hashing.QRSpec(vocab=self.vocab, collision=self.collision, dim=self.dim)

    @property
    def tt_spec(self):
        from repro.core import tt_embedding

        return tt_embedding.spec_for(self)

    @property
    def physical_hashed_rows(self) -> int:
        return self.hashed_rows or max(1, self.vocab // self.collision)

    def param_count(self) -> int:
        if self.kind == "dense":
            return self.vocab * self.dim
        if self.kind == "hashed":
            return self.physical_hashed_rows * self.dim
        if self.kind == "tt":
            return self.tt_spec.param_count()
        spec = self.qr_spec
        if self.reconstruction == "concat":
            return (spec.q_rows + spec.r_rows) * (self.dim // 2)
        return (spec.q_rows + spec.r_rows) * self.dim


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(key: jax.Array, cfg: EmbeddingConfig) -> dict:
    if cfg.kind == "tt":
        from repro.core import tt_embedding

        return tt_embedding.init(key, cfg)
    scale = cfg.dim ** -0.5
    if cfg.kind == "dense":
        return {
            "table": jax.random.normal(
                key, (_pad_rows(cfg.vocab), cfg.dim), cfg.param_dtype
            ) * scale
        }
    if cfg.kind == "hashed":
        return {
            "table": jax.random.normal(
                key, (_pad_rows(cfg.physical_hashed_rows), cfg.dim), cfg.param_dtype
            ) * scale
        }
    spec = cfg.qr_spec
    kq, kr = jax.random.split(key)
    dim = cfg.dim // 2 if cfg.reconstruction == "concat" else cfg.dim
    q = jax.random.normal(kq, (_pad_rows(spec.q_rows), dim), cfg.param_dtype) * scale
    if cfg.reconstruction == "mul":
        # Multiplicative sharing: R initialized around 1 so early training is stable.
        r = 1.0 + 0.01 * jax.random.normal(kr, (spec.r_rows, dim), cfg.param_dtype)
    else:
        r = jax.random.normal(kr, (spec.r_rows, dim), cfg.param_dtype) * scale
    return {"q": q, "r": r}


def param_axes(cfg: EmbeddingConfig) -> dict:
    """Logical sharding axes per parameter leaf.

    ``qrow``/``vocab`` rows are the "bank-group" partition axis; ``rrow`` is the
    replicated LUT tier (never sharded — it lives in every chip's VMEM).
    """
    if cfg.kind in ("dense", "hashed"):
        return {"table": ("vocab", "embed")}
    if cfg.kind == "tt":
        from repro.core import tt_embedding

        return tt_embedding.param_axes(cfg)
    return {"q": ("qrow", "embed"), "r": ("rrow", "embed")}


# ---------------------------------------------------------------------------
# lookup (reference, pure-jnp; the Pallas fused kernel lives in repro.kernels)
# ---------------------------------------------------------------------------

def lookup(params: dict, idx: jax.Array, cfg: EmbeddingConfig) -> jax.Array:
    """Logical-row lookup ``idx -> (..., dim)`` with weight-sharing expansion."""
    if cfg.kind == "tt":
        from repro.core import tt_embedding

        return tt_embedding.lookup(params, idx, cfg)
    if cfg.kind == "dense":
        return params["table"].astype(cfg.compute_dtype)[idx]
    if cfg.kind == "hashed":
        table = params["table"].astype(cfg.compute_dtype)
        hs = hashing.k_ary_hash(idx, cfg.physical_hashed_rows, cfg.hashed_k)
        return table[hs].sum(axis=-2)
    q_idx, r_idx = hashing.qr_decompose(idx, cfg.collision)
    q = params["q"].astype(cfg.compute_dtype)[q_idx]
    r = params["r"].astype(cfg.compute_dtype)[r_idx]
    return reconstruct(q, r, cfg.reconstruction)


def reconstruct(q: jax.Array, r: jax.Array, op: Reconstruction) -> jax.Array:
    if op == "add":
        return q + r
    if op == "mul":
        return q * r
    if op == "concat":
        return jnp.concatenate([q, r], axis=-1)
    raise ValueError(f"unknown reconstruction {op!r}")


def materialize(params: dict, cfg: EmbeddingConfig) -> jax.Array:
    """Reconstruct the full logical table ``(vocab, dim)``.

    Used by the tied LM head (baseline path) and by tests as an oracle.
    """
    all_idx = jnp.arange(cfg.vocab, dtype=jnp.int32)
    return lookup(params, all_idx, cfg)


def logits_head(params: dict, x: jax.Array, cfg: EmbeddingConfig) -> jax.Array:
    """Tied-embedding LM head ``x @ E^T`` exploiting the QR factorization.

    Beyond-paper optimization: for ``add`` reconstruction,
    ``logits[v] = x·Q[v//c] + x·R[v%c]`` — so we matmul against the *physical*
    tables (q_rows + c columns instead of vocab) and expand by gather. This
    cuts head FLOPs by ~`collision`× while producing identical logits.
    """
    if cfg.kind == "dense":
        return (x @ params["table"].astype(cfg.compute_dtype).T)[..., : cfg.vocab]
    if cfg.kind == "hashed":
        table = params["table"].astype(cfg.compute_dtype)
        hs = hashing.k_ary_hash(
            jnp.arange(cfg.vocab, dtype=jnp.int32), cfg.physical_hashed_rows, cfg.hashed_k
        )  # (vocab, k)
        small = x @ table.T  # (..., rows)
        return small[..., hs].sum(axis=-1)
    if cfg.kind == "tt":
        # TT head: logits against the reconstructed table (paper-faithful; a
        # factorized TT head would chain three small matmuls — future work).
        return x @ materialize(params, cfg).T
    if cfg.reconstruction != "add" or cfg.head == "materialize":
        # mul/concat heads — and the paper-faithful mode — materialize the
        # logical (vocab, dim) table and matmul against it.
        return x @ materialize(params, cfg).T
    all_idx = jnp.arange(cfg.vocab, dtype=jnp.int32)
    q_idx, r_idx = hashing.qr_decompose(all_idx, cfg.collision)
    xq = x @ params["q"].astype(cfg.compute_dtype).T  # (..., q_rows)
    xr = x @ params["r"].astype(cfg.compute_dtype).T  # (..., c)
    return xq[..., q_idx] + xr[..., r_idx]
