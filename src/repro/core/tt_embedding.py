"""TT-Rec embedding tables — tensor-train weight sharing (Yin et al. '21).

The paper's second target algorithm (2.15x speedup case): a logical table
``(vocab, dim)`` is factorized into a 3-core tensor train.  Logical row ``i``
decomposes as ``i -> (i1, i2, i3)`` over vocab factors ``(v1, v2, v3)`` and is
reconstructed by the chained contraction

    W[i] = G1[i1] @ G2[i2] @ G3[i3]          # (d1,r) @ (r,d2,r) @ (r,d3)

reshaped to ``dim = d1*d2*d3``.  The factorization is deliberately
*asymmetric*: the outer factors ``v1, v3`` are tiny (~vocab**0.25) so the
outer cores fit in per-PIM SRAM (VMEM on TPU — the bg-PIM cache analogue),
while the middle core carries the bulk of the rows (~vocab**0.5) and is the
streamed / tiered / row-sharded "big table", exactly the role the Q table
plays on the QR path.  Intra-GnR locality is structural here: every lookup
touches G1 and G3, so their reuse within one bag is ~pooling-fold — the
locality the paper prefetches into the bg-PIM SRAM cache.

Functional style matching ``qr_embedding``: ``init(key, cfg) -> params``,
``lookup(params, idx, cfg) -> (..., dim)``, ``param_axes(cfg)``.  Params are
``{"g1", "g2", "g3"}``; every core is stored 2-D ``(rows, flat_width)`` so the
existing row-sharding / checkpoint / kernel machinery applies unchanged.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

# Same physical-row padding contract as qr_embedding: mesh axes divide rows.
ROW_PAD = 128


def _pad_rows(rows: int) -> int:
    return -(-rows // ROW_PAD) * ROW_PAD


# ---------------------------------------------------------------------------
# factorization
# ---------------------------------------------------------------------------

def dim_factors3(dim: int) -> tuple[int, int, int]:
    """Exact 3-way factorization of ``dim``, most balanced, largest in the
    middle (the middle core's width is quadratic in rank; giving it the big
    dim factor keeps the *outer* SRAM cores small)."""
    best: tuple[int, int, int] | None = None
    for a in range(1, dim + 1):
        if dim % a:
            continue
        rest = dim // a
        for b in range(a, rest + 1):
            if rest % b:
                continue
            c = rest // b
            if c < b:
                continue
            tri = (a, b, c)
            if best is None or sum(tri) < sum(best):
                best = tri
    assert best is not None
    lo, mid, hi = best
    return (mid, hi, lo)


def vocab_factors3(vocab: int) -> tuple[int, int, int]:
    """Covering factorization ``v1*v2*v3 >= vocab`` with SRAM-sized outer
    factors (~vocab**0.25) and the bulk in the middle core — the paper's
    small-subtable / big-subtable split for TT-Rec."""
    outer = max(2, math.ceil(vocab ** 0.25))
    mid = math.ceil(vocab / (outer * outer))
    return (outer, mid, outer)


@dataclasses.dataclass(frozen=True)
class TTSpec:
    """Static shape spec of a 3-core tensor-train factorization."""

    vocab: int
    dim: int
    rank: int
    vocab_factors: tuple[int, int, int]
    dim_factors: tuple[int, int, int]

    def __post_init__(self):
        v1, v2, v3 = self.vocab_factors
        d1, d2, d3 = self.dim_factors
        if v1 * v2 * v3 < self.vocab:
            raise ValueError(
                f"vocab factors {self.vocab_factors} cover only {v1 * v2 * v3} "
                f"< vocab {self.vocab}"
            )
        if d1 * d2 * d3 != self.dim:
            raise ValueError(
                f"dim factors {self.dim_factors} must multiply to dim {self.dim}"
            )

    # vocab / dim factor accessors
    @property
    def v1(self) -> int: return self.vocab_factors[0]
    @property
    def v2(self) -> int: return self.vocab_factors[1]
    @property
    def v3(self) -> int: return self.vocab_factors[2]
    @property
    def d1(self) -> int: return self.dim_factors[0]
    @property
    def d2(self) -> int: return self.dim_factors[1]
    @property
    def d3(self) -> int: return self.dim_factors[2]

    @property
    def padded_vocab(self) -> int:
        return self.v1 * self.v2 * self.v3

    # flat core widths (the last axis of each stored 2-D core)
    @property
    def g1_width(self) -> int: return self.d1 * self.rank
    @property
    def g2_width(self) -> int: return self.rank * self.d2 * self.rank
    @property
    def g3_width(self) -> int: return self.rank * self.d3

    @property
    def g2_rows_padded(self) -> int:
        return _pad_rows(self.v2)

    def param_count(self) -> int:
        """Physical elements (middle core padded, matching ``init`` leaves)."""
        return (
            self.v1 * self.g1_width
            + self.g2_rows_padded * self.g2_width
            + self.v3 * self.g3_width
        )

    @property
    def compression(self) -> float:
        return (self.vocab * self.dim) / self.param_count()

    def sram_bytes(self, bytes_per_elem: int = 4) -> int:
        """Footprint of the VMEM/SRAM-pinned outer cores (G1 + G3) — the thing
        the paper prefetches into the bg-PIM SRAM cache.  Must stay small
        (tens-to-hundreds of KB) for the pin to be legal."""
        return (self.v1 * self.g1_width + self.v3 * self.g3_width) * bytes_per_elem

    def streamed_bytes_per_lookup(self, bytes_per_elem: int = 4) -> int:
        """DRAM bytes per lookup once the outer cores are pinned: one G2 row."""
        return self.g2_width * bytes_per_elem


def spec_for(cfg) -> TTSpec:
    """Build the TTSpec from an ``EmbeddingConfig`` with kind='tt'."""
    return TTSpec(
        vocab=cfg.vocab,
        dim=cfg.dim,
        rank=cfg.tt_rank,
        vocab_factors=cfg.tt_vocab_factors or vocab_factors3(cfg.vocab),
        dim_factors=cfg.tt_dim_factors or dim_factors3(cfg.dim),
    )


# ---------------------------------------------------------------------------
# index factorization
# ---------------------------------------------------------------------------

def tt_decompose_factors(
    idx: jax.Array, v2: int, v3: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Mixed-radix split ``idx = (i1*v2 + i2)*v3 + i3`` (int32) — the single
    source of the TT index arithmetic (spec-less form for the packed layout)."""
    idx = idx.astype(jnp.int32)
    i3 = idx % v3
    rest = idx // v3
    i2 = rest % v2
    i1 = rest // v2
    return i1, i2, i3


def tt_decompose(idx: jax.Array, spec: TTSpec) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Logical index -> (i1, i2, i3) core-row indices (int32).

    Mixed-radix over ``(v1, v2, v3)`` — unique per logical row, the TT
    analogue of the QR complementary partition.
    """
    return tt_decompose_factors(idx, spec.v2, spec.v3)


# ---------------------------------------------------------------------------
# init / axes
# ---------------------------------------------------------------------------

def init(key: jax.Array, cfg) -> dict:
    """Three 2-D cores; middle-core rows padded for row-sharding.

    Scale: a reconstructed element is a sum of ``rank**2`` products of three
    core entries, so core std ``(dim * rank**2) ** (-1/6)`` gives the
    reconstructed table the usual ``dim**-0.5``-scale entries.
    """
    spec = spec_for(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    scale = (cfg.dim * spec.rank ** 2) ** (-1.0 / 6.0)
    return {
        "g1": jax.random.normal(k1, (spec.v1, spec.g1_width), cfg.param_dtype) * scale,
        "g2": jax.random.normal(
            k2, (spec.g2_rows_padded, spec.g2_width), cfg.param_dtype
        ) * scale,
        "g3": jax.random.normal(k3, (spec.v3, spec.g3_width), cfg.param_dtype) * scale,
    }


def param_axes(cfg) -> dict:
    """Middle core rows ride the "bank-group" partition axis (same logical
    name as the Q table so the existing rules tables map it); outer cores are
    the replicated SRAM tier (same logical name as the R LUT)."""
    return {
        "g1": ("rrow", "embed"),
        "g2": ("qrow", "embed"),
        "g3": ("rrow", "embed"),
    }


# ---------------------------------------------------------------------------
# lookup (reference, pure-jnp; the fused Pallas kernel is repro.kernels.tt_gather)
# ---------------------------------------------------------------------------

def contract_rows(
    a_rows: jax.Array, b_rows: jax.Array, c_rows: jax.Array, spec: TTSpec
) -> jax.Array:
    """Chained TT contraction on gathered flat core rows.

    a_rows: (..., d1*r); b_rows: (..., r*d2*r); c_rows: (..., r*d3)
    -> (..., d1*d2*d3) with index layout ``(d1-major, d2, d3-minor)``.
    Linear in ``b_rows`` — which is what legalizes the sharded partial-GnR:
    zeroed non-owned G2 rows contribute exactly zero.
    """
    lead = a_rows.shape[:-1]
    a = a_rows.reshape(*lead, spec.d1, spec.rank)
    b = b_rows.reshape(*lead, spec.rank, spec.d2, spec.rank)
    c = c_rows.reshape(*lead, spec.rank, spec.d3)
    out = jnp.einsum("...ap,...pbq,...qc->...abc", a, b, c)
    return out.reshape(*lead, spec.dim)


def lookup(params: dict, idx: jax.Array, cfg) -> jax.Array:
    """Logical-row lookup ``idx -> (..., dim)`` via the 3-core contraction.

    With ``cfg.tt_exec == "pallas"`` the serving/jit path runs the fused
    Pallas gather-contract kernel on TPU (one HBM DMA per lookup, outer cores
    VMEM-pinned); off-TPU the pure-jnp contraction below is the fallback.
    """
    spec = spec_for(cfg)
    i1, i2, i3 = tt_decompose(idx, spec)
    if getattr(cfg, "tt_exec", "jnp") == "pallas" and jax.default_backend() == "tpu":
        from repro.kernels import ops

        shape = i1.shape
        out = ops.tt_pooled_auto(
            params["g1"], params["g2"], params["g3"],
            i1.reshape(-1, 1), i2.reshape(-1, 1), i3.reshape(-1, 1),
            dims=(spec.d1, spec.d2, spec.d3, spec.rank), exec_mode="pallas",
        )
        return out.reshape(*shape, spec.dim).astype(cfg.compute_dtype)
    compute = cfg.compute_dtype
    a = params["g1"].astype(compute)[i1]
    b = params["g2"].astype(compute)[i2]
    c = params["g3"].astype(compute)[i3]
    return contract_rows(a, b, c, spec)


def materialize(params: dict, cfg) -> jax.Array:
    """Reconstruct the full logical table ``(vocab, dim)`` (test oracle /
    paper-faithful tied head)."""
    all_idx = jnp.arange(cfg.vocab, dtype=jnp.int32)
    return lookup(params, all_idx, cfg)
