"""Two-level sharded gather-and-reduce — the paper's PIM scheme on a TPU mesh.

Mapping (see DESIGN.md §2):

* bank-group PIM  -> one chip holding a contiguous **row shard** of the Q/dense
  table in HBM; it gathers + partially reduces only rows it owns ("local GnR");
* base-die PIM    -> a single ``psum`` over the `model` mesh axis combining the
  per-shard pooled partials (one vector per bag — never raw rows on the wire);
* SRAM LUT        -> the R table **replicated** on every chip; R contributions
  are served locally and spread across shards by bag position for load balance;
* HBM hot tier    -> the hottest Q rows replicated on every chip (TierPlan);
  on TPU the win is Zipf load-balance: skewed rows no longer hammer one
  shard's HBM, and no extra collective is introduced (hot partials ride the
  same psum).

Associativity of the ``add`` reconstruction is what legalizes all of this —
exactly the paper's argument for why Q rows and R rows may live anywhere.

All ``*_partial`` functions run **inside** ``shard_map`` and take local shards.
They are the kernel-level pieces the engine (``repro.engine``) composes —
every jitted GnR path is built through ``repro.engine``'s
plan/compile/execute API (the deprecated ``build_*`` / ``cached_bag_lookup``
shims were removed after their two-PR grace window; see CHANGES.md).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import hashing
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.distributed import jax_compat

# Q tables are padded so every potential model-axis size divides the row count.
ROW_PAD = 128


def padded_q_rows(cfg: EmbeddingConfig) -> int:
    """Padded rows of the row-sharded ("big") table: Q for the QR path, the
    middle core G2 for the TT path, the whole table otherwise."""
    if cfg.kind == "qr":
        rows = cfg.qr_spec.q_rows
    elif cfg.kind == "tt":
        rows = cfg.tt_spec.v2
    else:
        rows = cfg.vocab
    return -(-rows // ROW_PAD) * ROW_PAD


def pad_q_table(table: jax.Array, cfg: EmbeddingConfig) -> jax.Array:
    rows = padded_q_rows(cfg)
    if table.shape[0] == rows:
        return table
    pad = rows - table.shape[0]
    return jnp.pad(table, ((0, pad), (0, 0)))


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Static description of one table's tiered sharding."""

    cfg: EmbeddingConfig
    num_shards: int                      # size of the row-shard ("model") axis
    num_hot: int = 0                     # replicated-tier rows (0 = no hot tier)

    @property
    def q_rows_padded(self) -> int:
        return padded_q_rows(self.cfg)

    @property
    def rows_per_shard(self) -> int:
        return self.q_rows_padded // self.num_shards


# ---------------------------------------------------------------------------
# local ("bank-group") partials — run inside shard_map
# ---------------------------------------------------------------------------

def _owned_rows_gather(
    q_shard: jax.Array, q_idx: jax.Array, plan: ShardPlan, axis: str
) -> jax.Array:
    """Gather rows of ``q_idx`` owned by this shard; zeros elsewhere.

    q_shard: (rows_per_shard, dim) local. q_idx: (...,) global Q-row ids.
    """
    shard = jax.lax.axis_index(axis)
    local = q_idx - shard * plan.rows_per_shard
    owned = (local >= 0) & (local < plan.rows_per_shard)
    local = jnp.clip(local, 0, plan.rows_per_shard - 1)
    rows = q_shard[local]
    return rows * owned[..., None].astype(rows.dtype)


def qr_bag_partial(
    q_shard: jax.Array,
    r_full: jax.Array,
    idx: jax.Array,
    plan: ShardPlan,
    *,
    axis: str = "model",
    hot_table: jax.Array | None = None,
    hot_slot: jax.Array | None = None,
    weights: jax.Array | None = None,
) -> jax.Array:
    """Local pooled partial for one QR-add bag. idx: (..., pooling) -> (..., dim).

    Tier routing per index:
      hot   -> replicated table, spread across shards by bag position;
      cold  -> owner shard's local Q shard;
      R     -> replicated LUT, spread by bag position.
    The caller psums the result over ``axis`` (the base-die combine).
    """
    cfg = plan.cfg
    shard = jax.lax.axis_index(axis)
    nsh = plan.num_shards
    q_idx, r_idx = hashing.qr_decompose(idx, cfg.collision)
    pooling = idx.shape[-1]
    # Spread replicated-tier work across shards by position (paper: R tables
    # spread evenly across LUTs / load balance between bank groups).
    pos_mine = (jnp.arange(pooling, dtype=jnp.int32) % nsh) == shard

    compute = cfg.compute_dtype
    if hot_table is not None:
        slot = hot_slot[q_idx]                       # (..., pooling)
        is_hot = slot >= 0
        hot_rows = hot_table.astype(compute)[jnp.clip(slot, 0)]
        hot_rows = hot_rows * (is_hot & pos_mine)[..., None].astype(compute)
        cold_gather_idx = q_idx
        cold_rows = _owned_rows_gather(q_shard.astype(compute), cold_gather_idx, plan, axis)
        cold_rows = cold_rows * (~is_hot)[..., None].astype(compute)
        q_rows = hot_rows + cold_rows
    else:
        q_rows = _owned_rows_gather(q_shard.astype(compute), q_idx, plan, axis)

    r_rows = r_full.astype(compute)[r_idx] * pos_mine[..., None].astype(compute)
    rows = q_rows + r_rows
    if weights is not None:
        rows = rows * weights[..., None].astype(compute)
    return rows.sum(axis=-2)


def tt_bag_partial(
    g1_full: jax.Array,
    g2_shard: jax.Array,
    g3_full: jax.Array,
    idx: jax.Array,
    plan: ShardPlan,
    *,
    axis: str = "model",
    hot_table: jax.Array | None = None,
    hot_slot: jax.Array | None = None,
    weights: jax.Array | None = None,
) -> jax.Array:
    """Local pooled partial for one TT bag. idx: (..., pooling) -> (..., dim).

    Tier routing mirrors the QR path, applied to the *middle core*:
      hot  -> replicated hot-G2 rows, spread across shards by bag position;
      cold -> owner shard's local G2 row shard;
      G1/G3 -> duplicated whole on every shard (the bg-PIM SRAM pin), so the
               full chained contraction runs where the G2 row lives and only
               the pooled vector crosses the network (one psum by the caller).
    Correctness rests on the contraction being *linear in G2*: zeroed
    non-owned rows contribute exactly zero to the psum — the TT analogue of
    the QR add-associativity argument.
    """
    from repro.core import tt_embedding

    cfg = plan.cfg
    spec = cfg.tt_spec
    shard = jax.lax.axis_index(axis)
    nsh = plan.num_shards
    i1, i2, i3 = tt_embedding.tt_decompose(idx, spec)
    pooling = idx.shape[-1]
    pos_mine = (jnp.arange(pooling, dtype=jnp.int32) % nsh) == shard

    compute = cfg.compute_dtype
    if hot_table is not None:
        slot = hot_slot[i2]                          # (..., pooling)
        is_hot = slot >= 0
        hot_rows = hot_table.astype(compute)[jnp.clip(slot, 0)]
        hot_rows = hot_rows * (is_hot & pos_mine)[..., None].astype(compute)
        cold_rows = _owned_rows_gather(g2_shard.astype(compute), i2, plan, axis)
        cold_rows = cold_rows * (~is_hot)[..., None].astype(compute)
        g2_rows = hot_rows + cold_rows
    else:
        g2_rows = _owned_rows_gather(g2_shard.astype(compute), i2, plan, axis)

    rows = tt_embedding.contract_rows(
        g1_full.astype(compute)[i1], g2_rows, g3_full.astype(compute)[i3], spec
    )
    if weights is not None:
        rows = rows * weights[..., None].astype(compute)
    return rows.sum(axis=-2)


def dense_bag_partial(
    table_shard: jax.Array,
    idx: jax.Array,
    plan: ShardPlan,
    *,
    axis: str = "model",
    weights: jax.Array | None = None,
) -> jax.Array:
    """Local pooled partial for a dense (non-weight-sharing) bag."""
    rows = _owned_rows_gather(table_shard.astype(plan.cfg.compute_dtype), idx, plan, axis)
    if weights is not None:
        rows = rows * weights[..., None].astype(rows.dtype)
    return rows.sum(axis=-2)


def qr_token_partial(
    q_shard: jax.Array,
    r_full: jax.Array,
    idx: jax.Array,
    plan: ShardPlan,
    *,
    axis: str = "model",
    hot_table: jax.Array | None = None,
    hot_slot: jax.Array | None = None,
) -> jax.Array:
    """Per-token (no pooling) partial: idx (...,) -> (..., dim); psum over axis.

    R rows are replicated so only shard 0 contributes them (no position axis to
    spread over); hot rows likewise. The psum exists only for cold Q rows —
    with a hot tier covering all requests it degenerates to a local lookup.
    """
    cfg = plan.cfg
    shard = jax.lax.axis_index(axis)
    q_idx, r_idx = hashing.qr_decompose(idx, cfg.collision)
    compute = cfg.compute_dtype
    first = (shard == 0)

    if hot_table is not None:
        slot = hot_slot[q_idx]
        is_hot = slot >= 0
        hot_rows = hot_table.astype(compute)[jnp.clip(slot, 0)]
        hot_rows = hot_rows * (is_hot & first)[..., None].astype(compute)
        cold = _owned_rows_gather(q_shard.astype(compute), q_idx, plan, axis)
        cold = cold * (~is_hot)[..., None].astype(compute)
        q_rows = hot_rows + cold
    else:
        q_rows = _owned_rows_gather(q_shard.astype(compute), q_idx, plan, axis)

    r_rows = r_full.astype(compute)[r_idx] * jnp.asarray(first, compute)
    return q_rows + r_rows


# ---------------------------------------------------------------------------
# packed-table local GnR (the multi-table megakernel inside shard_map)
# ---------------------------------------------------------------------------

def packed_local_partial(
    tables: Sequence[dict],
    indices: jax.Array,
    bags: Sequence[BagConfig],
    plans: Sequence[ShardPlan],
    *,
    axis: str = "model",
    hot_tiers: Sequence[dict] | None = None,
    comm_free: Sequence[bool] | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Every table's local pooled partial in ONE megakernel dispatch.

    Runs inside ``shard_map``.  The per-table loop of ``*_bag_partial`` calls
    becomes index arithmetic: this shard's local big-subtable shards (plus the
    replicated hot-tier segments) are concatenated into one packed buffer with
    a trailing all-zero row, and every access is *routed* instead of masked —

      hot row & my bag position  -> its hot-segment slot,
      cold row owned here        -> the local-shard segment,
      anything else              -> the zero row (contributes nothing),

    so the single ``ops.packed_multi_pooled`` call (Pallas megakernel on TPU,
    packed jnp oracle elsewhere) computes partials whose psum over ``axis``
    counts every contribution exactly once — the same math as the per-table
    partials, minus T-1 dispatches.  R LUTs (QR) are packed and spread across
    shards by bag position; TT outer cores are packed replicated.

    ``comm_free[t]`` marks tables whose params are full local replicas (the
    duplication planner's communication kill): every access is served locally
    and their output columns must be EXCLUDED from the caller's psum.
    Returns (B, T, dim) partials in the compute dtype.
    """
    from repro.core import packed_tables, tt_embedding
    from repro.kernels import ops

    emb0 = bags[0].emb
    kind = emb0.kind
    num_t = len(bags)
    compute = emb0.compute_dtype
    shard = jax.lax.axis_index(axis)
    nsh = plans[0].num_shards
    pooling = indices.shape[-1]
    cf = tuple(bool(c) for c in (comm_free or [False] * num_t))
    cf_b = jnp.asarray(cf)[None, :, None]
    pos_mine = ((jnp.arange(pooling, dtype=jnp.int32) % nsh) == shard)[None, None, :]

    segs = [tables[t][packed_tables.big_key(kind)] for t in range(num_t)]
    parts = list(segs)
    hot_sizes: list[int] = []
    if hot_tiers is not None:
        hots = [hot_tiers[t]["hot_table"] for t in range(num_t)]
        hot_sizes = [int(h.shape[0]) for h in hots]
        parts += hots
    width = int(segs[0].shape[1])
    big = packed_tables.concat_with_zero(parts, compute)
    seg_sizes = [int(s.shape[0]) for s in segs]
    seg_off = np.cumsum([0] + seg_sizes)
    hot_off = seg_off[-1] + np.cumsum([0] + hot_sizes)
    zero_row = int(seg_off[-1] + sum(hot_sizes))
    seg_off_a = jnp.asarray(seg_off[:num_t], jnp.int32)[None, :, None]
    rps = jnp.asarray(
        [plans[t].rows_per_shard for t in range(num_t)], jnp.int32
    )[None, :, None]

    def route_big(big_idx: jax.Array) -> jax.Array:
        """Table-local big-subtable rows (B, T, K) -> packed stream rows."""
        local = big_idx - shard * rps
        owned = ((local >= 0) & (local < rps)) | cf_b
        local = jnp.where(cf_b, big_idx, local)          # replicas: global row
        stream = jnp.where(owned, seg_off_a + local, zero_row)
        if hot_tiers is not None:
            hot_slot = jnp.stack(
                [hot_tiers[t]["hot_slot"] for t in range(num_t)]
            )                                            # (T, big_rows)
            slot = hot_slot[jnp.arange(num_t)[None, :, None], big_idx]
            is_hot = slot >= 0
            hot_off_a = jnp.asarray(hot_off[:num_t], jnp.int32)[None, :, None]
            stream = jnp.where(
                is_hot, jnp.where(pos_mine, hot_off_a + slot, zero_row), stream
            )
        return stream

    miss = jnp.full(indices.shape, -1, jnp.int32)
    cache = jnp.zeros((1, width), compute)

    if kind == "qr":
        q_idx, r_idx = hashing.qr_decompose(indices, emb0.collision)
        r_segs = [tables[t]["r"] for t in range(num_t)]
        r_sizes = [int(r.shape[0]) for r in r_segs]
        r_off = np.cumsum([0] + r_sizes)
        r_packed = packed_tables.concat_with_zero(r_segs, compute)
        r_off_a = jnp.asarray(r_off[:num_t], jnp.int32)[None, :, None]
        # replicated LUT: spread across shards by bag position; comm-free
        # tables take every position (their column skips the psum)
        r_stream = jnp.where(
            pos_mine | cf_b, r_off_a + r_idx, int(r_off[-1])
        )
        out = ops.packed_multi_pooled(
            {"q": big, "r": r_packed, "cache": cache},
            {"q_idx": route_big(q_idx), "slot": miss, "r_idx": r_stream},
            kind="qr", interpret=interpret,
        )
    elif kind == "tt":
        spec = emb0.tt_spec
        i1, i2, i3 = tt_embedding.tt_decompose(indices, spec)
        t_ids = jnp.arange(num_t, dtype=jnp.int32)[None, :, None]
        g1 = jnp.concatenate(
            [tables[t]["g1"].astype(compute) for t in range(num_t)], axis=0
        )
        g3 = jnp.concatenate(
            [tables[t]["g3"].astype(compute) for t in range(num_t)], axis=0
        )
        out = ops.packed_multi_pooled(
            {"g1": g1, "g2": big, "g3": g3, "cache": cache},
            {
                "i1": i1 + t_ids * spec.v1,
                "i2": route_big(i2),
                "i3": i3 + t_ids * spec.v3,
                "slot": miss,
            },
            kind="tt", dims=(spec.d1, spec.d2, spec.d3, spec.rank),
            interpret=interpret,
        )
    else:
        out = ops.packed_multi_pooled(
            {"table": big, "cache": cache},
            {"idx": route_big(indices), "slot": miss},
            kind="dense", interpret=interpret,
        )

    scale = packed_tables.combiner_scale(bags, out.dtype)
    return (out * scale[None, :, None]).astype(compute)


def make_dup_hot_tiers(tables: Sequence[dict], bags: Sequence[BagConfig], dup_plan):
    """Hot-tier arrays per table from a DuplicationPlan.

    Returns one ``{"hot_table", "hot_slot"}`` dict per bag (uniform pytree so
    shard_map in_specs stay static); tables with nothing to replicate get a
    1-row dummy whose slot map never matches.
    """
    tiers = []
    for params, bag, tp in zip(tables, bags, dup_plan.tables):
        big = params.get("q", params.get("g2", params.get("table")))
        rows = tp.hot_plan.hot_slot.size
        if tp.comm_free or tp.hot_plan.num_hot == 0:
            tiers.append({
                "hot_table": jnp.zeros((1, big.shape[1]), big.dtype),
                "hot_slot": jnp.full((rows,), -1, jnp.int32),
            })
        else:
            tiers.append({
                "hot_table": big[jnp.asarray(tp.hot_plan.hot_rows, jnp.int32)],
                "hot_slot": jnp.asarray(tp.hot_plan.hot_slot, jnp.int32),
            })
    return tiers


# ---------------------------------------------------------------------------
# global wrappers
# ---------------------------------------------------------------------------

def shard_qr_params(
    params: dict, cfg: EmbeddingConfig, mesh: Mesh, *, row_axis: str = "model"
) -> dict:
    """Device-put QR params with the tiered layout's shardings."""
    out = {}
    if "q" in params:
        out["q"] = jax.device_put(
            pad_q_table(params["q"], cfg), NamedSharding(mesh, P(row_axis, None))
        )
        out["r"] = jax.device_put(params["r"], NamedSharding(mesh, P()))  # LUT tier
    elif "g2" in params:
        # TT: middle core row-sharded, outer cores duplicated (SRAM tier)
        out["g2"] = jax.device_put(
            pad_q_table(params["g2"], cfg), NamedSharding(mesh, P(row_axis, None))
        )
        out["g1"] = jax.device_put(params["g1"], NamedSharding(mesh, P()))
        out["g3"] = jax.device_put(params["g3"], NamedSharding(mesh, P()))
    else:
        out["table"] = jax.device_put(
            pad_q_table(params["table"], cfg), NamedSharding(mesh, P(row_axis, None))
        )
    return out


def build_token_embed(
    mesh: Mesh,
    cfg: EmbeddingConfig,
    *,
    batch_axis: str = "data",
    row_axis: str = "model",
    hot: bool = False,
):
    """Jitted token-embedding lookup (B, S) -> (B, S, dim), two-level scheme."""
    nsh = mesh.shape[row_axis]
    plan = ShardPlan(cfg, nsh)

    def local_fn(params, idx, tier):
        if cfg.kind == "qr":
            part = qr_token_partial(
                params["q"], params["r"], idx, plan, axis=row_axis,
                hot_table=None if tier is None else tier["hot_table"],
                hot_slot=None if tier is None else tier["hot_slot"],
            )
        else:
            part = _owned_rows_gather(
                params["table"].astype(cfg.compute_dtype), idx, plan, axis=row_axis
            )
        return jax.lax.psum(part, row_axis)

    tspec = {"q": P(row_axis, None), "r": P()} if cfg.kind == "qr" else {
        "table": P(row_axis, None)
    }
    in_specs = (
        tspec,
        P(batch_axis, None),
        None if not hot else {"hot_table": P(), "hot_slot": P()},
    )

    @jax.jit
    def fn(params, idx, tier=None):
        return jax_compat.shard_map(
            local_fn, mesh=mesh, in_specs=in_specs,
            out_specs=P(batch_axis, None, None), check_vma=False,
        )(params, idx, tier)

    return fn


def token_embed_inline(params: dict, idx: jax.Array, cfg: EmbeddingConfig,
                       *, row_axis: str = "model") -> jax.Array:
    """Two-level GnR token embedding usable INSIDE a jitted model body.

    Reads the active mesh/rules from ``repro.distributed.sharding`` (set by
    the launcher's ``use_rules``); falls back to the plain lookup when no mesh
    is active or the row axis is absent. Differentiable: the backward pass is
    the transposed scatter-add into the local Q shard + psum, exactly the
    partial-reduce scheme in reverse.

    This is the paper's execution scheme as a drop-in for the GSPMD gather:
    the Q row is fetched only on its owner shard ("bank-group" locality), the
    replicated R add happens on one shard, and a single pooled psum combines —
    XLA's alternative would all-gather table rows to the data shards.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as SH

    mesh = SH.current_mesh()
    if mesh is None or row_axis not in mesh.shape or cfg.kind != "qr":
        from repro.core import qr_embedding

        return qr_embedding.lookup(params, idx, cfg)

    nsh = mesh.shape[row_axis]
    plan = ShardPlan(cfg, nsh)
    batch_spec = SH.spec_for(("batch",))[0]

    def local_fn(q_shard, r_full, idx_l):
        part = qr_token_partial(q_shard, r_full, idx_l, plan, axis=row_axis)
        return jax.lax.psum(part, row_axis)

    return jax_compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(row_axis, None), P(), P(batch_spec, None)),
        out_specs=P(batch_spec, None, None),
        check_vma=False,
    )(params["q"], params["r"], idx)
