"""Loop-aware analysis of post-SPMD HLO text: FLOPs, HBM bytes, collectives.

Why not ``compiled.cost_analysis()``: XLA's aggregate counts each while-loop
*body once*, but a scan-over-layers model executes the body ``num_layers``
times — the aggregate under-counts a 94-layer model by ~94x.  This analyzer
parses the optimized (post-partitioning, per-device) HLO text, reads each
loop's ``known_trip_count`` from ``backend_config``, and propagates costs
through the call graph, so the totals reflect what one device actually
executes per step.

Cost model (documented in EXPERIMENTS.md §Roofline methodology):

* FLOPs — 2 x prod(result dims) x contracted size, summed over every ``dot``
  (including dots inside fusion bodies), x loop multipliers.  Elementwise
  FLOPs are ignored (<1% for these models, and the MXU roofline is what the
  compute term measures).
* HBM bytes — per instruction: result + operand bytes, for ops that move
  data (fusions, dots, copies, converts, reduces, collectives, ...).
  Gather/dynamic-slice traffic counts *touched rows* (2 x result + indices),
  not the whole table operand — critical for embedding workloads; a fusion
  parameter consumed only by a gather inside the fusion body gets the same
  discount.  ``broadcast``/``iota``/``reshape``/``bitcast`` and tuple
  plumbing are free (fused on TPU).
* Collectives — per kind: op count, summed operand bytes (the spec's
  ``collective_bytes``), and ring-algorithm effective per-chip wire bytes
  using the parsed replica-group size g:
      all-reduce 2B(g-1)/g | all-gather B(g-1) | reduce-scatter B(g-1)/g |
      all-to-all B(g-1)/g  | collective-permute B.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}
_TYPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\](?:\{[^}]*\})?")
_NAME_RE = re.compile(r"%[\w.\-]+")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_DIMS_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Ops that are free (layout/tuple plumbing, or fused away on TPU).
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "broadcast",
    "reshape", "opt-barrier", "custom-call",
}


def _parse_dims(s: str) -> tuple[int, ...]:
    return tuple(int(d) for d in s.split(",") if d) if s else ()


def _parse_result_types(text: str) -> tuple[list[tuple[str, tuple[int, ...]]], int]:
    """Parse leading type or tuple-of-types; return (list of (dtype, dims), end)."""
    text = text.lstrip()
    if text.startswith("("):
        out = []
        pos = 1
        while pos < len(text) and text[pos] != ")":
            m = _TYPE_RE.match(text, pos)
            if not m:
                # skip /*index=N*/ comments and separators
                nxt = pos + 1
                while nxt < len(text) and text[nxt] not in ")%bfsupt":
                    nxt += 1
                if text[pos] in ", /*0123456789=":
                    pos += 1
                    continue
                m2 = _TYPE_RE.search(text, pos)
                if not m2 or m2.start() > text.find(")", pos):
                    break
                m = m2
            out.append((m.group(1), _parse_dims(m.group(2))))
            pos = m.end()
        end = text.find(")", pos) + 1
        return out, end
    m = _TYPE_RE.match(text)
    if not m:
        return [], 0
    return [(m.group(1), _parse_dims(m.group(2)))], m.end()


def _types_bytes(types: list[tuple[str, tuple[int, ...]]]) -> int:
    total = 0
    for dt, dims in types:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    types: list                     # [(dtype, dims), ...]
    operands: list[str]
    attrs: str
    opregion: str = ""              # raw text inside the op's parens
    is_root: bool = False

    @property
    def bytes(self) -> int:
        return _types_bytes(self.types)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: dict
    root: str = ""


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for raw in text.splitlines():
        m = _COMP_START.match(raw)
        if m:
            current = Computation(m.group(1), {})
            comps[current.name] = current
            continue
        if raw.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        mi = _INSTR_RE.match(raw)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        is_root = raw.lstrip().startswith("ROOT ")
        types, end = _parse_result_types(rhs)
        rest = rhs[end:].lstrip()
        mo = re.match(r"([\w\-]+)", rest)
        if not mo:
            continue
        opcode = mo.group(1)
        # operand region: balanced parens after opcode
        p0 = rest.find("(", mo.end())
        operands: list[str] = []
        attrs = ""
        opregion = ""
        if p0 >= 0:
            depth, i = 0, p0
            while i < len(rest):
                if rest[i] == "(":
                    depth += 1
                elif rest[i] == ")":
                    depth -= 1
                    if depth == 0:
                        break
                i += 1
            opregion = rest[p0: i + 1]
            operands = _NAME_RE.findall(opregion)
            attrs = rest[i + 1:]
        current.instrs[name] = Instr(
            name, opcode, types, operands, attrs, opregion, is_root
        )
        if is_root:
            current.root = name
    return comps


def _group_size(attrs: str, default: int = 1) -> int:
    m = _GROUPS_V2_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return default


_RING_FACTOR = {
    "all-reduce": lambda b, g: 2.0 * b * (g - 1) / max(g, 1),
    "all-gather": lambda b, g: 1.0 * b * (g - 1),
    "reduce-scatter": lambda b, g: 1.0 * b * (g - 1) / max(g, 1),
    "all-to-all": lambda b, g: 1.0 * b * (g - 1) / max(g, 1),
    "collective-permute": lambda b, g: 1.0 * b,
}


def _zero_cost() -> dict:
    return {
        "flops": 0.0,
        "bytes": 0.0,
        "coll_bytes": {k: 0.0 for k in COLLECTIVE_KINDS},
        "coll_wire": {k: 0.0 for k in COLLECTIVE_KINDS},
        "coll_counts": {k: 0 for k in COLLECTIVE_KINDS},
        "dots": {},                 # "MxNxK sig" -> flops (for perf logs)
        "unknown_loops": 0,
    }


def _acc(dst: dict, src: dict, mult: float = 1.0) -> None:
    dst["flops"] += src["flops"] * mult
    dst["bytes"] += src["bytes"] * mult
    for k in COLLECTIVE_KINDS:
        dst["coll_bytes"][k] += src["coll_bytes"][k] * mult
        dst["coll_wire"][k] += src["coll_wire"][k] * mult
        dst["coll_counts"][k] += int(src["coll_counts"][k] * mult)
    for sig, f in src["dots"].items():
        dst["dots"][sig] = dst["dots"].get(sig, 0.0) + f * mult
    dst["unknown_loops"] += src["unknown_loops"]


def _operand_bytes(comp: Computation, names: list[str]) -> int:
    return sum(comp.instrs[n.lstrip("%")].bytes for n in names if n.lstrip("%") in comp.instrs)


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems = 1
    for _, dims in ins.types:
        for d in dims:
            out_elems *= d
    contract = 1
    m = _LHS_CONTRACT_RE.search(ins.attrs)
    lhs = ins.operands[0].lstrip("%") if ins.operands else None
    if m and lhs and lhs in comp.instrs:
        ldims = comp.instrs[lhs].types[0][1]
        for di in _parse_dims(m.group(1)):
            if di < len(ldims):
                contract *= ldims[di]
    return 2.0 * out_elems * contract


def _param_indices(body: Computation) -> dict[str, int]:
    out = {}
    for ins in body.instrs.values():
        if ins.opcode == "parameter":
            m = re.search(r"\((\d+)\)", ins.opregion or "")
            if m:
                out[ins.name] = int(m.group(1))
    return out


def _gather_param_indices(comps: dict, fusion_body: str) -> dict[int, int]:
    """Fusion params consumed ONLY as the gathered operand of gather/d-slice,
    mapped to the touched-bytes bound (2 x the slice/gather results reading
    them — read once, conservatively doubled for write-allocate)."""
    body = comps.get(fusion_body)
    if body is None:
        return {}
    param_idx = _param_indices(body)
    uses: dict[str, list[tuple[str, int, int]]] = {}
    for ins in body.instrs.values():
        for j, op in enumerate(ins.operands):
            uses.setdefault(op.lstrip("%"), []).append((ins.opcode, j, ins.bytes))
    out: dict[int, int] = {}
    for pname, idx in param_idx.items():
        ulist = uses.get(pname, [])
        if ulist and all(
            (op in ("gather", "dynamic-slice") and j == 0) for op, j, _ in ulist
        ):
            out[idx] = 2 * sum(b for _, _, b in ulist)
    return out


def _dus_root_info(comps: dict, fusion_body: str) -> tuple[int, int] | None:
    """(aliased buffer param index, update bytes) for fusions whose root is a
    dynamic-update-slice into a parameter (loop-carried stacked buffers).

    Such fusions write only the update region in place; counting the whole
    buffer as read+written would overstate traffic by ~num_layers x.
    """
    body = comps.get(fusion_body)
    if body is None or not body.root:
        return None
    ins = body.instrs.get(body.root)
    # allow a trailing bitcast/convert chain above the DUS
    for _ in range(3):
        if ins is None:
            return None
        if ins.opcode == "dynamic-update-slice":
            break
        if ins.opcode in ("bitcast", "convert", "copy") and ins.operands:
            ins = body.instrs.get(ins.operands[0].lstrip("%"))
        else:
            return None
    if ins is None or ins.opcode != "dynamic-update-slice":
        return None
    param_idx = _param_indices(body)
    # resolve operand 0 (the buffer) through bitcast/convert to a parameter
    # (the convert would not exist on TPU — bf16 buffers DUS in place)
    buf = ins.operands[0].lstrip("%")
    for _ in range(4):
        bi = body.instrs.get(buf)
        if bi is None:
            return None
        if bi.opcode == "parameter":
            break
        if bi.opcode in ("bitcast", "copy", "convert") and bi.operands:
            buf = bi.operands[0].lstrip("%")
        else:
            return None
    if buf not in param_idx:
        return None
    upd = body.instrs.get(ins.operands[1].lstrip("%")) if len(ins.operands) > 1 else None
    upd_bytes = upd.bytes if upd is not None else 0
    return param_idx[buf], upd_bytes


def _comp_multipliers(comps: dict, entry: str) -> tuple[dict, int]:
    """Dynamic execution count per computation (loop trips propagate down)."""
    mult: dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    unknown = 0
    # topo order: callees appear before callers in HLO text, so walk reversed
    order = list(comps)
    order.reverse()                       # entry (last) first
    # safer: iterate until fixpoint (call graph is a DAG; depth is small)
    for _ in range(64):
        changed = False
        for name, comp in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs.values():
                if ins.opcode == "while":
                    mt = _TRIP_RE.search(ins.attrs)
                    trip = int(mt.group(1)) if mt else 1
                    if not mt:
                        unknown += 1
                    mb = re.search(r"body=%([\w.\-]+)", ins.attrs)
                    if mb and mb.group(1) in comps:
                        want = m * trip
                        if mult[mb.group(1)] < want:
                            mult[mb.group(1)] = want
                            changed = True
                elif ins.opcode == "conditional":
                    for b in re.findall(r"%([\w.\-]+)", ins.attrs):
                        if b in comps and mult[b] < m:
                            mult[b] = m
                            changed = True
                elif ins.opcode == "call":
                    mc = re.search(r"to_apply=%([\w.\-]+)", ins.attrs)
                    if mc and mc.group(1) in comps and mult[mc.group(1)] < m:
                        mult[mc.group(1)] = m
                        changed = True
        if not changed:
            break
    return mult, unknown


def analyze(text: str, *, entry: str | None = None, top_k: int = 12) -> dict:
    comps = parse_hlo(text)
    # entry = computation named main* (jax convention) or the last one
    if entry is None:
        cands = [n for n in comps if n.startswith("main")]
        entry = cands[0] if cands else list(comps)[-1]

    mult, unknown = _comp_multipliers(comps, entry)
    total = _zero_cost()
    total["unknown_loops"] = unknown
    traffic: list[tuple[float, str, str]] = []   # (bytes, opcode, where)
    bytes_by_op: dict[str, float] = {}

    def add_bytes(b: float, op: str, where: str) -> None:
        total["bytes"] += b
        bytes_by_op[op] = bytes_by_op.get(op, 0.0) + b
        traffic.append((b, op, where))

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs.values():
            op = ins.opcode
            if op in _FREE_OPS or op in ("while", "conditional", "call"):
                continue
            where = f"{cname}/{ins.name}"
            if op == "fusion":
                mc = re.search(r"calls=%([\w.\-]+)", ins.attrs)
                body = mc.group(1) if mc else None
                gparams = _gather_param_indices(comps, body) if body else {}
                dus = _dus_root_info(comps, body) if body else None
                if dus is not None:
                    buf_idx, upd_bytes = dus
                    b = 2 * upd_bytes                 # in-place update traffic
                else:
                    buf_idx = -1
                    b = ins.bytes
                for j, opn in enumerate(ins.operands):
                    if j == buf_idx:
                        continue                      # aliased buffer, not read
                    ob = _operand_bytes(comp, [opn])
                    if j in gparams:
                        ob = min(ob, gparams[j])      # touched-rows model
                    b += ob
                add_bytes(b * m, "fusion", where)
                # dots fused into the body still cost MXU flops
                if body and body in comps and mult.get(body, 0.0) == 0.0:
                    for bi in comps[body].instrs.values():
                        if bi.opcode == "dot":
                            f = _dot_flops(comps[body], bi) * m
                            total["flops"] += f
                            sig = "x".join(str(d) for d in bi.types[0][1])
                            total["dots"][sig] = total["dots"].get(sig, 0.0) + f
                continue
            if op == "dot":
                f = _dot_flops(comp, ins) * m
                total["flops"] += f
                sig = "x".join(str(d) for d in ins.types[0][1]) or "scalar"
                total["dots"][sig] = total["dots"].get(sig, 0.0) + f
                add_bytes((ins.bytes + _operand_bytes(comp, ins.operands)) * m,
                          "dot", where)
                continue
            base = op.removesuffix("-start")
            if base in COLLECTIVE_KINDS:
                if op.endswith("-done"):
                    continue
                ob = _operand_bytes(comp, ins.operands) or ins.bytes
                g = _group_size(ins.attrs)
                total["coll_bytes"][base] += ob * m
                total["coll_wire"][base] += _RING_FACTOR[base](ob, g) * m
                total["coll_counts"][base] += int(m)
                add_bytes((ins.bytes + ob) * m, base, where)
                continue
            if op in ("gather", "dynamic-slice"):
                idx_b = _operand_bytes(comp, ins.operands[1:])
                add_bytes((2 * ins.bytes + idx_b) * m, op, where)
                continue
            if op == "dynamic-update-slice":
                upd = _operand_bytes(comp, ins.operands[1:2])
                add_bytes((2 * upd + _operand_bytes(comp, ins.operands[2:])) * m,
                          op, where)
                continue
            if op.startswith("scatter"):
                upd = _operand_bytes(comp, ins.operands[2:3])
                add_bytes(
                    (3 * upd + _operand_bytes(comp, ins.operands[1:2])) * m, op, where
                )
                continue
            # default: real data movement (copy/convert/reduce/select/...)
            add_bytes((ins.bytes + _operand_bytes(comp, ins.operands)) * m, op, where)

    traffic.sort(key=lambda t: -t[0])
    top_dots = sorted(total["dots"].items(), key=lambda kv: -kv[1])[:top_k]
    return {
        "flops": total["flops"],
        "bytes": total["bytes"],
        "coll_bytes": total["coll_bytes"],
        "coll_wire": total["coll_wire"],
        "coll_counts": total["coll_counts"],
        "coll_bytes_total": sum(total["coll_bytes"].values()),
        "coll_wire_total": sum(total["coll_wire"].values()),
        "top_dots": [{"shape": s, "flops": f} for s, f in top_dots],
        "bytes_by_op": {
            k: v for k, v in sorted(bytes_by_op.items(), key=lambda kv: -kv[1])
        },
        "top_traffic": [
            {"bytes": b, "op": o, "where": w} for b, o, w in traffic[:top_k]
        ],
        "unknown_loops": total["unknown_loops"],
        "entry": entry,
    }
