"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so that
importing this module touches no jax device state — smoke tests see 1 CPU
device; only the dry-run process sets ``--xla_force_host_platform_device_count``.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    from repro.distributed import jax_compat

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax_compat.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests, degraded/elastic shapes)."""
    from repro.distributed import jax_compat

    return jax_compat.make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link
ICI_LINKS = 4                   # 2D torus: 4 links/chip usable
DCN_BW = 25e9                   # cross-pod (pod axis) bytes/s per host NIC
HBM_PER_CHIP = 16 * 2**30       # 16 GiB
