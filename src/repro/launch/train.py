"""End-to-end training driver: config -> mesh -> train loop, fault-tolerant.

Production behaviors implemented and exercised here (CPU smoke scale):

* auto-resume from the newest atomic checkpoint (params + optimizer + data
  cursor) — `--ckpt-dir`;
* preemption safety: SIGTERM/SIGINT checkpoints synchronously then exits 0
  (the behavior a k8s/Borg eviction expects);
* deterministic data: batch = f(seed, step) so restarts replay identically;
* optional elastic restart onto a different mesh shape (`--mesh-shape`),
  using the mesh-agnostic checkpoint format.

Usage (CPU smoke):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
    PYTHONPATH=src python -m repro.launch.train --arch dlrm-qr --smoke \
        --steps 10 --batch 16   # the paper's model; GnR via repro.engine
"""

from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax

from repro.checkpoint import checkpointer as ckpt
from repro.configs import registry
from repro.data import synthetic
from repro.distributed import sharding as SH
from repro.launch import mesh as mesh_mod
from repro.train import optimizer as opt_mod
from repro.train.train_step import make_dlrm_loss, make_train_step


def _build_model(args):
    """-> (cfg, params, axes, loss_fn0, make_batch) for LM or DLRM archs.

    DLRM archs (``--arch dlrm-qr`` etc.) train the paper's model: the
    embedding layer routes through the engine front door (``repro.engine``,
    via ``dlrm.forward_dlrm``) and batches carry planted CTR structure so the
    loss is learnable.
    """
    if args.arch.startswith("dlrm"):
        from repro import engine as engine_mod
        from repro.engine import EngineSpec
        from repro.models import dlrm as dlrm_mod

        name = f"{args.arch}-smoke" if args.smoke else args.arch
        cfg = registry.get_dlrm(name)
        if args.embedding:
            cfg = dataclasses.replace(cfg, embedding_kind=args.embedding)
        params, axes = dlrm_mod.init_dlrm(jax.random.PRNGKey(args.seed), cfg)
        loss_fn0 = make_dlrm_loss(cfg)
        truth = synthetic.dlrm_truth(cfg)
        eng = engine_mod.engine_for(EngineSpec.from_dlrm(cfg))
        print(f"[engine] {cfg.name}: {eng.summary()}")

        def make_batch(b, s, **kw):
            return synthetic.dlrm_planted_batch(cfg, truth, b, **kw)

        return cfg, params, axes, loss_fn0, make_batch

    binding = registry.get(args.arch)
    cfg = binding.smoke if args.smoke else binding.config
    if args.embedding:
        cfg = cfg.replace(embedding_kind=args.embedding)
    init = registry.init_fn(binding)
    params, axes = init(jax.random.PRNGKey(args.seed), cfg)
    loss_fn0 = registry.train_loss_fn(binding, cfg)
    make_batch = registry.make_batch_fn(binding, cfg)
    return cfg, params, axes, loss_fn0, make_batch


def build(args):
    cfg, params, axes, loss_fn0, make_batch = _build_model(args)
    opt_state = opt_mod.init(params)

    mesh = None
    if args.mesh_shape:
        shape = tuple(int(x) for x in args.mesh_shape.split(","))
        names = ("data", "model")[: len(shape)] if len(shape) <= 2 else (
            "pod", "data", "model"
        )
        mesh = mesh_mod.make_mesh(shape, names)
        rules = dict(SH.DEFAULT_RULES)
        pshard = SH.shardings_for_tree(mesh, params, axes, SH.PARAM_RULES)
        params = jax.device_put(params, pshard)
        opt_state = {
            "mu": jax.device_put(opt_state["mu"], pshard),
            "nu": jax.device_put(opt_state["nu"], pshard),
            "step": opt_state["step"],
        }
    else:
        rules = None

    def loss_fn(p, batch):
        with SH.use_rules(mesh, rules):
            return loss_fn0(p, batch)

    opt_cfg = opt_mod.OptConfig(
        lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
        total_steps=args.steps,
    )
    step_fn = jax.jit(
        make_train_step(loss_fn, opt_cfg, microbatches=args.microbatches)
    )
    return cfg, params, opt_state, step_fn, make_batch


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--embedding", default=None,
                    choices=[None, "dense", "hashed", "qr", "tt"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--mesh-shape", default=None, help="e.g. 2,4 for (data,model)")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg, params, opt_state, step_fn, make_batch = build(args)
    pipe = synthetic.Pipeline(
        make_batch=lambda seed, step: make_batch(args.batch, args.seq, seed=seed, step=step),
        seed=args.seed,
    )

    start = 0
    if args.ckpt_dir:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state = {"params": params, "opt": opt_state}
            state, extra = ckpt.restore(args.ckpt_dir, latest, state)
            params, opt_state = state["params"], state["opt"]
            pipe.seek(extra["pipeline"])
            start = latest
            print(f"[resume] step {start} from {args.ckpt_dir}")

    stop = {"now": False}

    def _graceful(signum, frame):
        stop["now"] = True

    signal.signal(signal.SIGTERM, _graceful)
    signal.signal(signal.SIGINT, _graceful)

    def save(step):
        if args.ckpt_dir:
            ckpt.save(
                args.ckpt_dir, step, {"params": params, "opt": opt_state},
                extra={"pipeline": pipe.state(), "arch": args.arch},
            )
            ckpt.prune(args.ckpt_dir, keep=3)

    t_last = time.time()
    for step in range(start, args.steps):
        batch = next(pipe)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == start:
            dt = time.time() - t_last
            t_last = time.time()
            print(
                f"step {step + 1:5d} loss {float(metrics['loss']):.4f} "
                f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.3f} "
                f"({dt:.2f}s)", flush=True,
            )
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(step + 1)
        if stop["now"]:
            print(f"[preempt] checkpointing at step {step + 1} and exiting")
            save(step + 1)
            return 0
    save(args.steps)
    print("done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
