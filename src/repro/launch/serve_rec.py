"""Batched DLRM recommendation serving — the ProactivePIM pipeline end-to-end.

Steady-state loop over a queued request stream:

1. **offline** (once): profile per-table traces, run the intra-GnR analyzer,
   waterfill the global cache-slot budget across tables by prefetch value
   (``cache_slot_policy="adaptive"``), and let the duplication planner decide
   which subtables are replicated per shard vs row-sharded — comm-free tables
   skip the cross-shard combine entirely.  All tables are packed into ONE
   row-major buffer (``repro.core.packed_tables``) with per-table row / LUT /
   cache-slot offsets;
2. **per batch** (the serving loop): while batch ``t`` executes, the prefetch
   hook stages batch ``t+1``'s highest-value big-table rows into the packed
   SRAM-cache model and batch ``t+1``'s packed gather is dispatched — the
   double buffer.  A batch's whole embedding layer is ONE
   ``packed_gather`` megakernel dispatch (hits route to the VMEM cache block,
   misses stream HBM rows) instead of one kernel per table, and the host only
   blocks at the tail of the stream (``--mode sequential`` keeps the
   one-batch-at-a-time baseline for parity checks and speedup measurement).

Usage (CPU smoke):
    PYTHONPATH=src python -m repro.launch.serve_rec --arch dlrm-qr --smoke
    PYTHONPATH=src python -m repro.launch.serve_rec --arch dlrm-tt --tiny --json q.json
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import duplication, intra_gnr
from repro.cache.sram_cache import PrefetchScheduler
from repro.configs import registry
from repro.core import packed_tables, placement
from repro.data import synthetic
from repro.models import dlrm


def big_subtable(emb) -> tuple[str, int]:
    """(name, rows) of the streamed/tiered big subtable the cache covers."""
    if emb.kind == "qr":
        return "q", emb.qr_spec.q_rows
    if emb.kind == "tt":
        return "g2", emb.tt_spec.v2
    rows = emb.physical_hashed_rows if emb.kind == "hashed" else emb.vocab
    return "table", rows


def big_rows(idx: np.ndarray, emb) -> np.ndarray:
    """Map a logical-index batch (bags, pooling) onto big-subtable rows (the
    cached stream), via the analyzer's single-sourced decomposition."""
    name, _rows = big_subtable(emb)
    trace, _r, _b = intra_gnr.subtable_traces(idx, emb)[name]
    return trace


@dataclasses.dataclass
class ServeState:
    """The offline pass's output, built once per session and reusable across
    pipeline runs (schedulers are stateful, so ``run_pipeline`` constructs a
    fresh set from ``slot_budgets`` + ``values`` per run)."""

    bags: list
    plan: duplication.DuplicationPlan
    locs: list[dict]                     # per-table intra-GnR analyses
    values: list[np.ndarray]             # per-table prefetch values (big subtable)
    layout: packed_tables.PackedLayout
    slot_budgets: list[int]

    def fresh_schedulers(self) -> list[PrefetchScheduler]:
        _name, rows = big_subtable(self.bags[0].emb)
        return [
            PrefetchScheduler(rows, slots, value)
            for slots, value in zip(self.slot_budgets, self.values)
        ]


def build_serve_state(cfg, *, shards: int, alpha: float, seed: int,
                      profile_n: int = 50_000) -> ServeState:
    """Offline pass: profile -> analyze -> slot waterfill -> dup plan -> packed
    layout + per-table schedulers."""
    bags = dlrm.make_bags(cfg)
    emb = bags[0].emb
    name, rows = big_subtable(emb)

    # per-table request streams: each sparse feature sees its own skew
    traces = [
        synthetic.zipf_trace(
            cfg.vocab_per_table, profile_n, alpha=alpha, seed=seed + 7 + t
        )
        for t in range(cfg.num_tables)
    ]
    counts = [placement.profile_counts(tr, cfg.vocab_per_table) for tr in traces]
    locs, values = [], []
    for tr in traces:
        pooled = tr[: profile_n - profile_n % cfg.pooling].reshape(-1, cfg.pooling)
        loc = intra_gnr.analyze_table(pooled, emb)
        locs.append(loc)
        values.append(loc[name].prefetch_value().astype(np.float64))

    # adaptive per-table slot budgets: waterfill the global budget by the
    # analyzer's prefetch value instead of one uniform cache_slots knob.
    # The global budget is clamped so the PACKED cache block (every table's
    # slots in one VMEM-resident buffer) fits the configured SRAM size class.
    row_bytes = (emb.tt_spec.g2_width if emb.kind == "tt" else emb.dim) \
        * np.dtype(cfg.pdtype).itemsize
    vmem_slots = (cfg.cache_vmem_mb * 2**20) // max(1, row_bytes)
    total_slots = min(cfg.cache_slots * cfg.num_tables, vmem_slots)
    if getattr(cfg, "cache_slot_policy", "adaptive") == "adaptive":
        budgets = intra_gnr.split_slot_budget(values, total_slots)
    else:
        budgets = [min(cfg.cache_slots, total_slots // cfg.num_tables)] \
            * cfg.num_tables
    budgets = [max(1, min(b, rows)) for b in budgets]

    plan = duplication.plan_duplication(
        bags, counts,
        num_shards=shards, budget_bytes=cfg.dup_budget_mb * 2**20,
        slot_budgets=budgets,
    )
    layout = packed_tables.build_layout(bags, budgets)
    return ServeState(bags, plan, locs, values, layout, budgets)


# Module-level jits keyed by STATIC layout/config (both hashable frozen
# dataclasses): repeated run_pipeline calls — the benchmark's best-of repeats,
# --mode both — hit jax's compilation cache instead of re-tracing per closure.

@functools.partial(jax.jit, static_argnames=("layout",))
def _gather_jit(packed, scale, idx, slot, cache_rows, layout):
    from repro.kernels import ops

    streams = packed_tables.pack_indices(idx, layout)
    streams["slot"] = packed_tables.global_slots(slot, layout)
    cache = packed[packed_tables.big_key(layout.kind)][cache_rows]
    pooled = ops.packed_multi_pooled(
        {**packed, "cache": cache}, streams,
        kind=layout.kind, dims=layout.tt_dims, exec_mode="kernel",
    )
    return pooled * scale[None, :, None].astype(pooled.dtype)


# Donate the consumed pooled buffer to the head on TPU (the double buffer's
# memory hand-off); CPU has no donation support and would only warn.
_HEAD_DONATE = (2,) if jax.default_backend() == "tpu" else ()


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=_HEAD_DONATE)
def _head_jit(params, dense, pooled, cfg):
    return dlrm.forward_from_pooled(params, dense, pooled, cfg)


def make_packed_gather(params, state: ServeState):
    """One jitted megakernel dispatch for a whole batch's embedding layer.

    Packs the tables once (device-side); per batch the caller passes the
    logical indices, the per-table local slot maps, and the scheduler's packed
    cache rows — the cache-block gather ``big[cache_rows]`` *is* the staging
    DMA, overlapped (on hardware) with the previous batch.
    """
    layout = state.layout
    packed = packed_tables.pack_params(params["tables"], layout)
    scale = packed_tables.combiner_scale(state.bags, jnp.float32)

    def gather(idx, slot, cache_rows):
        return _gather_jit(packed, scale, idx, slot, cache_rows, layout)

    return gather


def run_pipeline(cfg, *, batch: int = 16, batches: int = 6, alpha: float = 1.05,
                 shards: int = 4, seed: int = 0, mode: str = "overlap",
                 state: ServeState | None = None, params=None) -> dict:
    """Serve ``batches`` queued request batches; returns logits + measured QPS.

    ``mode="overlap"``: double-buffered — batch ``t+1``'s prefetch + packed
    gather are dispatched while batch ``t``'s interaction/MLP head runs, and
    the host blocks only at the tail of the stream.
    ``mode="sequential"``: the baseline — gather, head, block, every batch.
    Both modes produce identical logits (asserted by the tier-1 suite); the
    QPS difference is the pipeline win.
    """
    if params is None:
        params, _ = dlrm.init_dlrm(jax.random.PRNGKey(seed), cfg)
    if state is None:
        state = build_serve_state(cfg, shards=shards, alpha=alpha, seed=seed)
    bags = state.bags
    scheds = state.fresh_schedulers()    # per-run cache state
    emb = bags[0].emb

    data = [
        synthetic.dlrm_batch(cfg, batch, seed=seed, step=t, alpha=alpha)
        for t in range(batches)
    ]
    idx_np = [np.asarray(b["idx"]) for b in data]
    rows_np = [
        np.stack([big_rows(idx_np[t][:, i], emb) for i in range(cfg.num_tables)],
                 axis=1)
        for t in range(batches)
    ]                                          # (B, T, K) big-subtable rows

    gather = make_packed_gather(params, state)

    def head(params, dense, pooled):
        return _head_jit(params, dense, pooled, cfg)

    def prefetch(t: int) -> None:
        for i in range(cfg.num_tables):
            scheds[i].prefetch(rows_np[t][:, i])

    def dispatch_gather(t: int):
        """Translate batch t through the slot maps and enqueue its megakernel."""
        slot = np.stack(
            [scheds[i].slots_for(rows_np[t][:, i]) for i in range(cfg.num_tables)],
            axis=1,
        )
        cache_rows = packed_tables.packed_cache_rows(
            [s.cache_rows() for s in scheds], state.layout
        )
        return gather(
            jnp.asarray(idx_np[t]), jnp.asarray(slot), jnp.asarray(cache_rows)
        )

    logits: list = [None] * batches
    prefetch(0)                            # cold-start staging for batch 0
    # warm-up: batch 0 compiles gather + head (excluded from steady-state QPS)
    warm = head(params, data[0]["dense"], dispatch_gather(0))
    jax.block_until_ready(warm)
    logits[0] = np.asarray(warm)

    t0 = time.perf_counter()
    if mode == "overlap":
        if batches > 1:
            prefetch(1)
            pooled = dispatch_gather(1)
        for t in range(1, batches):
            # enqueue batch t's head, then stage + dispatch batch t+1's
            # gather while it runs; block only at the tail of the stream
            out = head(params, data[t]["dense"], pooled)
            if t + 1 < batches:
                prefetch(t + 1)
                pooled = dispatch_gather(t + 1)
            logits[t] = out
        jax.block_until_ready(logits[-1] if batches > 1 else warm)
        logits = [np.asarray(x) for x in logits]
    elif mode == "sequential":
        for t in range(1, batches):
            prefetch(t)
            pooled = dispatch_gather(t)
            out = head(params, data[t]["dense"], pooled)
            jax.block_until_ready(out)     # per-batch sync: the baseline
            logits[t] = np.asarray(out)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    wall_s = time.perf_counter() - t0

    served = batch * max(0, batches - 1)
    stats = [s.stats for s in scheds]
    hits = sum(s.hits for s in stats)
    acc = sum(s.accesses for s in stats)
    staged = sum(s.staged_rows for s in stats) / max(1, batches)
    return {
        "config": cfg.name,
        "mode": mode,
        "batch": batch,
        "batches": batches,
        "served": served,
        "wall_s": wall_s,
        "qps": served / max(wall_s, 1e-9),
        "hit_rate": hits / max(1, acc),
        "staged_per_batch": staged,
        "slot_budgets": list(state.slot_budgets),
        "logits": logits,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="dlrm config id (dlrm-qr | dlrm-tt | dlrm-dense)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: --smoke config with batch=8")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--alpha", type=float, default=1.05)
    ap.add_argument("--shards", type=int, default=4,
                    help="modeled row-shard count for the duplication plan")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", default="overlap",
                    choices=["overlap", "sequential", "both"])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measured QPS / hit-rate records as JSON")
    args = ap.parse_args(argv)

    name = f"{args.arch}-smoke" if (args.smoke or args.tiny) else args.arch
    cfg = registry.get_dlrm(name)
    batch = args.batch or (8 if args.tiny else 16)
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(args.seed), cfg)
    state = build_serve_state(
        cfg, shards=args.shards, alpha=args.alpha, seed=args.seed
    )
    emb = state.bags[0].emb
    big_name, _rows = big_subtable(emb)
    plan = state.plan
    print(
        f"{cfg.name}: {cfg.num_tables} tables, kind={cfg.embedding_kind}, "
        f"slot budgets {min(state.slot_budgets)}..{max(state.slot_budgets)} "
        f"({cfg.cache_slot_policy}), dup budget {cfg.dup_budget_mb} MiB, "
        f"packed rows {state.layout.total_rows}"
    )
    print(
        f"duplication plan: replicated {plan.replicated_bytes} B/chip, "
        f"comm_free={plan.comm_free}, local_share="
        f"{plan.tables[0].local_share:.2f}, "
        f"intra-GnR reuse[{big_name}]={state.locs[0][big_name].mean_intra_reuse:.2f}"
    )

    modes = ["sequential", "overlap"] if args.mode == "both" else [args.mode]
    records = []
    for mode in modes:
        res = run_pipeline(
            cfg, batch=batch, batches=args.batches, alpha=args.alpha,
            shards=args.shards, seed=args.seed, mode=mode,
            state=state, params=params,
        )
        ici = plan.ici_bytes_per_batch(batch, cfg.dim)
        print(
            f"[{mode}] served {res['served']} requests in {res['wall_s']:.2f}s "
            f"-> {res['qps']:.1f} QPS (steady state, excl. compile batch)"
        )
        print(
            f"[{mode}] cache hit rate {res['hit_rate']:.3f}, "
            f"staged {res['staged_per_batch']:.1f} rows/batch"
        )
        print(
            f"modeled combine traffic/batch: baseline {ici['baseline']:.0f} B -> "
            f"{ici['duplicated']:.0f} B (saved {ici['saved']:.0f} B)"
        )
        print("first logits:", np.asarray(res["logits"][-1][:4]).round(4).tolist())
        records.append({k: v for k, v in res.items() if k != "logits"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} records to {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
