"""Batched DLRM recommendation serving — the ProactivePIM pipeline end-to-end.

Steady-state loop over a queued request stream:

1. **offline** (once): profile a trace, run the intra-GnR analyzer, and let
   the duplication planner decide which subtables are replicated per shard
   vs row-sharded under the per-chip budget — comm-free tables skip the
   cross-shard combine entirely;
2. **per batch** (the serving loop): while batch ``t`` executes, the prefetch
   hook stages batch ``t+1``'s highest-value big-table rows into the SRAM
   cache model (requests are queued, so next-batch indices are known — the
   paper's proactive prefetch); batch ``t``'s GnR then routes hits to the
   VMEM cache block and misses to streamed HBM rows via the
   ``cached_gather`` Pallas kernel (QR/dense) or the fused TT kernel.

Usage (CPU smoke):
    PYTHONPATH=src python -m repro.launch.serve_rec --arch dlrm-qr --smoke
    PYTHONPATH=src python -m repro.launch.serve_rec --arch dlrm-tt --smoke
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import duplication, intra_gnr
from repro.cache.sram_cache import PrefetchScheduler
from repro.configs import registry
from repro.core import placement
from repro.core import sharded_embedding as SE
from repro.data import synthetic
from repro.models import dlrm


def big_subtable(emb) -> tuple[str, int]:
    """(name, rows) of the streamed/tiered big subtable the cache covers."""
    if emb.kind == "qr":
        return "q", emb.qr_spec.q_rows
    if emb.kind == "tt":
        return "g2", emb.tt_spec.v2
    rows = emb.physical_hashed_rows if emb.kind == "hashed" else emb.vocab
    return "table", rows


def big_rows(idx: np.ndarray, emb) -> np.ndarray:
    """Map a logical-index batch (bags, pooling) onto big-subtable rows (the
    cached stream), via the analyzer's single-sourced decomposition."""
    name, _rows = big_subtable(emb)
    trace, _r, _b = intra_gnr.subtable_traces(idx, emb)[name]
    return trace


def build_serve_state(cfg, *, shards: int, alpha: float, seed: int,
                      profile_n: int = 50_000):
    """Offline pass: profile -> analyze -> duplication plan -> schedulers."""
    bags = dlrm.make_bags(cfg)
    emb = bags[0].emb

    trace = synthetic.zipf_trace(
        cfg.vocab_per_table, profile_n, alpha=alpha, seed=seed + 7
    )
    counts = placement.profile_counts(trace, cfg.vocab_per_table)
    plan = duplication.plan_duplication(
        bags, [counts] * len(bags),
        num_shards=shards, budget_bytes=cfg.dup_budget_mb * 2**20,
    )

    # analyzer: per-GnR reuse of the big subtable feeds the scheduler tiebreak
    pooled_trace = trace[: profile_n - profile_n % cfg.pooling].reshape(
        -1, cfg.pooling
    )
    locs = intra_gnr.analyze_table(pooled_trace, emb)
    name, rows = big_subtable(emb)
    value = locs[name].prefetch_value().astype(np.float64)

    scheds = [
        PrefetchScheduler(rows, cfg.cache_slots, value) for _ in bags
    ]
    return bags, plan, locs, scheds


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="dlrm config id (dlrm-qr | dlrm-tt | dlrm-dense)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--alpha", type=float, default=1.05)
    ap.add_argument("--shards", type=int, default=4,
                    help="modeled row-shard count for the duplication plan")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    name = f"{args.arch}-smoke" if args.smoke else args.arch
    cfg = registry.get_dlrm(name)
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(args.seed), cfg)
    bags, plan, locs, scheds = build_serve_state(
        cfg, shards=args.shards, alpha=args.alpha, seed=args.seed
    )
    emb = bags[0].emb
    big_name, _rows = big_subtable(emb)
    print(
        f"{cfg.name}: {cfg.num_tables} tables, kind={cfg.embedding_kind}, "
        f"cache {cfg.cache_slots} slots/table, dup budget {cfg.dup_budget_mb} MiB"
    )
    print(
        f"duplication plan: replicated {plan.replicated_bytes} B/chip, "
        f"comm_free={plan.comm_free}, local_share="
        f"{plan.tables[0].local_share:.2f}, "
        f"intra-GnR reuse[{big_name}]={locs[big_name].mean_intra_reuse:.2f}"
    )

    # the serving queue: batches are known ahead -> next-batch prefetch is legal
    batches = [
        synthetic.dlrm_batch(
            cfg, args.batch, seed=args.seed, step=t, alpha=args.alpha
        )
        for t in range(args.batches)
    ]
    idx_np = [np.asarray(b["idx"]) for b in batches]

    @jax.jit
    def head(params, dense, pooled):
        return dlrm.forward_from_pooled(params, dense, pooled, cfg)

    def run_batch(t: int):
        pooled = []
        for i, bag in enumerate(bags):
            rows = big_rows(idx_np[t][:, i], bag.emb)
            slot = scheds[i].slots_for(rows)
            pooled.append(
                SE.cached_bag_lookup(
                    params["tables"][i],
                    jnp.asarray(idx_np[t][:, i]),
                    bag,
                    cache_rows=jnp.asarray(scheds[i].cache_rows()),
                    slot=jnp.asarray(slot),
                )
            )
        logits = head(params, batches[t]["dense"], jnp.stack(pooled, axis=1))
        return jax.block_until_ready(logits)

    # prefetch hook: stage batch t+1's rows while batch t executes
    def prefetch(t: int):
        for i, bag in enumerate(bags):
            scheds[i].prefetch(big_rows(idx_np[t][:, i], bag.emb))

    prefetch(0)                       # cold-start staging for the first batch
    logits = run_batch(0)             # compile batch (excluded from QPS)
    t0 = time.perf_counter()
    for t in range(1, args.batches):
        prefetch(t)
        logits = run_batch(t)
    dt = time.perf_counter() - t0

    served = args.batch * (args.batches - 1)
    stats = [s.stats for s in scheds]
    hits = sum(s.hits for s in stats)
    acc = sum(s.accesses for s in stats)
    staged = sum(s.staged_rows for s in stats) / max(1, args.batches)
    ici = plan.ici_bytes_per_batch(args.batch, cfg.dim)
    print(
        f"served {served} requests in {dt:.2f}s -> {served / max(dt, 1e-9):.1f} QPS "
        f"(steady state, excl. compile batch)"
    )
    print(
        f"cache hit rate {hits / max(1, acc):.3f} "
        f"({hits}/{acc} big-subtable accesses), staged {staged:.1f} rows/batch"
    )
    print(
        f"modeled combine traffic/batch: baseline {ici['baseline']:.0f} B -> "
        f"{ici['duplicated']:.0f} B (saved {ici['saved']:.0f} B)"
    )
    print("first logits:", np.asarray(logits[:4]).round(4).tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
