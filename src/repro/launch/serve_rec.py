"""Batched DLRM recommendation serving — the ProactivePIM pipeline end-to-end.

Steady-state loop over a queued request stream:

1. **offline** (once): profile per-table traces, run the intra-GnR analyzer,
   waterfill the global cache-slot budget across tables by prefetch value
   (``cache_slot_policy="adaptive"``), and let the duplication planner decide
   which subtables are replicated per shard vs row-sharded — comm-free tables
   skip the cross-shard combine entirely.  All tables are packed into ONE
   row-major buffer (``repro.core.packed_tables``) with per-table row / LUT /
   cache-slot offsets;
2. **per batch** (the serving loop): while batch ``t`` executes, the prefetch
   hook stages batch ``t+1``'s highest-value big-table rows into the packed
   SRAM-cache model and batch ``t+1``'s packed gather is dispatched — the
   double buffer.  A batch's whole embedding layer is ONE
   ``packed_gather`` megakernel dispatch (hits route to the VMEM cache block,
   misses stream HBM rows) instead of one kernel per table, and the host only
   blocks at the tail of the stream (``--mode sequential`` keeps the
   one-batch-at-a-time baseline for parity checks and speedup measurement).

Telemetry (``repro.obs``): the warm-up batch that compiles gather + head is
timed separately (``compile_s``) and excluded from the steady-state window;
every steady-state batch records a latency sample, so results carry
p50/p95/p99 instead of a single wall-clock number, plus the per-batch traffic
accounting (cache hits, modeled HBM bytes, comm bytes killed by duplication).
``--metrics-json`` dumps the full metric registry; ``--trace-out`` writes a
Chrome-trace/Perfetto JSON of the stage spans (pack -> h2d -> dispatch ->
device compute -> interact) — tracing fences each stage with
``block_until_ready`` for honest durations, which serializes the overlap
pipeline, so never compare a traced run's QPS against an untraced one.

Usage (CPU smoke):
    PYTHONPATH=src python -m repro.launch.serve_rec --arch dlrm-qr --smoke
    PYTHONPATH=src python -m repro.launch.serve_rec --arch dlrm-tt --tiny \
        --metrics-json metrics.json --trace-out trace.json
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engine_mod
from repro import obs
from repro.configs import registry
from repro.data import synthetic
from repro.engine import EngineSpec, big_rows, big_subtable  # noqa: F401 (re-export)
from repro.models import dlrm
from repro.obs import attribution as obs_attribution
from repro.obs import report as obs_report
from repro.obs import traffic as obs_traffic


@dataclasses.dataclass
class ServeState:
    """The offline pass's output, built once per session and reusable across
    pipeline runs (schedulers are stateful, so ``run_pipeline`` constructs a
    fresh set from the plan per run).

    A thin view over the engine's ``EmbeddingPlan``: the legacy field names
    (``plan`` = the duplication plan, ``layout``, ``slot_budgets``, ...) are
    kept for the benchmarks and tests that read them.  When the plan came
    from a fitted tuner, ``predicted_s`` carries the cost model's per-batch
    latency prediction and ``drift`` accumulates predicted-vs-measured
    residuals across every pipeline run on this state (the online
    re-fit trigger).
    """

    engine: engine_mod.EmbeddingEngine
    predicted_s: float | None = None
    drift: obs.DriftMonitor | None = None

    @property
    def eplan(self) -> engine_mod.EmbeddingPlan:
        return self.engine.plan

    @property
    def bags(self) -> list:
        return self.engine.bags

    @property
    def plan(self):                          # the duplication plan
        return self.eplan.dup

    @property
    def locs(self) -> list[dict]:            # per-table intra-GnR analyses
        return list(self.eplan.locality)

    @property
    def values(self) -> list[np.ndarray]:    # per-table prefetch values
        return list(self.eplan.values)

    @property
    def layout(self):
        return self.eplan.layout

    @property
    def slot_budgets(self) -> list[int]:
        return list(self.eplan.slot_budgets)

    def fresh_schedulers(self):
        return self.engine.fresh_schedulers()


def build_serve_state(cfg, *, shards: int, alpha: float, seed: int,
                      profile_n: int = 50_000, tuner=None,
                      knobs=None) -> ServeState:
    """Offline pass, one ``engine.plan`` call: profile -> analyze -> slot
    waterfill -> dup plan -> packed layout, compiled into the serving engine.

    ``tuner`` (a fitted ``repro.tune.Tuner``) or an explicit ``knobs`` routes
    the plan through the cost-model argmin instead of the heuristics; the
    serving pipeline needs the packed backend, so tuner choices are
    constrained to it.  A tuner also arms the drift monitor: its per-batch
    latency prediction for the chosen knobs is compared against measured
    batches while serving.
    """
    # per-table request streams: each sparse feature sees its own skew
    traces = [
        synthetic.zipf_trace(
            cfg.vocab_per_table, profile_n, alpha=alpha, seed=seed + 7 + t
        )
        for t in range(cfg.num_tables)
    ]
    spec = EngineSpec.from_dlrm(cfg, serving=True)
    predicted_s = drift = None
    if knobs is None and tuner is not None:
        knobs = tuner.choose(spec, backend="packed")
        predicted_s = tuner.predict(spec, knobs)
        drift = obs.DriftMonitor()
    eplan = engine_mod.plan(spec, num_shards=shards, trace=traces, knobs=knobs)
    return ServeState(engine=engine_mod.compile(eplan),
                      predicted_s=predicted_s, drift=drift)


# Donate the consumed pooled buffer to the head on TPU (the double buffer's
# memory hand-off); CPU has no donation support and would only warn.
_HEAD_DONATE = (2,) if jax.default_backend() == "tpu" else ()


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=_HEAD_DONATE)
def _head_jit(params, dense, pooled, cfg):
    return dlrm.forward_from_pooled(params, dense, pooled, cfg)


def make_packed_gather(params, state: ServeState):
    """One jitted megakernel dispatch for a whole batch's embedding layer.

    Packs the tables once (device-side); per batch the caller passes the
    logical indices, the per-table local slot maps, and the scheduler's packed
    cache rows — the cache-block gather ``big[cache_rows]`` *is* the staging
    DMA, overlapped (on hardware) with the previous batch.  The dispatch is
    ``EmbeddingEngine.serve_gather`` — one module-level jit keyed by the
    hashable plan, so repeated sessions hit jax's compilation cache.
    """
    eng = state.engine
    with obs.span("pack_tables", cat="offline"):
        packed = eng.pack(params["tables"])

    def gather(idx, slot, cache_rows):
        return eng.serve_gather(packed, idx, slot, cache_rows)

    return gather


# serving-record percentiles come from the same exact-quantile helper the
# obs histograms use (obs.metrics.exact_percentile) — one definition, so a
# metrics snapshot and a result record can never disagree.
_percentiles = obs.latency_percentiles


def run_pipeline(cfg, *, batch: int = 16, batches: int = 6, alpha: float = 1.05,
                 shards: int = 4, seed: int = 0, mode: str = "overlap",
                 state: ServeState | None = None, params=None,
                 fence: bool = False) -> dict:
    """Serve ``batches`` queued request batches; returns logits + measured QPS
    + the per-batch latency distribution + the traffic accounting.

    ``mode="overlap"``: double-buffered — batch ``t+1``'s prefetch + packed
    gather are dispatched while batch ``t``'s interaction/MLP head runs, and
    the host blocks only at the tail of the stream.
    ``mode="sequential"``: the baseline — gather, head, block, every batch.
    Both modes produce identical logits (asserted by the tier-1 suite); the
    QPS difference is the pipeline win.

    Batch 0 compiles gather + head; it is timed as ``compile_s`` and excluded
    from the steady-state window — ``qps`` covers post-warm-up batches only.
    Per-batch latency samples: sequential mode measures full request latency
    (dispatch to synced logits); overlap mode measures the pipeline's batch
    cycle time (the tail drain folds into the last sample).  ``fence=True``
    (set by ``--trace-out``) syncs after every stage so the trace spans carry
    device time — it serializes the overlap pipeline, perturbing QPS.
    """
    if params is None:
        params, _ = dlrm.init_dlrm(jax.random.PRNGKey(seed), cfg)
    if state is None:
        state = build_serve_state(cfg, shards=shards, alpha=alpha, seed=seed)
    bags = state.bags
    scheds = state.fresh_schedulers()    # per-run cache state
    emb = bags[0].emb

    data = [
        synthetic.dlrm_batch(cfg, batch, seed=seed, step=t, alpha=alpha)
        for t in range(batches)
    ]
    idx_np = [np.asarray(b["idx"]) for b in data]
    rows_np = [
        np.stack([big_rows(idx_np[t][:, i], emb) for i in range(cfg.num_tables)],
                 axis=1)
        for t in range(batches)
    ]                                          # (B, T, K) big-subtable rows

    gather = make_packed_gather(params, state)

    def head(params, dense, pooled):
        return _head_jit(params, dense, pooled, cfg)

    def prefetch(t: int) -> None:
        with obs.span("prefetch", batch=t):
            for i in range(cfg.num_tables):
                scheds[i].prefetch(rows_np[t][:, i])

    def dispatch_gather(t: int):
        """Translate batch t through the slot maps and enqueue its megakernel."""
        with obs.span("pack", batch=t):        # host-side slot translation
            slot = np.stack(
                [scheds[i].slots_for(rows_np[t][:, i])
                 for i in range(cfg.num_tables)],
                axis=1,
            )
            cache_rows = state.engine.packed_cache_rows(scheds)
        with obs.span("h2d", batch=t):         # host-to-device index upload
            args = (jnp.asarray(idx_np[t]), jnp.asarray(slot),
                    jnp.asarray(cache_rows))
        with obs.span("dispatch", batch=t):    # megakernel enqueue
            pooled = gather(*args)
        if fence:
            with obs.span("device_compute", batch=t):
                jax.block_until_ready(pooled)
        return pooled

    def interact(t: int, pooled):
        with obs.span("interact", batch=t):    # pairwise dot + MLP head
            out = head(params, data[t]["dense"], pooled)
        if fence:
            with obs.span("device_head", batch=t):
                jax.block_until_ready(out)
        return out

    logits: list = [None] * batches
    lats: list[float] = []
    # warm-up: batch 0 compiles gather + head — timed apart from steady state
    tc = time.perf_counter()
    with obs.span("compile_warmup", cat="offline"):
        prefetch(0)                        # cold-start staging for batch 0
        warm = interact(0, dispatch_gather(0))
        jax.block_until_ready(warm)
    compile_s = time.perf_counter() - tc
    logits[0] = np.asarray(warm)

    t0 = time.perf_counter()
    if mode == "overlap":
        if batches > 1:
            prefetch(1)
            pooled = dispatch_gather(1)
        prev = time.perf_counter()
        for t in range(1, batches):
            # enqueue batch t's head, then stage + dispatch batch t+1's
            # gather while it runs; block only at the tail of the stream
            with obs.span("batch", batch=t, mode=mode):
                out = interact(t, pooled)
                if t + 1 < batches:
                    prefetch(t + 1)
                    pooled = dispatch_gather(t + 1)
                logits[t] = out
            if t < batches - 1:            # cycle time: enqueue-to-enqueue
                now = time.perf_counter()
                lats.append(now - prev)
                prev = now
                obs.observe_batch(batch=t, mode=mode, latency_s=lats[-1])
        with obs.span("tail_sync", mode=mode):
            jax.block_until_ready(logits[-1] if batches > 1 else warm)
        if batches > 1:                    # last cycle includes the drain
            lats.append(time.perf_counter() - prev)
            obs.observe_batch(batch=batches - 1, mode=mode,
                              latency_s=lats[-1])
        logits = [np.asarray(x) for x in logits]
    elif mode == "sequential":
        for t in range(1, batches):
            tb = time.perf_counter()
            with obs.span("batch", batch=t, mode=mode):
                prefetch(t)
                pooled = dispatch_gather(t)
                out = interact(t, pooled)
                with obs.span("block", batch=t):
                    jax.block_until_ready(out)     # per-batch sync: the baseline
            lats.append(time.perf_counter() - tb)
            logits[t] = np.asarray(out)
            obs.observe_batch(batch=t, mode=mode, latency_s=lats[-1])
    else:
        raise ValueError(f"unknown mode {mode!r}")
    wall_s = time.perf_counter() - t0

    for lat in lats:                       # the SLO histograms (when enabled)
        obs.observe(f"serve/{mode}/batch_latency_s", lat)
    obs.observe(f"serve/{mode}/compile_s", compile_s)
    obs.inc(f"serve/{mode}/batches", len(lats))
    obs.inc(f"serve/{mode}/requests", batch * len(lats))
    if state.drift is not None and state.predicted_s is not None:
        for lat in lats:
            state.drift.observe(state.predicted_s, lat)

    served = batch * max(0, batches - 1)
    stats = [s.stats for s in scheds]
    hits = sum(s.hits for s in stats)
    acc = sum(s.accesses for s in stats)
    staged = sum(s.staged_rows for s in stats) / max(1, batches)
    report = obs_traffic.collect(state.eplan, scheds, batch=batch)
    if obs.enabled():
        obs.trace_counter(f"serve/{mode}/hit_rate", hit_rate=report.hit_rate)
    return {
        "config": cfg.name,
        "mode": mode,
        "batch": batch,
        "batches": batches,
        "served": served,
        "compile_s": compile_s,            # warm-up/compile, excluded from qps
        "wall_s": wall_s,
        "qps": served / max(wall_s, 1e-9),
        **_percentiles(lats),
        "latencies_s": lats,
        "hit_rate": hits / max(1, acc),
        "staged_per_batch": staged,
        "slot_budgets": list(state.slot_budgets),
        "traffic": report.describe(),
        "traffic_report": report,          # the live object (attribution joins)
        "drift": state.drift.summary() if state.drift is not None else None,
        "logits": logits,
    }


# result keys dropped from the --json / --metrics-json records (bulk arrays
# and live objects)
_RECORD_DROP = ("logits", "latencies_s", "traffic_report")


# -- resilient front-end mode (--frontend) ------------------------------------

_DEFAULT_ARRIVAL = "rate=400,horizon=3,deadline_ms=250"
_DEFAULT_FRONTEND_SLO = ("p99_ms=60,objective=0.99,fast_window=4,"
                         "slow_window=8,name=frontend")


def run_frontend(cfg, state, params, args, slo_engine=None) -> dict:
    """The ``--frontend`` serving session: open-loop traffic through the
    admission queue, fault injector, and degradation ladder.

    Returns the front end's report with the arrival/fault specs (seeds
    included) stamped in, so a saved record reproduces the run exactly.
    """
    from repro import serve

    aspec = serve.ArrivalSpec.parse(args.arrival or _DEFAULT_ARRIVAL)
    if args.seed and aspec.seed == 0:      # --seed flows into the traffic
        aspec = dataclasses.replace(aspec, seed=args.seed)
    fspec = serve.FaultSpec.parse(args.faults) if args.faults else serve.FaultSpec()
    if slo_engine is None:
        slo_engine = obs.SLOEngine(obs.SLOSpec.parse(_DEFAULT_FRONTEND_SLO))
    fcfg = serve.FrontendConfig(
        batch_size=args.batch or (8 if args.tiny else 16),
        queue_cap=args.queue_cap,
        shed_policy=args.shed_policy,
        queue_order=args.queue_order,
        # adaptation adapts *pinned* residency; the oracle prefetcher would
        # self-heal under drift and mask what the controller does
        residency="pinned" if args.adapt else "prefetch",
        service_mode=args.service_mode,
    )
    adapt_ctl = None
    if args.adapt:
        from repro.adapt import AdaptController

        adapt_ctl = AdaptController(state.eplan, seed=args.seed)
    frontend = serve.Frontend(
        cfg, fcfg, state, params,
        slo=slo_engine, faults=serve.FaultInjector(fspec),
        adapt=adapt_ctl,
    )
    requests = serve.generate(aspec, cfg)
    report = frontend.run(requests)
    report["arrival"] = aspec.describe()
    report["faults"] = fspec.describe()
    report["config"] = cfg.name
    report["mode"] = "frontend"

    req = report["requests"]
    print(
        f"[frontend] {req['generated']} requests over {aspec.horizon_s:.1f}s "
        f"(virtual): served {req['served']}, deadline-missed "
        f"{req['deadline_missed']}, shed {req['shed_total']} "
        f"(reject {req['shed_reject']} / evict {req['shed_evict']} / "
        f"shed-mode {req['shed_mode']} / abandoned {req['abandoned']}), "
        f"unaccounted {req['unaccounted']}"
    )
    print(
        f"[frontend] request latency p50={report['req_lat_p50_s'] * 1e3:.1f}ms "
        f"p95={report['req_lat_p95_s'] * 1e3:.1f}ms "
        f"p99={report['req_lat_p99_s'] * 1e3:.1f}ms (virtual), "
        f"miss rate {report['deadline_miss_rate']:.3f}, "
        f"shed rate {report['shed_rate']:.3f}, "
        f"hit rate {report['hit_rate']:.3f}"
    )
    deg = report["degrade"]
    for tr in deg["transitions"]:
        print(f"[degrade] batch {tr['at_batch']} t={tr['t_s']:.2f}s "
              f"{tr['from']} -> {tr['to']} ({tr['reason']})")
    ttr = report["time_to_recover_s"]
    print(
        f"[degrade] final rung {deg['rung']}, "
        f"{len(deg['transitions'])} transitions, time-to-recover "
        f"{'%.2fs' % ttr if ttr is not None else 'n/a'}"
    )
    if adapt_ctl is not None:
        print(f"[adapt] {adapt_ctl.batch_i} batches sketched, "
              f"events {report['adapt']['events'] or '{}'}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="dlrm config id (dlrm-qr | dlrm-tt | dlrm-dense)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: --smoke config with batch=8")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--alpha", type=float, default=1.05)
    ap.add_argument("--shards", type=int, default=4,
                    help="modeled row-shard count for the duplication plan")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", default="overlap",
                    choices=["overlap", "sequential", "both"])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measured QPS / latency / hit-rate records")
    ap.add_argument("--plan-json", default=None, metavar="PATH",
                    help="write the EmbeddingPlan summary as JSON (CI artifact)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="enable telemetry; write the metric registry "
                         "(latency histograms, dispatch counters, traffic)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable telemetry; write a Chrome-trace JSON of the "
                         "stage spans (fences every stage — perturbs overlap)")
    ap.add_argument("--slo", default=None, metavar="SPEC",
                    help="serving SLO, e.g. 'p99_ms=50,hit=0.5,qps=100,"
                         "objective=0.99' — enables telemetry, burn-rate "
                         "alerts, and the flight recorder")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write the serving-report artifact (markdown + JSON "
                         "twin): SLO state, per-stage attribution, traffic. "
                         "Enables telemetry and fences stages like --trace-out")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="directory for flight-recorder JSON dumps (written "
                         "when an SLO burns or a latency sample is anomalous)")
    ap.add_argument("--frontend", action="store_true",
                    help="serve open-loop traffic through the resilient "
                         "front end (admission queue + deadline batching + "
                         "fault injection + degradation ladder)")
    ap.add_argument("--arrival", default=None, metavar="SPEC",
                    help="traffic model, e.g. 'rate=400,horizon=3,"
                         "deadline_ms=250,flash=1.0+0.5x8,drift_s=1,seed=0'")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="fault schedule, e.g. 'stall@1.0:0.5,drop@1.5,"
                         "replica@2.0:1.0,gather@3.0:2,retries=3'")
    ap.add_argument("--shed-policy", default="reject_new",
                    choices=["reject_new", "drop_oldest"],
                    help="load-shedding policy at a full admission queue")
    ap.add_argument("--queue-cap", type=int, default=64,
                    help="admission queue bound (requests)")
    ap.add_argument("--service-mode", default="measured",
                    choices=["measured", "fixed"],
                    help="virtual service time: calibrated from measured "
                         "wall ('measured') or exactly one unit per batch "
                         "('fixed' — the deterministic CI configuration)")
    ap.add_argument("--queue-order", default="fifo", choices=["fifo", "edf"],
                    help="admission-queue dispatch order: arrival order or "
                         "deadline-earliest-first")
    ap.add_argument("--adapt", action="store_true",
                    help="online adaptation (repro.adapt): frequency "
                         "sketches + incremental re-pinning; standalone it "
                         "runs the pinned adaptive session, with --frontend "
                         "it feeds the admission loop's schedulers")
    ap.add_argument("--drift", default=None, metavar="SPEC",
                    help="batch-indexed hot-set drift for the --adapt "
                         "session, e.g. 'period=8,frac=0.25' (rotations "
                         "every `period` batches)")
    args = ap.parse_args(argv)

    telemetry = bool(args.metrics_json or args.trace_out or args.slo
                     or args.report or args.flight_dir)
    if telemetry:
        obs.enable()
    # --report needs device-honest stage durations for attribution, so it
    # fences like --trace-out (and carries the same QPS caveat).
    fence = bool(args.trace_out or args.report)

    slo_engine = recorder = None
    if args.slo:
        slo_engine = obs.SLOEngine(obs.SLOSpec.parse(args.slo))
    if args.slo or args.flight_dir or args.report:
        recorder = obs.FlightRecorder(out_dir=args.flight_dir)
    if slo_engine is not None or recorder is not None:
        # after enable(): the telemetry join cursors into the live registry.
        # In --frontend mode the front end feeds the SLO engine itself, so
        # the observatory carries only the recorder (no double observation).
        obs.install_observatory(
            slo=None if args.frontend else slo_engine, recorder=recorder,
        )

    name = f"{args.arch}-smoke" if (args.smoke or args.tiny) else args.arch
    cfg = registry.get_dlrm(name)
    batch = args.batch or (8 if args.tiny else 16)
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(args.seed), cfg)
    state = build_serve_state(
        cfg, shards=args.shards, alpha=args.alpha, seed=args.seed
    )
    emb = state.bags[0].emb
    big_name, _rows = big_subtable(emb)
    plan = state.plan
    if args.plan_json:
        with open(args.plan_json, "w") as f:
            json.dump(state.engine.summary(), f, indent=1)
        print(f"# wrote EmbeddingPlan summary to {args.plan_json}")
    print(
        f"{cfg.name}: {cfg.num_tables} tables, kind={cfg.embedding_kind}, "
        f"slot budgets {min(state.slot_budgets)}..{max(state.slot_budgets)} "
        f"({cfg.cache_slot_policy}), dup budget {cfg.dup_budget_mb} MiB, "
        f"packed rows {state.layout.total_rows}"
    )
    print(
        f"duplication plan: replicated {plan.replicated_bytes} B/chip, "
        f"comm_free={plan.comm_free}, local_share="
        f"{plan.tables[0].local_share:.2f}, "
        f"intra-GnR reuse[{big_name}]={state.locs[0][big_name].mean_intra_reuse:.2f}"
    )

    if args.frontend:
        report = run_frontend(cfg, state, params, args, slo_engine=slo_engine)
        if recorder is not None and recorder.dumps:
            for d in recorder.dumps:
                print(f"[flight] dumped {d['records']} records "
                      f"({d['reason']}) -> {d.get('path', '<memory>')}")
            report["flight_dumps"] = [
                {k: v for k, v in d.items() if k != "context"}
                for d in recorder.dumps
            ]
        if args.json:
            with open(args.json, "w") as f:
                json.dump([report], f, indent=1)
            print(f"# wrote frontend record to {args.json}")
        if args.metrics_json:
            snap = obs.snapshot().to_json()
            snap["config"] = cfg.name
            snap["frontend"] = report
            with open(args.metrics_json, "w") as f:
                json.dump(snap, f, indent=1)
            print(f"# wrote metric registry to {args.metrics_json}")
        return 0

    if args.adapt:
        from repro.adapt import DriftSchedule
        from repro.adapt.loop import serve_adaptive

        schedule = (DriftSchedule.parse(args.drift) if args.drift
                    else DriftSchedule(seed=args.seed))
        res = serve_adaptive(
            cfg, batch=batch, batches=args.batches, alpha=args.alpha,
            seed=args.seed, state=state, params=params,
            schedule=schedule, refit=True,
        )
        print(
            f"[adaptive] served {res['served']} requests in "
            f"{res['wall_s']:.2f}s -> {res['qps']:.1f} QPS, hit rate "
            f"{res['hit_rate']:.3f} (pinned residency)"
        )
        hs = res["hit_series"]
        print(f"[adaptive] hit-rate trajectory first->last: "
              f"{hs[0]:.3f} -> {hs[-1]:.3f} over {len(hs)} batches, "
              f"drift {res['schedule']}")
        for ev in res["events"]:
            print(f"[adapt] batch {ev['batch']}: {ev['kind']} "
                  f"(gain {ev.get('gain', 'n/a')})")
        if not res["events"]:
            print("[adapt] no re-plan events (policy held)")
        record = {k: v for k, v in res.items()
                  if k not in _RECORD_DROP and k != "hit_series"}
        record["hit_first"], record["hit_last"] = hs[0], hs[-1]
        if args.json:
            with open(args.json, "w") as f:
                json.dump([record], f, indent=1)
            print(f"# wrote adaptive record to {args.json}")
        if args.metrics_json:
            snap = obs.snapshot().to_json()
            snap["config"] = cfg.name
            snap["adaptive"] = record
            with open(args.metrics_json, "w") as f:
                json.dump(snap, f, indent=1)
            print(f"# wrote metric registry to {args.metrics_json}")
        if args.trace_out:
            obs.tracer().write(
                args.trace_out,
                metadata={"config": cfg.name, "modes": ["adaptive"]},
            )
            print(f"# wrote Chrome trace to {args.trace_out}")
        return 0

    modes = ["sequential", "overlap"] if args.mode == "both" else [args.mode]
    records = []
    for mode in modes:
        res = run_pipeline(
            cfg, batch=batch, batches=args.batches, alpha=args.alpha,
            shards=args.shards, seed=args.seed, mode=mode,
            state=state, params=params, fence=fence,
        )
        tr = res["traffic"]
        ici = plan.ici_bytes_per_batch(batch, cfg.dim)
        print(
            f"[{mode}] served {res['served']} requests in {res['wall_s']:.2f}s "
            f"-> {res['qps']:.1f} QPS (steady state; compile/warm-up "
            f"{res['compile_s']:.2f}s excluded)"
        )
        print(
            f"[{mode}] batch latency p50={res['lat_p50_s'] * 1e3:.2f}ms "
            f"p95={res['lat_p95_s'] * 1e3:.2f}ms "
            f"p99={res['lat_p99_s'] * 1e3:.2f}ms over {len(res['latencies_s'])} "
            f"batches"
        )
        print(
            f"[{mode}] cache hit rate {res['hit_rate']:.3f}, "
            f"staged {res['staged_per_batch']:.1f} rows/batch, "
            f"HBM {tr['hbm_cached_bytes']}B vs baseline "
            f"{tr['hbm_baseline_bytes']}B ({tr['hbm_reduction']:.2f}x)"
        )
        print(
            f"modeled combine traffic/batch: baseline {ici['baseline']:.0f} B -> "
            f"{ici['duplicated']:.0f} B (saved {ici['saved']:.0f} B)"
        )
        print("first logits:", np.asarray(res["logits"][-1][:4]).round(4).tolist())
        records.append({k: v for k, v in res.items() if k not in _RECORD_DROP})

    # -- observatory epilogue: SLO verdict, attribution, serving report -------
    if slo_engine is not None:
        floors = slo_engine.finalize(hit_rate=res["hit_rate"], qps=res["qps"])
        verdict = "BREACHED" if slo_engine.breached else "met"
        print(
            f"[slo] {slo_engine.spec.name}: {verdict} — "
            f"{slo_engine.bad_total}/{slo_engine.n} bad batches, "
            f"budget remaining {slo_engine.budget_remaining_frac * 100:.1f}%, "
            f"{len(slo_engine.alerts)} alerts"
        )
        for fname, f in floors.items():
            print(f"[slo] {fname} floor {f['floor']}: measured "
                  f"{f['measured']:.3f} — "
                  f"{'BREACHED' if f['breached'] else 'met'}")
    if recorder is not None and recorder.dumps:
        for d in recorder.dumps:
            print(f"[flight] dumped {d['records']} records "
                  f"({d['reason']}) -> {d.get('path', '<memory>')}")
    if args.report:
        att = obs_attribution.attribute(
            obs.tracer().events, res["traffic_report"], state.eplan,
            batch=batch, fenced=fence,
        )
        print(f"[attribution] bottleneck stage: {att.bottleneck} "
              f"(measured {att.total_s * 1e3:.2f} ms/batch, "
              f"cost model {att.modeled_total_s() * 1e3:.2f} ms/batch)")
        rep = obs_report.build(
            snapshot=obs.snapshot(),
            slo_state=slo_engine.state() if slo_engine is not None else None,
            attribution=att,
            traffic=res["traffic"],
            results={r["mode"]: r for r in records},
            flight_dumps=recorder.dumps if recorder is not None else None,
            meta={
                "config": cfg.name, "batch": batch, "batches": args.batches,
                "shards": args.shards, "alpha": args.alpha,
                "seed": args.seed, "modes": modes, "fenced": fence,
            },
        )
        md_path, jpath = obs_report.write(rep, args.report, attribution=att)
        print(f"# wrote serving report to {md_path} (+ {jpath})")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} records to {args.json}")
    if args.metrics_json:
        snap = obs.snapshot().to_json()
        snap["config"] = cfg.name
        snap["modes"] = {r["mode"]: r for r in records}
        snap["plan"] = state.engine.summary()
        with open(args.metrics_json, "w") as f:
            json.dump(snap, f, indent=1)
        print(f"# wrote metric registry to {args.metrics_json}")
    if args.trace_out:
        obs.tracer().write(
            args.trace_out,
            metadata={"config": cfg.name, "modes": modes, "fenced": fence},
        )
        print(f"# wrote Chrome trace to {args.trace_out} "
              f"(load in chrome://tracing or ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
