import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the real
train/prefill/decode step with full-size ShapeDtypeStruct inputs (no
allocation), compiles it, and records:

* ``memory_analysis()``  — per-device bytes (proves the cell fits HBM),
* ``cost_analysis()``    — HLO FLOPs/bytes for the roofline terms,
* collective bytes       — parsed from the post-SPMD HLO text, per op kind,
* analytic MODEL_FLOPS   — 6·N·D (dense) / 6·N_active·D (MoE).

One JSON per cell lands in ``experiments/dryrun/<mesh>/`` for
``benchmarks/roofline.py`` to consume.

Usage:
    python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --mesh pod1
    python -m repro.launch.dryrun --all --mesh both
    python -m repro.launch.dryrun --list
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.distributed import jax_compat
from repro.configs.base import LM_SHAPES, ModelConfig, ShapeConfig
from repro.distributed import sharding as SH
from repro.launch import hlo_analysis
from repro.launch import mesh as mesh_mod
from repro.train import optimizer as opt_mod
from repro.train.serve_step import serve_family
from repro.train.train_step import make_train_step

SHAPES = {s.name: s for s in LM_SHAPES}


def param_counts(params_sds, cfg: ModelConfig) -> dict:
    """Total + MoE-active parameter counts from the abstract tree."""
    total = 0
    moe_total = 0
    for path, leaf in jax_compat.tree_flatten_with_path(params_sds)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if "moe" in keys and "router" not in keys:
            moe_total += n
    active = total
    if cfg.num_experts and cfg.top_k:
        active = total - moe_total + moe_total * cfg.top_k / cfg.num_experts
    return {"total": int(total), "active": int(active)}


def model_flops(counts: dict, shape: ShapeConfig) -> float:
    """6·N·D with D = tokens processed by the lowered step."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * counts["active"] * tokens          # fwd only
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        return 2 * counts["active"] * tokens
    return 6 * counts["active"] * tokens


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------

def _batch_shardings(batch_sds: dict, mesh, act_rules) -> dict:
    out = {}
    for k, v in batch_sds.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, SH.resolve_spec(mesh, v.shape, axes, act_rules))
    return out


def _replicated_tree(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def lower_cell(
    arch_id: str,
    shape_name: str,
    *,
    multi_pod: bool,
    embedding_kind: str | None = None,
    qr_collision: int | None = None,
    microbatches: int = 8,
    seq_parallel: bool = False,
    serve_params: bool = False,
    extra_cfg: dict | None = None,
) -> dict:
    binding = registry.get(arch_id)
    cfg = binding.config
    if embedding_kind:
        cfg = cfg.replace(embedding_kind=embedding_kind)
    if qr_collision:
        cfg = cfg.replace(qr_collision=qr_collision)
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    shape = SHAPES[shape_name]
    status = registry.shape_status(binding, shape)
    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "pod2" if multi_pod else "pod1",
        "kind": shape.kind,
        "embedding": cfg.embedding_kind,
        "variant": dict(extra_cfg or {}, serve_params=serve_params),
        "status": status,
    }
    if status != "run":
        return rec

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    act_rules = SH.multi_pod_rules() if multi_pod else dict(SH.DEFAULT_RULES)
    par_rules = SH.multi_pod_param_rules() if multi_pod else dict(SH.PARAM_RULES)
    if serve_params:
        # inference placement: parameters bf16, TP-sharded only (no FSDP over
        # `data` -> no per-layer weight all-gathers in the decode loop)
        cfg = cfg.replace(param_dtype="bfloat16")
        par_rules["embed"] = None
        rec_extra = {"serve_params": True}
    else:
        rec_extra = {}
    if seq_parallel:
        act_rules["seq"] = ("model",)
    dp = mesh.shape["data"] * mesh.shape.get("pod", 1)
    if shape.global_batch % dp:
        act_rules["batch"] = None                     # B=1 long-context cells

    t0 = time.time()
    params_sds, axes = registry.abstract_params(binding, cfg)
    pshard = SH.shardings_for_tree(mesh, params_sds, axes, par_rules)
    counts = param_counts(params_sds, cfg)
    rec["params_total"] = counts["total"]
    rec["params_active"] = counts["active"]
    rec["model_flops"] = model_flops(counts, shape)
    rec["abstract_s"] = round(time.time() - t0, 2)

    mb = microbatches if shape.kind == "train" else 1
    while shape.global_batch % max(mb, 1) or (shape.global_batch // max(mb, 1)) % dp:
        mb //= 2
        if mb <= 1:
            mb = 1
            break
    rec["microbatches"] = mb

    if shape.kind == "train":
        batch_sds = registry.batch_specs(binding, cfg, shape.global_batch, shape.seq_len)
        bshard = _batch_shardings(batch_sds, mesh, act_rules)
        opt_sds = jax.eval_shape(opt_mod.init, params_sds)
        opt_shard = {
            "mu": pshard, "nu": pshard, "step": NamedSharding(mesh, P()),
        }
        loss0 = registry.train_loss_fn(binding, cfg)

        def loss_fn(params, batch):
            with SH.use_rules(mesh, act_rules):
                return loss0(params, batch)

        step = make_train_step(loss_fn, opt_mod.OptConfig(), microbatches=mb)
        fn = jax.jit(
            step,
            in_shardings=(pshard, opt_shard, bshard),
            out_shardings=(pshard, opt_shard, None),
        )
        args = (params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        fam = serve_family(binding.kind)
        batch_sds = registry.batch_specs(binding, cfg, shape.global_batch, shape.seq_len)
        bshard = _batch_shardings(batch_sds, mesh, act_rules)
        cache_sds = registry.cache_specs(binding, cfg, shape.global_batch, shape.seq_len)
        ca = fam.cache_axes()
        cshard = (
            SH.shardings_for_tree(mesh, cache_sds, ca, act_rules)
            if ca is not None
            else _replicated_tree(cache_sds, mesh)
        )

        def fn_prefill(params, batch):
            with SH.use_rules(mesh, act_rules):
                return fam.prefill(params, batch, cfg, shape.seq_len)

        fn = jax.jit(
            fn_prefill,
            in_shardings=(pshard, bshard),
            out_shardings=(None, cshard),
        )
        args = (params_sds, batch_sds)
    else:  # decode
        fam = serve_family(binding.kind)
        cache_sds = registry.cache_specs(binding, cfg, shape.global_batch, shape.seq_len)
        ca = fam.cache_axes()
        cshard = (
            SH.shardings_for_tree(mesh, cache_sds, ca, act_rules)
            if ca is not None
            else _replicated_tree(cache_sds, mesh)
        )
        token_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tshard = NamedSharding(
            mesh,
            SH.resolve_spec(mesh, token_sds.shape, ("batch", None), act_rules),
        )
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

        def fn_decode(params, cache, token, pos):
            with SH.use_rules(mesh, act_rules):
                return fam.decode(params, cache, token, pos, cfg)

        fn = jax.jit(
            fn_decode,
            in_shardings=(pshard, cshard, tshard, NamedSharding(mesh, P())),
            out_shardings=(None, cshard),
        )
        args = (params_sds, cache_sds, token_sds, pos_sds)

    t0 = time.time()
    lowered = fn.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 2)

    try:
        lc = lowered.cost_analysis()
        rec["lowered_cost"] = {
            "flops": lc.get("flops", 0.0),
            "bytes_accessed": lc.get("bytes accessed", 0.0),
        }
    except Exception:
        rec["lowered_cost"] = None

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_est_bytes": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes
        ),
    }
    ca_ = compiled.cost_analysis()
    rec["compiled_cost"] = {
        "flops": float(ca_.get("flops", 0.0)) if ca_ else 0.0,
        "bytes_accessed": float(ca_.get("bytes accessed", 0.0)) if ca_ else 0.0,
    }
    hlo = compiled.as_text()
    rec["hlo_bytes_len"] = len(hlo)
    t0 = time.time()
    rec["hlo"] = hlo_analysis.analyze(hlo)   # loop-aware per-device FLOPs/bytes
    rec["analyze_s"] = round(time.time() - t0, 2)
    rec["chips"] = chips
    rec["_hlo_text"] = hlo                   # stripped to .hlo.gz by run_cells
    return rec


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_cells(cells, out_dir: str, *, force: bool = False, tag: str | None = None,
              **kw) -> list[dict]:
    results = []
    for arch_id, shape_name, multi_pod in cells:
        mesh_tag = "pod2" if multi_pod else "pod1"
        base = tag or kw.get("embedding_kind") or "config"
        sp = "-sp" if kw.get("seq_parallel") else ""
        path = os.path.join(
            out_dir, mesh_tag, f"{arch_id}__{shape_name}__{base}{sp}.json"
        )
        os.makedirs(os.path.dirname(path), exist_ok=True)
        if os.path.exists(path) and not force:
            with open(path) as f:
                results.append(json.load(f))
            print(f"[skip] {path}")
            continue
        print(f"[dryrun] {arch_id} x {shape_name} x {mesh_tag} ({base}{sp}) ...",
              flush=True)
        t0 = time.time()
        try:
            rec = lower_cell(arch_id, shape_name, multi_pod=multi_pod, **kw)
        except Exception as e:  # record failures — they are bugs to fix
            rec = {
                "arch": arch_id, "shape": shape_name, "mesh": mesh_tag,
                "status": f"error: {type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
        rec["wall_s"] = round(time.time() - t0, 2)
        hlo_text = rec.pop("_hlo_text", None)
        if hlo_text is not None:
            import gzip

            with gzip.open(path.replace(".json", ".hlo.gz"), "wt") as g:
                g.write(hlo_text)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"   -> {rec.get('status')} ({rec['wall_s']}s)", flush=True)
        results.append(rec)
    return results


def reanalyze(out_dir: str) -> None:
    """Refresh every record's 'hlo' section from the saved .hlo.gz (no
    recompilation) — used when the analyzer's cost model improves."""
    import glob
    import gzip

    for path in sorted(glob.glob(os.path.join(out_dir, "*", "*.json"))):
        gz = path.replace(".json", ".hlo.gz")
        if not os.path.exists(gz):
            continue
        with open(path) as f:
            rec = json.load(f)
        with gzip.open(gz, "rt") as g:
            rec["hlo"] = hlo_analysis.analyze(g.read())
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[reanalyzed] {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reanalyze", action="store_true")
    ap.add_argument("--embedding", default=None, choices=[None, "dense", "hashed", "qr"])
    ap.add_argument("--collision", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--qr-head", default=None, choices=[None, "factorized", "materialize"])
    ap.add_argument("--embedding-exec", default=None, choices=[None, "gspmd", "twolevel"])
    ap.add_argument("--moe-dispatch", default=None, choices=[None, "scatter", "gather"])
    ap.add_argument("--remat-policy", default=None, choices=[None, "full", "dots"])
    ap.add_argument("--flash-block-dtype", default=None, choices=[None, "f32", "bf16"])
    ap.add_argument("--serve-params", action="store_true",
                    help="inference placement: bf16 params, TP-only (no FSDP)")
    ap.add_argument("--tag", default=None, help="output filename variant tag")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.reanalyze:
        reanalyze(args.out)
        return

    if args.list:
        for b, s, status in registry.cells(include_skipped=True):
            print(f"{b.arch_id:24s} {s.name:12s} {status}")
        return

    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]
    if args.all:
        cells = [
            (b.arch_id, s.name, mp)
            for mp in meshes
            for b, s, _ in registry.cells()
        ]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    extra_cfg = {}
    if args.qr_head:
        extra_cfg["qr_head"] = args.qr_head
    if args.embedding_exec:
        extra_cfg["embedding_exec"] = args.embedding_exec
    if args.moe_dispatch:
        extra_cfg["moe_dispatch"] = args.moe_dispatch
    if args.remat_policy:
        extra_cfg["remat_policy"] = args.remat_policy
    if args.flash_block_dtype:
        extra_cfg["flash_block_dtype"] = args.flash_block_dtype
    results = run_cells(
        cells, args.out, force=args.force, tag=args.tag,
        embedding_kind=args.embedding, qr_collision=args.collision,
        microbatches=args.microbatches, seq_parallel=args.seq_parallel,
        extra_cfg=extra_cfg or None, serve_params=args.serve_params,
    )
    ok = sum(1 for r in results if r.get("status") == "run")
    print(f"\n{ok}/{len(results)} cells compiled clean")
    bad = [r for r in results if str(r.get("status", "")).startswith("error")]
    for r in bad:
        print(f"FAILED: {r['arch']} x {r['shape']} x {r['mesh']}: {r['status']}")


if __name__ == "__main__":
    main()
