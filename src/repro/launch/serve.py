"""Batched serving driver: prefill a prompt batch, decode greedily.

Usage (CPU smoke):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import sys
import time

import jax

from repro.configs import registry
from repro.train.serve_step import greedy_generate, serve_family


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--embedding", default=None, choices=[None, "dense", "hashed", "qr"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    binding = registry.get(args.arch)
    cfg = binding.smoke if args.smoke else binding.config
    if args.embedding:
        cfg = cfg.replace(embedding_kind=args.embedding)
    init = registry.init_fn(binding)
    params, _ = init(jax.random.PRNGKey(args.seed), cfg)
    make_batch = registry.make_batch_fn(binding, cfg)
    batch = make_batch(args.batch, args.prompt_len, seed=args.seed, step=0)

    fam = serve_family(binding.kind)
    max_len = args.prompt_len + args.max_new

    t0 = time.time()
    out = greedy_generate(
        fam, params, batch, cfg, max_new=args.max_new, max_len=max_len
    )
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"generated {out.shape} in {dt:.2f}s ({toks / dt:.1f} tok/s incl. compile)")
    print("first sequence:", out[0].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
