"""Seeded open-loop traffic: Poisson arrivals, flash crowds, Zipf key drift.

The serving benchmarks so far pulled batches from an infinitely patient
queue; real recommendation traffic is *open-loop* — requests arrive on their
own clock whether or not the server keeps up, and the interesting regimes
are exactly the ones where it doesn't.  This module generates that traffic
deterministically:

* **Poisson base load** — exponential inter-arrival gaps at ``rate_rps``;
* **flash crowds** — :class:`FlashEpisode` windows multiply the instantaneous
  rate (the thinning construction keeps the process exact: draw at the peak
  rate, keep each arrival with probability ``rate(t)/peak``);
* **Zipf key drift** — each request's per-table multi-hot indices are drawn
  from the same permuted-Zipf law the profiler models
  (:func:`repro.data.synthetic.zipf_probs`), with the hot set rotated by a
  vocab offset every ``drift_period_s`` — the prefetch cache's working set
  moves under it mid-run, exactly the non-stationarity the paper's offline
  profiling cannot see.

Everything is a pure function of the spec (seed included): two calls to
:func:`generate` with equal specs return byte-identical request streams, so
benchmark rows stamped with the spec reproduce exactly.

All times are **virtual seconds** (the front end's simulated clock), not
wall time.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.adapt import schedule as schedule_mod
from repro.data import synthetic


@dataclasses.dataclass(frozen=True)
class FlashEpisode:
    """One flash-crowd window: rate × ``multiplier`` in [start, start+duration)."""

    start_s: float
    duration_s: float
    multiplier: float

    def active(self, t_s: float) -> bool:
        return self.start_s <= t_s < self.start_s + self.duration_s

    def describe(self) -> dict:
        return {
            "start_s": self.start_s,
            "duration_s": self.duration_s,
            "multiplier": self.multiplier,
        }


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """The full traffic model — hashable, JSON-able, parseable from the CLI."""

    rate_rps: float = 400.0          # base Poisson rate, virtual requests/s
    horizon_s: float = 4.0           # generate arrivals in [0, horizon)
    deadline_s: float = 0.25         # per-request latency budget
    alpha: float = 1.05              # Zipf skew of the key distribution
    drift_period_s: float = 0.0      # hot-set rotation period (0 = stationary)
    drift_fraction: float = 0.25     # vocab fraction the hot set moves per period
    flash: tuple[FlashEpisode, ...] = ()
    seed: int = 0

    def __post_init__(self):
        if self.rate_rps <= 0 or self.horizon_s <= 0:
            raise ValueError("rate_rps and horizon_s must be positive")
        if self.deadline_s <= 0:
            raise ValueError("deadline_s must be positive")

    def rate_at(self, t_s: float) -> float:
        """Instantaneous arrival rate (flash multipliers stack)."""
        r = self.rate_rps
        for ep in self.flash:
            if ep.active(t_s):
                r *= ep.multiplier
        return r

    @property
    def peak_rate(self) -> float:
        """Upper bound on ``rate_at`` — the thinning envelope."""
        r = self.rate_rps
        for ep in self.flash:
            if ep.multiplier > 1.0:
                # overlapping episodes stack, so the envelope is the product
                r *= ep.multiplier
        return r

    def describe(self) -> dict:
        """JSON form — stamped into benchmark rows for reproducibility."""
        return {
            "rate_rps": self.rate_rps,
            "horizon_s": self.horizon_s,
            "deadline_s": self.deadline_s,
            "alpha": self.alpha,
            "drift_period_s": self.drift_period_s,
            "drift_fraction": self.drift_fraction,
            "flash": [ep.describe() for ep in self.flash],
            "seed": self.seed,
        }

    # -- CLI form -------------------------------------------------------------

    @classmethod
    def parse(cls, text: str) -> "ArrivalSpec":
        """Parse the ``--arrival`` form, e.g.
        ``"rate=400,horizon=4,deadline_ms=250,flash=1.0+0.5x8,drift_s=2"``.

        ``flash=START+DURxMULT`` may repeat; times are virtual seconds.
        """
        kw: dict = {}
        flash: list[FlashEpisode] = []
        for tok in filter(None, (t.strip() for t in text.split(","))):
            if "=" not in tok:
                raise ValueError(f"bad --arrival token {tok!r} (want key=value)")
            k, v = (s.strip() for s in tok.split("=", 1))
            if k == "rate":
                kw["rate_rps"] = float(v)
            elif k == "horizon":
                kw["horizon_s"] = float(v)
            elif k == "deadline_ms":
                kw["deadline_s"] = float(v) * 1e-3
            elif k == "deadline_s":
                kw["deadline_s"] = float(v)
            elif k == "alpha":
                kw["alpha"] = float(v)
            elif k == "drift_s":
                kw["drift_period_s"] = float(v)
            elif k == "drift_frac":
                kw["drift_fraction"] = float(v)
            elif k == "seed":
                kw["seed"] = int(v)
            elif k == "flash":
                try:
                    start, rest = v.split("+", 1)
                    dur, mult = rest.split("x", 1)
                except ValueError:
                    raise ValueError(
                        f"bad flash episode {v!r} (want START+DURxMULT)"
                    ) from None
                flash.append(FlashEpisode(float(start), float(dur), float(mult)))
            else:
                raise ValueError(f"unknown --arrival key {k!r}")
        return cls(flash=tuple(flash), **kw)


@dataclasses.dataclass
class Request:
    """One timestamped recommendation request (a single batch row)."""

    rid: int
    t_arrive_s: float
    deadline_s: float               # absolute virtual deadline
    idx: np.ndarray                 # (num_tables, pooling) sparse indices
    dense: np.ndarray               # (num_dense,) dense features

    def slack_at(self, now_s: float) -> float:
        """Remaining budget at virtual time ``now_s`` (negative = late)."""
        return self.deadline_s - now_s


def _arrival_times(spec: ArrivalSpec, rng: np.random.Generator) -> np.ndarray:
    """Exact inhomogeneous-Poisson arrival times on [0, horizon) by thinning."""
    peak = spec.peak_rate
    times: list[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / peak)
        if t >= spec.horizon_s:
            break
        if rng.random() <= spec.rate_at(t) / peak:
            times.append(t)
    return np.asarray(times, dtype=np.float64)


def drift_offset(spec: ArrivalSpec, t_s: float, vocab: int) -> int:
    """Vocab rotation of the Zipf hot set at virtual time ``t_s``.

    Delegates to the shared drift-schedule law (`repro.adapt.schedule`) —
    the arrival generator and the drift benchmarks rotate identically.
    """
    return schedule_mod.rotation_offset(
        t_s, spec.drift_period_s, spec.drift_fraction, vocab
    )


def generate(spec: ArrivalSpec, cfg) -> list[Request]:
    """The full request stream for a ``DLRMConfig`` — sorted by arrival time.

    Keys come from the permuted-Zipf law (inverse-CDF sampled, so the
    distribution matches what ``build_serve_state`` profiled), rotated by the
    drift offset of each request's arrival time.
    """
    rng = np.random.default_rng(np.random.SeedSequence([spec.seed, 0xA221]))
    times = _arrival_times(spec, rng)
    n = times.size
    vocab = cfg.vocab_per_table
    cdf = np.cumsum(synthetic.zipf_probs(vocab, spec.alpha))
    cdf[-1] = 1.0                        # guard float round-off at the tail

    shape = (n, cfg.num_tables, cfg.pooling)
    base_idx = np.searchsorted(cdf, rng.random(shape)).astype(np.int32)
    dense = rng.standard_normal((n, cfg.num_dense)).astype(np.float32)

    out: list[Request] = []
    for i in range(n):
        t = float(times[i])
        off = drift_offset(spec, t, vocab)
        idx = (base_idx[i] + off) % vocab if off else base_idx[i]
        out.append(Request(
            rid=i,
            t_arrive_s=t,
            deadline_s=t + spec.deadline_s,
            idx=idx.astype(np.int32),
            dense=dense[i],
        ))
    return out
