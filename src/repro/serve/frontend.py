"""Admission control + deadline batching over the engine's serving seam.

The front end closes the loop between open-loop traffic (``arrival``) and
the packed-gather pipeline: a bounded queue admits requests, a deadline-aware
assembler closes batches on size-or-timeout, and every dispatched batch runs
through the degradation ladder's current rung (``degrade``) under the fault
injector's schedule (``faults``).

**Virtual clock.**  Arrivals, deadlines, SLO burns, backoff, and injected
stalls all live in virtual seconds.  Real kernel wall-time enters only
through calibration: the warm-up median wall ``s0`` maps to one
``service_unit_s`` of virtual time, so a batch that measures ``w`` seconds
of wall is charged ``w / s0 × service_unit_s`` of virtual service
(``service_mode="measured"``), or exactly one unit
(``service_mode="fixed"`` — the chaos CI configuration, where behavior must
be host-independent).  Injected stalls are virtual seconds added on top, so
a scheduled 0.5 s stall is ~50 service units regardless of host speed — SLO
burn alerts and ladder steps fire deterministically.

**Accounting identity** (the invariant the chaos gate asserts): every
generated request ends in exactly one bucket —

    generated = served + deadline_missed + shed_reject + shed_evict
                + shed_mode + abandoned

``unaccounted`` in the report is the residual and must be zero.  Requests in
a batch that exhausts its gather retries are *abandoned*; dispatched
requests are classified at completion (late completions count as
``deadline_missed``, not served).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time

import jax
import numpy as np

from repro import obs
from repro.data import synthetic
from repro.engine import big_rows
from repro.models import dlrm
from repro.serve.arrival import Request
from repro.serve.degrade import RUNGS, DegradationLadder, DegradePolicy
from repro.serve.faults import FaultInjector, FaultSpec, TransientGatherError


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Queue, batching, and virtual-clock policy."""

    batch_size: int = 8
    queue_cap: int = 64
    shed_policy: str = "reject_new"      # reject_new | drop_oldest
    queue_order: str = "fifo"            # fifo | edf (deadline-earliest-first)
    residency: str = "prefetch"          # prefetch (oracle) | pinned (static)
    assembly_timeout_s: float = 0.02     # close a partial batch after this wait
    service_unit_s: float = 0.01         # virtual service per calibrated batch
    service_mode: str = "measured"       # measured | fixed (CI determinism)
    warmup_batches: int = 3              # calibration dispatches (not counted)

    def __post_init__(self):
        if self.shed_policy not in ("reject_new", "drop_oldest"):
            raise ValueError(f"unknown shed policy {self.shed_policy!r}")
        if self.queue_order not in ("fifo", "edf"):
            raise ValueError(f"unknown queue order {self.queue_order!r}")
        if self.residency not in ("prefetch", "pinned"):
            raise ValueError(f"unknown residency {self.residency!r}")
        if self.service_mode not in ("measured", "fixed"):
            raise ValueError(f"unknown service mode {self.service_mode!r}")
        if self.batch_size <= 0 or self.queue_cap <= 0:
            raise ValueError("batch_size and queue_cap must be positive")

    def describe(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FrontendStats:
    """Every request's final bucket + the dispatch-path counters."""

    generated: int = 0
    admitted: int = 0            # entered the queue (may later be evicted)
    served: int = 0
    deadline_missed: int = 0
    shed_reject: int = 0         # reject_new at a full queue
    shed_evict: int = 0          # drop_oldest evictions
    shed_mode: int = 0           # rejected while the ladder sheds
    abandoned: int = 0           # batch dropped after retry exhaustion
    batches: int = 0
    retries: int = 0
    stall_s_injected: float = 0.0

    @property
    def shed_total(self) -> int:
        return (self.shed_reject + self.shed_evict + self.shed_mode
                + self.abandoned)

    @property
    def unaccounted(self) -> int:
        """Must be zero: the conservation law of the front end."""
        return (self.generated - self.served - self.deadline_missed
                - self.shed_total)

    def describe(self) -> dict:
        d = dataclasses.asdict(self)
        d["shed_total"] = self.shed_total
        d["unaccounted"] = self.unaccounted
        return d


class Frontend:
    """One serving session: queue → batches → ladder → accounting.

    ``state``/``params`` are the offline pass's ``ServeState`` + DLRM params
    (the same objects ``run_pipeline`` uses); ``slo`` an optional
    ``obs.SLOEngine`` whose burn signals drive the ladder.
    """

    def __init__(self, cfg, fcfg: FrontendConfig, state, params, *,
                 slo=None, faults: FaultInjector | None = None,
                 policy: DegradePolicy | None = None, adapt=None):
        self.cfg = cfg
        self.fcfg = fcfg
        self.state = state
        self.params = params
        self.slo = slo
        self.faults = faults or FaultInjector(FaultSpec())
        self.ladder = DegradationLadder(state, params, policy)
        # optional online adaptation: an ``repro.adapt.AdaptController`` fed
        # per dispatched batch; its re-plans re-pin residency (pinned) or
        # refresh the schedulers' value arrays (prefetch) in place — runtime
        # args only, the compiled rungs are untouched
        self.adapt = adapt
        self.scheds = self._fresh_residency()
        self.stats = FrontendStats()
        self._emb = state.bags[0].emb
        self._s0 = fcfg.service_unit_s        # wall seconds per service unit
        self._calibrated = False

    def _fresh_residency(self):
        """New cache state per the configured residency mode.

        ``prefetch`` is the oracle next-batch scheduler; ``pinned`` is static
        residency pinned to the offline plan's bet
        (:func:`repro.adapt.replan.pinned_from_plan`) — the mode online
        adaptation exists to keep honest under drift.
        """
        if self.fcfg.residency == "pinned":
            from repro.adapt import replan

            eplan = (self.adapt.eplan if self.adapt is not None
                     else self.state.eplan)
            return replan.pinned_from_plan(eplan)
        return self.state.fresh_schedulers()

    # -- execution ------------------------------------------------------------

    def _rows_for(self, idx: np.ndarray) -> np.ndarray:
        """(B, T, K) logical indices -> big-subtable rows (the cached stream)."""
        return np.stack(
            [big_rows(idx[:, t], self._emb) for t in range(self.cfg.num_tables)],
            axis=1,
        )

    def _dispatch_wall(self, idx: np.ndarray, dense: np.ndarray,
                       rows: np.ndarray) -> float:
        """Execute one batch end-to-end (gather + head); return wall seconds."""
        t0 = time.perf_counter()
        with obs.span("dispatch", cat="serve", rung=self.ladder.rung):
            pooled = self.ladder.pooled(idx, rows, self.scheds)
        with obs.span("interact", cat="serve"):
            out = _head_jit(self.params, dense, pooled, self.cfg)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    def calibrate(self) -> float:
        """Warm every rung (compiles) and fit the wall→virtual scale ``s0``.

        Runs on synthetic batches so the arrival stream is untouched; the
        schedulers are rebuilt afterwards, so warm-up never pollutes the
        session's hit-rate accounting.
        """
        fcfg = self.fcfg
        b = synthetic.dlrm_batch(self.cfg, fcfg.batch_size, seed=17, step=0)
        idx = np.asarray(b["idx"])
        dense = np.asarray(b["dense"])
        rows = self._rows_for(idx)
        with obs.span("frontend_warmup", cat="offline"):
            self.ladder.warm(idx, rows, self.scheds)
            # warm the head on every rung's pooled dtype
            here = self.ladder.rung_i
            try:
                for i in range(len(RUNGS) - 1):
                    self.ladder.rung_i = i
                    pooled = self.ladder.pooled(idx, rows, self.scheds)
                    jax.block_until_ready(
                        _head_jit(self.params, dense, pooled, self.cfg)
                    )
            finally:
                self.ladder.rung_i = here
            walls = []
            for k in range(max(1, fcfg.warmup_batches)):
                walls.append(self._dispatch_wall(idx, dense, rows))
        self._s0 = float(np.median(walls))
        self._calibrated = True
        self.scheds = self._fresh_residency()
        return self._s0

    def _service_s(self, wall_s: float) -> float:
        """Measured wall -> virtual service time per the configured mode."""
        if self.fcfg.service_mode == "fixed":
            return self.fcfg.service_unit_s
        return wall_s / max(self._s0, 1e-9) * self.fcfg.service_unit_s

    # -- admission ------------------------------------------------------------

    def _admit(self, pending, queue, now_s: float) -> None:
        st, fcfg = self.stats, self.fcfg
        while pending and pending[0].t_arrive_s <= now_s:
            r = pending.popleft()
            if self.ladder.shedding:
                st.shed_mode += 1
                obs.inc("serve/frontend/shed_mode")
            elif len(queue) >= fcfg.queue_cap:
                if fcfg.shed_policy == "reject_new":
                    st.shed_reject += 1
                    obs.inc("serve/frontend/shed_reject")
                else:                    # drop_oldest: evict, admit the new
                    queue.popleft()
                    st.shed_evict += 1
                    st.admitted += 1
                    queue.append(r)
                    obs.inc("serve/frontend/shed_evict")
            else:
                st.admitted += 1
                queue.append(r)

    # -- the serving loop -----------------------------------------------------

    def run(self, requests: list[Request]) -> dict:
        """Serve one request stream to completion; returns the full report."""
        fcfg, st = self.fcfg, self.stats
        if not self._calibrated:
            self.calibrate()
        pending = collections.deque(
            sorted(requests, key=lambda r: r.t_arrive_s)
        )
        st.generated = len(pending)
        queue: collections.deque = collections.deque()
        now = 0.0
        batch_i = 0
        req_lat: list[float] = []        # per-served/missed request latency
        batch_lat: list[float] = []
        guard = 0

        while pending or queue:
            guard += 1
            if guard > 100 * max(1, st.generated):
                raise RuntimeError("frontend made no progress (loop guard)")
            self._admit(pending, queue, now)

            if self.ladder.shedding:
                # drain tick: shed everything, let time pass, probe recovery
                while queue:
                    queue.popleft()
                    st.shed_mode += 1
                    obs.inc("serve/frontend/shed_mode")
                now += fcfg.service_unit_s
                self.faults.advance(now)
                self.ladder.on_batch(
                    batch_i=batch_i, now_s=now, alerts=(), fast_burn=0.0,
                    replica_lost=self.faults.replica_lost(),
                )
                batch_i += 1
                continue

            if not queue:
                if not pending:
                    break
                now = max(now, pending[0].t_arrive_s)
                continue

            # close on size-or-deadline: wait for a full batch only while the
            # oldest request's assembly window is still open
            close_t = queue[0].t_arrive_s + fcfg.assembly_timeout_s
            if len(queue) < fcfg.batch_size:
                nxt = pending[0].t_arrive_s if pending else float("inf")
                if nxt <= close_t:
                    now = max(now, nxt)
                    continue                 # admit the arrival first
                now = max(now, close_t)      # window expired: dispatch partial

            batch = self._take_batch(queue)
            done = self._dispatch_batch(batch, batch_i, now)
            if done is not None:
                now, blat = done
                batch_lat.append(blat)
                for r in batch:
                    lat = now - r.t_arrive_s
                    req_lat.append(lat)
                    if now <= r.deadline_s:
                        st.served += 1
                    else:
                        st.deadline_missed += 1
                obs.inc("serve/frontend/served_batch")
            batch_i += 1
            st.batches += 1

        return self._report(req_lat, batch_lat, now)

    def _take_batch(self, queue: collections.deque) -> list[Request]:
        """Pop the next batch per the configured queue order.

        ``fifo`` serves arrival order; ``edf`` picks the ``batch_size``
        requests with the earliest absolute deadlines (ties broken by
        arrival) — urgent requests jump the line, so under backlog the
        requests most likely to miss are exactly the ones dispatched first.
        Removal keeps the deque arrival-ordered either way, so the
        size-or-deadline assembly window (anchored at ``queue[0]``) and
        ``drop_oldest`` eviction are unaffected.
        """
        k = min(self.fcfg.batch_size, len(queue))
        if self.fcfg.queue_order == "fifo":
            return [queue.popleft() for _ in range(k)]
        picks = sorted(
            range(len(queue)),
            key=lambda i: (queue[i].deadline_s, queue[i].t_arrive_s),
        )[:k]
        batch = [queue[i] for i in picks]
        for i in sorted(picks, reverse=True):
            del queue[i]
        return batch

    def _dispatch_batch(self, batch: list[Request], batch_i: int,
                        now: float):
        """Dispatch with retry/backoff; returns (completion_s, batch_latency)
        or None when the batch is abandoned.  Advances fault state, feeds the
        SLO engine and the ladder either way."""
        fcfg, st = self.fcfg, self.stats
        spec = self.faults.spec
        B = fcfg.batch_size
        idx = np.stack([r.idx for r in batch]
                       + [batch[-1].idx] * (B - len(batch)))
        dense = np.stack([r.dense for r in batch]
                         + [batch[-1].dense] * (B - len(batch)))
        rows = self._rows_for(idx)
        if self.adapt is not None:          # sketch feed: O(bag) per batch
            self.adapt.observe(idx)

        self.faults.advance(now)
        stall = self.faults.consume_stall_s()
        if stall > 0:
            st.stall_s_injected += stall
            obs.inc("serve/frontend/stalls")

        if self.ladder.prefetch_enabled:
            if self.faults.consume_prefetch_drop():
                obs.inc("serve/frontend/prefetch_dropped")
            else:
                with obs.span("prefetch", cat="serve"):
                    for t in range(self.cfg.num_tables):
                        self.scheds[t].prefetch(rows[:, t])

        wall = None
        for attempt in range(spec.max_retries + 1):
            try:
                self.faults.check_gather()
                wall = self._dispatch_wall(idx, dense, rows)
                break
            except TransientGatherError:
                st.retries += 1
                obs.inc("serve/frontend/retries")
                if attempt >= spec.max_retries:
                    break
                now += spec.backoff_s(attempt)
                self.faults.advance(now)

        replica_lost = self.faults.replica_lost()
        if wall is None:                      # retries exhausted: abandon
            st.abandoned += len(batch)
            obs.inc("serve/frontend/abandoned", len(batch))
            # a failed batch is a bad event for the SLO — the ladder must see
            # the failure even though no latency was produced
            bad = 10.0 * (self.slo.spec.p99_latency_s or 1.0) if self.slo else 0.0
            alerts = self.slo.observe(bad) if self.slo else []
            fast = (self.slo.burn_rate(self.slo.spec.fast_window)
                    if self.slo else self.ladder.policy.enter_burn)
            self.ladder.on_batch(batch_i=batch_i, now_s=now, alerts=alerts,
                                 fast_burn=fast, replica_lost=replica_lost)
            return None

        service = self._service_s(wall) + stall
        done = now + service
        blat = done - min(r.t_arrive_s for r in batch)   # worst request
        alerts = self.slo.observe(blat) if self.slo else []
        fast = self.slo.burn_rate(self.slo.spec.fast_window) if self.slo else 0.0
        obs.observe("serve/frontend/batch_latency_s", blat)
        obs.observe_batch(batch=batch_i, mode="frontend", latency_s=blat)
        self.ladder.on_batch(batch_i=batch_i, now_s=done, alerts=alerts,
                             fast_burn=fast, replica_lost=replica_lost)
        if self.adapt is not None:
            self.adapt.step(self.scheds)
            self.adapt.maybe_refit(getattr(self.state, "drift", None))
        return done, blat

    # -- report ---------------------------------------------------------------

    def _report(self, req_lat: list[float], batch_lat: list[float],
                end_s: float) -> dict:
        st = self.stats
        stats = [s.stats for s in self.scheds]
        hits = sum(s.hits for s in stats)
        acc = sum(s.accesses for s in stats)
        recoveries = recovery_times(self.ladder.transitions)
        report = {
            "requests": st.describe(),
            "deadline_miss_rate": st.deadline_missed / max(1, st.generated),
            "shed_rate": st.shed_total / max(1, st.generated),
            "virtual_end_s": end_s,
            "virtual_qps": st.served / max(end_s, 1e-9),
            **{f"req_{k}": v
               for k, v in obs.latency_percentiles(req_lat).items()},
            **{f"batch_{k}": v
               for k, v in obs.latency_percentiles(batch_lat).items()},
            "hit_rate": hits / max(1, acc),
            "degrade": self.ladder.describe(),
            "recoveries_s": recoveries,
            "time_to_recover_s": max(recoveries) if recoveries else None,
            "faults_injected": list(self.faults.injected),
            "calibration": {
                "s0_wall_s": self._s0,
                "service_unit_s": self.fcfg.service_unit_s,
                "service_mode": self.fcfg.service_mode,
            },
            "frontend": self.fcfg.describe(),
        }
        if self.adapt is not None:
            report["adapt"] = {
                **self.adapt.summary(), "event_log": list(self.adapt.events),
            }
        if self.slo is not None:
            report["slo"] = self.slo.state()
        return report


def recovery_times(transitions: list[dict]) -> list[float]:
    """Virtual seconds from each departure-from-full to the next return.

    A degradation episode opens when the ladder leaves ``full`` and closes
    when it next arrives back; unfinished episodes are excluded (the report's
    ``time_to_recover_s`` is None when nothing recovered).
    """
    out: list[float] = []
    open_t: float | None = None
    for tr in transitions:
        if tr["from"] == "full" and open_t is None:
            open_t = tr["t_s"]
        if tr["to"] == "full" and open_t is not None:
            out.append(tr["t_s"] - open_t)
            open_t = None
    return out


@functools.partial(jax.jit, static_argnames=("cfg",))
def _head_jit(params, dense, pooled, cfg):
    return dlrm.forward_from_pooled(params, dense, pooled, cfg)
