"""Deterministic fault injection for the serving front end.

Chaos testing only pays off when a failure reproduces: every fault here is a
:class:`FaultEvent` pinned to a **virtual** timestamp, so the same spec +
seed produces the same outage at the same batch on any host.  Four fault
kinds cover the pipeline's distinct failure surfaces:

* ``stall``    — the dispatch path freezes for ``duration_s`` virtual
  seconds (a straggling device, a preempted host thread).  Consumed by the
  front end as extra service time on the next dispatched batch.
* ``drop``     — the prefetch staging for the next batch is lost (a missed
  DMA window); the cache serves stale residency, so hit rate degrades but
  nothing crashes.
* ``replica``  — a model-parallel replica goes silent for ``duration_s``:
  its heartbeat (:class:`repro.distributed.elastic.Heartbeat`, driven on
  this injector's virtual clock) stops, the front end sees
  ``replica_lost()`` once the watermark stalls past the detection deadline,
  and the degradation ladder is forced off the sharded path until the
  replica beats again.
* ``gather``   — the next ``count`` gather dispatches raise
  :class:`TransientGatherError` (a flaky interconnect read); the front end
  retries with exponential backoff and abandons the batch when retries
  exhaust.

The injector is advanced by the front end (``advance(now)``) before every
dispatch; faults whose time has come latch into pending state and are
consumed exactly once.
"""

from __future__ import annotations

import dataclasses

from repro.distributed.elastic import Heartbeat

KINDS = ("stall", "drop", "replica", "gather")


class TransientGatherError(RuntimeError):
    """A retryable failure of one packed-gather dispatch."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault at a virtual timestamp."""

    t_s: float
    kind: str                       # stall | drop | replica | gather
    duration_s: float = 0.0         # stall length / replica outage
    count: int = 1                  # gather: consecutive failing dispatches
    host: int = 1                   # replica: which host goes silent

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} (known: {KINDS})")

    def describe(self) -> dict:
        return {
            "t_s": self.t_s, "kind": self.kind,
            "duration_s": self.duration_s, "count": self.count,
            "host": self.host,
        }


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """A deterministic fault schedule plus the retry policy."""

    events: tuple[FaultEvent, ...] = ()
    max_retries: int = 3
    backoff_base_s: float = 0.005    # virtual seconds before retry 1
    backoff_factor: float = 2.0
    hosts: int = 4                   # replica fleet size the heartbeat tracks
    hb_deadline_s: float = 0.05      # heartbeat stall -> failure detection

    def backoff_s(self, attempt: int) -> float:
        """Virtual backoff before retry ``attempt`` (0-based)."""
        return self.backoff_base_s * self.backoff_factor ** attempt

    def describe(self) -> dict:
        return {
            "events": [e.describe() for e in self.events],
            "max_retries": self.max_retries,
            "backoff_base_s": self.backoff_base_s,
            "backoff_factor": self.backoff_factor,
            "hosts": self.hosts,
            "hb_deadline_s": self.hb_deadline_s,
        }

    # -- CLI form -------------------------------------------------------------

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        """Parse the ``--faults`` form:
        ``"stall@1.0:0.5,drop@1.5,replica@2.0:1.0,gather@3.0:2,retries=3"``.

        ``KIND@T[:X]`` — X is seconds for stall/replica, a dispatch count
        for gather, ignored for drop.  ``retries=N`` / ``backoff_ms=M`` /
        ``hosts=H`` set the policy fields.
        """
        events: list[FaultEvent] = []
        kw: dict = {}
        for tok in filter(None, (t.strip() for t in text.split(","))):
            if "=" in tok and "@" not in tok:
                k, v = (s.strip() for s in tok.split("=", 1))
                if k == "retries":
                    kw["max_retries"] = int(v)
                elif k == "backoff_ms":
                    kw["backoff_base_s"] = float(v) * 1e-3
                elif k == "hosts":
                    kw["hosts"] = int(v)
                elif k == "hb_deadline_ms":
                    kw["hb_deadline_s"] = float(v) * 1e-3
                else:
                    raise ValueError(f"unknown --faults key {k!r}")
                continue
            if "@" not in tok:
                raise ValueError(f"bad --faults token {tok!r} (want KIND@T[:X])")
            kind, rest = tok.split("@", 1)
            t_s, _, x = rest.partition(":")
            ev = {"t_s": float(t_s), "kind": kind.strip()}
            if x:
                if kind.strip() == "gather":
                    ev["count"] = int(x)
                else:
                    ev["duration_s"] = float(x)
            events.append(FaultEvent(**ev))
        events.sort(key=lambda e: e.t_s)
        return cls(events=tuple(events), **kw)


class FaultInjector:
    """Replays a :class:`FaultSpec` on the front end's virtual clock.

    ``advance(now)`` latches every event whose time has come; the front end
    then consumes pending faults exactly once per dispatch.  Replica loss is
    realized through a real :class:`Heartbeat` (injected virtual clock): the
    lost host simply stops beating, and detection falls out of the same
    watermark logic production uses — nothing here fakes the failure signal.
    """

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self._events = sorted(spec.events, key=lambda e: e.t_s)
        self._cursor = 0
        self.now_s = 0.0
        self._pending_stall_s = 0.0
        self._pending_drops = 0
        self._pending_gather_errors = 0
        # outages: host -> virtual end time; the host beats again after it
        self._outages: dict[int, float] = {}
        self.heartbeat = Heartbeat(
            deadline_s=spec.hb_deadline_s, clock=lambda: self.now_s
        )
        for h in range(spec.hosts):
            self.heartbeat.beat(h, step=0, now=0.0)
        self._step = 0
        self.injected: list[dict] = []   # every latched event, with latch time

    # -- clock ----------------------------------------------------------------

    def advance(self, now_s: float) -> list[FaultEvent]:
        """Move the virtual clock forward; latch and return due events."""
        self.now_s = max(self.now_s, float(now_s))
        due: list[FaultEvent] = []
        while (self._cursor < len(self._events)
               and self._events[self._cursor].t_s <= self.now_s):
            ev = self._events[self._cursor]
            self._cursor += 1
            due.append(ev)
            self.injected.append({**ev.describe(), "latched_at_s": self.now_s})
            if ev.kind == "stall":
                self._pending_stall_s += ev.duration_s
            elif ev.kind == "drop":
                self._pending_drops += 1
            elif ev.kind == "gather":
                self._pending_gather_errors += ev.count
            elif ev.kind == "replica":
                self._outages[ev.host] = max(
                    self._outages.get(ev.host, 0.0), ev.t_s + ev.duration_s
                )
        # every host outside an outage window beats; outage hosts go silent
        self._step += 1
        for h in range(self.spec.hosts):
            end = self._outages.get(h)
            if end is not None and self.now_s < end:
                continue
            if end is not None:
                del self._outages[h]     # outage over: the host beats again
            self.heartbeat.beat(h, step=self._step)
        return due

    # -- consumption (each exactly once) ---------------------------------------

    def consume_stall_s(self) -> float:
        """Pending dispatch-stall seconds; zero after consumption."""
        s, self._pending_stall_s = self._pending_stall_s, 0.0
        return s

    def consume_prefetch_drop(self) -> bool:
        """True when the next prefetch should be dropped (consumes one)."""
        if self._pending_drops > 0:
            self._pending_drops -= 1
            return True
        return False

    def check_gather(self) -> None:
        """Raise :class:`TransientGatherError` while armed errors remain."""
        if self._pending_gather_errors > 0:
            self._pending_gather_errors -= 1
            raise TransientGatherError(
                f"injected transient gather failure at t={self.now_s:.3f}s "
                f"({self._pending_gather_errors} more armed)"
            )

    def replica_lost(self) -> bool:
        """True while any replica's heartbeat watermark is stalled."""
        return bool(self.heartbeat.failed_hosts())

    def lost_hosts(self) -> list[int]:
        return self.heartbeat.failed_hosts()

    def exhausted(self) -> bool:
        """True once every scheduled event has latched."""
        return self._cursor >= len(self._events)
