"""The graceful-degradation ladder: trade technique for survivability.

ProactivePIM's serving wins stack three techniques — the packed megakernel,
the proactive SRAM cache, and subtable duplication.  Each is also a
dependency that can misbehave under stress, so the ladder orders the
execution paths from fastest to most conservative and walks down one rung at
a time when the SLO burns or a fault lands:

====  ==========  ====================================================
rung  name        execution path
====  ==========  ====================================================
0     full        packed megakernel + prefetch cache (the normal path)
1     nocache     same megakernel, all-miss slot map, prefetch stopped
2     pertable    one packed-kernel dispatch per table (no shared
                  layout, no cross-table blast radius)
3     baseline    the jnp reference gather (no Pallas at all)
4     shed        stop admitting; drain and recover
====  ==========  ====================================================

Numerics contract (asserted by ``tests/test_serve_frontend.py``): rungs 0–2
share the packed kernel program, so their pooled outputs are **bitwise
identical** — a mid-stream rung change is invisible to the model.  Rung 3 is
a different numeric program (jnp one-hot matmul vs the kernel's gather), so
it matches the engine's own ``multi_bag_lookup`` reference bitwise and the
kernel rungs only to float tolerance — documented, by design.

Transitions are governed by hysteresis (no rung change within
``hysteresis_batches`` of the last one) and recover by probing: after
``probe_after`` consecutive good batches the ladder steps *up* one rung and
watches whether the burn returns.  Replica loss clamps the ladder at
``floor_on_replica_loss`` or below until the replica's heartbeat returns.
Every transition goes through ``repro.obs`` (a counter + an instant event),
so flight-recorder dumps show exactly when and why the ladder moved.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engine_mod
from repro import obs
from repro.core import embedding_bag, packed_tables

RUNGS = ("full", "nocache", "pertable", "baseline", "shed")


@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """When the ladder moves.

    ``enter_burn`` — a fast-window burn rate at/above this (or any page
    alert) steps down one rung; ``recover_burn`` — a batch only counts
    toward the recovery streak when the fast burn is strictly below it.
    """

    enter_burn: float = 10.0
    recover_burn: float = 1.0
    hysteresis_batches: int = 2
    probe_after: int = 4
    floor_on_replica_loss: str = "pertable"

    def __post_init__(self):
        if self.floor_on_replica_loss not in RUNGS:
            raise ValueError(
                f"unknown floor rung {self.floor_on_replica_loss!r}"
            )

    def describe(self) -> dict:
        return {
            "enter_burn": self.enter_burn,
            "recover_burn": self.recover_burn,
            "hysteresis_batches": self.hysteresis_batches,
            "probe_after": self.probe_after,
            "floor_on_replica_loss": self.floor_on_replica_loss,
        }


class DegradationLadder:
    """Owns every rung's executable path plus the transition state machine.

    ``state`` is the serve-front ``ServeState`` (the compiled engine);
    ``params`` the DLRM params whose tables the rungs gather from.  The
    per-table engines and the jnp baseline are built lazily on first use and
    cached; :meth:`warm` precompiles every rung so a mid-storm transition
    never pays a compile inside a latency sample.
    """

    def __init__(self, state, params, policy: DegradePolicy | None = None):
        self.state = state
        self.params = params
        self.policy = policy or DegradePolicy()
        self.rung_i = 0
        self.transitions: list[dict] = []
        self.batches_at = {r: 0 for r in RUNGS}
        self._good_streak = 0
        self._last_transition_batch = -10**9
        self._replica_floor_active = False

        eng = state.engine
        self._packed = eng.pack(params["tables"])
        total_slots = int(sum(state.slot_budgets))
        # all-miss dispatches still pass a cache block of the plan's shape so
        # rungs 0-2 share one compiled program (values unreachable: slot=-1)
        self._zero_cache_rows = np.zeros(max(1, total_slots), np.int32)
        self._pertable = None       # built lazily: [(engine, packed, zeros)]
        self._baseline_fn = None

    # -- rung state ------------------------------------------------------------

    @property
    def rung(self) -> str:
        return RUNGS[self.rung_i]

    @property
    def shedding(self) -> bool:
        return self.rung == "shed"

    @property
    def prefetch_enabled(self) -> bool:
        """Only the full rung stages rows (the cache is bypassed below it)."""
        return self.rung == "full"

    # -- execution paths -------------------------------------------------------

    def _pertable_paths(self):
        if self._pertable is None:
            spec = self.state.engine.spec
            paths = []
            for t, bag in enumerate(self.state.bags):
                spec_t = spec.replace(bags=(bag,), duplication=False)
                eng_t = engine_mod.compile(engine_mod.plan(spec_t, num_shards=1))
                packed_t = eng_t.pack([self.params["tables"][t]])
                zeros_t = np.zeros(
                    max(1, int(sum(eng_t.plan.slot_budgets))), np.int32
                )
                paths.append((eng_t, packed_t, zeros_t))
            self._pertable = paths
        return self._pertable

    def _baseline(self):
        if self._baseline_fn is None:
            bags = tuple(self.state.bags)
            tables = self.params["tables"]

            @jax.jit
            def fn(idx):
                return embedding_bag.multi_bag_lookup(tables, idx, bags)

            self._baseline_fn = fn
        return self._baseline_fn

    def pooled(self, idx_np: np.ndarray, rows_np: np.ndarray, scheds):
        """One batch's pooled embeddings via the current rung.

        ``idx_np`` (B, T, K) logical indices; ``rows_np`` (B, T, K) the
        big-subtable rows (the cached stream); ``scheds`` the live prefetch
        schedulers (consumed only on the full rung).
        """
        rung = self.rung
        if rung == "shed":
            raise RuntimeError("ladder is shedding; no batches may dispatch")
        eng = self.state.engine
        idx = jnp.asarray(idx_np)
        if rung == "full":
            slot = np.stack(
                [scheds[i].slots_for(rows_np[:, i])
                 for i in range(len(scheds))], axis=1,
            )
            cache_rows = eng.packed_cache_rows(scheds)
            return eng.serve_gather(
                self._packed, idx, jnp.asarray(slot), jnp.asarray(cache_rows)
            )
        if rung == "nocache":
            return eng.serve_gather(
                self._packed, idx, packed_tables.miss_slots(idx),
                jnp.asarray(self._zero_cache_rows),
            )
        if rung == "pertable":
            parts = []
            for t, (eng_t, packed_t, zeros_t) in enumerate(self._pertable_paths()):
                idx_t = idx[:, t:t + 1]
                parts.append(eng_t.serve_gather(
                    packed_t, idx_t, packed_tables.miss_slots(idx_t),
                    jnp.asarray(zeros_t),
                ))
            return jnp.concatenate(parts, axis=1)
        return self._baseline()(idx)

    def warm(self, idx_np: np.ndarray, rows_np: np.ndarray, scheds) -> None:
        """Precompile every executable rung on a sample batch (setup time)."""
        here = self.rung_i
        try:
            for i, r in enumerate(RUNGS[:-1]):
                self.rung_i = i
                jax.block_until_ready(self.pooled(idx_np, rows_np, scheds))
        finally:
            self.rung_i = here

    # -- transition state machine ---------------------------------------------

    def _floor_i(self) -> int:
        if self._replica_floor_active:
            return RUNGS.index(self.policy.floor_on_replica_loss)
        return 0

    def _move(self, to_i: int, *, batch_i: int, now_s: float, reason: str):
        frm, to = self.rung, RUNGS[to_i]
        self.rung_i = to_i
        self._good_streak = 0
        self._last_transition_batch = batch_i
        self.transitions.append({
            "at_batch": batch_i, "t_s": float(now_s),
            "from": frm, "to": to, "reason": reason,
        })
        obs.inc(f"serve/degrade/to_{to}")
        obs.inc("serve/degrade/transitions")
        obs.instant("degrade_transition", cat="serve",
                    frm=frm, to=to, reason=reason, batch=batch_i)

    def on_batch(self, *, batch_i: int, now_s: float, alerts=(),
                 fast_burn: float = 0.0, replica_lost: bool = False) -> None:
        """Feed one completed (or attempted) batch's signals; maybe move.

        ``alerts`` are the SLO engine's fired alerts for this observation,
        ``fast_burn`` its current fast-window burn rate.  Replica loss is
        level-triggered: while asserted the ladder cannot sit above the
        policy floor, and its onset bypasses hysteresis (a half-lost mesh
        cannot wait politely).
        """
        pol = self.policy
        self.batches_at[self.rung] += 1

        if replica_lost and not self._replica_floor_active:
            self._replica_floor_active = True
            floor = RUNGS.index(pol.floor_on_replica_loss)
            if self.rung_i < floor:
                self._move(floor, batch_i=batch_i, now_s=now_s,
                           reason="replica_loss")
                return
        elif not replica_lost:
            self._replica_floor_active = False

        burning = (fast_burn >= pol.enter_burn
                   or any(a.get("severity") == "page" for a in alerts))
        settled = batch_i - self._last_transition_batch >= pol.hysteresis_batches

        if burning:
            self._good_streak = 0
            if settled and self.rung_i < len(RUNGS) - 1:
                self._move(self.rung_i + 1, batch_i=batch_i, now_s=now_s,
                           reason=f"burn={fast_burn:.1f}")
            return

        if fast_burn < pol.recover_burn and not alerts:
            self._good_streak += 1
            floor = self._floor_i()
            if (self._good_streak >= pol.probe_after and settled
                    and self.rung_i > floor):
                self._move(self.rung_i - 1, batch_i=batch_i, now_s=now_s,
                           reason=f"recovery_probe(streak={self._good_streak})")
        else:
            self._good_streak = 0

    def describe(self) -> dict:
        """JSON state: rung occupancy + the full transition log."""
        return {
            "rung": self.rung,
            "policy": self.policy.describe(),
            "batches_at": dict(self.batches_at),
            "transitions": list(self.transitions),
        }
