"""repro.serve — the resilient serving front end.

The engine's ``pack`` + ``serve_gather`` seam executes one batch; this
package wraps it with everything a production front end needs between the
wire and the kernel:

* ``arrival``  — seeded open-loop traffic (Poisson base rate, flash-crowd
  episodes, Zipf key drift) producing timestamped requests;
* ``frontend`` — bounded admission queue with load shedding, deadline-aware
  batch assembly, and per-request accounting (admitted = served + shed +
  deadline-missed, always);
* ``faults``   — a deterministic fault-injection harness (dispatch stalls,
  prefetch drops, replica loss via the elastic heartbeats, transient gather
  errors) with bounded retry + exponential backoff;
* ``degrade``  — the graceful-degradation ladder (full packed+cached →
  prefetch off → per-table kernels → baseline jnp → shed) driven by SLO
  burn-rate signals and fault events, with hysteresis and recovery probes.

All timing is on a **virtual clock**: measured kernel wall-time is
normalized by a calibrated warm-up median and scaled to a nominal service
unit, so arrival pressure, deadlines, SLO burns, and backoff are
host-speed-independent — the chaos CI gate asserts exact behavior, not
timing luck.
"""

from repro.serve.arrival import ArrivalSpec, FlashEpisode, Request, generate  # noqa: F401
from repro.serve.degrade import RUNGS, DegradationLadder, DegradePolicy  # noqa: F401
from repro.serve.faults import (  # noqa: F401
    FaultEvent, FaultInjector, FaultSpec, TransientGatherError,
)
from repro.serve.frontend import (  # noqa: F401
    Frontend, FrontendConfig, FrontendStats,
)
