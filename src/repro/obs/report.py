"""One serving-report artifact: snapshot + SLO state + attribution, rendered
as markdown (for humans/CI summaries) and JSON (for dashboards/joins).

The observatory's terminal product.  ``serve_rec --report report.md`` builds
it from the session's metric snapshot, the :class:`~repro.obs.slo.SLOEngine`
state, the :class:`~repro.obs.attribution.Attribution` table, and the flight
recorder's dump index; the markdown lands at the given path and the JSON
twin next to it (``report.md`` -> ``report.json``).  The JSON schema is
versioned (``serving-report/v1``) and its attribution rows use the same
``stage-attribution/v1`` row schema ``benchmarks/roofline.py`` emits, so
serving reports and dry-run rooflines join on one vocabulary.
"""

from __future__ import annotations

import json
import os


SCHEMA = "serving-report/v1"


def build(*, snapshot=None, slo_state: dict | None = None,
          attribution=None, traffic: dict | None = None,
          results: dict | None = None, flight_dumps: list | None = None,
          meta: dict | None = None) -> dict:
    """Assemble the JSON report.  Every section is optional — the report
    carries what the session produced (``snapshot`` a ``RegistrySnapshot``,
    ``attribution`` an ``Attribution``, ``results`` the per-mode serve_rec
    records minus bulk arrays)."""
    return {
        "schema": SCHEMA,
        "meta": dict(meta or {}),
        "slo": slo_state,
        "attribution": attribution.describe() if attribution else None,
        "traffic": traffic,
        "results": results,
        "flight_dumps": list(flight_dumps or []),
        "metrics": snapshot.to_json() if snapshot is not None else None,
    }


def _fmt(v, spec: str = ".3f") -> str:
    return format(v, spec) if isinstance(v, (int, float)) else "—"


def _slo_md(slo: dict) -> list[str]:
    spec = slo["spec"]
    target = spec["p99_latency_s"]
    lines = [
        "## SLO",
        "",
        f"**{spec['name']}** — objective {spec['objective']}, "
        f"p99 target {_fmt(target * 1e3 if target else None)} ms, "
        f"windows {spec['fast_window']}/{spec['slow_window']} batches: "
        + ("**BREACHED**" if slo["breached"] else "met"),
        "",
        "| observations | bad | budget spent | budget remaining | "
        "fast burn | slow burn |",
        "|---|---|---|---|---|---|",
        f"| {slo['observations']} | {slo['bad_events']} | "
        f"{slo['budget_spent']} / {_fmt(slo['budget_allowed'], '.2f')} | "
        f"{_fmt(slo['budget_remaining_frac'] * 100, '.1f')}% | "
        f"{_fmt(slo['fast_burn'], '.2f')}x | "
        f"{_fmt(slo['slow_burn'], '.2f')}x |",
    ]
    if slo["alerts"]:
        lines += ["", "Alerts:", ""]
        lines += [
            f"- `{a['severity']}` at batch {a['at_batch']}: fast burn "
            f"{a['fast_burn']:.1f}x / slow burn {a['slow_burn']:.1f}x "
            f"(threshold {a['threshold']}x)"
            for a in slo["alerts"]
        ]
    for name, f in (slo.get("floors") or {}).items():
        verdict = "**BREACHED**" if f["breached"] else "met"
        lines.append(
            f"- {name} floor {f['floor']}: measured "
            f"{_fmt(f['measured'])} — {verdict}"
        )
    return lines


def render_markdown(report: dict, *, attribution=None) -> str:
    """The human-facing artifact.  ``attribution`` (the live object) renders
    its own table when given; otherwise the table is rebuilt from the JSON
    rows so a stored report re-renders identically."""
    meta = report.get("meta", {})
    out = [f"# Serving report — {meta.get('config', 'unknown config')}", ""]
    if meta:
        out += [
            "```",
            *(f"{k}: {v}" for k, v in sorted(meta.items())),
            "```",
            "",
        ]
    if report.get("slo"):
        out += _slo_md(report["slo"]) + [""]
    att = report.get("attribution")
    if att:
        out += [
            "## Where did the time go (per steady-state batch)",
            "",
            f"Bottleneck stage: **{att['bottleneck']}** — measured stage "
            f"total {_fmt(att['total_s'] * 1e3)} ms/batch, cost-model total "
            f"{_fmt(att['modeled_total_s'] * 1e3)} ms/batch"
            + ("" if att["fenced"] else
               " *(unfenced: device stages show enqueue cost)*"),
            "",
        ]
        if attribution is not None:
            out.append(attribution.format_table())
        else:
            out.append(_rows_table(att["rows"], att["bottleneck"]))
        lr = att.get("largest_residual")
        if lr:
            out += [
                "",
                f"Largest predicted-vs-measured residual: **{lr['stage']}** "
                f"({_fmt(lr['residual_s'] * 1e3)} ms — measured "
                f"{_fmt(lr['measured_s'] * 1e3)} ms vs modeled "
                f"{_fmt(lr['modeled_s'] * 1e3)} ms)",
            ]
        out.append("")
    tr = report.get("traffic")
    if tr:
        out += [
            "## Traffic",
            "",
            f"- cache hit rate {_fmt(tr['hit_rate'])} over "
            f"{tr['accesses']} accesses ({tr['batches']} batches)",
            f"- HBM {tr['hbm_cached_bytes']} B vs uncached baseline "
            f"{tr['hbm_baseline_bytes']} B "
            f"({_fmt(tr['hbm_reduction'], '.2f')}x)",
        ]
        if "comm_saved_bytes_per_batch" in tr:
            out.append(
                f"- comm killed by duplication: "
                f"{_fmt(tr['comm_saved_bytes_per_batch'], '.0f')} B/batch"
            )
        out.append("")
    res = report.get("results")
    if res:
        out += ["## Modes", ""]
        out += [
            "| mode | QPS | p50 ms | p95 ms | p99 ms | compile s |",
            "|---|---|---|---|---|---|",
        ]
        for mode, r in sorted(res.items()):
            out.append(
                f"| {mode} | {_fmt(r['qps'], '.1f')} | "
                f"{_fmt(r['lat_p50_s'] * 1e3)} | "
                f"{_fmt(r['lat_p95_s'] * 1e3)} | "
                f"{_fmt(r['lat_p99_s'] * 1e3)} | "
                f"{_fmt(r['compile_s'], '.2f')} |"
            )
        out.append("")
    dumps = report.get("flight_dumps")
    if dumps:
        out += ["## Flight-recorder dumps", ""]
        out += [
            f"- `{d.get('path', '<memory>')}` — {d['reason']} "
            f"(trigger batch {d.get('trigger_batch')}, "
            f"{d['records']} records)"
            for d in dumps
        ]
        out.append("")
    return "\n".join(out)


def _rows_table(rows: list[dict], bottleneck: str | None) -> str:
    """Re-render the attribution table from stored JSON rows."""
    def ms(v):
        return f"{v * 1e3:.3f}" if v is not None else "—"

    lines = [
        "| stage | measured ms | share | bytes/batch | achieved GB/s | "
        "modeled ms | modeled GB/s | residual ms | basis |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        mark = " **(bottleneck)**" if r["stage"] == bottleneck else ""
        share = (f"{r['share'] * 100:.1f}%" if r["share"] is not None
                 else "—")
        nbytes = (f"{r['bytes_per_batch']:.0f}"
                  if r["bytes_per_batch"] is not None else "—")
        gba = (f"{r['achieved_gbps']:.2f}"
               if r["achieved_gbps"] is not None else "—")
        gbm = (f"{r['modeled_gbps']:.2f}"
               if r["modeled_gbps"] is not None else "—")
        lines.append(
            f"| {r['stage']}{mark} | {ms(r['measured_s'])} | {share} | "
            f"{nbytes} | {gba} | {ms(r['modeled_s'])} | {gbm} | "
            f"{ms(r['residual_s'])} | {r['basis'] or '—'} |"
        )
    return "\n".join(lines)


def json_twin_path(md_path: str) -> str:
    root, ext = os.path.splitext(md_path)
    return (root if ext == ".md" else md_path) + ".json"


def write(report: dict, md_path: str, *, attribution=None) -> tuple[str, str]:
    """Write markdown to ``md_path`` and the JSON twin next to it; returns
    both paths."""
    with open(md_path, "w") as f:
        f.write(render_markdown(report, attribution=attribution))
        f.write("\n")
    jpath = json_twin_path(md_path)
    with open(jpath, "w") as f:
        json.dump(report, f, indent=1)
    return md_path, jpath
