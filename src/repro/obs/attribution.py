"""Per-stage roofline attribution: where did a serving batch's time go, and
does the cost model agree?

The paper's 2.2x is an attribution argument (traffic and CPU-PIM transfer,
stage by stage), so the reproduction needs the same decomposition as a
continuously-producible artifact.  This module joins three things the repo
already measures separately:

* **tracer spans** (``repro.obs.tracer``) — measured per-stage durations of
  the serving loop (``prefetch -> pack -> h2d -> dispatch -> device_compute
  -> interact``), honest when the run is fenced;
* **traffic accounting** (``repro.obs.traffic``) — exact per-batch byte
  movement: HBM stream (misses + staging DMA), staged rows, modeled
  cross-shard comm bytes;
* **the cost model** (``repro.tune.KernelCostModel``) — the fitted (or
  analytic) per-feature latency prediction the autotuner plans against.

The output is one table: per stage, measured seconds/batch, its share,
the bytes it moved, achieved GB/s (bytes / measured time), the modeled
seconds (cost-model term or bandwidth bound), and the predicted-vs-measured
residual — with the bottleneck stage and the largest residual flagged.  The
same row schema is emitted by ``benchmarks/roofline.py`` for the dry-run
records, so serving attribution and compile-time roofline join on one
vocabulary.

:func:`model_terms` is the single source of truth for converting byte/flop
counts into roofline seconds (``benchmarks/roofline`` routes through it).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.tune.cost_model import FEATURES, KernelCostModel

# canonical serving-stage order (the tracer's span names); device_head only
# exists on fenced runs (the head's own block_until_ready)
STAGES = ("prefetch", "pack", "h2d", "dispatch", "device_compute",
          "interact", "device_head")

SCHEMA = "stage-attribution/v1"


# ---------------------------------------------------------------------------
# shared roofline terms (benchmarks/roofline.py routes through this)
# ---------------------------------------------------------------------------

def _hw():
    from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

    return PEAK_FLOPS_BF16, HBM_BW, 2 * ICI_BW_PER_LINK


def model_terms(*, flops: float = 0.0, hbm_bytes: float = 0.0,
                wire_bytes: float = 0.0, peak_flops: float | None = None,
                hbm_bw: float | None = None, wire_bw: float | None = None
                ) -> dict:
    """Byte/flop counts -> perfect-overlap roofline seconds.

    One source of truth for the compute / memory / collective terms: the
    dry-run roofline and the serving attribution price bytes identically.
    """
    dpeak, dhbm, dwire = _hw()
    peak_flops = peak_flops or dpeak
    hbm_bw = hbm_bw or dhbm
    wire_bw = wire_bw or dwire
    compute = flops / peak_flops
    memory = hbm_bytes / hbm_bw
    collective = wire_bytes / wire_bw
    step = max(compute, memory, collective, 1e-12)
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "step_s": step,
        "dominant": dominant,
    }


def term_rows(terms: dict, *, hbm_bytes: float = 0.0, wire_bytes: float = 0.0
              ) -> list[dict]:
    """Roofline terms in the attribution row schema (modeled-only rows), so
    dry-run rooflines and serving attributions share one consumer format."""
    rows = []
    for stage, key, nbytes in (
        ("compute", "compute_s", None),
        ("memory", "memory_s", hbm_bytes),
        ("collective", "collective_s", wire_bytes),
    ):
        sec = terms[key]
        rows.append({
            "stage": stage,
            "measured_s": None,
            "share": None,
            "bytes_per_batch": nbytes,
            "achieved_gbps": None,
            "modeled_s": sec,
            "modeled_gbps": (
                nbytes / sec / 1e9 if nbytes and sec > 0 else None
            ),
            "residual_s": None,
            "basis": "roofline",
        })
    return rows


# ---------------------------------------------------------------------------
# the analytic fallback model (serving sessions without a fitted tuner)
# ---------------------------------------------------------------------------

def analytic_cost_model(backend: str = "packed") -> KernelCostModel:
    """A :class:`KernelCostModel` priced from the chip constants instead of a
    fit: dispatch at the tuner's launch-overhead estimate, bytes at HBM
    bandwidth, comm at the ICI wire rate (tiles free).  Used when a serving
    session has no fitted tuner — attribution still reports modeled GB/s."""
    from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK
    from repro.tune.tuner import DISPATCH_OVERHEAD_S

    coef = {
        "dispatches": DISPATCH_OVERHEAD_S,
        "hbm_bytes": 1.0 / HBM_BW,
        "row_tiles": 0.0,
        "comm_bytes": 1.0 / (2 * ICI_BW_PER_LINK),
    }
    return KernelCostModel(
        coef=tuple(coef[f] for f in FEATURES), backend=backend,
        source="analytic", num_samples=0,
    )


# ---------------------------------------------------------------------------
# measured stage durations from the tracer's events
# ---------------------------------------------------------------------------

def stage_durations(events, *, skip_batches=(0,)) -> dict[str, list[float]]:
    """Span name -> per-occurrence durations (seconds) over steady-state
    batches.  Batch 0 (the compile/warm-up batch) is skipped by default —
    its spans time compilation, not serving."""
    skip = set(skip_batches)
    out: dict[str, list[float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        args = ev.get("args", {})
        batch = args.get("batch")
        if batch is None or batch in skip or ev["name"] == "batch":
            continue
        out.setdefault(ev["name"], []).append(ev["dur"] * 1e-6)
    return out


# ---------------------------------------------------------------------------
# per-batch cost-model features from the traffic report + plan
# ---------------------------------------------------------------------------

def batch_features(plan, traffic, *, batch: int) -> dict:
    """Per-batch byte/feature accounting from an ``EmbeddingPlan`` + its
    session :class:`~repro.obs.traffic.TrafficReport`.

    Returns the cost model's feature vector (``dispatches``, ``hbm_bytes``,
    ``row_tiles``, ``comm_bytes``) plus the auxiliary per-stage byte counts
    attribution prices (``staged_bytes`` for the prefetch DMA, ``h2d_bytes``
    for the index upload).  All values are *per batch* — session totals are
    divided by the scheduler-observed batch count, so they reconcile exactly
    with ``TrafficReport.describe()``.
    """
    batches = max(1, traffic.batches)
    dispatches = 1.0 if plan.packed else float(len(plan.bags))
    hbm = traffic.hbm_cached_bytes / batches
    staged = sum(
        t["staged_rows"] * t["row_bytes"] for t in traffic.tables
    ) / batches
    tiles = 0.0
    for t in traffic.tables:
        width = max(1, t["row_bytes"] // 4)
        bd = plan.dim_block or width
        tiles += (t["accesses"] / batches) * max(1.0, width / min(bd, width))
    # index upload: idx + slot (int32 per access) + the packed cache-row list
    accesses = traffic.accesses / batches
    h2d = accesses * 4 * 2 + sum(plan.slot_budgets) * 4
    comm = 0.0
    if plan.dup is not None:
        dim = plan.bags[0].emb.dim
        comm = float(plan.dup.ici_bytes_per_batch(batch, dim)["duplicated"])
    return {
        "dispatches": dispatches,
        "hbm_bytes": float(hbm),
        "row_tiles": float(tiles),
        "comm_bytes": comm,
        "staged_bytes": float(staged),
        "h2d_bytes": float(h2d),
    }


# ---------------------------------------------------------------------------
# the attribution table
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StageRow:
    """One where-did-time-go row.  ``basis`` says where ``modeled_s`` came
    from: "cost_model" (a fitted/analytic KernelCostModel term),
    "bandwidth_bound" (bytes at peak HBM bandwidth), or None (no model)."""

    stage: str
    measured_s: float | None
    share: float | None
    bytes_per_batch: float | None
    modeled_s: float | None
    basis: str | None

    @property
    def achieved_gbps(self) -> float | None:
        if self.bytes_per_batch and self.measured_s:
            return self.bytes_per_batch / self.measured_s / 1e9
        return None

    @property
    def modeled_gbps(self) -> float | None:
        if self.bytes_per_batch and self.modeled_s:
            return self.bytes_per_batch / self.modeled_s / 1e9
        return None

    @property
    def residual_s(self) -> float | None:
        if self.measured_s is None or self.modeled_s is None:
            return None
        return self.measured_s - self.modeled_s

    def describe(self) -> dict:
        return {
            "stage": self.stage,
            "measured_s": self.measured_s,
            "share": self.share,
            "bytes_per_batch": self.bytes_per_batch,
            "achieved_gbps": self.achieved_gbps,
            "modeled_s": self.modeled_s,
            "modeled_gbps": self.modeled_gbps,
            "residual_s": self.residual_s,
            "basis": self.basis,
        }


@dataclasses.dataclass
class Attribution:
    """The joined table + verdicts."""

    rows: list                          # StageRow, canonical stage order
    bottleneck: str | None              # stage with the largest measured share
    total_s: float                      # summed measured stage seconds/batch
    model: KernelCostModel | None
    features: dict                      # batch_features() output
    fenced: bool                        # were span durations device-honest?

    @property
    def largest_residual(self) -> dict | None:
        """The stage where the cost model misses measurement the most."""
        cand = [
            r for r in self.rows
            if r.basis == "cost_model" and r.residual_s is not None
        ]
        if not cand:
            return None
        worst = max(cand, key=lambda r: abs(r.residual_s))
        return {
            "stage": worst.stage,
            "residual_s": worst.residual_s,
            "measured_s": worst.measured_s,
            "modeled_s": worst.modeled_s,
        }

    def modeled_total_s(self) -> float:
        """Sum of the cost-model stage terms — equals
        ``model.predict(features)`` by construction (tested)."""
        return sum(
            r.modeled_s for r in self.rows
            if r.basis == "cost_model" and r.modeled_s is not None
        )

    def describe(self) -> dict:
        return {
            "schema": SCHEMA,
            "fenced": self.fenced,
            "bottleneck": self.bottleneck,
            "total_s": self.total_s,
            "modeled_total_s": self.modeled_total_s(),
            "largest_residual": self.largest_residual,
            "model": self.model.describe() if self.model else None,
            "features": dict(self.features),
            "rows": [r.describe() for r in self.rows],
        }

    def format_table(self) -> str:
        """Markdown where-did-time-go table (the report artifact's core)."""
        def ms(v):
            return f"{v * 1e3:.3f}" if v is not None else "—"

        def gb(v):
            return f"{v:.2f}" if v is not None else "—"

        lines = [
            "| stage | measured ms | share | bytes/batch | achieved GB/s | "
            "modeled ms | modeled GB/s | residual ms | basis |",
            "|---|---|---|---|---|---|---|---|---|",
        ]
        for r in self.rows:
            mark = " **(bottleneck)**" if r.stage == self.bottleneck else ""
            share = f"{r.share * 100:.1f}%" if r.share is not None else "—"
            nbytes = (f"{r.bytes_per_batch:.0f}"
                      if r.bytes_per_batch is not None else "—")
            lines.append(
                f"| {r.stage}{mark} | {ms(r.measured_s)} | {share} | "
                f"{nbytes} | {gb(r.achieved_gbps)} | {ms(r.modeled_s)} | "
                f"{gb(r.modeled_gbps)} | {ms(r.residual_s)} | "
                f"{r.basis or '—'} |"
            )
        return "\n".join(lines)


def attribute(events, traffic, plan, *, batch: int,
              model: KernelCostModel | None = None,
              fenced: bool = False) -> Attribution:
    """Join tracer ``events`` + a session :class:`TrafficReport` + the plan
    into the per-stage attribution table.

    ``model=None`` falls back to :func:`analytic_cost_model` so a session
    without a fitted tuner still reports modeled seconds/GB/s.  Unfenced
    runs attribute *enqueue* cost to the device stages; the table records
    ``fenced`` so consumers know which they got.
    """
    if model is None:
        model = analytic_cost_model(
            "packed" if getattr(plan, "packed", True) else "pertable"
        )
    feats = batch_features(plan, traffic, batch=batch)
    coef = dict(zip(FEATURES, model.coef))
    durs = stage_durations(events)
    measured = {name: float(np.mean(vals)) for name, vals in durs.items()}
    total = sum(measured.values())

    # per-stage byte + model assignment
    _, hbm_bw, _ = _hw()
    modeled: dict[str, tuple[float, str]] = {
        "dispatch": (coef["dispatches"] * feats["dispatches"], "cost_model"),
        "device_compute": (
            coef["hbm_bytes"] * feats["hbm_bytes"]
            + coef["row_tiles"] * feats["row_tiles"],
            "cost_model",
        ),
        "prefetch": (feats["staged_bytes"] / hbm_bw, "bandwidth_bound"),
        "h2d": (feats["h2d_bytes"] / hbm_bw, "bandwidth_bound"),
    }
    stage_bytes = {
        "prefetch": feats["staged_bytes"],
        "h2d": feats["h2d_bytes"],
        "device_compute": feats["hbm_bytes"],
    }

    names = [s for s in STAGES if s in measured]
    names += sorted(set(measured) - set(STAGES))
    rows = []
    for name in names:
        m_s, basis = modeled.get(name, (None, None))
        rows.append(StageRow(
            stage=name,
            measured_s=measured[name],
            share=measured[name] / total if total > 0 else None,
            bytes_per_batch=stage_bytes.get(name),
            modeled_s=m_s,
            basis=basis,
        ))
    # keep the cost-model decomposition complete even when a stage had no
    # span (unfenced runs): modeled-only rows, so the sum of cost_model
    # terms always equals model.predict(features)
    for name in ("dispatch", "device_compute"):
        if name not in measured:
            m_s, basis = modeled[name]
            rows.append(StageRow(
                stage=name, measured_s=None, share=None,
                bytes_per_batch=stage_bytes.get(name),
                modeled_s=m_s, basis=basis,
            ))
    # the cross-shard combine has no host-side span at all
    rows.append(StageRow(
        stage="comm", measured_s=None, share=None,
        bytes_per_batch=feats["comm_bytes"] or None,
        modeled_s=coef["comm_bytes"] * feats["comm_bytes"],
        basis="cost_model",
    ))

    bottleneck = max(
        (r for r in rows if r.measured_s is not None),
        key=lambda r: r.measured_s, default=None,
    )
    return Attribution(
        rows=rows,
        bottleneck=bottleneck.stage if bottleneck else None,
        total_s=total,
        model=model,
        features=feats,
        fenced=fenced,
    )
