"""Cost-model drift monitoring: predicted-vs-measured residuals over a
sliding window, with a "re-fit recommended" trigger.

The autotuner (``repro.tune``) freezes a fitted linear cost model into the
plan at ``plan()`` time; traffic drifts, hosts change, and the memoized model
quietly goes stale.  The ROADMAP's online-adaptation item asks for exactly
this detector: keep observing (predicted, measured) latency pairs while
serving, and flag when the *relationship* between them moves.

Two complementary signals:

* **residual drift** — the model's relative residual ``(measured -
  predicted) / predicted`` is allowed a constant bias (an HLO-derived model
  can be uniformly 2x off and still rank knob settings perfectly); what
  matters is the *recent window's* median residual moving away from the
  *calibration* median (the first window observed, i.e. the regime the fit
  was trusted in);
* **rank-agreement decay** — the tuner only needs ordering, so the monitor
  also estimates Kendall-style pairwise agreement between predictions and
  measurements inside the recent window, ignoring pairs whose measured gap
  is under the host-noise floor.

``refit_recommended`` is the OR of the two triggers once ``min_points``
observations exist.  Purely host-side numpy; a monitor costs one append per
batch.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# measured differences below this relative gap are host noise — pairs inside
# it are unrankable and excluded from agreement (same floor benchmarks use)
DEFAULT_NOISE_REL = 0.10


def rank_agreement(pairs, *, noise_rel: float = DEFAULT_NOISE_REL
                   ) -> tuple[float, int]:
    """Pairwise order agreement of [(predicted, measured), ...].

    Returns ``(agreement, rankable_pairs)``; pairs whose measured values sit
    within ``noise_rel`` of each other are skipped (unrankable), and an
    all-tied set reports perfect agreement over zero pairs.
    """
    agree = counted = 0
    pairs = list(pairs)
    for i in range(len(pairs)):
        for j in range(i + 1, len(pairs)):
            pi, mi = pairs[i]
            pj, mj = pairs[j]
            if abs(mi - mj) <= noise_rel * max(abs(mi), abs(mj)):
                continue
            counted += 1
            if (pi - pj) * (mi - mj) > 0:
                agree += 1
    return (agree / counted if counted else 1.0), counted


@dataclasses.dataclass
class DriftMonitor:
    """Sliding-window predicted-vs-measured residual monitor.

    ``window`` — observations per window (calibration = the first window,
    recent = the last); ``rel_tol`` — residual-median shift that triggers;
    ``rank_floor`` — recent rank agreement below this triggers;
    ``min_points`` — no verdict before this many observations.
    """

    window: int = 32
    rel_tol: float = 0.25
    rank_floor: float = 0.7
    min_points: int = 8
    noise_rel: float = DEFAULT_NOISE_REL

    def __post_init__(self):
        self._pred: list[float] = []
        self._meas: list[float] = []

    # -- observation ---------------------------------------------------------

    def observe(self, predicted_s: float, measured_s: float) -> None:
        self._pred.append(float(predicted_s))
        self._meas.append(float(measured_s))

    @property
    def n(self) -> int:
        return len(self._meas)

    def residuals(self) -> np.ndarray:
        """(n,) relative residuals (measured - predicted) / predicted."""
        p = np.asarray(self._pred)
        m = np.asarray(self._meas)
        return (m - p) / np.maximum(np.abs(p), 1e-30)

    # -- verdict -------------------------------------------------------------

    def _median(self, arr: np.ndarray) -> float:
        return float(np.median(arr)) if arr.size else 0.0

    @property
    def calibration_residual(self) -> float:
        return self._median(self.residuals()[: self.window])

    @property
    def recent_residual(self) -> float:
        return self._median(self.residuals()[-self.window:])

    @property
    def drift(self) -> float:
        """Shift of the recent residual median away from calibration."""
        if self.n == 0:
            return 0.0
        return abs(self.recent_residual - self.calibration_residual)

    def recent_rank_agreement(self) -> tuple[float, int]:
        pairs = list(zip(self._pred[-self.window:], self._meas[-self.window:]))
        return rank_agreement(pairs, noise_rel=self.noise_rel)

    @property
    def refit_recommended(self) -> bool:
        """True once the model has visibly drifted: residual-median shift
        beyond ``rel_tol`` or recent rank agreement under ``rank_floor``."""
        if self.n < self.min_points:
            return False
        if self.drift > self.rel_tol:
            return True
        agreement, counted = self.recent_rank_agreement()
        return counted > 0 and agreement < self.rank_floor

    def summary(self) -> dict:
        agreement, counted = self.recent_rank_agreement()
        return {
            "observations": self.n,
            "window": self.window,
            "calibration_residual": self.calibration_residual,
            "recent_residual": self.recent_residual,
            "drift": self.drift,
            "rel_tol": self.rel_tol,
            "rank_agreement": agreement,
            "rankable_pairs": counted,
            "rank_floor": self.rank_floor,
            "refit_recommended": self.refit_recommended,
        }
