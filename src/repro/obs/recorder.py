"""Anomaly flight recorder: a bounded ring buffer of per-batch serving
records, frozen to disk when something goes wrong.

The observability PR's histograms tell you *that* p99 moved; the flight
recorder tells you *what the pipeline was doing* around the batches that
moved it.  Per steady-state batch, a :class:`BatchRecord` captures the stage
span durations (joined from the tracer's events), the engine's dispatch
counter deltas, the latency sample, and optionally the live traffic state.
Records land in a fixed-capacity ring (old batches fall off), and the ring
is **dumped as one JSON context window** when:

* an SLO burn-rate alert fires (``repro.obs.slo``), or
* a latency sample exceeds a robust MAD-based anomaly threshold:
  ``|x - median| > mad_k * 1.4826 * MAD`` over the history seen so far
  (median/MAD, not mean/stddev, so the threshold itself is not dragged by
  the outliers it is meant to catch).

Dumps are capped (``max_dumps``) so a persistently-burning session produces
a handful of windows, not thousands of files.  Everything here is host-side
and allocation-cheap; the recorder is only constructed when ``serve_rec``
runs with ``--flight-dir``/``--slo``/``--report``.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os

import numpy as np

# scale factor making MAD a consistent sigma estimator for normal data
MAD_SIGMA = 1.4826


@dataclasses.dataclass
class BatchRecord:
    """One steady-state batch, as the flight recorder remembers it."""

    batch: int
    mode: str
    latency_s: float
    stages: dict                      # span name -> duration seconds
    counters: dict                    # counter name -> delta since last record
    traffic: dict | None = None      # optional live traffic state
    anomaly: bool = False            # set by the recorder on MAD breach

    def describe(self) -> dict:
        return {
            "batch": self.batch,
            "mode": self.mode,
            "latency_s": self.latency_s,
            "stages": {k: float(v) for k, v in self.stages.items()},
            "counters": {k: int(v) for k, v in self.counters.items()},
            "traffic": self.traffic,
            "anomaly": self.anomaly,
        }


class TelemetryJoin:
    """Incremental join of the tracer's span stream + the counter registry
    into per-batch records.

    Keeps a cursor into ``tracer.events`` (each event is consumed once, so a
    long session never rescans) and the last counter snapshot (so records
    carry *deltas* — e.g. ``engine/dispatch/serve_gather: 1`` per batch).
    Span durations are keyed by the ``batch=`` arg the serving loop already
    attaches; spans without one (offline/pack-tables) are ignored.
    """

    def __init__(self, tracer, registry):
        self._tracer = tracer
        self._registry = registry
        self._cursor = 0
        self._last_counters: dict[str, int] = {
            k: c.value for k, c in registry.counters.items()
        }
        self._pending: dict[int, dict] = {}    # batch id -> {stage: seconds}

    def _drain_events(self) -> None:
        events = self._tracer.events
        for ev in events[self._cursor:]:
            if ev.get("ph") != "X":
                continue
            batch = ev.get("args", {}).get("batch")
            if batch is None:
                continue
            stages = self._pending.setdefault(int(batch), {})
            # accumulate: a re-dispatched stage (retries) sums its spans
            stages[ev["name"]] = (
                stages.get(ev["name"], 0.0) + ev["dur"] * 1e-6
            )
        self._cursor = len(events)

    def counter_deltas(self) -> dict:
        now = {k: c.value for k, c in self._registry.counters.items()}
        delta = {
            k: v - self._last_counters.get(k, 0)
            for k, v in now.items()
            if v - self._last_counters.get(k, 0)
        }
        self._last_counters = now
        return delta

    def next_record(self, *, batch: int, mode: str, latency_s: float,
                    traffic: dict | None = None) -> BatchRecord:
        self._drain_events()
        stages = self._pending.pop(int(batch), {})
        # drop the wrapping "batch" span — its children are the breakdown
        stages.pop("batch", None)
        return BatchRecord(
            batch=int(batch), mode=mode, latency_s=float(latency_s),
            stages=stages, counters=self.counter_deltas(), traffic=traffic,
        )


class Observatory:
    """The per-session decision bundle: SLO engine + flight recorder + the
    telemetry join, driven once per steady-state batch.

    ``serve_rec`` installs one via ``obs.install_observatory`` when ``--slo``
    / ``--flight-dir`` / ``--report`` is passed; the serving loop then calls
    the ``obs.observe_batch`` facade (a bool check when telemetry is off).
    """

    def __init__(self, *, slo=None, recorder=None, join=None):
        self.slo = slo                    # repro.obs.slo.SLOEngine | None
        self.recorder = recorder          # FlightRecorder | None
        self.join = join                  # TelemetryJoin | None

    def observe_batch(self, *, batch: int, mode: str, latency_s: float,
                      traffic: dict | None = None) -> dict:
        alerts = self.slo.observe(latency_s) if self.slo is not None else []
        record = dump = None
        if self.recorder is not None:
            if self.join is not None:
                record = self.join.next_record(
                    batch=batch, mode=mode, latency_s=latency_s,
                    traffic=traffic,
                )
            else:
                record = BatchRecord(batch=int(batch), mode=mode,
                                     latency_s=float(latency_s),
                                     stages={}, counters={}, traffic=traffic)
            dump = self.recorder.observe(record, alerts=alerts)
        return {"record": record, "alerts": alerts, "dump": dump}

    def state(self) -> dict:
        return {
            "slo": self.slo.state() if self.slo is not None else None,
            "flight_dumps": (self.recorder.dumps
                             if self.recorder is not None else []),
        }


class FlightRecorder:
    """Bounded ring of :class:`BatchRecord`s + dump-on-trigger logic.

    ``capacity`` bounds the ring (old records fall off); ``out_dir`` is where
    JSON context windows land; ``mad_k`` scales the robust anomaly threshold;
    ``min_history`` suppresses anomaly verdicts until enough latencies exist
    for the median/MAD to mean something; ``max_dumps`` caps files per
    session.
    """

    def __init__(self, capacity: int = 64, *, out_dir: str | None = None,
                 mad_k: float = 6.0, min_history: int = 8,
                 max_dumps: int = 4):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self.out_dir = out_dir
        self.mad_k = mad_k
        self.min_history = min_history
        self.max_dumps = max_dumps
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self._latencies: list[float] = []
        self._dumps: list[dict] = []        # {"path", "reason", "at_batch"}

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def records(self) -> list[BatchRecord]:
        return list(self._ring)

    @property
    def dumps(self) -> list[dict]:
        return list(self._dumps)

    # -- anomaly threshold ---------------------------------------------------

    def anomaly_threshold(self) -> float | None:
        """Current MAD-based latency cutoff (None before ``min_history``)."""
        if len(self._latencies) < self.min_history:
            return None
        arr = np.asarray(self._latencies)
        med = float(np.median(arr))
        mad = float(np.median(np.abs(arr - med)))
        # MAD collapses to 0 on near-constant histories; fall back to a
        # relative band so a 2x step on a flat baseline still triggers.
        spread = max(MAD_SIGMA * mad, 0.05 * med, 1e-9)
        return med + self.mad_k * spread

    def _is_anomaly(self, latency_s: float) -> bool:
        cut = self.anomaly_threshold()
        return cut is not None and latency_s > cut

    # -- the per-batch entry ---------------------------------------------------

    def observe(self, record: BatchRecord, *, alerts: list | tuple = ()
                ) -> dict | None:
        """Append one record; dump the ring when an SLO alert accompanied it
        or its latency breached the MAD threshold.  Returns the dump info
        dict (``{"path", "reason", ...}``) when a dump was written.

        The anomaly verdict uses the history *before* this record, so the
        triggering batch is judged against its past, then appended.
        """
        record.anomaly = self._is_anomaly(record.latency_s)
        self._ring.append(record)
        self._latencies.append(record.latency_s)
        reason = None
        if alerts:
            sev = sorted({a.get("severity", "alert") for a in alerts})
            reason = "slo_burn:" + "+".join(sev)
        elif record.anomaly:
            reason = "latency_anomaly"
        if reason is None:
            return None
        return self.dump(reason, context={
            "trigger_batch": record.batch,
            "trigger_latency_s": record.latency_s,
            "anomaly_threshold_s": self.anomaly_threshold(),
            "alerts": list(alerts),
        })

    # -- freezing ------------------------------------------------------------

    def to_json(self, reason: str, context: dict | None = None) -> dict:
        return {
            "reason": reason,
            "capacity": self.capacity,
            "mad_k": self.mad_k,
            "context": context or {},
            "records": [r.describe() for r in self._ring],
        }

    def dump(self, reason: str, context: dict | None = None) -> dict | None:
        """Freeze the ring to ``out_dir`` (None = record the dump in memory
        only).  Returns dump info, or None once ``max_dumps`` is exhausted."""
        if len(self._dumps) >= self.max_dumps:
            return None
        seq = len(self._dumps)
        info = {"reason": reason, "records": len(self._ring),
                "trigger_batch": (context or {}).get("trigger_batch")}
        if self.out_dir is not None:
            os.makedirs(self.out_dir, exist_ok=True)
            path = os.path.join(self.out_dir, f"flight_{seq:03d}.json")
            with open(path, "w") as f:
                json.dump(self.to_json(reason, context), f, indent=1)
            info["path"] = path
        self._dumps.append(info)
        return info
