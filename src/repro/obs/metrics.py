"""Counters, gauges, and log-bucketed latency histograms — numpy only.

The serving claims this repo makes are *distribution* claims (p99 latency
under Zipf traffic, traffic reduction per batch), so the primitive here is a
histogram, not a scalar.  Design points:

* **log-bucketed**: latency spans ~6 decades (us kernel dispatch to seconds
  of compile); bucket bounds are geometric (``buckets_per_decade`` per x10)
  so relative resolution is constant across the range;
* **exact quantiles**: every recorded value is also retained verbatim (a
  serving session records one value per batch — thousands, not billions), so
  ``percentile(q)`` is ``numpy.percentile`` over the raw samples, and the
  bucket counts are a lossy *view* for dashboards/merging, never the source
  of truth.  ``bucket_percentile`` is the interpolated fallback used after a
  merge discards samples (``drop_samples=True``);
* **mergeable snapshots**: per-shard / per-process registries snapshot into
  plain dataclasses that merge associatively (counters add, histograms
  concatenate), so a fleet's metrics reduce like the psum tree they measure.

Everything is host-side and dependency-free (numpy only); the module-level
enable/disable switch lives in ``repro.obs`` — when disabled, the facade
never touches these classes at all.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# default bucket range: 1us .. 1000s, 5 buckets per decade (~58% ratio steps)
DEFAULT_LO = 1e-6
DEFAULT_HI = 1e3
DEFAULT_PER_DECADE = 5


def log_bounds(
    lo: float = DEFAULT_LO, hi: float = DEFAULT_HI,
    per_decade: int = DEFAULT_PER_DECADE,
) -> np.ndarray:
    """Geometric bucket bounds covering [lo, hi] (len = buckets + 1)."""
    if not (lo > 0 and hi > lo and per_decade > 0):
        raise ValueError(f"bad bucket spec lo={lo} hi={hi}/{per_decade}")
    decades = np.log10(hi / lo)
    n = int(np.ceil(decades * per_decade))
    return lo * 10.0 ** (np.arange(n + 1) / per_decade)


def exact_percentile(samples, q: float) -> float:
    """THE exact-quantile definition every serving number in this repo uses:
    ``numpy.percentile`` over raw samples, 0.0 when empty.  ``Histogram``,
    ``HistogramSnapshot``, and ``serve_rec``'s result records all route
    through here, so a histogram snapshot and a serving record computed from
    the same samples can never disagree."""
    samples = np.asarray(samples, dtype=np.float64)
    if samples.size == 0:
        return 0.0
    return float(np.percentile(samples, q))


def latency_percentiles(samples, qs=(50, 95, 99)) -> dict:
    """The serving-record percentile keys (``lat_p50_s``...) from raw
    per-batch latency samples — the shared form of ``serve_rec`` results and
    benchmark rows."""
    return {f"lat_p{q:g}_s": exact_percentile(samples, q) for q in qs}


def bucketize(samples: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    """Per-bucket counts; under/overflowing samples clip to the edge buckets."""
    samples = np.asarray(samples, dtype=np.float64)
    idx = np.searchsorted(bounds, samples, side="right") - 1
    idx = np.clip(idx, 0, len(bounds) - 2)
    return np.bincount(idx, minlength=len(bounds) - 1).astype(np.int64)


@dataclasses.dataclass
class CounterSnapshot:
    name: str
    value: int

    def merge(self, other: "CounterSnapshot") -> "CounterSnapshot":
        if other.name != self.name:
            raise ValueError(f"merging {other.name} into {self.name}")
        return CounterSnapshot(self.name, self.value + other.value)


@dataclasses.dataclass
class HistogramSnapshot:
    """Frozen view of a histogram: bucket counts + (optionally) raw samples."""

    name: str
    unit: str
    bounds: np.ndarray                  # (buckets + 1,) bucket edges
    counts: np.ndarray                  # (buckets,) int64
    samples: np.ndarray                 # raw values; empty after a lossy merge

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    def percentile(self, q: float) -> float:
        """Exact when samples are retained; bucket-interpolated otherwise."""
        if self.samples.size:
            return exact_percentile(self.samples, q)
        return self.bucket_percentile(q)

    def bucket_percentile(self, q: float) -> float:
        """Quantile from bucket counts alone (log-linear within the bucket)."""
        total = self.count
        if total == 0:
            return 0.0
        target = q / 100.0 * total
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, max(target, 1e-12)))
        b = min(b, len(self.counts) - 1)
        prev = cum[b - 1] if b > 0 else 0
        frac = (target - prev) / max(1, self.counts[b])
        lo, hi = self.bounds[b], self.bounds[b + 1]
        return float(lo * (hi / lo) ** min(max(frac, 0.0), 1.0))

    def merge(self, other: "HistogramSnapshot", *, drop_samples: bool = False
              ) -> "HistogramSnapshot":
        if other.bounds.shape != self.bounds.shape or not np.allclose(
            other.bounds, self.bounds
        ):
            raise ValueError("cannot merge histograms with different buckets")
        both = (self.samples.size or not self.counts.sum()) and (
            other.samples.size or not other.counts.sum()
        )
        samples = (
            np.concatenate([self.samples, other.samples])
            if both and not drop_samples else np.empty(0)
        )
        return HistogramSnapshot(
            name=self.name, unit=self.unit, bounds=self.bounds,
            counts=self.counts + other.counts, samples=samples,
        )

    def describe(self) -> dict:
        """JSON-ready summary (the metrics-artifact form)."""
        out = {
            "unit": self.unit,
            "count": self.count,
            "sum": float(self.samples.sum()) if self.samples.size else None,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "min": float(self.samples.min()) if self.samples.size else None,
            "max": float(self.samples.max()) if self.samples.size else None,
            "mean": float(self.samples.mean()) if self.samples.size else None,
            # sparse bucket view: [bucket_low_bound, count], nonzero only
            "buckets": [
                [float(self.bounds[i]), int(c)]
                for i, c in enumerate(self.counts) if c
            ],
        }
        return out


class Counter:
    """Monotonic event counter (dispatches, batches, cache misses...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> CounterSnapshot:
        return CounterSnapshot(self.name, self.value)


class Gauge:
    """Last-write-wins instantaneous value (queue depth, resident rows...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Recording half of :class:`HistogramSnapshot` — append-only, O(1)."""

    __slots__ = ("name", "unit", "bounds", "_samples")

    def __init__(self, name: str, unit: str = "s",
                 bounds: np.ndarray | None = None):
        self.name = name
        self.unit = unit
        self.bounds = log_bounds() if bounds is None else np.asarray(bounds)
        self._samples: list[float] = []

    def record(self, value: float) -> None:
        self._samples.append(float(value))

    @property
    def count(self) -> int:
        return len(self._samples)

    def percentile(self, q: float) -> float:
        return exact_percentile(self._samples, q)

    def snapshot(self) -> HistogramSnapshot:
        samples = np.asarray(self._samples, dtype=np.float64)
        return HistogramSnapshot(
            name=self.name, unit=self.unit, bounds=self.bounds,
            counts=bucketize(samples, self.bounds), samples=samples,
        )


@dataclasses.dataclass
class RegistrySnapshot:
    """Mergeable, JSON-serializable freeze of one registry."""

    counters: dict                      # name -> int
    gauges: dict                        # name -> float
    histograms: dict                    # name -> HistogramSnapshot
    info: dict                          # attached static payloads (plan summary)

    def merge(self, other: "RegistrySnapshot") -> "RegistrySnapshot":
        counters = dict(self.counters)
        for k, v in other.counters.items():
            counters[k] = counters.get(k, 0) + v
        gauges = {**self.gauges, **other.gauges}
        hists = dict(self.histograms)
        for k, h in other.histograms.items():
            hists[k] = hists[k].merge(h) if k in hists else h
        return RegistrySnapshot(
            counters=counters, gauges=gauges, histograms=hists,
            info={**self.info, **other.info},
        )

    def to_json(self) -> dict:
        return {
            "counters": dict(sorted(self.counters.items())),
            "gauges": dict(sorted(self.gauges.items())),
            "histograms": {
                k: h.describe() for k, h in sorted(self.histograms.items())
            },
            "info": self.info,
        }


class MetricRegistry:
    """Named metric store: get-or-create accessors, one snapshot per freeze."""

    def __init__(self):
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.info: dict = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, unit: str = "s",
                  bounds: np.ndarray | None = None) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, unit, bounds)
        return h

    def attach(self, key: str, value) -> None:
        """Attach a static JSON-able payload (e.g. the plan summary)."""
        self.info[key] = value

    def reset(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()
        self.info.clear()

    def snapshot(self) -> RegistrySnapshot:
        return RegistrySnapshot(
            counters={k: c.value for k, c in self.counters.items()},
            gauges={k: g.value for k, g in self.gauges.items()},
            histograms={k: h.snapshot() for k, h in self.histograms.items()},
            info=dict(self.info),
        )
