"""repro.obs — low-overhead metrics + tracing for the serving pipeline.

One module-level switch gates everything:

* ``obs.enable()`` / ``obs.disable()`` — flip telemetry for the process;
  ``serve_rec`` enables it when ``--metrics-json`` / ``--trace-out`` is
  passed, benchmarks leave it off.
* When **disabled** (the default), every facade call is a branch on a module
  bool and an immediate return — no counters, histograms, spans, or dicts
  are allocated, so instrumented hot paths cost nothing measurable
  (``tests/test_obs.py`` asserts the disabled path records nothing and
  ``span`` returns a shared singleton).
* When **enabled**, calls route to one process-global
  :class:`~repro.obs.metrics.MetricRegistry` and
  :class:`~repro.obs.tracer.Tracer`.

Instrumentation points call the facade (``obs.inc``, ``obs.observe``,
``obs.span``, ``obs.attach``) rather than holding metric objects, so the
engine/serving code carries no telemetry state of its own.  Note that jit
makes counters *host-side* counters: a counter bumped inside a traced
function counts traces, one bumped at a dispatch site counts dispatches —
the engine instruments the dispatch sites.

Submodules: ``metrics`` (counters/gauges/log-bucket histograms + mergeable
snapshots), ``tracer`` (Chrome-trace spans), ``traffic`` (per-batch HBM/comm
byte accounting), ``drift`` (cost-model residual monitoring), plus the
observatory decision layer: ``slo`` (error budgets + multi-window burn-rate
alerts), ``recorder`` (anomaly flight recorder), ``attribution`` (per-stage
roofline attribution), ``report`` (the serving-report artifact).
"""

from __future__ import annotations

from repro.obs.metrics import (  # noqa: F401 (re-exports)
    Counter, Gauge, Histogram, HistogramSnapshot, MetricRegistry,
    RegistrySnapshot, latency_percentiles,
)
from repro.obs.tracer import Tracer
from repro.obs.drift import DriftMonitor, rank_agreement  # noqa: F401
from repro.obs.slo import SLOEngine, SLOSpec  # noqa: F401
from repro.obs.recorder import (  # noqa: F401
    BatchRecord, FlightRecorder, Observatory, TelemetryJoin,
)

_enabled = False
_registry = MetricRegistry()
_tracer = Tracer()
_observatory: Observatory | None = None


class _NullSpan:
    """Reentrant no-op context manager — the disabled path's shared span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


NULL_SPAN = _NullSpan()


def enable(*, reset: bool = True) -> None:
    """Turn telemetry on (optionally wiping previously recorded state)."""
    global _enabled
    if reset:
        _registry.reset()
        _tracer.reset()
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def registry() -> MetricRegistry:
    return _registry


def tracer() -> Tracer:
    return _tracer


# -- facade: each call is one bool check when disabled -----------------------

def inc(name: str, n: int = 1) -> None:
    if _enabled:
        _registry.counter(name).inc(n)


def observe(name: str, value: float, unit: str = "s") -> None:
    if _enabled:
        _registry.histogram(name, unit).record(value)


def set_gauge(name: str, value: float) -> None:
    if _enabled:
        _registry.gauge(name).set(value)


def attach(key: str, value) -> None:
    if _enabled:
        _registry.attach(key, value)


def span(name: str, cat: str = "serve", **args):
    if _enabled:
        return _tracer.span(name, cat, args or None)
    return NULL_SPAN


def instant(name: str, cat: str = "serve", **args) -> None:
    if _enabled:
        _tracer.instant(name, cat, args or None)


def trace_counter(name: str, **values) -> None:
    if _enabled:
        _tracer.counter(name, values)


def snapshot() -> RegistrySnapshot:
    return _registry.snapshot()


# -- observatory: SLO + flight recorder, driven per steady-state batch --------

def install_observatory(*, slo: SLOEngine | None = None,
                        recorder: FlightRecorder | None = None
                        ) -> Observatory | None:
    """Install (or clear, with no arguments) the process observatory.

    Call AFTER :func:`enable` — the telemetry join keeps cursors into the
    live tracer/registry, so a later ``enable(reset=True)`` invalidates it.
    """
    global _observatory
    if slo is None and recorder is None:
        _observatory = None
        return None
    _observatory = Observatory(
        slo=slo, recorder=recorder,
        join=TelemetryJoin(_tracer, _registry),
    )
    return _observatory


def observatory() -> Observatory | None:
    return _observatory


def observe_batch(*, batch: int, mode: str, latency_s: float,
                  traffic: dict | None = None) -> dict | None:
    """Facade for the serving loop: one bool check when telemetry is off (or
    no observatory is installed); otherwise feeds the SLO engine + flight
    recorder and returns ``{"record", "alerts", "dump"}``."""
    if _enabled and _observatory is not None:
        return _observatory.observe_batch(
            batch=batch, mode=mode, latency_s=latency_s, traffic=traffic,
        )
    return None
