"""Declarative serving SLOs with error-budget accounting and Google-SRE-style
multi-window burn-rate alerts.

The repo's serving claims are distribution claims, so the SLO layer is built
on *events*, not averages: each steady-state batch is one event, and the
event is **bad** when its latency exceeds the :class:`SLOSpec` target.  With
an objective of, say, 0.99, the error budget allows 1% of batches to be bad;
the **burn rate** of a window is

    burn = (bad events in window / window size) / (1 - objective)

so burn 1.0 spends the budget exactly on schedule, burn 10 spends it 10x too
fast.  Alerting follows the SRE workbook's multi-window pattern, translated
from wall-clock windows to batch-count windows (the serving loop is the
clock):

* **page**  — both the slow and the fast window burn at >= ``page_burn``
  (the slow window proves the burn is sustained; the fast window proves it
  is still happening *now*);
* **ticket** — the slow window alone burns at >= ``ticket_burn`` (slow leak).

Hit-rate and QPS floors are session-level objectives (the prefetch cache and
throughput are cumulative quantities), checked by :meth:`SLOEngine.finalize`
rather than per batch.

Evaluation is streaming: feed :meth:`SLOEngine.observe` per batch, or point
:meth:`SLOEngine.evaluate_snapshot` at successive ``RegistrySnapshot``s — the
engine keeps a cursor into the latency histogram's retained samples and only
consumes what it has not seen, so repeated snapshots never double-count.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One serving SLO: a latency target plus optional session floors.

    ``p99_latency_s`` — per-batch latency target (the "good event" bound);
    ``objective`` — fraction of batches that must meet it (0.99 = 1% budget);
    ``hit_rate_floor`` / ``qps_floor`` — session-level floors checked at
    finalize; windows/burns parameterize the multi-window alert policy.
    """

    name: str = "serving"
    p99_latency_s: float | None = None
    hit_rate_floor: float | None = None
    qps_floor: float | None = None
    objective: float = 0.99
    fast_window: int = 8                 # batches ("is it happening now?")
    slow_window: int = 32                # batches ("is it sustained?")
    page_burn: float = 10.0
    ticket_burn: float = 2.0

    def __post_init__(self):
        if not (0.0 < self.objective < 1.0):
            raise ValueError(f"objective must be in (0,1), got {self.objective}")
        if self.fast_window <= 0 or self.slow_window < self.fast_window:
            raise ValueError(
                f"need 0 < fast_window <= slow_window, got "
                f"{self.fast_window}/{self.slow_window}"
            )

    @property
    def budget_fraction(self) -> float:
        return 1.0 - self.objective

    def describe(self) -> dict:
        return {
            "name": self.name,
            "p99_latency_s": self.p99_latency_s,
            "hit_rate_floor": self.hit_rate_floor,
            "qps_floor": self.qps_floor,
            "objective": self.objective,
            "fast_window": self.fast_window,
            "slow_window": self.slow_window,
            "page_burn": self.page_burn,
            "ticket_burn": self.ticket_burn,
        }

    # -- CLI form ------------------------------------------------------------

    _KEYS = ("p99_ms", "p99_s", "hit", "qps", "objective", "fast_window",
             "slow_window", "page_burn", "ticket_burn", "name")

    @classmethod
    def parse(cls, text: str) -> "SLOSpec":
        """Parse the ``serve_rec --slo`` form, e.g.
        ``"p99_ms=50,hit=0.5,qps=100,objective=0.99"``."""
        kw: dict = {}
        for tok in filter(None, (t.strip() for t in text.split(","))):
            if "=" not in tok:
                raise ValueError(f"bad --slo token {tok!r} (want key=value)")
            k, v = (s.strip() for s in tok.split("=", 1))
            if k not in cls._KEYS:
                raise ValueError(f"unknown --slo key {k!r} (known: {cls._KEYS})")
            if k == "name":
                kw["name"] = v
            elif k == "p99_ms":
                kw["p99_latency_s"] = float(v) * 1e-3
            elif k == "p99_s":
                kw["p99_latency_s"] = float(v)
            elif k == "hit":
                kw["hit_rate_floor"] = float(v)
            elif k == "qps":
                kw["qps_floor"] = float(v)
            elif k in ("fast_window", "slow_window"):
                kw[k] = int(v)
            else:
                kw[k] = float(v)
        return cls(**kw)


class SLOEngine:
    """Streaming burn-rate evaluation of one :class:`SLOSpec`.

    Feed :meth:`observe` one latency per steady-state batch; it returns the
    alerts (possibly empty) that fired on that observation.  Window math is
    over the most recent N *observations* — the serving loop is the clock.
    """

    def __init__(self, spec: SLOSpec):
        self.spec = spec
        self._bad: list[bool] = []          # per-observation verdicts, in order
        self._latencies: list[float] = []
        self._alerts: list[dict] = []       # every alert ever fired
        self._active: set[str] = set()      # severities currently firing
        self._hist_cursor: dict[str, int] = {}   # snapshot streaming state
        self._floors: dict = {}             # finalize() results

    # -- observation ---------------------------------------------------------

    @property
    def n(self) -> int:
        return len(self._bad)

    @property
    def bad_total(self) -> int:
        return sum(self._bad)

    def observe(self, latency_s: float) -> list[dict]:
        """Record one batch latency; return alerts fired by this observation."""
        target = self.spec.p99_latency_s
        bad = target is not None and float(latency_s) > target
        self._bad.append(bool(bad))
        self._latencies.append(float(latency_s))
        fired = self._evaluate_windows()
        self._alerts.extend(fired)
        return fired

    def evaluate_snapshot(self, snapshot, *,
                          histogram: str = "serve/overlap/batch_latency_s"
                          ) -> list[dict]:
        """Consume latency samples a ``RegistrySnapshot`` holds beyond this
        engine's cursor (streaming: repeated snapshots never double-count)."""
        h = snapshot.histograms.get(histogram)
        if h is None:
            return []
        samples = h.samples
        start = self._hist_cursor.get(histogram, 0)
        fired: list[dict] = []
        for v in samples[start:]:
            fired.extend(self.observe(float(v)))
        self._hist_cursor[histogram] = int(samples.size)
        return fired

    # -- window math ---------------------------------------------------------

    def burn_rate(self, window: int) -> float:
        """Burn rate of the most recent ``window`` observations (0 before the
        first observation; windows shorter than ``window`` use what exists)."""
        if not self._bad:
            return 0.0
        recent = self._bad[-window:]
        error_rate = sum(recent) / len(recent)
        return error_rate / self.spec.budget_fraction

    def _evaluate_windows(self) -> list[dict]:
        """Edge-triggered: an alert fires on the observation that *enters* the
        burning condition, not on every batch the condition persists."""
        spec = self.spec
        if spec.p99_latency_s is None or self.n < spec.fast_window:
            return []
        fast = self.burn_rate(spec.fast_window)
        slow = self.burn_rate(spec.slow_window)
        now: set[str] = set()
        if fast >= spec.page_burn and slow >= spec.page_burn:
            now.add("page")
        elif self.n >= spec.slow_window and slow >= spec.ticket_burn:
            now.add("ticket")
        fired = [
            {
                "severity": sev, "slo": spec.name, "at_batch": self.n - 1,
                "fast_burn": fast, "slow_burn": slow,
                "threshold": spec.page_burn if sev == "page"
                else spec.ticket_burn,
            }
            for sev in sorted(now - self._active)
        ]
        self._active = now
        return fired

    # -- error budget --------------------------------------------------------

    @property
    def budget_allowed(self) -> float:
        """Bad events the budget allows over everything observed so far."""
        return self.spec.budget_fraction * self.n

    @property
    def budget_spent(self) -> int:
        return self.bad_total

    @property
    def budget_remaining_frac(self) -> float:
        """1.0 = untouched budget, 0.0 = exactly exhausted, negative = blown."""
        if self.n == 0:
            return 1.0
        allowed = self.budget_allowed
        return 1.0 - self.budget_spent / allowed if allowed > 0 else 1.0

    # -- session floors + verdict --------------------------------------------

    def finalize(self, *, hit_rate: float | None = None,
                 qps: float | None = None) -> dict:
        """Check the session-level floors against measured totals."""
        spec = self.spec
        floors = {}
        if spec.hit_rate_floor is not None and hit_rate is not None:
            floors["hit_rate"] = {
                "floor": spec.hit_rate_floor, "measured": float(hit_rate),
                "breached": hit_rate < spec.hit_rate_floor,
            }
        if spec.qps_floor is not None and qps is not None:
            floors["qps"] = {
                "floor": spec.qps_floor, "measured": float(qps),
                "breached": qps < spec.qps_floor,
            }
        self._floors = floors
        return floors

    @property
    def breached(self) -> bool:
        """True once any alert fired, the budget blew, or a floor failed."""
        return (
            bool(self._alerts)
            or self.budget_remaining_frac < 0.0
            or any(f["breached"] for f in self._floors.values())
        )

    @property
    def alerts(self) -> list[dict]:
        return list(self._alerts)

    def state(self) -> dict:
        """JSON-ready engine state — the report's SLO section."""
        spec = self.spec
        return {
            "spec": spec.describe(),
            "observations": self.n,
            "bad_events": self.bad_total,
            "budget_allowed": self.budget_allowed,
            "budget_spent": self.budget_spent,
            "budget_remaining_frac": self.budget_remaining_frac,
            "fast_burn": self.burn_rate(spec.fast_window),
            "slow_burn": self.burn_rate(spec.slow_window),
            "alerts": list(self._alerts),
            "floors": dict(self._floors),
            "breached": self.breached,
        }
