"""Per-batch traffic accounting: the paper's Fig-style "memory access
reduction" as a first-class reported metric.

ProactivePIM's wins are traffic claims — fewer HBM row fetches (the proactive
SRAM cache), zero CPU<->PIM transfer for comm-free duplicated tables — so the
serving loop should report *bytes*, not just a scalar hit rate.  This module
turns the execution state the pipeline already carries into one JSON-ready
report:

* cache hits / misses / staged rows come from each ``PrefetchScheduler``'s
  exact :class:`~repro.cache.sram_cache.CacheStats` (the slot map is ground
  truth, so these are counts, not estimates);
* modeled HBM bytes price those counts at the big-subtable row width — the
  uncached baseline streams every access, the cached path streams misses plus
  the staging DMA;
* comm bytes come from the duplication plan's ICI model
  (``DuplicationPlan.ici_bytes_per_batch``): comm-free tables skip the
  cross-shard psum entirely.

Consistency with the rest of the repo is tested, not assumed: the totals here
must equal the schedulers' ``CacheStats`` and the ``cache_sim`` benchmark's
reported hit rate on the same trace (``tests/test_obs.py``).
"""

from __future__ import annotations

import dataclasses

from repro.cache.sram_cache import CacheStats


def big_row_bytes(emb, *, bytes_per_elem: int = 4) -> int:
    """Bytes per row of the streamed/cached big subtable (matches
    ``intra_gnr.subtable_traces``: G2 width for TT, dim otherwise)."""
    if emb.kind == "tt":
        return emb.tt_spec.g2_width * bytes_per_elem
    return emb.dim * bytes_per_elem


def cache_traffic(stats: CacheStats, row_bytes: int) -> dict:
    """One subtable's cache counters priced in modeled DRAM bytes."""
    tb = stats.traffic_bytes(row_bytes)
    baseline, cached = tb["baseline"], tb["cached"]
    return {
        "accesses": int(stats.accesses),
        "hits": int(stats.hits),
        "misses": int(stats.accesses - stats.hits),
        "hit_rate": stats.hit_rate,
        "staged_rows": int(stats.staged_rows),
        "kept_rows": int(stats.kept_rows),
        "row_bytes": int(row_bytes),
        "hbm_baseline_bytes": int(baseline),
        "hbm_cached_bytes": int(cached),
        "hbm_reduction": cached / baseline if baseline else 1.0,
    }


def format_cache_traffic(t: dict) -> str:
    """The benchmark-row column form shared by cache_sim and serve_qps."""
    return (
        f"hit={t['hit_rate']:.3f} staged={t['staged_rows']} "
        f"dram={t['hbm_cached_bytes']}B vs baseline={t['hbm_baseline_bytes']}B "
        f"({t['hbm_reduction']:.2f}x)"
    )


@dataclasses.dataclass
class TrafficReport:
    """Aggregated per-session traffic accounting across all tables."""

    tables: list                        # per-table cache_traffic dicts
    batches: int                        # scheduler-observed batches (max)
    comm: dict | None = None            # per-batch ICI bytes (dup plan model)

    @property
    def accesses(self) -> int:
        return sum(t["accesses"] for t in self.tables)

    @property
    def hits(self) -> int:
        return sum(t["hits"] for t in self.tables)

    @property
    def staged_rows(self) -> int:
        return sum(t["staged_rows"] for t in self.tables)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.accesses)

    @property
    def hbm_baseline_bytes(self) -> int:
        return sum(t["hbm_baseline_bytes"] for t in self.tables)

    @property
    def hbm_cached_bytes(self) -> int:
        return sum(t["hbm_cached_bytes"] for t in self.tables)

    @property
    def hbm_reduction(self) -> float:
        base = self.hbm_baseline_bytes
        return self.hbm_cached_bytes / base if base else 1.0

    def describe(self) -> dict:
        out = {
            "accesses": self.accesses,
            "hits": self.hits,
            "hit_rate": self.hit_rate,
            "staged_rows": self.staged_rows,
            "batches": self.batches,
            "hbm_baseline_bytes": self.hbm_baseline_bytes,
            "hbm_cached_bytes": self.hbm_cached_bytes,
            "hbm_reduction": self.hbm_reduction,
            "per_table": list(self.tables),
        }
        if self.comm is not None:
            out["comm_baseline_bytes_per_batch"] = float(self.comm["baseline"])
            out["comm_bytes_per_batch"] = float(self.comm["duplicated"])
            out["comm_saved_bytes_per_batch"] = float(self.comm["saved"])
        return out


def collect(plan, schedulers, *, batch: int) -> TrafficReport:
    """Build the report from an ``EmbeddingPlan`` + its live schedulers.

    ``plan`` is ``repro.engine.EmbeddingPlan``; ``schedulers`` the per-table
    ``PrefetchScheduler`` list a serving session ran (their ``CacheStats``
    are the exact hit/miss/staging counts); ``batch`` sizes the modeled
    per-batch comm bytes.
    """
    tables = [
        cache_traffic(s.stats, big_row_bytes(bag.emb))
        for s, bag in zip(schedulers, plan.bags)
    ]
    comm = None
    if plan.dup is not None:
        comm = plan.dup.ici_bytes_per_batch(batch, plan.bags[0].emb.dim)
    batches = max((s.stats.batches for s in schedulers), default=0)
    return TrafficReport(tables=tables, batches=batches, comm=comm)
