"""Nestable span recorder emitting Chrome-trace / Perfetto JSON.

Records the serving pipeline's stage structure — pack -> host-to-device ->
megakernel dispatch -> device compute -> interaction head — as *complete*
("ph": "X") events that ``chrome://tracing`` and https://ui.perfetto.dev load
directly.  Nesting needs no explicit parent links: the Trace Event Format
reconstructs the flame from [ts, ts+dur) containment per (pid, tid), and the
recorder keeps a thread-local stack only so each event can also carry its
depth in ``args`` (handy for tests and offline tools).

Device work enqueued by jax is asynchronous, so a span around a dispatch call
measures *enqueue* cost unless the caller fences; the serving driver fences
each stage with ``jax.block_until_ready`` when tracing is requested
(``serve_rec --trace-out``), trading pipeline overlap for honest per-stage
durations — the Chrome trace documents a *fenced* run.

Timestamps are microseconds from the tracer's construction (``perf_counter``
based), matching the format's expectation of monotonic us.
"""

from __future__ import annotations

import json
import os
import threading
import time


class _Span:
    """Context manager for one complete event (allocated only when enabled)."""

    __slots__ = ("tracer", "name", "cat", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self) -> "_Span":
        self.t0 = time.perf_counter()
        self.tracer._stack().append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        stack = self.tracer._stack()
        depth = len(stack) - 1
        if stack and stack[-1] is self:
            stack.pop()
        tr = self.tracer
        args = {"depth": depth}
        if self.args:
            args.update(self.args)
        tr.events.append({
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": (self.t0 - tr.origin) * 1e6,
            "dur": (t1 - self.t0) * 1e6,
            "pid": tr.pid,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": args,
        })
        return False


class Tracer:
    """Append-only event buffer + span factory for one process."""

    def __init__(self):
        self.pid = os.getpid()
        self.origin = time.perf_counter()
        self.events: list[dict] = []
        self._local = threading.local()

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def reset(self) -> None:
        self.origin = time.perf_counter()
        self.events.clear()

    def span(self, name: str, cat: str = "serve", args: dict | None = None
             ) -> _Span:
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "serve",
                args: dict | None = None) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": (time.perf_counter() - self.origin) * 1e6,
            "pid": self.pid,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": args or {},
        })

    def counter(self, name: str, values: dict) -> None:
        """Chrome counter-track sample ("ph": "C") — e.g. cache hit rate."""
        self.events.append({
            "name": name, "cat": "metrics", "ph": "C",
            "ts": (time.perf_counter() - self.origin) * 1e6,
            "pid": self.pid,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": {k: float(v) for k, v in values.items()},
        })

    def to_chrome(self, *, metadata: dict | None = None) -> dict:
        """The JSON object ``chrome://tracing`` / Perfetto load."""
        events = [
            {
                "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
                "args": {"name": "repro.serve"},
            },
        ] + self.events
        out = {"traceEvents": events, "displayTimeUnit": "ms"}
        if metadata:
            out["otherData"] = metadata
        return out

    def write(self, path: str, *, metadata: dict | None = None) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(metadata=metadata), f, indent=1)
