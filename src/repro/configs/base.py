"""Config dataclasses shared by the whole framework."""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

from repro.core.qr_embedding import EmbeddingConfig

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                  # 0 -> d_model // num_heads

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0        # chatglm3: 0.5 ("RoPE 2d")
    activation: str = "silu"           # silu | gelu | relu2
    norm: str = "rms"                  # rms | layer
    tie_embedding: bool = True

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid / xlstm
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    attn_every: int = 0                # zamba2: shared attention block cadence
    slstm_every: int = 0               # xlstm: sLSTM block cadence

    # encoder–decoder (whisper)
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    dec_layers: int = 0

    # vlm (pixtral): length of the stub patch-embedding prefix
    num_patches: int = 0

    # the paper's technique knob (applies to vocab embedding + tied head)
    embedding_kind: str = "dense"      # dense | hashed | qr | tt
    qr_collision: int = 64
    hot_fraction: float = 0.0
    # TT-Rec knobs (embedding_kind="tt")
    tt_rank: int = 16
    tt_vocab_factors: tuple[int, int, int] | None = None
    tt_dim_factors: tuple[int, int, int] | None = None
    tt_exec: str = "jnp"               # jnp | pallas (fused TT kernel on TPU)
    # execution-scheme knobs (hillclimb / §Perf switches)
    qr_head: str = "factorized"        # factorized | materialize (paper-faithful)
    embedding_exec: str = "gspmd"      # gspmd | twolevel (the PIM scheme)
    moe_dispatch: str = "scatter"      # scatter (GShard-style) | gather (opt)

    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"         # full | dots (save matmul outputs)
    flash_block_dtype: str = "f32"     # f32 | bf16 probability-tile storage
    scan_layers: bool = True
    microbatches: int = 1              # grad-accum steps per train_step

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]

    @property
    def emb_config(self) -> EmbeddingConfig:
        return EmbeddingConfig(
            vocab=self.vocab,
            dim=self.d_model,
            kind=self.embedding_kind,  # type: ignore[arg-type]
            collision=self.qr_collision,
            param_dtype=self.pdtype,
            compute_dtype=self.cdtype,
            hot_fraction=self.hot_fraction,
            head=self.qr_head,
            tt_rank=self.tt_rank,
            tt_vocab_factors=self.tt_vocab_factors,
            tt_dim_factors=self.tt_dim_factors,
            tt_exec=self.tt_exec,
        )

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The assigned LM shape set (identical across the 10 archs).
LM_SHAPES: tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    """The paper's own model family (CTR prediction)."""

    name: str = "dlrm-qr"
    num_tables: int = 26               # criteo-like sparse features
    vocab_per_table: int = 2_000_000
    dim: int = 128
    pooling: int = 32                  # multi-hot indices per bag (paper: ~78 lookups/op)
    num_dense: int = 13
    bottom_mlp: tuple[int, ...] = (512, 256, 128)
    top_mlp: tuple[int, ...] = (1024, 1024, 512, 256, 1)
    embedding_kind: str = "qr"         # dense | hashed | qr | tt
    qr_collision: int = 64
    hot_request_share: float = 0.8     # paper's hot-vector definition
    # TT-Rec knobs (embedding_kind="tt")
    tt_rank: int = 16
    tt_vocab_factors: tuple[int, int, int] | None = None
    tt_dim_factors: tuple[int, int, int] | None = None
    # ProactivePIM cache-subsystem knobs (serving)
    tt_exec: str = "jnp"               # jnp | pallas (fused TT kernel on TPU)
    cache_slots: int = 1024            # prefetch-cache rows per big subtable
    # "adaptive": cache_slots * num_tables is a GLOBAL budget waterfilled
    # across tables by the intra-GnR analyzer's prefetch value
    # (cache.intra_gnr.split_slot_budget); "uniform": cache_slots per table.
    cache_slot_policy: str = "adaptive"
    # Ceiling on the packed VMEM cache block (all tables' slots ride one
    # resident buffer in the megakernel) — the bg-PIM SRAM size class.  The
    # global slot budget is clamped so slots * row_bytes fits this.
    cache_vmem_mb: int = 8
    dup_budget_mb: int = 64            # per-chip replicated-subtable budget
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]


DLRM_SHAPES: tuple[ShapeConfig, ...] = (
    # seq_len carries the pooling factor for DLRM; batch is the request batch.
    ShapeConfig("serve_2k", 32, 2048, "prefill"),
    ShapeConfig("train_8k", 32, 8192, "train"),
)


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: tuple[int, ...] = (16, 16)
    axes: tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n
