"""Architecture registry: --arch ids -> configs, model bindings, shape cells.

The single source of truth for the 10 assigned architectures (+ the paper's
own DLRM), their family bindings (init / train-forward / serve family), the
shape grid, skip rules, and the ShapeDtypeStruct ``input_specs`` used by the
dry-run and benchmarks.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import LM_SHAPES, ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class ArchBinding:
    arch_id: str
    module: str                    # repro.configs.<module> holding CONFIG/SMOKE
    kind: str                      # transformer | zamba2 | xlstm | whisper | pixtral
    sub_quadratic: bool            # eligible for long_500k
    has_decode: bool = True

    @property
    def config(self) -> ModelConfig:
        return importlib.import_module(f"repro.configs.{self.module}").CONFIG

    @property
    def smoke(self) -> ModelConfig:
        return importlib.import_module(f"repro.configs.{self.module}").SMOKE


ARCHS: dict[str, ArchBinding] = {
    b.arch_id: b
    for b in [
        ArchBinding("qwen2-1.5b", "qwen2_1_5b", "transformer", False),
        ArchBinding("granite-34b", "granite_34b", "transformer", False),
        ArchBinding("chatglm3-6b", "chatglm3_6b", "transformer", False),
        ArchBinding("minitron-4b", "minitron_4b", "transformer", False),
        ArchBinding("zamba2-7b", "zamba2_7b", "zamba2", True),
        ArchBinding("whisper-large-v3", "whisper_large_v3", "whisper", False),
        ArchBinding("pixtral-12b", "pixtral_12b", "pixtral", False),
        ArchBinding("granite-moe-3b-a800m", "granite_moe_3b_a800m", "transformer", False),
        ArchBinding("qwen3-moe-235b-a22b", "qwen3_moe_235b_a22b", "transformer", False),
        ArchBinding("xlstm-125m", "xlstm_125m", "xlstm", True),
    ]
}


def get(arch_id: str) -> ArchBinding:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {sorted(ARCHS)}")
    return ARCHS[arch_id]


# ---------------------------------------------------------------------------
# DLRM (the paper's own model) registry: --config ids -> DLRMConfig objects
# ---------------------------------------------------------------------------

# name -> (repro.configs module, attribute)
DLRM_CONFIGS: dict[str, tuple[str, str]] = {
    "dlrm-qr": ("dlrm_qr", "CONFIG"),
    "dlrm-qr-smoke": ("dlrm_qr", "SMOKE"),
    "dlrm-dense": ("dlrm_qr", "DENSE_BASELINE"),
    "dlrm-dense-smoke": ("dlrm_qr", "DENSE_SMOKE"),
    "dlrm-tt": ("dlrm_tt", "CONFIG"),
    "dlrm-tt-smoke": ("dlrm_tt", "SMOKE"),
}


def get_dlrm(name: str):
    """Resolve a DLRM config id (scripts/dlrm_dryrun.py selects by name)."""
    if name not in DLRM_CONFIGS:
        raise KeyError(f"unknown dlrm config {name!r}; choose from {sorted(DLRM_CONFIGS)}")
    module, attr = DLRM_CONFIGS[name]
    return getattr(importlib.import_module(f"repro.configs.{module}"), attr)


# ---------------------------------------------------------------------------
# shape grid + skip rules
# ---------------------------------------------------------------------------

def shape_status(binding: ArchBinding, shape: ShapeConfig) -> str:
    """'run' or a skip reason (recorded, per the assignment, in DESIGN.md)."""
    if shape.kind == "decode" and not binding.has_decode:
        return "skip: encoder-only, no decode step"
    if shape.name.startswith("long_") and not binding.sub_quadratic:
        return "skip: pure full-attention arch; long_500k needs sub-quadratic"
    return "run"


def cells(include_skipped: bool = False):
    """Iterate (binding, shape, status) over the 10 x 4 assigned grid."""
    for binding in ARCHS.values():
        for shape in LM_SHAPES:
            status = shape_status(binding, shape)
            if status == "run" or include_skipped:
                yield binding, shape, status


# ---------------------------------------------------------------------------
# model bindings
# ---------------------------------------------------------------------------

def init_fn(binding: ArchBinding) -> Callable:
    """(key, cfg) -> (params, axes)."""
    kind = binding.kind
    if kind == "transformer":
        from repro.models import transformer as T

        return T.init_lm
    if kind == "zamba2":
        from repro.models import zamba2 as Z

        return Z.init_zamba2
    if kind == "xlstm":
        from repro.models import xlstm as X

        return X.init_xlstm
    if kind == "whisper":
        from repro.models import whisper as W

        return W.init_whisper
    if kind == "pixtral":
        from repro.models import pixtral as P

        return P.init_pixtral
    raise ValueError(kind)


def train_loss_fn(binding: ArchBinding, cfg: ModelConfig) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics) for this family."""
    from repro.train import train_step as TS

    kind = binding.kind
    if kind == "transformer":
        from repro.models import transformer as T

        return TS.make_lm_loss(T.forward_train, cfg)
    if kind == "zamba2":
        from repro.models import zamba2 as Z

        return TS.make_lm_loss(
            lambda p, t, c: Z.forward_zamba2(p, t, c)[0], cfg
        )
    if kind == "xlstm":
        from repro.models import xlstm as X

        return TS.make_lm_loss(lambda p, t, c: X.forward_xlstm(p, t, c)[0], cfg)
    if kind == "whisper":
        from repro.models import whisper as W

        return TS.make_prefixed_lm_loss(W.forward_train, cfg, "frames")
    if kind == "pixtral":
        from repro.models import pixtral as P

        return TS.make_prefixed_lm_loss(P.forward_train, cfg, "patches")
    raise ValueError(kind)


def make_batch_fn(binding: ArchBinding, cfg: ModelConfig) -> Callable:
    """(batch, seq, seed=, step=) -> concrete batch dict (for smoke/examples)."""
    from repro.data import synthetic as syn

    kind = binding.kind
    if kind == "whisper":
        return lambda b, s, **kw: syn.whisper_batch(cfg, b, s, **kw)
    if kind == "pixtral":
        return lambda b, s, **kw: syn.pixtral_batch(cfg, b, s, **kw)
    return lambda b, s, **kw: syn.lm_batch(cfg, b, s, **kw)


# ---------------------------------------------------------------------------
# abstract input specs (dry-run: ShapeDtypeStruct, no allocation)
# ---------------------------------------------------------------------------

def batch_specs(binding: ArchBinding, cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Abstract batch for train/prefill lowering."""
    specs: dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    }
    if binding.kind == "whisper":
        from repro.models.whisper import N_AUDIO

        specs["frames"] = jax.ShapeDtypeStruct((batch, N_AUDIO, cfg.d_model), jnp.float32)
    if binding.kind == "pixtral":
        specs["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_patches, cfg.d_model), jnp.float32
        )
    return specs


def cache_specs(binding: ArchBinding, cfg: ModelConfig, batch: int, max_len: int):
    """Abstract KV/SSM cache for decode lowering (shapes only)."""
    from repro.train.serve_step import serve_family

    fam = serve_family(binding.kind)
    return jax.eval_shape(lambda: fam.make_cache(cfg, batch, max_len))


def abstract_params(binding: ArchBinding, cfg: ModelConfig):
    """(params ShapeDtypeStructs, logical axes tree) without allocating."""
    init = init_fn(binding)
    params = jax.eval_shape(lambda k: init(k, cfg)[0], jax.random.PRNGKey(0))
    # axes trees contain python strings — build them from a tiny same-family
    # config (structure is depth-independent for scan-stacked models only if
    # layer count matches, so use the real cfg; init is cheap at eval_shape
    # level but axes need a real call on a reduced config with SAME structure).
    axes = _axes_for(binding, cfg)
    return params, axes


def _axes_for(binding: ArchBinding, cfg: ModelConfig):
    """Logical-axes tree. Computed on a reduced config with identical tree
    structure (same layer topology flags), then reused for the full config —
    axes depend only on structure, not sizes."""
    small = cfg.replace(
        d_model=64,
        num_heads=4,
        kv_heads=min(cfg.kv_heads, 4) if cfg.kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_groups=1 if cfg.ssm_state else cfg.ssm_groups,
        num_patches=8 if cfg.num_patches else 0,
        qr_collision=min(cfg.qr_collision, 8),
    )
    _, axes = init_fn(binding)(jax.random.PRNGKey(0), small)
    return axes
