"""dlrm-tt — DLRM with TT-Rec (tensor-train) embedding tables, the paper's
second weight-sharing target (2.15x speedup case).

Factorization: vocab 2M -> (38, 1386, 38) (auto, asymmetric: SRAM-sized outer
cores, bulk in the streamed middle core), dim 128 -> (4, 8, 4), rank 16.
Physical: ~2.9M elements per table vs 256M dense (~88x compression); the
pinned outer cores are ~19 KB/table — comfortably bg-PIM-SRAM / VMEM sized.
"""

from repro.configs.base import DLRMConfig

CONFIG = DLRMConfig(
    name="dlrm-tt",
    num_tables=26,
    vocab_per_table=2_000_000,
    dim=128,                       # same sweep point as dlrm-qr
    pooling=32,
    embedding_kind="tt",
    tt_rank=16,
    tt_exec="pallas",              # serving runs the fused gather-contract kernel
)

# The dense baseline lives in dlrm_qr.DENSE_BASELINE (registry id "dlrm-dense").

SMOKE = DLRMConfig(
    name="dlrm-tt-smoke",
    num_tables=4,
    vocab_per_table=4096,
    dim=32,
    pooling=8,
    bottom_mlp=(64, 32),
    top_mlp=(64, 1),
    embedding_kind="tt",
    tt_rank=4,
    tt_exec="pallas",
    cache_slots=128,
)
