"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, q/k norm, untied head.
The pool's largest model; the EP + FSDP showcase. [hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    kv_heads=4,
    d_ff=1536,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    top_k=8,
    activation="silu",
    norm="rms",
    tie_embedding=False,
)

SMOKE = CONFIG.replace(
    name="qwen3-moe-235b-a22b-smoke", num_layers=2, d_model=64, num_heads=4,
    kv_heads=2, head_dim=16, d_ff=64, vocab=512, num_experts=8, top_k=2,
)
