"""zamba2-7b [hybrid] — Mamba2 backbone + shared full-MHA block every 6 layers.
[arXiv:2411.15242; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,                  # 3584 / 32
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=2,
    attn_every=6,                  # 13 shared-attention application sites
    activation="gelu",
    norm="rms",
    tie_embedding=True,
)

SMOKE = CONFIG.replace(
    name="zamba2-7b-smoke", num_layers=4, d_model=64, num_heads=4, kv_heads=4,
    head_dim=16, d_ff=128, vocab=512, ssm_state=16, ssm_head_dim=16, ssm_groups=1,
    attn_every=2,
)
