"""qwen2-1.5b [dense] — GQA, QKV bias. [arXiv:2407.10671; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    kv_heads=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    activation="silu",
    norm="rms",
    tie_embedding=True,
)

SMOKE = CONFIG.replace(
    name="qwen2-1.5b-smoke", num_layers=2, d_model=128, num_heads=4, kv_heads=2,
    head_dim=32, d_ff=256, vocab=512,
)
