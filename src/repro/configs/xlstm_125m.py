"""xlstm-125m [ssm] — mLSTM backbone with sLSTM blocks interleaved (1:4),
attention-free (d_ff=0: mLSTM blocks carry their own projection FFN).
[arXiv:2405.04517; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=192,
    slstm_every=4,                 # blocks 3, 7, 11 are sLSTM
    norm="layer",
    tie_embedding=True,
)

SMOKE = CONFIG.replace(
    name="xlstm-125m-smoke", num_layers=4, d_model=64, num_heads=4, kv_heads=4,
    head_dim=16, vocab=512, slstm_every=2,
)
