"""minitron-4b [dense] — pruned nemotron: squared-ReLU MLP, partial RoPE,
256k vocab (the pool's largest embedding table — prime QR target).
[arXiv:2407.14679; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    kv_heads=8,
    d_ff=9216,
    vocab=256000,
    head_dim=128,
    partial_rotary=0.5,
    activation="relu2",
    norm="layer",
    tie_embedding=False,
)

SMOKE = CONFIG.replace(
    name="minitron-4b-smoke", num_layers=2, d_model=128, num_heads=4, kv_heads=2,
    head_dim=32, d_ff=256, vocab=512,
)
