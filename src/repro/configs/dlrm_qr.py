"""dlrm-qr — the paper's own model: DLRM with weight-sharing (QR) embedding
tables. This is the faithful-reproduction target for every paper benchmark."""

import dataclasses

from repro.configs.base import DLRMConfig

CONFIG = DLRMConfig(
    name="dlrm-qr",
    num_tables=26,
    vocab_per_table=2_000_000,
    dim=128,                       # 512 B rows at fp32 — the paper's largest sweep point
    pooling=32,
    embedding_kind="qr",
    qr_collision=64,
)

# The dense (no weight-sharing) baseline the paper compares against.
DENSE_BASELINE = dataclasses.replace(CONFIG, name="dlrm-dense", embedding_kind="dense")

SMOKE = DLRMConfig(
    name="dlrm-qr-smoke",
    num_tables=4,
    vocab_per_table=4096,
    dim=32,
    pooling=8,
    bottom_mlp=(64, 32),
    top_mlp=(64, 1),
    embedding_kind="qr",
    qr_collision=8,
    cache_slots=128,
)

DENSE_SMOKE = dataclasses.replace(
    SMOKE, name="dlrm-dense-smoke", embedding_kind="dense"
)
