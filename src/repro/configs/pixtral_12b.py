"""pixtral-12b [vlm] — mistral-nemo decoder backbone; pixtral-ViT frontend
STUBBED (input_specs supplies precomputed patch embeddings).
[hf:mistralai/Pixtral-12B-2409; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    activation="silu",
    norm="rms",
    tie_embedding=False,
    num_patches=256,               # stub ViT prefix length (16x16 patch grid)
)

SMOKE = CONFIG.replace(
    name="pixtral-12b-smoke", num_layers=2, d_model=128, num_heads=4, kv_heads=2,
    head_dim=32, d_ff=256, vocab=512, num_patches=8,
)
