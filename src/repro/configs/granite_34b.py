"""granite-34b [dense] — llama-arch code model, MQA (kv=1). [arXiv:2405.04324; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    kv_heads=1,
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    qkv_bias=True,                 # granite-34b-code keeps bias (gpt-bigcode lineage)
    activation="gelu",
    norm="layer",
    tie_embedding=True,
)

SMOKE = CONFIG.replace(
    name="granite-34b-smoke", num_layers=2, d_model=128, num_heads=4, kv_heads=1,
    head_dim=32, d_ff=256, vocab=512,
)
