"""chatglm3-6b [dense] — 2d (half-rotary) RoPE, GQA kv=2, QKV bias.
[arXiv:2406.12793; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    kv_heads=2,
    d_ff=13696,
    vocab=65024,
    head_dim=128,
    qkv_bias=True,
    partial_rotary=0.5,            # "RoPE 2d": rotate half the head dim
    activation="silu",
    norm="rms",
    tie_embedding=False,
)

SMOKE = CONFIG.replace(
    name="chatglm3-6b-smoke", num_layers=2, d_model=128, num_heads=4, kv_heads=2,
    head_dim=32, d_ff=256, vocab=512,
)
