"""whisper-large-v3 [audio] — encoder–decoder; conv/mel frontend STUBBED
(input_specs supplies precomputed frame embeddings). [arXiv:2212.04356]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=64,                 # 32 enc + 32 dec
    d_model=1280,
    num_heads=20,
    kv_heads=20,                   # full MHA
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    is_encoder_decoder=True,
    enc_layers=32,
    dec_layers=32,
    qkv_bias=True,
    activation="gelu",
    norm="layer",
    tie_embedding=True,
)

SMOKE = CONFIG.replace(
    name="whisper-large-v3-smoke", num_layers=4, enc_layers=2, dec_layers=2,
    d_model=64, num_heads=4, kv_heads=4, head_dim=16, d_ff=128, vocab=512,
)
