"""granite-moe-3b-a800m [moe] — 40 experts top-8, tiny per-expert FFN.

The assigned config line says 40 experts; the HF card for the 1b-a400m base
says 32 — we follow the explicit assigned numbers (noted in DESIGN.md).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    num_experts=40,
    top_k=8,
    activation="silu",
    norm="rms",
    tie_embedding=True,
)

SMOKE = CONFIG.replace(
    name="granite-moe-3b-a800m-smoke", num_layers=2, d_model=64, num_heads=4,
    kv_heads=2, head_dim=16, d_ff=64, vocab=512, num_experts=8, top_k=2,
)
