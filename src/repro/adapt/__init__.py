"""Online adaptation: sketches -> incremental re-planning -> drift refit.

The offline ``plan()`` pass bets on a static trace; this package keeps the
bet current while serving.  ``sketch`` watches live traffic (count-min +
space-saving over a decaying window ring), ``replan`` turns estimates into
new cache residency as pure runtime args against the same compiled program
(plus the expensive full ``plan()`` path), ``policy`` decides when either is
worth it (hysteresis + cooldown + the ``DriftMonitor`` refit hook), and
``loop`` is the ``serve_rec --adapt`` serving session.  ``schedule`` is the
shared seeded drift-schedule helper the arrival generator and the drift
benchmarks both use.
"""

from repro.adapt.policy import AdaptController, AdaptPolicy   # noqa: F401
from repro.adapt.replan import (                          # noqa: F401
    IncrementalUpdate,
    PinnedCache,
    incremental_update,
    pinned_from_plan,
    replan_full,
    sampled_traces,
)
from repro.adapt.schedule import (                        # noqa: F401
    DriftSchedule,
    drifting_zipf_batches,
    rotation_offset,
)
from repro.adapt.sketch import (                          # noqa: F401
    CountMinSketch,
    FrequencySketch,
    SpaceSaving,
)

# The serving session (``loop``) pulls in the full launch/engine stack; load
# it lazily so light consumers (the arrival generator importing ``schedule``,
# sketch-only benchmarks) stay cheap.
_LOOP_EXPORTS = ("serve_adaptive", "make_refit_hook", "make_full_hook")


def __getattr__(name: str):
    if name in _LOOP_EXPORTS:
        from repro.adapt import loop

        return getattr(loop, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
