"""Seeded hot-set-drift schedules — ONE definition of "the traffic moved".

Two places used to roll their own Zipf hot-set rotation: the open-loop
arrival generator (``repro.serve.arrival`` rotates each request's keys by a
vocab offset every ``drift_period_s``) and the cache benchmarks (per-batch
rotation of a profiled trace).  Both now route through
:class:`DriftSchedule`, so "rotate the hot set by ``fraction`` of the vocab
every ``period``" means exactly the same permutation everywhere — a
benchmark row stamped with a schedule reproduces the serving traffic that
produced it.

``period`` is unit-agnostic: the arrival generator passes virtual seconds,
the batch-stream helpers pass batch indices.  Rotation is a pure function of
``(t, period, fraction, vocab)``; the ``seed`` seeds the *trace sampling*
(:func:`drifting_zipf_batches`), not the rotation itself, so two equal
schedules always drift identically.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data import synthetic


def rotation_offset(t: float, period: float, fraction: float, vocab: int) -> int:
    """Vocab offset of the Zipf hot set at time (or batch index) ``t``.

    Every ``period`` units the hot set moves by ``int(fraction * vocab)``
    ids (mod vocab) — the permuted-Zipf head lands on a disjoint-ish row set
    while the marginal skew is unchanged, which is exactly the drift an
    offline ``plan()`` cannot see.
    """
    if period <= 0:
        return 0
    k = int(t / period)
    return (k * int(fraction * vocab)) % max(1, vocab)


@dataclasses.dataclass(frozen=True)
class DriftSchedule:
    """One hot-set-rotation law — hashable, JSON-able, shared by the arrival
    generator, the drift benchmarks, and the adaptive serving loop.

    ``period`` — units between rotations (virtual seconds for open-loop
    traffic, batch indices for batch streams; 0 = stationary);
    ``fraction`` — vocab fraction the hot set moves per rotation;
    ``seed`` — seeds trace *sampling* helpers (rotation is deterministic).
    """

    period: float = 0.0
    fraction: float = 0.25
    seed: int = 0

    @property
    def stationary(self) -> bool:
        return self.period <= 0

    def offset_at(self, t: float, vocab: int) -> int:
        return rotation_offset(t, self.period, self.fraction, vocab)

    def rotate(self, idx: np.ndarray, t: float, vocab: int) -> np.ndarray:
        """Apply the rotation active at ``t`` to a batch of logical indices."""
        off = self.offset_at(t, vocab)
        if off == 0:
            return idx
        return ((np.asarray(idx).astype(np.int64) + off) % vocab).astype(
            np.asarray(idx).dtype
        )

    def rotations_before(self, t: float) -> int:
        """How many distinct rotations happened strictly before ``t``."""
        if self.stationary:
            return 0
        return int(t / self.period)

    def describe(self) -> dict:
        return {
            "period": self.period,
            "fraction": self.fraction,
            "seed": self.seed,
        }

    @classmethod
    def parse(cls, text: str) -> "DriftSchedule":
        """Parse the CLI form, e.g. ``"period=8,frac=0.25,seed=3"``."""
        kw: dict = {}
        for tok in filter(None, (t.strip() for t in text.split(","))):
            if "=" not in tok:
                raise ValueError(f"bad --drift token {tok!r} (want key=value)")
            k, v = (s.strip() for s in tok.split("=", 1))
            if k == "period":
                kw["period"] = float(v)
            elif k in ("frac", "fraction"):
                kw["fraction"] = float(v)
            elif k == "seed":
                kw["seed"] = int(v)
            else:
                raise ValueError(f"unknown --drift key {k!r}")
        return cls(**kw)


def drifting_zipf_batches(
    vocab: int, n_batches: int, batch_elems: int, *,
    schedule: DriftSchedule, alpha: float = 1.05, seed: int | None = None,
) -> np.ndarray:
    """(n_batches, batch_elems) Zipf indices whose hot set follows the
    schedule — batch index is the schedule's time axis.

    Deterministic in ``(vocab, shape, schedule, alpha, seed)``: the base
    trace is one :func:`repro.data.synthetic.zipf_trace` draw, rotated per
    batch, so the un-drifted marginal distribution matches what the offline
    profiler models.  ``seed=None`` takes the schedule's seed.
    """
    seed = schedule.seed if seed is None else seed
    base = synthetic.zipf_trace(
        vocab, n_batches * batch_elems, alpha=alpha, seed=seed
    ).reshape(n_batches, batch_elems)
    return np.stack(
        [schedule.rotate(base[t], t, vocab) for t in range(n_batches)]
    )
