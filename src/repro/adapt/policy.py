"""The "is re-planning worthwhile?" trigger and the adaptation controller.

Swapping cache residency is cheap but not free (the swap stages rows over
the same DMA path the prefetcher uses), and the full offline ``plan()``
rebuild costs whole batches of wall clock plus a recompile.  The policy
prices both against the sketch-predicted hit-rate gain:

    act  iff  gain >= min_gain  and  gain * horizon_batches >= cost_batches

— the gain must clear a hysteresis floor *and* pay back its modeled cost
within the payback horizon.  A cooldown after every action keeps flapping
traffic (a hot set oscillating faster than the cooldown) from thrashing the
cache; together floor + cooldown are the two anti-thrash guards.

:class:`AdaptController` owns the loop-facing state: per-table frequency
sketches over *logical* ids (updated O(bag) per batch), the cached
logical->big-row fold, trigger evaluation every ``check_every`` batches, and
the drift-refit hook — when ``obs.drift.DriftMonitor.refit_recommended``
flips, the controller invokes a caller-supplied refit callback (re-fit the
tuner cost model, full re-plan, swap the engine) *from inside the serving
loop*, then re-arms.  Every decision lands in obs as a counter bump + an
instant event, so re-plan activity is visible in flight-recorder dumps (the
recorder snapshots counter deltas per batch) and Chrome traces.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro import obs
from repro.engine.plan import big_subtable as _big_subtable
from repro.adapt import replan
from repro.adapt.sketch import FrequencySketch


@dataclasses.dataclass(frozen=True)
class AdaptPolicy:
    """Trigger thresholds and modeled costs (all in batch-equivalents).

    ``min_gain`` doubles as the sampling-noise floor: the sketch's own top-k
    always looks better than the true-distribution pin under the sketch's
    empirical estimate (ranking and evaluation share the sample), an overfit
    bias that decays as mass accumulates but plateaus near 0.04-0.08 on
    stationary Zipf smoke traffic.  The default floor sits ~2x above that
    plateau and ~2x below the post-rotation gain (0.2+), so stationary
    traffic holds and real drift fires; ``min_batches`` keeps the trigger
    quiet while the bias is still warmup-sized.
    """

    check_every: int = 8          # batches between trigger evaluations
    min_batches: int = 12         # sketch warmup before any action
    min_gain: float = 0.10        # hysteresis floor on predicted hit-rate gain
    horizon_batches: int = 64     # payback horizon for the cost model
    swap_cost_batches: float = 1.0    # modeled cost of an incremental swap
    full_gain: float = 0.30       # floor before a full plan() rebuild
    full_cost_batches: float = 32.0   # modeled cost of plan() + recompile
    cooldown_batches: int = 8     # quiet period after any action
    refit_cooldown_batches: int = 64  # quiet period after a drift refit

    def swap_worthwhile(self, gain: float) -> bool:
        return (
            gain >= self.min_gain
            and gain * self.horizon_batches >= self.swap_cost_batches
        )

    def full_worthwhile(self, gain: float) -> bool:
        return (
            gain >= self.full_gain
            and gain * self.horizon_batches >= self.full_cost_batches
        )

    def describe(self) -> dict:
        return dataclasses.asdict(self)


class AdaptController:
    """Online adaptation driver: sketches -> trigger -> runtime-arg swap.

    ``full_hook``/``refit_hook`` are optional callbacks owning the expensive
    paths (they typically rebuild the plan and recompile); the controller
    only decides *when*.  Without hooks it degrades gracefully to
    incremental-only adaptation.
    """

    def __init__(
        self,
        eplan,
        *,
        policy: AdaptPolicy | None = None,
        sketch_kw: dict | None = None,
        full_hook: Callable[["AdaptController"], dict] | None = None,
        refit_hook: Callable[["AdaptController"], dict] | None = None,
        seed: int = 0,
    ):
        self.eplan = eplan
        self.policy = policy or AdaptPolicy()
        self.full_hook = full_hook
        self.refit_hook = refit_hook
        kw = dict(sketch_kw or {})
        self.sketches = [
            FrequencySketch(bag.emb.vocab, seed=seed * 100 + t, **kw)
            for t, bag in enumerate(eplan.bags)
        ]
        self._big_ids = [replan.big_id_map(bag.emb) for bag in eplan.bags]
        self._big_rows = [
            _big_subtable(bag.emb)[1] for bag in eplan.bags
        ]
        self.batch_i = 0
        self._last_action = -(10**9)
        self._last_refit = -(10**9)
        self.events: list[dict] = []

    # ---- observation ----------------------------------------------------

    def fresh_caches(self) -> list[replan.PinnedCache]:
        """Pinned caches seeded from the (possibly re-planned) offline bet."""
        return replan.pinned_from_plan(self.eplan)

    def observe(self, idx: np.ndarray) -> None:
        """Fold one batch of logical indices in: ``idx`` is (B, T, K)."""
        idx = np.asarray(idx)
        for t, sk in enumerate(self.sketches):
            sk.update(idx[:, t])
        self.batch_i += 1

    def big_estimates(self) -> list[np.ndarray]:
        """Sketch estimates folded onto big-subtable rows, per table."""
        return [
            replan.fold_to_big(sk.estimate_all(), ids, rows)
            for sk, ids, rows in zip(self.sketches, self._big_ids, self._big_rows)
        ]

    # ---- decisions ------------------------------------------------------

    def evaluate(self, caches) -> dict:
        """Predicted gain of re-pinning now (no side effects).

        Gain is the access-mass-weighted coverage delta between the sketch's
        best pin and the currently resident rows, under the sketch's own
        estimate of live traffic.
        """
        ests = self.big_estimates()
        update = replan.incremental_update(ests, self.eplan.slot_budgets)
        cur_mass, mass = 0.0, 0.0
        for est, cache in zip(ests, caches):
            rows = (
                cache.pinned_rows()
                if hasattr(cache, "pinned_rows")
                else cache.cache_rows()
            )
            cur_mass += float(est[np.asarray(rows, dtype=np.int64)].sum())
            mass += float(est.sum())
        current_hit = cur_mass / mass if mass > 0 else 0.0
        return {
            "batch": self.batch_i,
            "predicted_hit": update.predicted_hit,
            "current_hit": current_hit,
            "gain": update.predicted_hit - current_hit,
            "update": update,
        }

    def step(self, caches) -> dict | None:
        """Run the trigger; apply + record an action when it fires.

        Returns the event dict (kind ``replan`` or ``replan_full``) or None.
        """
        pol = self.policy
        if self.batch_i < pol.min_batches or self.batch_i % pol.check_every:
            return None
        if self.batch_i - self._last_action < pol.cooldown_batches:
            obs.inc("serve/adapt/cooldown_skips")
            return None
        ev = self.evaluate(caches)
        gain = ev["gain"]
        obs.set_gauge("serve/adapt/predicted_gain", gain)
        if self.full_hook is not None and pol.full_worthwhile(gain):
            result = self.full_hook(self)
            event = {
                "kind": "replan_full", "batch": self.batch_i,
                "gain": round(gain, 4), **(result or {}),
            }
            obs.inc("serve/adapt/replan_full")
            obs.instant("adapt_replan_full", cat="adapt",
                        batch=self.batch_i, gain=round(gain, 4))
        elif pol.swap_worthwhile(gain):
            staged = ev["update"].apply(caches)
            event = {
                "kind": "replan", "batch": self.batch_i,
                "gain": round(gain, 4), "staged_rows": int(staged),
                "predicted_hit": round(ev["predicted_hit"], 4),
            }
            obs.inc("serve/adapt/replan")
            obs.inc("serve/adapt/staged_rows", int(staged))
            obs.instant("adapt_replan", cat="adapt", batch=self.batch_i,
                        gain=round(gain, 4), staged_rows=int(staged))
        else:
            obs.inc("serve/adapt/holds")
            return None
        self._last_action = self.batch_i
        self.events.append(event)
        return event

    def maybe_refit(self, monitor) -> dict | None:
        """Act on ``DriftMonitor.refit_recommended`` — the autotuner's online
        re-fit, executed mid-serve through ``refit_hook`` (no restart)."""
        if monitor is None or self.refit_hook is None:
            return None
        if not monitor.refit_recommended:
            return None
        if self.batch_i - self._last_refit < self.policy.refit_cooldown_batches:
            return None
        summary = monitor.summary()
        result = self.refit_hook(self)
        event = {
            "kind": "refit", "batch": self.batch_i,
            "drift": summary, **(result or {}),
        }
        obs.inc("serve/adapt/refit")
        obs.instant(
            "adapt_refit", cat="adapt", batch=self.batch_i,
            reasons=",".join(summary.get("reasons", [])) or "drift",
        )
        self._last_refit = self.batch_i
        self._last_action = self.batch_i
        self.events.append(event)
        return event

    def summary(self) -> dict:
        counts: dict[str, int] = {}
        for ev in self.events:
            counts[ev["kind"]] = counts.get(ev["kind"], 0) + 1
        return {
            "batches": self.batch_i,
            "events": counts,
            "policy": self.policy.describe(),
        }
