"""The ``serve_rec --adapt`` session: pinned serving + online re-planning.

The adaptive loop serves the same packed megakernel pipeline as
``run_pipeline`` but with **pinned** cache residency (no oracle next-batch
prefetch — see :class:`repro.adapt.replan.PinnedCache`): steady-state batches
stage nothing, residency only changes when the :class:`AdaptController`
decides a swap pays.  Per batch it:

1. folds the batch's logical indices into the frequency sketches (O(bag));
2. routes through the pinned slot maps and dispatches the SAME compiled
   ``serve_gather`` program — swaps change runtime-arg *contents* only, and
   ``engine/compile/serve_gather`` proves it stays at one trace;
3. runs the controller's trigger; an incremental re-plan re-pins in place,
   a full re-plan / drift refit rebuilds plan + engine mid-loop (the one
   legitimately recompiling path) without restarting the session.

The drift-refit hook closes the autotuner loop: ``DriftMonitor`` flips
``refit_recommended``, the hook re-fits the tuner cost model on
sketch-sampled traffic, re-plans, recompiles, re-arms the monitor — all
between two batches of the same ``while`` loop.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engine_mod
from repro import obs
from repro.adapt.policy import AdaptController, AdaptPolicy
from repro.adapt.replan import sampled_traces
from repro.adapt.schedule import DriftSchedule, drifting_zipf_batches
from repro.data import synthetic
from repro.engine import big_rows
from repro.launch.serve_rec import ServeState, _head_jit, make_packed_gather


def make_refit_hook(state: ServeState, params, *, mode: str = "hlo",
                    sample_n: int = 4096, max_samples: int = 3,
                    repeats: int = 1, seed: int = 0):
    """Build the drift-refit callback: re-fit cost model, re-plan, recompile.

    Runs the full autotuner path on sketch-sampled traffic — the expensive
    but complete answer to a cost model whose predictions stopped ranking
    reality.  Mutates ``state`` in place (new engine, new prediction, fresh
    re-armed monitor) so the serving loop continues against the same object.
    """
    from repro import tune

    def hook(controller: AdaptController) -> dict:
        spec = state.eplan.spec
        traces = sampled_traces(controller.sketches, n=sample_n, seed=seed)
        with obs.span("adapt_refit_fit", cat="adapt"):
            tuner = tune.fit(
                spec, traces, mode=mode, num_shards=state.eplan.num_shards,
                max_samples=max_samples, repeats=repeats,
            )
            knobs = tuner.choose(spec, backend="packed")
            eplan = engine_mod.plan(
                spec, num_shards=state.eplan.num_shards, trace=traces,
                knobs=knobs,
            )
        state.engine = engine_mod.compile(eplan)
        state.predicted_s = tuner.predict(spec, knobs)
        state.drift = obs.DriftMonitor()      # re-arm on the fresh model
        controller.eplan = eplan
        return {"knobs": knobs.describe(),
                "predicted_s": state.predicted_s}

    return hook


def make_full_hook(state: ServeState, *, sample_n: int = 4096, seed: int = 0):
    """Build the full-replan callback: offline ``plan()`` on sketch traffic.

    Re-derives budgets/duplication/packing (keeping the frozen knobs) — a
    new plan, hence a recompile on the next dispatch.  Incremental swaps
    handle residency; this handles *structure*.
    """

    def hook(controller: AdaptController) -> dict:
        spec = state.eplan.spec
        traces = sampled_traces(controller.sketches, n=sample_n, seed=seed)
        with obs.span("adapt_replan_full", cat="adapt"):
            eplan = engine_mod.plan(
                spec, num_shards=state.eplan.num_shards, trace=traces,
                knobs=state.eplan.knobs,
            )
        state.engine = engine_mod.compile(eplan)
        controller.eplan = eplan
        return {"slot_budgets": list(eplan.slot_budgets)}

    return hook


def serve_adaptive(
    cfg, *, batch: int = 16, batches: int = 24, alpha: float = 1.05,
    seed: int = 0, state: ServeState, params,
    schedule: DriftSchedule | None = None,
    controller: AdaptController | None = None,
    policy: AdaptPolicy | None = None,
    refit: bool = False, refit_kw: dict | None = None,
    full_replan: bool = False,
    idx_override: list[np.ndarray] | None = None,
) -> dict:
    """Serve ``batches`` batches with online adaptation; returns the record.

    Traffic comes from the shared drift-schedule helper
    (:func:`drifting_zipf_batches`, per-table seeds matching what
    ``build_serve_state`` profiled — seed+7+t), so a stationary schedule
    means the offline plan's bet is *right* and the policy correctly holds;
    ``schedule`` rotates the hot set per batch index.  ``idx_override``
    (one (B, T, K) array per batch) substitutes an explicit index stream —
    the parity tests feed ``run_pipeline``'s exact batches through it.
    ``refit=True`` arms the drift-refit hook against ``state.drift``;
    ``full_replan=True`` allows policy-triggered full ``plan()`` rebuilds.
    Sequential dispatch (gather -> head -> block per batch): adaptation
    decisions happen on the host between batches, which is exactly where
    the admission queue would sit in the front end.
    """
    schedule = schedule or DriftSchedule()
    if controller is None:
        controller = AdaptController(state.eplan, policy=policy, seed=seed)
    if refit and controller.refit_hook is None:
        controller.refit_hook = make_refit_hook(
            state, params, seed=seed, **(refit_kw or {})
        )
    if full_replan and controller.full_hook is None:
        controller.full_hook = make_full_hook(state, seed=seed)

    emb = state.bags[0].emb
    vocab = emb.vocab
    data = [
        synthetic.dlrm_batch(cfg, batch, seed=seed, step=t, alpha=alpha)
        for t in range(batches)
    ]                                      # dense features + labels
    if idx_override is not None:
        idx_np = [np.asarray(x) for x in idx_override]
    else:
        # per-table streams under the shared drift law, seeded exactly like
        # the offline profile (seed+7+t) — same marginal, rotated hot set
        per_table = [
            drifting_zipf_batches(
                vocab, batches, batch * cfg.pooling,
                schedule=schedule, alpha=alpha, seed=seed + 7 + t,
            )
            for t in range(cfg.num_tables)
        ]
        idx_np = [
            np.stack(
                [pt[b].reshape(batch, cfg.pooling) for pt in per_table],
                axis=1,
            ).astype(np.int32)
            for b in range(batches)
        ]
    rows_np = [
        np.stack(
            [big_rows(idx_np[t][:, i], emb) for i in range(cfg.num_tables)],
            axis=1,
        )
        for t in range(batches)
    ]

    gather = make_packed_gather(params, state)
    caches = controller.fresh_caches()

    def dispatch(t):
        with obs.span("pack", batch=t):
            slot = np.stack(
                [caches[i].slots_for(rows_np[t][:, i])
                 for i in range(cfg.num_tables)],
                axis=1,
            )
            cache_rows = state.engine.packed_cache_rows(caches)
        with obs.span("dispatch", batch=t):
            pooled = gather(
                jnp.asarray(idx_np[t]), jnp.asarray(slot),
                jnp.asarray(cache_rows),
            )
        with obs.span("interact", batch=t):
            return _head_jit(params, data[t]["dense"], pooled, cfg)

    logits = [None] * batches
    lats: list[float] = []
    hit_series: list[float] = []
    staged_series: list[int] = []

    tc = time.perf_counter()
    with obs.span("compile_warmup", cat="offline"):
        warm = dispatch(0)
        jax.block_until_ready(warm)
    compile_s = time.perf_counter() - tc
    logits[0] = np.asarray(warm)
    controller.observe(idx_np[0])

    t0 = time.perf_counter()
    for t in range(1, batches):
        tb = time.perf_counter()
        prev_hits, prev_acc = (
            sum(c.stats.hits for c in caches),
            sum(c.stats.accesses for c in caches),
        )
        prev_staged = sum(c.stats.staged_rows for c in caches)
        with obs.span("batch", batch=t, mode="adaptive"):
            out = dispatch(t)
            with obs.span("block", batch=t):
                jax.block_until_ready(out)
        lat = time.perf_counter() - tb
        lats.append(lat)
        logits[t] = np.asarray(out)
        obs.observe_batch(batch=t, mode="adaptive", latency_s=lat)
        hits = sum(c.stats.hits for c in caches) - prev_hits
        acc = sum(c.stats.accesses for c in caches) - prev_acc
        hit_series.append(hits / max(1, acc))
        if state.drift is not None and state.predicted_s is not None:
            state.drift.observe(state.predicted_s, lat)

        # host-side adaptation, between batches (where the queue would sit)
        controller.observe(idx_np[t])
        engine_before = state.engine
        ev = controller.step(caches)
        rev = controller.maybe_refit(state.drift)
        if state.engine is not engine_before:
            # a full re-plan / refit swapped the engine: rebuild the packed
            # buffers + pinned caches against the new plan (recompiles once)
            gather = make_packed_gather(params, state)
            caches = controller.fresh_caches()
        if (ev or rev) and obs.enabled():
            obs.trace_counter("serve/adaptive/events",
                              events=len(controller.events))
        staged_series.append(
            sum(c.stats.staged_rows for c in caches) - prev_staged
            if state.engine is engine_before else 0
        )
    wall_s = time.perf_counter() - t0

    for lat in lats:
        obs.observe("serve/adaptive/batch_latency_s", lat)
    obs.inc("serve/adaptive/batches", len(lats))

    stats = [c.stats for c in caches]
    acc = sum(s.accesses for s in stats)
    hits = sum(s.hits for s in stats)
    served = batch * max(0, batches - 1)
    return {
        "config": cfg.name,
        "mode": "adaptive",
        "batch": batch,
        "batches": batches,
        "served": served,
        "compile_s": compile_s,
        "wall_s": wall_s,
        "qps": served / max(wall_s, 1e-9),
        **obs.latency_percentiles(lats),
        "latencies_s": lats,
        "hit_rate": hits / max(1, acc),
        "hit_series": hit_series,
        "staged_series": staged_series,
        "schedule": schedule.describe(),
        "events": list(controller.events),
        "adapt": controller.summary(),
        "drift": state.drift.summary() if state.drift is not None else None,
        "logits": logits,
    }
