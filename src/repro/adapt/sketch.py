"""Sliding-window frequency sketches for the online re-planner.

The serving loop cannot afford exact per-row counts over a 10^5..10^6-row
vocab, but the re-planner only needs two things: a *ranking* good enough to
re-pin cache slots, and a rough probability vector good enough to re-run the
offline analyzer.  Both tolerate the classic sketch trade-off — bounded
overestimation, never underestimation:

* :class:`CountMinSketch` — count-min with **conservative update** (only the
  minimum-valued counters are raised, batched via ``np.maximum.at``), which
  keeps the one-sided error guarantee while shrinking it substantially on
  skewed (Zipf) streams.  Hashing is multiply-shift over a power-of-two
  width: ``(a * x) >> (64 - log2(w))`` with seeded random odd ``a`` — two
  u64 ops per (row, depth), no Python hashing in the hot path.
* :class:`SpaceSaving` — the top-k heavy-hitter list (Metwally et al.):
  at most ``k`` tracked rows, evict-min on overflow, per-key error bound
  recorded at insertion.  Gives exact membership candidates for pinning
  without scanning the sketch.
* :class:`FrequencySketch` — the per-table facade the serving loop feeds:
  a decaying ring of window sketches (rotate every ``window_batches``
  batches, estimate = decay-weighted sum over live windows) so an expired
  hot set actually *leaves* the estimate instead of haunting it forever,
  plus one decayed heavy-hitter list across windows.

Update cost is O(uniques-in-batch x depth) — O(bag) in the serving loop's
terms — and everything is plain NumPy: sketches live host-side next to the
admission queue, never inside a jitted function.
"""

from __future__ import annotations

import numpy as np


def _round_pow2(n: int) -> int:
    return 1 << max(1, int(np.ceil(np.log2(max(2, n)))))


class CountMinSketch:
    """Count-min with conservative update; estimates never undercount."""

    def __init__(self, width: int = 2048, depth: int = 4, seed: int = 0):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.width = _round_pow2(width)
        self.depth = int(depth)
        self._shift = np.uint64(64 - int(np.log2(self.width)))
        rng = np.random.default_rng(np.random.SeedSequence([seed, 0xC317]))
        # Random odd multipliers: multiply-shift is 2-universal enough for
        # the one-sided CM bound, and stays pure uint64 arithmetic.
        self._mul = (
            rng.integers(1, 2**62, size=self.depth, dtype=np.uint64) << np.uint64(1)
        ) | np.uint64(1)
        self.table = np.zeros((self.depth, self.width), dtype=np.int64)
        self.total = 0

    def _buckets(self, keys: np.ndarray) -> np.ndarray:
        x = np.asarray(keys).astype(np.uint64, copy=False).reshape(-1)
        return ((x[None, :] * self._mul[:, None]) >> self._shift).astype(np.int64)

    def update(self, keys: np.ndarray, counts: np.ndarray | None = None) -> None:
        """Add ``counts`` (default: multiplicity of ``keys``) conservatively.

        Conservative update raises each key's counters only up to
        ``estimate + count``; with duplicate keys folded into per-unique
        counts first, ``np.maximum.at`` applies the whole batch in one shot
        per depth.  Collisions between distinct keys in the same batch can
        only push counters *higher* than the sequential schedule would, so
        the never-underestimate invariant survives batching.
        """
        keys = np.asarray(keys).reshape(-1)
        if counts is None:
            keys, counts = np.unique(keys, return_counts=True)
        else:
            counts = np.asarray(counts).reshape(-1)
        if keys.size == 0:
            return
        idx = self._buckets(keys)
        est = self.table[np.arange(self.depth)[:, None], idx].min(axis=0)
        target = est + counts
        for d in range(self.depth):
            np.maximum.at(self.table[d], idx[d], target)
        self.total += int(counts.sum())

    def estimate(self, keys: np.ndarray) -> np.ndarray:
        """Point estimates; >= true count, <= true + eps*total w.h.p."""
        keys = np.asarray(keys)
        idx = self._buckets(keys)
        est = self.table[np.arange(self.depth)[:, None], idx].min(axis=0)
        return est.reshape(keys.shape)


class SpaceSaving:
    """Top-k heavy hitters with per-key overestimation bound."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.counts: dict[int, int] = {}
        self.errors: dict[int, int] = {}

    def update(self, keys: np.ndarray, counts: np.ndarray | None = None) -> None:
        keys = np.asarray(keys).reshape(-1)
        if counts is None:
            keys, counts = np.unique(keys, return_counts=True)
        for k, c in zip(keys.tolist(), np.asarray(counts).tolist()):
            if k in self.counts:
                self.counts[k] += c
            elif len(self.counts) < self.capacity:
                self.counts[k] = c
                self.errors[k] = 0
            else:
                victim = min(self.counts, key=self.counts.__getitem__)
                floor = self.counts.pop(victim)
                self.errors.pop(victim)
                self.counts[k] = floor + c
                self.errors[k] = floor

    def scale(self, factor: float) -> None:
        """Decay all counters (window rotation); drops keys that hit zero."""
        for k in list(self.counts):
            self.counts[k] = int(self.counts[k] * factor)
            self.errors[k] = int(self.errors[k] * factor)
            if self.counts[k] <= 0:
                del self.counts[k]
                del self.errors[k]

    def top(self, n: int | None = None) -> list[tuple[int, int]]:
        """[(key, count)] sorted by count desc, key asc for determinism."""
        items = sorted(self.counts.items(), key=lambda kv: (-kv[1], kv[0]))
        return items if n is None else items[:n]


class FrequencySketch:
    """Per-table sliding-window sketch: ring of count-min windows + decayed
    heavy hitters.  This is the object the serving loop feeds each batch.

    ``windows`` live windows of ``window_batches`` batches each; when the
    active window fills, the ring rotates and the oldest window is zeroed.
    Estimates are ``sum_i decay**age_i * window_i`` — recent traffic
    dominates, and a hot set older than ``windows * window_batches`` batches
    contributes nothing at all.
    """

    def __init__(
        self,
        num_rows: int,
        *,
        width: int | None = None,
        depth: int = 4,
        windows: int = 4,
        window_batches: int = 16,
        decay: float = 0.5,
        topk: int = 256,
        seed: int = 0,
    ):
        if windows < 1:
            raise ValueError(f"windows must be >= 1, got {windows}")
        self.num_rows = int(num_rows)
        self.windows = int(windows)
        self.window_batches = int(window_batches)
        self.decay = float(decay)
        # Default width tracks the key space (collision inflation corrupts
        # mid-rank ordering once keys outnumber cells severalfold), capped
        # at 64Ki cells / window; always capped at the next pow2 >= num_rows
        # — a sketch wider than the key space is pure waste.
        if width is None:
            width = min(_round_pow2(num_rows), 65_536)
        width = min(_round_pow2(width), _round_pow2(num_rows))
        self._ring = [
            CountMinSketch(width, depth, seed=seed * 1000 + i)
            for i in range(self.windows)
        ]
        self._active = 0
        self.heavy = SpaceSaving(topk)
        self.batches = 0
        self._batches_in_window = 0

    def update(self, keys: np.ndarray) -> None:
        """Fold one batch of row ids in; O(uniques x depth)."""
        uniq, counts = np.unique(np.asarray(keys).reshape(-1), return_counts=True)
        self._ring[self._active].update(uniq, counts)
        self.heavy.update(uniq, counts)
        self.batches += 1
        self._batches_in_window += 1
        if self._batches_in_window >= self.window_batches:
            self.advance()

    def advance(self) -> None:
        """Rotate the window ring: oldest window forgotten, heavy decayed."""
        self._active = (self._active + 1) % self.windows
        sk = self._ring[self._active]
        sk.table[:] = 0
        sk.total = 0
        self.heavy.scale(self.decay)
        self._batches_in_window = 0

    def estimate(self, keys: np.ndarray) -> np.ndarray:
        """Decay-weighted estimate across live windows (float64)."""
        keys = np.asarray(keys)
        out = np.zeros(keys.shape, dtype=np.float64)
        for age in range(self.windows):
            sk = self._ring[(self._active - age) % self.windows]
            if sk.total == 0:
                continue
            out += (self.decay**age) * sk.estimate(keys)
        return out

    def estimate_all(self) -> np.ndarray:
        """Estimates for every row id in ``[0, num_rows)``."""
        return self.estimate(np.arange(self.num_rows))

    @property
    def total(self) -> float:
        """Decay-weighted stream mass (same weighting as ``estimate``)."""
        return sum(
            (self.decay**age) * self._ring[(self._active - age) % self.windows].total
            for age in range(self.windows)
        )

    def top_rows(self, n: int) -> np.ndarray:
        """Heavy-hitter candidates, best-first; may return fewer than n."""
        keys = [k for k, _ in self.heavy.top(n)]
        return np.asarray(keys, dtype=np.int64)
