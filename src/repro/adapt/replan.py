"""Incremental re-planning: new cache residency, same compiled program.

The jit key of the serving dispatch is the ``EmbeddingPlan`` — ``spec``,
``backend``, ``layout``, ``slot_budgets``, ``knobs``.  Everything else the
cache machinery feeds the kernel is a *runtime argument*: the per-table slot
maps, the ``cache_rows`` gather indices, and the hot-tier row sets.  This
module recomputes exactly that runtime half from a live frequency sketch:

* :class:`PinnedCache` — static-residency counterpart of
  :class:`repro.cache.sram_cache.PrefetchScheduler` (same duck type:
  ``prefetch`` / ``slots_for`` / ``cache_rows`` / ``.stats``), holding the
  *planner-predicted* hot rows resident with **no per-batch staging DMA**.
  The oracle prefetcher re-ranks from the next batch's actual indices and
  so self-heals under drift; the pinned mode is the steady-state serving
  configuration whose hit rate genuinely decays when traffic moves — the
  thing online adaptation exists to fix.  ``pin()`` swaps the resident set
  in place; the arrays keep their shapes (``(slot_budgets[t],)`` per table),
  so ``packed_cache_rows`` and the packed dispatch see only new *contents*.
* :func:`incremental_update` — sketch estimates -> new pinned row set +
  refreshed scheduler tiebreak values per table, applied via
  :meth:`IncrementalUpdate.apply` to either cache flavor.
* :func:`sampled_traces` / :func:`replan_full` — the expensive path: turn
  the sketch into a synthetic logical-index trace and re-run the whole
  offline ``plan()`` (analyzer, waterfill, duplication, packing).  The
  result is a *new* plan — new jit key, recompile expected — reserved for
  when the policy decides the distribution moved enough to re-derive
  structure, not just residency.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.cache.sram_cache import CacheStats
from repro.engine.plan import big_rows as _big_rows
from repro.engine.plan import big_subtable as _big_subtable
from repro.engine.plan import plan as _offline_plan


def top_rows(est: np.ndarray, n: int) -> np.ndarray:
    """The ``n`` highest-estimate rows, deterministically (stable, id-asc ties)."""
    est = np.asarray(est)
    n = min(int(n), est.size)
    return np.argsort(-est, kind="stable")[:n].astype(np.int64)


class PinnedCache:
    """Statically pinned cache residency over one subtable.

    Drop-in for ``PrefetchScheduler`` in the serving loop: ``prefetch`` is a
    no-op (nothing staged per batch — residency only changes when ``pin``
    swaps it), ``slots_for`` routes through the same slot-map representation,
    and ``cache_rows`` keeps shape ``(num_slots,)`` forever so swapped
    contents reuse the already-compiled packed dispatch.
    """

    def __init__(
        self, num_rows: int, num_slots: int, rows: np.ndarray | None = None
    ):
        if num_slots <= 0:
            raise ValueError("num_slots must be positive")
        self.num_rows = int(num_rows)
        self.num_slots = min(int(num_slots), self.num_rows)
        self.slot_rows = np.full(self.num_slots, -1, dtype=np.int32)
        self.slot_map = np.full(self.num_rows, -1, dtype=np.int32)
        self.stats = CacheStats()
        self.swaps = 0
        if rows is not None:
            self.pin(rows)

    def pin(self, rows: np.ndarray) -> int:
        """Swap the resident row set; returns rows newly staged.

        Rows already resident keep their slot (their re-pin is free, exactly
        the prefetcher's inter-batch keep rule); only the difference is
        staged.  Duplicates are dropped, overflow beyond ``num_slots`` is
        truncated best-first.
        """
        rows = np.asarray(rows).reshape(-1)
        _, first = np.unique(rows, return_index=True)
        rows = rows[np.sort(first)][: self.num_slots]

        keep_set = set(int(r) for r in rows) & set(
            int(r) for r in self.slot_rows if r >= 0
        )
        for s, r in enumerate(self.slot_rows):
            if r >= 0 and int(r) not in keep_set:
                self.slot_map[r] = -1
                self.slot_rows[s] = -1
        stage = np.array([r for r in rows if int(r) not in keep_set], dtype=np.int32)
        free = np.flatnonzero(self.slot_rows < 0)
        for s, r in zip(free, stage):
            self.slot_rows[s] = r
            self.slot_map[r] = s

        self.stats.staged_rows += int(stage.size)
        self.stats.kept_rows += len(keep_set)
        self.swaps += 1
        return int(stage.size)

    def pinned_rows(self) -> np.ndarray:
        """Currently resident row ids (unordered, no sentinel)."""
        return self.slot_rows[self.slot_rows >= 0].astype(np.int64)

    def prefetch(self, next_idx: np.ndarray) -> int:
        """Static residency: per-batch prefetch stages nothing."""
        return 0

    def slots_for(self, idx: np.ndarray, *, record: bool = True) -> np.ndarray:
        idx = np.asarray(idx)
        slots = self.slot_map[idx]
        if record:
            self.stats.accesses += int(idx.size)
            self.stats.hits += int((slots >= 0).sum())
            self.stats.batches += 1
        return slots

    def cache_rows(self) -> np.ndarray:
        return np.maximum(self.slot_rows, 0).astype(np.int32)


def pinned_from_plan(eplan) -> list[PinnedCache]:
    """One :class:`PinnedCache` per table, pinned to the offline plan's bet.

    The initial resident set is the plan's profiled popularity (logical-id
    trace counts folded onto big-subtable rows) — what ``plan()`` itself
    predicts is hot — falling back to the analyzer's prefetch values for
    trace-less plans.  A frozen pinned engine is exactly what the offline
    pass would deploy with no online information.
    """
    if not eplan.has_cache:
        raise ValueError("plan has no cache slots; set spec.cache_slots")
    caches = []
    for t, bag in enumerate(eplan.bags):
        _name, rows = _big_subtable(bag.emb)
        if getattr(eplan, "counts", ()):
            hot = fold_to_big(
                np.asarray(eplan.counts[t], dtype=np.float64),
                big_id_map(bag.emb), rows,
            )
        elif eplan.values:
            hot = np.asarray(eplan.values[t], dtype=np.float64)
        else:
            hot = np.arange(rows, 0, -1, dtype=np.float64)
        caches.append(
            PinnedCache(rows, eplan.slot_budgets[t], top_rows(hot, eplan.slot_budgets[t]))
        )
    return caches


def big_id_map(emb) -> np.ndarray:
    """(vocab, m) big-subtable row(s) touched by each logical id.

    ``m`` is 1 for dense/qr/tt and ``hashed_k`` for hashed tables; the map is
    how sketches over *logical* ids (what the serving loop sees) fold onto
    *big-subtable* rows (what the cache pins).
    """
    ids = np.arange(emb.vocab, dtype=np.int64)[:, None]
    big = np.asarray(_big_rows(ids, emb))
    return big.reshape(emb.vocab, -1)


def fold_to_big(est: np.ndarray, big_ids: np.ndarray, num_rows: int) -> np.ndarray:
    """Fold per-logical-id estimates onto big-subtable rows (sums mass)."""
    est = np.asarray(est, dtype=np.float64).reshape(-1)
    m = big_ids.shape[1]
    return np.bincount(
        big_ids.reshape(-1), weights=np.repeat(est, m), minlength=num_rows
    )[:num_rows]


def coverage(est: np.ndarray, rows: np.ndarray) -> float:
    """Predicted hit rate of pinning ``rows`` under the estimate vector."""
    est = np.asarray(est, dtype=np.float64)
    total = est.sum()
    if total <= 0:
        return 0.0
    return float(est[np.asarray(rows, dtype=np.int64)].sum() / total)


@dataclasses.dataclass
class IncrementalUpdate:
    """New runtime-arg state for every table: pinned rows + tiebreak values."""

    rows: list[np.ndarray]
    values: list[np.ndarray]
    predicted_hit: float = 0.0

    def apply(self, caches) -> int:
        """Swap into live caches; returns total rows staged.

        ``PinnedCache`` gets the new resident set; a ``PrefetchScheduler``
        (oracle arm) gets its analyzer tiebreak refreshed in place — both are
        pure runtime-arg mutations, shapes untouched.
        """
        staged = 0
        for cache, rows, value in zip(caches, self.rows, self.values):
            if hasattr(cache, "pin"):
                staged += cache.pin(rows)
            else:
                v = np.asarray(value, dtype=np.float64)
                cache.value = v / (v.max() + 1.0) if v.size else v
        return staged


def incremental_update(
    estimates: list[np.ndarray], slot_budgets: tuple[int, ...]
) -> IncrementalUpdate:
    """Sketch estimates (per big-subtable row) -> the cheap re-plan.

    Pure ranking: top ``slot_budgets[t]`` rows per table win residency, the
    raw estimates become the schedulers' tiebreak values.  ``predicted_hit``
    is the access-weighted coverage of the new pin across tables — the
    policy's gain numerator.
    """
    rows, values, hit_mass, mass = [], [], 0.0, 0.0
    for est, budget in zip(estimates, slot_budgets):
        est = np.asarray(est, dtype=np.float64)
        r = top_rows(est, budget)
        rows.append(r)
        values.append(est)
        hit_mass += float(est[r].sum())
        mass += float(est.sum())
    return IncrementalUpdate(
        rows=rows, values=values,
        predicted_hit=hit_mass / mass if mass > 0 else 0.0,
    )


def sampled_traces(
    sketches, *, n: int = 20_000, seed: int = 0
) -> list[np.ndarray]:
    """Synthesize one logical-index trace per table from the sketches.

    The sketch's full estimate vector, normalized, is a probability model of
    live traffic; sampling it gives ``plan()`` the same shaped input the
    offline Zipf profiler provides — the bridge from online observation back
    to the full analyzer/waterfill/duplication pass.
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xF0117]))
    traces = []
    for sk in sketches:
        est = sk.estimate_all()
        total = est.sum()
        if total <= 0:
            traces.append(rng.integers(0, sk.num_rows, size=n, dtype=np.int64))
            continue
        traces.append(rng.choice(sk.num_rows, size=n, p=est / total))
    return traces


def replan_full(
    spec, sketches, *, num_shards: int = 1, knobs=None, tuner=None,
    n: int = 20_000, seed: int = 0
):
    """The expensive path: full offline ``plan()`` on sketch-sampled traffic.

    Returns a fresh ``EmbeddingPlan`` — a *different* jit static argument;
    the caller owns recompiling and swapping the engine.  Reserved for
    policy-approved structural re-plans (duplication/packing/budgets), not
    the per-rotation residency swap.
    """
    traces = sampled_traces(sketches, n=n, seed=seed)
    return _offline_plan(
        spec, trace=traces, num_shards=num_shards, knobs=knobs, tuner=tuner
    )
