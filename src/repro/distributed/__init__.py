from repro.distributed import collectives, sharding  # noqa: F401
