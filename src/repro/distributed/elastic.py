"""Elastic scaling + fault-tolerance runtime policies.

What "1000+ nodes" requires and how this framework provides it:

* **Checkpoint/restart** — atomic sharded checkpoints (`repro.checkpoint`),
  auto-resume from the newest step, data-pipeline cursor persisted alongside
  (`Pipeline.state()`), deterministic per-(seed, step) batches ⇒ replay-exact
  restarts.
* **Elastic re-mesh** — ``reshard_tree`` moves a whole training state between
  meshes of different shape (e.g. 256-chip single pod ↔ 512-chip two-pod, or a
  degraded 240-chip mesh after losing a tray): the on-disk/logical arrays are
  mesh-agnostic; only the NamedShardings change.
* **Straggler mitigation** — the synchronous-SPMD answer is (a) deterministic
  re-dispatch: any host can recompute any batch slice, so a slow host can be
  fenced and its slice reassigned; (b) bounded-staleness gradient accumulation
  across pods: the `pod` axis all-reduce may be skipped for ``stale_limit``
  steps (`PodAsyncState`), trading exactness for tail-latency immunity — the
  async-SGD trick restricted to the slow (DCN) axis.
* **Failure detection** — `Heartbeat` tracks per-host progress watermarks; the
  launcher re-meshes when a watermark stalls past the deadline.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.distributed import sharding as SH


def reshard_tree(tree, axes_tree, new_mesh: Mesh, rules=None):
    """Re-place every leaf onto ``new_mesh`` per its logical axes.

    Works device→device when memory allows; leaves not described by
    ``axes_tree`` (None) are replicated.
    """
    shardings = jax.tree.map(
        lambda axes: SH.named_sharding(new_mesh, axes, rules),
        axes_tree,
        is_leaf=lambda a: isinstance(a, tuple)
        and all(isinstance(x, (str, type(None))) for x in a),
    )
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s)
        if isinstance(s, NamedSharding)
        else jax.device_put(x, NamedSharding(new_mesh, jax.sharding.PartitionSpec())),
        tree,
        shardings,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


@dataclasses.dataclass
class Heartbeat:
    """Progress watermarks per host; a stalled watermark marks a failure.

    In a real deployment the watermark store is etcd/GCS; here it is an
    in-process dict with the same semantics, exercised by tests, the
    elastic-restart example, and the serving fault harness
    (``repro.serve.faults``).

    ``clock`` is injectable (defaults to ``time.monotonic``) so tests and the
    fault harness can drive the watermarks on a virtual clock; per-call
    ``now=`` overrides still win.  A host may be :meth:`register`-ed before
    its first beat — such an *empty-beat* host counts as stalled once the
    deadline passes its registration time, and holds :meth:`min_step` at 0
    (it has proven no progress), instead of being invisible.
    """

    deadline_s: float = 300.0
    marks: dict = dataclasses.field(default_factory=dict)
    clock: "object" = time.monotonic          # () -> float, injectable

    def _now(self, now: float | None) -> float:
        return self.clock() if now is None else now

    def register(self, host: int, now: float | None = None) -> None:
        """Declare a host expected to beat (step ``None`` until it does).

        Without registration a host that dies before its first beat is
        invisible to :meth:`failed_hosts`; registering starts its deadline
        clock immediately.  Re-registering a beating host is a no-op.
        """
        if host not in self.marks:
            self.marks[host] = (None, self._now(now))

    def beat(self, host: int, step: int, now: float | None = None) -> None:
        self.marks[host] = (int(step), self._now(now))

    def failed_hosts(self, now: float | None = None) -> list[int]:
        """Hosts whose last beat (or registration) stalled past the deadline."""
        now = self._now(now)
        return [h for h, (_, t) in self.marks.items() if now - t > self.deadline_s]

    def min_step(self) -> int:
        """The fleet's progress watermark: the smallest step any known host
        has proven.  Empty-beat (registered, never beat) hosts pin it at 0;
        no hosts at all is also 0."""
        steps = [s for s, _ in self.marks.values()]
        if any(s is None for s in steps):
            return 0
        return min(steps, default=0)

    def alive_hosts(self, now: float | None = None) -> list[int]:
        """Complement of :meth:`failed_hosts` over the known hosts."""
        failed = set(self.failed_hosts(now))
        return [h for h in self.marks if h not in failed]


@dataclasses.dataclass
class PodAsyncState:
    """Bounded-staleness cross-pod gradient exchange.

    Within a pod, gradients all-reduce synchronously over ICI every step.
    Across pods (slow DCN), the exchange may lag up to ``stale_limit`` steps:
    each pod applies its local gradient immediately and folds in the other
    pods' *delayed* contribution when it arrives.  ``should_sync`` is the
    policy hook the train loop consults; tests assert convergence parity at
    stale_limit=0 and bounded divergence at small limits.
    """

    stale_limit: int = 4
    last_sync: int = 0

    def should_sync(self, step: int, *, pod_slow: bool = False) -> bool:
        if step - self.last_sync >= self.stale_limit:
            return True
        return not pod_slow

    def mark_synced(self, step: int) -> None:
        self.last_sync = step


def degraded_mesh_shapes(num_devices: int, model_axis: int) -> list[tuple[int, int]]:
    """Usable (data, model) shapes after losing devices (elastic fallback).

    Keeps the model axis intact (weights stay shardable) and shrinks data.
    """
    shapes = []
    d = num_devices // model_axis
    while d >= 1:
        shapes.append((d, model_axis))
        d //= 2
    return shapes
