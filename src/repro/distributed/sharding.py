"""Logical-axis sharding: flax-linen-style rules without flax.

Models annotate tensors with *logical* axis names ("batch", "heads", "qrow",
…); a rules table maps logical names to mesh axes. ``constrain`` is a no-op
outside a rules context, so the same model code runs on 1 CPU device (smoke
tests) and on the 512-chip production mesh (dry-run).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import jax_compat

_state = threading.local()


# Default production rules (single-pod). "pod" is prepended to batch for the
# multi-pod mesh. None = replicated along that logical axis.
DEFAULT_RULES: dict[str, tuple[str, ...] | None] = {
    "batch": ("data",),
    "seq": None,            # "model" under sequence parallelism (hillclimb)
    "kvseq": ("model",),    # decode KV cache length — the big decode tensor
    "embed": None,
    "heads": ("model",),
    "kv_heads": None,        # GQA kv heads are few; replicate
    "head_dim": None,
    "ffn": ("model",),
    "vocab": ("model",),
    "qrow": ("model",),     # Q-table rows = the "bank group" axis
    "rrow": None,            # R table = replicated LUT tier
    "experts": ("model",),  # EP
    "expert_ffn": None,
    "layers": None,
    "state": None,           # SSM state dim
    "mlp": None,
    "table": None,           # DLRM table index axis
}


def multi_pod_rules(rules: Mapping[str, tuple[str, ...] | None] | None = None) -> dict:
    """Extend batch-like axes over the 'pod' axis for the 2-pod mesh."""
    base = dict(DEFAULT_RULES if rules is None else rules)
    for k in ("batch",):
        v = base.get(k) or ()
        if "pod" not in v:
            base[k] = ("pod",) + tuple(v)
    return base


# Parameter (at-rest) rules: TP over `model`, FSDP over `data` — optimizer
# state inherits these leaf-for-leaf (ZeRO-style). Activations use
# DEFAULT_RULES; the two tables share logical names but map differently.
PARAM_RULES: dict[str, tuple[str, ...] | None] = {
    "batch": None,
    "embed": ("data",),      # FSDP axis for every weight's d_model dim
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "ffn": ("model",),
    "vocab": ("model",),
    "qrow": ("model",),      # Q-table rows = the "bank group" axis
    "rrow": None,            # R table = replicated LUT tier
    "experts": ("model",),
    "expert_ffn": ("model",),  # picked up when `experts` doesn't divide
    "layers": None,
    "state": None,
    "mlp": ("model",),
    "table": None,
}


def multi_pod_param_rules(rules: Mapping | None = None) -> dict:
    """FSDP additionally over 'pod' for the 2-pod mesh."""
    base = dict(PARAM_RULES if rules is None else rules)
    v = base.get("embed") or ()
    if "pod" not in v:
        base["embed"] = ("pod",) + tuple(v)
    return base


def resolve_spec(
    mesh: Mesh,
    shape: Sequence[int],
    logical_axes: Sequence[str | None],
    rules: Mapping[str, tuple[str, ...] | None],
) -> P:
    """First-fit spec resolution with divisibility + duplicate-axis dropping.

    For each tensor dim, the rule's mesh axes are applied only if (a) the axis
    is not already used by an earlier dim of the same tensor and (b) the dim
    size is divisible by the product of the accepted axes. Handles kv=1 MQA,
    40 experts on a 16-way axis, odd vocab sizes, etc. with one rule table.
    """
    used: set[str] = set()
    parts: list = []
    for dim, ax in zip(shape, logical_axes):
        ent = rules.get(ax) if ax else None
        if not ent:
            parts.append(None)
            continue
        accepted: list[str] = []
        prod = 1
        for mesh_ax in ent:
            if mesh_ax in used or mesh_ax not in mesh.shape:
                continue
            size = mesh.shape[mesh_ax]
            if dim % (prod * size) == 0:
                accepted.append(mesh_ax)
                prod *= size
        used.update(accepted)
        if not accepted:
            parts.append(None)
        elif len(accepted) == 1:
            parts.append(accepted[0])
        else:
            parts.append(tuple(accepted))
    return P(*parts)


def _is_axes_tuple(a) -> bool:
    return isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a)


def shardings_for_tree(mesh: Mesh, tree, axes_tree, rules: Mapping):
    """NamedShardings for a pytree of arrays/SDS given its logical-axes tree.

    Leaves whose axes annotation is missing/mismatched fall back to
    replication — safe for scalars and small state.
    """
    flat_axes = {}

    def record(path, axes):
        flat_axes[path] = axes

    # walk axes tree by path so arrays and axes may differ in leaf typing
    for path, axes in jax_compat.tree_flatten_with_path(
        axes_tree, is_leaf=_is_axes_tuple
    )[0]:
        record(tuple(str(p) for p in path), axes)

    def leaf(path, x):
        axes = flat_axes.get(tuple(str(p) for p in path))
        if not _is_axes_tuple(axes) or len(axes) != len(x.shape):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, resolve_spec(mesh, x.shape, axes, rules))

    flat, treedef = jax_compat.tree_flatten_with_path(tree)
    return jax.tree.unflatten(treedef, [leaf(tuple(str(p) for p in pa), x) for pa, x in flat])


@contextlib.contextmanager
def use_rules(mesh: Mesh | None, rules: Mapping[str, tuple[str, ...] | None] | None):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, dict(rules) if rules else None)
    try:
        yield
    finally:
        _state.ctx = prev


def current_mesh() -> Mesh | None:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def current_rules() -> dict | None:
    ctx = getattr(_state, "ctx", None)
    return ctx[1] if ctx else None


def spec_for(logical_axes: Sequence[str | None]) -> P:
    """PartitionSpec for a tuple of logical axis names under current rules.

    Mesh axes are assigned first-come-first-served across the tensor's dims
    (a mesh axis may appear at most once in a spec) — e.g. under sequence
    parallelism a (batch, seq, heads, head_dim) tensor gets seq->model and
    heads falls back to replicated."""
    rules = current_rules()
    if rules is None:
        return P()
    used: set[str] = set()
    parts = []
    for ax in logical_axes:
        ent = rules.get(ax) if ax else None
        ent = tuple(a for a in (ent or ()) if a not in used)
        used.update(ent)
        if not ent:
            parts.append(None)
        elif len(ent) == 1:
            parts.append(ent[0])
        else:
            parts.append(tuple(ent))
    return P(*parts)


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without rules/mesh."""
    mesh = current_mesh()
    rules = current_rules()
    if mesh is None or rules is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{len(logical_axes)} axes for rank-{x.ndim} tensor")
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec_for(logical_axes)))


def named_sharding(mesh: Mesh, logical_axes: Sequence[str | None],
                   rules: Mapping | None = None) -> NamedSharding:
    """Resolve logical axes to a NamedSharding (for in_shardings at jit time)."""
    rules = dict(DEFAULT_RULES if rules is None else rules)
    parts = []
    for ax in logical_axes:
        ent = rules.get(ax) if ax else None
        if ent is None:
            parts.append(None)
        elif len(ent) == 1:
            parts.append(ent[0])
        else:
            parts.append(tuple(ent))
    return NamedSharding(mesh, P(*parts))


def tree_shardings(mesh: Mesh, axes_tree, rules: Mapping | None = None):
    """Map a pytree of logical-axes tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: named_sharding(mesh, axes, rules),
        axes_tree,
        is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a),
    )
