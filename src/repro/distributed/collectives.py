"""Distributed-optimization collectives: compression + overlap helpers.

For 1000+-node deployments the cross-pod (DCN) links are far slower than ICI;
gradient compression with error feedback keeps the pod axis usable. These are
pure-JAX (shard_map-compatible) and exercised in tests on small meshes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return q.astype(dtype) * scale.astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed all-reduce over ``axis_name`` (inside shard_map).

    A shared scale is agreed first (pmax of local amax — an 8-byte collective),
    then int8 payloads are psum'd in int32 and dequantized by the shared scale.
    Wire bytes: ~x.size (int8) instead of 4*x.size.
    """
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = jax.lax.pmax(amax, axis_name) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return acc.astype(x.dtype) * scale.astype(x.dtype)


def ef_step(grad: jax.Array, residual: jax.Array, axis_name: str) -> tuple[jax.Array, jax.Array]:
    """Error-feedback compressed all-reduce step.

    Adds the carried quantization residual to the gradient, reduces the
    compressed sum, and returns (reduced_grad, new_residual). The residual is
    the part the shared-scale int8 wire format could not represent locally.
    """
    g = grad + residual
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = jax.lax.pmax(amax, axis_name) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    local_deq = q.astype(g.dtype) * scale.astype(g.dtype)
    new_residual = g - local_deq
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return acc.astype(g.dtype) * scale.astype(g.dtype), new_residual
