"""Version compatibility shims for the jax APIs this repo leans on.

The codebase targets current jax (``jax.shard_map``, ``jax.sharding.AxisType``)
but must also run on older runtimes (0.4.x) where ``shard_map`` still lives in
``jax.experimental`` with the ``check_rep`` spelling and meshes have no
``axis_types``.  Every mesh / shard_map call site goes through this module so
the difference is absorbed in exactly one place.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with graceful fallback to the experimental API."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def tree_flatten_with_path(tree, is_leaf=None):
    """``jax.tree.flatten_with_path`` with fallback to ``jax.tree_util``."""
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree, is_leaf=is_leaf)
    return jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with explicit Auto axis types where supported, and a
    ``mesh_utils.create_device_mesh`` fallback for runtimes predating
    ``jax.make_mesh`` (0.4.x)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if hasattr(jax, "make_mesh"):
        if axis_type is not None:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes)
            )
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils

    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)
