"""Synthetic data: LM token streams + criteo-like long-tail embedding traces.

Determinism contract (fault-tolerance requirement): every batch is a pure
function of ``(seed, step)`` — a restarted or re-scheduled worker regenerates
byte-identical batches, so checkpoint-resume and straggler re-execution are
replay-exact.  Zipf traces model the paper's long-tail access distribution
(\"a small subset of embeddings takes the majority of access\" — the hot-vector
premise behind the tiered placement).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig, ModelConfig


def _key(seed: int, step: int, tag: int = 0) -> jax.Array:
    return jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), tag)


# ---------------------------------------------------------------------------
# LM batches
# ---------------------------------------------------------------------------

def lm_batch(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0, step: int = 0):
    tokens = jax.random.randint(_key(seed, step), (batch, seq), 0, cfg.vocab, jnp.int32)
    return {"tokens": tokens}


def whisper_batch(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0, step: int = 0):
    from repro.models.whisper import N_AUDIO

    frames = jax.random.normal(
        _key(seed, step, 1), (batch, N_AUDIO, cfg.d_model), jnp.float32
    )
    tokens = jax.random.randint(_key(seed, step), (batch, seq), 0, cfg.vocab, jnp.int32)
    return {"frames": frames, "tokens": tokens}


def pixtral_batch(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0, step: int = 0):
    patches = jax.random.normal(
        _key(seed, step, 1), (batch, cfg.num_patches, cfg.d_model), jnp.float32
    )
    tokens = jax.random.randint(_key(seed, step), (batch, seq), 0, cfg.vocab, jnp.int32)
    return {"patches": patches, "tokens": tokens}


# ---------------------------------------------------------------------------
# long-tail (Zipf) traces — the paper's access model
# ---------------------------------------------------------------------------

def zipf_probs(vocab: int, alpha: float = 1.05) -> np.ndarray:
    """Zipf(alpha) over a fixed random permutation of row ids.

    The permutation matters: the paper observes hot rows are *scattered* across
    the table (which is why quotient-folding shrinks the hot set sub-linearly);
    an unpermuted Zipf would cluster them at low ids and overstate the gain.
    """
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -alpha
    p /= p.sum()
    rng = np.random.default_rng(1234)
    perm = rng.permutation(vocab)
    out = np.empty_like(p)
    out[perm] = p
    return out


def zipf_trace(
    vocab: int, n: int, *, alpha: float = 1.05, seed: int = 0, step: int = 0
) -> np.ndarray:
    """n long-tail logical indices (host-side numpy, for profiling/benches)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    return rng.choice(vocab, size=n, p=zipf_probs(vocab, alpha)).astype(np.int32)


def zipf_batch_jax(
    vocab: int, shape: tuple, *, alpha: float = 1.05, seed: int = 0, step: int = 0
) -> jax.Array:
    """Device-side approximate Zipf sampling via inverse-CDF on uniform draws."""
    u = jax.random.uniform(_key(seed, step, 2), shape, jnp.float32, 1e-6, 1.0)
    # inverse CDF of a continuous zipf-like density x^-alpha on [1, vocab]
    a = 1.0 - alpha
    x = ((vocab ** a - 1.0) * u + 1.0) ** (1.0 / a)
    idx = jnp.clip(x.astype(jnp.int32) - 1, 0, vocab - 1)
    # fixed permutation to scatter hot ids (cheap multiplicative shuffle)
    return ((idx.astype(jnp.uint32) * np.uint32(2654435761)) % np.uint32(vocab)).astype(
        jnp.int32
    )


def dlrm_batch(
    cfg: DLRMConfig, batch: int, *, seed: int = 0, step: int = 0, alpha: float = 1.05
):
    """Dense features + per-table multi-hot Zipf indices + random labels."""
    dense = jax.random.normal(_key(seed, step, 3), (batch, cfg.num_dense), jnp.float32)
    idx = zipf_batch_jax(
        cfg.vocab_per_table, (batch, cfg.num_tables, cfg.pooling),
        alpha=alpha, seed=seed, step=step,
    )
    labels = jax.random.bernoulli(_key(seed, step, 4), 0.25, (batch,)).astype(jnp.float32)
    return {"dense": dense, "idx": idx, "labels": labels}


def dlrm_truth(cfg: DLRMConfig, *, dim: int = 8, seed: int = 99) -> jax.Array:
    """Ground-truth item embeddings for planted-structure CTR labels."""
    return jax.random.normal(
        jax.random.PRNGKey(seed), (cfg.vocab_per_table, dim)
    ) * 0.5


def dlrm_planted_batch(
    cfg: DLRMConfig, truth: jax.Array, batch: int, *, seed: int = 0, step: int = 0,
    alpha: float = 1.05,
):
    """CTR batch whose labels come from a planted embedding model — a learnable
    signal, so AUC against it measures real model quality (used by the
    collision-vs-quality reproduction and the DLRM example)."""
    dense = jax.random.normal(_key(seed, step, 3), (batch, cfg.num_dense), jnp.float32)
    idx = zipf_batch_jax(
        cfg.vocab_per_table, (batch, cfg.num_tables, cfg.pooling),
        alpha=alpha, seed=seed, step=step,
    )
    score = truth[idx].sum(axis=(1, 2)).mean(-1) + 0.1 * dense.sum(-1)
    prob = jax.nn.sigmoid(score - score.mean())
    labels = (
        jax.random.uniform(_key(seed, step, 4), (batch,)) < prob
    ).astype(jnp.float32)
    return {"dense": dense, "idx": idx, "labels": labels}


# ---------------------------------------------------------------------------
# sharded host pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Pipeline:
    """Deterministic, restart-safe batch iterator.

    ``state()`` returns the cursor persisted in checkpoints; ``seek`` resumes.
    Each host in a multi-host launch uses its own ``shard``/``num_shards`` and
    generates only its slice, identical across retries (straggler-safe).
    """

    make_batch: callable
    seed: int = 0
    step: int = 0
    shard: int = 0
    num_shards: int = 1

    def __iter__(self):
        return self

    def __next__(self):
        b = self.make_batch(seed=self.seed * self.num_shards + self.shard, step=self.step)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def seek(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self.step = int(state["step"])
