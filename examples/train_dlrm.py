"""End-to-end driver: train a DLRM whose embedding layer is the paper's
weight-sharing operator, for a few hundred steps, with checkpoints.

The configuration serves a ~330M-parameter *logical* embedding capacity
(26 tables x 200K rows x 64 dims) from ~5.3M physical parameters via QR
(collision 64) — exactly the memory-capacity story the paper targets — and
trains it against synthetic long-tail (Zipf) CTR traces with planted
structure, reporting loss + AUC.

Run:  PYTHONPATH=src python examples/train_dlrm.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer as ckpt
from repro.configs.base import DLRMConfig
from repro.data.synthetic import dlrm_planted_batch, dlrm_truth
from repro.models import dlrm
from repro.train import optimizer as opt_mod
from repro.train.train_step import make_dlrm_loss, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--ckpt-dir", default=None,
                    help="default: /tmp/repro_dlrm_ckpt_<embedding> (keyed by "
                         "kind so switching --embedding never resumes a "
                         "checkpoint with a mismatched table structure)")
    ap.add_argument("--embedding", choices=["qr", "tt", "dense"], default="qr",
                    help="weight-sharing algorithm (dense = paper baseline)")
    ap.add_argument("--dense-baseline", action="store_true",
                    help="alias for --embedding dense (paper baseline)")
    ap.add_argument("--tt-rank", type=int, default=16)
    args = ap.parse_args()
    kind = "dense" if args.dense_baseline else args.embedding
    if args.ckpt_dir is None:
        args.ckpt_dir = f"/tmp/repro_dlrm_ckpt_{kind}"

    cfg = DLRMConfig(
        name=f"dlrm-{kind}-example",
        num_tables=26,
        vocab_per_table=200_000,
        dim=64,
        pooling=8,
        bottom_mlp=(256, 128, 64),
        top_mlp=(256, 128, 1),
        embedding_kind=kind,
        qr_collision=64,
        tt_rank=args.tt_rank,
    )
    logical = cfg.num_tables * cfg.vocab_per_table * cfg.dim
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
    physical = sum(int(x.size) for x in jax.tree.leaves(params["tables"]))
    print(f"logical embedding params {logical/1e6:.0f}M -> physical "
          f"{physical/1e6:.2f}M ({logical/max(physical,1):.0f}x)")

    opt_cfg = opt_mod.OptConfig(lr=2e-3, warmup_steps=20, total_steps=args.steps)
    step = jax.jit(make_train_step(make_dlrm_loss(cfg), opt_cfg))
    opt = opt_mod.init(params)

    start = 0
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest:
        (params, opt), extra = ckpt.restore(
            args.ckpt_dir, latest, (params, opt))
        start = latest
        print(f"[resume] from step {start}")

    truth = dlrm_truth(cfg)            # planted structure -> learnable AUC
    t0 = time.time()
    for s in range(start, args.steps):
        batch = dlrm_planted_batch(cfg, truth, args.batch, seed=0, step=s)
        params, opt, m = step(params, opt, batch)
        if (s + 1) % 25 == 0:
            print(f"step {s+1:4d}  loss {float(m['loss']):.4f}  "
                  f"({(time.time()-t0)/(s-start+1):.2f}s/step)")
        if (s + 1) % 100 == 0:
            ckpt.save(args.ckpt_dir, s + 1, (params, opt))
            ckpt.prune(args.ckpt_dir, keep=2)

    # evaluation on held-out traces
    test = dlrm_planted_batch(cfg, truth, 4096, seed=123, step=10_000)
    logits = dlrm.forward_dlrm(params, test["dense"], test["idx"], cfg)
    print(f"final: loss {float(dlrm.bce_loss(logits, test['labels'])):.4f}  "
          f"auc {float(dlrm.auc(logits, test['labels'])):.4f}")


if __name__ == "__main__":
    main()
