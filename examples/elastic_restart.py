"""Fault-tolerance drill: train on one mesh, 'lose' devices, resume on a
smaller mesh from the atomic checkpoint — losses line up across the re-mesh.

This is the elastic path a 1000-node deployment needs when a tray drops out:
checkpoints are mesh-agnostic, the data pipeline cursor is persisted, and
batches are pure functions of (seed, step), so the restarted run replays the
exact batch stream.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import shutil
import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer as ckpt
from repro.configs import registry
from repro.data.synthetic import Pipeline
from repro.distributed import elastic, sharding as SH
from repro.launch.mesh import make_mesh
from repro.train import optimizer as opt_mod
from repro.train.train_step import make_train_step

CKPT = "/tmp/repro_elastic_ckpt"


def build(binding, cfg, mesh):
    loss0 = registry.train_loss_fn(binding, cfg)
    rules = dict(SH.DEFAULT_RULES)

    def loss_fn(p, b):
        with SH.use_rules(mesh, rules):
            return loss0(p, b)

    return jax.jit(make_train_step(
        loss_fn, opt_mod.OptConfig(lr=1e-3, warmup_steps=2, total_steps=40)))


def main() -> None:
    shutil.rmtree(CKPT, ignore_errors=True)
    binding = registry.get("qwen2-1.5b")
    cfg = binding.smoke
    params, axes = registry.init_fn(binding)(jax.random.PRNGKey(0), cfg)
    opt = opt_mod.init(params)
    pipe = Pipeline(make_batch=lambda seed, step: registry.make_batch_fn(
        binding, cfg)(8, 64, seed=seed, step=step))

    # --- phase 1: healthy 8-chip mesh (2 data x 4 model) -------------------
    mesh1 = make_mesh((2, 4), ("data", "model"))
    params = elastic.reshard_tree(params, axes, mesh1, SH.PARAM_RULES)
    opt["mu"] = elastic.reshard_tree(opt["mu"], axes, mesh1, SH.PARAM_RULES)
    opt["nu"] = elastic.reshard_tree(opt["nu"], axes, mesh1, SH.PARAM_RULES)
    step1 = build(binding, cfg, mesh1)
    print("phase 1: mesh (data=2, model=4)")
    for _ in range(6):
        params, opt, m = step1(params, opt, next(pipe))
    print(f"  step {pipe.step}: loss {float(m['loss']):.4f}")
    ckpt.save(CKPT, pipe.step, {"params": params, "opt": opt},
              extra={"pipeline": pipe.state()})
    print(f"  checkpointed at step {pipe.step}; simulating loss of 4 devices")

    # --- phase 2: degraded 4-chip mesh (1 data x 4 model) ------------------
    mesh2 = make_mesh((1, 4), ("data", "model"))
    latest = ckpt.latest_step(CKPT)
    state, extra = ckpt.restore(CKPT, latest, {"params": params, "opt": opt})
    params2 = elastic.reshard_tree(state["params"], axes, mesh2, SH.PARAM_RULES)
    opt2 = dict(state["opt"])
    opt2["mu"] = elastic.reshard_tree(opt2["mu"], axes, mesh2, SH.PARAM_RULES)
    opt2["nu"] = elastic.reshard_tree(opt2["nu"], axes, mesh2, SH.PARAM_RULES)
    pipe2 = Pipeline(make_batch=pipe.make_batch)
    pipe2.seek(extra["pipeline"])
    step2 = build(binding, cfg, mesh2)
    print(f"phase 2: resumed step {latest} on degraded mesh (data=1, model=4)")
    for _ in range(6):
        params2, opt2, m = step2(params2, opt2, next(pipe2))
    print(f"  step {pipe2.step}: loss {float(m['loss']):.4f}")
    print("elastic restart complete: same model, new mesh, replayed data stream")


if __name__ == "__main__":
    main()
