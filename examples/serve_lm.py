"""Batched LM serving with the weight-sharing embedding: prefill a prompt
batch, decode greedily, report tokens/s.  Exercises the same prefill/decode
paths the decode_32k / long_500k dry-run cells lower.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch xlstm-125m]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.train.serve_step import greedy_generate, serve_family


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m",
                    choices=sorted(registry.ARCHS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--embedding", default="qr", choices=["dense", "hashed", "qr"])
    args = ap.parse_args()

    binding = registry.get(args.arch)
    cfg = binding.smoke.replace(embedding_kind=args.embedding, qr_collision=8)
    params, _ = registry.init_fn(binding)(jax.random.PRNGKey(0), cfg)
    batch = registry.make_batch_fn(binding, cfg)(args.batch, args.prompt_len,
                                                 seed=0, step=0)
    fam = serve_family(binding.kind)
    max_len = args.prompt_len + args.max_new

    t0 = time.time()
    out = greedy_generate(fam, params, batch, cfg, max_new=args.max_new,
                          max_len=max_len)
    dt = time.time() - t0
    n = args.batch * args.max_new
    print(f"{args.arch} ({args.embedding} embedding): generated {out.shape} "
          f"in {dt:.2f}s -> {n/dt:.1f} tok/s (incl. compile)")

    # steady-state decode rate (compiled)
    logits, cache = jax.jit(lambda p, b: fam.prefill(p, b, cfg, max_len))(params, batch)
    step = jax.jit(lambda p, c, t, pos: fam.decode(p, c, t, pos, cfg))
    tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    _, cache = step(params, cache, tok, jnp.int32(args.prompt_len))  # warm
    t0 = time.time()
    iters = 20
    for i in range(iters):
        logits, cache = step(params, cache, tok, jnp.int32(args.prompt_len + i))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"steady-state decode: {args.batch*iters/dt:.1f} tok/s "
          f"({dt/iters*1000:.1f} ms/step)")


if __name__ == "__main__":
    main()
