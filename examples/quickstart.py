"""Quickstart: the paper's operator in 60 lines.

Builds a QR (weight-sharing) embedding table, looks tokens up three ways —
naive double-gather, associativity-fused GnR, and the Pallas LUT kernel
(interpret mode on CPU) — checks they agree, then runs a few training steps
of a small LM that uses the QR table as its vocab embedding.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import embedding_bag, hashing, qr_embedding
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.kernels import ops
from repro.train import optimizer as opt_mod
from repro.train.train_step import make_train_step


def main() -> None:
    # --- 1. a weight-shared table: 1M logical rows in 16K physical rows ----
    cfg = EmbeddingConfig(vocab=1_000_000, dim=128, kind="qr", collision=64,
                          compute_dtype=jnp.float32)
    params = qr_embedding.init(jax.random.PRNGKey(0), cfg)
    spec = cfg.qr_spec
    print(f"logical rows {cfg.vocab:,} -> physical {spec.q_rows + spec.r_rows:,} "
          f"({spec.compression:.1f}x compression, LUT = {spec.lut_bytes()/1024:.0f} KiB)")

    # --- 2. three equivalent lookups ---------------------------------------
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    naive = qr_embedding.lookup(params, idx, cfg).sum(axis=-2)        # 2 gathers
    bag = BagConfig(emb=cfg, pooling=32)
    fused = embedding_bag.bag_lookup(params, idx, bag)                # partial sums
    q_idx, r_idx = hashing.qr_decompose(idx, cfg.collision)
    kernel = ops.gnr_pooled(params["q"], params["r"], q_idx, r_idx)   # Pallas LUT
    np.testing.assert_allclose(naive, fused, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(naive, kernel, rtol=1e-4, atol=1e-4)
    print("naive == fused == pallas-LUT lookup: OK")

    # --- 3. the engine front door: declare -> plan -> compile -> execute ---
    from repro import engine as engine_mod

    spec = engine_mod.EngineSpec.from_bags([bag])       # tables + policies
    eng = engine_mod.compile(engine_mod.plan(spec))     # offline pass, once
    pooled = eng.lookup([params], idx[:, None, :])[:, 0]
    np.testing.assert_allclose(naive, pooled, rtol=1e-4, atol=1e-4)
    print(f"engine lookup == naive: OK  (plan: {eng.summary()})")

    # --- 4. a small LM whose vocab table is the QR operator ----------------
    binding = registry.get("qwen2-1.5b")
    lm_cfg = binding.smoke.replace(embedding_kind="qr", qr_collision=8)
    lm_params, _ = registry.init_fn(binding)(jax.random.PRNGKey(2), lm_cfg)
    step = jax.jit(make_train_step(
        registry.train_loss_fn(binding, lm_cfg),
        opt_mod.OptConfig(lr=1e-3, warmup_steps=2, total_steps=20),
    ))
    opt = opt_mod.init(lm_params)
    batch = registry.make_batch_fn(binding, lm_cfg)(8, 64, seed=0, step=0)
    for i in range(10):
        lm_params, opt, metrics = step(lm_params, opt, batch)
        if i % 3 == 0:
            print(f"  step {i}: loss {float(metrics['loss']):.4f}")
    print("quickstart done.")


if __name__ == "__main__":
    main()
