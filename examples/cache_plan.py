"""Walkthrough of the ProactivePIM cache subsystem: trace -> analyzer ->
duplication plan -> prefetch scheduler -> cached Pallas kernel.

Run: PYTHONPATH=src python examples/cache_plan.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import duplication, intra_gnr
from repro.cache.sram_cache import PrefetchScheduler
from repro.core import embedding_bag, placement
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.data.synthetic import zipf_trace
from repro.kernels import ops, ref


def main():
    emb = EmbeddingConfig(
        vocab=65_536, dim=128, kind="qr", collision=32,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    bag = BagConfig(emb=emb, pooling=16)
    pooling = bag.pooling

    # 1. Offline: profile a long-tail trace and measure intra-GnR locality.
    trace = zipf_trace(emb.vocab, 64_000, alpha=1.05, seed=0)
    bag_trace = trace.reshape(-1, pooling)
    locs = intra_gnr.analyze_table(bag_trace, emb)
    print("intra-GnR reuse per bag:",
          {k: round(v.mean_intra_reuse, 2) for k, v in locs.items()})

    # 2. Duplication plan: replicate R (+ hot Q rows) under a per-chip budget.
    counts = placement.profile_counts(trace, emb.vocab)
    plan = duplication.plan_duplication(
        [bag], [counts], num_shards=8, budget_bytes=1 * 2**20
    )
    t = plan.tables[0]
    print(f"duplication: replicated={t.replicated_bytes}B "
          f"hot_rows={t.hot_plan.num_hot} comm_free={t.comm_free} "
          f"local_share={t.local_share:.2f}")

    # 3. Serving: double-buffered prefetch + the cached gather kernel.
    params = embedding_bag.init_tables(jax.random.PRNGKey(0), [bag])[0]
    spec = emb.qr_spec
    sched = PrefetchScheduler(
        spec.q_rows, num_slots=512, value=locs["q"].prefetch_value()
    )
    batches = [
        zipf_trace(emb.vocab, 64 * pooling, seed=1, step=s).reshape(-1, pooling)
        for s in range(4)
    ]
    sched.prefetch(batches[0] // emb.collision)        # cold-start staging
    for s, idx in enumerate(batches):
        q_idx, r_idx = idx // emb.collision, idx % emb.collision
        slot = sched.slots_for(q_idx)
        cache = params["q"][jnp.asarray(sched.cache_rows())]   # staging DMA
        out = ops.cached_qr_pooled(
            params["q"], cache, params["r"],
            jnp.asarray(q_idx), jnp.asarray(slot), jnp.asarray(r_idx),
        )
        expect = ref.cached_qr_bag_ref(
            params["q"], cache, params["r"],
            jnp.asarray(q_idx), jnp.asarray(slot), jnp.asarray(r_idx),
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)
        if s + 1 < len(batches):                       # the prefetch hook
            sched.prefetch(batches[s + 1] // emb.collision)
    st = sched.stats
    print(f"served {st.batches} batches: hit rate {st.hit_rate:.3f}, "
          f"staged {st.staged_per_batch:.1f} rows/batch")
    tr = st.traffic_bytes(emb.dim * 4)
    print(f"modeled DRAM bytes: {tr['cached']} vs uncached {tr['baseline']} "
          f"({tr['cached'] / tr['baseline']:.2f}x)")


if __name__ == "__main__":
    main()
