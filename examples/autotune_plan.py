"""Walkthrough of the trace-driven autotuner: trace -> fit cost model ->
rank the knob space -> freeze the winner into an EmbeddingPlan.

Run: PYTHONPATH=src python examples/autotune_plan.py
"""

import os
import tempfile

from repro import engine, tune
from repro.configs.dlrm_qr import SMOKE
from repro.data.synthetic import zipf_trace


def main():
    # 1. The spec declares WHAT to serve; the knobs decide HOW.
    spec = engine.EngineSpec.from_dlrm(SMOKE, serving=True).replace(
        duplication=False
    )
    traces = [
        zipf_trace(b.emb.vocab, 16_384, alpha=1.05, seed=t)
        for t, b in enumerate(spec.bags)
    ]

    # The heuristic defaults are what plan() picks with no tuner at all.
    base = tune.default_knobs(spec, packable=True)
    print("heuristic knobs:", base.describe())
    print("knob space size:", len(tune.knob_space(spec, packable=True)))

    # 2. Fit a per-kernel linear cost model from the trace.  mode="auto"
    #    times real micro-runs on an accelerator and falls back to the
    #    loop-aware HLO analyzer on CPU; the fit memoizes to cache_path
    #    keyed by (spec digest, device kind), so re-running is free.
    cache = os.path.join(tempfile.gettempdir(), "autotune_memo.json")
    tuner = tune.fit(spec, traces, mode="auto", batch=16, max_samples=8,
                     cache_path=cache)
    print(f"\nfit: source={tuner.source} samples={len(tuner.samples)} "
          f"cached={tuner.from_cache} device={tuner.metadata['device_kind']}")
    for backend, model in tuner.models.items():
        coefs = {f: f"{c:.3g}" for f, c in zip(tune.FEATURES, model.coef)}
        print(f"  {backend}: {coefs}")

    # 3. Rank every candidate by predicted latency.
    print("\npredicted latency per candidate (best first):")
    for knobs, pred in tuner.rank(spec, packable=True)[:5]:
        tag = " <- heuristic" if knobs == base else ""
        print(f"  {pred * 1e6:9.1f} us  {knobs.describe()}{tag}")

    # 4. plan() freezes the winner; the knobs are part of the plan's hash,
    #    so differently-tuned plans never collide in the jit cache.
    eplan = engine.plan(spec, traces, tuner=tuner)
    print("\ntuned plan knobs:", eplan.knobs.describe())
    print("slot budgets:", eplan.slot_budgets)
    assert eplan.knobs in tune.knob_space(spec, packable=True)

    # The zero-trace fallback is bit-for-bit the old heuristic plan.
    assert engine.plan(spec) == engine.plan(spec, knobs=base)
    print("no-trace plan == heuristic-knobs plan: OK")


if __name__ == "__main__":
    main()
