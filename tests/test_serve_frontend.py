"""The resilient serving front end: arrival, faults, ladder, accounting.

The load-bearing claims:

* the traffic generator is a pure function of its spec (seeded);
* every generated request lands in exactly one accounting bucket
  (``unaccounted == 0`` — the conservation law the chaos CI gate relies on);
* the degradation ladder's kernel rungs (full / nocache / pertable) are
  **bitwise identical** — a mid-stream rung change is invisible to the
  model — and the baseline rung matches the engine's own jnp reference
  bitwise (single-chip and on an 8-device mesh);
* fault injection is deterministic and the retry/backoff/abandon path
  keeps the accounting identity intact.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import obs, serve
from repro.configs import registry
from repro.core import embedding_bag
from repro.launch.serve_rec import build_serve_state
from repro.models import dlrm
from repro.serve.degrade import RUNGS
from repro.serve.frontend import recovery_times


@pytest.fixture(scope="module")
def served():
    """One offline pass shared by the whole module (plan+compile is slow)."""
    cfg = registry.get_dlrm("dlrm-qr-smoke")
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
    state = build_serve_state(cfg, shards=4, alpha=1.05, seed=0)
    return cfg, params, state


def _frontend(served, *, faults=None, slo_text=None, **fkw):
    cfg, params, state = served
    fkw.setdefault("batch_size", 8)
    fkw.setdefault("queue_cap", 32)
    fkw.setdefault("service_mode", "fixed")
    slo = obs.SLOEngine(obs.SLOSpec.parse(
        slo_text or "p99_ms=60,objective=0.99,fast_window=4,slow_window=8"
    ))
    return serve.Frontend(
        cfg, serve.FrontendConfig(**fkw), state, params,
        slo=slo, faults=serve.FaultInjector(faults or serve.FaultSpec()),
    )


# ---------------------------------------------------------------------------
# arrival
# ---------------------------------------------------------------------------

def test_arrival_deterministic_and_sorted(served):
    cfg, _, _ = served
    spec = serve.ArrivalSpec(rate_rps=500, horizon_s=1.0, seed=7,
                             drift_period_s=0.3)
    a = serve.generate(spec, cfg)
    b = serve.generate(spec, cfg)
    assert len(a) == len(b) > 100
    for ra, rb in zip(a, b):
        assert ra.t_arrive_s == rb.t_arrive_s
        assert np.array_equal(ra.idx, rb.idx)
        assert np.array_equal(ra.dense, rb.dense)
    ts = [r.t_arrive_s for r in a]
    assert ts == sorted(ts) and all(0 <= t < 1.0 for t in ts)
    assert all(r.idx.shape == (cfg.num_tables, cfg.pooling) for r in a[:5])
    # a different seed moves the stream
    c = serve.generate(dataclasses.replace(spec, seed=8), cfg)
    assert len(c) != len(a) or ts != [r.t_arrive_s for r in c]


def test_flash_episode_raises_arrivals(served):
    cfg, _, _ = served
    base = serve.ArrivalSpec(rate_rps=300, horizon_s=2.0, seed=3)
    flash = dataclasses.replace(
        base, flash=(serve.FlashEpisode(0.5, 1.0, 8.0),)
    )
    n_base = len(serve.generate(base, cfg))
    n_flash = len(serve.generate(flash, cfg))
    # expected ~300*2 vs 300*1 + 2400*1: the flash stream is far denser
    assert n_flash > 2 * n_base
    in_ep = [r for r in serve.generate(flash, cfg) if 0.5 <= r.t_arrive_s < 1.5]
    assert len(in_ep) > 0.6 * n_flash


def test_arrival_parse_roundtrip():
    spec = serve.ArrivalSpec.parse(
        "rate=250,horizon=2,deadline_ms=100,alpha=1.1,"
        "flash=0.5+0.4x6,flash=1.2+0.2x3,drift_s=0.5,drift_frac=0.3,seed=9"
    )
    assert spec.rate_rps == 250 and spec.deadline_s == pytest.approx(0.1)
    assert len(spec.flash) == 2 and spec.flash[1].multiplier == 3.0
    assert spec.rate_at(0.6) == pytest.approx(250 * 6)
    assert spec.rate_at(1.9) == pytest.approx(250)
    with pytest.raises(ValueError, match="unknown --arrival key"):
        serve.ArrivalSpec.parse("bogus=1")
    with pytest.raises(ValueError, match="flash episode"):
        serve.ArrivalSpec.parse("flash=1.0")


def test_zipf_drift_moves_the_hot_set(served):
    cfg, _, _ = served
    spec = serve.ArrivalSpec(rate_rps=2000, horizon_s=1.0, seed=1,
                             drift_period_s=0.5, drift_fraction=0.25)
    reqs = serve.generate(spec, cfg)
    early = np.concatenate([r.idx.ravel() for r in reqs if r.t_arrive_s < 0.5])
    late = np.concatenate([r.idx.ravel() for r in reqs if r.t_arrive_s >= 0.5])
    off = serve.arrival.drift_offset(spec, 0.7, cfg.vocab_per_table)
    assert off > 0
    # the late hot set is the early hot set rotated by the drift offset
    top_early = np.bincount(early, minlength=cfg.vocab_per_table).argmax()
    top_late = np.bincount(late, minlength=cfg.vocab_per_table).argmax()
    assert top_late == (top_early + off) % cfg.vocab_per_table


# ---------------------------------------------------------------------------
# faults
# ---------------------------------------------------------------------------

def test_fault_parse_and_latching():
    spec = serve.FaultSpec.parse(
        "stall@1.0:0.5,drop@1.5,replica@2.0:1.0,gather@3.0:2,"
        "retries=2,backoff_ms=10,hosts=3"
    )
    assert spec.max_retries == 2 and spec.hosts == 3
    assert spec.backoff_s(0) == pytest.approx(0.01)
    assert spec.backoff_s(2) == pytest.approx(0.04)
    inj = serve.FaultInjector(spec)
    assert inj.advance(0.5) == []
    due = inj.advance(1.6)
    assert [e.kind for e in due] == ["stall", "drop"]
    assert inj.consume_stall_s() == pytest.approx(0.5)
    assert inj.consume_stall_s() == 0.0          # consumed exactly once
    assert inj.consume_prefetch_drop() is True
    assert inj.consume_prefetch_drop() is False
    inj.advance(3.1)
    with pytest.raises(serve.TransientGatherError):
        inj.check_gather()
    with pytest.raises(serve.TransientGatherError):
        inj.check_gather()
    inj.check_gather()                           # 2 armed, both consumed
    assert inj.exhausted()


def test_replica_loss_detected_and_recovers():
    spec = serve.FaultSpec(
        events=(serve.FaultEvent(t_s=1.0, kind="replica",
                                 duration_s=0.5, host=2),),
        hosts=4, hb_deadline_s=0.05,
    )
    inj = serve.FaultInjector(spec)
    for t in np.arange(0.0, 0.99, 0.02):
        inj.advance(float(t))
        assert not inj.replica_lost()
    inj.advance(1.0)                 # outage latches; host 2 goes silent
    assert not inj.replica_lost()    # watermark not yet past the deadline
    inj.advance(1.1)
    assert inj.replica_lost() and inj.lost_hosts() == [2]
    inj.advance(1.6)                 # outage over: the host beats again
    assert not inj.replica_lost()


def test_gather_retry_exhaustion_abandons_but_accounts(served):
    # arm more gather errors than retries: the first batch must be abandoned,
    # yet every request still lands in a bucket
    faults = serve.FaultSpec.parse("gather@0.0:10,retries=2")
    fe = _frontend(served, faults=faults)
    cfg = served[0]
    reqs = serve.generate(
        serve.ArrivalSpec(rate_rps=300, horizon_s=0.5, seed=2), cfg
    )
    rep = fe.run(reqs)
    st = rep["requests"]
    assert st["abandoned"] >= 1
    assert st["unaccounted"] == 0
    assert fe.stats.retries >= 2


# ---------------------------------------------------------------------------
# frontend: shedding, deadline batching, accounting
# ---------------------------------------------------------------------------

def _storm_requests(cfg, seed=4):
    return serve.generate(serve.ArrivalSpec(
        rate_rps=300, horizon_s=1.5, deadline_s=0.25, seed=seed,
        flash=(serve.FlashEpisode(0.4, 0.5, 8.0),),
    ), cfg)


@pytest.mark.parametrize("policy", ["reject_new", "drop_oldest"])
def test_shed_policies_and_identity(served, policy):
    cfg = served[0]
    fe = _frontend(served, shed_policy=policy, queue_cap=16)
    rep = fe.run(_storm_requests(cfg))
    st = rep["requests"]
    assert st["unaccounted"] == 0
    assert st["shed_total"] > 0          # the flash crowd must overflow cap 16
    if policy == "reject_new":
        assert st["shed_reject"] > 0 and st["shed_evict"] == 0
    else:
        assert st["shed_evict"] > 0 and st["shed_reject"] == 0
    assert st["served"] > 0
    assert rep["shed_rate"] == pytest.approx(st["shed_total"] / st["generated"])


def test_deadline_batching_closes_partial_batches(served):
    cfg = served[0]
    # sparse trickle: arrivals far apart, so full batches never assemble —
    # the assembly timeout must close singletons instead of waiting forever
    fe = _frontend(served, batch_size=8)
    reqs = serve.generate(serve.ArrivalSpec(rate_rps=20, horizon_s=1.0, seed=6), cfg)
    assert len(reqs) < 8 * 4             # genuinely sparse
    rep = fe.run(reqs)
    st = rep["requests"]
    assert st["unaccounted"] == 0
    assert st["served"] == st["generated"]          # nothing shed or missed
    assert fe.stats.batches >= max(2, len(reqs) // 8)
    # served latency bounded by assembly window + service, well under deadline
    assert rep["req_lat_p99_s"] < 0.25


def test_frontend_report_shape(served):
    cfg = served[0]
    fe = _frontend(served)
    rep = fe.run(_storm_requests(cfg))
    for key in ("requests", "deadline_miss_rate", "shed_rate", "virtual_qps",
                "req_lat_p99_s", "batch_lat_p99_s", "hit_rate", "degrade",
                "recoveries_s", "time_to_recover_s", "faults_injected",
                "calibration", "slo"):
        assert key in rep, key
    assert rep["calibration"]["service_mode"] == "fixed"
    assert rep["slo"]["observations"] == fe.stats.batches


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------

def test_ladder_steps_down_and_recovers_under_chaos(served):
    cfg = served[0]
    faults = serve.FaultSpec.parse("stall@0.6:0.5,replica@0.8:0.3")
    fe = _frontend(served, faults=faults)
    rep = fe.run(serve.generate(serve.ArrivalSpec(
        rate_rps=300, horizon_s=2.0, deadline_s=0.25, seed=11), cfg))
    trs = rep["degrade"]["transitions"]
    assert any(t["from"] == "full" for t in trs), "ladder never stepped down"
    assert rep["degrade"]["rung"] == "full", "ladder did not fully recover"
    assert rep["time_to_recover_s"] is not None
    assert rep["requests"]["unaccounted"] == 0
    # the replica outage must clamp the ladder at the policy floor:
    # while hosts were lost, no transition lands above pertable
    floor = RUNGS.index(fe.ladder.policy.floor_on_replica_loss)
    lost_window = [t for t in trs if t["reason"] == "replica_loss"]
    if lost_window:
        assert RUNGS.index(lost_window[0]["to"]) >= floor


def test_ladder_hysteresis_and_probe(served):
    cfg, params, state = served
    ladder = serve.DegradationLadder(
        state, params,
        serve.DegradePolicy(enter_burn=5.0, hysteresis_batches=3,
                            probe_after=2),
    )
    # sustained burn: steps are spaced by the hysteresis, never back-to-back
    for i in range(12):
        ladder.on_batch(batch_i=i, now_s=float(i), fast_burn=50.0)
    batches = [t["at_batch"] for t in ladder.transitions]
    assert all(b2 - b1 >= 3 for b1, b2 in zip(batches, batches[1:]))
    assert ladder.rung == "shed"
    # recovery: probe_after good batches per rung, one rung at a time
    start = 100
    for i in range(start, start + 40):
        ladder.on_batch(batch_i=i, now_s=float(i), fast_burn=0.0)
        if ladder.rung == "full":
            break
    assert ladder.rung == "full"
    ups = [t for t in ladder.transitions if "recovery" in t["reason"]]
    assert len(ups) == len(RUNGS) - 1


def test_ladder_replica_floor_blocks_recovery(served):
    cfg, params, state = served
    ladder = serve.DegradationLadder(state, params)
    # replica loss forces the floor immediately (bypasses hysteresis)
    ladder.on_batch(batch_i=0, now_s=0.0, fast_burn=0.0, replica_lost=True)
    assert ladder.rung == "pertable"
    # good batches cannot probe above the floor while the replica is lost
    for i in range(1, 20):
        ladder.on_batch(batch_i=i, now_s=float(i), fast_burn=0.0,
                        replica_lost=True)
    assert ladder.rung == "pertable"
    # replica returns: recovery resumes to full
    for i in range(20, 60):
        ladder.on_batch(batch_i=i, now_s=float(i), fast_burn=0.0)
        if ladder.rung == "full":
            break
    assert ladder.rung == "full"


# ---------------------------------------------------------------------------
# ladder numerics: rung parity
# ---------------------------------------------------------------------------

def _parity_setup(served, batch=8, seed=0):
    cfg, params, state = served
    from repro.data import synthetic

    b = synthetic.dlrm_batch(cfg, batch, seed=seed, step=1)
    idx = np.asarray(b["idx"])
    ladder = serve.DegradationLadder(state, params)
    scheds = state.fresh_schedulers()
    fe = serve.Frontend(cfg, serve.FrontendConfig(batch_size=batch),
                        state, params)
    rows = fe._rows_for(idx)
    # stage the cache so the full rung actually takes hits
    for t in range(cfg.num_tables):
        scheds[t].prefetch(rows[:, t])
    return cfg, params, ladder, scheds, idx, rows


def _rung_pooled(ladder, rung, idx, rows, scheds):
    ladder.rung_i = RUNGS.index(rung)
    return np.asarray(ladder.pooled(idx, rows, scheds))


def test_kernel_rungs_bitwise_identical_single_chip(served):
    _, _, ladder, scheds, idx, rows = _parity_setup(served)
    full = _rung_pooled(ladder, "full", idx, rows, scheds)
    assert np.asarray(
        scheds[0].slots_for(rows[:, 0], record=False) >= 0
    ).any(), "cache took no hits; the parity check would be vacuous"
    nocache = _rung_pooled(ladder, "nocache", idx, rows, scheds)
    pertable = _rung_pooled(ladder, "pertable", idx, rows, scheds)
    # the paper's degradation contract: dropping the cache or the shared
    # layout must not change a single bit of the pooled output
    assert full.dtype == nocache.dtype == pertable.dtype
    assert np.array_equal(full, nocache)
    assert np.array_equal(full, pertable)


def test_baseline_rung_matches_reference(served):
    cfg, params, ladder, scheds, idx, rows = _parity_setup(served)
    full = _rung_pooled(ladder, "full", idx, rows, scheds)
    base = _rung_pooled(ladder, "baseline", idx, rows, scheds)
    # bitwise vs the engine's own jnp reference (same numeric program)
    ref = np.asarray(embedding_bag.multi_bag_lookup(
        params["tables"], idx, list(served[2].bags)
    ))
    assert np.array_equal(base, ref)
    # float-tolerance vs the kernel rungs (different program, by design)
    np.testing.assert_allclose(
        base.astype(np.float32), full.astype(np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_rung_parity_on_8_device_mesh(mesh_runner):
    mesh_runner("""
import numpy as np, jax
from jax.sharding import Mesh
from repro import serve
from repro.configs import registry
from repro.core import embedding_bag
from repro.data import synthetic
from repro.launch.serve_rec import build_serve_state
from repro.models import dlrm
from repro.serve.degrade import RUNGS

assert jax.device_count() == 8
cfg = registry.get_dlrm("dlrm-qr-smoke")
params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
state = build_serve_state(cfg, shards=4, alpha=1.05, seed=0)
b = synthetic.dlrm_batch(cfg, 8, seed=0, step=1)
idx = np.asarray(b["idx"])
ladder = serve.DegradationLadder(state, params)
scheds = state.fresh_schedulers()
fe = serve.Frontend(cfg, serve.FrontendConfig(batch_size=8), state, params)
rows = fe._rows_for(idx)
for t in range(cfg.num_tables):
    scheds[t].prefetch(rows[:, t])

def rung(name):
    ladder.rung_i = RUNGS.index(name)
    return np.asarray(ladder.pooled(idx, rows, scheds))

full = rung("full")
assert np.array_equal(full, rung("nocache")), "nocache diverged on mesh"
assert np.array_equal(full, rung("pertable")), "pertable diverged on mesh"
base = rung("baseline")

# the sharded GSPMD baseline (the bottom rung's production form) agrees
# bitwise with the ladder's single-chip jnp program
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
gspmd = state.engine.baseline(mesh)
out = np.asarray(gspmd(params["tables"], idx))
assert np.array_equal(base, out), "sharded baseline diverged"
print("MESH_PARITY_OK")
""", n_devices=8)


def test_recovery_times_helper():
    trs = [
        {"from": "full", "to": "nocache", "t_s": 1.0},
        {"from": "nocache", "to": "pertable", "t_s": 1.5},
        {"from": "pertable", "to": "nocache", "t_s": 2.0},
        {"from": "nocache", "to": "full", "t_s": 3.0},
        {"from": "full", "to": "nocache", "t_s": 5.0},   # unfinished episode
    ]
    assert recovery_times(trs) == [2.0]
    assert recovery_times([]) == []


# ---------------------------------------------------------------------------
# chaos end-to-end (the CI gate's assertion set)
# ---------------------------------------------------------------------------

def test_chaos_storm_end_to_end(served):
    cfg = served[0]
    faults = serve.FaultSpec.parse(
        "stall@0.5:0.5,drop@0.6,replica@0.8:0.3,gather@1.2:1,retries=3"
    )
    fe = _frontend(served, faults=faults)
    reqs = serve.generate(serve.ArrivalSpec(
        rate_rps=300, horizon_s=2.0, deadline_s=0.25, seed=13,
        flash=(serve.FlashEpisode(0.4, 0.4, 6.0),),
    ), cfg)
    rep = fe.run(reqs)
    st = rep["requests"]
    # 1. the run completes with zero unaccounted requests
    assert st["unaccounted"] == 0
    assert st["generated"] == len(reqs)
    # 2. at least one ladder step-down and a full recovery
    trs = rep["degrade"]["transitions"]
    assert any(RUNGS.index(t["to"]) > RUNGS.index(t["from"]) for t in trs)
    assert rep["degrade"]["rung"] == "full"
    assert rep["time_to_recover_s"] is not None and rep["time_to_recover_s"] > 0
    # 3. the report carries p99 / shed rate / time-to-recover
    assert rep["req_lat_p99_s"] > 0
    assert 0 <= rep["shed_rate"] < 1
    # 4. every scheduled fault actually latched
    assert fe.faults.exhausted()
    assert fe.stats.stall_s_injected == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# queue order (fifo vs edf)
# ---------------------------------------------------------------------------

def _crafted_deadline_trace(cfg):
    """8 near-simultaneous arrivals: 4 loose deadlines first, 4 tight last.

    With batch_size=1 and a fixed 10ms service unit, FIFO serves in arrival
    order and completes the tight quartet at 50-80ms — mostly past their
    55ms deadline — while EDF pulls them to the front (the first dispatch
    happens before they arrive, so they complete 2nd-5th) and misses none.
    """
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(8):
        t = i * 1e-4
        deadline = 0.2 if i < 4 else 0.055
        reqs.append(serve.Request(
            rid=i, t_arrive_s=t, deadline_s=t + deadline,
            idx=rng.integers(0, 64, (cfg.num_tables, cfg.pooling),
                             dtype=np.int32),
            dense=np.zeros(cfg.num_dense, dtype=np.float32),
        ))
    return reqs


def test_edf_strictly_reduces_deadline_misses(served):
    cfg = served[0]
    reqs = _crafted_deadline_trace(cfg)
    misses = {}
    for order in ("fifo", "edf"):
        fe = _frontend(served, batch_size=1, queue_order=order)
        rep = fe.run(reqs)
        st = rep["requests"]
        assert st["unaccounted"] == 0
        assert st["served"] + st["deadline_missed"] == 8
        misses[order] = st["deadline_missed"]
    assert misses["edf"] == 0, "EDF must serve the tight quartet in time"
    assert misses["fifo"] >= 3, "FIFO must pay for arrival-order service"
    assert misses["edf"] < misses["fifo"]
