"""Observatory layer (repro.obs.{slo,recorder,attribution,report}): burn-rate
window math against hand-computed budgets, flight-recorder ring bounding and
dump-on-breach, attribution-vs-cost-model consistency, the serving-report
artifact, and the end-to-end serve_rec wiring (flight dumps whose records
match the tracer's span durations)."""

import json

import numpy as np
import pytest

from repro import obs
from repro.obs import attribution as A
from repro.obs import report as R
from repro.obs.recorder import BatchRecord, FlightRecorder, TelemetryJoin
from repro.obs.slo import SLOEngine, SLOSpec


@pytest.fixture(autouse=True)
def _obs_off():
    obs.disable()
    obs.registry().reset()
    obs.tracer().reset()
    obs.install_observatory()
    yield
    obs.disable()
    obs.registry().reset()
    obs.tracer().reset()
    obs.install_observatory()


# ---------------------------------------------------------------------------
# SLOSpec: CLI parsing + validation
# ---------------------------------------------------------------------------

def test_slospec_parse_cli_form():
    spec = SLOSpec.parse("p99_ms=50,hit=0.5,qps=100,objective=0.95,"
                         "fast_window=4,slow_window=16,name=prod")
    assert spec.p99_latency_s == pytest.approx(0.050)
    assert spec.hit_rate_floor == 0.5
    assert spec.qps_floor == 100.0
    assert spec.objective == 0.95
    assert spec.fast_window == 4 and spec.slow_window == 16
    assert spec.name == "prod"
    assert spec.budget_fraction == pytest.approx(0.05)
    json.dumps(spec.describe())


def test_slospec_parse_rejects_unknown_keys_and_bad_windows():
    with pytest.raises(ValueError, match="unknown --slo key"):
        SLOSpec.parse("p99ms=50")
    with pytest.raises(ValueError, match="key=value"):
        SLOSpec.parse("p99_ms")
    with pytest.raises(ValueError, match="fast_window"):
        SLOSpec(p99_latency_s=0.05, fast_window=8, slow_window=4)
    with pytest.raises(ValueError, match="objective"):
        SLOSpec(objective=1.0)


# ---------------------------------------------------------------------------
# burn-rate window math vs hand-computed budgets
# ---------------------------------------------------------------------------

def test_burn_rate_hand_computed():
    # objective 0.9 -> 10% budget: a window's burn = bad_fraction / 0.1
    eng = SLOEngine(SLOSpec(p99_latency_s=0.010, objective=0.9,
                            fast_window=4, slow_window=8))
    for _ in range(8):
        eng.observe(0.005)                  # 8 good
    assert eng.burn_rate(4) == 0.0 and eng.burn_rate(8) == 0.0
    for _ in range(2):
        eng.observe(0.020)                  # 2 bad
    # fast window = last 4 = [good, good, bad, bad] -> 0.5 / 0.1 = 5x
    assert eng.burn_rate(4) == pytest.approx(5.0)
    # slow window = last 8 = 6 good 2 bad -> 0.25 / 0.1 = 2.5x
    assert eng.burn_rate(8) == pytest.approx(2.5)
    # budget: 10 observations at 10% -> 1.0 allowed, 2 spent -> blown
    assert eng.budget_allowed == pytest.approx(1.0)
    assert eng.budget_spent == 2
    assert eng.budget_remaining_frac == pytest.approx(1.0 - 2.0 / 1.0)
    assert eng.breached                     # negative budget => breached


def test_burn_rate_short_history_uses_what_exists():
    eng = SLOEngine(SLOSpec(p99_latency_s=0.010, objective=0.9,
                            fast_window=4, slow_window=8))
    eng.observe(0.020)
    # only 1 observation: window of 8 sees [bad] -> 1.0 / 0.1 = 10x
    assert eng.burn_rate(8) == pytest.approx(10.0)


def test_page_alert_needs_both_windows_and_is_edge_triggered():
    # all-bad stream: both windows saturate -> exactly ONE page alert fires
    # (edge-triggered), not one per burning batch
    eng = SLOEngine(SLOSpec(p99_latency_s=0.010, objective=0.99,
                            fast_window=2, slow_window=4,
                            page_burn=10.0))
    fired = []
    for _ in range(10):
        fired += eng.observe(0.020)
    assert [a["severity"] for a in fired] == ["page"]
    assert fired[0]["at_batch"] == 1        # fired as soon as fast_window filled
    assert fired[0]["fast_burn"] == pytest.approx(100.0)
    assert eng.breached


def test_ticket_alert_on_slow_leak():
    # 1-in-3 bad: slow burn ~ 0.33/0.01 = 33x >= ticket(2) but the fast
    # window must NOT page (page needs BOTH windows >= 10 -- here fast often
    # is, so pick a sparser leak against a 10% budget instead)
    eng = SLOEngine(SLOSpec(p99_latency_s=0.010, objective=0.9,
                            fast_window=4, slow_window=12,
                            page_burn=10.0, ticket_burn=2.0))
    fired = []
    # 1 bad in every 4: slow burn = (3/12)/0.1 = 2.5x >= 2, fast burn =
    # (1/4)/0.1 = 2.5x < 10 -> ticket, never page
    for i in range(24):
        fired += eng.observe(0.020 if i % 4 == 0 else 0.005)
    sevs = {a["severity"] for a in fired}
    assert sevs == {"ticket"}


def test_evaluate_snapshot_streams_without_double_count():
    eng = SLOEngine(SLOSpec(p99_latency_s=0.010, objective=0.9,
                            fast_window=2, slow_window=4))
    obs.enable()
    for v in (0.005, 0.020, 0.005):
        obs.observe("serve/overlap/batch_latency_s", v)
    eng.evaluate_snapshot(obs.snapshot())
    assert eng.n == 3 and eng.bad_total == 1
    eng.evaluate_snapshot(obs.snapshot())   # same snapshot: nothing new
    assert eng.n == 3
    obs.observe("serve/overlap/batch_latency_s", 0.030)
    eng.evaluate_snapshot(obs.snapshot())   # only the new sample consumed
    assert eng.n == 4 and eng.bad_total == 2


def test_finalize_floors_and_state_json():
    eng = SLOEngine(SLOSpec(p99_latency_s=0.010, hit_rate_floor=0.8,
                            qps_floor=100.0, objective=0.9))
    eng.observe(0.005)
    floors = eng.finalize(hit_rate=0.95, qps=50.0)
    assert not floors["hit_rate"]["breached"]
    assert floors["qps"]["breached"]
    assert eng.breached                     # the qps floor alone breaches
    state = eng.state()
    json.dumps(state)
    assert state["breached"] and state["floors"]["qps"]["measured"] == 50.0
    assert state["observations"] == 1 and state["bad_events"] == 0


# ---------------------------------------------------------------------------
# flight recorder: ring bounding, MAD anomaly, dump caps
# ---------------------------------------------------------------------------

def _record(batch, lat, **kw):
    return BatchRecord(batch=batch, mode="overlap", latency_s=lat,
                       stages={}, counters={}, **kw)


def test_ring_is_bounded():
    rec = FlightRecorder(capacity=4)
    for t in range(10):
        rec.observe(_record(t, 0.01))
    assert len(rec) == 4
    assert [r.batch for r in rec.records] == [6, 7, 8, 9]


def test_mad_anomaly_threshold_and_dump(tmp_path):
    rec = FlightRecorder(capacity=8, out_dir=str(tmp_path), mad_k=6.0,
                         min_history=8)
    for t in range(8):                      # flat baseline ~10ms
        assert rec.observe(_record(t, 0.010 + 1e-5 * t)) is None
    cut = rec.anomaly_threshold()
    # flat history: MAD ~ 0, the relative floor keeps cut ~ med * 1.3
    assert 0.010 < cut < 0.020
    dump = rec.observe(_record(8, 0.050))   # 5x step: anomalous
    assert dump is not None and dump["reason"] == "latency_anomaly"
    assert rec.records[-1].anomaly
    doc = json.load(open(dump["path"]))
    assert doc["reason"] == "latency_anomaly"
    assert doc["context"]["trigger_batch"] == 8
    assert len(doc["records"]) == 8         # ring snapshot at dump time
    assert doc["records"][-1]["anomaly"]


def test_no_anomaly_before_min_history():
    rec = FlightRecorder(min_history=8)
    for t in range(5):
        rec.observe(_record(t, 0.010))
    assert rec.anomaly_threshold() is None
    assert rec.observe(_record(5, 10.0)) is None   # judged unknowable, kept
    assert not rec.records[-1].anomaly


def test_slo_alert_dump_and_max_dumps_cap(tmp_path):
    rec = FlightRecorder(capacity=4, out_dir=str(tmp_path), max_dumps=2)
    alert = {"severity": "page", "at_batch": 0}
    d0 = rec.observe(_record(0, 0.01), alerts=[alert])
    assert d0["reason"] == "slo_burn:page"
    d1 = rec.observe(_record(1, 0.01), alerts=[alert])
    assert d1 is not None
    assert rec.observe(_record(2, 0.01), alerts=[alert]) is None   # capped
    assert len(rec.dumps) == 2
    assert sorted(p.name for p in tmp_path.iterdir()) == [
        "flight_000.json", "flight_001.json",
    ]


def test_telemetry_join_stages_and_counter_deltas():
    obs.enable()
    join = TelemetryJoin(obs.tracer(), obs.registry())
    with obs.span("prefetch", batch=3):
        pass
    with obs.span("dispatch", batch=3):
        pass
    with obs.span("batch", batch=3):        # wrapper: dropped from stages
        pass
    with obs.span("pack_tables"):           # no batch arg: ignored
        pass
    obs.inc("engine/dispatch/serve_gather")
    r = join.next_record(batch=3, mode="overlap", latency_s=0.01)
    assert set(r.stages) == {"prefetch", "dispatch"}
    assert all(v >= 0.0 for v in r.stages.values())
    assert r.counters == {"engine/dispatch/serve_gather": 1}
    # deltas, not totals: an idle next batch carries no counters
    r2 = join.next_record(batch=4, mode="overlap", latency_s=0.01)
    assert r2.stages == {} and r2.counters == {}


# ---------------------------------------------------------------------------
# attribution: cost-model consistency + bottleneck flagging
# ---------------------------------------------------------------------------

def _serve_session(batches=4, batch=4):
    from repro.configs import registry
    from repro.launch import serve_rec

    cfg = registry.get_dlrm("dlrm-qr-smoke")
    obs.enable()
    res = serve_rec.run_pipeline(cfg, batch=batch, batches=batches,
                                 mode="sequential", fence=True)
    return res


def test_attribution_modeled_total_matches_cost_model_predict():
    res = _serve_session()
    from repro.configs import registry
    from repro.launch import serve_rec

    cfg = registry.get_dlrm("dlrm-qr-smoke")
    state = serve_rec.build_serve_state(cfg, shards=4, alpha=1.05, seed=0)
    att = A.attribute(obs.tracer().events, res["traffic_report"], state.eplan,
                      batch=4, fenced=True)
    # the decomposition is complete: cost-model stage terms sum to the
    # model's own prediction of the feature vector
    from repro.tune.cost_model import FEATURES

    feats = tuple(att.features[f] for f in FEATURES)
    assert att.modeled_total_s() == pytest.approx(
        att.model.predict(feats), rel=1e-9)
    # fenced session: every serving stage was measured
    measured = {r.stage for r in att.rows if r.measured_s is not None}
    assert {"prefetch", "pack", "h2d", "dispatch", "device_compute",
            "interact"} <= measured
    assert att.bottleneck in measured
    # shares sum to 1 over measured rows
    assert sum(r.share for r in att.rows if r.share is not None) \
        == pytest.approx(1.0)
    # bytes-bearing rows report both achieved and modeled GB/s
    dc = next(r for r in att.rows if r.stage == "device_compute")
    assert dc.bytes_per_batch > 0
    assert dc.achieved_gbps > 0 and dc.modeled_gbps > 0
    assert dc.residual_s == pytest.approx(dc.measured_s - dc.modeled_s)
    lr = att.largest_residual
    assert lr is not None and abs(lr["residual_s"]) <= att.total_s
    json.dumps(att.describe())
    assert att.describe()["schema"] == A.SCHEMA


def test_analytic_cost_model_prices_from_chip_constants():
    from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK
    from repro.tune.cost_model import FEATURES
    from repro.tune.tuner import DISPATCH_OVERHEAD_S

    m = A.analytic_cost_model()
    coef = dict(zip(FEATURES, m.coef))
    assert coef["dispatches"] == DISPATCH_OVERHEAD_S
    assert coef["hbm_bytes"] == pytest.approx(1.0 / HBM_BW)
    assert coef["comm_bytes"] == pytest.approx(1.0 / (2 * ICI_BW_PER_LINK))
    assert m.source == "analytic"


def test_model_terms_shared_with_roofline():
    """benchmarks/roofline.terms must price bytes/flops exactly like the
    serving attribution's model_terms (one source of truth)."""
    from benchmarks import roofline

    rec = {"status": "run", "mesh": "1pod", "chips": 4, "model_flops": 1e12,
           "hlo": {"flops": 4e12, "bytes": 8e9, "coll_wire_total": 1e9}}
    t = roofline.terms(rec)
    shared = A.model_terms(flops=4e12, hbm_bytes=8e9, wire_bytes=1e9)
    for k in ("compute_s", "memory_s", "collective_s", "step_s", "dominant"):
        assert t[k] == shared[k]
    rows = A.term_rows(shared, hbm_bytes=8e9, wire_bytes=1e9)
    assert [r["stage"] for r in rows] == ["compute", "memory", "collective"]
    assert all(r["basis"] == "roofline" for r in rows)
    mem = rows[1]
    assert mem["modeled_gbps"] == pytest.approx(
        8e9 / mem["modeled_s"] / 1e9)


# ---------------------------------------------------------------------------
# the serving-report artifact
# ---------------------------------------------------------------------------

def test_report_build_render_write(tmp_path):
    res = _serve_session()
    from repro.configs import registry
    from repro.launch import serve_rec

    cfg = registry.get_dlrm("dlrm-qr-smoke")
    state = serve_rec.build_serve_state(cfg, shards=4, alpha=1.05, seed=0)
    att = A.attribute(obs.tracer().events, res["traffic_report"], state.eplan,
                      batch=4, fenced=True)
    eng = SLOEngine(SLOSpec(p99_latency_s=1e-9, fast_window=1, slow_window=1,
                            qps_floor=1e9))
    for lat in res["latencies_s"]:
        eng.observe(lat)
    eng.finalize(hit_rate=res["hit_rate"], qps=res["qps"])
    rep = R.build(
        snapshot=obs.snapshot(), slo_state=eng.state(), attribution=att,
        traffic=res["traffic"],
        results={"sequential": {k: v for k, v in res.items()
                                if k not in ("logits", "latencies_s",
                                             "traffic_report")}},
        flight_dumps=[{"path": "f.json", "reason": "slo_burn:page",
                       "trigger_batch": 2, "records": 3}],
        meta={"config": cfg.name},
    )
    assert rep["schema"] == R.SCHEMA
    json.dumps(rep)
    md_path, jpath = R.write(rep, str(tmp_path / "report.md"), attribution=att)
    md = open(md_path).read()
    assert "**BREACHED**" in md
    assert f"**{att.bottleneck}" in md      # bottleneck named
    assert "achieved GB/s" in md and "modeled GB/s" in md
    assert "slo_burn:page" in md
    stored = json.load(open(jpath))
    assert stored["attribution"]["bottleneck"] == att.bottleneck
    # a stored report re-renders without the live Attribution object,
    # producing the same table
    re_md = R.render_markdown(stored)
    assert re_md.rstrip("\n") == md.rstrip("\n")


# ---------------------------------------------------------------------------
# end-to-end: serve_rec + observatory -> flight dump matches tracer spans
# ---------------------------------------------------------------------------

def test_pipeline_breach_dumps_flight_window_matching_tracer(tmp_path):
    from repro.configs import registry
    from repro.launch import serve_rec

    cfg = registry.get_dlrm("dlrm-qr-smoke")
    obs.enable()
    eng = SLOEngine(SLOSpec(p99_latency_s=1e-9, objective=0.99,
                            fast_window=2, slow_window=4))
    rec = FlightRecorder(capacity=16, out_dir=str(tmp_path))
    obs.install_observatory(slo=eng, recorder=rec)
    res = serve_rec.run_pipeline(cfg, batch=4, batches=6, mode="sequential",
                                 fence=True)
    # every steady-state batch was bad -> page alert -> at least one dump
    assert eng.n == len(res["latencies_s"]) == 5
    assert eng.breached and rec.dumps
    doc = json.load(open(rec.dumps[0]["path"]))
    assert doc["records"], "dump carries the ring"
    # each dumped record's stage durations equal the tracer's span durations
    # for that batch (sum over spans, us -> s), wrapper span excluded
    spans: dict = {}
    for ev in obs.tracer().events:
        if ev.get("ph") != "X" or ev["name"] == "batch":
            continue
        b = ev.get("args", {}).get("batch")
        if b is None:
            continue
        spans.setdefault(int(b), {}).setdefault(ev["name"], 0.0)
        spans[int(b)][ev["name"]] += ev["dur"] * 1e-6
    for r in doc["records"]:
        assert r["stages"], f"batch {r['batch']} record has no stages"
        assert r["stages"] == pytest.approx(spans[r["batch"]])
        # first steady-state record's delta also covers the warm-up dispatch
        expect = 2 if r["batch"] == 1 else 1
        assert r["counters"].get("engine/dispatch/serve_gather") == expect
    # the facade returned the observatory verdicts to the loop
    assert obs.observatory() is not None
    state = obs.observatory().state()
    assert state["slo"]["breached"] and state["flight_dumps"]


# ---------------------------------------------------------------------------
# satellite: serve_rec percentiles come from obs.metrics
# ---------------------------------------------------------------------------

def test_serve_rec_percentiles_are_the_shared_helper():
    from repro.launch import serve_rec
    from repro.obs.metrics import exact_percentile, latency_percentiles

    assert serve_rec._percentiles is obs.latency_percentiles
    samples = [0.001, 0.002, 0.003, 0.010, 0.020]
    got = latency_percentiles(samples)
    assert set(got) == {"lat_p50_s", "lat_p95_s", "lat_p99_s"}
    for q in (50, 95, 99):
        assert got[f"lat_p{q:g}_s"] == pytest.approx(
            np.percentile(samples, q))
    assert exact_percentile([], 99) == 0.0
