"""Fused Pallas TT gather-contract kernel vs the pure-jnp oracle
(interpret=True on CPU), plus integration with the tt_embedding module."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qr_embedding as QE, tt_embedding as TT
from repro.core.qr_embedding import EmbeddingConfig
from repro.kernels import ops, ref


def _cores(v1, v2, v3, dims, dtype, seed=0):
    d1, d2, d3, r = dims
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    g1 = jax.random.normal(k1, (v1, d1 * r), dtype)
    g2 = jax.random.normal(k2, (v2, r * d2 * r), dtype)
    g3 = jax.random.normal(k3, (v3, r * d3), dtype)
    return g1, g2, g3


def _indices(key, shape, v1, v2, v3):
    return (
        jax.random.randint(jax.random.fold_in(key, 1), shape, 0, v1),
        jax.random.randint(jax.random.fold_in(key, 2), shape, 0, v2),
        jax.random.randint(jax.random.fold_in(key, 3), shape, 0, v3),
    )


@pytest.mark.parametrize("dims", [(4, 8, 4, 4), (4, 8, 4, 16), (2, 4, 2, 8), (4, 4, 2, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tt_bag_sweep(dims, dtype):
    v1, v2, v3 = 8, 64, 8
    g1, g2, g3 = _cores(v1, v2, v3, dims, dtype)
    i1, i2, i3 = _indices(jax.random.PRNGKey(1), (6, 5), v1, v2, v3)
    out = ops.tt_pooled(g1, g2, g3, i1, i2, i3, dims=dims)
    expect = ref.tt_bag_ref(g1, g2, g3, i1, i2, i3, dims=dims)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=1e-5 if dtype == jnp.float32 else 3e-2, atol=1e-2,
    )


@pytest.mark.parametrize("k", [1, 4, 32])
def test_tt_bag_pooling_sizes(k):
    dims = (4, 8, 4, 8)
    g1, g2, g3 = _cores(16, 128, 16, dims, jnp.float32)
    i1, i2, i3 = _indices(jax.random.PRNGKey(2), (5, k), 16, 128, 16)
    out = ops.tt_pooled(g1, g2, g3, i1, i2, i3, dims=dims)
    expect = ref.tt_bag_ref(g1, g2, g3, i1, i2, i3, dims=dims)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("lead", [(7,), (2, 5), (3, 2, 2)])
def test_tt_lookup_leading_shapes(lead):
    dims = (4, 4, 2, 4)
    g1, g2, g3 = _cores(8, 32, 8, dims, jnp.float32)
    i1, i2, i3 = _indices(jax.random.PRNGKey(3), lead, 8, 32, 8)
    out = ops.tt_lookup(g1, g2, g3, i1, i2, i3, dims=dims)
    assert out.shape == lead + (32,)
    expect = ref.tt_row_ref(g1, g2, g3, i1, i2, i3, dims=dims)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-6)


def test_tt_small_dim_fallback():
    """dims with no 8-aligned output tile fall back to the jnp reference."""
    dims = (2, 3, 2, 2)                     # dim 12: not 8-aligned
    g1, g2, g3 = _cores(4, 8, 4, dims, jnp.float32)
    i1, i2, i3 = _indices(jax.random.PRNGKey(4), (3, 2), 4, 8, 4)
    out = ops.tt_pooled(g1, g2, g3, i1, i2, i3, dims=dims)
    expect = ref.tt_bag_ref(g1, g2, g3, i1, i2, i3, dims=dims)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)


def test_tt_bag_accumulates_fp32():
    """bf16 cores with many repeated adds must not lose precision (the fp32
    VMEM accumulator — 'MAC-unit accuracy')."""
    dims = (1, 1, 1, 1)
    k = 256
    g1 = jnp.full((2, 1), 1.0, jnp.bfloat16)
    g2 = jnp.full((2, 1), jnp.bfloat16(1.001), jnp.bfloat16)
    g3 = jnp.full((2, 1), 1.0, jnp.bfloat16)
    zeros = jnp.zeros((1, k), jnp.int32)
    out = ops.tt_pooled(g1, g2, g3, zeros, zeros, zeros, dims=dims)
    expect = float(jnp.bfloat16(1.001)) * k
    assert abs(float(out[0, 0]) - expect) / expect < 1e-2


def test_tt_kernel_matches_module_lookup():
    """The fused kernel reproduces tt_embedding.lookup numerics end to end:
    kind='tt' serving can swap the jnp path for the kernel transparently."""
    cfg = EmbeddingConfig(
        vocab=4096, dim=32, kind="tt", tt_rank=4,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    spec = cfg.tt_spec
    params = QE.init(jax.random.PRNGKey(5), cfg)
    idx = jax.random.randint(jax.random.PRNGKey(6), (17,), 0, cfg.vocab)
    i1, i2, i3 = TT.tt_decompose(idx, spec)
    out = ops.tt_lookup(
        params["g1"], params["g2"], params["g3"], i1, i2, i3,
        dims=(spec.d1, spec.d2, spec.d3, spec.rank),
    )
    expect = QE.lookup(params, idx, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-6)


def test_tt_bag_matches_pooled_module_bag():
    """Kernel bag == module-level pooled bag (the DLRM GnR contract)."""
    from repro.core.embedding_bag import BagConfig, bag_lookup

    cfg = EmbeddingConfig(
        vocab=4096, dim=32, kind="tt", tt_rank=4,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    spec = cfg.tt_spec
    params = QE.init(jax.random.PRNGKey(7), cfg)
    idx = jax.random.randint(jax.random.PRNGKey(8), (9, 8), 0, cfg.vocab)
    i1, i2, i3 = TT.tt_decompose(idx, spec)
    out = ops.tt_pooled(
        params["g1"], params["g2"], params["g3"], i1, i2, i3,
        dims=(spec.d1, spec.d2, spec.d3, spec.rank),
    )
    expect = bag_lookup(params, idx, BagConfig(emb=cfg, pooling=8))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-5)
