"""MoE dispatch: capacity semantics, EP equivalence on a mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod


def _cfg(**kw):
    base = dict(
        name="m", family="moe", num_layers=1, d_model=32, num_heads=4, kv_heads=2,
        d_ff=16, vocab=64, num_experts=8, top_k=2,
        compute_dtype="float32", param_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def test_moe_output_finite_and_shaped():
    cfg = _cfg()
    params, axes = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out = moe_mod.apply_moe(params, x, cfg)
    assert out.shape == (2, 8, 32)
    assert not bool(jnp.isnan(out).any())


def test_moe_manual_oracle_high_capacity():
    """With capacity ample enough to never drop, dispatch must equal the
    dense per-token mixture Σ_k w_k · FFN_{e_k}(x)."""
    cfg = _cfg(capacity_factor=8.0)
    params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))

    out = moe_mod.apply_moe(params, x, cfg)

    logits = x.reshape(-1, 32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    wts, ids = jax.lax.top_k(probs, 2)
    wts = wts / wts.sum(-1, keepdims=True)
    expect = np.zeros((16, 32), np.float32)
    for t in range(16):
        for k in range(2):
            e = int(ids[t, k])
            h = x.reshape(-1, 32)[t] @ params["w_up"][e]
            g = x.reshape(-1, 32)[t] @ params["w_gate"][e]
            y = (jax.nn.silu(g) * h) @ params["w_down"][e]
            expect[t] += float(wts[t, k]) * np.asarray(y)
    np.testing.assert_allclose(np.asarray(out.reshape(16, 32)), expect, rtol=2e-4,
                               atol=2e-5)


def test_moe_capacity_drops_tokens():
    """A tiny capacity factor must drop load beyond each expert's queue —
    outputs shrink in norm but stay finite (GShard semantics)."""
    cfg_full = _cfg(capacity_factor=8.0)
    params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg_full)
    # skew all tokens to the same expert by biasing the router
    params = dict(params)
    params["router"] = params["router"].at[:, 0].add(100.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    full = moe_mod.apply_moe(params, x, cfg_full)
    tiny = moe_mod.apply_moe(params, x, _cfg(capacity_factor=0.1))
    assert float(jnp.linalg.norm(tiny)) < float(jnp.linalg.norm(full))
    assert not bool(jnp.isnan(tiny).any())


def test_moe_ep_matches_single_device(mesh_runner):
    mesh_runner(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.distributed import sharding as SH
from repro.launch.mesh import make_mesh

cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=32, num_heads=4,
                  kv_heads=2, d_ff=16, vocab=64, num_experts=8, top_k=2,
                  capacity_factor=8.0, compute_dtype="float32", param_dtype="float32")
params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))

single = moe_mod.apply_moe(params, x, cfg)

mesh = make_mesh((2, 4), ("data", "model"))
with SH.use_rules(mesh, SH.DEFAULT_RULES):
    ep = jax.jit(lambda p, v: moe_mod.apply_moe(p, v, cfg))(params, x)
np.testing.assert_allclose(np.asarray(single), np.asarray(ep), rtol=2e-4, atol=2e-5)
print("OK")
""",
        n_devices=8,
    )


def test_padded_experts():
    assert moe_mod.padded_experts(_cfg(num_experts=40), 16) == 48
    assert moe_mod.padded_experts(_cfg(num_experts=128), 16) == 128
