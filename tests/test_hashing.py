"""Property tests for the weight-sharing hash constructions."""

import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import hashing


@given(
    vocab=st.integers(10, 100_000),
    collision=st.integers(2, 512),
)
@settings(max_examples=50, deadline=None)
def test_qr_spec_counts(vocab, collision):
    spec = hashing.QRSpec(vocab=vocab, collision=collision, dim=16)
    assert spec.q_rows == -(-vocab // collision)
    assert spec.r_rows == collision
    # capacity shrinks whenever the table is meaningfully bigger than c^2
    if vocab >= 4 * collision * collision:
        assert spec.compression > 1.0


@given(
    vocab=st.integers(8, 50_000),
    collision=st.integers(2, 128),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_qr_complementary_partition(vocab, collision, seed):
    """(q, r) is unique per logical index — the complementarity property."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, vocab, size=min(vocab, 512)).astype(np.int32)
    q, r = hashing.qr_decompose(jnp.asarray(idx), collision)
    recon = np.asarray(q) * collision + np.asarray(r)
    np.testing.assert_array_equal(recon, idx)


@given(buckets=st.integers(1, 10_000), seed=st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_universal_hash_range(buckets, seed):
    idx = jnp.arange(256, dtype=jnp.int32)
    h = hashing.universal_hash(idx, buckets, seed=seed)
    assert h.dtype == jnp.int32
    assert int(h.min()) >= 0 and int(h.max()) < buckets
    # deterministic
    h2 = hashing.universal_hash(idx, buckets, seed=seed)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(h2))


def test_k_ary_hash_shape():
    idx = jnp.arange(17, dtype=jnp.int32)
    hs = hashing.k_ary_hash(idx, 97, 3)
    assert hs.shape == (17, 3)
    # different seeds give different hash functions (overwhelmingly likely)
    assert not np.array_equal(np.asarray(hs[:, 0]), np.asarray(hs[:, 1]))


@given(
    rows=st.integers(1, 100_000),
    shards=st.sampled_from([1, 2, 4, 8, 16, 64]),
    seed=st.integers(0, 1000),
)
@settings(max_examples=40, deadline=None)
def test_row_owner_local_consistency(rows, shards, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, rows, size=64).astype(np.int32))
    owner = hashing.row_owner(idx, rows, shards)
    local = hashing.local_row(idx, rows, shards)
    rps = -(-rows // shards)
    np.testing.assert_array_equal(
        np.asarray(owner) * rps + np.asarray(local), np.asarray(idx)
    )
    assert int(owner.max()) < shards
    assert hashing.padded_rows(rows, shards) % shards == 0
    assert hashing.padded_rows(rows, shards) >= rows


def test_qr_shard_owner_matches_decompose():
    idx = jnp.arange(1000, dtype=jnp.int32)
    c, q_rows, nsh = 8, 125, 4
    owner = hashing.qr_shard_owner(idx, c, q_rows, nsh)
    q, _ = hashing.qr_decompose(idx, c)
    np.testing.assert_array_equal(
        np.asarray(owner), np.asarray(hashing.row_owner(q, q_rows, nsh))
    )
