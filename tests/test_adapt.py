"""Online adaptation: sketches, incremental re-planning, drift-triggered refit.

The load-bearing claims:

* the count-min sketch NEVER underestimates and its overestimate stays
  inside the classic eps*N bound on a Zipf stream; heavy-hitter recall at
  the defaults clears the pinning bar; expired hot sets actually leave the
  sliding-window estimate;
* the drift law is single-sourced: the arrival generator and the
  adaptation benchmarks rotate hot sets through the same seeded helper;
* an incremental re-pin is a pure runtime-arg mutation — shapes frozen,
  **no recompile** (the engine's trace-time counter stays at one program)
  — and the adaptive session's logits are bitwise identical to the
  non-adaptive pipeline on the same index stream;
* the policy holds on stationary traffic and fires under rotation, and a
  drifted cost model (``DriftMonitor.refit_recommended``) re-fits the tuner
  and re-plans mid-serve, visible as obs counters + instant events.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import obs
from repro.adapt.policy import AdaptController, AdaptPolicy
from repro.adapt.replan import (
    PinnedCache, big_id_map, coverage, fold_to_big, incremental_update,
    pinned_from_plan, top_rows,
)
from repro.adapt.schedule import DriftSchedule, drifting_zipf_batches
from repro.adapt.sketch import CountMinSketch, FrequencySketch, SpaceSaving
from repro.configs import registry
from repro.data.synthetic import zipf_trace
from repro.launch.serve_rec import build_serve_state, run_pipeline
from repro.models import dlrm
from repro.serve import arrival


@pytest.fixture(scope="module")
def served():
    """One offline pass shared by the module (plan+compile is slow)."""
    cfg = registry.get_dlrm("dlrm-qr-smoke")
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
    state = build_serve_state(cfg, shards=1, alpha=1.05, seed=0)
    return cfg, params, state


@pytest.fixture
def metrics():
    """Fresh obs session per test (counters + tracer), always disabled after."""
    obs.enable(reset=True)
    try:
        yield
    finally:
        obs.disable()


# ---------------------------------------------------------------------------
# sketch accuracy
# ---------------------------------------------------------------------------

def test_cms_one_sided_error_on_zipf():
    vocab, width = 2048, 1024
    stream = zipf_trace(vocab, 20_000, alpha=1.05, seed=3)
    cms = CountMinSketch(width=width, depth=4, seed=1)
    for chunk in np.array_split(stream, 20):
        cms.update(chunk)
    truth = np.bincount(stream, minlength=vocab)
    est = cms.estimate(np.arange(vocab))
    over = est - truth
    assert over.min() >= 0, "count-min must never underestimate"
    # classic bound: overestimate <= e/width * N per depth row w.h.p.;
    # conservative update only tightens it.  4x slack keeps this seed-proof.
    assert over.max() <= 4 * np.e * cms.total / width
    assert cms.total == stream.size


def test_cms_estimate_exact_when_unique_fits():
    cms = CountMinSketch(width=4096, depth=4, seed=0)
    keys = np.arange(64)
    cms.update(np.repeat(keys, 5))
    assert np.array_equal(cms.estimate(keys), np.full(64, 5))


def test_heavy_hitter_recall_at_defaults():
    vocab, want = 4096, 32
    sk = FrequencySketch(vocab, seed=2)          # default topk=256
    stream = zipf_trace(vocab, 32 * 512, alpha=1.05, seed=11)
    for chunk in stream.reshape(32, 512):
        sk.update(chunk)
    exact = set(np.argsort(-np.bincount(stream, minlength=vocab),
                           kind="stable")[:want].tolist())
    got = set(sk.top_rows(want).tolist())
    recall = len(exact & got) / want
    assert recall >= 0.9, f"heavy-hitter recall {recall:.2f} < 0.9"


def test_space_saving_capacity_and_error_floor():
    ss = SpaceSaving(capacity=4)
    ss.update(np.array([1, 1, 1, 2, 2, 3, 3, 4]))
    ss.update(np.array([5, 5, 5, 5, 5]))          # evicts the current min
    assert len(ss.counts) == 4
    top = ss.top(2)
    assert top[0][0] == 5
    assert ss.errors[5] > 0                        # inherited the evict floor


def test_window_decay_forgets_expired_hot_set():
    sk = FrequencySketch(256, windows=2, window_batches=2, decay=0.5, seed=0)
    hot_a = np.arange(0, 16)
    hot_b = np.arange(128, 144)
    for _ in range(4):                             # fills both windows with A
        sk.update(np.repeat(hot_a, 8))
    assert sk.estimate(hot_a).min() > 0
    for _ in range(4):                             # ...then B pushes A out
        sk.update(np.repeat(hot_b, 8))
    assert sk.estimate(hot_a).max() == 0, "expired hot set must leave"
    assert sk.estimate(hot_b).min() > 0
    assert sk.top_rows(8).size > 0                 # heavy decays but survives


# ---------------------------------------------------------------------------
# drift schedule (single-sourced law)
# ---------------------------------------------------------------------------

def test_arrival_drift_offset_matches_schedule_law():
    spec = arrival.ArrivalSpec(rate_rps=100, horizon_s=4.0,
                               drift_period_s=1.5, drift_fraction=0.25)
    sched = DriftSchedule(period=1.5, fraction=0.25)
    for t in (0.0, 0.4, 1.5, 2.2, 3.7, 9.0):
        assert arrival.drift_offset(spec, t, 4096) == sched.offset_at(t, 4096)


def test_drifting_zipf_batches_deterministic_and_rotates():
    sched = DriftSchedule(period=2.0, fraction=0.25, seed=9)
    a = drifting_zipf_batches(1024, 6, 128, schedule=sched, seed=9)
    b = drifting_zipf_batches(1024, 6, 128, schedule=sched, seed=9)
    assert np.array_equal(a, b), "same seed must reproduce bitwise"
    flat = drifting_zipf_batches(
        1024, 6, 128, schedule=DriftSchedule(period=0.0, seed=9), seed=9
    )
    step = int(0.25 * 1024)
    for t in range(6):
        off = sched.offset_at(t, 1024)
        assert off == (step * (t // 2)) % 1024
        assert np.array_equal(a[t], (flat[t] + off) % 1024)


def test_drift_schedule_parse_and_describe():
    s = DriftSchedule.parse("period=8,frac=0.3,seed=4")
    assert (s.period, s.fraction, s.seed) == (8.0, 0.3, 4)
    assert not s.stationary
    assert DriftSchedule.parse("").stationary
    assert s.describe()["period"] == 8.0


# ---------------------------------------------------------------------------
# incremental re-planning
# ---------------------------------------------------------------------------

def test_pinned_cache_swap_semantics():
    c = PinnedCache(16, 4, rows=np.array([1, 2, 3, 4]))
    assert c.stats.staged_rows == 4
    slots = c.slots_for(np.array([1, 4, 9]))
    assert (slots >= 0).tolist() == [True, True, False]
    assert c.stats.hits == 2 and c.stats.accesses == 3
    # re-pin keeps surviving residents in their slots; only the diff stages
    keep_slot = {int(r): s for s, r in enumerate(c.slot_rows)}
    staged = c.pin(np.array([3, 4, 5, 6]))
    assert staged == 2
    assert int(c.slot_map[3]) == keep_slot[3]
    assert int(c.slot_map[4]) == keep_slot[4]
    assert set(c.pinned_rows().tolist()) == {3, 4, 5, 6}
    # shapes are frozen: this is what keeps the jit key stable
    assert c.slot_rows.shape == (4,) and c.cache_rows().dtype == np.int32
    assert c.cache_rows().min() >= 0
    assert c.prefetch(np.arange(4)) == 0


def test_pinned_cache_dedup_and_truncate():
    c = PinnedCache(16, 3)
    staged = c.pin(np.array([7, 7, 2, 9, 11]))     # dup dropped, overflow cut
    assert staged == 3
    assert set(c.pinned_rows().tolist()) == {7, 2, 9}


def test_incremental_update_math_and_apply():
    est = [np.array([5.0, 1.0, 3.0, 0.0]), np.array([0.0, 8.0, 2.0, 0.0])]
    upd = incremental_update(est, (2, 1))
    assert upd.rows[0].tolist() == [0, 2]
    assert upd.rows[1].tolist() == [1]
    assert upd.predicted_hit == pytest.approx((5 + 3 + 8) / 19)
    caches = [PinnedCache(4, 2), PinnedCache(4, 1)]
    assert upd.apply(caches) == 3
    assert coverage(est[0], caches[0].pinned_rows()) == pytest.approx(8 / 9)


def test_fold_to_big_sums_logical_mass():
    big_ids = np.array([[0], [1], [0], [2]])
    folded = fold_to_big(np.array([1.0, 2.0, 3.0, 4.0]), big_ids, 3)
    assert folded.tolist() == [4.0, 2.0, 4.0]


def test_pinned_from_plan_pins_profiled_hot_rows(served):
    cfg, _params, state = served
    caches = pinned_from_plan(state.eplan)
    assert len(caches) == cfg.num_tables
    for t, cache in enumerate(caches):
        budget = state.eplan.slot_budgets[t]
        assert cache.pinned_rows().size == min(budget, cache.num_rows)
        # the pin is the plan's own profiled popularity, folded to big rows
        emb = state.eplan.bags[t].emb
        hot = fold_to_big(
            np.asarray(state.eplan.counts[t], dtype=np.float64),
            big_id_map(emb), cache.num_rows,
        )
        want = set(top_rows(hot, budget).tolist())
        assert set(cache.pinned_rows().tolist()) == want


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------

def test_policy_thresholds():
    pol = AdaptPolicy(min_gain=0.1, horizon_batches=64, swap_cost_batches=1.0,
                      full_gain=0.3, full_cost_batches=32.0)
    assert not pol.swap_worthwhile(0.05)           # below the gain floor
    assert pol.swap_worthwhile(0.12)
    assert not pol.full_worthwhile(0.12)           # below the full floor
    assert pol.full_worthwhile(0.6)
    # payback: gain clears the floor but cannot amortize the cost in-horizon
    tight = AdaptPolicy(min_gain=0.01, horizon_batches=4, swap_cost_batches=1.0)
    assert not tight.swap_worthwhile(0.02)


def test_controller_holds_stationary_fires_on_rotation(served, metrics):
    cfg, _params, state = served
    vocab = state.bags[0].emb.vocab
    pol = AdaptPolicy(check_every=4, min_batches=8, min_gain=0.08,
                      cooldown_batches=4)
    skw = dict(window_batches=4, windows=4, decay=0.3)

    def feed(period):
        ctl = AdaptController(state.eplan, policy=pol, sketch_kw=skw, seed=0)
        caches = ctl.fresh_caches()
        sched = DriftSchedule(period=float(period), fraction=0.3, seed=0)
        per_table = [
            drifting_zipf_batches(vocab, 24, 64 * cfg.pooling,
                                  schedule=sched, seed=7 + t)
            for t in range(cfg.num_tables)
        ]
        for b in range(24):
            idx = np.stack(
                [per_table[t][b].reshape(64, cfg.pooling)
                 for t in range(cfg.num_tables)], axis=1,
            )
            ctl.observe(idx)
            ctl.step(caches)
        return ctl

    flat = feed(period=0)
    assert flat.events == [], "stationary traffic must not trigger re-plans"
    drift = feed(period=8)
    kinds = [e["kind"] for e in drift.events]
    assert "replan" in kinds, "a rotated hot set must trigger a re-pin"
    # cooldown: consecutive checks inside the quiet period are skipped
    batches = [e["batch"] for e in drift.events]
    assert all(b2 - b1 >= pol.cooldown_batches
               for b1, b2 in zip(batches, batches[1:]))


# ---------------------------------------------------------------------------
# the adaptive serving loop (acceptance checks)
# ---------------------------------------------------------------------------

def test_stationary_logits_bitwise_equal_pipeline(served, metrics):
    from repro.adapt.loop import serve_adaptive
    from repro.data import synthetic

    cfg, params, state = served
    batch, batches = 8, 4
    ref = run_pipeline(cfg, batch=batch, batches=batches, seed=0,
                       mode="sequential", state=state, params=params)
    idx_override = [
        np.asarray(synthetic.dlrm_batch(cfg, batch, seed=0, step=t)["idx"])
        for t in range(batches)
    ]
    res = serve_adaptive(cfg, batch=batch, batches=batches, seed=0,
                         state=state, params=params,
                         idx_override=idx_override)
    for t in range(batches):
        assert np.array_equal(np.asarray(ref["logits"][t]),
                              np.asarray(res["logits"][t])), (
            f"batch {t}: adaptive logits diverge from the pipeline"
        )


def test_drift_session_replans_without_recompile(served, metrics):
    from repro.adapt.loop import serve_adaptive

    cfg, params, state = served
    pol = AdaptPolicy(check_every=4, min_batches=8, min_gain=0.05,
                      cooldown_batches=4)
    ctl = AdaptController(state.eplan, policy=pol,
                          sketch_kw=dict(window_batches=4, windows=4,
                                         decay=0.3), seed=0)
    res = serve_adaptive(cfg, batch=16, batches=20, seed=0, state=state,
                         params=params, controller=ctl,
                         schedule=DriftSchedule(period=6.0, fraction=0.3))
    kinds = [e["kind"] for e in res["events"]]
    assert "replan" in kinds
    counters = obs.snapshot().counters
    assert counters.get("serve/adapt/replan", 0) >= 1
    # the tentpole invariant: every swap reused the SAME compiled program
    assert counters.get("engine/compile/serve_gather", 0) <= 1, (
        "incremental re-pins must not retrace serve_gather"
    )
    names = [e.get("name") for e in obs.tracer().events]
    assert "adapt_replan" in names                 # visible in trace/flight
    assert any(s > 0 for s in res["staged_series"])


def test_drift_monitor_refit_replans_mid_serve(served, metrics):
    from repro.adapt.loop import serve_adaptive

    cfg, params, state = served
    state = dataclasses.replace(state)             # don't poison the module
    # constant predictions + alternating measurements: rank agreement 0
    state.drift = obs.DriftMonitor()
    for i in range(12):
        state.drift.observe(1.0, 1.0 if i % 2 else 2.0)
    state.predicted_s = 1.0
    assert state.drift.refit_recommended
    engine_before = state.engine
    res = serve_adaptive(cfg, batch=8, batches=5, seed=0, state=state,
                         params=params, refit=True,
                         refit_kw=dict(max_samples=2, repeats=1))
    kinds = [e["kind"] for e in res["events"]]
    assert "refit" in kinds, "refit_recommended must re-fit mid-serve"
    assert state.engine is not engine_before       # re-planned + recompiled
    assert state.drift.n < 12                      # fresh re-armed monitor
    counters = obs.snapshot().counters
    assert counters.get("serve/adapt/refit", 0) == 1
    names = [e.get("name") for e in obs.tracer().events]
    assert "adapt_refit" in names
    ev = next(e for e in res["events"] if e["kind"] == "refit")
    assert "drift" in ev and "knobs" in ev
