"""ProactivePIM cache subsystem: intra-GnR analyzer, prefetch scheduler,
duplication planner, the plan-aware sharded GnR, and the serve_rec driver."""

import numpy as np
import pytest

from repro.cache import duplication, intra_gnr, sram_cache
from repro.core import placement
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.data.synthetic import zipf_trace


def _qr_cfg(vocab=4096, dim=32, collision=8):
    return EmbeddingConfig(vocab=vocab, dim=dim, kind="qr", collision=collision)


def _tt_cfg(vocab=4096, dim=32, rank=4):
    return EmbeddingConfig(vocab=vocab, dim=dim, kind="tt", tt_rank=rank)


def _bag_trace(vocab, bags, pooling, seed=0):
    return zipf_trace(vocab, bags * pooling, seed=seed).reshape(bags, pooling)


# ---------------------------------------------------------------------------
# intra-GnR locality analyzer
# ---------------------------------------------------------------------------

def test_analyzer_counts_and_bags():
    trace = np.array([[0, 0, 1], [1, 1, 1], [2, 0, 2]])
    loc = intra_gnr.analyze_bags(trace, rows=4)
    assert loc.touches.tolist() == [3, 4, 2, 0]
    assert loc.bags.tolist() == [2, 2, 1, 0]
    # row 1: 4 touches over 2 bags -> reuse 2.0
    assert loc.intra_reuse[1] == 2.0
    assert loc.num_bags == 3


def test_shared_subtables_have_structural_reuse():
    """R / outer-core reuse must exceed the big table's — the paper's premise."""
    trace = _bag_trace(4096, 300, pooling=8)
    qr = intra_gnr.analyze_table(trace, _qr_cfg())
    assert qr["r"].mean_intra_reuse > qr["q"].mean_intra_reuse
    tt = intra_gnr.analyze_table(trace, _tt_cfg())
    assert tt["g1"].mean_intra_reuse > tt["g2"].mean_intra_reuse
    assert tt["g3"].mean_intra_reuse > tt["g2"].mean_intra_reuse


def test_rank_prefetch_orders_by_saved_accesses():
    trace = _bag_trace(4096, 200, pooling=8)
    loc = intra_gnr.analyze_table(trace, _qr_cfg())["q"]
    rank = intra_gnr.rank_prefetch(loc)
    vals = loc.prefetch_value()[rank]
    assert np.all(np.diff(vals) <= 0)            # descending
    assert np.all(vals > 0)                      # never ranks untouched rows
    top3 = intra_gnr.rank_prefetch(loc, top=3)
    assert top3.tolist() == rank[:3].tolist()


def test_analyzer_empty_and_shape_checks():
    loc = intra_gnr.analyze_bags(np.empty((0, 4), dtype=np.int64), rows=8)
    assert loc.touches.sum() == 0 and loc.bags.sum() == 0
    with pytest.raises(ValueError):
        intra_gnr.analyze_bags(np.zeros(5, dtype=np.int64), rows=8)


# ---------------------------------------------------------------------------
# prefetch scheduler
# ---------------------------------------------------------------------------

def test_scheduler_double_buffer_accounting():
    sched = sram_cache.PrefetchScheduler(num_rows=64, num_slots=8)
    b0 = np.array([1, 2, 3, 1, 2, 1])
    sched.prefetch(b0)
    assert sched.stats.staged_rows == 3          # cold start: 3 distinct rows
    slots = sched.slots_for(b0)
    assert (slots >= 0).all()                    # staged exactly what b0 needs
    assert sched.stats.hit_rate == 1.0
    # next batch shares row 1 -> only the new rows are staged
    b1 = np.array([1, 9, 9, 10, 1, 1])
    sched.prefetch(b1)
    assert sched.stats.staged_rows == 3 + 2
    assert sched.stats.kept_rows >= 1
    slots = sched.slots_for(b1)
    assert (slots >= 0).all()
    # slot map and slot_rows stay mutually consistent
    for r, s in enumerate(sched.slot_map):
        if s >= 0:
            assert sched.slot_rows[s] == r


def test_scheduler_capacity_eviction():
    sched = sram_cache.PrefetchScheduler(num_rows=100, num_slots=4)
    batch = np.array([0, 0, 0, 1, 1, 2, 3, 4, 5])   # 6 distinct, 4 slots
    sched.prefetch(batch)
    slots = sched.slots_for(batch)
    hit_rows = set(int(r) for r, s in zip(batch, slots) if s >= 0)
    assert len(hit_rows) == 4
    assert {0, 1} <= hit_rows                    # highest-count rows win slots
    assert (sched.slot_rows >= 0).sum() == 4


def test_scheduler_zipf_hit_rate_and_traffic():
    """Acceptance-adjacent: double-buffered prefetch reaches a high hit rate
    on a Zipf(1.05) stream, and cached DRAM traffic beats the baseline."""
    q = zipf_trace(262_144, 24 * 2048, alpha=1.05, seed=3).reshape(24, -1) // 64
    stats = sram_cache.simulate([q[t] for t in range(24)], 4096, 1024)
    assert stats.hit_rate >= 0.8
    tr = stats.traffic_bytes(512)
    assert tr["cached"] < tr["baseline"]


def test_scheduler_value_tiebreak():
    """Analyzer value breaks ties between equal in-batch counts."""
    value = np.zeros(10)
    value[7] = 5.0
    sched = sram_cache.PrefetchScheduler(10, 1, value)
    sched.prefetch(np.array([3, 7]))             # tied counts; 7 has value
    assert sched.slot_rows[0] == 7


# ---------------------------------------------------------------------------
# duplication planner
# ---------------------------------------------------------------------------

def _counts(vocab=4096, n=30_000, seed=1):
    return placement.profile_counts(zipf_trace(vocab, n, seed=seed), vocab)


def test_duplication_generous_budget_kills_communication():
    bags = [BagConfig(emb=_qr_cfg(), pooling=8) for _ in range(3)]
    plan = duplication.plan_duplication(
        bags, [_counts()] * 3, num_shards=4, budget_bytes=32 * 2**20
    )
    assert plan.comm_free
    assert all(t.local_share == 1.0 for t in plan.tables)
    ici = plan.ici_bytes_per_batch(256, 32)
    assert ici["duplicated"] == 0 and ici["saved"] == ici["baseline"] > 0


def test_duplication_budget_respected_and_prioritized():
    bags = [BagConfig(emb=_qr_cfg(), pooling=8)]
    budget = 8192
    plan = duplication.plan_duplication(
        bags, [_counts()], num_shards=4, budget_bytes=budget
    )
    assert plan.replicated_bytes <= budget
    assert not plan.comm_free
    t = plan.tables[0]
    by_name = {d.name: d for d in t.decisions}
    assert by_name["r"].replicated              # tiny LUT always wins first
    assert 0 < t.hot_plan.num_hot < 512         # leftover budget -> hot rows
    # hot tier holds the hottest rows
    folded = duplication._fold_quotient(_counts(), 8, 512)
    assert folded[t.hot_plan.hot_rows].min() >= np.sort(folded)[::-1][t.hot_plan.num_hot - 1]


def test_duplication_tt_pins_outer_cores_first():
    bags = [BagConfig(emb=_tt_cfg(), pooling=8)]
    spec = bags[0].emb.tt_spec
    smalls = (spec.v1 * spec.g1_width + spec.v3 * spec.g3_width) * 4
    plan = duplication.plan_duplication(
        bags, [_counts()], num_shards=2, budget_bytes=smalls + 10
    )
    t = plan.tables[0]
    by_name = {d.name: d for d in t.decisions}
    assert by_name["g1"].replicated and by_name["g3"].replicated
    assert t.hot_plan.num_hot == 0              # nothing left for G2 rows
    assert t.local_share == pytest.approx(2 / 3)


def test_duplication_partial_profile_not_comm_free():
    """An all-hot *profile* must not flip comm_free: unseen indices can still
    arrive at serving time, so full-row coverage is required."""
    counts = np.zeros(4096, dtype=np.int64)
    counts[:800] = 50                           # only 100 of 512 q-rows touched
    bags = [BagConfig(emb=_qr_cfg(), pooling=8)]
    rb = 32 * 4
    budget = bags[0].emb.qr_spec.lut_bytes() + 150 * rb   # R + 150 hot rows
    plan = duplication.plan_duplication(
        bags, [counts], num_shards=4, budget_bytes=budget
    )
    t = plan.tables[0]
    assert t.hot_plan.expected_hot_hit == 1.0   # profile fully covered...
    assert not t.comm_free                      # ...but the table is not
    assert not plan.comm_free
    # generous budget replicates every row, including untouched ones
    plan_full = duplication.plan_duplication(
        bags, [counts], num_shards=4, budget_bytes=32 * 2**20
    )
    assert plan_full.tables[0].hot_plan.num_hot == 512
    assert plan_full.comm_free


def test_duplication_hashed_folds_counts():
    """Hashed tables fold logical counts through the k-ary hash, not truncate."""
    emb = EmbeddingConfig(vocab=4096, dim=32, kind="hashed", collision=8)
    bags = [BagConfig(emb=emb, pooling=8)]
    counts = np.zeros(4096, dtype=np.int64)
    counts[4000] = 100                          # hot logical id past row count
    plan = duplication.plan_duplication(
        bags, [counts], num_shards=2, budget_bytes=4 * 32 * 4
    )
    hot = plan.tables[0].hot_plan
    from repro.core import hashing

    expect_rows = set(np.asarray(
        hashing.k_ary_hash(np.array([4000]), emb.physical_hashed_rows, emb.hashed_k)
    ).reshape(-1).tolist())
    assert expect_rows <= set(hot.hot_rows.tolist())


def test_tt_pallas_flag_is_differentiable():
    """tt_exec='pallas' must stay legal under value_and_grad (training configs
    carry the flag); the kernel path has a reference-recompute vjp."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    dims = (2, 4, 2, 2)
    g1 = jax.random.normal(jax.random.PRNGKey(0), (4, 4))
    g2 = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    g3 = jax.random.normal(jax.random.PRNGKey(2), (4, 4))
    i = jax.random.randint(jax.random.PRNGKey(3), (3, 4), 0, 4)
    i2 = jax.random.randint(jax.random.PRNGKey(4), (3, 4), 0, 8)

    def loss(a, b, c, use_kernel):
        out = ops.tt_pooled_auto(
            a, b, c, i, i2, i, dims=dims, exec_mode="pallas",
            interpret=True if use_kernel else None,
        )
        return (out.astype(jnp.float32) ** 2).sum()

    gk = jax.grad(lambda a, b, c: loss(a, b, c, True), argnums=(0, 1, 2))(g1, g2, g3)
    gr = jax.grad(lambda a, b, c: loss(a, b, c, False), argnums=(0, 1, 2))(g1, g2, g3)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_duplication_zero_budget():
    bags = [BagConfig(emb=_qr_cfg(), pooling=8)]
    plan = duplication.plan_duplication(
        bags, [_counts()], num_shards=4, budget_bytes=0
    )
    assert plan.replicated_bytes == 0
    assert not plan.comm_free
    assert plan.tables[0].local_share == 0.0


# ---------------------------------------------------------------------------
# plan-aware sharded GnR (mesh subprocess)
# ---------------------------------------------------------------------------

def test_dup_gnr_matches_oracle(mesh_runner):
    mesh_runner(
        """
import numpy as np, jax, jax.numpy as jnp
from repro import engine as E
from repro.cache import duplication
from repro.core import embedding_bag, placement, sharded_embedding as SE
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.data.synthetic import zipf_trace
from repro.engine import EngineSpec
from repro.launch.mesh import make_mesh

emb = EmbeddingConfig(vocab=4096, dim=32, kind="qr", collision=8,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
bags = [BagConfig(emb=emb, pooling=8) for _ in range(2)]
tables = embedding_bag.init_tables(jax.random.PRNGKey(0), bags)
idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 8), 0, 4096)
oracle = embedding_bag.multi_bag_lookup(tables, idx, bags)

counts = placement.profile_counts(zipf_trace(4096, 20000, seed=1), 4096)
mesh = make_mesh((2, 4), ("data", "model"))
for budget in (32 * 2**20, 8192):   # comm-free and mixed regimes
    plan = duplication.plan_duplication(
        bags, [counts] * 2, num_shards=4, budget_bytes=budget)
    spec = EngineSpec.from_bags(bags, duplication=True)
    fn = E.compile(E.plan(spec, mesh=mesh, dup=plan)).gnr(mesh)
    tiers = SE.make_dup_hot_tiers(tables, bags, plan)
    out = fn(tables, idx, tiers)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)
    assert plan.comm_free == (budget > 8192)
print("OK")
""",
        n_devices=8,
    )


# ---------------------------------------------------------------------------
# serving driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["dlrm-qr", "dlrm-tt"])
def test_serve_rec_smoke(arch, capsys):
    from repro.launch import serve_rec

    rc = serve_rec.main([
        "--arch", arch, "--smoke", "--batch", "4", "--batches", "3",
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "QPS" in out and "cache hit rate" in out
    assert "comm_free=True" in out
