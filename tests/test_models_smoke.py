"""Per-arch REDUCED-config smoke tests (assignment deliverable f): one
forward/train step on CPU asserting output shapes + no NaNs, plus one
prefill+decode step per arch with a decode path."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.train import optimizer as opt_mod
from repro.train.serve_step import serve_family
from repro.train.train_step import make_train_step

ARCHS = sorted(registry.ARCHS)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    binding = registry.get(arch)
    cfg = binding.smoke
    params, axes = registry.init_fn(binding)(jax.random.PRNGKey(0), cfg)
    batch = registry.make_batch_fn(binding, cfg)(4, 32, seed=0, step=0)
    loss_fn = registry.train_loss_fn(binding, cfg)
    step = jax.jit(
        make_train_step(loss_fn, opt_mod.OptConfig(warmup_steps=1), microbatches=2)
    )
    p2, o2, m = step(params, opt_mod.init(params), batch)
    assert m["loss"].shape == ()
    assert not bool(jnp.isnan(m["loss"]))
    for leaf in jax.tree.leaves(p2):
        assert not bool(jnp.isnan(leaf).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    binding = registry.get(arch)
    cfg = binding.smoke
    params, _ = registry.init_fn(binding)(jax.random.PRNGKey(0), cfg)
    batch = registry.make_batch_fn(binding, cfg)(2, 16, seed=0, step=0)
    fam = serve_family(binding.kind)
    logits, cache = jax.jit(lambda p, b: fam.prefill(p, b, cfg, 32))(params, batch)
    assert logits.shape == (2, 1, cfg.vocab)
    pos0 = 16 + (cfg.num_patches if binding.kind == "pixtral" else 0)
    tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t, pos: fam.decode(p, c, t, pos, cfg)
    )(params, cache, tok, jnp.int32(pos0))
    assert logits2.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits2).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_qr_embedding_variant(arch):
    """Every arch accepts the paper's technique (embedding.kind = qr)."""
    binding = registry.get(arch)
    cfg = binding.smoke.replace(embedding_kind="qr", qr_collision=8)
    params, _ = registry.init_fn(binding)(jax.random.PRNGKey(0), cfg)
    assert "q" in params["embed"] and "r" in params["embed"]
    batch = registry.make_batch_fn(binding, cfg)(2, 16, seed=0, step=0)
    loss_fn = registry.train_loss_fn(binding, cfg)
    loss, _ = jax.jit(loss_fn)(params, batch)
    assert not bool(jnp.isnan(loss))
