"""Autotuner tests: knob space, cost model, plan identity, fit round-trip."""

import os

import numpy as np
import pytest

from repro import engine, tune
from repro.configs.dlrm_qr import SMOKE
from repro.data.synthetic import zipf_trace
from repro.engine.spec import EngineSpec
from repro.tune import (
    CostSample, Knobs, default_knobs, fit_cost_model, knob_space,
    plan_features, slot_budgets,
)


def _spec(**kw):
    spec = EngineSpec.from_dlrm(SMOKE, serving=True).replace(duplication=False)
    return spec.replace(**kw) if kw else spec


def _traces(spec, n=4096):
    return [zipf_trace(b.emb.vocab, n, seed=t) for t, b in enumerate(spec.bags)]


# ---------------------------------------------------------------------------
# knob space
# ---------------------------------------------------------------------------

def test_knob_space_default_first_and_unique():
    spec = _spec()
    space = knob_space(spec, packable=True)
    assert space[0] == default_knobs(spec, packable=True)
    assert len(set(space)) == len(space)
    assert {k.backend for k in space} == {"packed", "pertable"}
    # slot ladder: halve / keep / double around the spec's allowance
    assert {k.cache_slots for k in space} == {
        spec.cache_slots // 2, spec.cache_slots, spec.cache_slots * 2
    }


def test_knob_space_unpackable_pins_backend():
    spec = _spec()
    space = knob_space(spec, packable=False)
    assert {k.backend for k in space} == {"pertable"}


def test_knobs_hashable():
    a = Knobs(dim_block=128, cache_slots=64)
    b = Knobs(dim_block=128, cache_slots=64)
    assert a == b and hash(a) == hash(b)
    assert a != Knobs(dim_block=128, cache_slots=32)


def test_slot_budgets_policies():
    spec = _spec()
    uniform = slot_budgets(
        spec, Knobs(cache_slots=spec.cache_slots,
                    cache_slot_policy="uniform"), None,
    )
    assert uniform == tuple([spec.cache_slots] * spec.num_tables)
    # zero allowance -> no cache
    assert slot_budgets(spec, Knobs(cache_slots=0), None) == (0,) * 4
    # adaptive + values waterfills (unequal budgets for unequal value mass)
    values = [np.arange(10, dtype=np.float64) * (t + 1) for t in range(4)]
    adaptive = slot_budgets(
        spec, Knobs(cache_slots=8, cache_slot_policy="adaptive"), values
    )
    assert sum(adaptive) <= 8 * 4 and len(set(adaptive)) > 1


# ---------------------------------------------------------------------------
# plan identity (satellite: no stale jit-cache hits)
# ---------------------------------------------------------------------------

def test_plans_differing_only_in_knobs_are_unequal():
    spec = _spec()
    traces = _traces(spec)
    base = default_knobs(spec, packable=True)
    import dataclasses

    halved = dataclasses.replace(base, cache_slots=base.cache_slots // 2)
    p1 = engine.plan(spec, trace=traces, knobs=base)
    p2 = engine.plan(spec, trace=traces, knobs=halved)
    assert p1 != p2
    assert hash(p1) != hash(p2)
    # same knobs -> equal plans, equal hashes (jit cache hit)
    p3 = engine.plan(spec, trace=traces, knobs=base)
    assert p1 == p3 and hash(p1) == hash(p3)


def test_no_trace_plan_reproduces_heuristics():
    """plan() with neither knobs nor tuner must match an explicit
    default-knobs plan bit-for-bit (the zero-trace fallback guarantee)."""
    spec = _spec()
    p_plain = engine.plan(spec)
    p_knobs = engine.plan(spec, knobs=default_knobs(spec, packable=True))
    assert p_plain == p_knobs
    assert p_plain.knobs == p_knobs.knobs
    assert p_plain.slot_budgets == p_knobs.slot_budgets
    # historical uniform budgets: min(spec.cache_slots, vmem-capped share)
    assert p_plain.slot_budgets == (spec.cache_slots,) * spec.num_tables
    # and with a trace, repeated planning is deterministic
    traces = _traces(spec)
    assert engine.plan(spec, trace=traces) == engine.plan(spec, trace=traces)


def test_positional_trace_convenience():
    spec = _spec()
    traces = _traces(spec)
    assert engine.plan(spec, traces) == engine.plan(spec, trace=traces)
    with pytest.raises(ValueError, match="positionally and as trace="):
        engine.plan(spec, traces, trace=traces)


def test_packed_knobs_on_unpackable_spec_rejected():
    spec = _spec()
    import dataclasses

    # mixed vocabs break the uniform-layout megakernel contract
    bags = (spec.bags[0],) + tuple(
        dataclasses.replace(b, emb=dataclasses.replace(b.emb, vocab=b.emb.vocab + 8))
        for b in spec.bags[1:]
    )
    with pytest.raises(ValueError, match="not packable"):
        engine.plan(
            spec.replace(bags=bags),
            knobs=Knobs(dim_block=32, cache_slots=128, backend="packed"),
        )
    # pertable knobs on a packable spec are fine (tuner may choose the loop)
    p = engine.plan(spec, knobs=Knobs(dim_block=32, backend="pertable"))
    assert not p.packed and p.layout is None


def test_plan_summary_records_knobs():
    spec = _spec()
    s = engine.plan(spec).summary()
    assert s["knobs"]["cache_slots"] == spec.cache_slots
    assert s["knobs"]["backend"] == "packed"


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_fit_cost_model_recovers_coefficients():
    rng = np.random.default_rng(0)
    true = np.array([5e-6, 1e-9, 2e-7, 1e-10])
    feats = rng.uniform(1.0, 100.0, size=(32, 4)) * np.array(
        [1.0, 1e6, 1e2, 1e5]
    )
    y = feats @ true
    samples = [
        CostSample(knobs=Knobs(), features=tuple(f), measured_s=float(v))
        for f, v in zip(feats, y)
    ]
    model = fit_cost_model(samples, backend="packed")
    np.testing.assert_allclose(model.coef, true, rtol=1e-6)
    # round-trips through JSON
    from repro.tune import KernelCostModel

    again = KernelCostModel.from_json(model.describe())
    assert again.coef == model.coef


def test_fit_cost_model_clips_negative_coefficients():
    # y depends only on feature 0; collinear noise must not go negative
    feats = np.array([[1.0, 2.0, 0.0, 0.0], [2.0, 1.0, 0.0, 0.0],
                      [3.0, 5.0, 0.0, 0.0], [4.0, 1.0, 0.0, 0.0]])
    y = feats[:, 0] * 10.0 - feats[:, 1] * 0.5
    samples = [
        CostSample(knobs=Knobs(), features=tuple(f), measured_s=float(v))
        for f, v in zip(feats, y)
    ]
    model = fit_cost_model(samples, backend="packed")
    assert all(c >= 0 for c in model.coef)


def test_plan_features_track_knobs():
    spec = _spec()
    traces = _traces(spec)
    prof = tune.TraceProfile.from_trace(spec, traces, batch=16)
    base = default_knobs(spec, packable=True)
    import dataclasses

    f_base = plan_features(spec, base, prof)
    # packed = 1 dispatch; pertable = T dispatches
    assert f_base[0] == 1.0
    f_pt = plan_features(
        spec, dataclasses.replace(base, backend="pertable"), prof
    )
    assert f_pt[0] == spec.num_tables
    # more cache slots -> no more streamed bytes (monotone non-increasing)
    f_big = plan_features(
        spec, dataclasses.replace(base, cache_slots=base.cache_slots * 4), prof
    )
    assert f_big[1] <= f_base[1] * 1.01
    # no cache -> strictly more streamed bytes than the default budget
    f_none = plan_features(
        spec, dataclasses.replace(base, cache_slots=0), prof
    )
    assert f_none[1] > f_base[1]


# ---------------------------------------------------------------------------
# fit -> choose -> plan round-trip (HLO mode: no accelerator needed)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def hlo_tuner_and_spec(tmp_path_factory):
    spec = _spec()
    traces = _traces(spec)
    cache = str(tmp_path_factory.mktemp("tuner") / "cache.json")
    tuner = tune.fit(spec, traces, mode="hlo", batch=8, max_samples=4,
                     cache_path=cache)
    return tuner, spec, traces, cache


def test_fit_produces_models_and_samples(hlo_tuner_and_spec):
    tuner, spec, _traces_, _ = hlo_tuner_and_spec
    assert set(tuner.models) == {"packed", "pertable"}
    assert tuner.samples and not tuner.from_cache
    for m in tuner.models.values():
        assert any(c > 0 for c in m.coef)
    assert tuner.digest == tune.spec_digest(spec)
    # metadata rides the tuner (cross-machine comparability)
    assert {"backend", "device_kind", "jax_version"} <= set(tuner.metadata)


def test_tuned_plan_selects_from_knob_space(hlo_tuner_and_spec):
    tuner, spec, traces, _ = hlo_tuner_and_spec
    p = engine.plan(spec, traces, tuner=tuner)
    assert p.knobs in knob_space(spec, packable=True)
    assert p.slot_budgets == tune.slot_budgets(
        spec, p.knobs, list(p.values) or None
    )
    # backend filter: the serving pipeline can pin the packed megakernel
    k_packed = tuner.choose(spec, backend="packed")
    assert k_packed.backend == "packed"


def test_fit_memo_cache_roundtrip(hlo_tuner_and_spec):
    tuner, spec, traces, cache = hlo_tuner_and_spec
    assert os.path.exists(cache)
    again = tune.fit(spec, traces, mode="hlo", batch=8, max_samples=4,
                     cache_path=cache)
    assert again.from_cache
    for b in tuner.models:
        assert again.models[b].coef == pytest.approx(tuner.models[b].coef)
    assert (engine.plan(spec, traces, tuner=again).knobs
            == engine.plan(spec, traces, tuner=tuner).knobs)


def test_spec_digest_stable_and_distinct():
    spec = _spec()
    assert tune.spec_digest(spec) == tune.spec_digest(spec)
    assert tune.spec_digest(spec) != tune.spec_digest(
        spec.replace(cache_slots=spec.cache_slots * 2)
    )


def test_rank_orders_by_prediction(hlo_tuner_and_spec):
    tuner, spec, _t, _c = hlo_tuner_and_spec
    ranked = tuner.rank(spec, packable=True)
    preds = [p for _k, p in ranked]
    assert preds == sorted(preds)
    assert len(ranked) == len(knob_space(spec, packable=True))
