"""Launcher-level integration: train loop with checkpoint/auto-resume,
serving driver, dry-run cell listing."""

import os

import pytest

from repro.checkpoint import checkpointer as ckpt


@pytest.mark.slow
def test_train_resume_roundtrip(tmp_path, capsys):
    from repro.launch.train import main

    args = [
        "--arch", "qwen2-1.5b", "--smoke", "--steps", "6", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
        "--log-every", "2",
    ]
    assert main(args) == 0
    assert ckpt.latest_step(str(tmp_path)) == 6

    # resume: a second invocation starts from step 6 and does nothing more
    assert main(args) == 0
    out = capsys.readouterr().out
    assert "[resume] step 6" in out


@pytest.mark.slow
def test_train_elastic_mesh_restart(tmp_path, mesh_runner):
    """Train on a (2,2) mesh, checkpoint, resume onto (4,1) — the elastic
    re-mesh path end-to-end (subprocess owns its device count)."""
    mesh_runner(
        f"""
from repro.launch.train import main
args = ["--arch", "qwen2-1.5b", "--smoke", "--steps", "4", "--batch", "4",
        "--seq", "32", "--ckpt-dir", r"{tmp_path}", "--ckpt-every", "2",
        "--mesh-shape", "2,2"]
assert main(args) == 0
args[-1] = "4,1"
args[4] = "8"   # --steps 8: continue on the new mesh
assert main(args) == 0
print("OK")
""",
        n_devices=4,
        timeout=560,
    )


@pytest.mark.slow
def test_serve_driver(capsys):
    from repro.launch.serve import main

    rc = main([
        "--arch", "xlstm-125m", "--smoke", "--batch", "2",
        "--prompt-len", "8", "--max-new", "4",
    ])
    assert rc == 0
    assert "generated (2, 4)" in capsys.readouterr().out


def test_dryrun_list(capsys):
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["REPRO_XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--list"],
        capture_output=True, text=True, env=env, timeout=120,
    )
    assert out.returncode == 0
    lines = [l for l in out.stdout.splitlines() if l.strip()]
    assert len(lines) == 40                      # the full assigned grid
    assert sum("run" in l for l in lines) == 32
