"""Committed benchmark baselines + the tolerance gate (benchmarks/baseline):
point-ratio fallback, the noise-aware bootstrap-CI gate for sampled rows, and
the 3x hard backstop."""

import json
import os

import numpy as np
import pytest

from benchmarks import baseline as B

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(REPO, "benchmarks", "baselines")
SUITES = ("serve_qps", "cache_sim", "cache_drift")
# cache_drift rows come from benchmarks.cache_sim.run_drift, so they share
# the emitting module's row prefix
ROW_PREFIX = {"cache_drift": "cache_sim/drift_"}


@pytest.mark.parametrize("suite", SUITES)
def test_committed_baseline_parses(suite):
    path = os.path.join(BASELINE_DIR, f"BENCH_{suite}.json")
    assert os.path.exists(path), f"missing committed baseline {path}"
    rows = B._rows(path)
    assert rows, "baseline is empty"
    for r in rows:
        assert {"name", "us_per_call", "derived"} <= set(r)
        # satellite: every row carries host metadata
        assert {"backend", "device_kind", "jax_version"} <= set(r)
    prefix = ROW_PREFIX.get(suite, f"{suite}/")
    assert any(r["name"].startswith(prefix) for r in rows)
    assert any(r["name"] == f"run/{suite}_wall" and r["us_per_call"] > 0
               for r in rows)


def _row(name, us, **meta):
    return {"name": name, "us_per_call": us, "derived": "",
            "device_kind": "cpu", "backend": "cpu", "jax_version": "x",
            **meta}


def test_compare_flags_missing_rows():
    res = B.compare([_row("a", 10.0)], [_row("a", 10.0), _row("b", 5.0)],
                    rel_tol=1.0)
    assert res["missing"] == ["b"]
    assert not res["regressions"]


def test_compare_flags_regressions_within_tolerance():
    base = [_row("a", 10.0), _row("b", 10.0), _row("c", 10.0)]
    meas = [_row("a", 10.5),      # within tol
            _row("b", 100.0),     # 10x: regression at tol 3.0
            _row("c", 1.0)]       # 10x faster: improvement
    res = B.compare(meas, base, rel_tol=3.0)
    assert [r[0] for r in res["regressions"]] == ["b"]
    assert [r[0] for r in res["improvements"]] == ["c"]
    assert res["checked"] == 3
    # cross-host comparisons report but never gate
    res2 = B.compare(meas, base, rel_tol=3.0, gate_timing=False)
    assert not res2["regressions"]


def test_compare_skips_modeled_rows():
    # us_per_call == 0 rows (modeled/ratio) are presence-checked only
    res = B.compare([_row("a", 0.0)], [_row("a", 0.0)], rel_tol=0.1)
    assert res["checked"] == 0 and not res["missing"]


def _sampled(name, us, center, n=20, jitter=1e-4, seed=0):
    rng = np.random.default_rng(seed)
    return _row(name, us,
                samples_s=list(center + jitter * rng.standard_normal(n)))


def test_bootstrap_gate_ignores_point_noise():
    """Same latency distribution, jittery point ratio inside the backstop:
    the sampled gate passes where the point gate would fail."""
    base = _sampled("a", 100.0, 0.010, seed=1)
    meas = _sampled("a", 250.0, 0.010, seed=2)      # 2.5x point blip
    res = B.compare([meas], [base], rel_tol=3.0, boot_tol=0.5)
    assert not res["regressions"]
    d = res["detail"]["a"]
    assert d["method"] == "bootstrap"
    lo, hi = d["ci"]
    assert lo <= 1.0 <= hi or (lo < 1.5 and hi < 1.5)
    # the same point blip WITHOUT samples fails a tight point gate
    res2 = B.compare([_row("a", 250.0)], [_row("a", 100.0)], rel_tol=0.5)
    assert res2["regressions"] and res2["detail"]["a"]["method"] == "point"


def test_bootstrap_gate_catches_consistent_shift_under_backstop():
    """A consistent 2x median shift is well inside the 3x point tolerance but
    statistically unambiguous — the bootstrap gate fails it."""
    base = _sampled("a", 100.0, 0.010, seed=1)
    meas = _sampled("a", 200.0, 0.020, seed=2)
    res = B.compare([meas], [base], rel_tol=3.0, boot_tol=0.5)
    assert [r[0] for r in res["regressions"]] == ["a"]
    lo, hi = res["detail"]["a"]["ci"]
    assert lo > 1.5 and hi == pytest.approx(2.0, rel=0.2)
    # deterministic: the same inputs give the same CI verdict
    lo2, hi2 = B.bootstrap_ratio_ci(base["samples_s"], meas["samples_s"])
    assert (lo2, hi2) == (lo, hi)


def test_hard_backstop_applies_even_with_samples():
    """A 5x shift fails regardless of gate flavor (the 3x point backstop)."""
    base = _sampled("a", 100.0, 0.010, seed=1)
    meas = _sampled("a", 500.0, 0.050, seed=2)
    res = B.compare([meas], [base], rel_tol=3.0, boot_tol=100.0)
    assert [r[0] for r in res["regressions"]] == ["a"]


def test_too_few_samples_falls_back_to_point_gate():
    base = _row("a", 100.0, samples_s=[0.01, 0.01])     # < MIN_SAMPLES
    meas = _sampled("a", 150.0, 0.015, seed=3)
    res = B.compare([meas], [base], rel_tol=3.0)
    assert res["detail"]["a"]["method"] == "point"
    assert not res["regressions"]


def test_cross_host_never_gates_sampled_rows():
    base = _sampled("a", 100.0, 0.010, seed=1)
    meas = _sampled("a", 200.0, 0.020, seed=2)
    res = B.compare([meas], [base], rel_tol=3.0, gate_timing=False)
    assert not res["regressions"]


def test_bootstrap_improvement_reported():
    base = _sampled("a", 200.0, 0.020, seed=1)
    meas = _sampled("a", 100.0, 0.010, seed=2)
    res = B.compare([meas], [base], rel_tol=3.0, boot_tol=0.5)
    assert [r[0] for r in res["improvements"]] == ["a"]


def test_refresh_script_covers_committed_suites():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "refresh_baselines",
        os.path.join(REPO, "scripts", "refresh_baselines.py"),
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert tuple(mod.SUITES) == SUITES
