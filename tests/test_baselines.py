"""Committed benchmark baselines + the tolerance gate (benchmarks/baseline)."""

import json
import os

import pytest

from benchmarks import baseline as B

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(REPO, "benchmarks", "baselines")
SUITES = ("serve_qps", "cache_sim")


@pytest.mark.parametrize("suite", SUITES)
def test_committed_baseline_parses(suite):
    path = os.path.join(BASELINE_DIR, f"BENCH_{suite}.json")
    assert os.path.exists(path), f"missing committed baseline {path}"
    rows = B._rows(path)
    assert rows, "baseline is empty"
    for r in rows:
        assert {"name", "us_per_call", "derived"} <= set(r)
        # satellite: every row carries host metadata
        assert {"backend", "device_kind", "jax_version"} <= set(r)
    assert any(r["name"].startswith(f"{suite}/") for r in rows)
    assert any(r["name"] == f"run/{suite}_wall" and r["us_per_call"] > 0
               for r in rows)


def _row(name, us, **meta):
    return {"name": name, "us_per_call": us, "derived": "",
            "device_kind": "cpu", "backend": "cpu", "jax_version": "x",
            **meta}


def test_compare_flags_missing_rows():
    res = B.compare([_row("a", 10.0)], [_row("a", 10.0), _row("b", 5.0)],
                    rel_tol=1.0)
    assert res["missing"] == ["b"]
    assert not res["regressions"]


def test_compare_flags_regressions_within_tolerance():
    base = [_row("a", 10.0), _row("b", 10.0), _row("c", 10.0)]
    meas = [_row("a", 10.5),      # within tol
            _row("b", 100.0),     # 10x: regression at tol 3.0
            _row("c", 1.0)]       # 10x faster: improvement
    res = B.compare(meas, base, rel_tol=3.0)
    assert [r[0] for r in res["regressions"]] == ["b"]
    assert [r[0] for r in res["improvements"]] == ["c"]
    assert res["checked"] == 3
    # cross-host comparisons report but never gate
    res2 = B.compare(meas, base, rel_tol=3.0, gate_timing=False)
    assert not res2["regressions"]


def test_compare_skips_modeled_rows():
    # us_per_call == 0 rows (modeled/ratio) are presence-checked only
    res = B.compare([_row("a", 0.0)], [_row("a", 0.0)], rel_tol=0.1)
    assert res["checked"] == 0 and not res["missing"]


def test_refresh_script_covers_committed_suites():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "refresh_baselines",
        os.path.join(REPO, "scripts", "refresh_baselines.py"),
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert tuple(mod.SUITES) == SUITES
