"""Embedding-bag GnR semantics + the traffic model the benchmarks rely on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import embedding_bag as EB, qr_embedding as QE
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig


def _bag(kind="qr", **kw):
    emb = EmbeddingConfig(
        vocab=512, dim=16, kind=kind, collision=8,
        param_dtype=jnp.float32, compute_dtype=jnp.float32, **kw,
    )
    return BagConfig(emb=emb, pooling=4)


def test_qr_add_pooling_pushes_through_reconstruction():
    """Σ(Q[q]+R[r]) == pooled lookup — the associativity the PIM scheme uses."""
    bag = _bag()
    params = QE.init(jax.random.PRNGKey(0), bag.emb)
    idx = jax.random.randint(jax.random.PRNGKey(1), (6, 4), 0, 512)
    fast = EB.bag_lookup(params, idx, bag)
    naive = QE.lookup(params, idx, bag.emb).sum(axis=-2)
    np.testing.assert_allclose(fast, naive, rtol=1e-5)


def test_weighted_bag():
    bag = _bag()
    params = QE.init(jax.random.PRNGKey(0), bag.emb)
    idx = jax.random.randint(jax.random.PRNGKey(1), (3, 4), 0, 512)
    w = jax.random.uniform(jax.random.PRNGKey(2), (3, 4))
    out = EB.bag_lookup(params, idx, bag, weights=w)
    expect = (QE.lookup(params, idx, bag.emb) * w[..., None]).sum(axis=-2)
    np.testing.assert_allclose(out, expect, rtol=1e-5)


def test_mean_combiner():
    bag = BagConfig(emb=_bag().emb, pooling=4, combiner="mean")
    params = QE.init(jax.random.PRNGKey(0), bag.emb)
    idx = jnp.zeros((2, 4), jnp.int32)
    out = EB.bag_lookup(params, idx, bag)
    single = QE.lookup(params, jnp.zeros((2,), jnp.int32), bag.emb)
    np.testing.assert_allclose(out, single, rtol=1e-5)


def test_multi_bag_stacks_tables():
    bags = [_bag(), _bag(kind="dense")]
    tables = EB.init_tables(jax.random.PRNGKey(0), bags)
    idx = jax.random.randint(jax.random.PRNGKey(1), (5, 2, 4), 0, 512)
    out = EB.multi_bag_lookup(tables, idx, bags)
    assert out.shape == (5, 2, 16)
    for t in range(2):
        np.testing.assert_allclose(
            out[:, t], EB.bag_lookup(tables[t], idx[:, t], bags[t]), rtol=1e-5
        )


def test_traffic_model_paper_premises():
    """The analytic traffic model must encode the paper's two facts:
    (1) weight-sharing doubles DRAM access; (2) the LUT removes the doubling."""
    qr = EB.traffic_model(_bag("qr"))
    assert qr["naive"] == 2 * qr["dense"]          # the double-access problem
    assert qr["fused"] == qr["dense"]              # the LUT restores parity
    dense = EB.traffic_model(_bag("dense"))
    assert dense["naive"] == dense["dense"] == dense["fused"]
