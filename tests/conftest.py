"""Shared fixtures. NOTE: no XLA device-count flags here by design — smoke
tests must see exactly 1 CPU device (the dry-run alone forces 512). Tests that
need a mesh spawn a subprocess via tests/mesh_worker.py."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run a python snippet in a child process with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
        )
    return out.stdout


@pytest.fixture
def mesh_runner():
    return run_with_devices
