"""Decode-vs-train-forward consistency at fp32: prefill + one decode step must
reproduce the train-mode forward logits at that position, per family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry

TOL = 5e-5


def _fp32(binding):
    return binding.smoke.replace(compute_dtype="float32", param_dtype="float32")


def test_transformer_decode_consistency():
    from repro.models import transformer as T

    binding = registry.get("qwen2-1.5b")
    cfg = _fp32(binding)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    full = T.forward_train(params, toks, cfg)
    lg, cache = T.forward_prefill(params, toks[:, :11], cfg, max_len=16)
    np.testing.assert_allclose(lg[:, 0], full[:, 10], rtol=TOL, atol=TOL)
    lg2, _ = T.forward_decode(params, toks[:, 11:12], cache, jnp.int32(11), cfg)
    np.testing.assert_allclose(lg2[:, 0], full[:, 11], rtol=TOL, atol=TOL)


def test_zamba2_decode_consistency():
    from repro.models import zamba2 as Z

    binding = registry.get("zamba2-7b")
    cfg = _fp32(binding)
    params, _ = Z.init_zamba2(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    full, _ = Z.forward_zamba2(params, toks, cfg)
    cache = Z.init_zamba2_cache(cfg, 2, 12, dtype=jnp.float32)
    lg, cache = Z.forward_zamba2(
        params, toks[:, :7], cfg, cache=cache, pos=jnp.int32(0), decode=False
    )
    lg2, _ = Z.forward_zamba2(
        params, toks[:, 7:8], cfg, cache=cache, pos=jnp.int32(7), decode=True
    )
    np.testing.assert_allclose(lg2[:, 0], full[:, 7], rtol=1e-4, atol=1e-4)


def test_xlstm_decode_consistency():
    from repro.models import xlstm as X

    binding = registry.get("xlstm-125m")
    cfg = _fp32(binding)
    params, _ = X.init_xlstm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab)
    full, _ = X.forward_xlstm(params, toks, cfg)
    st = X.init_xlstm_state(cfg, 2)
    outs = []
    for t in range(9):
        lg, st = X.forward_xlstm(params, toks[:, t: t + 1], cfg, states=st, decode=True)
        outs.append(lg[:, 0])
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(seq, full, rtol=2e-4, atol=2e-4)


def test_whisper_decode_consistency():
    from repro.models import whisper as W

    binding = registry.get("whisper-large-v3")
    cfg = _fp32(binding)
    params, _ = W.init_whisper(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(2), (2, W.N_AUDIO, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    full = W.forward_train(params, frames, toks, cfg)
    lg, cache = W.forward_prefill(params, frames, toks[:, :5], cfg, max_len=8)
    np.testing.assert_allclose(lg[:, 0], full[:, 4], rtol=TOL, atol=TOL)
    lg2, _ = W.forward_decode(params, toks[:, 5:6], cache, jnp.int32(5), cfg)
    np.testing.assert_allclose(lg2[:, 0], full[:, 5], rtol=1e-4, atol=1e-4)


def test_pixtral_decode_consistency():
    from repro.models import pixtral as P

    binding = registry.get("pixtral-12b")
    cfg = _fp32(binding)
    params, _ = P.init_pixtral(jax.random.PRNGKey(0), cfg)
    patches = jax.random.normal(jax.random.PRNGKey(2), (2, cfg.num_patches, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    full = P.forward_train(params, patches, toks, cfg)
    max_len = cfg.num_patches + 8
    lg, cache = P.forward_prefill(params, patches, toks[:, :5], cfg, max_len)
    np.testing.assert_allclose(lg[:, 0], full[:, 4], rtol=TOL, atol=TOL)
    pos = cfg.num_patches + 5
    lg2, _ = P.forward_decode(params, toks[:, 5:6], cache, jnp.int32(pos), cfg)
    np.testing.assert_allclose(lg2[:, 0], full[:, 5], rtol=1e-4, atol=1e-4)


def test_mamba2_chunked_vs_step():
    from repro.configs.base import ModelConfig
    from repro.models import mamba2 as M

    cfg = ModelConfig(
        name="m", family="ssm", num_layers=1, d_model=32, num_heads=2, kv_heads=2,
        d_ff=0, vocab=64, ssm_state=8, ssm_head_dim=8,
        compute_dtype="float32", param_dtype="float32",
    )
    params, _ = M.init_mamba2(jax.random.PRNGKey(4), cfg)
    u = jax.random.normal(jax.random.PRNGKey(5), (1, 8, 32))
    y_par, _ = M.mamba2_fwd(params, u, cfg)
    st, conv = M.init_ssm_state(cfg, 1, dtype=jnp.float32)
    ys = []
    for t in range(8):
        yt, (st, conv) = M.mamba2_fwd(
            params, u[:, t: t + 1], cfg, state=st, conv_state=conv, decode=True
        )
        ys.append(yt)
    np.testing.assert_allclose(
        jnp.concatenate(ys, axis=1), y_par, rtol=1e-4, atol=1e-5
    )


def test_flash_attention_vs_naive():
    from repro.models import layers as L

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 4, 64, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 64, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 64, 16))
    out = L.flash_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    # naive reference with GQA expansion
    kk = jnp.repeat(k, 2, axis=1)
    vv = jnp.repeat(v, 2, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q * 16 ** -0.5, kk)
    mask = jnp.tril(jnp.ones((64, 64), bool))
    s = jnp.where(mask, s, -1e30)
    expect = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), vv)
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-5)


def test_flash_attention_non_divisible_blocks():
    from repro.models import layers as L

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 2, 48, 8))   # 48 not divisible by 32
    k = jax.random.normal(key, (1, 2, 96, 8))
    v = jax.random.normal(key, (1, 2, 96, 8))
    out = L.flash_attention(q, k, v, causal=False, q_block=32, kv_block=32)
    assert out.shape == (1, 2, 48, 8)
    assert not bool(jnp.isnan(out).any())
