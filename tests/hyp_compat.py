"""Optional-hypothesis shim: property tests skip cleanly when the package is
absent instead of breaking collection of the whole suite.

Usage in test modules:  ``from hyp_compat import given, settings, st``
With hypothesis installed this is a pure re-export; without it, ``@given``
replaces the test with a zero-arg skipped stub (so strategy kwargs never reach
pytest's fixture resolution) and ``st``/``settings`` are inert placeholders.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Accepts any ``st.<name>(...)`` call at collection time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
