"""TT-Rec embedding subsystem: factorization, lookup oracles, placement,
gradient flow, and DLRM-with-TT end-to-end (single-device and sharded)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import placement, qr_embedding as QE, tt_embedding as TT
from repro.core.qr_embedding import EmbeddingConfig


def _cfg(**kw):
    base = dict(
        vocab=4096, dim=32, kind="tt", tt_rank=4,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    base.update(kw)
    return EmbeddingConfig(**base)


# ---------------------------------------------------------------------------
# factorization / spec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim", [8, 16, 32, 64, 128, 512])
def test_dim_factors_exact(dim):
    d1, d2, d3 = TT.dim_factors3(dim)
    assert d1 * d2 * d3 == dim
    assert d2 == max(d1, d2, d3)       # bulk in the middle core


@pytest.mark.parametrize("vocab", [100, 4096, 50_000, 2_000_000])
def test_vocab_factors_cover(vocab):
    v1, v2, v3 = TT.vocab_factors3(vocab)
    assert v1 * v2 * v3 >= vocab
    # asymmetric: outer factors are SRAM-sized, the bulk is the middle core
    assert v1 == v3 and v1 ** 4 <= 16 * vocab
    assert v2 >= v1


def test_decompose_roundtrip():
    cfg = _cfg()
    spec = cfg.tt_spec
    idx = jnp.arange(cfg.vocab, dtype=jnp.int32)
    i1, i2, i3 = TT.tt_decompose(idx, spec)
    recon = (np.asarray(i1) * spec.v2 + np.asarray(i2)) * spec.v3 + np.asarray(i3)
    np.testing.assert_array_equal(recon, np.asarray(idx))
    assert int(i1.max()) < spec.v1
    assert int(i2.max()) < spec.v2
    assert int(i3.max()) < spec.v3


def test_bad_factors_rejected():
    with pytest.raises(ValueError):
        _cfg(tt_vocab_factors=(2, 2, 2)).tt_spec       # covers 8 < 4096
    with pytest.raises(ValueError):
        _cfg(tt_dim_factors=(2, 2, 2)).tt_spec         # 8 != 32


# ---------------------------------------------------------------------------
# lookup / materialize
# ---------------------------------------------------------------------------

def test_lookup_shape_and_dtype():
    cfg = _cfg()
    params = QE.init(jax.random.PRNGKey(0), cfg)
    idx = jnp.array([[0, 1], [4095, 500]], jnp.int32)
    out = QE.lookup(params, idx, cfg)
    assert out.shape == (2, 2, 32)
    assert out.dtype == jnp.float32


def test_lookup_matches_manual_contraction():
    """TT lookup == dense reconstruction by explicit per-index einsum."""
    cfg = _cfg()
    spec = cfg.tt_spec
    params = QE.init(jax.random.PRNGKey(1), cfg)
    idx = jnp.array([3, 17, 999, 4095], jnp.int32)
    i1, i2, i3 = TT.tt_decompose(idx, spec)
    a = params["g1"][i1].reshape(-1, spec.d1, spec.rank)
    b = params["g2"][i2].reshape(-1, spec.rank, spec.d2, spec.rank)
    c = params["g3"][i3].reshape(-1, spec.rank, spec.d3)
    expect = jnp.einsum("nap,npbq,nqc->nabc", a, b, c).reshape(-1, cfg.dim)
    np.testing.assert_allclose(
        np.asarray(QE.lookup(params, idx, cfg)), np.asarray(expect), rtol=1e-6
    )


def test_materialize_matches_lookup():
    cfg = _cfg(vocab=1000)                 # padded_vocab > vocab: pad never read
    params = QE.init(jax.random.PRNGKey(2), cfg)
    table = QE.materialize(params, cfg)
    assert table.shape == (1000, 32)
    idx = jnp.array([5, 99, 731], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(table[idx]), np.asarray(QE.lookup(params, idx, cfg)), rtol=1e-6
    )


def test_distinct_rows():
    """Mixed-radix factorization is complementary: rows are distinct (a.s.)."""
    cfg = _cfg()
    params = QE.init(jax.random.PRNGKey(3), cfg)
    out = np.asarray(QE.lookup(params, jnp.arange(64, dtype=jnp.int32), cfg))
    assert len(np.unique(out.round(5), axis=0)) == 64


def test_param_count_and_compression():
    cfg = _cfg(vocab=2_000_000, dim=128, tt_rank=16)
    params = QE.init(jax.random.PRNGKey(4), cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    assert n == cfg.param_count()
    assert cfg.tt_spec.compression > 50      # way past QR's collision=64 point
    # outer cores stay SRAM-sized (the pin must be legal)
    assert cfg.tt_spec.sram_bytes() < 64 * 1024


def test_param_axes_tiering():
    """Middle core rides the bank-group axis; outer cores the SRAM tier."""
    axes = QE.param_axes(_cfg())
    assert axes["g2"] == ("qrow", "embed")
    assert axes["g1"] == ("rrow", "embed") and axes["g3"] == ("rrow", "embed")


def test_logits_head_matches_materialized():
    cfg = _cfg(vocab=257)
    params = QE.init(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 32))
    fast = QE.logits_head(params, x, cfg)
    slow = x @ QE.materialize(params, cfg).T
    assert fast.shape == (4, 257)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# gradients
# ---------------------------------------------------------------------------

def test_gradient_flows_through_all_cores():
    cfg = _cfg()
    params = QE.init(jax.random.PRNGKey(7), cfg)
    idx = jax.random.randint(jax.random.PRNGKey(8), (32,), 0, cfg.vocab)

    def loss(p):
        return (QE.lookup(p, idx, cfg) ** 2).sum()

    grads = jax.grad(loss)(params)
    for k in ("g1", "g2", "g3"):
        g = np.asarray(grads[k])
        assert np.isfinite(g).all()
        assert np.abs(g).max() > 0, f"no gradient reached {k}"
    # rows never looked up get zero gradient (sparse update semantics)
    spec = cfg.tt_spec
    _, i2, _ = TT.tt_decompose(idx, spec)
    untouched = np.setdiff1d(np.arange(spec.v2), np.asarray(i2))
    assert np.abs(np.asarray(grads["g2"])[untouched]).max() == 0


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

def test_plan_tt_tiers():
    from repro.data.synthetic import zipf_trace

    cfg = _cfg()
    spec = cfg.tt_spec
    counts = placement.profile_counts(zipf_trace(cfg.vocab, 20_000, seed=3), cfg.vocab)
    plan = placement.plan_tt_tiers(counts, spec, request_share=0.8)
    assert plan.mid_plan.expected_hot_hit >= 0.8 - 1e-9
    assert 0 < plan.num_hot <= spec.v2
    assert plan.sram_fits                   # outer cores must fit the budget
    assert plan.sram_bytes == spec.sram_bytes()
    # folding conserves requests
    folded = placement.fold_counts_tt(counts, spec)
    assert folded.sum() == counts.sum()
    assert folded.size == spec.v2


# ---------------------------------------------------------------------------
# DLRM with TT tables, end to end
# ---------------------------------------------------------------------------

def test_dlrm_tt_smoke_trains():
    from repro.configs import dlrm_tt
    from repro.data.synthetic import dlrm_batch
    from repro.models import dlrm
    from repro.train import optimizer as opt_mod
    from repro.train.train_step import make_dlrm_loss, make_train_step

    cfg = dlrm_tt.SMOKE
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
    batch = dlrm_batch(cfg, 16, seed=0, step=0)
    logits = dlrm.forward_dlrm(params, batch["dense"], batch["idx"], cfg)
    assert logits.shape == (16,)
    assert not bool(jnp.isnan(logits).any())

    step = jax.jit(make_train_step(make_dlrm_loss(cfg), opt_mod.OptConfig()))
    opt = opt_mod.init(params)
    p2, opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    for k in ("g1", "g2", "g3"):            # the update reached every core
        delta = float(jnp.abs(p2["tables"][0][k] - params["tables"][0][k]).max())
        assert delta > 0


def test_dlrm_tt_vs_dense_same_structure():
    from repro.configs import dlrm_tt
    from repro.data.synthetic import dlrm_batch
    from repro.models import dlrm

    cfg_tt = dlrm_tt.SMOKE
    cfg_dense = dataclasses.replace(cfg_tt, embedding_kind="dense")
    pt, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg_tt)
    pd, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg_dense)
    nt = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(pt["tables"]))
    nd = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(pd["tables"]))
    assert nt * 4 < nd                      # real compression at smoke scale
    batch = dlrm_batch(cfg_tt, 8, seed=0, step=0)
    for p, c in ((pt, cfg_tt), (pd, cfg_dense)):
        out = dlrm.forward_dlrm(p, batch["dense"], batch["idx"], c)
        assert out.shape == (8,)


def test_sharded_dlrm_tt_matches_single(mesh_runner):
    mesh_runner(
        """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import dlrm_tt
from repro.data.synthetic import dlrm_batch
from repro.models import dlrm
from repro.distributed import sharding as SH
from repro.launch.mesh import make_mesh

cfg = dataclasses.replace(dlrm_tt.SMOKE, compute_dtype="float32")
params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
batch = dlrm_batch(cfg, 8, seed=0, step=0)
single = dlrm.forward_dlrm(params, batch["dense"], batch["idx"], cfg)

mesh = make_mesh((2, 4), ("data", "model"))
params_p = dlrm.pad_tables_for_mesh(params, cfg, 4)
with SH.use_rules(mesh, SH.DEFAULT_RULES):
    sharded = jax.jit(lambda p, d, i: dlrm.forward_dlrm(p, d, i, cfg))(
        params_p, batch["dense"], batch["idx"])
np.testing.assert_allclose(np.asarray(single), np.asarray(sharded), rtol=2e-3, atol=2e-3)
print("OK")
""",
        n_devices=8,
    )
