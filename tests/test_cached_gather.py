"""Cached-gather Pallas kernel vs the jnp oracle (interpret=True on CPU)
across a size/skew sweep, plus integration with the serving lookup path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as E
from repro.cache.sram_cache import PrefetchScheduler
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.data.synthetic import zipf_trace
from repro.engine import EngineSpec
from repro.kernels import ops, ref


def _setup(rows, slots, dim, bk, dtype=jnp.float32, seed=0, hit_p=0.5):
    """Table + cache block + indices with a controlled hit fraction."""
    k = jax.random.PRNGKey(seed)
    table = jax.random.normal(jax.random.fold_in(k, 0), (rows, dim), dtype)
    cache = jax.random.normal(jax.random.fold_in(k, 1), (slots, dim), dtype)
    b, kk = bk
    idx = jax.random.randint(jax.random.fold_in(k, 2), (b, kk), 0, rows)
    slot = jnp.where(
        jax.random.uniform(jax.random.fold_in(k, 3), (b, kk)) < hit_p,
        jax.random.randint(jax.random.fold_in(k, 4), (b, kk), 0, slots),
        -1,
    )
    return table, cache, idx, slot


@pytest.mark.parametrize("dim", [8, 32, 128, 256])
@pytest.mark.parametrize("hit_p", [0.0, 0.5, 1.0])
def test_cached_bag_size_hit_sweep(dim, hit_p):
    table, cache, idx, slot = _setup(64, 8, dim, (5, 7), hit_p=hit_p)
    out = ops.cached_pooled(table, cache, idx, slot)
    expect = ref.cached_bag_ref(table, cache, idx, slot)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bk", [(1, 1), (3, 16), (8, 4)])
def test_cached_qr_bag_sweep(dtype, bk):
    table, cache, idx, slot = _setup(96, 16, 32, bk, dtype=dtype)
    r_lut = jax.random.normal(jax.random.PRNGKey(9), (8, 32), dtype)
    r_idx = jax.random.randint(jax.random.PRNGKey(10), bk, 0, 8)
    out = ops.cached_qr_pooled(table, cache, r_lut, idx, slot, r_idx)
    expect = ref.cached_qr_bag_ref(table, cache, r_lut, idx, slot, r_idx)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=1e-5 if dtype == jnp.float32 else 3e-2, atol=1e-2,
    )


def test_cached_bag_zipf_skew_with_scheduler():
    """End-to-end skew case: slots staged by the real prefetch scheduler on a
    Zipf trace; kernel must agree with the oracle bit-for-bit in fp32."""
    rows, slots, dim, pooling = 512, 64, 32, 8
    table = jax.random.normal(jax.random.PRNGKey(0), (rows, dim))
    trace = zipf_trace(rows, 64 * pooling, alpha=1.05, seed=2).reshape(-1, pooling)
    sched = PrefetchScheduler(rows, slots)
    sched.prefetch(trace)
    slot = sched.slots_for(trace)
    assert (slot >= 0).any() and (slot < 0).any()   # genuinely mixed routing
    cache = table[jnp.asarray(sched.cache_rows())]
    out = ops.cached_pooled(table, cache, jnp.asarray(trace), jnp.asarray(slot))
    expect = ref.cached_bag_ref(table, cache, jnp.asarray(trace), jnp.asarray(slot))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    # routing consistency: staged cache rows equal the table rows they mirror,
    # so the cached result also equals a plain uncached bag
    plain = ref.dense_bag_ref(table, jnp.asarray(trace))
    np.testing.assert_allclose(np.asarray(out), np.asarray(plain),
                               rtol=1e-5, atol=1e-5)


def test_cached_small_dim_fallback():
    """Dims with no 8-aligned tile fall back to the jnp reference."""
    table, cache, idx, slot = _setup(32, 4, 12, (3, 5))
    out = ops.cached_pooled(table, cache, idx, slot)
    expect = ref.cached_bag_ref(table, cache, idx, slot)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)


def test_cached_bag_lookup_matches_plain_bag():
    """The serving path (cache staged from the same table) must reproduce the
    uncached bag lookup exactly, for QR and dense kinds."""
    for kind in ("qr", "dense"):
        emb = EmbeddingConfig(
            vocab=1024, dim=32, kind=kind, collision=8,
            param_dtype=jnp.float32, compute_dtype=jnp.float32,
        )
        bag = BagConfig(emb=emb, pooling=8)
        from repro.core import embedding_bag

        params = embedding_bag.init_tables(jax.random.PRNGKey(0), [bag])[0]
        idx = jax.random.randint(jax.random.PRNGKey(1), (6, 8), 0, 1024)
        rows = np.asarray(idx) // emb.collision if kind == "qr" else np.asarray(idx)
        nrows = emb.qr_spec.q_rows if kind == "qr" else emb.vocab
        sched = PrefetchScheduler(nrows, 16)
        sched.prefetch(rows)
        slot = sched.slots_for(rows)
        eng = E.engine_for(EngineSpec.from_bags((bag,)))
        out = eng.cached_lookup(
            params, idx, 0,
            cache_rows=jnp.asarray(sched.cache_rows()), slot=jnp.asarray(slot),
        )
        expect = embedding_bag.bag_lookup(params, idx, bag)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            rtol=1e-5, atol=1e-5,
        )


def test_cached_bag_lookup_tt_kernel_parity():
    """TT serving path: tt_exec='pallas' (oracle fallback on CPU) matches the
    jnp module lookup."""
    emb = EmbeddingConfig(
        vocab=2048, dim=32, kind="tt", tt_rank=4, tt_exec="pallas",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    bag = BagConfig(emb=emb, pooling=4)
    from repro.core import embedding_bag

    params = embedding_bag.init_tables(jax.random.PRNGKey(0), [bag])[0]
    idx = jax.random.randint(jax.random.PRNGKey(1), (5, 4), 0, 2048)
    eng = E.engine_for(EngineSpec.from_bags((bag,)))
    out = eng.cached_lookup(params, idx, 0, cache_rows=None, slot=None)
    import dataclasses

    plain = embedding_bag.bag_lookup(
        params, idx, BagConfig(emb=dataclasses.replace(emb, tt_exec="jnp"), pooling=4)
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(plain), rtol=1e-5, atol=1e-5
    )
