"""Registry: the 10-arch x 4-shape grid, skip rules, abstract specs."""

import jax
import pytest

from repro.configs import registry
from repro.configs.base import LM_SHAPES


def test_ten_archs_present():
    assert len(registry.ARCHS) == 10


def test_grid_is_40_cells():
    cells = list(registry.cells(include_skipped=True))
    assert len(cells) == 40
    runnable = [c for c in cells if c[2] == "run"]
    # long_500k runs only for the 2 sub-quadratic archs -> 10*3 + 2 = 32
    assert len(runnable) == 32


def test_skip_reasons():
    b = registry.get("qwen2-1.5b")
    long = [s for s in LM_SHAPES if s.name == "long_500k"][0]
    assert "sub-quadratic" in registry.shape_status(b, long)
    z = registry.get("zamba2-7b")
    assert registry.shape_status(z, long) == "run"
    x = registry.get("xlstm-125m")
    assert registry.shape_status(x, long) == "run"


def test_assigned_config_numbers():
    """The exact assigned architecture hyperparameters (spot checks)."""
    c = registry.get("qwen2-1.5b").config
    assert (c.num_layers, c.d_model, c.num_heads, c.kv_heads, c.d_ff, c.vocab) == (
        28, 1536, 12, 2, 8960, 151936)
    c = registry.get("granite-34b").config
    assert (c.num_layers, c.d_model, c.num_heads, c.kv_heads, c.d_ff, c.vocab) == (
        88, 6144, 48, 1, 24576, 49152)
    c = registry.get("qwen3-moe-235b-a22b").config
    assert (c.num_layers, c.num_experts, c.top_k, c.vocab) == (94, 128, 8, 151936)
    c = registry.get("zamba2-7b").config
    assert (c.num_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    c = registry.get("minitron-4b").config
    assert c.vocab == 256000
    c = registry.get("granite-moe-3b-a800m").config
    assert (c.num_experts, c.top_k, c.d_ff) == (40, 8, 512)
    c = registry.get("xlstm-125m").config
    assert (c.num_layers, c.d_model, c.d_ff) == (12, 768, 0)
    c = registry.get("whisper-large-v3").config
    assert (c.enc_layers, c.dec_layers, c.d_model, c.vocab) == (32, 32, 1280, 51866)
    c = registry.get("pixtral-12b").config
    assert (c.num_layers, c.d_model, c.kv_heads, c.vocab) == (40, 5120, 8, 131072)
    c = registry.get("chatglm3-6b").config
    assert (c.d_ff, c.vocab, c.partial_rotary) == (13696, 65024, 0.5)


@pytest.mark.parametrize("arch", sorted(registry.ARCHS))
def test_batch_specs_shapes(arch):
    b = registry.get(arch)
    cfg = b.config
    specs = registry.batch_specs(b, cfg, 4, 128)
    assert specs["tokens"].shape == (4, 128)
    if b.kind == "whisper":
        assert specs["frames"].shape[2] == cfg.d_model
    if b.kind == "pixtral":
        assert specs["patches"].shape == (4, cfg.num_patches, cfg.d_model)


@pytest.mark.parametrize("arch", sorted(registry.ARCHS))
def test_abstract_params_match_real_init_structure(arch):
    """eval_shape params and reduced-config axes trees must align leaf-for-
    leaf — this is what the dry-run's shardings are built from."""
    b = registry.get(arch)
    smoke = b.smoke
    params, axes = registry.init_fn(b)(jax.random.PRNGKey(0), smoke)
    import jax.tree_util as jtu

    pleaves = jtu.tree_flatten_with_path(params)[0]
    # every param leaf must have a resolvable axes annotation path
    from repro.distributed import sharding as SH
    from repro.launch.mesh import make_mesh

    # 1-device mesh is enough to exercise resolution
    mesh = make_mesh((1,), ("model",))
    sh = SH.shardings_for_tree(mesh, params, axes, SH.PARAM_RULES)
    assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(params))


def test_dlrm_registry_resolves_all_names():
    """Every registered DLRM id resolves to a config whose embedding kind
    matches the name (the selection surface used by scripts/dlrm_dryrun.py)."""
    from repro.configs import registry as R

    for name in R.DLRM_CONFIGS:
        cfg = R.get_dlrm(name)
        if "-tt" in name:
            assert cfg.embedding_kind == "tt"
        elif "-qr" in name:
            assert cfg.embedding_kind == "qr"
        elif "-dense" in name:
            assert cfg.embedding_kind == "dense"
    import pytest

    with pytest.raises(KeyError):
        R.get_dlrm("dlrm-nope")
