"""Checkpointing: atomic roundtrip, resume, prune, pipeline cursor."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.data.synthetic import Pipeline


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 4)), "b": jnp.zeros((4,))},
        "opt": {"mu": {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))},
                "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    state = _state()
    path = ckpt.save(str(tmp_path), 42, state, extra={"pipeline": {"seed": 0, "step": 9}})
    assert os.path.isdir(path)
    assert ckpt.latest_step(str(tmp_path)) == 42
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, extra = ckpt.restore(str(tmp_path), 42, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["pipeline"]["step"] == 9


def test_atomicity_tmp_ignored(tmp_path):
    ckpt.save(str(tmp_path), 1, _state())
    # simulate a crashed write
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_overwrite_same_step(tmp_path):
    ckpt.save(str(tmp_path), 3, _state(0))
    s2 = _state(1)
    ckpt.save(str(tmp_path), 3, s2)
    restored, _ = ckpt.restore(str(tmp_path), 3, jax.tree.map(jnp.zeros_like, s2))
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(s2["params"]["w"])
    )


def test_prune(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, {"x": jnp.ones(1)})
    ckpt.prune(str(tmp_path), keep=2)
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert steps == [4, 5]


def test_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, {"x": jnp.zeros((3,))})


def test_leaf_count_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.ones(1)})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, {"x": jnp.ones(1), "y": jnp.ones(1)})


def test_pipeline_cursor_replay():
    """Restart-exactness: a pipeline seeked to a cursor replays byte-identical
    batches — the determinism the straggler/restart story depends on."""
    mk = lambda seed, step: {"t": jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(seed), step), (4,), 0, 100
    )}
    p1 = Pipeline(make_batch=mk)
    batches = [next(p1) for _ in range(5)]
    cursor = p1.state()
    b5 = next(p1)

    p2 = Pipeline(make_batch=mk)
    p2.seek(cursor)
    b5_replay = next(p2)
    np.testing.assert_array_equal(np.asarray(b5["t"]), np.asarray(b5_replay["t"]))
    del batches
