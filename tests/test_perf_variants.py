"""Correctness of the §Perf hillclimb variants: every optimized execution
scheme must be numerically equivalent to its baseline (forward AND grad)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.core import qr_embedding as QE
from repro.core.qr_embedding import EmbeddingConfig
from repro.models import moe as moe_mod


def test_moe_gather_dispatch_matches_scatter():
    cfg_s = ModelConfig(
        name="m", family="moe", num_layers=1, d_model=32, num_heads=4,
        kv_heads=2, d_ff=16, vocab=64, num_experts=8, top_k=2,
        capacity_factor=2.0, compute_dtype="float32", param_dtype="float32",
    )
    cfg_g = cfg_s.replace(moe_dispatch="gather")
    params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg_s)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    np.testing.assert_allclose(
        np.asarray(moe_mod.apply_moe(params, x, cfg_s)),
        np.asarray(moe_mod.apply_moe(params, x, cfg_g)),
        rtol=1e-5, atol=1e-6,
    )

    def loss(p, cfg):
        return jnp.sum(moe_mod.apply_moe(p, x, cfg) ** 2)

    g_s = jax.grad(lambda p: loss(p, cfg_s))(params)
    g_g = jax.grad(lambda p: loss(p, cfg_g))(params)
    for a, b in zip(jax.tree.leaves(g_s), jax.tree.leaves(g_g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)


def test_moe_gather_dispatch_drops_identically():
    """Capacity overflow must drop the SAME assignments in both schemes."""
    cfg_s = ModelConfig(
        name="m", family="moe", num_layers=1, d_model=16, num_heads=2,
        kv_heads=2, d_ff=8, vocab=64, num_experts=4, top_k=2,
        capacity_factor=0.25, compute_dtype="float32", param_dtype="float32",
    )
    cfg_g = cfg_s.replace(moe_dispatch="gather")
    params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg_s)
    params = dict(params)
    params["router"] = params["router"].at[:, 0].add(10.0)   # force overflow
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16))
    np.testing.assert_allclose(
        np.asarray(moe_mod.apply_moe(params, x, cfg_s)),
        np.asarray(moe_mod.apply_moe(params, x, cfg_g)),
        rtol=1e-5, atol=1e-6,
    )


def test_qr_head_modes_equivalent():
    cfg = EmbeddingConfig(vocab=999, dim=32, kind="qr", collision=8,
                          compute_dtype=jnp.float32)
    p = QE.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    fast = QE.logits_head(p, x, cfg)
    slow = QE.logits_head(p, x, dataclasses.replace(cfg, head="materialize"))
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow),
                               rtol=2e-5, atol=2e-5)


def test_twolevel_embedding_matches_gspmd(mesh_runner):
    mesh_runner(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import registry
from repro.distributed import sharding as SH
from repro.launch.mesh import make_mesh
from repro.models import transformer as T

binding = registry.get("qwen2-1.5b")
cfg = binding.smoke.replace(embedding_kind="qr", qr_collision=8,
                            compute_dtype="float32")
cfg2 = cfg.replace(embedding_exec="twolevel")
params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
mesh = make_mesh((2, 4), ("data", "model"))
rules = dict(SH.DEFAULT_RULES)

def loss(p, c):
    with SH.use_rules(mesh, rules):
        lg = T.forward_train(p, toks, c)
    return jnp.mean(lg.astype(jnp.float32) ** 2)

np.testing.assert_allclose(float(jax.jit(lambda p: loss(p, cfg))(params)),
                           float(jax.jit(lambda p: loss(p, cfg2))(params)),
                           rtol=1e-5)
ga = jax.jit(jax.grad(lambda p: loss(p, cfg)))(params)
gb = jax.jit(jax.grad(lambda p: loss(p, cfg2)))(params)
for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-5)
print("OK")
""",
        n_devices=8,
        timeout=560,
    )
