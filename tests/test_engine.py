"""Engine front door (repro.engine): spec/plan hashability, parity of every
engine entry with the legacy path it replaced — {dense, qr, tt} x {baseline,
cached, dup, packed} x {single-chip, sharded} — and gradients through the
training entry.  The legacy builder shims are removed; the suite asserts
they stay gone."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as E
from repro.core import embedding_bag as EB
from repro.core import sharded_embedding as SE
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.data.synthetic import zipf_trace
from repro.engine import EngineSpec

KINDS = [("dense", {}), ("qr", {"collision": 8}), ("tt", {"tt_rank": 4})]


def _bags(kind, num_tables=3, vocab=1024, dim=32, pooling=8, **kw):
    emb = EmbeddingConfig(
        vocab=vocab, dim=dim, kind=kind, param_dtype=jnp.float32,
        compute_dtype=jnp.float32, **kw,
    )
    return [BagConfig(emb=emb, pooling=pooling) for _ in range(num_tables)]


# ---------------------------------------------------------------------------
# spec + plan: validation, hashability, summaries
# ---------------------------------------------------------------------------

def test_spec_validation():
    bags = _bags("dense")
    with pytest.raises(ValueError, match="at least one bag"):
        EngineSpec(bags=())
    with pytest.raises(ValueError, match="packing"):
        EngineSpec.from_bags(bags, packing="sometimes")
    with pytest.raises(ValueError, match="backend"):
        EngineSpec.from_bags(bags, exec_backend="cuda")
    with pytest.raises(ValueError, match="slot policy"):
        EngineSpec.from_bags(bags, cache_slot_policy="lru")


def test_plan_is_hashable_and_stable():
    bags = _bags("qr", collision=8)
    spec = EngineSpec.from_bags(bags, cache_slots=16)
    p1 = E.plan(spec, num_shards=2)
    p2 = E.plan(spec, num_shards=2)
    assert hash(p1) == hash(p2) and p1 == p2          # jit-static-arg safe
    assert p1 != E.plan(spec, num_shards=4)
    # trace payloads must NOT change eq/hash (they are compare=False)
    trace = [zipf_trace(1024, 2000, seed=t) for t in range(3)]
    p3 = E.plan(spec.replace(cache_slot_policy="uniform"), num_shards=2)
    assert p3.slot_budgets == p1.slot_budgets or p3 != p1


def test_plan_summary_is_json_serializable():
    import json

    bags = _bags("tt", tt_rank=4)
    trace = [zipf_trace(1024, 2000, seed=t) for t in range(3)]
    spec = EngineSpec.from_bags(bags, cache_slots=8, duplication=True)
    plan = E.plan(spec, num_shards=2, trace=trace)
    s = json.loads(json.dumps(plan.summary()))
    assert s["backend"] == "packed" and s["num_tables"] == 3
    assert len(s["slot_budgets"]) == 3 and s["total_slots"] > 0
    assert "replicated_bytes_per_chip" in s
    assert len(s["mean_intra_reuse_big"]) == 3


def test_plan_adaptive_budgets_waterfill():
    bags = _bags("qr", collision=8)
    # tables see different skews -> the waterfill splits unevenly
    trace = [zipf_trace(1024, 8000, alpha=1.4, seed=0),
             zipf_trace(1024, 8000, alpha=1.01, seed=1),
             zipf_trace(1024, 8000, alpha=1.01, seed=2)]
    spec = EngineSpec.from_bags(bags, cache_slots=16)
    plan = E.plan(spec, trace=trace)
    assert sum(plan.slot_budgets) <= 16 * 3
    assert all(b >= 1 for b in plan.slot_budgets)
    assert len(set(plan.slot_budgets)) > 1          # value-driven, not uniform
    uniform = E.plan(spec.replace(cache_slot_policy="uniform"), trace=trace)
    assert len(set(uniform.slot_budgets)) == 1


def test_engine_for_is_memoized():
    spec = EngineSpec.from_bags(_bags("dense"))
    assert E.engine_for(spec) is E.engine_for(spec)


# ---------------------------------------------------------------------------
# single-chip parity: packed + per-table backends vs the legacy semantic loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", KINDS)
@pytest.mark.parametrize("packing", ["auto", "off"])
def test_engine_lookup_matches_legacy(kind, kw, packing):
    bags = _bags(kind, **kw)
    tables = EB.init_tables(jax.random.PRNGKey(0), bags)
    idx = jax.random.randint(jax.random.PRNGKey(1), (5, 3, 8), 0, 1024)
    eng = E.compile(E.plan(EngineSpec.from_bags(bags, packing=packing)))
    assert eng.plan.backend == ("packed" if packing == "auto" else "pertable")
    out = eng.lookup(tables, idx)
    oracle = EB.multi_bag_lookup(tables, idx, bags)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind,kw", KINDS)
def test_engine_kernel_backend_matches_oracle(kind, kw):
    """exec_backend="kernel" runs the megakernel program (interpret on CPU)."""
    bags = _bags(kind, **kw)
    tables = EB.init_tables(jax.random.PRNGKey(2), bags)
    idx = jax.random.randint(jax.random.PRNGKey(3), (4, 3, 8), 0, 1024)
    eng = E.compile(E.plan(EngineSpec.from_bags(bags, exec_backend="kernel")))
    out = eng.lookup(tables, idx, interpret=True)
    oracle = EB.multi_bag_lookup(tables, idx, bags)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind,kw", KINDS)
def test_engine_grad_parity_training_entry(kind, kw):
    """jax.grad through engine.lookup: kernel path == jnp oracle path, for
    every table leaf (the custom-vjp-backed training entry)."""
    bags = _bags(kind, num_tables=2, **kw)
    tables = EB.init_tables(jax.random.PRNGKey(4), bags)
    idx = jax.random.randint(jax.random.PRNGKey(5), (3, 2, 4), 0, 1024)

    def loss(tabs, backend, interpret):
        eng = E.compile(E.plan(EngineSpec.from_bags(bags, exec_backend=backend)))
        out = eng.lookup(tabs, idx, interpret=interpret)
        return (out.astype(jnp.float32) ** 2).sum()

    gk = jax.grad(lambda t: loss(t, "kernel", True))(tables)
    gr = jax.grad(lambda t: loss(t, "jnp", None))(tables)
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    assert any(float(jnp.abs(g).max()) > 0 for g in jax.tree.leaves(gk))


# ---------------------------------------------------------------------------
# cached serving parity (single-chip): scheduler slots through the engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", KINDS)
def test_engine_cached_lookup_matches_uncached(kind, kw):
    from repro.cache.sram_cache import PrefetchScheduler

    bags = _bags(kind, num_tables=1, **kw)
    emb = bags[0].emb
    params = EB.init_tables(jax.random.PRNGKey(6), bags)[0]
    idx = np.asarray(
        jax.random.randint(jax.random.PRNGKey(7), (6, 8), 0, 1024))
    _name, rows = E.big_subtable(emb)
    sched = PrefetchScheduler(rows, 16)
    r = E.big_rows(idx, emb)
    sched.prefetch(r)
    slot = sched.slots_for(r)
    assert (slot >= 0).any()

    eng = E.engine_for(EngineSpec.from_bags(bags))
    out = eng.cached_lookup(
        params, jnp.asarray(idx), 0,
        cache_rows=jnp.asarray(sched.cache_rows()), slot=jnp.asarray(slot),
    )
    oracle = EB.bag_lookup(params, jnp.asarray(idx), bags[0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind,kw", KINDS)
def test_engine_serve_gather_matches_oracle(kind, kw):
    """The full serving dispatch: plan w/ cache -> pack -> serve_gather."""
    bags = _bags(kind, **kw)
    tables = EB.init_tables(jax.random.PRNGKey(8), bags)
    idx = np.asarray(
        jax.random.randint(jax.random.PRNGKey(9), (6, 3, 8), 0, 1024))
    trace = [idx[:, t].reshape(-1) for t in range(3)]
    spec = EngineSpec.from_bags(bags, cache_slots=16, exec_backend="kernel")
    eng = E.compile(E.plan(spec, trace=trace))
    assert eng.plan.has_cache

    scheds = eng.fresh_schedulers()
    slot = []
    for t in range(3):
        r = E.big_rows(idx[:, t], bags[t].emb)
        scheds[t].prefetch(r)
        slot.append(scheds[t].slots_for(r))
    slot = np.stack(slot, axis=1)
    assert (slot >= 0).any()

    packed = eng.pack(tables)
    out = eng.serve_gather(
        packed, jnp.asarray(idx), jnp.asarray(slot),
        jnp.asarray(eng.packed_cache_rows(scheds)),
    )
    oracle = EB.multi_bag_lookup(tables, jnp.asarray(idx), bags)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# duplication plan on a 1x1 mesh (single device): comm-free local serving
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", KINDS)
def test_engine_gnr_dup_single_device(kind, kw):
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    bags = _bags(kind, num_tables=2, **kw)
    tables = EB.init_tables(jax.random.PRNGKey(10), bags)
    idx = jax.random.randint(jax.random.PRNGKey(11), (4, 2, 8), 0, 1024)
    oracle = EB.multi_bag_lookup(tables, idx, bags)
    trace = [zipf_trace(1024, 4000, seed=t) for t in range(2)]

    spec = EngineSpec.from_bags(bags, duplication=True, dup_budget_bytes=1 << 24)
    eng = E.compile(E.plan(spec, mesh=mesh, trace=trace))
    assert eng.plan.dup is not None and all(eng.plan.comm_free)
    fn = eng.gnr(mesh)
    out = fn(tables, idx, eng.hot_tiers(tables))
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# sharded parity (8-device host mesh, one subprocess per kind):
# {baseline, packed two-level, per-table two-level, dup comm-free + starved}
# ---------------------------------------------------------------------------

_SHARDED = r"""
import numpy as np, jax, jax.numpy as jnp
from repro import engine as E
from repro.core import embedding_bag as EB, sharded_embedding as SE
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.data.synthetic import zipf_trace
from repro.engine import EngineSpec
from repro.launch.mesh import make_mesh

kind, kw = __KIND__, __KW__
mesh = make_mesh((2, 4), ("data", "model"))
emb = EmbeddingConfig(vocab=4096, dim=32, kind=kind, param_dtype=jnp.float32,
                      compute_dtype=jnp.float32, **kw)
bags = [BagConfig(emb=emb, pooling=8) for _ in range(2)]
tables = EB.init_tables(jax.random.PRNGKey(0), bags)
idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 8), 0, 4096)
oracle = np.asarray(EB.multi_bag_lookup(tables, idx, bags))
sharded = [SE.shard_qr_params(t, b.emb, mesh) for t, b in zip(tables, bags)]

def check(out, tag):
    np.testing.assert_allclose(np.asarray(out), oracle, rtol=1e-4, atol=1e-5,
                               err_msg=tag)
    print(tag, "OK")

# packed two-level GnR
eng = E.compile(E.plan(EngineSpec.from_bags(bags), mesh=mesh))
assert eng.plan.packed
check(eng.gnr(mesh)(sharded, idx), "packed")

# per-table two-level GnR
engp = E.compile(E.plan(EngineSpec.from_bags(bags, packing="off"), mesh=mesh))
check(engp.gnr(mesh)(sharded, idx), "pertable")

# GSPMD baseline (TT outer cores are too small to row-shard: skip tt)
if kind != "tt":
    check(eng.baseline(mesh)(sharded, idx), "baseline")

# duplication: comm-free (generous budget) and mixed (starved budget) regimes
trace = [zipf_trace(4096, 20000, seed=3 + t) for t in range(2)]
for budget, expect_cf in ((32 * 2**20, True), (8192, False)):
    spec = EngineSpec.from_bags(bags, duplication=True, dup_budget_bytes=budget)
    engd = E.compile(E.plan(spec, mesh=mesh, trace=trace))
    assert all(engd.plan.comm_free) == expect_cf, engd.plan.comm_free
    out = engd.gnr(mesh)(tables, idx, engd.hot_tiers(tables))
    check(out, f"dup budget={budget}")
print("ALL OK")
"""


@pytest.mark.parametrize("kind,kw", KINDS)
def test_engine_sharded_parity(kind, kw, mesh_runner):
    code = _SHARDED.replace("__KIND__", repr(kind)).replace("__KW__", repr(kw))
    out = mesh_runner(code, n_devices=8)
    assert "ALL OK" in out


# ---------------------------------------------------------------------------
# legacy builder shims are gone: the engine is the only GnR front door
# ---------------------------------------------------------------------------

def test_legacy_builder_shims_removed():
    """The PR-5 deprecation shims completed their grace window and were
    removed — importing them must fail so stale callers break loudly."""
    for name in ("build_multi_bag_gnr", "build_dup_multi_bag_gnr",
                 "cached_bag_lookup", "gspmd_baseline_gnr"):
        assert not hasattr(SE, name), f"shim {name} resurrected"


# ---------------------------------------------------------------------------
# the model forward routes through the engine (no mesh): DLRM parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["dlrm-qr-smoke", "dlrm-tt-smoke",
                                  "dlrm-dense-smoke"])
def test_dlrm_forward_matches_semantic_gnr(arch):
    from repro.configs import registry
    from repro.models import dlrm

    cfg = registry.get_dlrm(arch)
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
    bags = dlrm.make_bags(cfg)
    idx = jax.random.randint(
        jax.random.PRNGKey(1), (4, cfg.num_tables, cfg.pooling), 0,
        cfg.vocab_per_table,
    )
    pooled = dlrm._gnr(params["tables"], idx, bags, cfg)
    oracle = EB.multi_bag_lookup(params["tables"], idx, bags)
    np.testing.assert_allclose(
        np.asarray(pooled, dtype=np.float32),
        np.asarray(oracle, dtype=np.float32), rtol=2e-2, atol=2e-2,
    )
