"""Packed-table megakernel layer: layout, oracle parity, ragged bags, slot
routing, gradients through the custom vjp, slot-budget waterfilling, and the
overlapped serving pipeline's parity with the sequential baseline."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import intra_gnr
from repro.cache.sram_cache import PrefetchScheduler
from repro.core import embedding_bag as EB
from repro.core import packed_tables as PT
from repro.core.embedding_bag import BagConfig
from repro.core.qr_embedding import EmbeddingConfig
from repro.kernels import ops, ref


def _bags(kind, num_tables=3, vocab=1024, dim=32, pooling=8, **kw):
    emb = EmbeddingConfig(
        vocab=vocab, dim=dim, kind=kind, param_dtype=jnp.float32,
        compute_dtype=jnp.float32, **kw,
    )
    return [BagConfig(emb=emb, pooling=pooling) for _ in range(num_tables)]


KINDS = [("dense", {}), ("qr", {"collision": 8}), ("tt", {"tt_rank": 4})]


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

def test_layout_offsets_and_zero_rows():
    bags = _bags("qr", num_tables=3, collision=8)
    layout = PT.build_layout(bags, [4, 8, 2])
    assert layout.num_tables == 3
    assert layout.row_offsets == (0, 128, 256)       # q_rows=128, 128-padded
    assert layout.zero_row == layout.total_rows == 384
    assert layout.small_offsets == (0, 8, 16)        # R LUTs of collision 8
    assert layout.small_zero_row == 24
    assert layout.slot_offsets == (0, 4, 12) and layout.total_slots == 14
    tt = PT.build_layout(_bags("tt", num_tables=2))
    spec = _bags("tt")[0].emb.tt_spec
    assert tt.big_width == spec.g2_width
    assert tt.tt_vocab == spec.vocab_factors


def test_packable_rejects_non_uniform_and_unsupported():
    assert PT.packable(_bags("qr"))
    assert PT.packable(_bags("dense")) and PT.packable(_bags("tt"))
    hashed = _bags("dense")[:1] + [
        BagConfig(emb=dataclasses.replace(_bags("dense")[0].emb, kind="hashed"),
                  pooling=8)
    ]
    assert not PT.packable(hashed)
    mixed_dim = _bags("dense", dim=32)[:1] + _bags("dense", dim=64)[:1]
    assert not PT.packable(mixed_dim)
    # mixed vocab falls back too (hot-slot maps must stack on the mesh path)
    mixed_vocab = _bags("qr", vocab=1024)[:1] + _bags("qr", vocab=2048)[:1]
    assert not PT.packable(mixed_vocab)
    mul = [BagConfig(emb=dataclasses.replace(_bags("qr")[0].emb,
                                             reconstruction="mul"), pooling=8)]
    assert not PT.packable(mul)
    assert not PT.packable([])


# ---------------------------------------------------------------------------
# oracle parity (packed path vs the per-table loop, both exec modes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", KINDS)
@pytest.mark.parametrize("exec_mode", ["jnp", "kernel"])
def test_packed_multi_bag_parity(kind, kw, exec_mode):
    bags = _bags(kind, **kw)
    tables = EB.init_tables(jax.random.PRNGKey(0), bags)
    idx = jax.random.randint(jax.random.PRNGKey(1), (5, 3, 8), 0, 1024)
    oracle = EB.multi_bag_lookup(tables, idx, bags)
    out = PT.packed_multi_bag_lookup(
        tables, idx, bags, exec_mode=exec_mode,
        interpret=True if exec_mode == "kernel" else None,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind,kw", KINDS)
def test_packed_single_table_degenerate(kind, kw):
    """T=1 must reduce to the plain bag lookup (no packing artifacts)."""
    bags = _bags(kind, num_tables=1, **kw)
    tables = EB.init_tables(jax.random.PRNGKey(2), bags)
    idx = jax.random.randint(jax.random.PRNGKey(3), (4, 1, 8), 0, 1024)
    out = PT.packed_multi_bag_lookup(tables, idx, bags, exec_mode="kernel",
                                     interpret=True)
    oracle = EB.multi_bag_lookup(tables, idx, bags)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind,kw", KINDS)
def test_packed_ragged_and_empty_bags(kind, kw):
    """Positions past a bag's length route to the zero row: a masked-oracle
    match, and an empty bag pools to exactly zero."""
    bags = _bags(kind, **kw)
    tables = EB.init_tables(jax.random.PRNGKey(4), bags)
    idx = jax.random.randint(jax.random.PRNGKey(5), (4, 3, 8), 0, 1024)
    lengths = jnp.array([[8, 3, 0]] * 4)
    out = PT.packed_multi_bag_lookup(tables, idx, bags, lengths=lengths,
                                     exec_mode="kernel", interpret=True)
    # masked oracle: zero out invalid positions before the per-table pool
    from repro.core import qr_embedding as QE

    emb = bags[0].emb
    rows = jnp.stack(
        [QE.lookup(tables[t], idx[:, t], emb) for t in range(3)], axis=1
    )                                                  # (B, T, K, dim)
    mask = (jnp.arange(8)[None, None, :] < lengths[..., None])[..., None]
    expect = (rows * mask).sum(axis=-2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    assert bool(jnp.all(out[:, 2] == 0))               # empty bag


def test_packed_ragged_mean_divides_by_valid_length():
    """mean combiner on ragged bags divides by the VALID length, not K."""
    emb = EmbeddingConfig(vocab=256, dim=32, kind="dense",
                          param_dtype=jnp.float32, compute_dtype=jnp.float32)
    bags = [BagConfig(emb=emb, pooling=8, combiner="mean") for _ in range(2)]
    tables = EB.init_tables(jax.random.PRNGKey(0), bags)
    idx = jax.random.randint(jax.random.PRNGKey(1), (3, 2, 8), 0, 256)
    lengths = jnp.array([[3, 8]] * 3)
    out = PT.packed_multi_bag_lookup(tables, idx, bags, lengths=lengths)
    expect0 = tables[0]["table"][idx[:, 0, :3]].mean(axis=-2)   # mean of 3
    expect1 = tables[1]["table"][idx[:, 1]].mean(axis=-2)       # full bag
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(expect0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[:, 1]), np.asarray(expect1),
                               rtol=1e-5, atol=1e-5)


def test_vmem_resident_budget_guard():
    """Oversized packed cache blocks fail loudly at trace time, not as a
    Mosaic VMEM OOM on hardware."""
    from repro.kernels import packed_gather as PG

    table = jnp.zeros((64, 128))
    too_big = PG.VMEM_RESIDENT_BUDGET // (128 * 4) + 1
    cache = jnp.zeros((too_big, 128))
    idx = jnp.zeros((2, 4), jnp.int32)
    with pytest.raises(AssertionError, match="VMEM-resident"):
        PG.packed_bag(table, cache, idx, idx, interpret=True)


# ---------------------------------------------------------------------------
# cache-slot routing through the packed block (megakernel x scheduler)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", KINDS)
def test_packed_cache_routing_matches_uncached(kind, kw):
    """Slots staged by real per-table schedulers, translated to the packed
    cache block: hits must reproduce the uncached result bit-for-bit."""
    from repro.launch import serve_rec

    bags = _bags(kind, **kw)
    emb = bags[0].emb
    tables = EB.init_tables(jax.random.PRNGKey(6), bags)
    _name, rows = serve_rec.big_subtable(emb)
    idx = np.asarray(
        jax.random.randint(jax.random.PRNGKey(7), (6, 3, 8), 0, 1024))
    scheds = [PrefetchScheduler(rows, 16) for _ in range(3)]
    slot = []
    for t in range(3):
        r = serve_rec.big_rows(idx[:, t], emb)
        scheds[t].prefetch(r)
        slot.append(scheds[t].slots_for(r))
    slot = np.stack(slot, axis=1)
    assert (slot >= 0).any()

    layout = PT.build_layout(bags, [s.num_slots for s in scheds])
    packed = PT.pack_params(tables, layout)
    cache_rows = PT.packed_cache_rows([s.cache_rows() for s in scheds], layout)
    packed["cache"] = packed[PT.big_key(kind)][jnp.asarray(cache_rows)]
    streams = PT.pack_indices(jnp.asarray(idx), layout)
    streams["slot"] = PT.global_slots(jnp.asarray(slot), layout)
    out = ops.packed_multi_pooled(
        packed, streams, kind=layout.kind, dims=layout.tt_dims,
        exec_mode="kernel", interpret=True,
    )
    oracle = EB.multi_bag_lookup(tables, jnp.asarray(idx), bags)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# gradients through the reference-recompute vjp
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", KINDS)
def test_packed_kernel_grads_match_oracle(kind, kw):
    """The megakernel path must be training-safe: grads w.r.t. every table
    leaf equal the pure-jnp packed oracle's."""
    bags = _bags(kind, num_tables=2, **kw)
    tables = EB.init_tables(jax.random.PRNGKey(8), bags)
    idx = jax.random.randint(jax.random.PRNGKey(9), (3, 2, 4), 0, 1024)

    def loss(tabs, exec_mode, interpret):
        out = PT.packed_multi_bag_lookup(
            tabs, idx, bags, exec_mode=exec_mode, interpret=interpret)
        return (out.astype(jnp.float32) ** 2).sum()

    gk = jax.grad(lambda t: loss(t, "kernel", True))(tables)
    gr = jax.grad(lambda t: loss(t, "jnp", None))(tables)
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    assert any(float(jnp.abs(g).max()) > 0 for g in jax.tree.leaves(gk))


# ---------------------------------------------------------------------------
# adaptive slot budgets (waterfilling by prefetch value)
# ---------------------------------------------------------------------------

def test_split_slot_budget_waterfills_by_value():
    hot = np.zeros(100)
    hot[:50] = 10.0                        # table 0: 50 valuable rows
    cold = np.zeros(100)
    cold[:5] = 1.0                         # table 1: 5 mildly valuable rows
    budgets = intra_gnr.split_slot_budget([hot, cold], 40)
    assert sum(budgets) == 40
    assert budgets[0] > budgets[1] >= 1    # value skew drives the split
    # marginal-value exactness: table 1 keeps exactly its 5 valuable rows + base
    assert budgets[1] <= 6


def test_split_slot_budget_min_and_caps():
    vals = [np.ones(4), np.zeros(1000)]
    budgets = intra_gnr.split_slot_budget(vals, 100)
    assert budgets[0] >= 1 and budgets[1] >= 1
    assert budgets[0] <= 4                 # never more slots than rows
    assert sum(budgets) <= 100
    # degenerate inputs are explicit errors, not silent empty plans
    with pytest.raises(ValueError, match="empty table list"):
        intra_gnr.split_slot_budget([], 10)
    with pytest.raises(ValueError, match="positive slot budget"):
        intra_gnr.split_slot_budget([np.ones(4)], 0)
    with pytest.raises(ValueError, match="positive slot budget"):
        intra_gnr.split_slot_budget([np.ones(4)], -3)
    with pytest.raises(ValueError, match="min_slots"):
        intra_gnr.split_slot_budget([np.ones(4)], 10, min_slots=0)
    # starved budget still gives every table one slot
    tight = intra_gnr.split_slot_budget([np.ones(8)] * 3, 2)
    assert all(b >= 1 for b in tight)
    # the min_slots floor takes precedence over the total
    floored = intra_gnr.split_slot_budget([np.ones(8)] * 4, 7, min_slots=2)
    assert floored == [2, 2, 2, 2]
    # a rowless table gets zero slots
    assert intra_gnr.split_slot_budget([np.ones(4), np.empty(0)], 10)[1] == 0


def test_dup_plan_records_slot_budgets():
    from repro.cache import duplication
    from repro.core import placement
    from repro.data.synthetic import zipf_trace

    bags = _bags("qr", num_tables=2, collision=8)
    counts = placement.profile_counts(zipf_trace(1024, 10_000, seed=1), 1024)
    plan = duplication.plan_duplication(
        bags, [counts] * 2, num_shards=2, budget_bytes=4096,
        slot_budgets=[12, 20],
    )
    assert [t.cache_slots for t in plan.tables] == [12, 20]


# ---------------------------------------------------------------------------
# serving pipeline: batch overlap must not change the math
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["dlrm-qr-smoke", "dlrm-tt-smoke"])
def test_serve_pipeline_overlap_matches_sequential(arch):
    from repro.configs import registry
    from repro.launch import serve_rec
    from repro.models import dlrm

    cfg = registry.get_dlrm(arch)
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
    res = {
        mode: serve_rec.run_pipeline(
            cfg, batch=4, batches=4, mode=mode, params=params)
        for mode in ("sequential", "overlap")
    }
    for a, b in zip(res["sequential"]["logits"], res["overlap"]["logits"]):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
    assert res["overlap"]["qps"] > 0
    # adaptive budgets: one scheduler per table, waterfilled global budget
    assert len(res["overlap"]["slot_budgets"]) == cfg.num_tables
    assert sum(res["overlap"]["slot_budgets"]) <= cfg.cache_slots * cfg.num_tables
