"""DLRM (the paper's model): forward semantics, interaction, quality metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import dlrm_qr
from repro.data.synthetic import dlrm_batch
from repro.models import dlrm


def test_forward_shapes():
    cfg = dlrm_qr.SMOKE
    params, axes = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
    batch = dlrm_batch(cfg, 16, seed=0, step=0)
    logits = dlrm.forward_dlrm(params, batch["dense"], batch["idx"], cfg)
    assert logits.shape == (16,)
    assert not bool(jnp.isnan(logits).any())


def test_interaction_count():
    cfg = dlrm_qr.SMOKE
    f = cfg.num_tables + 1
    bottom = jax.random.normal(jax.random.PRNGKey(0), (3, cfg.dim))
    pooled = jax.random.normal(jax.random.PRNGKey(1), (3, cfg.num_tables, cfg.dim))
    z = dlrm.interact(bottom, pooled)
    assert z.shape == (3, f * (f - 1) // 2)
    # first interaction = bottom . pooled[0]
    np.testing.assert_allclose(
        np.asarray(z[:, 0]), np.asarray((bottom * pooled[:, 0]).sum(-1)), rtol=1e-5
    )


def test_bce_loss_matches_reference():
    logits = jnp.array([0.0, 2.0, -3.0])
    labels = jnp.array([1.0, 0.0, 0.0])
    ours = float(dlrm.bce_loss(logits, labels))
    p = 1 / (1 + np.exp(-np.asarray(logits)))
    ref = -np.mean(np.asarray(labels) * np.log(p) + (1 - np.asarray(labels)) * np.log(1 - p))
    np.testing.assert_allclose(ours, ref, rtol=1e-6)


def test_auc_separable():
    logits = jnp.array([-2.0, -1.0, 1.0, 2.0])
    labels = jnp.array([0.0, 0.0, 1.0, 1.0])
    assert float(dlrm.auc(logits, labels)) == 1.0
    assert abs(float(dlrm.auc(-logits, labels))) < 1e-6


def test_qr_vs_dense_same_structure():
    """QR-DLRM must expose identical input/output contract as dense DLRM
    while holding ~collision x fewer embedding parameters."""
    import dataclasses

    cfg_qr = dlrm_qr.SMOKE
    cfg_dense = dataclasses.replace(cfg_qr, embedding_kind="dense")
    pq, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg_qr)
    pd, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg_dense)
    nq = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(pq["tables"]))
    nd = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(pd["tables"]))
    assert nq * (cfg_qr.qr_collision // 2) < nd
    batch = dlrm_batch(cfg_qr, 8, seed=0, step=0)
    for p, c in ((pq, cfg_qr), (pd, cfg_dense)):
        out = dlrm.forward_dlrm(p, batch["dense"], batch["idx"], c)
        assert out.shape == (8,)


def test_sharded_dlrm_matches_single(mesh_runner):
    mesh_runner(
        """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import dlrm_qr
from repro.data.synthetic import dlrm_batch
from repro.models import dlrm
from repro.distributed import sharding as SH
from repro.launch.mesh import make_mesh

cfg = dataclasses.replace(dlrm_qr.SMOKE, compute_dtype="float32")
params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
batch = dlrm_batch(cfg, 8, seed=0, step=0)
single = dlrm.forward_dlrm(params, batch["dense"], batch["idx"], cfg)

mesh = make_mesh((2, 4), ("data", "model"))
params_p = dlrm.pad_tables_for_mesh(params, cfg, 4)
with SH.use_rules(mesh, SH.DEFAULT_RULES):
    sharded = jax.jit(lambda p, d, i: dlrm.forward_dlrm(p, d, i, cfg))(
        params_p, batch["dense"], batch["idx"])
np.testing.assert_allclose(np.asarray(single), np.asarray(sharded), rtol=2e-3, atol=2e-3)
print("OK")
""",
        n_devices=8,
    )
