"""Training substrate: optimizer math, microbatch equivalence, convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.train import optimizer as opt_mod
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_lm_loss, make_train_step, next_token_loss


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    cfg = OptConfig(lr=1e-2, warmup_steps=0, weight_decay=0.1, clip_norm=0.0,
                    schedule="constant")
    params = {"w": jnp.array([1.0, -2.0]), "b": jnp.array([[0.5]])}
    grads = {"w": jnp.array([0.1, 0.2]), "b": jnp.array([[-0.3]])}
    state = opt_mod.init(params)
    new_params, new_state, metrics = opt_mod.update(params, grads, state, cfg)

    for k in ("w", "b"):
        g = np.asarray(grads[k], np.float64)
        p = np.asarray(params[k], np.float64)
        m = (1 - cfg.beta1) * g
        v = (1 - cfg.beta2) * g * g
        mh = m / (1 - cfg.beta1)
        vh = v / (1 - cfg.beta2)
        expect = p - cfg.lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        np.testing.assert_allclose(np.asarray(new_params[k]), expect, rtol=1e-5)
    assert int(new_state["step"]) == 1


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    lrs = [float(opt_mod.learning_rate(cfg, jnp.int32(s))) for s in range(0, 111, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # end of warmup
    assert all(lrs[i] >= lrs[i + 1] - 1e-9 for i in range(1, len(lrs) - 1))
    assert abs(lrs[-1] - 0.1) < 1e-6          # cosine floor


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = opt_mod.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0) < 1e-5
    assert abs(float(opt_mod.global_norm(clipped)) - 1.0) < 1e-5


def test_next_token_loss_value():
    logits = jnp.zeros((1, 3, 5))
    tokens = jnp.array([[1, 2, 3]], jnp.int32)
    loss = next_token_loss(logits, tokens)
    np.testing.assert_allclose(float(loss), np.log(5.0), rtol=1e-6)


def test_microbatch_equivalence():
    """mb=1 and mb=4 must produce the same update (grad averaging exactness)."""
    binding = registry.get("qwen2-1.5b")
    cfg = binding.smoke.replace(compute_dtype="float32", remat=False)
    params, _ = registry.init_fn(binding)(jax.random.PRNGKey(0), cfg)
    loss_fn = registry.train_loss_fn(binding, cfg)
    batch = registry.make_batch_fn(binding, cfg)(8, 16, seed=0, step=0)
    ocfg = OptConfig(warmup_steps=0, schedule="constant")

    p1, _, m1 = jax.jit(make_train_step(loss_fn, ocfg, microbatches=1))(
        params, opt_mod.init(params), batch
    )
    p4, _, m4 = jax.jit(make_train_step(loss_fn, ocfg, microbatches=4))(
        params, opt_mod.init(params), batch
    )
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


@pytest.mark.slow
def test_loss_decreases_tiny_lm():
    binding = registry.get("qwen2-1.5b")
    cfg = binding.smoke
    params, _ = registry.init_fn(binding)(jax.random.PRNGKey(0), cfg)
    loss_fn = registry.train_loss_fn(binding, cfg)
    step = jax.jit(make_train_step(loss_fn, OptConfig(lr=1e-3, warmup_steps=2)))
    opt = opt_mod.init(params)
    batch = registry.make_batch_fn(binding, cfg)(8, 32, seed=0, step=0)
    losses = []
    for i in range(12):
        params, opt, m = step(params, opt, batch)   # same batch -> must overfit
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_dlrm_train_step():
    from repro.configs import dlrm_qr
    from repro.data.synthetic import dlrm_batch
    from repro.models import dlrm
    from repro.train.train_step import make_dlrm_loss

    cfg = dlrm_qr.SMOKE
    params, _ = dlrm.init_dlrm(jax.random.PRNGKey(0), cfg)
    batch = dlrm_batch(cfg, 32, seed=0, step=0)
    step = jax.jit(make_train_step(make_dlrm_loss(cfg), OptConfig(lr=1e-3,
                                                                  warmup_steps=1)))
    opt = opt_mod.init(params)
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert not any(np.isnan(losses))
