"""The loop-aware HLO analyzer — the roofline's metrology layer."""

import numpy as np

from repro.launch import hlo_analysis as HA

# A hand-written HLO module: entry calls a while (trip 3) whose body has one
# dot (m=4,k=8,n=16 -> 1024 flops) and one all-reduce over groups of 4.
SYNTH = """
HloModule synth

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (p: (s32[], f32[4,8], f32[8,16], f32[4,16])) -> (s32[], f32[4,8], f32[8,16], f32[4,16]) {
  %p = (s32[], f32[4,8], f32[8,16], f32[4,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %lhs = f32[4,8]{1,0} get-tuple-element(%p), index=1
  %rhs = f32[8,16]{1,0} get-tuple-element(%p), index=2
  %dot.1 = f32[4,16]{1,0} dot(%lhs, %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,16]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,8], f32[8,16], f32[4,16]) tuple(%ip, %lhs, %rhs, %ar)
}

%cond (p: (s32[], f32[4,8], f32[8,16], f32[4,16])) -> pred[] {
  %p = (s32[], f32[4,8], f32[8,16], f32[4,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[4,8], y: f32[8,16]) -> f32[4,16] {
  %x = f32[4,8]{1,0} parameter(0)
  %y = f32[8,16]{1,0} parameter(1)
  %z = s32[] constant(0)
  %acc = f32[4,16]{1,0} broadcast(%z), dimensions={}
  %t0 = (s32[], f32[4,8], f32[8,16], f32[4,16]) tuple(%z, %x, %y, %acc)
  %w = (s32[], f32[4,8], f32[8,16], f32[4,16]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"3"}}
  ROOT %out = f32[4,16]{1,0} get-tuple-element(%w), index=3
}
"""


def test_parse_computations():
    comps = HA.parse_hlo(SYNTH)
    assert set(comps) == {"add", "body", "cond", "main"}
    assert comps["body"].instrs["dot.1"].opcode == "dot"
    assert comps["main"].root == "out"


def test_loop_multiplied_flops_and_collectives():
    res = HA.analyze(SYNTH, entry="main")
    # dot: 2*4*16*8 = 1024 flops, x3 trips
    assert res["flops"] == 3 * 1024
    # all-reduce operand f32[4,16] = 256 B, x3
    assert res["coll_bytes"]["all-reduce"] == 3 * 256
    assert res["coll_counts"]["all-reduce"] == 3
    # ring wire bytes: 2*B*(g-1)/g with g=4
    np.testing.assert_allclose(
        res["coll_wire"]["all-reduce"], 3 * 2 * 256 * 3 / 4
    )
    assert res["unknown_loops"] == 0


def test_bytes_model():
    res = HA.analyze(SYNTH, entry="main")
    # per trip: dot (32+128+64 fl.. bytes: lhs 128 + rhs 512 + out 256) +
    # all-reduce (256+256) + add s32 (12) -> x3; broadcast/tuple/GTE are free
    per_trip = (128 + 512 + 256) + (256 + 256) + 12
    assert res["bytes"] == 3 * per_trip


def test_dtype_table_and_type_parse():
    types, end = HA._parse_result_types("(f32[2,2]{1,0}, bf16[4]{0}) tuple(...)")
    assert HA._types_bytes(types) == 16 + 8
    types, _ = HA._parse_result_types("pred[] compare(...)")
    assert HA._types_bytes(types) == 1


GATHER_FUSION = """
HloModule g

%fused_computation (param_0: f32[1000,64], param_1: s32[8,1]) -> f32[8,64] {
  %param_0 = f32[1000,64]{1,0} parameter(0)
  %param_1 = s32[8,1]{1,0} parameter(1)
  ROOT %g = f32[8,64]{1,0} gather(%param_0, %param_1), offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1,64}
}

ENTRY %main (t: f32[1000,64], i: s32[8,1]) -> f32[8,64] {
  %t = f32[1000,64]{1,0} parameter(0)
  %i = s32[8,1]{1,0} parameter(1)
  ROOT %f = f32[8,64]{1,0} fusion(%t, %i), kind=kLoop, calls=%fused_computation
}
"""


def test_gather_fusion_touched_rows_discount():
    """A 256 KB table consumed only by a gather of 8 rows must NOT count as
    256 KB of traffic (the embedding-lookup case the paper lives on)."""
    res = HA.analyze(GATHER_FUSION, entry="main")
    touched = 2 * 8 * 64 * 4          # 2 x result bytes
    idx = 8 * 4
    assert res["bytes"] <= 8 * 64 * 4 + touched + idx
    assert res["bytes"] < 1000 * 64 * 4   # far below the full table
