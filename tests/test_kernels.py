"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.kernels import ops, ref


def _tables(q_rows, c, dim, dtype, seed=0):
    kq, kr = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.normal(kq, (q_rows, dim), dtype)
    r = jax.random.normal(kr, (c, dim), dtype)
    return q, r


@pytest.mark.parametrize("dim", [128, 256, 512, 640, 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qr_lookup_sweep(dim, dtype):
    q, r = _tables(64, 8, dim, dtype)
    key = jax.random.PRNGKey(1)
    qi = jax.random.randint(key, (33,), 0, 64)
    ri = jax.random.randint(key, (33,), 0, 8)
    out = ops.qr_lookup(q, r, qi, ri)
    expect = ref.qr_lookup_ref(q, r, qi, ri)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=1e-5 if dtype == jnp.float32 else 2e-2,
    )


@pytest.mark.parametrize("lead", [(7,), (2, 5), (3, 2, 2)])
def test_qr_lookup_leading_shapes(lead):
    q, r = _tables(32, 4, 128, jnp.float32)
    key = jax.random.PRNGKey(2)
    qi = jax.random.randint(key, lead, 0, 32)
    ri = jax.random.randint(key, lead, 0, 4)
    out = ops.qr_lookup(q, r, qi, ri)
    assert out.shape == lead + (128,)
    np.testing.assert_allclose(out, ref.qr_lookup_ref(q, r, qi, ri), rtol=1e-6)


@pytest.mark.parametrize("dim", [128, 512])
@pytest.mark.parametrize("k", [1, 4, 32])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gnr_pooled_sweep(dim, k, dtype):
    q, r = _tables(128, 16, dim, dtype)
    key = jax.random.PRNGKey(3)
    qi = jax.random.randint(key, (6, k), 0, 128)
    ri = jax.random.randint(key, (6, k), 0, 16)
    out = ops.gnr_pooled(q, r, qi, ri)
    expect = ref.gnr_bag_ref(q, r, qi, ri)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=1e-5 if dtype == jnp.float32 else 3e-2, atol=1e-2,
    )


@pytest.mark.parametrize("dim", [128, 384])
def test_gnr_dense_sweep(dim):
    t, _ = _tables(64, 2, dim, jnp.float32)
    key = jax.random.PRNGKey(4)
    idx = jax.random.randint(key, (5, 9), 0, 64)
    out = ops.gnr_pooled_dense(t, idx)
    # atol covers fp32 accumulation-order differences between the interpret-
    # mode kernel and the XLA-fused reference (host-dependent).
    np.testing.assert_allclose(out, ref.dense_bag_ref(t, idx), rtol=1e-5, atol=1e-5)


def test_small_dim_fallback():
    """dims with no 8-aligned tile fall back to the jnp reference path."""
    q, r = _tables(16, 4, 12, jnp.float32)
    qi = jnp.array([0, 15], jnp.int32)
    ri = jnp.array([1, 3], jnp.int32)
    np.testing.assert_allclose(
        ops.qr_lookup(q, r, qi, ri), ref.qr_lookup_ref(q, r, qi, ri), rtol=1e-6
    )


@pytest.mark.parametrize("dim", [96, 200])
def test_non_128_dim_single_tile(dim):
    """8-aligned dims NOT divisible by 128 run the kernel as one wide tile
    (the explicit fallback): kernel == oracle, for lookup and pooled paths."""
    q, r = _tables(64, 8, dim, jnp.float32)
    key = jax.random.PRNGKey(7)
    qi = jax.random.randint(key, (5, 6), 0, 64)
    ri = jax.random.randint(key, (5, 6), 0, 8)
    np.testing.assert_allclose(
        np.asarray(ops.gnr_pooled(q, r, qi, ri)),
        np.asarray(ref.gnr_bag_ref(q, r, qi, ri)), rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(ops.qr_lookup(q, r, qi[:, 0], ri[:, 0])),
        np.asarray(ref.qr_lookup_ref(q, r, qi[:, 0], ri[:, 0])), rtol=1e-5,
    )


def test_pick_dim_block_ladder():
    """The dim-block choice is now an explicit tuner knob
    (``repro.tune.knobs``): the heuristic default reproduces the historical
    ladder (largest of 512/256/128 dividing dim; whole dim when 8-aligned;
    None = jnp reference), and the valid-block enumeration bounds what a
    tuned plan may pass."""
    from repro.tune import knobs as K

    for d, want in ((128, 128), (256, 256), (512, 512), (640, 128),
                    (384, 128)):
        assert ops._pick_dim_block(d) == want == K.default_dim_block(d)
    # 8-aligned, non-128 dims: single wide tile (96, 200)
    assert ops._pick_dim_block(96) == 96
    assert ops._pick_dim_block(200) == 200
    assert K.valid_dim_blocks(96) == (96,)
    assert K.valid_dim_blocks(200) == (200,)
    # no 8-aligned tile at all: jnp reference only
    assert ops._pick_dim_block(13) is None
    assert K.valid_dim_blocks(13) == ()


@pytest.mark.parametrize("dim,block", [(96, 96), (200, 200), (256, 128)])
def test_explicit_dim_block_matches_oracle(dim, block):
    """A tuner-chosen ``dim_block`` threads through the public wrappers and
    produces oracle-identical results."""
    q, r = _tables(64, 8, dim, jnp.float32)
    key = jax.random.PRNGKey(11)
    qi = jax.random.randint(key, (4, 6), 0, 64)
    ri = jax.random.randint(key, (4, 6), 0, 8)
    np.testing.assert_allclose(
        np.asarray(ops.gnr_pooled(q, r, qi, ri, dim_block=block)),
        np.asarray(ref.gnr_bag_ref(q, r, qi, ri)), rtol=1e-5, atol=1e-5,
    )


def test_invalid_dim_block_rejected():
    """An explicit block that is illegal for the dim is an error, and a dim
    with no valid block rejects every explicit block."""
    q, r = _tables(64, 8, 96, jnp.float32)
    qi = jnp.zeros((2, 3), jnp.int32)
    ri = jnp.zeros((2, 3), jnp.int32)
    with pytest.raises(ValueError, match="not valid for dim 96"):
        ops.gnr_pooled(q, r, qi, ri, dim_block=128)
    q13, r13 = _tables(64, 8, 13, jnp.float32)
    with pytest.raises(ValueError, match="not valid for dim 13"):
        ops.gnr_pooled(q13, r13, qi, ri, dim_block=13)


@given(
    n=st.integers(1, 64),
    q_rows=st.integers(1, 200),
    c=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_qr_lookup_property(n, q_rows, c, seed):
    """Kernel == oracle for arbitrary index distributions (dim fixed 128)."""
    q, r = _tables(q_rows, c, 128, jnp.float32, seed=seed % 97)
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    qi = jax.random.randint(k1, (n,), 0, q_rows)
    ri = jax.random.randint(k2, (n,), 0, c)
    np.testing.assert_allclose(
        ops.qr_lookup(q, r, qi, ri), ref.qr_lookup_ref(q, r, qi, ri), rtol=1e-6
    )


def test_gnr_accumulates_fp32():
    """bf16 tables with many repeated adds must not lose precision (the
    kernel's fp32 VMEM accumulator — 'MAC-unit accuracy')."""
    dim, k = 128, 256
    q = jnp.full((4, dim), 1.001, jnp.bfloat16)
    r = jnp.zeros((2, dim), jnp.bfloat16)
    qi = jnp.zeros((1, k), jnp.int32)
    ri = jnp.zeros((1, k), jnp.int32)
    out = ops.gnr_pooled(q, r, qi, ri)
    expect = float(jnp.bfloat16(1.001)) * k
    assert abs(float(out[0, 0]) - expect) / expect < 1e-2


# ---------------------------------------------------------------------------
# Pallas flash attention (VMEM-resident tiles)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    (2, 4, 2, 256, 256, True),    # GQA causal
    (1, 4, 4, 128, 384, False),   # MHA cross-length
    (2, 8, 2, 512, 512, True),
])
def test_flash_fused_vs_oracle(shape):
    from repro.kernels.flash_attention import flash_fwd

    b, h, kh, sq, skv, causal = shape
    key = jax.random.PRNGKey(0)
    q_ = jax.random.normal(jax.random.fold_in(key, 1), (b, h, sq, 128))
    k_ = jax.random.normal(jax.random.fold_in(key, 2), (b, kh, skv, 128))
    v_ = jax.random.normal(jax.random.fold_in(key, 3), (b, kh, skv, 128))
    out = flash_fwd(q_, k_, v_, causal=causal, q_block=128, kv_block=128,
                    interpret=True)
    expect = ref.flash_attention_ref(q_, k_, v_, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_fused_dtypes(dtype):
    from repro.kernels.flash_attention import flash_fwd

    key = jax.random.PRNGKey(1)
    q_ = jax.random.normal(key, (1, 2, 128, 128), dtype)
    k_ = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 128, 128), dtype)
    v_ = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 128, 128), dtype)
    out = flash_fwd(q_, k_, v_, causal=True, interpret=True)
    expect = ref.flash_attention_ref(
        q_.astype(jnp.float32), k_.astype(jnp.float32), v_.astype(jnp.float32),
        causal=True)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(expect),
                               rtol=tol, atol=tol)


def test_flash_fused_grad_matches_reference():
    from repro.kernels.flash_attention import flash_mha
    from repro.models.layers import flash_attention

    key = jax.random.PRNGKey(2)
    q_ = jax.random.normal(key, (1, 2, 128, 128))
    k_ = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 128, 128))
    v_ = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 128, 128))
    g1 = jax.grad(lambda a: flash_mha(a, k_, v_, True, True).sum())(q_)
    g2 = jax.grad(lambda a: flash_attention(a, k_, v_, causal=True).sum())(q_)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5,
                               atol=1e-6)
