"""Direct unit tests for the elastic fault-detection primitives.

The serving fault harness (``repro.serve.faults``) drives ``Heartbeat`` on a
virtual clock to simulate replica loss deterministically, so the edge cases
here — empty-beat hosts, injected clocks, ``min_step`` semantics — are
load-bearing for the chaos benchmarks, not just hygiene.
"""

import pytest

from repro.distributed.elastic import Heartbeat


def test_heartbeat_empty_state():
    hb = Heartbeat(deadline_s=5.0)
    assert hb.failed_hosts(now=1e9) == []
    assert hb.min_step() == 0
    assert hb.alive_hosts() == []


def test_registered_but_never_beat_host_fails_and_pins_min_step():
    hb = Heartbeat(deadline_s=5.0)
    hb.register(0, now=0.0)
    hb.beat(1, step=7, now=0.0)
    # before the deadline: both alive, but the empty-beat host has proven no
    # progress, so the fleet watermark is 0, not 7
    assert hb.failed_hosts(now=4.0) == []
    assert hb.min_step() == 0
    # past the deadline the silent host is detected without ever beating
    hb.beat(1, step=8, now=4.0)           # keep host 1 fresh
    assert hb.failed_hosts(now=6.0) == [0]
    assert hb.alive_hosts(now=6.0) == [1]
    # its first beat clears both the failure and the watermark pin
    hb.beat(0, step=9, now=6.5)
    assert hb.failed_hosts(now=7.0) == []
    assert hb.min_step() == 8


def test_register_is_idempotent_and_never_demotes_a_beat():
    hb = Heartbeat(deadline_s=5.0)
    hb.beat(0, step=3, now=10.0)
    hb.register(0, now=99.0)              # no-op: host already beating
    assert hb.marks[0] == (3, 10.0)
    hb.register(1, now=10.0)
    hb.register(1, now=20.0)              # idempotent: keeps the first clock
    assert hb.marks[1] == (None, 10.0)


def test_injected_clock_drives_default_now():
    t = {"now": 0.0}
    hb = Heartbeat(deadline_s=2.0, clock=lambda: t["now"])
    hb.beat(0, step=1)                    # stamped at virtual 0.0
    t["now"] = 1.0
    assert hb.failed_hosts() == []
    t["now"] = 3.5
    assert hb.failed_hosts() == [0]
    # per-call now= still overrides the injected clock
    assert hb.failed_hosts(now=1.5) == []
    hb.beat(0, step=2)                    # re-stamped at virtual 3.5
    assert hb.failed_hosts() == []
    assert hb.min_step() == 2


def test_min_step_over_mixed_hosts():
    hb = Heartbeat(deadline_s=5.0)
    hb.beat(0, step=10, now=0.0)
    hb.beat(1, step=4, now=0.0)
    hb.beat(2, step=7, now=0.0)
    assert hb.min_step() == 4
    # a failed host still holds the watermark (its progress is the truth)
    assert hb.failed_hosts(now=10.0) == [0, 1, 2]
    assert hb.min_step() == 4


def test_heartbeat_steps_coerced_to_int():
    hb = Heartbeat(deadline_s=5.0)
    hb.beat(0, step=3.0, now=0.0)         # float steps normalize
    assert hb.marks[0][0] == 3 and isinstance(hb.marks[0][0], int)


def test_failed_hosts_boundary_is_strict():
    hb = Heartbeat(deadline_s=5.0)
    hb.beat(0, step=1, now=0.0)
    assert hb.failed_hosts(now=5.0) == []   # exactly at deadline: alive
    assert hb.failed_hosts(now=5.0 + 1e-9) == [0]


def test_degraded_mesh_shapes_and_pod_async_unchanged():
    # the legacy behaviors the serve harness composes with
    from repro.distributed.elastic import PodAsyncState, degraded_mesh_shapes

    st = PodAsyncState(stale_limit=2, last_sync=0)
    assert st.should_sync(0, pod_slow=True) is False
    assert st.should_sync(2, pod_slow=True) is True
    shapes = degraded_mesh_shapes(16, 4)
    assert shapes[0] == (4, 4) and shapes[-1][0] >= 1


@pytest.mark.parametrize("policy", ["register_first", "beat_first"])
def test_alive_then_lost_then_recovered_cycle(policy):
    """The replica-loss cycle the fault injector simulates."""
    t = {"now": 0.0}
    hb = Heartbeat(deadline_s=3.0, clock=lambda: t["now"])
    for h in range(4):
        if policy == "register_first":
            hb.register(h)
        hb.beat(h, step=0)
    # steady state: everyone beats each tick
    for tick in range(1, 4):
        t["now"] = float(tick)
        for h in range(4):
            hb.beat(h, step=tick)
    assert hb.failed_hosts() == []
    # host 2 goes silent for > deadline
    for tick in range(4, 9):
        t["now"] = float(tick)
        for h in (0, 1, 3):
            hb.beat(h, step=tick)
    assert hb.failed_hosts() == [2]
    assert hb.alive_hosts() == [0, 1, 3]
    assert hb.min_step() == 3             # the lost host's watermark holds
    # recovery: one beat brings it back
    hb.beat(2, step=8)
    assert hb.failed_hosts() == []
