"""Distribution substrate: spec resolution, two-level GnR on a real (host)
mesh, compressed collectives, elastic resharding.  Mesh tests run in a child
process so this test session keeps its single CPU device."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as SH


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_resolve_spec_divisibility():
    mesh = _FakeMesh({"data": 4, "model": 8})
    rules = {"rows": ("model",), "cols": ("data",)}
    assert SH.resolve_spec(mesh, (64, 16), ("rows", "cols"), rules) == P("model", "data")
    # 63 rows not divisible by 8 -> replicated
    assert SH.resolve_spec(mesh, (63, 16), ("rows", "cols"), rules) == P(None, "data")


def test_resolve_spec_duplicate_axis_dropped():
    mesh = _FakeMesh({"data": 4, "model": 16})
    rules = {"experts": ("model",), "ffn": ("model",), "embed": ("data",)}
    # experts takes `model`; ffn wants it too -> dropped (replicated dim)
    spec = SH.resolve_spec(mesh, (64, 32, 32), ("experts", "embed", "ffn"), rules)
    assert spec == P("model", "data", None)
    # when experts doesn't divide (40 % 16 != 0), ffn picks `model` up instead
    spec = SH.resolve_spec(mesh, (40, 32, 32), ("experts", "embed", "ffn"), rules)
    assert spec == P(None, "data", "model")


def test_resolve_spec_multi_axis_fsdp():
    mesh = _FakeMesh({"pod": 2, "data": 4, "model": 8})
    rules = {"embed": ("pod", "data")}
    assert SH.resolve_spec(mesh, (64,), ("embed",), rules) == P(("pod", "data"))
    # 6 divides by pod=2 but not by pod*data=8 -> partial acceptance
    assert SH.resolve_spec(mesh, (6,), ("embed",), rules) == P("pod")


def test_multi_pod_rules():
    r = SH.multi_pod_rules()
    assert r["batch"] == ("pod", "data")
    pr = SH.multi_pod_param_rules()
    assert pr["embed"] == ("pod", "data")


def test_two_level_gnr_matches_oracle(mesh_runner):
    mesh_runner(
        """
import jax, jax.numpy as jnp, numpy as np
from repro import engine as E
from repro.core import sharded_embedding as SE, embedding_bag as EB, qr_embedding as QE
from repro.core.qr_embedding import EmbeddingConfig
from repro.core.embedding_bag import BagConfig
from repro.engine import EngineSpec
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
cfg = EmbeddingConfig(vocab=1024, dim=64, kind="qr", collision=8, compute_dtype=jnp.float32)
bag = BagConfig(emb=cfg, pooling=4)
params = QE.init(jax.random.PRNGKey(0), cfg)
idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 1024)
oracle = EB.multi_bag_lookup([params, params], idx, [bag, bag])
sp = SE.shard_qr_params(params, cfg, mesh)
spec = EngineSpec.from_bags((bag, bag))
fn = E.compile(E.plan(spec, mesh=mesh)).gnr(mesh)
out = fn([sp, sp], idx)
np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), rtol=1e-5, atol=1e-6)

# token path
fn2 = SE.build_token_embed(mesh, cfg)
tok = jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 1024)
np.testing.assert_allclose(np.asarray(fn2(sp, tok)),
                           np.asarray(QE.lookup(params, tok, cfg)), rtol=1e-5)
print("OK")
""",
        n_devices=8,
    )


def test_hot_tier_gnr_matches_oracle(mesh_runner):
    mesh_runner(
        """
import jax, jax.numpy as jnp, numpy as np
from repro import engine as E
from repro.core import sharded_embedding as SE, embedding_bag as EB, qr_embedding as QE
from repro.core import placement
from repro.core.qr_embedding import EmbeddingConfig
from repro.core.embedding_bag import BagConfig
from repro.data.synthetic import zipf_trace
from repro.core import hashing
from repro.engine import EngineSpec
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
cfg = EmbeddingConfig(vocab=4096, dim=32, kind="qr", collision=8, compute_dtype=jnp.float32)
bag = BagConfig(emb=cfg, pooling=4)
params = QE.init(jax.random.PRNGKey(0), cfg)

trace = zipf_trace(4096, 20000, seed=3)
q_idx, _ = hashing.qr_decompose(jnp.asarray(trace), 8)
counts = placement.profile_counts(np.asarray(q_idx), cfg.qr_spec.q_rows)
plan = placement.plan_tiers(counts, request_share=0.8)
padded = SE.pad_q_table(params["q"], cfg)
hot, cold = placement.split_table(padded, placement.TierPlan(
    hot_rows=plan.hot_rows, hot_slot=np.pad(plan.hot_slot, (0, padded.shape[0]-plan.hot_slot.size), constant_values=-1),
    hot_fraction=plan.hot_fraction, expected_hot_hit=plan.expected_hot_hit))
tier = {"hot_table": hot, "hot_slot": jnp.asarray(
    np.pad(plan.hot_slot, (0, padded.shape[0]-plan.hot_slot.size), constant_values=-1))}
sp = SE.shard_qr_params({"q": cold, "r": params["r"]}, cfg, mesh)

idx = jax.random.randint(jax.random.PRNGKey(1), (8, 1, 4), 0, 4096)
oracle = EB.multi_bag_lookup([params], idx, [bag])
spec = EngineSpec.from_bags((bag,))
fn = E.compile(E.plan(spec, mesh=mesh)).gnr(mesh, hot=True)
out = fn([sp], idx, [tier])
np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), rtol=1e-4, atol=1e-5)
print("OK")
""",
        n_devices=8,
    )


def test_compressed_psum_close_to_exact(mesh_runner):
    mesh_runner(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import compressed_psum, ef_step
from repro.distributed.jax_compat import shard_map
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("d",))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 128))

exact = shard_map(lambda v: jax.lax.psum(v, "d"), mesh=mesh,
    in_specs=P("d"), out_specs=P("d"), check_vma=False)(x)
approx = shard_map(lambda v: compressed_psum(v, "d"), mesh=mesh,
    in_specs=P("d"), out_specs=P("d"), check_vma=False)(x)
err = float(jnp.abs(exact - approx).max() / (jnp.abs(exact).max() + 1e-9))
assert err < 0.05, err

# error feedback: residual carried across steps shrinks accumulated bias
def two_steps(v):
    r = jnp.zeros_like(v)
    g1, r = ef_step(v, r, "d")
    g2, r = ef_step(v, r, "d")
    return g1 + g2
efsum = shard_map(two_steps, mesh=mesh, in_specs=P("d"), out_specs=P("d"),
    check_vma=False)(x)
err_ef = float(jnp.abs(2*exact - efsum).max() / (jnp.abs(exact).max() + 1e-9))
assert err_ef < 0.08, err_ef
print("OK")
""",
        n_devices=4,
    )


def test_elastic_reshard_roundtrip(mesh_runner):
    mesh_runner(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import elastic, sharding as SH
from repro.launch.mesh import make_mesh

tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((8,))}
axes = {"w": ("ffn", "embed"), "b": ("ffn",)}
m1 = make_mesh((2, 4), ("data", "model"))
placed = elastic.reshard_tree(tree, axes, m1, SH.PARAM_RULES)
m2 = make_mesh((4, 2), ("data", "model"))
moved = elastic.reshard_tree(placed, axes, m2, SH.PARAM_RULES)
np.testing.assert_array_equal(np.asarray(moved["w"]), np.asarray(tree["w"]))
np.testing.assert_array_equal(np.asarray(moved["b"]), np.asarray(tree["b"]))
print("OK")
""",
        n_devices=8,
    )


def test_heartbeat_and_async_policy():
    from repro.distributed.elastic import Heartbeat, PodAsyncState, degraded_mesh_shapes

    hb = Heartbeat(deadline_s=10.0)
    hb.beat(0, 5, now=100.0)
    hb.beat(1, 5, now=100.0)
    assert hb.failed_hosts(now=105.0) == []
    assert hb.failed_hosts(now=111.0) == [0, 1]
    hb.beat(0, 6, now=112.0)
    assert hb.failed_hosts(now=115.0) == [1]
    assert hb.min_step() == 5

    st = PodAsyncState(stale_limit=2, last_sync=0)
    assert st.should_sync(0, pod_slow=True) is False
    assert st.should_sync(2, pod_slow=True) is True   # staleness bound hit
    assert st.should_sync(1, pod_slow=False) is True  # fast path: always sync

    shapes = degraded_mesh_shapes(256, 16)
    assert (16, 16) in shapes and shapes[-1][0] >= 1


def test_quantize_roundtrip():
    from repro.distributed.collectives import dequantize_int8, quantize_int8

    x = jax.random.normal(jax.random.PRNGKey(0), (512,)) * 3.0
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x)).max()
    assert err <= float(scale) * 0.5 + 1e-6
