"""Weight-sharing embedding module: lookup/materialize/logits-head oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing, qr_embedding as QE
from repro.core.qr_embedding import EmbeddingConfig


def _cfg(**kw):
    base = dict(
        vocab=1000, dim=32, kind="qr", collision=8,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    base.update(kw)
    return EmbeddingConfig(**base)


@pytest.mark.parametrize("kind", ["dense", "hashed", "qr"])
def test_lookup_shape_and_dtype(kind):
    cfg = _cfg(kind=kind)
    params = QE.init(jax.random.PRNGKey(0), cfg)
    idx = jnp.array([[0, 1], [999, 500]], jnp.int32)
    out = QE.lookup(params, idx, cfg)
    assert out.shape == (2, 2, 32)
    assert out.dtype == jnp.float32


def test_dense_rows_padded_but_lookup_exact():
    cfg = _cfg(kind="dense", vocab=1000)
    params = QE.init(jax.random.PRNGKey(0), cfg)
    assert params["table"].shape[0] % QE.ROW_PAD == 0
    out = QE.lookup(params, jnp.arange(1000, dtype=jnp.int32), cfg)
    np.testing.assert_allclose(out, params["table"][:1000], rtol=0)


def test_qr_lookup_matches_manual():
    cfg = _cfg()
    params = QE.init(jax.random.PRNGKey(1), cfg)
    idx = jnp.array([3, 17, 999], jnp.int32)
    q, r = hashing.qr_decompose(idx, cfg.collision)
    expect = params["q"][q] + params["r"][r]
    np.testing.assert_allclose(QE.lookup(params, idx, cfg), expect, rtol=1e-6)


@pytest.mark.parametrize("recon", ["add", "mul", "concat"])
def test_reconstructions(recon):
    cfg = _cfg(reconstruction=recon)
    params = QE.init(jax.random.PRNGKey(2), cfg)
    idx = jnp.arange(64, dtype=jnp.int32)
    out = QE.lookup(params, idx, cfg)
    assert out.shape == (64, 32)
    assert not bool(jnp.isnan(out).any())
    # complementarity means no two logical rows are identical (a.s.)
    flat = np.asarray(out)
    assert len(np.unique(flat.round(5), axis=0)) == 64


def test_materialize_matches_lookup():
    cfg = _cfg()
    params = QE.init(jax.random.PRNGKey(3), cfg)
    table = QE.materialize(params, cfg)
    assert table.shape == (1000, 32)
    idx = jnp.array([5, 99, 731], jnp.int32)
    np.testing.assert_allclose(table[idx], QE.lookup(params, idx, cfg), rtol=1e-6)


@pytest.mark.parametrize("kind", ["dense", "hashed", "qr"])
def test_logits_head_equals_materialized_matmul(kind):
    """The QR-factorized head (beyond-paper FLOP cut) must produce identical
    logits to the naive x @ E^T against the materialized table."""
    cfg = _cfg(kind=kind, vocab=257)     # odd vocab exercises padding
    params = QE.init(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32))
    fast = QE.logits_head(params, x, cfg)
    slow = x @ QE.materialize(params, cfg).T
    assert fast.shape == (4, 257)
    np.testing.assert_allclose(fast, slow, rtol=2e-5, atol=2e-5)


def test_param_count_matches_leaves():
    for kind in ("dense", "hashed", "qr"):
        cfg = _cfg(kind=kind, vocab=2048)  # multiple of ROW_PAD: exact count
        params = QE.init(jax.random.PRNGKey(6), cfg)
        n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        assert n == cfg.param_count()


def test_qr_compression_factor():
    cfg = _cfg(vocab=64_000, collision=64)
    dense_elems = cfg.vocab * cfg.dim
    assert cfg.param_count() * 50 < dense_elems  # ~64x compression
