"""Tier-placement planner (the paper's allocation strategy)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import placement
from repro.data.synthetic import zipf_trace


def _counts(n=1000, seed=0):
    return placement.profile_counts(zipf_trace(n, 20_000, seed=seed), n)


def test_plan_covers_request_share():
    counts = _counts()
    plan = placement.plan_tiers(counts, request_share=0.8)
    assert plan.expected_hot_hit >= 0.8 - 1e-9
    # long-tail: covering 80% of requests needs well under 80% of rows
    assert plan.hot_fraction < 0.5


@given(share=st.floats(0.05, 0.99))
@settings(max_examples=20, deadline=None)
def test_plan_monotone_in_share(share):
    counts = _counts()
    lo = placement.plan_tiers(counts, request_share=share)
    hi = placement.plan_tiers(counts, request_share=min(0.99, share + 0.05))
    assert hi.num_hot >= lo.num_hot
    assert hi.expected_hot_hit >= lo.expected_hot_hit - 1e-12


def test_hot_fraction_and_cap():
    counts = _counts()
    plan = placement.plan_tiers(counts, hot_fraction=0.1)
    assert plan.num_hot == 100
    capped = placement.plan_tiers(counts, request_share=0.99, max_hot_rows=7)
    assert capped.num_hot == 7


def test_split_table_no_double_count():
    counts = _counts(100)
    plan = placement.plan_tiers(counts, request_share=0.5)
    table = jnp.arange(100 * 4, dtype=jnp.float32).reshape(100, 4) + 1.0
    hot, cold = placement.split_table(table, plan)
    assert hot.shape[0] == plan.num_hot
    # hot rows zeroed in cold; every row recoverable from exactly one tier
    recon = np.asarray(cold).copy()
    recon[plan.hot_rows] += np.asarray(hot)
    np.testing.assert_allclose(recon, np.asarray(table))
    assert np.all(np.asarray(cold)[plan.hot_rows] == 0)


def test_bandwidth_balanced_fraction_bounds():
    f = placement.bandwidth_balanced_fraction(counts=_counts())
    assert 0.0 <= f < 1.0
    # faster ICI -> smaller hot tier needed
    f_fast = placement.bandwidth_balanced_fraction(
        counts=_counts(), ici_gbps_per_link=200.0
    )
    assert f_fast <= f


def test_profile_counts_empty_trace():
    counts = placement.profile_counts(np.empty((0,), dtype=np.int64), 16)
    assert counts.shape == (16,) and counts.sum() == 0
    # planning over an all-zero profile is legal and replicates nothing useful
    plan = placement.plan_tiers(counts, request_share=0.8)
    assert plan.expected_hot_hit == 0.0
    assert plan.num_hot <= 16


def test_profile_counts_multi_dim_trace():
    trace = np.array([[1, 1], [3, 1]])
    counts = placement.profile_counts(trace, 5)
    assert counts.tolist() == [0, 3, 0, 1, 0]


def test_plan_tiers_uniform_counts():
    """No skew -> request share needs a proportional row share, and every
    hot-fraction choice hits exactly its fraction of requests."""
    counts = np.full(100, 7, dtype=np.int64)
    plan = placement.plan_tiers(counts, request_share=0.5)
    assert plan.num_hot == 50
    plan = placement.plan_tiers(counts, hot_fraction=0.2)
    assert plan.expected_hot_hit == pytest.approx(0.2)


def test_plan_tiers_single_row_table():
    counts = np.array([42], dtype=np.int64)
    plan = placement.plan_tiers(counts, request_share=0.8)
    assert plan.num_hot == 1
    assert plan.expected_hot_hit == 1.0
    assert plan.hot_slot.tolist() == [0]
    # hot_fraction rounding can't exceed the table
    plan = placement.plan_tiers(counts, hot_fraction=1.0)
    assert plan.num_hot == 1


def test_bandwidth_balanced_fraction_clamping():
    counts = _counts()
    # ICI faster than HBM -> no hot tier needed -> clamps at 0.0
    f = placement.bandwidth_balanced_fraction(
        counts=counts, hbm_gbps=100.0, ici_gbps_per_link=100.0, ici_links=4
    )
    assert f == 0.0
    # ICI vanishing -> everything must be local, clamped below 1.0
    f = placement.bandwidth_balanced_fraction(
        counts=counts, ici_gbps_per_link=1e-6
    )
    assert f == pytest.approx(0.999)
    # safety scales the cold share monotonically
    f_tight = placement.bandwidth_balanced_fraction(counts=counts, safety=0.5)
    f_loose = placement.bandwidth_balanced_fraction(counts=counts, safety=1.0)
    assert f_tight >= f_loose


def test_hot_vector_reduction_curve():
    """The paper's Fig. 12(a): quotient folding shrinks the hot set, but
    sub-linearly (hot rows are scattered, not clustered)."""
    logical = placement.profile_counts(zipf_trace(8192, 40_000, seed=1), 8192)
    curve = placement.hot_vector_reduction_curve(logical, [1, 4, 16, 64])
    assert curve[4] <= curve[1]
    assert curve[16] <= curve[4]
    assert curve[64] <= curve[16]
    # sub-linear: folding by 64 does NOT shrink hot rows by 64x
    assert curve[64] > curve[1] / 64
